"""Train the Llama2-nano model on the synthetic corpus and export artifacts.

Build-time only (invoked by `make artifacts`).  Produces, under artifacts/:

  nano_f32.lfck        fp32 checkpoint (LFCK)
  nano_q8.lfq8         W8A8 GS=256 checkpoint (LFQ8) — what the Rust engine loads
  loss_curve.csv       step,loss — the E2E training record (EXPERIMENTS.md)
  corpus_train.txt     training text (Rust PPL eval re-uses the val split)
  corpus_val.txt       held-out text for Table V PPL
  golden_prompt.txt    prompt used for golden generation
  golden_tokens.json   greedy token ids from the numpy reference engine
  golden_logits.bin    f32 per-step logits (steps x vocab) from the reference
  quant_error.json     Table IV statistics for the trained checkpoint

Usage: python -m compile.train --out ../artifacts [--steps 400]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, quantize
from .model import NANO, init_params, loss_fn
from .refmodel import RefEngine

GOLDEN_PROMPT = "the engineer builds"
GOLDEN_STEPS = 48


def batches(tokens: np.ndarray, batch: int, seq: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        x = np.stack([tokens[i: i + seq] for i in idx])
        y = np.stack([tokens[i + 1: i + seq + 1] for i in idx])
        yield jnp.asarray(x, jnp.int32), jnp.asarray(y, jnp.int32)


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t)
    vhat_scale = 1.0 / (1 - b2 ** t)

    def upd(p, m_, v_):
        return p - lr * (m_ * mhat_scale / (jnp.sqrt(v_ * vhat_scale) + eps) + wd * p)

    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = NANO
    train_text, val_text = corpus.train_val_split()
    with open(os.path.join(args.out, "corpus_train.txt"), "w") as f:
        f.write(train_text)
    with open(os.path.join(args.out, "corpus_val.txt"), "w") as f:
        f.write(val_text)
    tokens = np.asarray(corpus.encode(train_text), np.int32)
    print(f"corpus: {len(train_text)} chars -> {len(tokens)} tokens")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"nano model: {n_params/1e6:.2f}M params "
          f"(dim={cfg.dim} hidden={cfg.hidden_dim} layers={cfg.n_layers} "
          f"heads={cfg.n_heads}/{cfg.n_kv_heads} vocab={cfg.vocab_size})")

    @jax.jit
    def step(params, opt, x, y, lr):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, x, y))(params)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    opt = adamw_init(params)
    gen = batches(tokens, args.batch, args.seq, args.seed)
    curve = []
    t0 = time.time()
    warmup = max(10, args.steps // 20)
    for i in range(args.steps):
        # linear warmup then cosine decay
        if i < warmup:
            lr = args.lr * (i + 1) / warmup
        else:
            prog = (i - warmup) / max(1, args.steps - warmup)
            lr = args.lr * 0.5 * (1 + np.cos(np.pi * prog))
        x, y = next(gen)
        params, opt, loss = step(params, opt, x, y, lr)
        curve.append((i, float(loss)))
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}  lr {lr:.2e}  "
                  f"({time.time()-t0:.1f}s)")

    with open(os.path.join(args.out, "loss_curve.csv"), "w") as f:
        f.write("step,loss\n")
        for i, l in curve:
            f.write(f"{i},{l:.6f}\n")

    # -- export checkpoints ------------------------------------------------
    params_np = jax.tree.map(lambda t: np.asarray(t, np.float32), params)
    f32_path = os.path.join(args.out, "nano_f32.lfck")
    q8_path = os.path.join(args.out, "nano_q8.lfq8")
    quantize.write_f32(f32_path, cfg, params_np)
    qparams = quantize.quantize_checkpoint(cfg, params_np)
    quantize.write_q8(q8_path, cfg, qparams)
    print(f"wrote {f32_path} ({os.path.getsize(f32_path)/1e6:.1f} MB), "
          f"{q8_path} ({os.path.getsize(q8_path)/1e6:.1f} MB)")

    # -- Table IV statistics ----------------------------------------------
    stats = quantize.quant_error_stats(cfg, params_np)
    with open(os.path.join(args.out, "quant_error.json"), "w") as f:
        json.dump(stats, f, indent=2)
    print("quant error:", stats)

    # -- golden generation (numpy reference engine) ------------------------
    engine = RefEngine(cfg, qparams)
    prompt_ids = corpus.encode(GOLDEN_PROMPT)
    ids, logits = engine.generate(prompt_ids, GOLDEN_STEPS)
    with open(os.path.join(args.out, "golden_prompt.txt"), "w") as f:
        f.write(GOLDEN_PROMPT)
    with open(os.path.join(args.out, "golden_tokens.json"), "w") as f:
        json.dump({"prompt_ids": prompt_ids, "all_ids": ids,
                   "steps": GOLDEN_STEPS}, f)
    logits.astype("<f4").tofile(os.path.join(args.out, "golden_logits.bin"))
    print(f"golden: '{corpus.decode(ids)}'")
    print(f"train done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
