"""Layer-1 Pallas kernel: group-wise quantized matrix-vector multiply (GQMV).

This is the compute hot-spot the paper offloads to the FPGA PL.  The HLS
dataflow pipeline (paper §IV: pre-processing -> dot-product w/ adder tree ->
accumulate) is re-thought for a TPU-style memory hierarchy:

  * the `w_stream` DDR burst reads become the Pallas *grid*: each grid step
    DMAs one (TM, n) weight tile HBM->VMEM (double-buffered by the Pallas
    pipeline — the analogue of DATAFLOW stage overlap);
  * the BRAM-cached activation becomes a VMEM-resident block whose
    index_map is constant (loaded once, reused every step);
  * the GS-lane SIMD multiply + depth-8 adder tree becomes a vectorized
    int16 multiply with an int32 group reduction;
  * the gradual INT8 -> INT16 -> INT32 -> FP32 cast chain is kept verbatim
    so results are bit-identical with the hardware algorithm (ref.py).

interpret=True is REQUIRED on this image: the CPU PJRT plugin cannot run
Mosaic custom-calls, so the kernel lowers to plain HLO.  See DESIGN.md
§Hardware-Adaptation for the VMEM/MXU analysis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Max rows of W processed per grid step.  256 rows x n=5632 int8 = 1.4 MiB
# per tile: two in-flight tiles (double buffering) + x + scales stay well
# under a real TPU core's ~16 MiB VMEM.  Perf note (EXPERIMENTS.md §Perf):
# the CPU-PJRT execution of the lowered grid loop costs ~20-30 us per grid
# step, so the TILE_M sweep 8 -> 64 -> 128 -> 256 cut kernel latency up to
# ~13x at identical numerics; on a real TPU the same change trades grid
# parallelism for VMEM pressure and stays comfortably inside budget.
TILE_M = 256


def _pick_tile(m: int) -> int:
    """Largest tile <= TILE_M dividing m (every Algorithm-2 shape is a
    multiple of 256; smaller test shapes fall back to their own divisors)."""
    t = min(TILE_M, m)
    while m % t:
        t -= 1
    return t


def _gqmv_kernel(xq_ref, xs_ref, wq_ref, ws_ref, out_ref, *, gs: int):
    """One grid step: out[TM] for a (TM, n) weight tile.

    Cast chain mirrors the FPGA datapath:
      int8 -> int16 (pre-processing stage casts both operands),
      int16 * int16 products (|p| <= 127*127 fits int16),
      int32 group sums (adder tree's first layer widens),
      fp32 scale & accumulate.
    """
    n = wq_ref.shape[1]
    g = n // gs
    w16 = wq_ref[...].astype(jnp.int16)             # (TM, n)
    x16 = xq_ref[...].astype(jnp.int16)             # (n,)
    prod = w16 * x16[None, :]                       # (TM, n) int16
    gsum = jnp.sum(
        prod.reshape(prod.shape[0], g, gs).astype(jnp.int32), axis=2
    )                                               # (TM, g) int32
    scale = ws_ref[...] * xs_ref[...][None, :]      # (TM, g) f32
    out_ref[...] = jnp.sum(gsum.astype(jnp.float32) * scale, axis=1)


@functools.partial(jax.jit, static_argnames=("gs",))
def gqmv(xq, xs, wq, ws, *, gs: int = 256):
    """Group-wise quantized matvec via Pallas.

    Args:
      xq: int8[n]        quantized activation
      xs: f32[n//gs]     activation group scales
      wq: int8[m, n]     quantized weight matrix (row-major)
      ws: f32[m, n//gs]  weight group scales
      gs: group size (static)

    Returns f32[m].
    """
    m, n = wq.shape
    assert n % gs == 0, f"n={n} must be a multiple of GS={gs}"
    tile = _pick_tile(m)
    g = n // gs
    grid = (m // tile,)
    return pl.pallas_call(
        functools.partial(_gqmv_kernel, gs=gs),
        grid=grid,
        in_specs=[
            # activation: same block every step -> resident in VMEM (BRAM analogue)
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((g,), lambda i: (0,)),
            # weights/scales: streamed one row-tile per step (w_stream analogue)
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((tile, g), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(xq, xs, wq, ws)


def quantize_jnp(r, gs: int):
    """jnp twin of ref.quantize (round-half-away, symmetric, group-wise).

    Used by the L2 model so activation quantization lowers into the same
    HLO module as the kernel call.
    """
    flat = r.reshape(-1)
    groups = flat.reshape(-1, gs)
    gmax = jnp.max(jnp.abs(groups), axis=1)
    scales = (gmax / 127.0).astype(jnp.float32)
    safe = jnp.where(scales == 0.0, 1.0, scales)
    q = jnp.sign(groups / safe[:, None]) * jnp.floor(
        jnp.abs(groups / safe[:, None]) + 0.5
    )
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q.reshape(r.shape), scales


def gqmv_fused(x, wq, ws, *, gs: int = 256):
    """Run-time quantization of x fused with the GQMV kernel (paper §III-A:
    'run-time quantization of inference parameters')."""
    xq, xs = quantize_jnp(x, gs)
    return gqmv(xq, xs, wq, ws, gs=gs)
