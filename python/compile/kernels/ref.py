"""Pure-jnp / numpy oracle for group-wise quantized matrix-vector multiply.

This mirrors the paper's Algorithm 1 (GQMV) exactly, including the cast
chain the hardware uses (INT8 -> INT16 products -> INT32 group sums ->
FP32 scaled accumulation).  It is the single source of truth: the Pallas
kernel (gqmv.py), the JAX model (model.py), the numpy reference engine
(refmodel.py) and the Rust implementations are all tested against it.

Quantization scheme (symmetric, group-wise, W8A8 as in paper Eq. 1-2):

    S    = max(|r|_group) / 127.0
    q    = clip(round_half_away(r / S), -127, 127)      (int8)
    rhat = q * S

The paper writes S = 2*max|r|/255 (= max|r|/127.5); we use the llama2.c
convention max|r|/127 so that +max quantizes exactly to +127.  The error
characteristics (Table IV) are statistically identical; see DESIGN.md §5.
"""

from __future__ import annotations

import numpy as np


def round_half_away(x: np.ndarray) -> np.ndarray:
    """Round half away from zero (matches Rust f32::round, not numpy's
    banker's rounding)."""
    return np.sign(x) * np.floor(np.abs(x) + 0.5)


def quantize(r: np.ndarray, gs: int) -> tuple[np.ndarray, np.ndarray]:
    """Group-wise symmetric INT8 quantization of a flat array.

    Returns (q int8[shape], scales f32[size // gs]).
    """
    r = np.asarray(r, dtype=np.float32)
    flat = r.reshape(-1)
    assert flat.size % gs == 0, f"size {flat.size} not divisible by GS={gs}"
    groups = flat.reshape(-1, gs)
    gmax = np.max(np.abs(groups), axis=1)
    scales = (gmax / 127.0).astype(np.float32)
    safe = np.where(scales == 0.0, 1.0, scales)
    q = round_half_away(groups / safe[:, None])
    q = np.clip(q, -127, 127).astype(np.int8)
    return q.reshape(r.shape), scales


def dequantize(q: np.ndarray, scales: np.ndarray, gs: int) -> np.ndarray:
    """Inverse of quantize (Eq. 2)."""
    flat = q.reshape(-1).astype(np.float32)
    groups = flat.reshape(-1, gs)
    out = groups * np.asarray(scales, np.float32)[:, None]
    return out.reshape(q.shape).astype(np.float32)


def gqmv_ref(
    xq: np.ndarray,
    xs: np.ndarray,
    wq: np.ndarray,
    ws: np.ndarray,
    gs: int,
) -> np.ndarray:
    """Algorithm 1: out[i] = sum_g (sum_k xq[g*GS+k] * wq[i,g*GS+k]) * ws[i,g] * xs[g].

    xq: int8[n], xs: f32[n//gs], wq: int8[m, n], ws: f32[m, n//gs].
    Returns f32[m].  Group sums are exact int32; the scaled accumulation is
    f32 in ascending group order (matching the sequential hardware
    accumulate stage).
    """
    m, n = wq.shape
    g = n // gs
    assert xq.shape == (n,)
    assert xs.shape == (g,)
    assert ws.shape == (m, g)
    # INT16 products (8b x 8b fits 16b: |q| <= 127 so |prod| <= 16129),
    # INT32 group sums (adder tree first layer casts to int32).
    prod = wq.astype(np.int16) * xq.astype(np.int16)[None, :]
    gsum = prod.reshape(m, g, gs).astype(np.int32).sum(axis=2)
    # float_scale = ws * xs FIRST (the hardware's accumulate stage, §IV-D),
    # then applied to the group sums — matches the Pallas kernel and every
    # Rust backend bit-for-bit.
    scaled = gsum.astype(np.float32) * (ws * xs[None, :].astype(np.float32))
    # Sequential accumulation over groups, mirroring the accumulate stage.
    out = np.zeros(m, dtype=np.float32)
    for j in range(g):
        out += scaled[:, j]
    return out


def gqmv_dequant_ref(x: np.ndarray, w: np.ndarray, gs: int) -> np.ndarray:
    """Float reference: quantize both operands, run GQMV.  Convenience for
    end-to-end accuracy tests (how far is quantized matvec from w @ x)."""
    xq, xs = quantize(x, gs)
    wq, ws = quantize(w, gs)
    return gqmv_ref(xq, xs, wq, ws.reshape(w.shape[0], -1), gs)
