"""Deterministic synthetic corpus generator.

The image is offline (no WikiText-2 / SQuAD), so all language-modeling
experiments use a synthetic English-like corpus produced by a small seeded
template grammar.  The corpus is deterministic (seed 42), byte-level
tokenizable, and has enough structure (agreement, templates, punctuation,
numerals) that a ~4M-parameter model's cross-entropy drops well below the
uniform baseline — making the W32A32 vs W8A8 PPL comparison (Table V)
meaningful.  See DESIGN.md §5 (substitution 3).
"""

from __future__ import annotations

import numpy as np

SUBJECTS = [
    "the engineer", "a student", "the quick fox", "the old captain",
    "my neighbor", "the tall robot", "a young writer", "the museum guide",
    "the ship's crew", "an honest merchant", "the night watchman",
    "the curious child", "a wandering monk", "the village baker",
]
VERBS = [
    "builds", "sees", "repairs", "studies", "paints", "measures",
    "describes", "follows", "carries", "designs", "observes", "records",
    "collects", "examines",
]
OBJECTS = [
    "a small bridge", "the broken clock", "an ancient map", "the wooden boat",
    "a copper wire", "the stone tower", "a paper lantern", "the silver coin",
    "an iron gate", "the glass prism", "a woolen coat", "the marble statue",
]
PLACES = [
    "near the river", "in the market", "behind the hill", "at the harbor",
    "under the bridge", "inside the library", "by the old mill",
    "along the coast", "in the valley", "on the mountain",
]
CONNECTIVES = ["and then", "because", "while", "although", "so", "after that"]
QUESTION_WORDS = ["what", "where", "when", "who", "why", "how"]


def _sentence(rng: np.random.Generator) -> str:
    s = rng.choice(SUBJECTS)
    v = rng.choice(VERBS)
    o = rng.choice(OBJECTS)
    p = rng.choice(PLACES)
    form = rng.integers(0, 5)
    if form == 0:
        return f"{s} {v} {o} {p}."
    if form == 1:
        return f"{s} {v} {o}."
    if form == 2:
        c = rng.choice(CONNECTIVES)
        s2, v2, o2 = rng.choice(SUBJECTS), rng.choice(VERBS), rng.choice(OBJECTS)
        return f"{s} {v} {o} {c} {s2} {v2} {o2}."
    if form == 3:
        q = rng.choice(QUESTION_WORDS)
        return f"{q} does {s} {v.removesuffix('s')} {o}? {s} {v} {o} {p}."
    n = int(rng.integers(2, 100))
    return f"{s} {v} {n} of {o.split(' ', 1)[1]} {p}."


def generate(n_bytes: int, seed: int = 42) -> str:
    """Generate at least n_bytes of corpus text."""
    rng = np.random.default_rng(seed)
    parts: list[str] = []
    size = 0
    while size < n_bytes:
        para_len = int(rng.integers(3, 9))
        para = " ".join(_sentence(rng) for _ in range(para_len))
        parts.append(para)
        size += len(para) + 2
    return "\n\n".join(parts)


def train_val_split(n_train: int = 262144, n_val: int = 32768, seed: int = 42):
    """Disjoint train/val texts (different seeds => different samples)."""
    return generate(n_train, seed=seed), generate(n_val, seed=seed + 1)


# --- byte-level tokenizer (mirrored exactly by rust/src/tokenizer) ---------
PAD_ID, BOS_ID, EOS_ID = 0, 1, 2
BYTE_OFFSET = 3  # token id of byte b is b + 3


def encode(text: str, bos: bool = True) -> list[int]:
    ids = [BOS_ID] if bos else []
    ids.extend(b + BYTE_OFFSET for b in text.encode("utf-8"))
    return ids


def decode(ids: list[int]) -> str:
    data = bytes(i - BYTE_OFFSET for i in ids if i >= BYTE_OFFSET)
    return data.decode("utf-8", errors="replace")
