"""Layer-2: Llama2-architecture model in JAX.

Two forwards:
  * `forward_float`  — fp32 training/eval forward over a whole sequence
    (used by train.py and the W32A32 PPL row of Table V);
  * `forward_quant`  — W8A8 forward whose every matrix-vector product goes
    through the Pallas GQMV kernel (kernels/gqmv.py), exactly as the FPGA
    path does: weights pre-quantized (post-training), activations quantized
    at run time (paper §III-A).

The architecture matches the paper's Fig. 1 / Table I: RMSNorm, fused QKV
projection, RoPE, GQA attention, SwiGLU FFN, final RMSNorm + classifier.
RoPE uses the llama2.c interleaved-pair convention, which the Rust engines
mirror exactly.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.gqmv import gqmv
from .kernels import ref

RMS_EPS = 1e-5
ROPE_THETA = 10000.0


@dataclass(frozen=True)
class LlamaConfig:
    dim: int
    hidden_dim: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    vocab_size: int
    seq_len: int
    gs: int = 256

    @property
    def head_dim(self) -> int:
        return self.dim // self.n_heads

    @property
    def kv_dim(self) -> int:
        return self.head_dim * self.n_kv_heads

    def validate(self) -> None:
        assert self.dim % self.n_heads == 0
        assert self.n_heads % self.n_kv_heads == 0
        for name in ("dim", "hidden_dim", "vocab_size"):
            v = getattr(self, name)
            assert v % self.gs == 0, f"{name}={v} not divisible by GS={self.gs}"


# The E2E model: every Llama2 feature, dims divisible by GS=256.
NANO = LlamaConfig(dim=256, hidden_dim=768, n_layers=4, n_heads=4,
                   n_kv_heads=2, vocab_size=512, seq_len=256)

# The paper's TinyLlama 1.1B geometry (perf experiments use synthetic weights).
TINYLLAMA_1_1B = LlamaConfig(dim=2048, hidden_dim=5632, n_layers=22,
                             n_heads=32, n_kv_heads=4, vocab_size=32000,
                             seq_len=2048)


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------

def init_params(cfg: LlamaConfig, key: jax.Array) -> dict:
    """Scaled-normal init (GPT-2 style residual scaling)."""
    cfg.validate()
    std = 0.02
    res_std = std / np.sqrt(2 * cfg.n_layers)

    def norm(k, shape, s):
        return (jax.random.normal(k, shape, dtype=jnp.float32) * s)

    keys = iter(jax.random.split(key, 4 + 9 * cfg.n_layers))
    params = {
        "tok_emb": norm(next(keys), (cfg.vocab_size, cfg.dim), std),
        "layers": [],
        "final_norm": jnp.ones((cfg.dim,), jnp.float32),
        "cls": norm(next(keys), (cfg.vocab_size, cfg.dim), std),
    }
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "att_norm": jnp.ones((cfg.dim,), jnp.float32),
            "wq": norm(next(keys), (cfg.dim, cfg.dim), std),
            "wk": norm(next(keys), (cfg.kv_dim, cfg.dim), std),
            "wv": norm(next(keys), (cfg.kv_dim, cfg.dim), std),
            "wo": norm(next(keys), (cfg.dim, cfg.dim), res_std),
            "ffn_norm": jnp.ones((cfg.dim,), jnp.float32),
            "w1": norm(next(keys), (cfg.hidden_dim, cfg.dim), std),
            "w2": norm(next(keys), (cfg.dim, cfg.hidden_dim), res_std),
            "w3": norm(next(keys), (cfg.hidden_dim, cfg.dim), std),
        })
    return params


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array) -> jax.Array:
    ss = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ss + RMS_EPS) * w


def rope_angles(cfg: LlamaConfig, positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(cos, sin) of shape (T, head_dim//2), llama2.c frequency layout."""
    half = cfg.head_dim // 2
    freqs = ROPE_THETA ** (-jnp.arange(0, half, dtype=jnp.float32) * 2.0 / cfg.head_dim)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (T, H, head_dim) with interleaved (even, odd) pairs."""
    x0 = x[..., 0::2]
    x1 = x[..., 1::2]
    c = cos[:, None, :]
    s = sin[:, None, :]
    r0 = x0 * c - x1 * s
    r1 = x0 * s + x1 * c
    out = jnp.stack([r0, r1], axis=-1)  # (T, H, half, 2)
    return out.reshape(x.shape)


# --------------------------------------------------------------------------
# float forward (training / W32A32 eval)
# --------------------------------------------------------------------------

def forward_float(cfg: LlamaConfig, params: dict, tokens: jax.Array) -> jax.Array:
    """tokens: int32 (B, T) -> logits (B, T, vocab)."""
    B, T = tokens.shape
    x = params["tok_emb"][tokens]  # (B, T, dim)
    positions = jnp.arange(T)
    cos, sin = rope_angles(cfg, positions)
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    rep = cfg.n_heads // cfg.n_kv_heads

    for layer in params["layers"]:
        xb = rmsnorm(x, layer["att_norm"])
        q = xb @ layer["wq"].T  # (B, T, dim)
        k = xb @ layer["wk"].T  # (B, T, kv_dim)
        v = xb @ layer["wv"].T
        q = q.reshape(B, T, cfg.n_heads, cfg.head_dim)
        k = k.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = v.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        q = jax.vmap(apply_rope, in_axes=(0, None, None))(q, cos, sin)
        k = jax.vmap(apply_rope, in_axes=(0, None, None))(k, cos, sin)
        # GQA: expand kv heads
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
        att = jnp.einsum("bthd,bshd->bhts", q, k) / np.sqrt(cfg.head_dim)
        att = jnp.where(mask[None, None, :, :], att, -jnp.inf)
        att = jax.nn.softmax(att, axis=-1)
        out = jnp.einsum("bhts,bshd->bthd", att, v).reshape(B, T, cfg.dim)
        x = x + out @ layer["wo"].T

        xb = rmsnorm(x, layer["ffn_norm"])
        h1 = xb @ layer["w1"].T
        h3 = xb @ layer["w3"].T
        h = jax.nn.silu(h1) * h3
        x = x + h @ layer["w2"].T

    x = rmsnorm(x, params["final_norm"])
    return x @ params["cls"].T


def loss_fn(cfg: LlamaConfig, params: dict, tokens: jax.Array, targets: jax.Array) -> jax.Array:
    logits = forward_float(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    valid = targets != 0  # PAD
    return -(ll * valid).sum() / valid.sum()


# --------------------------------------------------------------------------
# quantized forward (single token, KV cache) — the LlamaF datapath in JAX
# --------------------------------------------------------------------------

def quantize_params(cfg: LlamaConfig, params: dict) -> dict:
    """Post-training W8A8 quantization of all matrix weights (Table I: norm
    vectors stay fp32). numpy-side; returns int8 data + f32 scales."""
    gs = cfg.gs

    def q(t):
        arr = np.asarray(t, np.float32)
        qd, sc = ref.quantize(arr, gs)
        return {"q": qd, "s": sc.reshape(arr.shape[0], -1)}

    out = {
        "tok_emb": q(params["tok_emb"]),
        "layers": [],
        "final_norm": np.asarray(params["final_norm"], np.float32),
        "cls": q(params["cls"]),
    }
    for layer in params["layers"]:
        out["layers"].append({
            "att_norm": np.asarray(layer["att_norm"], np.float32),
            "wq": q(layer["wq"]), "wk": q(layer["wk"]), "wv": q(layer["wv"]),
            "wo": q(layer["wo"]),
            "ffn_norm": np.asarray(layer["ffn_norm"], np.float32),
            "w1": q(layer["w1"]), "w2": q(layer["w2"]), "w3": q(layer["w3"]),
        })
    return out


def _quantize_act(x: jax.Array, gs: int):
    groups = x.reshape(-1, gs)
    gmax = jnp.max(jnp.abs(groups), axis=1)
    scales = (gmax / 127.0).astype(jnp.float32)
    safe = jnp.where(scales == 0.0, 1.0, scales)
    g = groups / safe[:, None]
    q = jnp.clip(jnp.sign(g) * jnp.floor(jnp.abs(g) + 0.5), -127, 127)
    return q.reshape(x.shape).astype(jnp.int8), scales


def forward_quant_step(cfg: LlamaConfig, qparams: dict, token: int,
                       pos: int, kcache: np.ndarray, vcache: np.ndarray) -> np.ndarray:
    """One decode step of the quantized model; every matvec runs the Pallas
    GQMV kernel.  kcache/vcache: (n_layers, seq_len, kv_dim), updated in
    place.  Returns logits f32[vocab].  Mirrors Algorithm 2 line by line."""
    gs = cfg.gs
    emb = qparams["tok_emb"]
    x = ref.dequantize(emb["q"][token], emb["s"][token], gs).astype(np.float32)

    half = cfg.head_dim // 2
    freqs = ROPE_THETA ** (-np.arange(half, dtype=np.float32) * 2.0 / cfg.head_dim)
    cos = np.cos(pos * freqs).astype(np.float32)
    sin = np.sin(pos * freqs).astype(np.float32)
    rep = cfg.n_heads // cfg.n_kv_heads

    def kernel(xv, wdict):
        xq, xs = _quantize_act(jnp.asarray(xv), gs)
        out = gqmv(xq, xs, jnp.asarray(wdict["q"]), jnp.asarray(wdict["s"]), gs=gs)
        return np.asarray(out)

    def kernel_fused(xv, wdicts):
        wq = np.concatenate([w["q"] for w in wdicts], axis=0)
        ws = np.concatenate([w["s"] for w in wdicts], axis=0)
        xq, xs = _quantize_act(jnp.asarray(xv), gs)
        return np.asarray(gqmv(xq, xs, jnp.asarray(wq), jnp.asarray(ws), gs=gs))

    def rope(vec):
        v = vec.reshape(-1, cfg.head_dim).copy()
        v0, v1 = v[:, 0::2].copy(), v[:, 1::2].copy()
        v[:, 0::2] = v0 * cos - v1 * sin
        v[:, 1::2] = v0 * sin + v1 * cos
        return v.reshape(vec.shape)

    for li, layer in enumerate(qparams["layers"]):
        xb = _rmsnorm_np(x, layer["att_norm"])
        qkv = kernel_fused(xb, [layer["wq"], layer["wk"], layer["wv"]])  # Alg.2 l.4
        q, k, v = qkv[:cfg.dim], qkv[cfg.dim:cfg.dim + cfg.kv_dim], qkv[cfg.dim + cfg.kv_dim:]
        q, k = rope(q), rope(k)                                          # Alg.2 l.5
        kcache[li, pos] = k
        vcache[li, pos] = v
        att_out = np.zeros(cfg.dim, np.float32)
        qh = q.reshape(cfg.n_heads, cfg.head_dim)
        kh = kcache[li, : pos + 1].reshape(pos + 1, cfg.n_kv_heads, cfg.head_dim)
        vh = vcache[li, : pos + 1].reshape(pos + 1, cfg.n_kv_heads, cfg.head_dim)
        for h in range(cfg.n_heads):                                     # Alg.2 l.7
            kv_h = h // rep
            scores = kh[:, kv_h] @ qh[h] / np.sqrt(cfg.head_dim)
            scores = scores - scores.max()
            p = np.exp(scores)
            p /= p.sum()
            att_out[h * cfg.head_dim:(h + 1) * cfg.head_dim] = p @ vh[:, kv_h]
        x = x + kernel(att_out, layer["wo"])                             # Alg.2 l.9-10

        xb = _rmsnorm_np(x, layer["ffn_norm"])
        h13 = kernel_fused(xb, [layer["w1"], layer["w3"]])               # Alg.2 l.12
        h1, h3 = h13[:cfg.hidden_dim], h13[cfg.hidden_dim:]
        h = h1 / (1.0 + np.exp(-h1)) * h3                                # SwiGLU
        x = x + kernel(h, layer["w2"])                                   # Alg.2 l.14-15

    x = _rmsnorm_np(x, qparams["final_norm"])
    return kernel(x, qparams["cls"])                                     # Alg.2 l.17


def _rmsnorm_np(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    ss = float(np.mean(x.astype(np.float32) ** 2))
    return (x / np.sqrt(ss + RMS_EPS) * w).astype(np.float32)
