"""Pure-numpy incremental (KV-cached) quantized inference engine.

This is the executable specification of the Rust engines: the exact op
order, cast chain, RoPE convention and accumulation order that
`rust/src/ps` and `rust/src/engine` implement.  train.py uses it to export
golden tokens/logits that the Rust integration tests compare against.

It differs from model.forward_quant_step only in the GQMV backend (numpy
ref.gqmv_ref instead of the Pallas kernel); the two are asserted equal in
python/tests/test_model.py.
"""

from __future__ import annotations

import numpy as np

from .kernels import ref
from .model import LlamaConfig, RMS_EPS, ROPE_THETA


class QuantWeight:
    """int8 data + per-group f32 scales for one matrix (m, n)."""

    __slots__ = ("q", "s")

    def __init__(self, q: np.ndarray, s: np.ndarray):
        self.q = q  # int8 (m, n)
        self.s = s  # f32  (m, n // gs)

    @staticmethod
    def from_float(w: np.ndarray, gs: int) -> "QuantWeight":
        q, s = ref.quantize(w, gs)
        return QuantWeight(q, s.reshape(w.shape[0], -1))

    def concat(self, *others: "QuantWeight") -> "QuantWeight":
        """Row-wise fusion (the paper concatenates Wq/Wk/Wv and W1/W3)."""
        return QuantWeight(
            np.concatenate([self.q] + [o.q for o in others], axis=0),
            np.concatenate([self.s] + [o.s for o in others], axis=0),
        )


class RefEngine:
    """Numpy twin of the Rust LlamaF/PS engine."""

    def __init__(self, cfg: LlamaConfig, qparams: dict):
        self.cfg = cfg
        self.p = qparams
        half = cfg.head_dim // 2
        self.freqs = ROPE_THETA ** (
            -np.arange(half, dtype=np.float32) * 2.0 / cfg.head_dim
        )
        self.reset()

    def reset(self) -> None:
        c = self.cfg
        self.kcache = np.zeros((c.n_layers, c.seq_len, c.kv_dim), np.float32)
        self.vcache = np.zeros((c.n_layers, c.seq_len, c.kv_dim), np.float32)

    # -- ops (all mirrored in rust/src/ps/ops.rs) ------------------------
    @staticmethod
    def rmsnorm(x: np.ndarray, w: np.ndarray) -> np.ndarray:
        ss = float(np.mean(x.astype(np.float32) ** 2))
        return (x / np.sqrt(ss + RMS_EPS) * w).astype(np.float32)

    def rope(self, vec: np.ndarray, pos: int) -> np.ndarray:
        cos = np.cos(pos * self.freqs).astype(np.float32)
        sin = np.sin(pos * self.freqs).astype(np.float32)
        v = vec.reshape(-1, self.cfg.head_dim).copy()
        v0, v1 = v[:, 0::2].copy(), v[:, 1::2].copy()
        v[:, 0::2] = v0 * cos - v1 * sin
        v[:, 1::2] = v0 * sin + v1 * cos
        return v.reshape(vec.shape)

    def gqmv(self, x: np.ndarray, w) -> np.ndarray:
        gs = self.cfg.gs
        xq, xs = ref.quantize(x, gs)
        if isinstance(w, dict):
            wq, ws = w["q"], w["s"]
        else:
            wq, ws = w.q, w.s
        return ref.gqmv_ref(xq, xs, wq, ws, gs)

    # -- Algorithm 2 ------------------------------------------------------
    def forward(self, token: int, pos: int) -> np.ndarray:
        c, p, gs = self.cfg, self.p, self.cfg.gs
        emb = p["tok_emb"]
        x = ref.dequantize(emb["q"][token], emb["s"][token], gs)
        rep = c.n_heads // c.n_kv_heads

        for li, layer in enumerate(p["layers"]):
            xb = self.rmsnorm(x, layer["att_norm"])
            wqkv = QuantWeight(layer["wq"]["q"], layer["wq"]["s"]).concat(
                QuantWeight(layer["wk"]["q"], layer["wk"]["s"]),
                QuantWeight(layer["wv"]["q"], layer["wv"]["s"]),
            )
            qkv = self.gqmv(xb, wqkv)
            q = qkv[: c.dim]
            k = qkv[c.dim: c.dim + c.kv_dim]
            v = qkv[c.dim + c.kv_dim:]
            q, k = self.rope(q, pos), self.rope(k, pos)
            self.kcache[li, pos] = k
            self.vcache[li, pos] = v

            att_out = np.zeros(c.dim, np.float32)
            qh = q.reshape(c.n_heads, c.head_dim)
            kh = self.kcache[li, : pos + 1].reshape(pos + 1, c.n_kv_heads, c.head_dim)
            vh = self.vcache[li, : pos + 1].reshape(pos + 1, c.n_kv_heads, c.head_dim)
            for h in range(c.n_heads):
                kv_h = h // rep
                scores = kh[:, kv_h] @ qh[h] / np.sqrt(c.head_dim)
                scores = scores - scores.max()
                pr = np.exp(scores)
                pr /= pr.sum()
                att_out[h * c.head_dim:(h + 1) * c.head_dim] = pr @ vh[:, kv_h]
            x = x + self.gqmv(att_out, layer["wo"])

            xb = self.rmsnorm(x, layer["ffn_norm"])
            w13 = QuantWeight(layer["w1"]["q"], layer["w1"]["s"]).concat(
                QuantWeight(layer["w3"]["q"], layer["w3"]["s"])
            )
            h13 = self.gqmv(xb, w13)
            h1, h3 = h13[: c.hidden_dim], h13[c.hidden_dim:]
            h = (h1 / (1.0 + np.exp(-h1)) * h3).astype(np.float32)
            x = x + self.gqmv(h, layer["w2"])

        x = self.rmsnorm(x, p["final_norm"])
        return self.gqmv(x, p["cls"])

    def generate(self, prompt_ids: list[int], steps: int) -> tuple[list[int], np.ndarray]:
        """Greedy generation (paper §V-C: greedy sampling, no EOS stop).

        Returns (all token ids, per-step logits (steps, vocab))."""
        self.reset()
        ids = list(prompt_ids)
        logits_log = []
        pos = 0
        # consume prompt
        for t in ids[:-1]:
            self.forward(t, pos)
            pos += 1
        cur = ids[-1]
        for _ in range(steps):
            logits = self.forward(cur, pos)
            logits_log.append(logits.copy())
            cur = int(np.argmax(logits))
            ids.append(cur)
            pos += 1
        return ids, np.stack(logits_log) if logits_log else np.zeros((0, self.cfg.vocab_size), np.float32)
