"""Checkpoint formats + post-training quantizer.

Two little-endian binary formats, mirrored bit-for-bit by rust/src/ckpt:

LFCK (float32 checkpoint)
  magic  b"LFCK"
  u32    version (=1)
  u32 x8 dim, hidden_dim, n_layers, n_heads, n_kv_heads, vocab_size,
         seq_len, gs
  f32    tok_emb      (vocab, dim)
  per layer l in 0..n_layers:
    f32  att_norm (dim)
    f32  wq (dim, dim)   wk (kv_dim, dim)   wv (kv_dim, dim)   wo (dim, dim)
    f32  ffn_norm (dim)
    f32  w1 (hidden, dim)   w2 (dim, hidden)   w3 (hidden, dim)
  f32    final_norm (dim)
  f32    cls          (vocab, dim)

LFQ8 (W8A8 group-quantized checkpoint, GS from header)
  magic  b"LFQ8"; same header fields.
  Quantized tensors are stored as   i8 data  then  f32 scales (size/gs).
  Norm vectors stay f32 (Table I: RMSNorm weights are not quantized).
  Tensor order identical to LFCK.  The per-layer grouping is what lets the
  Rust engine stream one layer at a time (paper §III-B: sequential buffer
  loads, 111.5 MB instead of 1.1 GB resident).
"""

from __future__ import annotations

import struct

import numpy as np

from .kernels import ref
from .model import LlamaConfig

MAGIC_F32 = b"LFCK"
MAGIC_Q8 = b"LFQ8"
VERSION = 1


def _header(magic: bytes, cfg: LlamaConfig) -> bytes:
    return magic + struct.pack(
        "<9I", VERSION, cfg.dim, cfg.hidden_dim, cfg.n_layers, cfg.n_heads,
        cfg.n_kv_heads, cfg.vocab_size, cfg.seq_len, cfg.gs,
    )


def _parse_header(data: bytes, magic: bytes) -> tuple[LlamaConfig, int]:
    assert data[:4] == magic, f"bad magic {data[:4]!r}, want {magic!r}"
    (version, dim, hidden, n_layers, n_heads, n_kv, vocab, seq, gs) = struct.unpack(
        "<9I", data[4:40]
    )
    assert version == VERSION
    cfg = LlamaConfig(dim=dim, hidden_dim=hidden, n_layers=n_layers,
                      n_heads=n_heads, n_kv_heads=n_kv, vocab_size=vocab,
                      seq_len=seq, gs=gs)
    return cfg, 40


def _tensor_order(cfg: LlamaConfig):
    """Yield (path, shape, quantized?) in file order."""
    yield ("tok_emb", (cfg.vocab_size, cfg.dim), True)
    for li in range(cfg.n_layers):
        yield (f"layers.{li}.att_norm", (cfg.dim,), False)
        yield (f"layers.{li}.wq", (cfg.dim, cfg.dim), True)
        yield (f"layers.{li}.wk", (cfg.kv_dim, cfg.dim), True)
        yield (f"layers.{li}.wv", (cfg.kv_dim, cfg.dim), True)
        yield (f"layers.{li}.wo", (cfg.dim, cfg.dim), True)
        yield (f"layers.{li}.ffn_norm", (cfg.dim,), False)
        yield (f"layers.{li}.w1", (cfg.hidden_dim, cfg.dim), True)
        yield (f"layers.{li}.w2", (cfg.dim, cfg.hidden_dim), True)
        yield (f"layers.{li}.w3", (cfg.hidden_dim, cfg.dim), True)
    yield ("final_norm", (cfg.dim,), False)
    yield ("cls", (cfg.vocab_size, cfg.dim), True)


def _get(params: dict, path: str):
    cur = params
    for part in path.split("."):
        cur = cur[int(part)] if part.isdigit() else cur[part]
    return cur


def _set(params: dict, path: str, value) -> None:
    parts = path.split(".")
    cur = params
    for part in parts[:-1]:
        key = int(part) if part.isdigit() else part
        if isinstance(key, int):
            while len(cur) <= key:
                cur.append({})
            cur = cur[key]
        else:
            cur = cur.setdefault(key, [] if key == "layers" else {})
    cur[parts[-1]] = value


def write_f32(path: str, cfg: LlamaConfig, params: dict) -> None:
    with open(path, "wb") as f:
        f.write(_header(MAGIC_F32, cfg))
        for name, shape, _ in _tensor_order(cfg):
            t = np.asarray(_get(params, name), np.float32)
            assert t.shape == shape, f"{name}: {t.shape} != {shape}"
            f.write(t.astype("<f4").tobytes())


def read_f32(path: str) -> tuple[LlamaConfig, dict]:
    data = open(path, "rb").read()
    cfg, off = _parse_header(data, MAGIC_F32)
    params: dict = {"layers": []}
    for name, shape, _ in _tensor_order(cfg):
        count = int(np.prod(shape))
        t = np.frombuffer(data, "<f4", count, off).reshape(shape).copy()
        off += 4 * count
        _set(params, name, t)
    assert off == len(data), f"trailing bytes: {len(data) - off}"
    return cfg, params


def quantize_checkpoint(cfg: LlamaConfig, params: dict) -> dict:
    """Post-training W8A8 quantization (weights only; Table I)."""
    qparams: dict = {"layers": []}
    for name, shape, quant in _tensor_order(cfg):
        t = np.asarray(_get(params, name), np.float32)
        if quant:
            q, s = ref.quantize(t, cfg.gs)
            _set(qparams, name, {"q": q, "s": s.reshape(shape[0], -1)})
        else:
            _set(qparams, name, t)
    return qparams


def write_q8(path: str, cfg: LlamaConfig, qparams: dict) -> None:
    with open(path, "wb") as f:
        f.write(_header(MAGIC_Q8, cfg))
        for name, shape, quant in _tensor_order(cfg):
            t = _get(qparams, name)
            if quant:
                q = np.ascontiguousarray(t["q"], dtype=np.int8)
                s = np.ascontiguousarray(t["s"], dtype="<f4")
                assert q.shape == shape
                assert s.size == q.size // cfg.gs
                f.write(q.tobytes())
                f.write(s.tobytes())
            else:
                f.write(np.asarray(t, "<f4").tobytes())


def read_q8(path: str) -> tuple[LlamaConfig, dict]:
    data = open(path, "rb").read()
    cfg, off = _parse_header(data, MAGIC_Q8)
    qparams: dict = {"layers": []}
    for name, shape, quant in _tensor_order(cfg):
        count = int(np.prod(shape))
        if quant:
            q = np.frombuffer(data, np.int8, count, off).reshape(shape).copy()
            off += count
            ns = count // cfg.gs
            s = np.frombuffer(data, "<f4", ns, off).reshape(shape[0], -1).copy()
            off += 4 * ns
            _set(qparams, name, {"q": q, "s": s})
        else:
            t = np.frombuffer(data, "<f4", count, off).reshape(shape).copy()
            off += 4 * count
            _set(qparams, name, t)
    assert off == len(data), f"trailing bytes: {len(data) - off}"
    return cfg, qparams


def quant_error_stats(cfg: LlamaConfig, params: dict) -> dict:
    """Table IV: statistics of |rhat - r| over every quantized weight, plus
    the error-percentage distribution the paper quotes (3.30% +- 11.57%)."""
    errs = []
    pct = []
    for name, _, quant in _tensor_order(cfg):
        if not quant:
            continue
        t = np.asarray(_get(params, name), np.float32)
        q, s = ref.quantize(t, cfg.gs)
        rhat = ref.dequantize(q, s, cfg.gs)
        e = np.abs(rhat - t).reshape(-1)
        errs.append(e)
        nz = np.abs(t.reshape(-1)) > 1e-12
        pct.append(e[nz] / np.abs(t.reshape(-1)[nz]))
    e = np.concatenate(errs)
    p = np.concatenate(pct)
    return {
        "max": float(e.max()), "min": float(e.min()),
        "mean": float(e.mean()), "std": float(e.std()),
        "pct_mean": float(p.mean() * 100), "pct_std": float(p.std() * 100),
    }
