"""AOT export: lower the Pallas GQMV kernel to HLO text for the Rust runtime.

HLO *text* (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (what
the published `xla` 0.1.6 crate binds) rejects; the text parser reassigns
ids and round-trips cleanly.  See /opt/xla-example/README.md.

One executable per GQMV shape, mirroring the paper's statically
instantiated kernel1 (n = dim) / kernel2 (n = hidden_dim).  The Rust
runtime compiles each at startup and calls them from the decode hot path
with (xq, xs, wq, ws) buffers — python is never on the request path.

Outputs under artifacts/:
  gqmv_m{M}_n{N}_g{GS}.hlo.txt   per shape
  manifest.json                  shape -> file map + config metadata
  golden_gqmv_*.bin              input/output fixture for the Rust runtime
                                 smoke test (xq i8, xs f32, wq i8, ws f32,
                                 out f32 raw little-endian arrays)

Usage: python -m compile.aot --out ../artifacts [--full]
       (--full additionally exports the TinyLlama-1.1B geometry kernels)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels import ref
from .kernels.gqmv import gqmv
from .model import NANO, TINYLLAMA_1_1B, LlamaConfig


def gqmv_shapes(cfg: LlamaConfig) -> dict[str, tuple[int, int]]:
    """The matrix shapes Algorithm 2 needs (rows m, cols n).  Fused QKV and
    W1+W3 per paper §III-B; classifier reuses kernel1 with m=vocab."""
    return {
        "qkv": (cfg.dim + 2 * cfg.kv_dim, cfg.dim),
        "wo": (cfg.dim, cfg.dim),
        "w13": (2 * cfg.hidden_dim, cfg.dim),
        "w2": (cfg.dim, cfg.hidden_dim),
        "cls": (cfg.vocab_size, cfg.dim),
    }


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_gqmv(m: int, n: int, gs: int) -> str:
    g = n // gs
    specs = (
        jax.ShapeDtypeStruct((n,), jnp.int8),
        jax.ShapeDtypeStruct((g,), jnp.float32),
        jax.ShapeDtypeStruct((m, n), jnp.int8),
        jax.ShapeDtypeStruct((m, g), jnp.float32),
    )
    lowered = jax.jit(lambda xq, xs, wq, ws: gqmv(xq, xs, wq, ws, gs=gs)).lower(*specs)
    return to_hlo_text(lowered)


def export_golden(out_dir: str, m: int, n: int, gs: int, seed: int = 123) -> dict:
    """Raw-array fixture so the Rust runtime test can verify numerics."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, n)).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    wq, ws = ref.quantize(w, gs)
    xq, xs = ref.quantize(x, gs)
    ws2 = ws.reshape(m, n // gs)
    out = ref.gqmv_ref(xq, xs, wq, ws2, gs)
    files = {}
    for name, arr in [("xq", xq), ("xs", xs), ("wq", wq), ("ws", ws2), ("out", out)]:
        path = f"golden_gqmv_{name}.bin"
        arr.tofile(os.path.join(out_dir, path))
        files[name] = path
    return {"m": m, "n": n, "gs": gs, "files": files}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also export TinyLlama-1.1B geometry kernels")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest: dict = {"gs": NANO.gs, "kernels": [], "configs": {}}
    shapes: dict[tuple[int, int], str] = {}

    def add_config(name: str, cfg: LlamaConfig):
        manifest["configs"][name] = {
            "dim": cfg.dim, "hidden_dim": cfg.hidden_dim,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "vocab_size": cfg.vocab_size,
            "seq_len": cfg.seq_len, "gs": cfg.gs,
            "kernels": {},
        }
        for role, (m, n) in gqmv_shapes(cfg).items():
            fname = f"gqmv_m{m}_n{n}_g{cfg.gs}.hlo.txt"
            manifest["configs"][name]["kernels"][role] = fname
            if (m, n) not in shapes:
                shapes[(m, n)] = fname

    add_config("nano", NANO)
    if args.full:
        add_config("tinyllama-1.1b", TINYLLAMA_1_1B)

    for (m, n), fname in sorted(shapes.items()):
        text = lower_gqmv(m, n, NANO.gs)
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["kernels"].append({"m": m, "n": n, "gs": NANO.gs, "file": fname})
        print(f"wrote {fname} ({len(text)/1024:.0f} KiB)")

    golden = export_golden(args.out, m=64, n=512, gs=NANO.gs)
    manifest["golden"] = golden

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['kernels'])} kernels, "
          f"configs: {list(manifest['configs'])}")


if __name__ == "__main__":
    main()
