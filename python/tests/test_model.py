"""L2 model correctness: float forward, quantized forward, reference engine.

Key parities:
  * forward_quant_step (Pallas-kernel datapath) == RefEngine (numpy oracle)
  * quantized logits track float logits (quantization quality)
  * incremental (KV-cached) forward == batched float forward at each pos
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import corpus
from compile.model import (NANO, LlamaConfig, forward_float,
                           forward_quant_step, init_params, loss_fn,
                           quantize_params, rmsnorm)
from compile.refmodel import RefEngine

TINY = LlamaConfig(dim=64, hidden_dim=128, n_layers=2, n_heads=2,
                   n_kv_heads=1, vocab_size=64, seq_len=32, gs=32)


@pytest.fixture(scope="module")
def tiny_setup():
    params = init_params(TINY, jax.random.PRNGKey(1))
    qparams = quantize_params(TINY, params)
    return params, qparams


def test_forward_float_shapes(tiny_setup):
    params, _ = tiny_setup
    tokens = jnp.asarray(np.arange(2 * 8).reshape(2, 8) % TINY.vocab_size)
    logits = forward_float(TINY, params, tokens)
    assert logits.shape == (2, 8, TINY.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


def test_loss_decreases_direction(tiny_setup):
    """Loss on random tokens ~ log(vocab) at init."""
    params, _ = tiny_setup
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(3, TINY.vocab_size, (2, 16)), jnp.int32)
    y = jnp.asarray(rng.integers(3, TINY.vocab_size, (2, 16)), jnp.int32)
    loss = float(loss_fn(TINY, params, x, y))
    assert abs(loss - np.log(TINY.vocab_size)) < 0.5


def test_quant_step_matches_refengine(tiny_setup):
    """The Pallas datapath and the numpy oracle produce the same logits."""
    _, qparams = tiny_setup
    eng = RefEngine(TINY, qparams)
    kc = np.zeros((TINY.n_layers, TINY.seq_len, TINY.kv_dim), np.float32)
    vc = np.zeros_like(kc)
    toks = [5, 17, 3, 42]
    for pos, t in enumerate(toks):
        ref_logits = eng.forward(t, pos)
        pal_logits = forward_quant_step(TINY, qparams, t, pos, kc, vc)
        np.testing.assert_allclose(pal_logits, ref_logits, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(kc, eng.kcache, rtol=1e-5, atol=1e-6)


def test_refengine_matches_float_forward(tiny_setup):
    """Quantized incremental logits track the float batched forward: same
    top-1 for a well-separated distribution and small relative gap."""
    params, qparams = tiny_setup
    toks = [1, 9, 25, 13, 40, 2, 33]
    eng = RefEngine(TINY, qparams)
    q_logits = []
    for pos, t in enumerate(toks):
        q_logits.append(eng.forward(t, pos))
    f_logits = np.asarray(forward_float(
        TINY, params, jnp.asarray([toks], jnp.int32))[0])
    q_logits = np.stack(q_logits)
    # random-init weights => logits are small; compare by correlation
    for pos in range(len(toks)):
        a, b = q_logits[pos], f_logits[pos]
        corr = np.corrcoef(a, b)[0, 1]
        assert corr > 0.98, f"pos {pos}: corr {corr}"


def test_refengine_deterministic(tiny_setup):
    _, qparams = tiny_setup
    e1, e2 = RefEngine(TINY, qparams), RefEngine(TINY, qparams)
    prompt = [1, 10, 11]  # ids valid for TINY's vocab of 64
    ids1, lg1 = e1.generate(prompt, 8)
    ids2, lg2 = e2.generate(prompt, 8)
    assert ids1 == ids2
    np.testing.assert_array_equal(lg1, lg2)


def test_refengine_generate_lengths(tiny_setup):
    _, qparams = tiny_setup
    prompt = [1, 5, 6]
    ids, logits = RefEngine(TINY, qparams).generate(prompt, 5)
    assert len(ids) == len(prompt) + 5
    assert logits.shape == (5, TINY.vocab_size)


def test_rmsnorm_scale_invariance():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(64), jnp.float32)
    w = jnp.ones(64)
    y1 = rmsnorm(x, w)
    y2 = rmsnorm(x * 1000.0, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-3)
    # unit RMS output
    assert abs(float(jnp.mean(y1 * y1)) - 1.0) < 1e-3


def test_rope_preserves_norm(tiny_setup):
    _, qparams = tiny_setup
    eng = RefEngine(TINY, qparams)
    v = np.random.default_rng(2).standard_normal(TINY.dim).astype(np.float32)
    for pos in (0, 1, 7, 31):
        r = eng.rope(v, pos)
        np.testing.assert_allclose(np.linalg.norm(r), np.linalg.norm(v), rtol=1e-5)
    # pos 0 is identity
    np.testing.assert_allclose(eng.rope(v, 0), v, rtol=1e-6)


def test_gqa_kv_sharing(tiny_setup):
    """TINY has n_heads=2, n_kv_heads=1: both heads must read the same KV."""
    _, qparams = tiny_setup
    eng = RefEngine(TINY, qparams)
    eng.forward(3, 0)
    assert eng.kcache[0, 0].shape == (TINY.kv_dim,)
    assert TINY.kv_dim == TINY.head_dim * 1


def test_nano_config_valid():
    NANO.validate()
    assert NANO.head_dim == 64
    assert NANO.kv_dim == 128


def test_tokenizer_roundtrip():
    text = "the quick fox? 42 _#\n ok"
    ids = corpus.encode(text)
    assert ids[0] == corpus.BOS_ID
    assert corpus.decode(ids) == text


def test_corpus_deterministic():
    a = corpus.generate(10_000, seed=42)
    b = corpus.generate(10_000, seed=42)
    assert a == b
    c = corpus.generate(10_000, seed=43)
    assert a != c
