"""AOT path: lowered HLO text is parseable, self-consistent, and the
lowered module recomputes the oracle's numbers when re-executed in JAX."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels import ref
from compile.model import NANO, TINYLLAMA_1_1B


def test_gqmv_shapes_nano():
    shapes = aot.gqmv_shapes(NANO)
    assert shapes == {
        "qkv": (512, 256), "wo": (256, 256), "w13": (1536, 256),
        "w2": (256, 768), "cls": (512, 256),
    }


def test_gqmv_shapes_tinyllama():
    shapes = aot.gqmv_shapes(TINYLLAMA_1_1B)
    # Table I geometry: dim=2048, kv_dim=256, hidden=5632, vocab=32000
    assert shapes["qkv"] == (2048 + 512, 2048)
    assert shapes["w13"] == (11264, 2048)
    assert shapes["w2"] == (2048, 5632)
    assert shapes["cls"] == (32000, 2048)


def test_lowered_hlo_text_structure():
    text = aot.lower_gqmv(16, 512, 256)
    assert "HloModule" in text
    assert "ENTRY" in text
    # four parameters: xq, xs, wq, ws
    for i in range(4):
        assert f"parameter({i})" in text
    # int8 inputs and f32 output present
    assert "s8[" in text
    assert "f32[" in text


def test_lowered_hlo_text_reparses():
    """The exported HLO text must round-trip through the HLO text parser —
    the exact operation the Rust runtime performs
    (HloModuleProto::from_text_file).  Numeric re-execution through PJRT is
    covered by the Rust integration test rust/tests/runtime_golden.rs."""
    from jax._src.lib import xla_client as xc
    m, n, gs = 16, 512, 256
    text = aot.lower_gqmv(m, n, gs)
    module = xc._xla.hlo_module_from_text(text)
    # instruction ids must have been reassigned to fit 32 bits
    proto = module.as_serialized_hlo_module_proto()
    assert len(proto) > 0
    text2 = module.to_string()
    assert "ENTRY" in text2


def test_lowered_kernel_numerics_via_jit():
    """Execute the same jitted function the AOT path lowers and compare to
    the oracle — guards the lowering input itself."""
    m, n, gs = 16, 512, 256
    rng = np.random.default_rng(1)
    w = rng.standard_normal((m, n)).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    wq, ws = ref.quantize(w, gs)
    xq, xs = ref.quantize(x, gs)
    ws2 = ws.reshape(m, n // gs)
    expected = ref.gqmv_ref(xq, xs, wq, ws2, gs)
    from compile.kernels.gqmv import gqmv
    got = np.asarray(gqmv(jnp.asarray(xq), jnp.asarray(xs), jnp.asarray(wq),
                          jnp.asarray(ws2), gs=gs))
    np.testing.assert_allclose(got, expected, rtol=1e-6, atol=1e-5)


def test_export_golden_fixture(tmp_path):
    meta = aot.export_golden(str(tmp_path), m=24, n=256, gs=64)
    xq = np.fromfile(os.path.join(tmp_path, meta["files"]["xq"]), np.int8)
    xs = np.fromfile(os.path.join(tmp_path, meta["files"]["xs"]), np.float32)
    wq = np.fromfile(os.path.join(tmp_path, meta["files"]["wq"]), np.int8).reshape(24, 256)
    ws = np.fromfile(os.path.join(tmp_path, meta["files"]["ws"]), np.float32).reshape(24, 4)
    out = np.fromfile(os.path.join(tmp_path, meta["files"]["out"]), np.float32)
    np.testing.assert_allclose(ref.gqmv_ref(xq, xs, wq, ws, 64), out, rtol=1e-6)


@pytest.mark.skipif(not os.path.exists(os.path.join(
    os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")),
    reason="artifacts not built")
def test_artifacts_manifest_consistent():
    import json
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = json.load(open(os.path.join(art, "manifest.json")))
    assert manifest["gs"] == 256
    for k in manifest["kernels"]:
        path = os.path.join(art, k["file"])
        assert os.path.exists(path), k["file"]
        head = open(path).read(4096)
        assert "HloModule" in head
    nano = manifest["configs"]["nano"]
    assert nano["dim"] == 256 and nano["vocab_size"] == 512
