"""Checkpoint format round-trips + quantization error statistics (Table IV)."""

import os

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quantize
from compile.kernels import ref
from compile.model import LlamaConfig, init_params

TINY = LlamaConfig(dim=64, hidden_dim=128, n_layers=2, n_heads=2,
                   n_kv_heads=1, vocab_size=64, seq_len=32, gs=32)


@pytest.fixture(scope="module")
def tiny_params():
    return jax.tree.map(lambda t: np.asarray(t, np.float32),
                        init_params(TINY, jax.random.PRNGKey(0)))


def test_f32_roundtrip(tmp_path, tiny_params):
    path = os.path.join(tmp_path, "m.lfck")
    quantize.write_f32(path, TINY, tiny_params)
    cfg2, params2 = quantize.read_f32(path)
    assert cfg2 == TINY
    np.testing.assert_array_equal(params2["tok_emb"], tiny_params["tok_emb"])
    for l1, l2 in zip(tiny_params["layers"], params2["layers"]):
        for k in l1:
            np.testing.assert_array_equal(l2[k], np.asarray(l1[k]))
    np.testing.assert_array_equal(params2["cls"], tiny_params["cls"])


def test_q8_roundtrip(tmp_path, tiny_params):
    path = os.path.join(tmp_path, "m.lfq8")
    qp = quantize.quantize_checkpoint(TINY, tiny_params)
    quantize.write_q8(path, TINY, qp)
    cfg2, qp2 = quantize.read_q8(path)
    assert cfg2 == TINY
    np.testing.assert_array_equal(qp2["tok_emb"]["q"], qp["tok_emb"]["q"])
    np.testing.assert_array_equal(qp2["tok_emb"]["s"], qp["tok_emb"]["s"])
    for l1, l2 in zip(qp["layers"], qp2["layers"]):
        np.testing.assert_array_equal(l1["att_norm"], l2["att_norm"])
        for k in ("wq", "wk", "wv", "wo", "w1", "w2", "w3"):
            np.testing.assert_array_equal(l1[k]["q"], l2[k]["q"])
            np.testing.assert_array_equal(l1[k]["s"], l2[k]["s"])


def test_q8_file_smaller_than_f32(tmp_path, tiny_params):
    """The paper's 4.4GB -> 1.1GB claim: q8 ~ 1/4 + scale overhead."""
    fp = os.path.join(tmp_path, "m.lfck")
    qp_path = os.path.join(tmp_path, "m.lfq8")
    quantize.write_f32(fp, TINY, tiny_params)
    quantize.write_q8(qp_path, TINY, quantize.quantize_checkpoint(TINY, tiny_params))
    ratio = os.path.getsize(fp) / os.path.getsize(qp_path)
    assert 3.0 < ratio < 4.1, f"compression ratio {ratio}"


def test_bad_magic_rejected(tmp_path, tiny_params):
    path = os.path.join(tmp_path, "m.lfck")
    quantize.write_f32(path, TINY, tiny_params)
    data = bytearray(open(path, "rb").read())
    data[:4] = b"XXXX"
    bad = os.path.join(tmp_path, "bad.lfck")
    open(bad, "wb").write(bytes(data))
    with pytest.raises(AssertionError):
        quantize.read_f32(bad)


def test_truncated_rejected(tmp_path, tiny_params):
    path = os.path.join(tmp_path, "m.lfq8")
    quantize.write_q8(path, TINY, quantize.quantize_checkpoint(TINY, tiny_params))
    data = open(path, "rb").read()
    bad = os.path.join(tmp_path, "bad.lfq8")
    open(bad, "wb").write(data + b"\x00" * 17)
    with pytest.raises(AssertionError):
        quantize.read_q8(bad)


def test_quant_error_stats_shape(tiny_params):
    stats = quantize.quant_error_stats(TINY, tiny_params)
    # Theoretical bound: per-group max error is scale/2 = max|r|/254.
    assert stats["max"] <= float(
        max(np.abs(np.asarray(tiny_params["cls"])).max(),
            np.abs(np.asarray(tiny_params["tok_emb"])).max(), 1.0)
    ) / 254 * 1.01 + 1e-6 or stats["max"] < 0.01
    assert 0 <= stats["min"] <= stats["mean"] <= stats["max"]
    assert stats["std"] > 0


@settings(max_examples=30, deadline=None)
@given(
    gs_pow=st.integers(2, 8),
    groups=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(1e-3, 1e3),
)
def test_quant_error_bounded_by_half_scale(gs_pow, groups, seed, scale):
    """|rhat - r| <= S/2 per group (rounding), the Table IV theory."""
    gs = 2 ** gs_pow
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(groups * gs) * scale).astype(np.float32)
    q, s = ref.quantize(x, gs)
    rhat = ref.dequantize(q, s, gs)
    err = np.abs(rhat - x).reshape(groups, gs)
    bound = s[:, None] / 2 * (1 + 1e-5) + 1e-9
    assert (err <= bound).all()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), gs_pow=st.integers(2, 6))
def test_quantize_idempotent_on_lattice(seed, gs_pow):
    """Quantizing an already-dequantized array is lossless."""
    gs = 2 ** gs_pow
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(4 * gs).astype(np.float32)
    q, s = ref.quantize(x, gs)
    rhat = ref.dequantize(q, s, gs)
    q2, s2 = ref.quantize(rhat, gs)
    np.testing.assert_array_equal(q, q2)
    np.testing.assert_allclose(s, s2, rtol=1e-6)
