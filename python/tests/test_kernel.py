"""L1 correctness: Pallas GQMV kernel vs the numpy oracle (ref.py).

This is the CORE correctness signal for the accelerator datapath.
hypothesis sweeps shapes, group sizes and value distributions; targeted
tests pin down the cast chain and edge cases (overflow, zeros, extremes).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gqmv import gqmv, gqmv_fused, quantize_jnp


def run_kernel(xq, xs, wq, ws, gs):
    out = gqmv(jnp.asarray(xq), jnp.asarray(xs), jnp.asarray(wq),
               jnp.asarray(ws), gs=gs)
    return np.asarray(out)


def make_case(m, n, gs, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    w = (rng.standard_normal((m, n)) * scale).astype(np.float32)
    x = (rng.standard_normal(n) * scale).astype(np.float32)
    wq, ws = ref.quantize(w, gs)
    xq, xs = ref.quantize(x, gs)
    return xq, xs, wq, ws.reshape(m, n // gs)


@pytest.mark.parametrize("m,n,gs", [
    (8, 256, 256),        # minimal single tile, single group
    (16, 512, 256),       # two groups
    (64, 256, 64),        # small groups
    (256, 256, 256),      # nano wo shape
    (512, 256, 256),      # nano qkv/cls shape
    (1536, 256, 256),     # nano w13 shape
    (256, 768, 256),      # nano w2 shape (kernel2 analogue: n=hidden)
    (8, 128, 32),         # tiny groups
    (40, 512, 128),       # m not a power of two (tile fallback)
])
def test_kernel_matches_ref_shapes(m, n, gs):
    xq, xs, wq, ws = make_case(m, n, gs, seed=m * 31 + n)
    expected = ref.gqmv_ref(xq, xs, wq, ws, gs)
    got = run_kernel(xq, xs, wq, ws, gs)
    np.testing.assert_allclose(got, expected, rtol=1e-6, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    mt=st.integers(1, 8),
    g=st.integers(1, 6),
    gs_pow=st.integers(3, 8),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
)
def test_kernel_matches_ref_hypothesis(mt, g, gs_pow, seed, scale):
    gs = 2 ** gs_pow
    m, n = mt * 8, g * gs  # small m exercises the _pick_tile fallback
    xq, xs, wq, ws = make_case(m, n, gs, seed, scale)
    expected = ref.gqmv_ref(xq, xs, wq, ws, gs)
    got = run_kernel(xq, xs, wq, ws, gs)
    # relative tolerance scaled by magnitude of output
    tol = max(1e-5, float(np.abs(expected).max()) * 1e-6)
    np.testing.assert_allclose(got, expected, rtol=1e-6, atol=tol)


def test_kernel_extreme_values_no_overflow():
    """All-|127| operands: per-group int32 sum is 256 * 16129 = 4,129,024 —
    must not saturate/overflow anywhere in the cast chain."""
    m, n, gs = 8, 2048, 256
    wq = np.full((m, n), 127, np.int8)
    wq[1::2] = -127
    xq = np.full(n, 127, np.int8)
    ws = np.full((m, n // gs), 0.01, np.float32)
    xs = np.full(n // gs, 0.02, np.float32)
    expected = ref.gqmv_ref(xq, xs, wq, ws, gs)
    got = run_kernel(xq, xs, wq, ws, gs)
    assert expected[0] == pytest.approx(127 * 127 * n * 0.01 * 0.02, rel=1e-5)
    np.testing.assert_allclose(got, expected, rtol=1e-6)


def test_kernel_zero_inputs():
    m, n, gs = 16, 512, 256
    out = run_kernel(np.zeros(n, np.int8), np.zeros(n // gs, np.float32),
                     np.zeros((m, n), np.int8), np.zeros((m, n // gs), np.float32),
                     gs)
    np.testing.assert_array_equal(out, np.zeros(m, np.float32))


def test_kernel_identity_rows():
    """W rows that select single elements: out[i] = xq[i]*ws*xs."""
    m, n, gs = 8, 256, 256
    wq = np.zeros((m, n), np.int8)
    for i in range(m):
        wq[i, i] = 1
    ws = np.ones((m, 1), np.float32)
    rng = np.random.default_rng(3)
    xq = rng.integers(-127, 128, n).astype(np.int8)
    xs = np.asarray([0.5], np.float32)
    got = run_kernel(xq, xs, wq, ws, gs)
    np.testing.assert_allclose(got, xq[:m].astype(np.float32) * 0.5, rtol=1e-6)


def test_quantize_jnp_matches_ref():
    rng = np.random.default_rng(11)
    x = (rng.standard_normal(1024) * 3).astype(np.float32)
    q_ref, s_ref = ref.quantize(x, 256)
    q_jnp, s_jnp = quantize_jnp(jnp.asarray(x), 256)
    np.testing.assert_array_equal(np.asarray(q_jnp), q_ref)
    np.testing.assert_allclose(np.asarray(s_jnp), s_ref, rtol=1e-7)


def test_gqmv_fused_runtime_quantization():
    """Paper §III-A: activations quantized at run time, fused with kernel."""
    rng = np.random.default_rng(5)
    m, n, gs = 32, 512, 256
    w = rng.standard_normal((m, n)).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    wq, ws = ref.quantize(w, gs)
    got = np.asarray(gqmv_fused(jnp.asarray(x), jnp.asarray(wq),
                                jnp.asarray(ws.reshape(m, n // gs)), gs=gs))
    expected = ref.gqmv_dequant_ref(x, w, gs)
    np.testing.assert_allclose(got, expected, rtol=1e-6, atol=1e-5)


def test_quantized_matvec_close_to_float():
    """End-to-end quantization quality: GQMV approximates W @ x (the whole
    point of W8A8 — paper Table IV/V territory)."""
    rng = np.random.default_rng(9)
    m, n, gs = 128, 2048, 256
    w = rng.standard_normal((m, n)).astype(np.float32) * 0.05
    x = rng.standard_normal(n).astype(np.float32)
    got = ref.gqmv_dequant_ref(x, w, gs)
    exact = w @ x
    err = np.abs(got - exact) / (np.abs(exact) + 1e-3)
    assert float(np.median(err)) < 0.05, f"median rel err {np.median(err)}"


def test_round_half_away():
    x = np.asarray([0.5, -0.5, 1.5, -1.5, 2.4, -2.4, 2.6])
    np.testing.assert_array_equal(ref.round_half_away(x),
                                  [1, -1, 2, -2, 2, -2, 3])


def test_quantize_all_zero_group():
    q, s = ref.quantize(np.zeros(512, np.float32), 256)
    np.testing.assert_array_equal(q, np.zeros(512, np.int8))
    np.testing.assert_array_equal(s, np.zeros(2, np.float32))


def test_quantize_max_maps_to_127():
    x = np.linspace(-4, 4, 256).astype(np.float32)
    q, s = ref.quantize(x, 256)
    assert q.max() == 127 and q.min() == -127
    np.testing.assert_allclose(ref.dequantize(q, s, 256), x, atol=4 / 127 / 2 + 1e-6)
