//! Quickstart: load the trained nano checkpoint, bring up the PJRT
//! runtime with the AOT-compiled Pallas GQMV kernels, and generate text
//! with the full LlamaF engine (async weight streaming).
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! This is the end-to-end path of the paper's system: Rust host control
//! (Algorithm 2) + streamed per-layer weights + kernel offload.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;
use llamaf::engine::forward::Engine;
use llamaf::engine::generate::{generate, Sampler};
use llamaf::engine::llamaf::LlamafEngine;
use llamaf::runtime::Runtime;
use llamaf::sched::SchedMode;
use llamaf::tokenizer::Tokenizer;

fn main() -> Result<()> {
    let artifacts = Path::new("artifacts");
    let ckpt = artifacts.join("nano_q8.lfq8");
    anyhow::ensure!(
        ckpt.exists(),
        "missing {ckpt:?} — run `make artifacts` first (trains the nano model \
         and AOT-compiles the Pallas kernels)"
    );

    println!("loading PJRT runtime + AOT GQMV kernels...");
    let rt = Arc::new(Runtime::load(artifacts)?);
    println!("platform: {}, kernels: {:?}", rt.platform(), rt.compiled_shapes());

    let mut engine = LlamafEngine::open(&ckpt, rt, SchedMode::Async)?;
    let tok = Tokenizer::new(engine.cfg().vocab_size);

    let prompt = "the engineer builds";
    let prompt_ids = tok.encode(prompt, true);
    println!("\nprompt: {prompt:?}\ngenerating 48 tokens (greedy)...\n");
    let out = generate(&mut engine, &prompt_ids, 48, Sampler::Greedy, false)?;

    println!("--- output -------------------------------------------");
    println!("{}{}", prompt, tok.decode(&out.generated));
    println!("------------------------------------------------------");
    let (total_xfer, blocked_xfer, transfers) = engine.transfer_stats();
    println!(
        "{} tokens at {:.2} tok/s | p50 {:.2} ms p99 {:.2} ms",
        out.generated.len(),
        out.tok_per_s,
        out.latency_p50_s * 1e3,
        out.latency_p99_s * 1e3
    );
    println!(
        "weight streaming: {transfers} layer stagings, {:.1} ms total, {:.1} ms blocking \
         (async scheduling hid {:.0}%)",
        total_xfer * 1e3,
        blocked_xfer * 1e3,
        100.0 * (1.0 - blocked_xfer / total_xfer.max(1e-12))
    );
    Ok(())
}
