//! Table V driver: measure W32A32 vs W8A8 perplexity of the trained nano
//! model on the held-out synthetic corpus.
//!
//!     cargo run --release --example ppl_eval [max_tokens]

use std::path::Path;

use anyhow::Result;
use llamaf::exp::table5;

fn main() -> Result<()> {
    let max_tokens: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("max_tokens must be an integer"))
        .unwrap_or(2048);
    let art = Path::new("artifacts");
    for f in ["nano_f32.lfck", "nano_q8.lfq8", "corpus_val.txt"] {
        anyhow::ensure!(art.join(f).exists(), "missing artifacts/{f}; run `make artifacts`");
    }
    println!("evaluating PPL on {max_tokens} held-out predictions (W32A32 then W8A8)...");
    let r = table5::eval(
        &art.join("nano_f32.lfck"),
        &art.join("nano_q8.lfq8"),
        &art.join("corpus_val.txt"),
        max_tokens,
    )?;
    let delta = 100.0 * (r.ppl_q8 - r.ppl_f32) / r.ppl_f32;
    println!("\n  W32A32 PPL: {:.4}", r.ppl_f32);
    println!("  W8A8   PPL: {:.4}  (GS=256)", r.ppl_q8);
    println!("  delta:      {delta:+.3}%   (paper: +0.57% on TinyLlama/WikiText-2)");
    anyhow::ensure!(delta.abs() < 5.0, "quantization degraded PPL by more than 5%");
    Ok(())
}
