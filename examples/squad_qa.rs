//! SQuAD-style QA workload (paper §V-C): answer a batch of questions with
//! greedy sampling, EOS omitted, at step sizes 64/128/224, measuring tok/s
//! for the PS baseline and the LlamaF engine (sync + async).
//!
//! The real SQuAD set is unavailable offline; the questions below follow
//! the same "short factual question over a context" shape using the
//! synthetic corpus domain (DESIGN.md §5 substitution 3).

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;
use llamaf::engine::forward::{CpuEngine, Engine};
use llamaf::engine::generate::{generate, Sampler};
use llamaf::engine::llamaf::LlamafEngine;
use llamaf::ps::ThreadedGqmv;
use llamaf::runtime::Runtime;
use llamaf::sched::SchedMode;
use llamaf::tokenizer::Tokenizer;
use llamaf::util::ThreadPool;

const QUESTIONS: &[&str] = &[
    "what does the engineer build? ",
    "where does the old captain carry the wooden boat? ",
    "who repairs the broken clock near the river? ",
    "when does a student measure the glass prism? ",
];

fn bench_engine(name: &str, engine: &mut dyn Engine, tok: &Tokenizer, steps: usize) -> Result<f64> {
    let mut total_toks = 0usize;
    let mut total_s = 0.0f64;
    for q in QUESTIONS {
        let ids = tok.encode(q, true);
        let out = generate(engine, &ids, steps, Sampler::Greedy, false)?;
        total_toks += out.generated.len();
        total_s += out.generated.len() as f64 / out.tok_per_s;
    }
    let tps = total_toks as f64 / total_s;
    println!("  {name:<34} steps={steps:<4} {tps:>9.2} tok/s");
    Ok(tps)
}

fn main() -> Result<()> {
    let artifacts = Path::new("artifacts");
    let ckpt = artifacts.join("nano_q8.lfq8");
    anyhow::ensure!(ckpt.exists(), "run `make artifacts` first");
    let qm = llamaf::ckpt::read_q8(&ckpt)?;
    let tok = Tokenizer::new(qm.cfg.vocab_size);
    // nano seq_len=256: prompts ~50 tokens, so cap steps at 64/128/192
    let steps_list = [64usize, 128, 192];

    println!("SQuAD-style QA benchmark ({} questions, greedy, EOS omitted)\n", QUESTIONS.len());
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();

    let pool = Arc::new(ThreadPool::new(4));
    let mut ps = CpuEngine::new(qm.clone(), Box::new(ThreadedGqmv::new(pool)));
    let mut ps_row = vec![];
    for &s in &steps_list {
        ps_row.push(bench_engine("ZCU102-PS analogue (threaded x4)", &mut ps, &tok, s)?);
    }
    rows.push(("PS".into(), ps_row));

    let rt = Arc::new(Runtime::load(artifacts)?);
    for (label, mode) in
        [("LlamaF no-sched (sync)", SchedMode::Sync), ("LlamaF (async)", SchedMode::Async)]
    {
        let mut eng = LlamafEngine::open(&ckpt, Arc::clone(&rt), mode)?;
        let mut row = vec![];
        for &s in &steps_list {
            row.push(bench_engine(label, &mut eng, &tok, s)?);
        }
        rows.push((label.into(), row));
    }

    println!("\nsample answers (LlamaF async, 48 steps):");
    let mut eng = LlamafEngine::open(&ckpt, rt, SchedMode::Async)?;
    for q in QUESTIONS.iter().take(2) {
        let ids = tok.encode(q, true);
        let out = generate(&mut eng, &ids, 48, Sampler::Greedy, false)?;
        println!("  Q: {q}\n  A: {}", tok.decode(&out.generated).replace('\n', " "));
    }
    Ok(())
}
