//! Table II driver: profile the PS baseline's forward pass at positions
//! 63/127/255 and print the component distribution.
//!
//!     cargo run --release --example profile_forward [nano|tinyllama]
//!
//! `tinyllama` profiles the paper geometry with synthetic weights (slower:
//! ~1 GMAC per token on the CPU).

use anyhow::Result;
use llamaf::exp::table2;
use llamaf::model::{NANO, TINYLLAMA_1_1B, QuantModel};

fn main() -> Result<()> {
    let geometry = std::env::args().nth(1).unwrap_or_else(|| "nano".into());
    let (cfg, name) = match geometry.as_str() {
        "tinyllama" => (TINYLLAMA_1_1B, "TinyLlama-1.1B geometry (synthetic weights)"),
        _ => (NANO, "nano geometry"),
    };
    println!("profiling PS forward pass: {name}");
    let positions =
        [63usize, 127, 255].iter().copied().filter(|&p| p < cfg.seq_len).collect::<Vec<_>>();
    let model = if geometry == "tinyllama" {
        QuantModel::synthetic(cfg, 42)
    } else {
        let p = std::path::Path::new("artifacts/nano_q8.lfq8");
        if p.exists() { llamaf::ckpt::read_q8(p)? } else { QuantModel::synthetic(cfg, 42) }
    };
    let profiles = table2::measure(model, &positions, 4)?;
    println!(
        "\n  {:<22} {}",
        "Computation",
        positions.iter().map(|p| format!("{:>10}", format!("pos={p}"))).collect::<String>()
    );
    let rows: [(&str, fn(&llamaf::metrics::ForwardProfile) -> f64); 5] = [
        ("Matrix Computation", |p| p.matrix_s),
        ("Multi-head Attention", |p| p.attention_s),
        ("SwiGLU", |p| p.swiglu_s),
        ("RoPE", |p| p.rope_s),
        ("RMSNorm", |p| p.rmsnorm_s),
    ];
    for (name, get) in rows {
        print!("  {name:<22}");
        for (_, prof) in &profiles {
            let compute =
                prof.matrix_s + prof.attention_s + prof.swiglu_s + prof.rope_s + prof.rmsnorm_s;
            print!("{:>9.2}% ", 100.0 * get(prof) / compute);
        }
        println!();
    }
    println!("\npaper (TinyLlama on 4x A53): matrix 98.98/98.53/97.64%, attention 0.47/0.92/1.82%");
    Ok(())
}
