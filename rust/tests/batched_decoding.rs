//! Integration: step-synchronous batched decoding.
//!
//! Property under test — the tentpole invariant of the batch scheduler:
//! for any batch size B ∈ {2, 4, 8}, any interleaving, and lanes that
//! join or leave mid-flight, every lane's greedy token stream is
//! **bit-identical** to sequential batch-1 generation of the same
//! prompt.  Plus the bandwidth claim: with 4 concurrent sessions the
//! weight-bytes-staged-per-token counter drops ≥3× below 4 independent
//! passes.
//!
//! Runs on the synthetic tiny model — no artifacts required.

use std::sync::{Arc, Barrier};

use llamaf::engine::batch::{BatchOpts, BatchScheduler, WeightMode};
use llamaf::engine::forward::CpuEngine;
use llamaf::engine::generate::{generate, Sampler};
use llamaf::engine::session::Session;
use llamaf::model::{FloatModel, LlamaConfig, QuantModel};
use llamaf::ps::ScalarGqmv;

fn tiny_cfg() -> LlamaConfig {
    LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 4,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 64,
        seq_len: 64,
        gs: 32,
    }
}

fn tiny_model(seed: u64) -> Arc<QuantModel> {
    Arc::new(QuantModel::from_float(&FloatModel::random(tiny_cfg(), seed)))
}

/// Batch-1 reference: a dedicated engine decoding the prompt greedily.
fn batch1_reference(model: &Arc<QuantModel>, prompt: &[u32], steps: usize) -> Vec<u32> {
    let mut engine = CpuEngine::new(Arc::clone(model), Box::new(ScalarGqmv));
    generate(&mut engine, prompt, steps, Sampler::Greedy, false).unwrap().generated
}

/// Run `specs` lanes concurrently through `sched`, asserting each lane's
/// streamed and returned tokens equal its batch-1 reference.
fn run_lanes_and_check(
    model: &Arc<QuantModel>,
    sched: &Arc<BatchScheduler>,
    specs: &[(Vec<u32>, usize)],
    sync_start: bool,
) {
    let barrier = Arc::new(Barrier::new(specs.len()));
    let handles: Vec<_> = specs
        .iter()
        .map(|(prompt, steps)| {
            let model = Arc::clone(model);
            let sched = Arc::clone(sched);
            let prompt = prompt.clone();
            let steps = *steps;
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                let want = batch1_reference(&model, &prompt, steps);
                if sync_start {
                    barrier.wait();
                }
                let mut streamed = Vec::new();
                let (sess, out) =
                    sched.generate(Session::new(&model.cfg), &prompt, steps, |step, id| {
                        assert_eq!(step, streamed.len(), "out-of-order token");
                        streamed.push(id);
                        Ok(())
                    });
                let out = out.expect("batched generation failed");
                assert!(sess.is_some(), "session not returned");
                assert_eq!(out.generated, want, "lane diverged from batch-1");
                assert_eq!(streamed, want, "streamed tokens diverged");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn batched_decode_bit_identical_for_b_2_4_8() {
    let model = tiny_model(21);
    for &bsz in &[2usize, 4, 8] {
        let sched = BatchScheduler::new(
            Arc::clone(&model),
            Box::new(ScalarGqmv),
            BatchOpts { max_batch: bsz, ..Default::default() },
        );
        // distinct prompts AND distinct step counts: lanes retire at
        // different steps, so the batch shrinks mid-flight while the
        // stragglers keep decoding
        let specs: Vec<(Vec<u32>, usize)> = (0..bsz)
            .map(|i| {
                let prompt: Vec<u32> =
                    (0..(2 + i % 3)).map(|k| ((7 * i + k) % 64) as u32).collect();
                (prompt, 4 + (i % 5))
            })
            .collect();
        run_lanes_and_check(&model, &sched, &specs, true);
        sched.shutdown();
    }
}

#[test]
fn overcommitted_batch_queues_lanes_and_stays_exact() {
    // 6 lanes through a max_batch=3 scheduler: lanes wait at the step
    // barrier for a slot and join mid-flight as earlier lanes retire
    let model = tiny_model(22);
    let sched = BatchScheduler::new(
        Arc::clone(&model),
        Box::new(ScalarGqmv),
        BatchOpts { max_batch: 3, ..Default::default() },
    );
    let specs: Vec<(Vec<u32>, usize)> =
        (0..6).map(|i| (vec![(i + 1) as u32, (5 * i + 2) as u32 % 64], 6 + i % 4)).collect();
    run_lanes_and_check(&model, &sched, &specs, true);
    sched.shutdown();
}

#[test]
fn late_joining_lane_is_bit_exact() {
    // lane B is submitted only after lane A has already streamed 3 tokens
    // — a mid-flight join at a step barrier.  Bit-exactness is asserted
    // unconditionally; the overlap itself is timing-dependent (the decode
    // thread runs ahead of the caller's token drain), so on a loaded
    // runner the attempt is retried with a fresh scheduler until the
    // occupancy histogram proves the two lanes actually coexisted.
    let model = tiny_model(23);
    let prompt_a: Vec<u32> = vec![1, 10, 11];
    let prompt_b: Vec<u32> = vec![9, 2];
    let want_a = batch1_reference(&model, &prompt_a, 24);
    let want_b = batch1_reference(&model, &prompt_b, 5);

    const ATTEMPTS: usize = 5;
    for attempt in 0..ATTEMPTS {
        let sched = BatchScheduler::new(
            Arc::clone(&model),
            Box::new(ScalarGqmv),
            BatchOpts { max_batch: 4, ..Default::default() },
        );
        let mut b_handle: Option<std::thread::JoinHandle<()>> = None;
        let mut streamed_a = Vec::new();
        let (sess_a, out_a) = {
            let model = Arc::clone(&model);
            let sched_b = Arc::clone(&sched);
            let want_b = want_b.clone();
            sched.generate(Session::new(&model.cfg), &prompt_a, 24, |_, id| {
                streamed_a.push(id);
                if streamed_a.len() == 3 && b_handle.is_none() {
                    let model = Arc::clone(&model);
                    let sched_b = Arc::clone(&sched_b);
                    let prompt_b = prompt_b.clone();
                    let want_b = want_b.clone();
                    b_handle = Some(std::thread::spawn(move || {
                        let (sess, out) = sched_b.generate(
                            Session::new(&model.cfg),
                            &prompt_b,
                            5,
                            |_, _| Ok(()),
                        );
                        assert!(sess.is_some());
                        assert_eq!(out.unwrap().generated, want_b, "late joiner diverged");
                    }));
                }
                Ok(())
            })
        };
        assert!(sess_a.is_some());
        assert_eq!(out_a.unwrap().generated, want_a, "original lane diverged");
        assert_eq!(streamed_a, want_a);
        b_handle.expect("lane B was never submitted").join().unwrap();
        let overlapped = sched.metrics().occupancy_max() >= 2.0;
        sched.shutdown();
        if overlapped {
            return; // the join genuinely happened mid-flight
        }
        eprintln!("attempt {attempt}: lanes never overlapped, retrying");
    }
    panic!("lane B never joined mid-flight in {ATTEMPTS} attempts");
}

#[test]
fn resident_scheduler_bit_exact_and_stages_zero_bytes() {
    // `serve --resident` path: the decode thread runs ResidentLayers
    // (zero-copy), so token streams stay bit-identical to batch-1 while
    // the staging counters stay at zero.
    let model = tiny_model(25);
    let sched = BatchScheduler::new(
        Arc::clone(&model),
        Box::new(ScalarGqmv),
        BatchOpts { max_batch: 4, weights: WeightMode::Resident, ..Default::default() },
    );
    let specs: Vec<(Vec<u32>, usize)> =
        (0..4).map(|i| (vec![(i + 2) as u32, (3 * i + 1) as u32 % 64], 6 + i)).collect();
    run_lanes_and_check(&model, &sched, &specs, true);
    assert!(sched.metrics().steps() > 0);
    assert_eq!(sched.metrics().bytes_staged(), 0, "resident mode must never stage");
    assert_eq!(sched.metrics().prefetch_wait_s(), 0.0, "no staging, no staging waits");
    sched.shutdown();
}

#[test]
fn persistent_worker_survives_many_sequential_generations() {
    // Lifecycle soak of the persistent prefetch worker: one streamed
    // scheduler serves many generations back to back (each ends with the
    // streamer wrapped mid-cycle, so the next lane's layer-0 access
    // exercises the stale-prefetch discard + re-arm path).  Every
    // generation must stay bit-exact, and the staging counters must keep
    // advancing — a wedged or dead worker would hang or error here.
    let model = tiny_model(26);
    let sched = BatchScheduler::new(
        Arc::clone(&model),
        Box::new(ScalarGqmv),
        BatchOpts { max_batch: 2, ..Default::default() },
    );
    let mut staged_last = 0;
    for round in 0u32..6 {
        let prompt = vec![1 + round % 8, 10, (7 * round + 3) % 64];
        let steps = 3 + (round as usize % 3);
        let want = batch1_reference(&model, &prompt, steps);
        let (sess, out) = sched.generate(Session::new(&model.cfg), &prompt, steps, |_, _| Ok(()));
        assert!(sess.is_some(), "round {round}: session lost");
        assert_eq!(out.unwrap().generated, want, "round {round} diverged");
        let staged = sched.metrics().bytes_staged();
        assert!(staged > staged_last, "round {round}: staging stopped advancing");
        staged_last = staged;
    }
    let wait = sched.metrics().prefetch_wait_s();
    assert!(wait.is_finite() && wait >= 0.0, "prefetch wait must be sane: {wait}");
    sched.shutdown();
}

#[test]
fn four_sessions_stage_at_least_3x_fewer_bytes_per_token() {
    // the acceptance criterion: batched occupancy-4 decoding vs 4
    // independent (batch-1) passes over the same workloads.  Occupancy
    // depends on how quickly the 4 client threads get scheduled after
    // the barrier, so an attempt whose mean occupancy ramped too slowly
    // (loaded CI runner) is discarded and retried with a fresh
    // scheduler; bit-exactness is still asserted on every attempt.
    let model = tiny_model(24);
    let specs: Vec<(Vec<u32>, usize)> =
        (0..4).map(|i| (vec![3, (i + 1) as u32, 17], 32)).collect();

    // batch-1 baseline: identical workloads submitted one at a time
    let solo = BatchScheduler::new(
        Arc::clone(&model),
        Box::new(ScalarGqmv),
        BatchOpts { max_batch: 1, ..Default::default() },
    );
    for spec in &specs {
        run_lanes_and_check(&model, &solo, std::slice::from_ref(spec), false);
    }
    let solo_bpt = solo.metrics().bytes_per_token();
    solo.shutdown();
    assert!(solo_bpt > 0.0);

    const ATTEMPTS: usize = 5;
    let mut last_mean = 0.0;
    for attempt in 0..ATTEMPTS {
        let batched = BatchScheduler::new(
            Arc::clone(&model),
            Box::new(ScalarGqmv),
            BatchOpts { max_batch: 4, ..Default::default() },
        );
        run_lanes_and_check(&model, &batched, &specs, true);
        let batched_bpt = batched.metrics().bytes_per_token();
        last_mean = batched.metrics().occupancy_mean();
        batched.shutdown();
        if last_mean < 3.4 {
            eprintln!("attempt {attempt}: mean occupancy {last_mean:.2}, retrying");
            continue;
        }
        assert!(batched_bpt > 0.0);
        let reduction = solo_bpt / batched_bpt;
        assert!(
            reduction >= 3.0,
            "expected >=3x staging reduction at occupancy 4, got {reduction:.2}x \
             (solo {solo_bpt:.0} B/tok, batched {batched_bpt:.0} B/tok)"
        );
        return;
    }
    panic!(
        "batch never reached steady occupancy 4 in {ATTEMPTS} attempts \
         (last mean {last_mean:.2})"
    );
}
