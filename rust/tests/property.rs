//! Cross-module property tests using the in-repo `testutil` framework
//! (proptest is unavailable offline).  These cover the coordinator
//! invariants: GQMV backend equivalence, quantization round-trip bounds,
//! checkpoint round-trips, scheduler-model monotonicity.

use std::sync::Arc;

use llamaf::fpga::{AxiModel, DataflowSim, PlConfig};
use llamaf::model::{FloatModel, KvStore, LlamaConfig, PagePool, PagedKv, QuantModel};
use llamaf::ps::gqmv::GqmvExec;
use llamaf::ps::{ScalarGqmv, ThreadedGqmv};
use llamaf::quant::{quantize_activation, QuantizedTensor};
use llamaf::sched::sim_token_time;
use llamaf::testutil::{all_close, forall};
use llamaf::util::{Rng, ThreadPool};

fn random_gqmv_case(rng: &mut Rng) -> (Vec<i8>, Vec<f32>, QuantizedTensor) {
    let gs = *rng.choose(&[16usize, 32, 64, 128, 256]);
    let groups = rng.below(5) as usize + 1;
    let n = gs * groups;
    let m = (rng.below(48) as usize + 1) * 8;
    let scale = *rng.choose(&[0.01f32, 0.3, 1.0, 30.0]);
    let w = QuantizedTensor::from_f32(&rng.normal_vec(m * n, scale), m, n, gs);
    let (xq, xs) = quantize_activation(&rng.normal_vec(n, scale), gs);
    (xq, xs, w)
}

#[test]
fn prop_all_gqmv_backends_bit_identical() {
    let pool = Arc::new(ThreadPool::new(4));
    forall("gqmv backends identical", 48, |rng| {
        let (xq, xs, w) = random_gqmv_case(rng);
        let m = w.rows;
        let mut scalar = vec![0.0f32; m];
        ScalarGqmv.gqmv(&xq, &xs, &w, &mut scalar).unwrap();

        let mut th = ThreadedGqmv::new(pool.clone());
        th.min_parallel_macs = 0;
        let mut threaded = vec![0.0f32; m];
        th.gqmv(&xq, &xs, &w, &mut threaded).unwrap();
        if scalar != threaded {
            return false;
        }
        let mut sim_out = vec![0.0f32; m];
        DataflowSim::new(PlConfig::default()).gqmv(&xq, &xs, &w, &mut sim_out).unwrap();
        scalar == sim_out
    });
}

#[test]
fn prop_quant_roundtrip_bounded() {
    forall("quant roundtrip |err| <= S/2", 64, |rng| {
        let gs = *rng.choose(&[16usize, 64, 256]);
        let groups = rng.below(6) as usize + 1;
        let scale = *rng.choose(&[1e-3f32, 1.0, 1e3]);
        let x = rng.normal_vec(gs * groups, scale);
        let t = QuantizedTensor::from_f32(&x, 1, x.len(), gs);
        let back = t.dequantize();
        (0..x.len()).all(|i| {
            let g = i / gs;
            (back[i] - x[i]).abs() <= t.s[g] / 2.0 * 1.0001 + 1e-12
        })
    });
}

#[test]
fn prop_gqmv_linearity_in_weight_scale() {
    // doubling every weight scale doubles the output exactly (f32*2 exact)
    forall("gqmv scale linearity", 32, |rng| {
        let (xq, xs, w) = random_gqmv_case(rng);
        let mut out1 = vec![0.0f32; w.rows];
        ScalarGqmv.gqmv(&xq, &xs, &w, &mut out1).unwrap();
        let w2 = QuantizedTensor {
            s: w.s.iter().map(|&s| s * 2.0).collect(),
            ..w.clone()
        };
        let mut out2 = vec![0.0f32; w.rows];
        ScalarGqmv.gqmv(&xq, &xs, &w2, &mut out2).unwrap();
        let doubled: Vec<f32> = out1.iter().map(|&v| v * 2.0).collect();
        all_close(&doubled, &out2, 1e-6, 1e-9)
    });
}

#[test]
fn prop_gqmv_zero_activation_zero_output() {
    forall("gqmv zero x -> zero out", 16, |rng| {
        let (_, _, w) = random_gqmv_case(rng);
        let xq = vec![0i8; w.cols];
        let xs = vec![0.0f32; w.cols / w.gs];
        let mut out = vec![1.0f32; w.rows];
        ScalarGqmv.gqmv(&xq, &xs, &w, &mut out).unwrap();
        out.iter().all(|&v| v == 0.0)
    });
}

#[test]
fn prop_ckpt_q8_roundtrip() {
    forall("lfq8 write/read roundtrip", 8, |rng| {
        let cfg = LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: (rng.below(3) + 1) as usize,
            n_heads: 2,
            n_kv_heads: *rng.choose(&[1usize, 2]),
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        };
        let fm = FloatModel::random(cfg, rng.next_u64());
        let path = std::env::temp_dir().join(format!("llamaf_prop_{}.lfq8", rng.next_u64()));
        llamaf::ckpt::write_q8_from_float(&path, &fm).unwrap();
        let from_file = llamaf::ckpt::read_q8(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let from_mem = QuantModel::from_float(&fm);
        from_file.tok_emb == from_mem.tok_emb
            && from_file.cls == from_mem.cls
            && from_file
                .layers
                .iter()
                .zip(&from_mem.layers)
                .all(|(a, b)| a.wqkv == b.wqkv && a.wo == b.wo && a.w13 == b.w13 && a.w2 == b.w2)
    });
}

#[test]
fn prop_sched_model_async_never_slower() {
    forall("async <= sync in timeline model", 32, |rng| {
        let cfg = LlamaConfig {
            dim: 256 * (rng.below(8) + 1) as usize,
            hidden_dim: 256 * (rng.below(24) + 1) as usize,
            n_layers: (rng.below(30) + 1) as usize,
            n_heads: 4,
            n_kv_heads: 2,
            vocab_size: 256 * (rng.below(100) + 2) as usize,
            seq_len: 2048,
            gs: 256,
        };
        if cfg.validate().is_err() {
            return true; // skip invalid draws
        }
        let (sync_s, async_s) = sim_token_time(&cfg, &PlConfig::default(), &AxiModel::default());
        async_s <= sync_s && async_s > 0.0
    });
}

#[test]
fn prop_engine_backends_same_tokens() {
    // whole-engine equivalence on random tiny models
    use llamaf::engine::forward::CpuEngine;
    use llamaf::engine::generate::{generate, Sampler};
    let pool = Arc::new(ThreadPool::new(4));
    forall("cpu engines same greedy tokens", 6, |rng| {
        let cfg = LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        };
        let qm = QuantModel::from_float(&FloatModel::random(cfg, rng.next_u64()));
        let prompt = vec![1u32, rng.below(60) as u32 + 3, rng.below(60) as u32 + 3];
        let mut e1 = CpuEngine::new(qm.clone(), Box::new(ScalarGqmv));
        let mut th = ThreadedGqmv::new(pool.clone());
        th.min_parallel_macs = 0;
        let mut e2 = CpuEngine::new(qm, Box::new(th));
        let a = generate(&mut e1, &prompt, 10, Sampler::Greedy, false).unwrap();
        let b = generate(&mut e2, &prompt, 10, Sampler::Greedy, false).unwrap();
        a.ids == b.ids
    });
}

/// Tiny geometry for page-pool churn: 2 layers × kv_dim 16 keeps each
/// `store` cheap so thousands of churn ops stay fast.
const PAGED: LlamaConfig = LlamaConfig {
    dim: 32,
    hidden_dim: 64,
    n_layers: 2,
    n_heads: 2,
    n_kv_heads: 1,
    vocab_size: 64,
    seq_len: 64,
    gs: 32,
};

/// Write one position (all layers) of deterministic, tag-distinguishable
/// KV rows into `kv`.
fn store_pos(kv: &mut PagedKv, pos: usize, tag: f32) {
    let kd = PAGED.kv_dim();
    for layer in 0..PAGED.n_layers {
        let k: Vec<f32> = (0..kd).map(|i| tag + (layer * 1000 + pos * 10 + i) as f32).collect();
        let v: Vec<f32> = k.iter().map(|x| -x).collect();
        kv.store(layer, pos, &k, &v);
    }
}

#[test]
fn prop_page_pool_ledger_exact_under_churn() {
    use std::collections::HashSet;
    // Random alloc / free / fork(COW) / evict churn: at every step the
    // pool's `pages_used()` ledger must equal the number of DISTINCT
    // pages reachable from live sessions plus the prefix cache (i.e.
    // nothing double-freed, nothing leaked), and after dropping every
    // session and clearing the cache the ledger drains to exactly zero.
    forall("page pool ledger exact", 24, |rng| {
        let ps = *rng.choose(&[2usize, 4]);
        let cap = rng.below(14) as usize + 2;
        let pool = Arc::new(PagePool::new(&PAGED, cap, ps));
        let mut sessions: Vec<(PagedKv, Vec<u32>)> = Vec::new();

        for op in 0..48u64 {
            match rng.below(8) {
                0 | 1 => {
                    // admit: fresh session, random prompt, try adoption
                    if sessions.len() < 6 {
                        let plen = rng.below(12) as usize + 2;
                        let prompt: Vec<u32> = (0..plen).map(|_| rng.below(6) as u32).collect();
                        let mut kv = PagedKv::new(Arc::clone(&pool));
                        let adopted = kv.adopt_prefix(&prompt);
                        if adopted >= prompt.len() {
                            return false; // must leave >=1 token to feed
                        }
                        sessions.push((kv, prompt));
                    }
                }
                2 => {
                    // grow: feed the next position of a random session
                    if !sessions.is_empty() {
                        let i = rng.below(sessions.len() as u64) as usize;
                        let (kv, _) = &mut sessions[i];
                        let pos = kv.filled();
                        if pos < PAGED.seq_len {
                            store_pos(kv, pos, op as f32);
                        }
                    }
                }
                3 => {
                    // overwrite a filled position: COW when shared
                    if !sessions.is_empty() {
                        let i = rng.below(sessions.len() as u64) as usize;
                        let (kv, _) = &mut sessions[i];
                        if kv.filled() > 0 {
                            let pos = rng.below(kv.filled() as u64) as usize;
                            store_pos(kv, pos, 7000.0 + op as f32);
                        }
                    }
                }
                4 => {
                    // publish a random session's prompt prefix
                    if !sessions.is_empty() {
                        let i = rng.below(sessions.len() as u64) as usize;
                        let (kv, prompt) = &sessions[i];
                        kv.cache_prefix(prompt);
                    }
                }
                5 => {
                    // retire: reset or drop a random session
                    if !sessions.is_empty() {
                        let i = rng.below(sessions.len() as u64) as usize;
                        if rng.below(2) == 0 {
                            sessions[i].0.reset();
                        } else {
                            sessions.swap_remove(i);
                        }
                    }
                }
                6 => {
                    // fail_lane: shed a random session the way the
                    // scheduler's fault-isolation path does — reset
                    // (donating every page back to the pool) then drop.
                    // The failed lane itself must hold zero pages; its
                    // cache-published pages stay alive through the
                    // prefix cache's own refs (the ledger check below
                    // proves the release was exact, not a double-free).
                    if !sessions.is_empty() {
                        let i = rng.below(sessions.len() as u64) as usize;
                        let (mut kv, _) = sessions.swap_remove(i);
                        kv.reset();
                        if !kv.page_ids().is_empty() {
                            return false;
                        }
                    }
                }
                _ => {
                    // occasional explicit cache flush (mass eviction)
                    if rng.below(4) == 0 {
                        pool.clear_cache();
                    }
                }
            }
            // Ledger invariant after every single operation.
            let mut live: HashSet<u64> = HashSet::new();
            for (kv, _) in &sessions {
                live.extend(kv.page_ids());
            }
            live.extend(pool.cached_page_ids());
            if pool.pages_used() != live.len() {
                return false;
            }
        }

        // Refcounts must drain to zero: no page outlives its holders.
        sessions.clear();
        pool.clear_cache();
        pool.pages_used() == 0 && pool.cached_page_ids().is_empty()
    });
}

#[test]
fn prop_cow_write_never_corrupts_other_holders() {
    // Fork a cached prefix into a second session, scribble over a shared
    // position, and require the donor's view to be bit-identical to its
    // pre-write snapshot (copy-on-write isolation) at every geometry.
    forall("cow isolates writers", 24, |rng| {
        let ps = *rng.choose(&[2usize, 4, 8]);
        let pool = Arc::new(PagePool::new(&PAGED, 32, ps));
        let n = rng.below(20) as usize + ps + 2; // >= one cacheable page
        let mut donor = PagedKv::new(Arc::clone(&pool));
        for pos in 0..n {
            store_pos(&mut donor, pos, 1.0);
        }
        let prompt: Vec<u32> = (0..n as u32).collect();
        donor.cache_prefix(&prompt);

        let mut writer = PagedKv::new(Arc::clone(&pool));
        let adopted = writer.adopt_prefix(&prompt);
        if adopted == 0 {
            return true; // prefix rounded below one page: nothing shared
        }
        let kd = PAGED.kv_dim();
        let snapshot: Vec<Vec<f32>> = (0..PAGED.n_layers)
            .flat_map(|l| (0..n).map(move |p| (l, p)))
            .map(|(l, p)| donor.key(l, p, 0, kd).to_vec())
            .collect();

        let pos = rng.below(adopted as u64) as usize;
        store_pos(&mut writer, pos, -999.0);

        let unchanged = (0..PAGED.n_layers)
            .flat_map(|l| (0..n).map(move |p| (l, p)))
            .zip(&snapshot)
            .all(|((l, p), snap)| donor.key(l, p, 0, kd) == &snap[..]);
        let wrote = writer.key(0, pos, 0, kd)[0] != donor.key(0, pos, 0, kd)[0];
        unchanged && wrote
    });
}
