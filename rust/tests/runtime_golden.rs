//! Integration: the PJRT runtime executes the AOT-lowered Pallas GQMV
//! kernel and reproduces (a) the python oracle's golden fixture and
//! (b) the Rust CPU backends, on real artifacts.
//!
//! Requires `make artifacts`; tests self-skip when artifacts are absent so
//! `cargo test` stays green on a fresh checkout.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use llamaf::fpga::{DataflowSim, PlConfig};
use llamaf::ps::gqmv::GqmvExec;
use llamaf::ps::{ScalarGqmv, ThreadedGqmv};
use llamaf::quant::{quantize_activation, QuantizedTensor};
use llamaf::runtime::{PjrtGqmv, Runtime};
use llamaf::util::{Rng, ThreadPool};

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn read_f32(p: &Path) -> Vec<f32> {
    std::fs::read(p)
        .unwrap()
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn read_i8(p: &Path) -> Vec<i8> {
    std::fs::read(p).unwrap().into_iter().map(|b| b as i8).collect()
}

#[test]
fn runtime_loads_and_lists_kernels() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let shapes = rt.compiled_shapes();
    assert!(shapes.contains(&(512, 256)), "{shapes:?}"); // nano qkv/cls
    assert!(shapes.contains(&(256, 768)), "{shapes:?}"); // nano w2 (kernel2)
    assert!(rt.platform().to_lowercase().contains("cpu"));
}

#[test]
fn pjrt_kernel_matches_cpu_backends() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let mut rng = Rng::new(99);
    for (m, n) in [(512usize, 256usize), (256, 256), (1536, 256), (256, 768)] {
        let gs = 256;
        let w = QuantizedTensor::from_f32(&rng.normal_vec(m * n, 0.1), m, n, gs);
        let (xq, xs) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
        let mut cpu = vec![0.0f32; m];
        ScalarGqmv.gqmv(&xq, &xs, &w, &mut cpu).unwrap();

        let mut pjrt_out = vec![0.0f32; m];
        let mut pjrt = PjrtGqmv { rt: &rt };
        pjrt.gqmv(&xq, &xs, &w, &mut pjrt_out).unwrap();
        for i in 0..m {
            assert!(
                (cpu[i] - pjrt_out[i]).abs() <= 1e-5 + cpu[i].abs() * 1e-5,
                "({m}x{n}) row {i}: cpu {} pjrt {}",
                cpu[i],
                pjrt_out[i]
            );
        }
    }
}

#[test]
fn pjrt_kernel_matches_python_golden_fixture() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // fixture shape is 64x512 (see aot.py export_golden); the runtime can
    // only run shapes with compiled kernels, so check CPU paths here and
    // full-chain numerics via the compiled nano shapes above.
    let xq = read_i8(&dir.join("golden_gqmv_xq.bin"));
    let xs = read_f32(&dir.join("golden_gqmv_xs.bin"));
    let wq = read_i8(&dir.join("golden_gqmv_wq.bin"));
    let ws = read_f32(&dir.join("golden_gqmv_ws.bin"));
    let expect = read_f32(&dir.join("golden_gqmv_out.bin"));
    let m = expect.len();
    let n = wq.len() / m;
    let w = QuantizedTensor {
        q: wq,
        s: ws,
        rows: m,
        cols: n,
        gs: 256,
        fmt: llamaf::quant::FormatId::Q8,
    };

    let pool = Arc::new(ThreadPool::new(4));
    let mut backends: Vec<Box<dyn GqmvExec>> = vec![
        Box::new(ScalarGqmv),
        Box::new(ThreadedGqmv::new(pool)),
        Box::new(DataflowSim::new(PlConfig::default())),
    ];
    for be in backends.iter_mut() {
        let mut out = vec![0.0f32; m];
        be.gqmv(&xq, &xs, &w, &mut out).unwrap();
        assert_eq!(out, expect, "backend {} diverges from python oracle", be.name());
    }
}

#[test]
fn missing_shape_reports_helpful_error() {
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::load(&dir).unwrap();
    let err = rt.ensure_shape(123, 456).unwrap_err().to_string();
    assert!(err.contains("make artifacts") || err.contains("compile.aot"), "{err}");
}

#[test]
fn runtime_rejects_empty_dir() {
    let tmp = std::env::temp_dir().join("llamaf_empty_artifacts");
    std::fs::create_dir_all(&tmp).unwrap();
    let err = match Runtime::load(&tmp) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("load of empty dir unexpectedly succeeded"),
    };
    assert!(err.contains("no gqmv"), "{err}");
}

#[test]
fn concurrent_execution_is_safe() {
    // PJRT thread-safety claim behind our unsafe Send impls: hammer one
    // runtime from several threads at once.
    let Some(dir) = artifacts() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Arc::new(Runtime::load(&dir).unwrap());
    let mut rng = Rng::new(5);
    let (m, n, gs) = (256usize, 256usize, 256usize);
    let w = Arc::new(QuantizedTensor::from_f32(&rng.normal_vec(m * n, 0.1), m, n, gs));
    let (xq, xs) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
    let mut expect = vec![0.0f32; m];
    ScalarGqmv.gqmv(&xq, &xs, &w, &mut expect).unwrap();

    let xq = Arc::new(xq);
    let xs = Arc::new(xs);
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let (rt, w, xq, xs, expect) =
                (rt.clone(), w.clone(), xq.clone(), xs.clone(), expect.clone());
            std::thread::spawn(move || {
                for _ in 0..8 {
                    let dw = rt.upload(&w).unwrap();
                    let mut out = vec![0.0f32; m];
                    rt.gqmv_device(&dw, &xq, &xs, &mut out).unwrap();
                    for i in 0..m {
                        assert!((out[i] - expect[i]).abs() <= 1e-5 + expect[i].abs() * 1e-5);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}
