//! Cross-backend trace equivalence, end to end: the host engine
//! (`CpuEngine`) and the streamed-weight device engine (`LlamafEngine`
//! over the simulated runtime) must record bit-identical execution
//! traces for the same prompt — the `trace-diff` acceptance contract —
//! and a seeded single-bit perturbation must be localized to its exact
//! (step, layer, op, lane) coordinates.
//!
//! Runs on the synthetic tiny model — no artifacts required.

use llamaf::engine::forward::{CpuEngine, Engine};
use llamaf::engine::generate::{generate, Sampler};
use llamaf::model::{FloatModel, LlamaConfig, QuantModel};
use llamaf::ps::ScalarGqmv;
use llamaf::trace::{diff, DiffOutcome, ExecTrace, TraceOp};

const PROMPT: [u32; 3] = [1, 7, 42];
const STEPS: usize = 5;

fn tiny_cfg() -> LlamaConfig {
    LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 512,
        seq_len: 64,
        gs: 32,
    }
}

/// Greedy-generate with tracing on and return the recorded trace.
fn record(engine: &mut dyn Engine, label: &str) -> ExecTrace {
    assert!(engine.trace_start(label), "engine must support tracing");
    generate(engine, &PROMPT, STEPS, Sampler::Greedy, false).unwrap();
    engine.trace_take().expect("tracing enabled but no trace produced")
}

#[test]
#[cfg(not(feature = "pjrt"))]
fn host_and_device_backends_record_identical_traces() {
    use std::sync::Arc;

    use llamaf::engine::llamaf::LlamafEngine;
    use llamaf::runtime::Runtime;
    use llamaf::sched::{SchedMode, StageGranularity};

    let cfg = tiny_cfg();
    let qm = QuantModel::from_float(&FloatModel::random(cfg, 11));
    let mut host = CpuEngine::new(qm.clone(), Box::new(ScalarGqmv));
    let a = record(&mut host, "host");

    // streamed matrix-granular device path: maximally different staging
    // schedule, must still compute (and therefore digest) the same bits
    let rt = Arc::new(Runtime::with_shapes(&cfg.all_mat_shapes()));
    let mut dev =
        LlamafEngine::from_model_with_opts(qm, rt, SchedMode::Async, 2, StageGranularity::Matrix)
            .unwrap();
    let b = record(&mut dev, "device-sim");

    let report = diff(&a, &b);
    assert!(report.identical(), "host vs device: {}", report.summary());
    assert!(report.compared > 0, "traces must not be empty");
    assert_eq!(report.compared, a.len());
    // every forward step records 4 GQMV digests per layer + the classifier
    let per_step = cfg.n_layers * 4 + 1;
    assert_eq!(a.len(), per_step * a.steps() as usize);
    // labels differ but are metadata, never compared
    assert_ne!(a.label(), b.label());
}

#[test]
fn perturbed_trace_is_localized_to_exact_coordinates() {
    let cfg = tiny_cfg();
    let qm = QuantModel::from_float(&FloatModel::random(cfg, 12));
    let mut host = CpuEngine::new(qm, Box::new(ScalarGqmv));
    let a = record(&mut host, "baseline");

    // seed a single-bit divergence at step 2 / layer 1 / W13 / lane 0 by
    // editing the serialized trace — exactly what a diverging backend
    // would produce at that op
    let needle = "e 2 1 w13 0 ";
    let mut lines: Vec<String> = a.to_text().lines().map(String::from).collect();
    let idx = lines
        .iter()
        .position(|l| l.starts_with(needle))
        .expect("target op must appear in the trace");
    let digest = u64::from_str_radix(&lines[idx][needle.len()..], 16).unwrap();
    lines[idx] = format!("{needle}{:016x}", digest ^ 1);
    let b = ExecTrace::parse(&(lines.join("\n") + "\n")).unwrap();

    let report = diff(&a, &b);
    assert!(!report.identical());
    match report.outcome {
        DiffOutcome::Diverged { first, total } => {
            assert_eq!(total, 1, "exactly one op was perturbed");
            assert_eq!(first.step, 2);
            assert_eq!(first.layer, 1);
            assert_eq!(first.op, TraceOp::W13);
            assert_eq!(first.lane, 0);
            // step events are ordered (layer, qkv/wo/w13/w2)*, cls — so the
            // divergent index is fully determined by the coordinates
            let per_step = cfg.n_layers * 4 + 1;
            assert_eq!(first.index, 2 * per_step + 4 + 2);
            assert_eq!(first.a ^ first.b, 1);
        }
        other => panic!("expected Diverged, got {other:?}"),
    }
    let s = report.summary();
    assert!(s.contains("step 2 layer 1 op w13 lane 0"), "summary must localize: {s}");
}

#[test]
fn traces_survive_a_save_load_round_trip() {
    let cfg = tiny_cfg();
    let qm = QuantModel::from_float(&FloatModel::random(cfg, 13));
    let mut host = CpuEngine::new(qm, Box::new(ScalarGqmv));
    let a = record(&mut host, "round-trip");

    let path = std::env::temp_dir().join(format!("llamaf_trace_rt_{}.trace", std::process::id()));
    a.save(&path).unwrap();
    let loaded = ExecTrace::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert!(diff(&a, &loaded).identical());
    assert_eq!(loaded.label(), a.label());
    assert_eq!(loaded.steps(), a.steps());
    assert_eq!(loaded.cfg(), a.cfg());
    assert!(!loaded.truncated());
}
