//! Integration: concurrent multi-session serving over one shared weight
//! copy.
//!
//!  * ≥ 4 parallel TCP clients streaming through one `SessionPool` get
//!    greedy token streams byte-identical to sequential batch-1 serving.
//!  * Connection-queue overflow answers `ERR busy` instead of hanging.
//!  * `STATS` exposes the serving metrics.
//!
//! Runs on the synthetic tiny model — no artifacts required.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use llamaf::engine::forward::CpuEngine;
use llamaf::engine::generate::{generate, Sampler};
use llamaf::model::{FloatModel, LlamaConfig, QuantModel};
use llamaf::ps::gqmv::GqmvExec;
use llamaf::ps::ScalarGqmv;
use llamaf::server::{ServeOpts, Server};
use llamaf::tokenizer::Tokenizer;

fn scalar_exec() -> Box<dyn GqmvExec + Send> {
    Box::new(ScalarGqmv)
}

fn tiny_model(seed: u64) -> Arc<QuantModel> {
    let cfg = LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 512,
        seq_len: 64,
        gs: 32,
    };
    Arc::new(QuantModel::from_float(&FloatModel::random(cfg, seed)))
}

#[test]
fn four_concurrent_clients_match_sequential_batch1() {
    let model = tiny_model(7);
    let steps = 8usize;
    let prompts =
        ["the engineer builds", "a student studies", "hello world", "fpga streams weights"];

    // sequential batch-1 reference, one dedicated engine per prompt
    let tok = Tokenizer::new(512);
    let mut expected = Vec::new();
    for p in prompts {
        let mut eng = CpuEngine::new(Arc::clone(&model), Box::new(ScalarGqmv));
        let ids = tok.encode(p, true);
        let out = generate(&mut eng, &ids, steps, Sampler::Greedy, false).unwrap();
        expected.push(out.generated);
    }

    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts { workers: 4, queue_depth: 16, max_sessions: 8, ..Default::default() };
    let m2 = Arc::clone(&model);
    let server_thread = std::thread::spawn(move || {
        server.serve_shared(m2, &scalar_exec, &opts, Some(prompts.len())).unwrap()
    });

    let clients: Vec<_> = prompts
        .iter()
        .map(|p| {
            let p = p.to_string();
            std::thread::spawn(move || -> Vec<u32> {
                let mut conn = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                conn.write_all(format!("SGEN {steps} {p}\n").as_bytes()).unwrap();
                let mut ids = Vec::new();
                loop {
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let line = line.trim_end();
                    if let Some(rest) = line.strip_prefix("TOK ") {
                        // TOK <step> <id> <piece...>
                        let mut parts = rest.splitn(3, ' ');
                        let step: usize = parts.next().unwrap().parse().unwrap();
                        assert_eq!(step, ids.len(), "out-of-order TOK line");
                        let id_str = parts.next().expect("TOK line missing token id");
                        ids.push(id_str.parse().unwrap());
                    } else if line.starts_with("DONE ") {
                        break;
                    } else {
                        panic!("unexpected server line: {line:?}");
                    }
                }
                conn.write_all(b"QUIT\n").unwrap();
                ids
            })
        })
        .collect();

    for (client, want) in clients.into_iter().zip(&expected) {
        let got = client.join().unwrap();
        assert_eq!(&got, want, "concurrent session diverged from batch-1 serving");
    }
    let report = server_thread.join().unwrap();
    assert_eq!(report.accepted, prompts.len());
    assert_eq!(report.requests, prompts.len() as u64);
    assert_eq!(report.rejected, 0);
    assert_eq!(report.tokens, (prompts.len() * steps) as u64);
}

#[test]
fn queue_overflow_returns_err_busy_not_hang() {
    let model = tiny_model(8);
    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts { workers: 1, queue_depth: 1, max_sessions: 2, ..Default::default() };
    let server_thread = std::thread::spawn(move || {
        server.serve_shared(model, &scalar_exec, &opts, Some(3)).unwrap()
    });

    // A occupies the single worker (PONG proves it was dequeued)
    let mut a = TcpStream::connect(addr).unwrap();
    let mut ra = BufReader::new(a.try_clone().unwrap());
    a.write_all(b"PING\n").unwrap();
    let mut line = String::new();
    ra.read_line(&mut line).unwrap();
    assert_eq!(line.trim(), "PONG");

    // B fills the one queue slot (the worker is still held by A)
    let b = TcpStream::connect(addr).unwrap();
    std::thread::sleep(Duration::from_millis(100)); // let the accept loop enqueue B

    // C overflows the bounded queue -> immediate ERR busy, no hang
    let c = TcpStream::connect(addr).unwrap();
    let mut rc = BufReader::new(c.try_clone().unwrap());
    line.clear();
    rc.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR busy"), "expected busy rejection, got {line:?}");

    // release the worker; B gets served (EOF) and the server drains
    a.write_all(b"QUIT\n").unwrap();
    drop(a);
    drop(ra);
    drop(b);
    let report = server_thread.join().unwrap();
    assert_eq!(report.accepted, 3);
    assert_eq!(report.rejected, 1);
}

#[test]
fn stats_and_plain_gen_roundtrip() {
    let model = tiny_model(9);
    // reference output for the same prompt through the batch-1 path
    let tok = Tokenizer::new(512);
    let mut eng = CpuEngine::new(Arc::clone(&model), Box::new(ScalarGqmv));
    let ids = tok.encode("hello", true);
    let want = generate(&mut eng, &ids, 4, Sampler::Greedy, false).unwrap();
    let want_text = tok.decode(&want.generated).replace('\n', " ");

    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts { workers: 2, queue_depth: 8, max_sessions: 4, ..Default::default() };
    let server_thread = std::thread::spawn(move || {
        server.serve_shared(model, &scalar_exec, &opts, Some(1)).unwrap()
    });

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    conn.write_all(b"GEN 4 hello\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "{line}");
    let text = line.trim_end().split_once(" | ").expect("OK <rate> | <text>").1.to_string();
    assert_eq!(text, want_text, "shared-mode GEN diverged from batch-1 output");

    conn.write_all(b"STATS\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "{line}");
    for field in [
        "sessions_idle=",
        "sessions_busy=",
        "sessions_cap=4",
        "weights=streamed",
        "requests=1",
        "tokens=4",
        // batched-decoding counters: "hello" encodes to 6 tokens (BOS +
        // 5 bytes), so 5 prompt feeds + 4 sampled steps = 9 forwards
        "batch_steps=9",
        "batch_tokens=9",
        "bytes_staged=",
        "bytes_per_tok=",
        "prefetch_wait_ms=",
    ] {
        assert!(line.contains(field), "STATS missing {field}: {line}");
    }

    conn.write_all(b"QUIT\n").unwrap();
    drop(conn);
    let report = server_thread.join().unwrap();
    assert_eq!(report.requests, 1);
}

#[test]
fn resident_serving_matches_batch1_and_reports_zero_staging() {
    // `serve --resident`: same protocol, zero-copy weights.  Outputs must
    // still be byte-identical to batch-1 serving, and STATS must show
    // weights=resident with no staged bytes.
    let model = tiny_model(10);
    let tok = Tokenizer::new(512);
    let mut eng = CpuEngine::new(Arc::clone(&model), Box::new(ScalarGqmv));
    let ids = tok.encode("resident weights", true);
    let want = generate(&mut eng, &ids, 6, Sampler::Greedy, false).unwrap();
    let want_text = tok.decode(&want.generated).replace('\n', " ");

    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts {
        workers: 2,
        queue_depth: 8,
        max_sessions: 4,
        resident: true,
        ..Default::default()
    };
    let server_thread = std::thread::spawn(move || {
        server.serve_shared(model, &scalar_exec, &opts, Some(1)).unwrap()
    });

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    conn.write_all(b"GEN 6 resident weights\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "{line}");
    let text = line.trim_end().split_once(" | ").expect("OK <rate> | <text>").1.to_string();
    assert_eq!(text, want_text, "resident serving diverged from batch-1 output");

    conn.write_all(b"STATS\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    for field in ["weights=resident", "bytes_staged=0 ", "prefetch_wait_ms=0.000"] {
        assert!(line.contains(field), "STATS missing {field}: {line}");
    }

    conn.write_all(b"QUIT\n").unwrap();
    drop(conn);
    let report = server_thread.join().unwrap();
    assert_eq!(report.requests, 1);
    assert_eq!(report.tokens, 6);
}

#[test]
fn resident_plus_sync_is_rejected_at_startup() {
    let model = tiny_model(11);
    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let opts = ServeOpts { resident: true, sync_staging: true, ..Default::default() };
    let err = server.serve_shared(model, &scalar_exec, &opts, Some(1)).unwrap_err();
    assert!(err.to_string().contains("--resident"), "{err}");
}
