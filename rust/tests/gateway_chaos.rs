//! Integration: the replicated-serving gateway under deterministic
//! chaos, end to end.
//!
//! The contract under test (see `docs/ARCHITECTURE.md`, "Scale-out
//! topology"): killing one of three replicas mid-soak leaves every
//! completed stream **bit-exact** against the batch-1 oracle (greedy
//! decoding is deterministic and every replica serves the same
//! checkpoint, so a redirected request produces the very tokens the dead
//! replica would have); every client sees either a complete stream or an
//! honest `ERR` (`fault:`/`busy`/`deadline:` taxonomy — never a silent
//! truncation, never garbage); the gateway's bounded queues drain to
//! zero; and every replica drains to zero checked-out sessions and zero
//! live KV pages.  Chaos plans are scripted and seeded, so a run is
//! reproducible from its seed.
//!
//! CI sweeps the kill/stall × replica matrix through the environment:
//! `LLAMAF_CHAOS_FAULT` (kill|stall), `LLAMAF_CHAOS_BACKEND` (replica
//! index), `LLAMAF_CHAOS_SEED` (u64).  Defaults exercise killing replica
//! 1.  Runs on the synthetic tiny model — no artifacts required.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use llamaf::engine::forward::CpuEngine;
use llamaf::engine::generate::{generate, Sampler};
use llamaf::model::{FloatModel, LlamaConfig, QuantModel};
use llamaf::ps::gqmv::GqmvExec;
use llamaf::ps::ScalarGqmv;
use llamaf::sched::FaultPlan;
use llamaf::server::gateway::{ChaosPlan, Gateway, GatewayOpts, GatewayReport};
use llamaf::server::{ServeOpts, ServeReport, Server};
use llamaf::tokenizer::Tokenizer;

const VOCAB: usize = 512;

fn tiny_cfg() -> LlamaConfig {
    LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: VOCAB,
        seq_len: 64,
        gs: 32,
    }
}

fn tiny_model(seed: u64) -> Arc<QuantModel> {
    Arc::new(QuantModel::from_float(&FloatModel::random(tiny_cfg(), seed)))
}

fn scalar_exec() -> Box<dyn GqmvExec + Send> {
    Box::new(ScalarGqmv)
}

/// Batch-1 greedy oracle for `prompt`: the tokens every replica (and
/// therefore the gateway) must stream for it, bit for bit.
fn batch1_oracle(model: &Arc<QuantModel>, prompt: &[u32], steps: usize) -> Vec<u32> {
    let mut eng = CpuEngine::new(Arc::clone(model), Box::new(ScalarGqmv));
    generate(&mut eng, prompt, steps, Sampler::Greedy, false).unwrap().generated
}

/// One engine replica serving the shared checkpoint until `SHUTDOWN`.
struct Replica {
    addr: SocketAddr,
    thread: JoinHandle<ServeReport>,
}

fn spawn_replica(model: &Arc<QuantModel>, faults: Option<FaultPlan>) -> Replica {
    let server = Server::bind("127.0.0.1:0", VOCAB).unwrap();
    let addr = server.local_addr().unwrap();
    let model = Arc::clone(model);
    let thread = std::thread::spawn(move || {
        let opts = ServeOpts {
            workers: 2,
            queue_depth: 16,
            max_sessions: 4,
            kv_pages: 32,
            faults,
            ..Default::default()
        };
        server.serve_shared(model, &scalar_exec, &opts, None).unwrap()
    });
    Replica { addr, thread }
}

fn spawn_gateway(
    backends: &[SocketAddr],
    max_queue: usize,
    chaos: Option<ChaosPlan>,
) -> (SocketAddr, JoinHandle<GatewayReport>) {
    let gw = Gateway::bind("127.0.0.1:0").unwrap();
    let addr = gw.local_addr().unwrap();
    let opts = GatewayOpts {
        backends: backends.iter().map(|a| a.to_string()).collect(),
        workers: 4,
        queue_depth: 32,
        max_queue,
        probe_interval_ms: 10,
        probe_timeout_ms: 200,
        connect_timeout_ms: 1000,
        chaos,
    };
    let thread = std::thread::spawn(move || gw.run(&opts, None).unwrap());
    (addr, thread)
}

/// Send `SHUTDOWN` to a gateway or replica and read the ack.
fn shutdown(addr: SocketAddr) {
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(b"SHUTDOWN\n").unwrap();
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    assert!(line.starts_with("OK"), "SHUTDOWN not acknowledged: {line:?}");
    let _ = conn.write_all(b"QUIT\n");
}

/// What one soak client observed, normalized for comparison across runs.
#[derive(Debug, PartialEq, Eq)]
enum Outcome {
    /// A complete stream: the exact token ids, in order.
    Done(Vec<u32>),
    /// An honest refusal/shed line (`ERR ...`), verbatim.
    Refused(String),
}

/// Run one client through the gateway: `SGEN steps <prompt>`, collect
/// the stream, classify the outcome.  Panics on anything dishonest —
/// an unknown line, or an `ERR` outside the documented taxonomy.
fn run_client(addr: SocketAddr, prompt: &str, steps: usize) -> Outcome {
    let mut conn = TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(format!("SGEN {steps} {prompt}\n").as_bytes()).unwrap();
    let mut got: Vec<u32> = Vec::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let line = line.trim_end().to_string();
        if let Some(rest) = line.strip_prefix("TOK ") {
            let id: u32 = rest.split_whitespace().nth(1).unwrap().parse().unwrap();
            got.push(id);
        } else if line.starts_with("DONE ") {
            let _ = conn.write_all(b"QUIT\n");
            return Outcome::Done(got);
        } else if line.starts_with("ERR ") {
            let honest = line.starts_with("ERR fault:")
                || line.starts_with("ERR deadline:")
                || line.starts_with("ERR busy");
            assert!(honest, "dishonest error line: {line:?}");
            assert!(
                got.is_empty() || line.starts_with("ERR fault:"),
                "a started stream may only end in a fault shed: {line:?}"
            );
            let _ = conn.write_all(b"QUIT\n");
            return Outcome::Refused(line);
        } else {
            panic!("unexpected gateway line: {line:?}");
        }
    }
}

fn env_or(name: &str, default: &str) -> String {
    std::env::var(name).unwrap_or_else(|_| default.to_string())
}

/// Send one command and read its first reply line.
fn ask(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, cmd: &str) -> String {
    conn.write_all(format!("{cmd}\n").as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line.trim_end().to_string()
}

#[test]
fn killing_one_of_three_replicas_mid_soak_keeps_survivors_bit_exact() {
    // The tentpole drill, CI-parameterized: 12 staggered clients stream
    // through a 3-replica gateway while a scripted fault (default: kill
    // replica 1 after 6 routed requests) lands mid-soak.  Every DONE
    // stream must match the batch-1 oracle exactly; every failure must be
    // an honest ERR; gateway and replica ledgers must drain to zero.
    let fault = env_or("LLAMAF_CHAOS_FAULT", "kill");
    let backend = env_or("LLAMAF_CHAOS_BACKEND", "1");
    let seed = env_or("LLAMAF_CHAOS_SEED", "7");
    let spec = match fault.as_str() {
        "kill" => format!("seed={seed},after=6,at={backend}/kill"),
        "stall" => format!("seed={seed},stall_ms=30,after=6,at={backend}/stall/3"),
        other => panic!("LLAMAF_CHAOS_FAULT must be kill or stall, got {other:?}"),
    };
    let chaos = ChaosPlan::parse(&spec).unwrap();

    let model = tiny_model(50);
    let replicas: Vec<Replica> = (0..3).map(|_| spawn_replica(&model, None)).collect();
    let backend_addrs: Vec<SocketAddr> = replicas.iter().map(|r| r.addr).collect();
    let (gw_addr, gw_thread) = spawn_gateway(&backend_addrs, 4, Some(chaos));

    let tokenizer = Tokenizer::new(VOCAB);
    let n_clients = 12usize;
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let want = batch1_oracle(&model, &tokenizer.encode(&format!("soak {i}"), true), 4);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(i as u64 * 15));
                let outcome = run_client(gw_addr, &format!("soak {i}"), 4);
                match outcome {
                    Outcome::Done(got) => {
                        assert_eq!(got, want, "client {i}: stream diverged from the oracle");
                        (1usize, 0usize)
                    }
                    Outcome::Refused(_) => (0, 1),
                }
            })
        })
        .collect();
    let (mut done, mut errs) = (0usize, 0usize);
    for h in handles {
        let (d, e) = h.join().unwrap();
        done += d;
        errs += e;
    }
    assert_eq!(done + errs, n_clients);
    assert!(done >= n_clients / 2, "soak mostly failed: {done} done, {errs} errors");

    shutdown(gw_addr);
    let report = gw_thread.join().unwrap();
    assert!(report.routed >= done as u64, "every DONE stream was routed");
    assert_eq!(report.in_flight_at_exit, 0, "per-backend queues did not drain");
    assert_eq!(report.queued_at_exit, 0, "client connections left queued at exit");
    if fault == "kill" {
        assert!(report.probes_failed > 0, "the prober never saw the killed replica");
    }

    // chaos severs only the gateway's view — the replica processes are
    // healthy and must drain to zero sessions and zero KV pages
    for (ri, r) in replicas.into_iter().enumerate() {
        shutdown(r.addr);
        let rep = r.thread.join().unwrap();
        assert_eq!(rep.busy_at_exit, 0, "replica {ri}: session leaked");
        assert_eq!(rep.kv_pages_at_exit, 0, "replica {ri}: KV pages leaked");
    }
}

#[test]
fn pre_stream_backend_death_redirects_transparently() {
    // Replica 0 is killed by the chaos plan after the first routed
    // request — i.e. between the client's pin (connect) and its first
    // send.  The gateway must notice the dead send before any output
    // reached the client and replay the request on replica 1: the client
    // sees ONE clean stream, bit-exact, and never learns anything failed.
    let model = tiny_model(51);
    let replicas: Vec<Replica> = (0..2).map(|_| spawn_replica(&model, None)).collect();
    let backend_addrs: Vec<SocketAddr> = replicas.iter().map(|r| r.addr).collect();
    let chaos = ChaosPlan::parse("after=1,at=0/kill").unwrap();
    let (gw_addr, gw_thread) = spawn_gateway(&backend_addrs, 4, Some(chaos));

    let tokenizer = Tokenizer::new(VOCAB);
    let want = batch1_oracle(&model, &tokenizer.encode("redirect me", true), 5);
    match run_client(gw_addr, "redirect me", 5) {
        Outcome::Done(got) => assert_eq!(got, want, "redirected stream diverged"),
        Outcome::Refused(e) => panic!("pre-stream death must be transparent, got {e:?}"),
    }

    shutdown(gw_addr);
    let report = gw_thread.join().unwrap();
    assert_eq!(report.redirected, 1, "exactly one transparent redirect");
    assert_eq!(report.shed, 0, "nothing was client-visibly shed");
    assert_eq!(report.in_flight_at_exit, 0);
    for r in replicas {
        shutdown(r.addr);
        let rep = r.thread.join().unwrap();
        assert_eq!(rep.busy_at_exit, 0);
        assert_eq!(rep.kv_pages_at_exit, 0);
    }
}

#[test]
fn mid_stream_backend_loss_is_shed_honestly_and_the_replica_drains() {
    // One replica whose engine stalls 30 ms per step (so a generation is
    // slow enough to observe mid-flight).  Client A starts a long stream
    // and reads its first tokens; then client B's request arms the kill
    // (after=2).  A's stream must end in `ERR fault: backend lost` with
    // the tokens-so-far a bit-exact PREFIX of the oracle; B must get an
    // honest `ERR fault:` (no backend left to redirect to); the orphaned
    // replica lane must be cancelled by the dropped pin, draining the
    // replica to zero sessions and pages.
    let model = tiny_model(52);
    let stall = FaultPlan::parse("stall_ms=30,at=1/any/stall/always").unwrap();
    let replica = spawn_replica(&model, Some(stall));
    let chaos = ChaosPlan::parse("after=2,at=0/kill").unwrap();
    let (gw_addr, gw_thread) = spawn_gateway(&[replica.addr], 4, Some(chaos));

    let tokenizer = Tokenizer::new(VOCAB);
    let want = batch1_oracle(&model, &tokenizer.encode("long slow stream", true), 20);

    let mut a = TcpStream::connect(gw_addr).unwrap();
    a.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut a_reader = BufReader::new(a.try_clone().unwrap());
    a.write_all(b"SGEN 20 long slow stream\n").unwrap();
    let mut got: Vec<u32> = Vec::new();
    let mut first = String::new();
    a_reader.read_line(&mut first).unwrap();
    let first = first.trim_end();
    assert!(first.starts_with("TOK "), "stream did not start: {first:?}");
    got.push(first.split_whitespace().nth(2).unwrap().parse().unwrap());

    // B arms the kill and is refused honestly (sole backend is now dead)
    match run_client(gw_addr, "second request", 4) {
        Outcome::Refused(e) => assert!(e.starts_with("ERR fault:"), "{e:?}"),
        Outcome::Done(_) => panic!("B must not complete on a killed backend"),
    }

    // A's stream must now die with the documented shed line
    let shed_line = loop {
        let mut line = String::new();
        a_reader.read_line(&mut line).unwrap();
        let line = line.trim_end().to_string();
        if let Some(rest) = line.strip_prefix("TOK ") {
            got.push(rest.split_whitespace().nth(1).unwrap().parse().unwrap());
        } else {
            break line;
        }
    };
    assert_eq!(shed_line, "ERR fault: backend lost", "shed must be explicit");
    assert!(!got.is_empty() && got.len() < 20, "shed landed mid-stream (got {})", got.len());
    assert_eq!(got[..], want[..got.len()], "pre-shed tokens must be oracle-exact");
    let _ = a.write_all(b"QUIT\n");
    drop(a_reader);
    drop(a);

    shutdown(gw_addr);
    let report = gw_thread.join().unwrap();
    assert_eq!(report.shed, 1, "exactly one mid-stream shed");
    assert_eq!(report.in_flight_at_exit, 0);

    // the dropped pin cancels the orphaned lane on the (healthy) replica
    shutdown(replica.addr);
    let rep = replica.thread.join().unwrap();
    assert_eq!(rep.busy_at_exit, 0, "orphaned session leaked");
    assert_eq!(rep.kv_pages_at_exit, 0, "orphaned KV pages leaked");
}

#[test]
fn gateway_shutdown_drains_in_flight_work_and_refuses_late_connections() {
    // SHUTDOWN mid-conversation: client A holds an open connection, B
    // orders the shutdown, C connects late.  A must still be served until
    // it quits (drain, not abort), C must be refused immediately with an
    // honest ERR busy — never silently dropped, never hung.
    let model = tiny_model(53);
    let replica = spawn_replica(&model, None);
    let (gw_addr, gw_thread) = spawn_gateway(&[replica.addr], 4, None);

    let tokenizer = Tokenizer::new(VOCAB);
    let want = batch1_oracle(&model, &tokenizer.encode("drain me", true), 4);
    let mut a = TcpStream::connect(gw_addr).unwrap();
    a.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut a_reader = BufReader::new(a.try_clone().unwrap());
    a.write_all(b"PING\n").unwrap();
    let mut pong = String::new();
    a_reader.read_line(&mut pong).unwrap();
    assert_eq!(pong.trim_end(), "PONG");

    shutdown(gw_addr); // B

    // C: late connection is refused, not queued and not hung
    let mut c = TcpStream::connect(gw_addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut c_reader = BufReader::new(c.try_clone().unwrap());
    let mut refusal = String::new();
    c_reader.read_line(&mut refusal).unwrap();
    assert_eq!(refusal.trim_end(), "ERR busy: gateway shutting down");

    // A keeps working through the drain: a full generation, bit-exact
    a.write_all(b"SGEN 4 drain me\n").unwrap();
    let mut got: Vec<u32> = Vec::new();
    loop {
        let mut line = String::new();
        a_reader.read_line(&mut line).unwrap();
        let line = line.trim_end();
        if let Some(rest) = line.strip_prefix("TOK ") {
            got.push(rest.split_whitespace().nth(1).unwrap().parse().unwrap());
        } else {
            assert!(line.starts_with("DONE "), "drain aborted A's stream: {line:?}");
            break;
        }
    }
    assert_eq!(got, want, "drained stream diverged");
    a.write_all(b"QUIT\n").unwrap();
    drop(a_reader);
    drop(a);

    let report = gw_thread.join().unwrap();
    assert_eq!(report.in_flight_at_exit, 0);
    assert_eq!(report.queued_at_exit, 0);

    shutdown(replica.addr);
    let rep = replica.thread.join().unwrap();
    assert_eq!(rep.busy_at_exit, 0);
    assert_eq!(rep.kv_pages_at_exit, 0);
}

#[test]
fn same_seed_chaos_runs_are_reproducible() {
    // Two sequential-client soaks under seeded probabilistic connect
    // faults (p=0.4): the per-client outcome sequence — which requests
    // completed, which tokens, which error lines — must be identical
    // across runs with the same seed.  Clients run one at a time so RNG
    // consumption order is schedule-independent.
    let model = tiny_model(54);
    let tokenizer = Tokenizer::new(VOCAB);
    let run_once = || -> Vec<Outcome> {
        let replicas: Vec<Replica> = (0..2).map(|_| spawn_replica(&model, None)).collect();
        let backend_addrs: Vec<SocketAddr> = replicas.iter().map(|r| r.addr).collect();
        let chaos = ChaosPlan::parse("p=0.4,seed=99").unwrap();
        let (gw_addr, gw_thread) = spawn_gateway(&backend_addrs, 4, Some(chaos));
        let outcomes: Vec<Outcome> = (0..8)
            .map(|i| {
                // let at least one probe cycle land between clients so a
                // transient-fault streak never accumulates into Down
                // (which would skip a backend without consuming an RNG
                // roll and desynchronize the two runs)
                std::thread::sleep(Duration::from_millis(25));
                run_client(gw_addr, &format!("replay {i}"), 3)
            })
            .collect();
        shutdown(gw_addr);
        let report = gw_thread.join().unwrap();
        assert_eq!(report.in_flight_at_exit, 0);
        for r in replicas {
            shutdown(r.addr);
            let rep = r.thread.join().unwrap();
            assert_eq!(rep.busy_at_exit, 0);
            assert_eq!(rep.kv_pages_at_exit, 0);
        }
        outcomes
    };
    let first = run_once();
    let second = run_once();
    assert_eq!(first, second, "same seed must replay the same outcome sequence");
    // and completed streams are still oracle-exact, faults or not
    for (i, o) in first.iter().enumerate() {
        if let Outcome::Done(got) = o {
            let want = batch1_oracle(&model, &tokenizer.encode(&format!("replay {i}"), true), 3);
            assert_eq!(got, &want, "client {i}: faulty-run stream diverged");
        }
    }
}

#[test]
fn gateway_observability_surfaces_answer_locally() {
    // PING / HEALTH / STATS / METRICS are gateway-local (never proxied):
    // pin their shapes so dashboards and the prober can rely on them.
    let model = tiny_model(55);
    let replica = spawn_replica(&model, None);
    let (gw_addr, gw_thread) = spawn_gateway(&[replica.addr], 4, None);

    // complete one generation so the counters are non-trivial
    match run_client(gw_addr, "warm up", 3) {
        Outcome::Done(got) => assert_eq!(got.len(), 3),
        Outcome::Refused(e) => panic!("healthy gateway refused: {e:?}"),
    }

    let mut conn = TcpStream::connect(gw_addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    assert_eq!(ask(&mut conn, &mut reader, "PING"), "PONG");

    let health = ask(&mut conn, &mut reader, "HEALTH");
    let parsed = llamaf::server::health::parse_health_reply(&health).unwrap();
    assert_eq!(parsed.busy, 0, "nothing in flight");
    assert_eq!(parsed.lanes, 1, "lanes= counts Up backends at the gateway");

    let stats = ask(&mut conn, &mut reader, "STATS");
    assert!(stats.starts_with("OK gateway backends=1 "), "{stats:?}");
    assert!(stats.contains(" routed=1 "), "warm-up request not counted: {stats:?}");
    assert!(stats.contains(" b0=up/0/1"), "per-backend triple missing: {stats:?}");

    let head = ask(&mut conn, &mut reader, "METRICS");
    let n: usize = head.strip_prefix("METRICS ").unwrap().parse().unwrap();
    assert_eq!(n, 16, "12 aggregate + 4 per-backend lines for one backend");
    for _ in 0..n {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("llamaf_gateway_"), "unprefixed metric: {line:?}");
        assert_eq!(line.trim_end().split(' ').count(), 2, "name value: {line:?}");
    }

    // TRACE before any generation on THIS connection is an honest error
    let trace = ask(&mut conn, &mut reader, "TRACE");
    assert!(trace.starts_with("ERR "), "{trace:?}");
    conn.write_all(b"QUIT\n").unwrap();
    drop(reader);
    drop(conn);

    shutdown(gw_addr);
    let report = gw_thread.join().unwrap();
    assert_eq!(report.routed, 1);
    assert_eq!(report.in_flight_at_exit, 0);
    shutdown(replica.addr);
    let rep = replica.thread.join().unwrap();
    assert_eq!(rep.busy_at_exit, 0);
    assert_eq!(rep.kv_pages_at_exit, 0);
}
