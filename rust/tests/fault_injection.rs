//! Integration: the fault-tolerant serving core under injected I/O
//! faults, end to end.
//!
//! The contract under test (see `docs/ARCHITECTURE.md`, "Failure
//! domains"): an injected staging fault is absorbed by the retry ladder
//! — staged-read retries first, full-step retries above them — and a
//! request that survives faults via retries must be **bit-identical**
//! (tokens AND per-op digest trace) to a fault-free batch-1 run.  A
//! fault that exhausts every retry sheds exactly ONE lane with a
//! `fault:` error while every other lane keeps decoding bit-identically;
//! an expired per-request deadline sheds with `deadline:`.  In all
//! cases the server drains to zero checked-out sessions and zero live
//! KV pages, and a checksum-corrupted checkpoint is rejected at staging
//! time, before any token could be produced from bad weights.
//!
//! Everything is deterministic: fault plans are scripted or seeded, so
//! the same spec produces the same fault sequence on every run.  Runs on
//! the synthetic tiny model — no artifacts required.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use llamaf::engine::batch::{
    BatchOpts, BatchScheduler, DEADLINE_ERR_PREFIX, FAULT_ERR_PREFIX, MAX_STEP_ATTEMPTS,
};
use llamaf::engine::forward::{CpuEngine, Engine};
use llamaf::engine::generate::{generate, Sampler};
use llamaf::engine::session::Session;
use llamaf::model::{FloatModel, LlamaConfig, MatrixUnit, QuantModel};
use llamaf::ps::gqmv::GqmvExec;
use llamaf::ps::ScalarGqmv;
use llamaf::sched::{DiskFetcher, FaultPlan, LayerFetcher};
use llamaf::server::{ServeOpts, Server};
use llamaf::tokenizer::Tokenizer;
use llamaf::trace;

fn tiny_cfg() -> LlamaConfig {
    LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 64,
        seq_len: 64,
        gs: 32,
    }
}

fn tiny_model(seed: u64) -> Arc<QuantModel> {
    Arc::new(QuantModel::from_float(&FloatModel::random(tiny_cfg(), seed)))
}

fn scalar_exec() -> Box<dyn GqmvExec + Send> {
    Box::new(ScalarGqmv)
}

/// Batch-1 oracle: a dedicated fault-free engine generating greedily with
/// the per-op digest recorder armed.  Returns (tokens, trace).
fn batch1_oracle(
    model: &Arc<QuantModel>,
    prompt: &[u32],
    steps: usize,
) -> (Vec<u32>, trace::ExecTrace) {
    let mut eng = CpuEngine::new(Arc::clone(model), Box::new(ScalarGqmv));
    assert!(eng.trace_start("oracle"));
    let out = generate(&mut eng, prompt, steps, Sampler::Greedy, false).unwrap();
    (out.generated, eng.trace_take().unwrap())
}

#[test]
fn scripted_transient_faults_absorbed_bit_identically_under_concurrency() {
    // Three one-shot faults — a read error on layer 0, a corruption and a
    // truncation on layer 1 — land while several clients share the batch.
    // Every fault is absorbed below the step level by the staged-read
    // retries, so every client must match its batch-1 oracle token for
    // token AND op for op, and only the retry counter may move.
    let model = tiny_model(40);
    let plan =
        FaultPlan::parse("at=0/any/readerr/1,at=1/any/corrupt/1,at=1/any/truncated/1").unwrap();
    let sched = BatchScheduler::with_faults(
        Arc::clone(&model),
        Box::new(ScalarGqmv),
        BatchOpts { max_batch: 4, trace: true, ..Default::default() },
        Some(plan),
    );
    let handles: Vec<_> = (0..4u64)
        .map(|ci| {
            let model = Arc::clone(&model);
            let sched = Arc::clone(&sched);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(ci * 10));
                let prompt: Vec<u32> = vec![1 + ci as u32, 10, 11];
                let steps = 6;
                let (want, ref_trace) = batch1_oracle(&model, &prompt, steps);
                let (sess, out) =
                    sched.generate(Session::new(&model.cfg), &prompt, steps, |_, _| Ok(()));
                assert!(sess.is_some(), "client {ci}: session not returned");
                let gen = out.expect("transient faults must be invisible to the caller");
                assert_eq!(gen.generated, want, "client {ci}: tokens diverged after retries");
                let exec = gen.exec_trace.expect("trace: true returns an op trace");
                let report = trace::diff(&ref_trace, &exec);
                assert!(
                    report.identical(),
                    "client {ci}: op trace diverged from batch-1: {}",
                    report.summary()
                );
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert!(
        sched.metrics().stage_retries() >= 3,
        "all three injected faults must surface as staged-read retries"
    );
    assert_eq!(sched.metrics().stage_faults(), 0, "no stage may exhaust its retries");
    assert_eq!(sched.metrics().step_retries(), 0, "faults were absorbed below the step level");
    assert_eq!(sched.metrics().lane_faults(), 0, "no lane may be shed");
    sched.shutdown();
}

#[test]
fn exhausted_retries_shed_one_lane_while_survivors_stay_bit_identical() {
    // A nine-strike fault burst on layer 1: each failed step burns the
    // staging layer's full retry budget (3 reads), and after
    // MAX_STEP_ATTEMPTS failed steps the scheduler sheds exactly one
    // lane.  9 = 3 × 3 strikes are consumed precisely by that ladder, so
    // the outcome is deterministic: ONE request fails with a `fault:`
    // error, the burst is then exhausted, and every surviving request
    // must be bit-identical to its fault-free batch-1 oracle.
    let model = tiny_model(41);
    let plan = FaultPlan::parse("at=1/any/readerr/9").unwrap();
    let sched = BatchScheduler::with_faults(
        Arc::clone(&model),
        Box::new(ScalarGqmv),
        BatchOpts { max_batch: 4, trace: true, ..Default::default() },
        Some(plan),
    );
    let handles: Vec<_> = (0..3u64)
        .map(|ci| {
            let model = Arc::clone(&model);
            let sched = Arc::clone(&sched);
            std::thread::spawn(move || -> Option<String> {
                std::thread::sleep(Duration::from_millis(ci * 25));
                let prompt: Vec<u32> = vec![2 + ci as u32, 7, 9];
                let steps = 5;
                let (sess, out) =
                    sched.generate(Session::new(&model.cfg), &prompt, steps, |_, _| Ok(()));
                assert!(sess.is_some(), "client {ci}: session not returned");
                match out {
                    Ok(gen) => {
                        let (want, ref_trace) = batch1_oracle(&model, &prompt, steps);
                        assert_eq!(gen.generated, want, "client {ci}: survivor diverged");
                        let exec = gen.exec_trace.expect("trace: true returns an op trace");
                        let report = trace::diff(&ref_trace, &exec);
                        assert!(
                            report.identical(),
                            "client {ci}: survivor op trace diverged: {}",
                            report.summary()
                        );
                        None
                    }
                    Err(e) => Some(e.to_string()),
                }
            })
        })
        .collect();
    let errors: Vec<String> = handles.into_iter().filter_map(|h| h.join().unwrap()).collect();
    assert_eq!(errors.len(), 1, "exactly one lane must be shed, got: {errors:?}");
    assert!(errors[0].starts_with(FAULT_ERR_PREFIX), "{}", errors[0]);
    assert!(errors[0].contains("injected fault"), "cause must be preserved: {}", errors[0]);
    assert_eq!(sched.metrics().lane_faults(), 1);
    assert_eq!(sched.metrics().step_retries(), u64::from(MAX_STEP_ATTEMPTS));
    assert!(sched.metrics().stage_faults() >= 1, "staging-layer exhaustion must be exported");
    sched.shutdown();
}

#[test]
fn stall_injection_is_absorbed_and_never_hangs() {
    // Two 40 ms stalls on layer-1 staging: well inside the per-stage
    // deadline, so the fetches complete late but correctly.  Tokens and
    // the op trace must be bit-identical to the fault-free oracle, and
    // nothing may count as an error — a stall is lost time, not lost
    // data.  (The test finishing at all is the no-hang assertion; a
    // stall past RetryPolicy::stage_timeout_ms would surface as a
    // timeout error, covered by the sched unit tests.)
    let model = tiny_model(42);
    let prompt = [3u32, 12, 13];
    let steps = 6;
    let (want, ref_trace) = batch1_oracle(&model, &prompt, steps);
    let plan = FaultPlan::parse("stall_ms=40,at=1/any/stall/2").unwrap();
    let sched = BatchScheduler::with_faults(
        Arc::clone(&model),
        Box::new(ScalarGqmv),
        BatchOpts { trace: true, ..Default::default() },
        Some(plan),
    );
    let (sess, out) = sched.generate(Session::new(&model.cfg), &prompt, steps, |_, _| Ok(()));
    assert!(sess.is_some());
    let gen = out.expect("a stall inside the stage deadline must be invisible");
    assert_eq!(gen.generated, want, "stalled staging changed tokens");
    let report = trace::diff(&ref_trace, &gen.exec_trace.unwrap());
    assert!(report.identical(), "stalled staging perturbed ops: {}", report.summary());
    assert_eq!(sched.metrics().stage_retries(), 0, "a stall is not a retryable error");
    assert_eq!(sched.metrics().lane_faults(), 0);
    sched.shutdown();
}

#[test]
fn corrupt_checkpoint_rejected_at_staging_before_any_token() {
    // A single flipped byte inside layer 1's W2 segment must be caught by
    // the CRC32 footer when that layer is STAGED — the fetch errors out
    // before the bad weights could ever reach a forward pass — while
    // untouched layers still stage cleanly.  `verify_ckpt` must flag the
    // same mismatch offline.
    use llamaf::ckpt::{verify_ckpt, write_ckpt_from_float, CkptLayout, VerifyOutcome};
    use llamaf::quant::FormatId;

    let cfg = tiny_cfg();
    let fm = FloatModel::random(cfg, 43);
    let path = std::env::temp_dir().join("llamaf_test_fault_corrupt.lfq8");
    write_ckpt_from_float(&path, &fm, FormatId::Q8).unwrap();
    match verify_ckpt(&path).unwrap() {
        VerifyOutcome::Ok { segments } => assert!(segments > 0, "footer covers no segments"),
        VerifyOutcome::NoFooter => panic!("freshly written checkpoint must carry a footer"),
    }

    let off = CkptLayout::new(cfg, FormatId::Q8).matrix_offset(1, MatrixUnit::W2) as usize;
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[off + 7] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();

    let mut fetcher = DiskFetcher::open(&path).expect("geometry is intact, open succeeds");
    assert!(fetcher.fetch(0).is_ok(), "untouched layer 0 stages cleanly");
    let e = fetcher.fetch(1).unwrap_err().to_string();
    assert!(
        e.contains("checksum mismatch in layer 1 (w2)"),
        "corruption must be named at staging time: {e}"
    );
    let e = verify_ckpt(&path).unwrap_err().to_string();
    assert!(e.contains("checksum mismatch"), "offline verify must agree: {e}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn serve_soak_under_injected_faults_drains_clean_and_matches_oracle() {
    // End-to-end soak: seeded probabilistic faults plus a guaranteed
    // scripted strike while staggered clients stream over a paged KV
    // pool.  Retries make the faults invisible: completed requests must
    // be token-identical to the batch-1 oracle, failed ones (possible
    // only via the explicit shed paths) must carry an honest ERR code,
    // and the drained server must report zero checked-out sessions and
    // zero live KV pages either way.
    let cfg = LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 512,
        seq_len: 64,
        gs: 32,
    };
    let model = Arc::new(QuantModel::from_float(&FloatModel::random(cfg, 44)));
    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts {
        workers: 3,
        queue_depth: 16,
        max_sessions: 4,
        kv_pages: 32,
        faults: Some(FaultPlan::parse("p=0.03,seed=11,at=1/any/readerr/1").unwrap()),
        request_timeout_ms: Some(30_000),
        ..Default::default()
    };
    let n_clients = 8usize;
    let server_model = Arc::clone(&model);
    let server_thread = std::thread::spawn(move || {
        server.serve_shared(server_model, &scalar_exec, &opts, Some(n_clients)).unwrap()
    });

    let tokenizer = Tokenizer::new(512);
    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            let model = Arc::clone(&model);
            let want = {
                let ids = tokenizer.encode(&format!("soak prompt {i}"), true);
                batch1_oracle(&model, &ids, 4).0
            };
            std::thread::spawn(move || -> (usize, usize) {
                std::thread::sleep(Duration::from_millis((i as u64 % 4) * 20));
                let mut conn = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                conn.write_all(format!("SGEN 4 soak prompt {i}\n").as_bytes()).unwrap();
                let mut got: Vec<u32> = Vec::new();
                loop {
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let line = line.trim_end();
                    if line.starts_with("TOK ") {
                        let id: u32 = line.split_whitespace().nth(2).unwrap().parse().unwrap();
                        got.push(id);
                    } else if line.starts_with("DONE ") {
                        assert_eq!(got, want, "client {i}: streamed tokens diverged");
                        conn.write_all(b"QUIT\n").unwrap();
                        return (1, 0);
                    } else if line.starts_with("ERR ") {
                        // the only legitimate failures are the explicit
                        // shed paths — never a hang, never garbage tokens
                        let honest = line.starts_with("ERR fault:")
                            || line.starts_with("ERR deadline:")
                            || line.starts_with("ERR busy");
                        assert!(honest, "client {i}: dishonest error: {line:?}");
                        return (0, 1);
                    } else {
                        panic!("client {i}: unexpected server line: {line:?}");
                    }
                }
            })
        })
        .collect();
    let (mut done, mut errs) = (0usize, 0usize);
    for h in handles {
        let (d, e) = h.join().unwrap();
        done += d;
        errs += e;
    }
    let report = server_thread.join().unwrap();
    assert_eq!(done + errs, n_clients);
    assert!(done >= n_clients / 2, "soak mostly failed: {done} done, {errs} errors");
    assert!(report.tokens > 0, "soak produced no tokens");
    assert_eq!(report.busy_at_exit, 0, "a session leaked out of the pool");
    assert_eq!(
        report.kv_pages_at_exit, 0,
        "KV page ledger did not drain to zero under injected faults"
    );
}

#[test]
fn request_timeout_sheds_stalled_requests_with_deadline_err() {
    // A permanent 25 ms stall on layer-1 staging makes every step slow;
    // a 60 ms request deadline then expires a 32-step generation after a
    // couple of steps.  The server must answer `ERR deadline:` promptly
    // — the stall may slow the lane but can never hold it past its
    // deadline — and still drain to zero sessions and pages.
    let cfg = LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 512,
        seq_len: 64,
        gs: 32,
    };
    let model = Arc::new(QuantModel::from_float(&FloatModel::random(cfg, 45)));
    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts {
        workers: 1,
        queue_depth: 4,
        max_sessions: 2,
        kv_pages: 16,
        faults: Some(FaultPlan::parse("stall_ms=25,at=1/any/stall/always").unwrap()),
        request_timeout_ms: Some(60),
        ..Default::default()
    };
    let server_thread = std::thread::spawn(move || {
        server.serve_shared(model, &scalar_exec, &opts, Some(1)).unwrap()
    });

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    conn.write_all(b"GEN 32 slow prompt\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(
        line.starts_with(&format!("ERR {DEADLINE_ERR_PREFIX}")),
        "expired request must shed with the deadline code: {line:?}"
    );
    conn.write_all(b"QUIT\n").unwrap();
    let report = server_thread.join().unwrap();
    assert_eq!(report.requests, 0, "the timed-out request must not count as completed");
    assert_eq!(report.busy_at_exit, 0);
    assert_eq!(report.kv_pages_at_exit, 0, "deadline shed must donate its pages back");
}
