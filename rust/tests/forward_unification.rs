//! Guard of the forward-path unification: batch-1 decoding now routes
//! through a 1-lane `forward_batch`, and this test pins its outputs
//! against an op-for-op reference of the **pre-unification** dedicated
//! batch-1 pass (the historical `forward_pass` body, reproduced below
//! from the public primitives).  Any arithmetic drift in the unified
//! path — reordered ops, changed associativity, a different cast chain —
//! breaks bit-equality here.
//!
//! Runs on the synthetic tiny model — no artifacts required.

use std::sync::Arc;

use llamaf::engine::forward::CpuEngine;
use llamaf::engine::generate::{generate, Sampler};
use llamaf::engine::session::Session;
use llamaf::engine::Engine;
use llamaf::metrics::ForwardProfile;
use llamaf::model::{FloatModel, KvCache, LlamaConfig, QuantModel};
use llamaf::ps::float::attention;
use llamaf::ps::gqmv::GqmvExec;
use llamaf::ps::ScalarGqmv;
use llamaf::quant::{quantize_activation_into, QuantizedTensor};
use llamaf::tensor;

fn tiny_cfg() -> LlamaConfig {
    LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 3,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 64,
        seq_len: 32,
        gs: 32,
    }
}

fn tiny_model(seed: u64) -> Arc<QuantModel> {
    Arc::new(QuantModel::from_float(&FloatModel::random(tiny_cfg(), seed)))
}

/// Pre-unification reference scratch (the historical `Scratch` layout).
struct RefScratch {
    x: Vec<f32>,
    xb: Vec<f32>,
    qkv: Vec<f32>,
    att_out: Vec<f32>,
    h13: Vec<f32>,
    logits: Vec<f32>,
    qbuf: Vec<i8>,
    sbuf: Vec<f32>,
}

impl RefScratch {
    fn new(cfg: &LlamaConfig) -> Self {
        let max_in = cfg.dim.max(cfg.hidden_dim);
        RefScratch {
            x: vec![0.0; cfg.dim],
            xb: vec![0.0; cfg.dim],
            qkv: vec![0.0; cfg.dim + 2 * cfg.kv_dim()],
            att_out: vec![0.0; cfg.dim],
            h13: vec![0.0; 2 * cfg.hidden_dim],
            logits: vec![0.0; cfg.vocab_size],
            qbuf: vec![0; max_in],
            sbuf: vec![0.0; max_in / cfg.gs],
        }
    }
}

/// quantize + one GQMV, exactly as the historical batch-1 pass did it.
fn ref_quant_gqmv(
    exec: &mut dyn GqmvExec,
    x: &[f32],
    w: &QuantizedTensor,
    out: &mut [f32],
    qbuf: &mut [i8],
    sbuf: &mut [f32],
    gs: usize,
) {
    let n = x.len();
    quantize_activation_into(x, gs, &mut qbuf[..n], &mut sbuf[..n / gs]);
    exec.gqmv(&qbuf[..n], &sbuf[..n / gs], w, out).unwrap();
}

/// The historical dedicated batch-1 Algorithm-2 op sequence, verbatim:
/// embed, then per layer RMSNorm → QKV GQMV → RoPE → KV store →
/// attention → Wo GQMV → residual → RMSNorm → W1‖W3 GQMV → SwiGLU →
/// W2 GQMV → residual, then final RMSNorm → classifier GQMV.
fn ref_forward_pass(
    model: &QuantModel,
    exec: &mut dyn GqmvExec,
    s: &mut RefScratch,
    kv: &mut KvCache,
    token: u32,
    pos: usize,
) {
    let cfg = model.cfg;
    let (d, kv_d, hd, gs) = (cfg.dim, cfg.kv_dim(), cfg.head_dim(), cfg.gs);
    model.tok_emb.dequantize_row(token as usize, &mut s.x);
    for li in 0..cfg.n_layers {
        let layer = &model.layers[li];
        tensor::rmsnorm(&mut s.xb, &s.x, &layer.att_norm);
        ref_quant_gqmv(exec, &s.xb, &layer.wqkv, &mut s.qkv, &mut s.qbuf, &mut s.sbuf, gs);
        let (q, kvs) = s.qkv.split_at_mut(d);
        let (k, v) = kvs.split_at_mut(kv_d);
        tensor::rope(q, pos, hd);
        tensor::rope(k, pos, hd);
        kv.store(li, pos, k, v);
        attention(&cfg, kv, li, pos, q, &mut s.att_out);
        ref_quant_gqmv(exec, &s.att_out, &layer.wo, &mut s.xb, &mut s.qbuf, &mut s.sbuf, gs);
        tensor::add_assign(&mut s.x, &s.xb);
        tensor::rmsnorm(&mut s.xb, &s.x, &layer.ffn_norm);
        ref_quant_gqmv(exec, &s.xb, &layer.w13, &mut s.h13, &mut s.qbuf, &mut s.sbuf, gs);
        let (h1, h3) = s.h13.split_at_mut(cfg.hidden_dim);
        tensor::swiglu(h1, h3);
        let h1 = &s.h13[..cfg.hidden_dim];
        ref_quant_gqmv(exec, h1, &layer.w2, &mut s.xb, &mut s.qbuf, &mut s.sbuf, gs);
        tensor::add_assign(&mut s.x, &s.xb);
    }
    tensor::rmsnorm(&mut s.xb, &s.x, &model.final_norm);
    ref_quant_gqmv(exec, &s.xb, &model.cls, &mut s.logits, &mut s.qbuf, &mut s.sbuf, gs);
}

#[test]
fn unified_batch1_bit_identical_to_pre_refactor_pass() {
    let qm = tiny_model(31);
    let cfg = qm.cfg;
    let tokens = [5u32, 8, 2, 60, 1, 33, 17, 9];

    // reference: the historical op sequence, step by step
    let mut ref_exec = ScalarGqmv;
    let mut ref_s = RefScratch::new(&cfg);
    let mut ref_kv = KvCache::new(&cfg);
    let mut want: Vec<Vec<f32>> = Vec::new();
    for (pos, &t) in tokens.iter().enumerate() {
        ref_forward_pass(&qm, &mut ref_exec, &mut ref_s, &mut ref_kv, t, pos);
        want.push(ref_s.logits.clone());
    }

    // unified: CpuEngine::forward (a 1-lane forward_batch since the
    // unification) must reproduce every logit vector bit for bit
    let mut engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
    let mut prof = ForwardProfile::default();
    for (pos, &t) in tokens.iter().enumerate() {
        let got = engine.forward(t, pos, &mut prof).unwrap();
        assert_eq!(got, &want[pos][..], "unified pass diverged at pos {pos}");
    }
}

#[test]
fn unified_session_path_bit_identical_to_pre_refactor_pass() {
    // the serving entry point (forward_session) rides the same unified
    // pass; pin it against the reference too
    let qm = tiny_model(32);
    let cfg = qm.cfg;
    let tokens = [3u32, 40, 7, 1, 22];

    let mut ref_exec = ScalarGqmv;
    let mut ref_s = RefScratch::new(&cfg);
    let mut ref_kv = KvCache::new(&cfg);
    let mut want: Vec<Vec<f32>> = Vec::new();
    for (pos, &t) in tokens.iter().enumerate() {
        ref_forward_pass(&qm, &mut ref_exec, &mut ref_s, &mut ref_kv, t, pos);
        want.push(ref_s.logits.clone());
    }

    let mut engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
    let mut sess = Session::new(&cfg);
    let mut prof = ForwardProfile::default();
    for (pos, &t) in tokens.iter().enumerate() {
        let got = engine.forward_session(&mut sess, t, &mut prof).unwrap();
        assert_eq!(got, &want[pos][..], "session path diverged at pos {pos}");
        assert_eq!(sess.pos, pos + 1);
    }
}

#[test]
fn blocked_threaded_backend_bit_identical_to_pre_refactor_pass() {
    // the pipelined-execution refactor rebuilt the threaded backend on
    // cache-blocked row kernels dispatched over safe disjoint output
    // splits; its logits must still match the historical batch-1 op
    // sequence (run with the scalar reference backend) bit for bit
    use llamaf::ps::ThreadedGqmv;
    use llamaf::util::ThreadPool;
    let qm = tiny_model(34);
    let cfg = qm.cfg;
    let tokens = [4u32, 19, 8, 52, 2, 33];

    let mut ref_exec = ScalarGqmv;
    let mut ref_s = RefScratch::new(&cfg);
    let mut ref_kv = KvCache::new(&cfg);
    let mut want: Vec<Vec<f32>> = Vec::new();
    for (pos, &t) in tokens.iter().enumerate() {
        ref_forward_pass(&qm, &mut ref_exec, &mut ref_s, &mut ref_kv, t, pos);
        want.push(ref_s.logits.clone());
    }

    let mut th = ThreadedGqmv::new(Arc::new(ThreadPool::new(4)));
    th.min_parallel_macs = 0; // force real pool dispatches at nano scale
    let mut engine = CpuEngine::new(Arc::clone(&qm), Box::new(th));
    let mut prof = ForwardProfile::default();
    for (pos, &t) in tokens.iter().enumerate() {
        let got = engine.forward(t, pos, &mut prof).unwrap();
        assert_eq!(got, &want[pos][..], "blocked threaded pass diverged at pos {pos}");
    }
}

#[test]
fn fused_dispatch_bit_identical_to_storage_fusion() {
    // dispatch-level fusion (gqmv_fused over split Wq/Wk/Wv) must equal
    // the storage-level fusion the model ships (one concatenated tensor,
    // one gqmv) — the 7 -> 4 launch reduction cannot change a single bit
    use llamaf::ps::ThreadedGqmv;
    use llamaf::util::ThreadPool;
    let cfg = tiny_cfg();
    let (d, kv_d, gs) = (cfg.dim, cfg.kv_dim(), cfg.gs);
    let mut rng = llamaf::util::Rng::new(35);
    let wq = QuantizedTensor::from_f32(&rng.normal_vec(d * d, 0.5), d, d, gs);
    let wk = QuantizedTensor::from_f32(&rng.normal_vec(kv_d * d, 0.5), kv_d, d, gs);
    let wv = QuantizedTensor::from_f32(&rng.normal_vec(kv_d * d, 0.5), kv_d, d, gs);
    let fused_tensor = QuantizedTensor::concat_rows(&[&wq, &wk, &wv]);
    let x = rng.normal_vec(d, 1.0);
    let mut xq = vec![0i8; d];
    let mut xs = vec![0.0f32; d / gs];
    quantize_activation_into(&x, gs, &mut xq, &mut xs);

    let mut storage_out = vec![0.0f32; fused_tensor.rows];
    ScalarGqmv.gqmv(&xq, &xs, &fused_tensor, &mut storage_out).unwrap();

    for threaded in [false, true] {
        let mut q = vec![0.0f32; d];
        let mut k = vec![0.0f32; kv_d];
        let mut v = vec![0.0f32; kv_d];
        {
            let mut outs = [&mut q[..], &mut k[..], &mut v[..]];
            let ws = [&wq, &wk, &wv];
            if threaded {
                let mut th = ThreadedGqmv::new(Arc::new(ThreadPool::new(4)));
                th.min_parallel_macs = 0;
                th.gqmv_fused(&xq, &xs, &ws, &mut outs).unwrap();
            } else {
                ScalarGqmv.gqmv_fused(&xq, &xs, &ws, &mut outs).unwrap();
            }
        }
        let dispatch_out: Vec<f32> = q.iter().chain(k.iter()).chain(v.iter()).copied().collect();
        assert_eq!(dispatch_out, storage_out, "threaded={threaded}");
    }
}

#[test]
fn unified_greedy_decode_matches_reference_decode() {
    // end to end: a greedy generation through the unified engine equals
    // a greedy generation driven by the reference pass
    let qm = tiny_model(33);
    let cfg = qm.cfg;
    let prompt = [1u32, 10, 11];
    let steps = 12;

    let mut ref_exec = ScalarGqmv;
    let mut ref_s = RefScratch::new(&cfg);
    let mut ref_kv = KvCache::new(&cfg);
    let mut pos = 0;
    for &t in &prompt[..prompt.len() - 1] {
        ref_forward_pass(&qm, &mut ref_exec, &mut ref_s, &mut ref_kv, t, pos);
        pos += 1;
    }
    let mut cur = *prompt.last().unwrap();
    let mut want = Vec::new();
    for _ in 0..steps {
        ref_forward_pass(&qm, &mut ref_exec, &mut ref_s, &mut ref_kv, cur, pos);
        pos += 1;
        cur = tensor::argmax(&ref_s.logits) as u32;
        want.push(cur);
    }

    let mut engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
    let out = generate(&mut engine, &prompt, steps, Sampler::Greedy, false).unwrap();
    assert_eq!(out.generated, want, "greedy stream diverged from pre-refactor reference");
}

// ---------------------------------------------------------------------------
// Streamed-provider and device-path unification (sim runtime, no artifacts)
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
mod streamed {
    use super::*;
    use llamaf::engine::forward::{forward_batch, BatchLane, BatchScratch};
    use llamaf::engine::llamaf::LlamafEngine;
    use llamaf::model::KvStore;
    use llamaf::runtime::Runtime;
    use llamaf::sched::{MemFetcher, SchedMode, StageGranularity, Streamer};

    #[test]
    fn streamer_provider_bit_identical_at_every_granularity_and_depth() {
        // the Streamer as a LayerProvider — layer-granular or
        // matrix-granular, at depths 1/2/4 — must reproduce the
        // pre-refactor op sequence bit for bit: staging granularity is a
        // latency knob, never a data path
        let qm = tiny_model(41);
        let cfg = qm.cfg;
        let tokens = [5u32, 8, 2, 60, 1, 33];

        let mut ref_exec = ScalarGqmv;
        let mut ref_s = RefScratch::new(&cfg);
        let mut ref_kv = KvCache::new(&cfg);
        let mut want: Vec<Vec<f32>> = Vec::new();
        for (pos, &t) in tokens.iter().enumerate() {
            ref_forward_pass(&qm, &mut ref_exec, &mut ref_s, &mut ref_kv, t, pos);
            want.push(ref_s.logits.clone());
        }

        for gran in [StageGranularity::Layer, StageGranularity::Matrix] {
            for depth in [1usize, 2, 4] {
                let rt = Arc::new(Runtime::with_shapes(&[]));
                let fetcher = MemFetcher { layers: Arc::new(qm.layers.clone()) };
                let mut provider =
                    Streamer::with_opts(rt, fetcher, SchedMode::Async, depth, gran).unwrap();
                let mut exec = ScalarGqmv;
                let mut scratch = BatchScratch::new(&cfg, 1);
                let mut kv = KvCache::new(&cfg);
                let mut prof = ForwardProfile::default();
                for (pos, &t) in tokens.iter().enumerate() {
                    let lanes = [BatchLane { kv: 0, pos, token: t }];
                    let mut kvs: [&mut dyn KvStore; 1] = [&mut kv];
                    forward_batch(
                        &qm,
                        &mut provider,
                        &mut exec,
                        &mut scratch,
                        &lanes,
                        &mut kvs,
                        &mut prof,
                    )
                    .unwrap();
                    assert_eq!(
                        scratch.logits(0),
                        &want[pos][..],
                        "{gran:?} depth {depth} diverged at pos {pos}"
                    );
                }
            }
        }
    }

    #[test]
    fn device_path_routes_through_forward_batch_bit_identical_to_cpu() {
        // LlamafEngine no longer carries its own Algorithm-2 copy: it
        // decodes through forward_batch with the DeviceLayers/DeviceGqmv
        // pairing, so its logits must equal the CPU engine's bit for bit
        // at every granularity x depth (the sim runtime's device kernel
        // shares the exact cast chain with ScalarGqmv)
        let qm = tiny_model(42);
        let cfg = qm.cfg;
        let tokens = [3u32, 40, 7, 1, 22];
        let mut cpu = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let mut prof = ForwardProfile::default();
        let mut want: Vec<Vec<f32>> = Vec::new();
        for (pos, &t) in tokens.iter().enumerate() {
            want.push(cpu.forward(t, pos, &mut prof).unwrap().to_vec());
        }
        for gran in [StageGranularity::Layer, StageGranularity::Matrix] {
            for depth in [1usize, 2, 4] {
                let rt = Arc::new(Runtime::with_shapes(&cfg.all_mat_shapes()));
                let mut dev = LlamafEngine::from_model_with_opts(
                    (*qm).clone(),
                    rt,
                    SchedMode::Async,
                    depth,
                    gran,
                )
                .unwrap();
                assert_eq!(dev.granularity(), gran);
                for (pos, &t) in tokens.iter().enumerate() {
                    let got = dev.forward(t, pos, &mut prof).unwrap();
                    assert_eq!(got, &want[pos][..], "{gran:?} depth {depth} diverged at pos {pos}");
                }
                let stats = dev.streamer_stats();
                assert!(stats.transfers > 0, "device path must actually stream");
                assert!(stats.staged_bytes > 0);
            }
        }
    }

    #[test]
    fn device_path_reset_streams_next_generation_bit_identical() {
        // a reset mid-stream re-arms the sub-layer ring; the next
        // generation must reproduce the first one exactly
        let qm = tiny_model(43);
        let cfg = qm.cfg;
        let rt = Arc::new(Runtime::with_shapes(&cfg.all_mat_shapes()));
        let mut dev = LlamafEngine::from_model_with_opts(
            (*qm).clone(),
            rt,
            SchedMode::Async,
            3,
            StageGranularity::Matrix,
        )
        .unwrap();
        let mut prof = ForwardProfile::default();
        let tokens = [4u32, 19, 8];
        let mut first: Vec<Vec<f32>> = Vec::new();
        for (pos, &t) in tokens.iter().enumerate() {
            first.push(dev.forward(t, pos, &mut prof).unwrap().to_vec());
        }
        dev.reset();
        for (pos, &t) in tokens.iter().enumerate() {
            let got = dev.forward(t, pos, &mut prof).unwrap();
            assert_eq!(got, &first[pos][..], "post-reset divergence at pos {pos}");
        }
    }
}
