//! Per-format integration contracts for the `QuantFormat` redesign:
//!
//!  * quantize→dequantize error stays within half a lattice step and the
//!    packed wire encoding round-trips losslessly for every format;
//!  * a Q4_0 checkpoint decodes **bit-identically** across backends
//!    (scalar / threaded / dataflow-sim / streamed device engine), both
//!    staging granularities and prefetch depths {1, 2} — the trace-diff
//!    acceptance contract for sub-INT8 serving;
//!  * a GGUF file (F32 and ggml-block-quantized) imports into a native
//!    checkpoint that computes the same bits as in-memory quantization;
//!  * sub-INT8 checkpoints really are about half the bytes on disk.
//!
//! Runs on the synthetic tiny model — no artifacts required.

use std::path::PathBuf;

use llamaf::engine::forward::{CpuEngine, Engine};
use llamaf::engine::generate::{generate, Sampler};
use llamaf::model::{FloatModel, LlamaConfig, QuantModel};
use llamaf::ps::ScalarGqmv;
use llamaf::quant::{FormatId, PackedTensor, QuantizedTensor};
use llamaf::trace::{diff, ExecTrace};
use llamaf::util::Rng;

const PROMPT: [u32; 3] = [1, 7, 42];
const STEPS: usize = 5;

fn tiny_cfg() -> LlamaConfig {
    LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 512,
        seq_len: 64,
        gs: 32,
    }
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("llamaf_qf_{name}_{}", std::process::id()))
}

/// Greedy-generate with tracing on; return the trace and the token ids.
fn record(engine: &mut dyn Engine, label: &str) -> (ExecTrace, Vec<u32>) {
    assert!(engine.trace_start(label), "engine must support tracing");
    let out = generate(engine, &PROMPT, STEPS, Sampler::Greedy, false).unwrap();
    (engine.trace_take().expect("tracing enabled but no trace produced"), out.ids)
}

#[test]
fn every_format_roundtrip_error_bounded_by_half_step() {
    let mut rng = Rng::new(7);
    for &fmt in FormatId::ALL.iter() {
        for gs in [32usize, 64] {
            let x = rng.normal_vec(4 * 2 * gs, 0.8);
            let t = QuantizedTensor::from_f32_fmt(&x, 4, 2 * gs, gs, fmt);
            // every value lands on the format's lattice ...
            let qmax = fmt.qmax();
            let on_lattice = t.q.iter().all(|&q| (-qmax..=qmax).contains(&q));
            assert!(on_lattice, "{fmt}: off-lattice value");
            // ... and reconstruction error is at most half a step (= S/2)
            let back = t.dequantize();
            for g in 0..t.s.len() {
                for k in 0..gs {
                    let i = g * gs + k;
                    let err = (back[i] - x[i]).abs();
                    assert!(
                        err <= t.s[g] / 2.0 + 1e-7,
                        "{fmt} gs={gs}: err {err} > step/2 {}",
                        t.s[g] / 2.0
                    );
                }
            }
        }
    }
}

#[test]
fn packed_wire_roundtrip_is_lossless_for_every_format() {
    let mut rng = Rng::new(8);
    for &fmt in FormatId::ALL.iter() {
        let t = QuantizedTensor::from_f32_fmt(&rng.normal_vec(6 * 64, 1.0), 6, 64, 32, fmt);
        let p = PackedTensor::pack(&t);
        assert_eq!(p.wire_bytes(), t.stream_bytes(), "{fmt}: wire accounting drift");
        assert_eq!(p.unpack(), t, "{fmt}: pack/unpack must be lossless");
    }
}

/// The ISSUE acceptance contract: one Q4_0 checkpoint, decoded by every
/// backend and every staging schedule, produces bit-identical traces and
/// tokens (and a repeat run reproduces them exactly).
#[test]
#[cfg(not(feature = "pjrt"))]
fn q4_checkpoint_decodes_bit_identically_across_backends_and_schedules() {
    use std::sync::Arc;

    use llamaf::engine::llamaf::LlamafEngine;
    use llamaf::fpga::{DataflowSim, PlConfig};
    use llamaf::ps::ThreadedGqmv;
    use llamaf::runtime::Runtime;
    use llamaf::sched::{SchedMode, StageGranularity};
    use llamaf::util::ThreadPool;

    let cfg = tiny_cfg();
    let fm = FloatModel::random(cfg, 21);
    let path = tmp("e2e.lfq4");
    llamaf::ckpt::write_ckpt_from_float(&path, &fm, FormatId::Q40).unwrap();

    let qm = llamaf::ckpt::read_ckpt(&path).unwrap();
    assert_eq!(qm.fmt(), FormatId::Q40);
    let mut host = CpuEngine::new(qm.clone(), Box::new(ScalarGqmv));
    let (reference, ref_ids) = record(&mut host, "scalar");

    // same checkpoint through maximally different compute backends
    let pool = Arc::new(ThreadPool::new(2));
    let mut threaded = CpuEngine::new(qm.clone(), Box::new(ThreadedGqmv::new(pool)));
    let mut dataflow = CpuEngine::new(qm.clone(), Box::new(DataflowSim::new(PlConfig::default())));
    let backends: [(&mut dyn Engine, &str); 2] =
        [(&mut threaded, "threaded"), (&mut dataflow, "dataflow-sim")];
    for (eng, label) in backends {
        let (t, ids) = record(eng, label);
        let report = diff(&reference, &t);
        assert!(report.identical(), "scalar vs {label}: {}", report.summary());
        assert_eq!(ids, ref_ids, "{label} token divergence");
    }

    // streamed device engine: every granularity x prefetch depth
    let rt = Arc::new(Runtime::with_shapes(&cfg.all_mat_shapes()));
    for gran in [StageGranularity::Layer, StageGranularity::Matrix] {
        for depth in [1usize, 2] {
            let rt2 = Arc::clone(&rt);
            let mut dev =
                LlamafEngine::open_with_opts(&path, rt2, SchedMode::Async, depth, gran).unwrap();
            let label = format!("device-{gran:?}-d{depth}");
            let (t, ids) = record(&mut dev, &label);
            let report = diff(&reference, &t);
            assert!(report.identical(), "scalar vs {label}: {}", report.summary());
            assert_eq!(ids, ref_ids, "{label} token divergence");
        }
    }

    // and a fresh run of the same setup reproduces the bits exactly
    let qm2 = llamaf::ckpt::read_ckpt(&path).unwrap();
    let mut again = CpuEngine::new(qm2, Box::new(ScalarGqmv));
    let (t2, ids2) = record(&mut again, "scalar-run2");
    assert!(diff(&reference, &t2).identical(), "decode must be reproducible across runs");
    assert_eq!(ids2, ref_ids);
    std::fs::remove_file(&path).ok();
}

/// Streamed (device) decode equals resident host decode for *every*
/// format — the checkpoint byte layout and the staging path introduce no
/// format-dependent drift.
#[test]
#[cfg(not(feature = "pjrt"))]
fn streamed_decode_matches_resident_for_every_format() {
    use std::sync::Arc;

    use llamaf::engine::llamaf::LlamafEngine;
    use llamaf::runtime::Runtime;
    use llamaf::sched::{SchedMode, StageGranularity};

    let cfg = tiny_cfg();
    let fm = FloatModel::random(cfg, 22);
    let rt = Arc::new(Runtime::with_shapes(&cfg.all_mat_shapes()));
    for &fmt in FormatId::ALL.iter() {
        let path = tmp(&format!("stream_{fmt}.ckpt"));
        llamaf::ckpt::write_ckpt_from_float(&path, &fm, fmt).unwrap();
        let qm = llamaf::ckpt::read_ckpt(&path).unwrap();
        assert_eq!(qm.fmt(), fmt);
        let mut host = CpuEngine::new(qm, Box::new(ScalarGqmv));
        let (a, ids_a) = record(&mut host, "host");
        let rt2 = Arc::clone(&rt);
        let gran = StageGranularity::Matrix;
        let mut dev = LlamafEngine::open_with_opts(&path, rt2, SchedMode::Async, 2, gran).unwrap();
        let (b, ids_b) = record(&mut dev, "device");
        let report = diff(&a, &b);
        assert!(report.identical(), "{fmt}: host vs streamed: {}", report.summary());
        assert_eq!(ids_a, ids_b, "{fmt}: token divergence");
        std::fs::remove_file(&path).ok();
    }
}

/// `import-gguf` round trip: a GGUF written from a float model imports
/// into a checkpoint that computes exactly the same bits as quantizing
/// that model in memory — for an F32 GGUF and for every ggml
/// block-quantized encoding we read.
#[test]
fn gguf_import_computes_the_same_bits_as_native_quantization() {
    use llamaf::ckpt::gguf::{
        gguf_to_float, import_gguf, read_gguf, write_gguf_from_float, GGML_F32, GGML_Q4_0,
        GGML_Q5_0, GGML_Q8_0,
    };

    let cfg = tiny_cfg();
    let fm = FloatModel::random(cfg, 31);
    let cases =
        [(GGML_F32, "f32"), (GGML_Q8_0, "q8_0"), (GGML_Q4_0, "q4_0"), (GGML_Q5_0, "q5_0")];
    for (ggml_type, tag) in cases {
        let gguf_path = tmp(&format!("{tag}.gguf"));
        let out_path = tmp(&format!("{tag}.ckpt"));
        write_gguf_from_float(&gguf_path, &fm, ggml_type).unwrap();

        let got_cfg = import_gguf(&gguf_path, &out_path, FormatId::Q40, Some(cfg.gs)).unwrap();
        assert_eq!(got_cfg, cfg, "{tag}: geometry must survive the round trip");

        // the imported checkpoint must equal requantizing the GGUF's own
        // dequantized weights — proven by bit-identical execution traces
        let g = read_gguf(&gguf_path).unwrap();
        let fm2 = gguf_to_float(&g, Some(cfg.gs)).unwrap();
        let native = QuantModel::from_float_fmt(&fm2, FormatId::Q40);
        let imported = llamaf::ckpt::read_ckpt(&out_path).unwrap();
        assert_eq!(imported.fmt(), FormatId::Q40);
        let (a, ids_a) = record(&mut CpuEngine::new(imported, Box::new(ScalarGqmv)), "imported");
        let (b, ids_b) = record(&mut CpuEngine::new(native, Box::new(ScalarGqmv)), "native");
        let report = diff(&a, &b);
        assert!(report.identical(), "{tag}: imported vs native: {}", report.summary());
        assert_eq!(ids_a, ids_b, "{tag}: token divergence");
        std::fs::remove_file(&gguf_path).ok();
        std::fs::remove_file(&out_path).ok();
    }
}

/// The headline claim: sub-INT8 checkpoints halve the bytes.  At the
/// test group size (32) a Q4_0 group is 20 B against Q8's 36 B; at the
/// paper's GS=256 the ratio drops to 132/260 ≈ 0.51.
#[test]
fn q4_checkpoint_is_about_half_the_q8_bytes_on_disk() {
    let cfg = tiny_cfg();
    let fm = FloatModel::random(cfg, 41);
    let mut sizes = std::collections::HashMap::new();
    for &fmt in FormatId::ALL.iter() {
        let path = tmp(&format!("size_{fmt}.ckpt"));
        llamaf::ckpt::write_ckpt_from_float(&path, &fm, fmt).unwrap();
        let on_disk = std::fs::metadata(&path).unwrap().len();
        let layout = llamaf::ckpt::CkptLayout::new(cfg, fmt);
        assert_eq!(on_disk, layout.file_bytes(), "{fmt}: layout accounting vs real file");
        sizes.insert(fmt, on_disk as f64);
        std::fs::remove_file(&path).ok();
    }
    let ratio4 = sizes[&FormatId::Q40] / sizes[&FormatId::Q8];
    let ratio5 = sizes[&FormatId::Q50] / sizes[&FormatId::Q8];
    assert!(ratio4 <= 0.62, "q4_0/q8 byte ratio {ratio4} (gs=32 bound 0.62)");
    assert!(ratio4 < ratio5 && ratio5 < 1.0, "q4 {ratio4} < q5 {ratio5} < 1");
}

/// Serving a sub-INT8 model works end to end and the STATS line reports
/// the format; identical requests get identical (deterministic) replies.
#[test]
fn server_decodes_q4_model_and_reports_the_format() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::Arc;

    use llamaf::ps::gqmv::GqmvExec;
    use llamaf::server::{ServeOpts, Server};

    fn scalar_exec() -> Box<dyn GqmvExec + Send> {
        Box::new(ScalarGqmv)
    }

    let cfg = tiny_cfg();
    let model = Arc::new(QuantModel::from_float_fmt(&FloatModel::random(cfg, 9), FormatId::Q40));
    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts { workers: 1, ..Default::default() };
    let m2 = Arc::clone(&model);
    let server_thread =
        std::thread::spawn(move || server.serve_shared(m2, &scalar_exec, &opts, Some(1)).unwrap());

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut replies = Vec::new();
    let mut line = String::new();
    for _ in 0..2 {
        line.clear();
        conn.write_all(b"GEN 6 the quick fox\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("OK "), "{line}");
        replies.push(line.trim_end().to_string());
    }
    assert_eq!(replies[0], replies[1], "greedy decode must be deterministic");
    line.clear();
    conn.write_all(b"STATS\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("quant=q4_0"), "STATS must label the serving format: {line}");
    conn.write_all(b"QUIT\n").unwrap();
    drop(conn);
    server_thread.join().unwrap();
}
