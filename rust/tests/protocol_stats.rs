//! PROTOCOL contract: the `STATS`, `TRACE` and `METRICS` replies carry
//! every field documented in `docs/PROTOCOL.md` /
//! `docs/OBSERVABILITY.md`, well-formed — parsed from REAL server
//! replies, so the wire format and the spec cannot drift apart silently.
//!
//! Runs on the synthetic tiny model — no artifacts required.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use llamaf::model::{FloatModel, LlamaConfig, QuantModel};
use llamaf::ps::gqmv::GqmvExec;
use llamaf::ps::ScalarGqmv;
use llamaf::server::{ServeOpts, Server};

fn scalar_exec() -> Box<dyn GqmvExec + Send> {
    Box::new(ScalarGqmv)
}

fn tiny_model(seed: u64) -> Arc<QuantModel> {
    let cfg = LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 512,
        seq_len: 64,
        gs: 32,
    };
    Arc::new(QuantModel::from_float(&FloatModel::random(cfg, seed)))
}

/// Every `k=v` numeric field the PROTOCOL doc promises in a `STATS` reply.
const NUMERIC_FIELDS: &[&str] = &[
    "sessions_idle",
    "sessions_busy",
    "sessions_cap",
    "workers",
    "requests",
    "rejected",
    "tokens",
    "queue",
    "queue_peak",
    "p50_ms",
    "p99_ms",
    "mean_ms",
    "tok_s_p50",
    "batch_steps",
    "batch_tokens",
    "batch_mean",
    "batch_max",
    "bytes_staged",
    "bytes_per_tok",
    "prefetch_wait_ms",
    "prefetch_depth",
    "ring_occ",
    "stage_mb_s",
    "matrix_pct",
    "admission_ms",
    "prefill_chunk",
    "chunk_feeds",
    "stage_retries",
    "stage_faults",
    "stage_timeouts",
    "step_retries",
    "lane_faults",
    "deadline_expired",
    "page_hits",
    "page_misses",
    "page_evictions",
    "kv_pages_used",
    "kv_pages_cap",
];

#[test]
fn stats_reply_carries_every_documented_field() {
    let model = tiny_model(3);
    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts { workers: 2, ..Default::default() };
    let m2 = Arc::clone(&model);
    let server_thread =
        std::thread::spawn(move || server.serve_shared(m2, &scalar_exec, &opts, Some(1)).unwrap());

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    // run one real generation so the batch counters are live
    conn.write_all(b"GEN 4 hello\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "{line}");
    line.clear();
    conn.write_all(b"STATS\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let stats = line.trim_end().strip_prefix("OK ").expect("STATS must reply OK ...").to_string();
    conn.write_all(b"QUIT\n").unwrap();
    drop(conn);
    server_thread.join().unwrap();

    // the reply is a single line of space-separated k=v fields
    let mut kv: HashMap<String, String> = HashMap::new();
    for field in stats.split_whitespace() {
        let (k, v) = field
            .split_once('=')
            .unwrap_or_else(|| panic!("field '{field}' is not k=v: {stats}"));
        assert!(!kv.contains_key(k), "duplicate field {k}: {stats}");
        kv.insert(k.to_string(), v.to_string());
    }
    let num = |k: &str| -> f64 {
        kv.get(k)
            .unwrap_or_else(|| panic!("missing documented field '{k}': {stats}"))
            .parse()
            .unwrap_or_else(|_| panic!("field '{k}' is not numeric: {stats}"))
    };
    for &k in NUMERIC_FIELDS {
        let v = num(k);
        assert!(v.is_finite() && v >= 0.0, "field {k} = {v}: {stats}");
    }
    // enum-valued fields
    let weights = kv.get("weights").map(|s| s.as_str());
    assert!(matches!(weights, Some("streamed") | Some("resident")), "{stats}");
    let gran = kv.get("granularity").map(|s| s.as_str());
    assert!(matches!(gran, Some("layer") | Some("matrix") | Some("none")), "{stats}");
    let quant = kv.get("quant").map(|s| s.as_str());
    assert!(matches!(quant, Some("q8") | Some("q4_0") | Some("q5_0")), "{stats}");
    // mat_wait_ms is five slash-separated millisecond buckets (one per
    // matrix unit: norms/qkv/wo/w13/w2)
    let waits = kv.get("mat_wait_ms").unwrap_or_else(|| panic!("missing mat_wait_ms: {stats}"));
    let parts: Vec<f64> = waits
        .split('/')
        .map(|p| p.parse().unwrap_or_else(|_| panic!("mat_wait_ms part '{p}' not numeric")))
        .collect();
    assert_eq!(parts.len(), 5, "one wait bucket per matrix unit: {waits}");
    assert!(parts.iter().all(|w| w.is_finite() && *w >= 0.0), "{waits}");
    // the GEN above really ran through the counters
    assert!(num("requests") >= 1.0, "{stats}");
    assert!(num("tokens") >= 4.0, "{stats}");
    assert!(num("batch_steps") >= 1.0, "{stats}");
    assert_eq!(gran, Some("layer"), "default serving streams layer-granular: {stats}");
    assert_eq!(quant, Some("q8"), "from_float model serves on the INT8 lattice: {stats}");
    assert!(num("prefetch_depth") >= 1.0, "{stats}");
}

#[test]
fn stats_reports_matrix_granularity_and_bandwidth_when_configured() {
    use llamaf::sched::StageGranularity;
    let model = tiny_model(4);
    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts {
        workers: 1,
        granularity: StageGranularity::Matrix,
        prefetch_depth: 4,
        ..Default::default()
    };
    let m2 = Arc::clone(&model);
    let server_thread =
        std::thread::spawn(move || server.serve_shared(m2, &scalar_exec, &opts, Some(1)).unwrap());

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    conn.write_all(b"GEN 4 hi\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "{line}");
    line.clear();
    conn.write_all(b"STATS\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let stats = line.trim_end().to_string();
    conn.write_all(b"QUIT\n").unwrap();
    drop(conn);
    server_thread.join().unwrap();

    assert!(stats.contains("granularity=matrix"), "{stats}");
    assert!(stats.contains("prefetch_depth=4"), "{stats}");
    // something was staged, so the derived bandwidth must be nonzero
    let mbs = stats
        .split_whitespace()
        .find_map(|f| f.strip_prefix("stage_mb_s="))
        .expect("stage_mb_s field present")
        .parse::<f64>()
        .unwrap();
    assert!(mbs > 0.0, "staging ran, bandwidth must be derivable: {stats}");
}

/// Every `k=v` field a `TRACE` reply promises (`mat_wait_ms`, the one
/// non-scalar field, is checked separately below).
const TRACE_FIELDS: &[&str] = &[
    "id",
    "queue_ms",
    "prefill_tokens",
    "decode_tokens",
    "prefill_ms",
    "decode_ms",
    "staged_bytes",
    "prefetch_wait_ms",
    "batch_mean",
    "tok_s",
    "chunk_feeds",
    "prefix_tokens",
    "faults",
];

/// Every `llamaf_<name>` line the `METRICS` export promises, in the
/// order pinned by `docs/OBSERVABILITY.md`.
const METRIC_NAMES: &[&str] = &[
    "sessions_idle",
    "sessions_busy",
    "sessions_cap",
    "workers",
    "requests_total",
    "rejected_total",
    "tokens_total",
    "queue_depth",
    "queue_peak",
    "request_latency_p50_ms",
    "request_latency_p99_ms",
    "request_latency_mean_ms",
    "request_tok_s_p50",
    "traced_requests_total",
    "queue_wait_ms_p50",
    "queue_wait_ms_p99",
    "prefill_seconds_total",
    "decode_seconds_total",
    "prefill_tokens_total",
    "decode_tokens_total",
    "batch_steps_total",
    "batch_lane_tokens_total",
    "batch_occupancy_mean",
    "batch_occupancy_max",
    "staged_bytes_total",
    "staged_bytes_per_token",
    "prefetch_wait_ms_total",
    "prefetch_depth",
    "ring_occupancy",
    "stage_mb_s",
    "mat_wait_ms_norms",
    "mat_wait_ms_qkv",
    "mat_wait_ms_wo",
    "mat_wait_ms_w13",
    "mat_wait_ms_w2",
    "matrix_time_pct",
    "weights_resident",
    "granularity_matrix",
    "admission_ms_mean",
    "prefill_chunk",
    "chunk_feeds_total",
    "stage_retries_total",
    "stage_faults_total",
    "stage_timeouts_total",
    "step_retries_total",
    "lane_faults_total",
    "deadline_expired_total",
    "page_hits_total",
    "page_misses_total",
    "page_evictions_total",
    "kv_pages_used",
    "kv_pages_cap",
];

#[test]
fn trace_and_metrics_replies_match_the_documented_contract() {
    let model = tiny_model(5);
    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts { workers: 1, ..Default::default() };
    let m2 = Arc::clone(&model);
    let server_thread =
        std::thread::spawn(move || server.serve_shared(m2, &scalar_exec, &opts, Some(1)).unwrap());

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();

    // TRACE before any generation on this connection is an explicit error
    conn.write_all(b"TRACE\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("ERR "), "TRACE with no prior generation must ERR: {line}");

    // one streamed generation: TOK lines then DONE
    conn.write_all(b"SGEN 4 hello\n").unwrap();
    let mut toks = 0usize;
    loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        if line.starts_with("TOK ") {
            toks += 1;
        } else {
            assert!(line.starts_with("DONE "), "unexpected SGEN line: {line}");
            break;
        }
    }
    assert_eq!(toks, 4, "SGEN 4 must stream exactly 4 tokens");

    // TRACE now returns the per-request breakdown of that generation
    line.clear();
    conn.write_all(b"TRACE\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let trace = line
        .trim_end()
        .strip_prefix("OK trace ")
        .unwrap_or_else(|| panic!("TRACE must reply 'OK trace ...': {line}"))
        .to_string();
    let mut kv: HashMap<String, String> = HashMap::new();
    for field in trace.split_whitespace() {
        let (k, v) = field
            .split_once('=')
            .unwrap_or_else(|| panic!("TRACE field '{field}' is not k=v: {trace}"));
        assert!(!kv.contains_key(k), "duplicate TRACE field {k}: {trace}");
        kv.insert(k.to_string(), v.to_string());
    }
    let num = |k: &str| -> f64 {
        kv.get(k)
            .unwrap_or_else(|| panic!("missing documented TRACE field '{k}': {trace}"))
            .parse()
            .unwrap_or_else(|_| panic!("TRACE field '{k}' is not numeric: {trace}"))
    };
    for &k in TRACE_FIELDS {
        let v = num(k);
        assert!(v.is_finite() && v >= 0.0, "TRACE field {k} = {v}: {trace}");
    }
    // mat_wait_ms mirrors STATS: five slash-separated ms buckets
    let waits = kv.get("mat_wait_ms").unwrap_or_else(|| panic!("missing mat_wait_ms: {trace}"));
    let parts: Vec<f64> = waits
        .split('/')
        .map(|p| p.parse().unwrap_or_else(|_| panic!("mat_wait_ms part '{p}' not numeric")))
        .collect();
    assert_eq!(parts.len(), 5, "one wait bucket per matrix unit: {waits}");
    // the phase split must reconcile with what the wire protocol streamed
    assert_eq!(num("decode_tokens"), 4.0, "decode split must equal streamed tokens: {trace}");
    assert!(num("decode_ms") > 0.0, "4 decode steps took nonzero time: {trace}");
    assert!(num("staged_bytes") > 0.0, "streamed serving stages weights: {trace}");
    assert!(num("batch_mean") >= 1.0, "the lane itself occupies the batch: {trace}");
    assert_eq!(kv.len(), TRACE_FIELDS.len() + 1, "undocumented TRACE field present: {trace}");

    // METRICS: `METRICS <n>` header, then exactly n `llamaf_<name> <value>`
    // lines covering the documented name set and nothing else
    line.clear();
    conn.write_all(b"METRICS\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let n: usize = line
        .trim_end()
        .strip_prefix("METRICS ")
        .unwrap_or_else(|| panic!("METRICS must reply 'METRICS <n>': {line}"))
        .parse()
        .expect("METRICS count must be an integer");
    let mut metrics: HashMap<String, f64> = HashMap::new();
    for _ in 0..n {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let body = line
            .trim_end()
            .strip_prefix("llamaf_")
            .unwrap_or_else(|| panic!("metric line must start llamaf_: {line}"));
        let (name, value) =
            body.split_once(' ').unwrap_or_else(|| panic!("metric line not 'name value': {line}"));
        let value: f64 = value.parse().unwrap_or_else(|_| panic!("metric not numeric: {line}"));
        assert!(value.is_finite() && value >= 0.0, "metric {name} = {value}");
        assert!(metrics.insert(name.to_string(), value).is_none(), "duplicate metric {name}");
    }
    assert_eq!(n, METRIC_NAMES.len(), "header count must match the documented export");
    for &name in METRIC_NAMES {
        assert!(metrics.contains_key(name), "missing documented metric llamaf_{name}");
    }
    assert_eq!(metrics.len(), METRIC_NAMES.len(), "undocumented metric exported");
    // the SGEN above flowed through the aggregates
    assert!(metrics["requests_total"] >= 1.0);
    assert!(metrics["traced_requests_total"] >= 1.0, "completed request must be traced");
    assert!(metrics["decode_tokens_total"] >= 4.0);
    assert!(metrics["batch_steps_total"] >= 1.0);
    assert!(metrics["staged_bytes_total"] > 0.0);
    assert_eq!(metrics["weights_resident"], 0.0, "default serving streams");
    // no injection, no deadline: every fault counter must read zero
    for name in
        ["stage_faults_total", "stage_timeouts_total", "lane_faults_total", "deadline_expired_total"]
    {
        assert_eq!(metrics[name], 0.0, "fault-free run must export zero {name}");
    }

    conn.write_all(b"QUIT\n").unwrap();
    drop(conn);
    server_thread.join().unwrap();
}
