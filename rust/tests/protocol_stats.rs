//! PROTOCOL contract: the `STATS` reply carries every field documented in
//! `docs/PROTOCOL.md`, well-formed — parsed from a REAL server reply, so
//! the wire format and the spec cannot drift apart silently.
//!
//! Runs on the synthetic tiny model — no artifacts required.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use llamaf::model::{FloatModel, LlamaConfig, QuantModel};
use llamaf::ps::gqmv::GqmvExec;
use llamaf::ps::ScalarGqmv;
use llamaf::server::{ServeOpts, Server};

fn scalar_exec() -> Box<dyn GqmvExec + Send> {
    Box::new(ScalarGqmv)
}

fn tiny_model(seed: u64) -> Arc<QuantModel> {
    let cfg = LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 512,
        seq_len: 64,
        gs: 32,
    };
    Arc::new(QuantModel::from_float(&FloatModel::random(cfg, seed)))
}

/// Every `k=v` numeric field the PROTOCOL doc promises in a `STATS` reply.
const NUMERIC_FIELDS: &[&str] = &[
    "sessions_idle",
    "sessions_busy",
    "sessions_cap",
    "workers",
    "requests",
    "rejected",
    "tokens",
    "queue",
    "queue_peak",
    "p50_ms",
    "p99_ms",
    "mean_ms",
    "tok_s_p50",
    "batch_steps",
    "batch_tokens",
    "batch_mean",
    "batch_max",
    "bytes_staged",
    "bytes_per_tok",
    "prefetch_wait_ms",
    "prefetch_depth",
    "ring_occ",
    "stage_mb_s",
    "matrix_pct",
];

#[test]
fn stats_reply_carries_every_documented_field() {
    let model = tiny_model(3);
    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts { workers: 2, ..Default::default() };
    let m2 = Arc::clone(&model);
    let server_thread =
        std::thread::spawn(move || server.serve_shared(m2, &scalar_exec, &opts, Some(1)).unwrap());

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    // run one real generation so the batch counters are live
    conn.write_all(b"GEN 4 hello\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "{line}");
    line.clear();
    conn.write_all(b"STATS\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let stats = line.trim_end().strip_prefix("OK ").expect("STATS must reply OK ...").to_string();
    conn.write_all(b"QUIT\n").unwrap();
    drop(conn);
    server_thread.join().unwrap();

    // the reply is a single line of space-separated k=v fields
    let mut kv: HashMap<String, String> = HashMap::new();
    for field in stats.split_whitespace() {
        let (k, v) = field
            .split_once('=')
            .unwrap_or_else(|| panic!("field '{field}' is not k=v: {stats}"));
        assert!(!kv.contains_key(k), "duplicate field {k}: {stats}");
        kv.insert(k.to_string(), v.to_string());
    }
    let num = |k: &str| -> f64 {
        kv.get(k)
            .unwrap_or_else(|| panic!("missing documented field '{k}': {stats}"))
            .parse()
            .unwrap_or_else(|_| panic!("field '{k}' is not numeric: {stats}"))
    };
    for &k in NUMERIC_FIELDS {
        let v = num(k);
        assert!(v.is_finite() && v >= 0.0, "field {k} = {v}: {stats}");
    }
    // enum-valued fields
    let weights = kv.get("weights").map(|s| s.as_str());
    assert!(matches!(weights, Some("streamed") | Some("resident")), "{stats}");
    let gran = kv.get("granularity").map(|s| s.as_str());
    assert!(matches!(gran, Some("layer") | Some("matrix") | Some("none")), "{stats}");
    // mat_wait_ms is five slash-separated millisecond buckets (one per
    // matrix unit: norms/qkv/wo/w13/w2)
    let waits = kv.get("mat_wait_ms").unwrap_or_else(|| panic!("missing mat_wait_ms: {stats}"));
    let parts: Vec<f64> = waits
        .split('/')
        .map(|p| p.parse().unwrap_or_else(|_| panic!("mat_wait_ms part '{p}' not numeric")))
        .collect();
    assert_eq!(parts.len(), 5, "one wait bucket per matrix unit: {waits}");
    assert!(parts.iter().all(|w| w.is_finite() && *w >= 0.0), "{waits}");
    // the GEN above really ran through the counters
    assert!(num("requests") >= 1.0, "{stats}");
    assert!(num("tokens") >= 4.0, "{stats}");
    assert!(num("batch_steps") >= 1.0, "{stats}");
    assert_eq!(gran, Some("layer"), "default serving streams layer-granular: {stats}");
    assert!(num("prefetch_depth") >= 1.0, "{stats}");
}

#[test]
fn stats_reports_matrix_granularity_and_bandwidth_when_configured() {
    use llamaf::sched::StageGranularity;
    let model = tiny_model(4);
    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts {
        workers: 1,
        granularity: StageGranularity::Matrix,
        prefetch_depth: 4,
        ..Default::default()
    };
    let m2 = Arc::clone(&model);
    let server_thread =
        std::thread::spawn(move || server.serve_shared(m2, &scalar_exec, &opts, Some(1)).unwrap());

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    conn.write_all(b"GEN 4 hi\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("OK "), "{line}");
    line.clear();
    conn.write_all(b"STATS\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let stats = line.trim_end().to_string();
    conn.write_all(b"QUIT\n").unwrap();
    drop(conn);
    server_thread.join().unwrap();

    assert!(stats.contains("granularity=matrix"), "{stats}");
    assert!(stats.contains("prefetch_depth=4"), "{stats}");
    // something was staged, so the derived bandwidth must be nonzero
    let mbs = stats
        .split_whitespace()
        .find_map(|f| f.strip_prefix("stage_mb_s="))
        .expect("stage_mb_s field present")
        .parse::<f64>()
        .unwrap();
    assert!(mbs > 0.0, "staging ran, bandwidth must be derivable: {stats}");
}
