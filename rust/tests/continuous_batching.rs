//! Integration: continuous batching pinned by a randomized-schedule
//! equivalence harness.
//!
//! The scheduler invariant under test is stronger than "same tokens":
//! for ANY admission schedule — requests arriving at random times, with
//! ragged prompt lengths and step budgets, joining mid-decode and
//! retiring independently — every session's output AND its per-op
//! digest trace ([`ExecTrace`]) must be bit-identical to sequential
//! batch-1 greedy generation of the same prompt.  On divergence the
//! trace diff names the first differing (step, layer, op) instead of
//! just "tokens differ".
//!
//! Also here: chunked-prefill equivalence (chunk sizes 1, 3 and
//! whole-prompt leave identical KV contents and outputs, covering the
//! prompt-boundary off-by-one) and a serve-level soak with join/leave
//! churn — including a client that drops mid-generation — pinning that
//! sessions and KV pages drain to exactly zero.
//!
//! Randomized cases run a fixed seed set by default (CI-reproducible);
//! `LLAMAF_TEST_REPEATS=N` sweeps N× the seeds (`testutil::repeats`).
//! Runs on the synthetic tiny model — no artifacts required.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use llamaf::engine::batch::{Admission, BatchOpts, BatchScheduler};
use llamaf::engine::forward::{CpuEngine, Engine};
use llamaf::engine::generate::{generate, Sampler};
use llamaf::engine::session::Session;
use llamaf::model::{FloatModel, KvStore, LlamaConfig, QuantModel};
use llamaf::ps::gqmv::GqmvExec;
use llamaf::ps::ScalarGqmv;
use llamaf::server::{ServeOpts, Server};
use llamaf::testutil::forall;
use llamaf::trace;

fn tiny_cfg() -> LlamaConfig {
    LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 64,
        seq_len: 64,
        gs: 32,
    }
}

fn tiny_model(seed: u64) -> Arc<QuantModel> {
    Arc::new(QuantModel::from_float(&FloatModel::random(tiny_cfg(), seed)))
}

fn scalar_exec() -> Box<dyn GqmvExec + Send> {
    Box::new(ScalarGqmv)
}

/// Batch-1 oracle: a dedicated engine generating greedily with the per-op
/// digest recorder armed.  Returns (tokens, trace).
fn batch1_oracle(
    model: &Arc<QuantModel>,
    prompt: &[u32],
    steps: usize,
) -> (Vec<u32>, trace::ExecTrace) {
    let mut eng = CpuEngine::new(Arc::clone(model), Box::new(ScalarGqmv));
    assert!(eng.trace_start("oracle"));
    let out = generate(&mut eng, prompt, steps, Sampler::Greedy, false).unwrap();
    (out.generated, eng.trace_take().unwrap())
}

#[test]
fn randomized_admission_schedules_match_batch1_oracle() {
    // The tentpole harness: seeded random arrival times, ragged prompts,
    // random step budgets through a traced continuous-admission
    // scheduler.  Every session must match its batch-1 oracle token for
    // token AND op for op; a scheduling bug that perturbs even one
    // intermediate digest fails with the first divergent op named.
    let model = tiny_model(31);
    forall("random admission schedules", 4, |rng| {
        let max_batch = *rng.choose(&[2usize, 3, 4, 8]);
        let n_clients = rng.below(5) as usize + 3;
        let sched = BatchScheduler::new(
            Arc::clone(&model),
            Box::new(ScalarGqmv),
            BatchOpts { max_batch, trace: true, ..Default::default() },
        );
        let handles: Vec<std::thread::JoinHandle<bool>> = (0..n_clients)
            .map(|ci| {
                let plen = rng.below(6) as usize + 1;
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(64) as u32).collect();
                let steps = rng.below(10) as usize + 1;
                let delay_ms = rng.below(30);
                let model = Arc::clone(&model);
                let sched = Arc::clone(&sched);
                std::thread::spawn(move || -> bool {
                    std::thread::sleep(Duration::from_millis(delay_ms));
                    let (want, ref_trace) = batch1_oracle(&model, &prompt, steps);
                    let mut streamed = Vec::new();
                    let (sess, out) =
                        sched.generate(Session::new(&model.cfg), &prompt, steps, |step, id| {
                            assert_eq!(step, streamed.len(), "out-of-order token");
                            streamed.push(id);
                            Ok(())
                        });
                    assert!(sess.is_some(), "client {ci}: session not returned");
                    let gen = out.expect("batched generation failed");
                    if gen.generated != want || streamed != want {
                        eprintln!(
                            "client {ci}: tokens diverged (prompt {prompt:?}, {steps} steps): \
                             got {:?} want {want:?}",
                            gen.generated
                        );
                        return false;
                    }
                    let exec = gen.exec_trace.expect("trace: true returns an op trace");
                    let report = trace::diff(&ref_trace, &exec);
                    if !report.identical() {
                        eprintln!(
                            "client {ci}: op trace diverged from batch-1: {}",
                            report.summary()
                        );
                        return false;
                    }
                    true
                })
            })
            .collect();
        let ok = handles.into_iter().all(|h| h.join().unwrap());
        sched.shutdown();
        ok
    });
}

#[test]
fn chunked_prefill_leaves_identical_kv_and_outputs() {
    // Prefill chunk sizes 1, 3 and whole-prompt must be indistinguishable
    // after the fact: same tokens, same final position, and bit-identical
    // KV floats at every (layer, pos, head).  Prompt lengths 2..=5 and 7
    // sweep the chunk-boundary off-by-ones (len % chunk ∈ {0, 1, 2},
    // including the final-token-samples case landing on each offset).
    let model = tiny_model(32);
    let cfg = model.cfg;
    let hd = cfg.head_dim();
    let steps = 5usize;
    for plen in [2usize, 3, 4, 5, 7] {
        let prompt: Vec<u32> = (0..plen).map(|i| ((3 * i + 1) % 64) as u32).collect();
        let mut eng = CpuEngine::new(Arc::clone(&model), Box::new(ScalarGqmv));
        let want = generate(&mut eng, &prompt, steps, Sampler::Greedy, false).unwrap().generated;
        let mut baseline: Option<Session> = None;
        for chunk in [1usize, 3, plen] {
            let sched = BatchScheduler::new(
                Arc::clone(&model),
                Box::new(ScalarGqmv),
                BatchOpts { prefill_chunk: chunk, ..Default::default() },
            );
            let (sess, out) = sched.generate(Session::new(&cfg), &prompt, steps, |_, _| Ok(()));
            sched.shutdown();
            let sess = sess.expect("session returned");
            assert_eq!(
                out.unwrap().generated,
                want,
                "plen {plen} chunk {chunk}: tokens diverged"
            );
            assert_eq!(sess.pos, plen - 1 + steps, "plen {plen} chunk {chunk}: bad position");
            assert_eq!(sess.kv.filled(), plen - 1 + steps);
            match &baseline {
                None => baseline = Some(sess),
                Some(b) => {
                    for layer in 0..cfg.n_layers {
                        for pos in 0..b.kv.filled() {
                            for h in 0..cfg.n_kv_heads {
                                assert_eq!(
                                    sess.kv.key(layer, pos, h, hd),
                                    b.kv.key(layer, pos, h, hd),
                                    "plen {plen} chunk {chunk}: K diverged at \
                                     layer {layer} pos {pos} head {h}"
                                );
                                assert_eq!(
                                    sess.kv.value(layer, pos, h, hd),
                                    b.kv.value(layer, pos, h, hd),
                                    "plen {plen} chunk {chunk}: V diverged at \
                                     layer {layer} pos {pos} head {h}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn drain_admission_matches_oracle_under_concurrency() {
    // The static-batch baseline must be just as exact as continuous
    // admission — it only changes WHEN lanes join, never what they
    // compute.  Three concurrent ragged clients through Drain mode.
    let model = tiny_model(33);
    let sched = BatchScheduler::new(
        Arc::clone(&model),
        Box::new(ScalarGqmv),
        BatchOpts { max_batch: 3, admission: Admission::Drain, ..Default::default() },
    );
    let handles: Vec<_> = (0..3usize)
        .map(|i| {
            let model = Arc::clone(&model);
            let sched = Arc::clone(&sched);
            std::thread::spawn(move || {
                let prompt: Vec<u32> = (0..2 + i).map(|k| ((5 * i + k + 1) % 64) as u32).collect();
                let steps = 4 + i;
                let mut eng = CpuEngine::new(Arc::clone(&model), Box::new(ScalarGqmv));
                let want =
                    generate(&mut eng, &prompt, steps, Sampler::Greedy, false).unwrap().generated;
                let (sess, out) =
                    sched.generate(Session::new(&model.cfg), &prompt, steps, |_, _| Ok(()));
                assert!(sess.is_some());
                assert_eq!(out.unwrap().generated, want, "drain lane {i} diverged");
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    sched.shutdown();
}

#[test]
fn serve_soak_with_churn_drains_sessions_and_kv_pages_to_zero() {
    // Serve-level soak: clients join and leave at staggered times, some
    // vanish mid-generation without QUIT (dead-socket cancel path), all
    // over a paged KV pool.  After the server drains, no session may
    // still be checked out and the page ledger must read exactly zero —
    // a leaked page or double-free shows up as a nonzero count.
    let cfg = LlamaConfig {
        dim: 64,
        hidden_dim: 128,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        vocab_size: 512,
        seq_len: 64,
        gs: 32,
    };
    let model = Arc::new(QuantModel::from_float(&FloatModel::random(cfg, 34)));
    let server = Server::bind("127.0.0.1:0", 512).unwrap();
    let addr = server.local_addr().unwrap();
    let opts = ServeOpts {
        workers: 3,
        queue_depth: 16,
        max_sessions: 4,
        kv_pages: 32,
        ..Default::default()
    };
    let n_clients = 9usize;
    let server_thread = std::thread::spawn(move || {
        server.serve_shared(model, &scalar_exec, &opts, Some(n_clients)).unwrap()
    });

    let handles: Vec<_> = (0..n_clients)
        .map(|i| {
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis((i as u64 % 4) * 15));
                let mut conn = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                if i % 3 == 2 {
                    // churn client: start a long generation, read two
                    // tokens, then vanish — the server must cancel the
                    // lane and reclaim the session and its pages
                    conn.write_all(format!("SGEN 32 soak prompt {i}\n").as_bytes()).unwrap();
                    for _ in 0..2 {
                        let mut line = String::new();
                        reader.read_line(&mut line).unwrap();
                        assert!(line.starts_with("TOK "), "unexpected line: {line:?}");
                    }
                    drop(reader);
                    drop(conn); // no QUIT: dead socket mid-stream
                } else {
                    conn.write_all(format!("SGEN 4 soak prompt {i}\n").as_bytes()).unwrap();
                    let mut toks = 0usize;
                    loop {
                        let mut line = String::new();
                        reader.read_line(&mut line).unwrap();
                        let line = line.trim_end();
                        if line.starts_with("TOK ") {
                            toks += 1;
                        } else if line.starts_with("DONE ") {
                            break;
                        } else {
                            panic!("unexpected server line: {line:?}");
                        }
                    }
                    assert_eq!(toks, 4, "client {i} lost tokens");
                    conn.write_all(b"QUIT\n").unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let report = server_thread.join().unwrap();
    assert_eq!(report.accepted, n_clients);
    assert!(report.tokens > 0, "soak produced no tokens");
    assert_eq!(report.busy_at_exit, 0, "a session leaked out of the pool");
    assert!(report.idle_at_exit <= 4, "more idle sessions than the pool cap");
    assert_eq!(
        report.kv_pages_at_exit, 0,
        "KV page ledger did not drain to zero after churn"
    );
}
