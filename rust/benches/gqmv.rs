//! GQMV micro-benchmarks: every backend at every Algorithm-2 shape, plus
//! the GOPS figures for Table VI's first column.

use std::sync::Arc;

use llamaf::bench::{section, Bench};
use llamaf::fpga::{DataflowSim, PlConfig};
use llamaf::model::{MatKind, NANO, TINYLLAMA_1_1B};
use llamaf::ps::gqmv::GqmvExec;
use llamaf::ps::{ScalarGqmv, ThreadedGqmv};
use llamaf::quant::{quantize_activation, QuantizedTensor};
use llamaf::util::{Rng, ThreadPool};

fn bench_backend(exec: &mut dyn GqmvExec, m: usize, n: usize, gs: usize, b: &Bench) -> f64 {
    let mut rng = Rng::new((m * 31 + n) as u64);
    let w = QuantizedTensor {
        q: rng.i8_vec(m * n),
        s: (0..m * n / gs).map(|_| rng.next_f32() * 1e-3).collect(),
        rows: m,
        cols: n,
        gs,
    };
    let (xq, xs) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
    let mut out = vec![0.0f32; m];
    let r = b.run(&format!("{} {m}x{n}", exec.name()), || {
        exec.gqmv(&xq, &xs, &w, &mut out).unwrap();
    });
    let gops = 2.0 * (m * n) as f64 / r.mean_s / 1e9;
    println!("{}  -> {gops:.3} GOPS", r.row());
    gops
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || llamaf::bench::smoke();
    let b = if quick { Bench::quick() } else { Bench::default() };
    let mut report = llamaf::bench::Report::new("gqmv");
    let pool = Arc::new(ThreadPool::new(4));

    section("GQMV backends at nano shapes (Algorithm 2 launches)");
    for kind in [MatKind::Qkv, MatKind::Wo, MatKind::W13, MatKind::W2, MatKind::Cls] {
        let (m, n) = NANO.mat_shape(kind);
        bench_backend(&mut ScalarGqmv, m, n, NANO.gs, &b);
        let mut th = ThreadedGqmv::new(pool.clone());
        bench_backend(&mut th, m, n, NANO.gs, &b);
    }

    section("GQMV at the paper's logits shape (32000x2048) — Table VI GOPS column");
    let (m, n) = TINYLLAMA_1_1B.mat_shape(MatKind::Cls);
    let slow = Bench { budget_s: if quick { 0.5 } else { 4.0 }, min_iters: 3, ..Bench::default() };
    let scalar_gops = bench_backend(&mut ScalarGqmv, m, n, 256, &slow);
    let mut th = ThreadedGqmv::new(Arc::new(ThreadPool::new(4)));
    let th4 = bench_backend(&mut th, m, n, 256, &slow);
    let mut th_all = ThreadedGqmv::new(Arc::new(ThreadPool::new(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8),
    )));
    let th_all_gops = bench_backend(&mut th_all, m, n, 256, &slow);

    let pl = PlConfig::default();
    println!(
        "\nmodelled FPGA PL (205 MHz, 16 B/cyc): {:.3} GOPS (paper: 4.696)",
        pl.gops(m, n, 256)
    );
    println!("paper ZCU102 PS (4x A53 OpenMP):      0.201 GOPS");
    println!(
        "this CPU scalar: {scalar_gops:.3} | threaded x4: {th4:.3} | all cores: \
         {th_all_gops:.3}"
    );
    report.case("cls_scalar", scalar_gops, "GOPS");
    report.case("cls_threaded_x4", th4, "GOPS");
    report.case("cls_threaded_all", th_all_gops, "GOPS");

    section("PJRT kernel path (requires artifacts): upload vs execute split");
    if let Ok(rt) = llamaf::runtime::Runtime::load(std::path::Path::new("artifacts")) {
        let mut rng = Rng::new(7);
        for (m, n) in [(512usize, 256usize), (1536, 256)] {
            let gs = 256;
            let w = QuantizedTensor {
                q: rng.i8_vec(m * n),
                s: (0..m * n / gs).map(|_| rng.next_f32() * 1e-3).collect(),
                rows: m,
                cols: n,
                gs,
            };
            let (xq, xs) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
            let mut out = vec![0.0f32; m];
            let up = b.run(&format!("pjrt upload {m}x{n}"), || {
                let dw = rt.upload(&w).unwrap();
                std::hint::black_box(&dw);
            });
            println!("{}", up.row());
            let dw = rt.upload(&w).unwrap();
            let ex = b.run(&format!("pjrt execute {m}x{n}"), || {
                rt.gqmv_device(&dw, &xq, &xs, &mut out).unwrap();
            });
            println!("{}  -> {:.3} GOPS", ex.row(), 2.0 * (m * n) as f64 / ex.mean_s / 1e9);
        }
    } else {
        println!("(skipped: run `make artifacts`)");
    }

    section("dataflow simulator functional throughput (host-side cost of simulation)");
    let mut sim = DataflowSim::new(PlConfig::default());
    let sim_gops = bench_backend(&mut sim, 512, 256, 256, &b);
    println!(
        "simulated PL time for those calls: {:.3} ms ({:.3} simulated GOPS)",
        sim.simulated_time_s() * 1e3,
        sim.achieved_gops()
    );
    report.case("dataflow_sim_host", sim_gops, "GOPS");
    match report.write() {
        Ok(p) => eprintln!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
