//! GQMV micro-benchmarks: every backend at every Algorithm-2 shape, the
//! GOPS figures for Table VI's first column, and the dispatch-efficiency
//! A/Bs of the pipelined execution engine — fused vs unfused same-input
//! dispatch (7 vs 4 launches per layer) and blocked vs strided row
//! kernels.

use std::sync::Arc;

use anyhow::Result;
use llamaf::bench::{section, Bench};
use llamaf::fpga::{DataflowSim, PlConfig};
use llamaf::model::{LlamaConfig, MatKind, NANO, TINYLLAMA_1_1B};
use llamaf::ps::gqmv::{gqmv_row, gqmv_rows, GqmvExec};
use llamaf::ps::{ScalarGqmv, ThreadedGqmv};
use llamaf::quant::{quantize_activation, QuantizedTensor};
use llamaf::util::{Rng, ThreadPool};

fn bench_backend(exec: &mut dyn GqmvExec, m: usize, n: usize, gs: usize, b: &Bench) -> f64 {
    let mut rng = Rng::new((m * 31 + n) as u64);
    let w = QuantizedTensor {
        q: rng.i8_vec(m * n),
        s: (0..m * n / gs).map(|_| rng.next_f32() * 1e-3).collect(),
        rows: m,
        cols: n,
        gs,
        fmt: llamaf::quant::FormatId::Q8,
    };
    let (xq, xs) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
    let mut out = vec![0.0f32; m];
    let r = b.run(&format!("{} {m}x{n}", exec.name()), || {
        exec.gqmv(&xq, &xs, &w, &mut out).unwrap();
    });
    let gops = 2.0 * (m * n) as f64 / r.mean_s / 1e9;
    println!("{}  -> {gops:.3} GOPS", r.row());
    gops
}

/// Counts backend dispatches (pool wakeup opportunities) while delegating
/// to an inner exec — the measurement behind the 7 → 4 launch claim.
struct CountingExec<E: GqmvExec> {
    inner: E,
    dispatches: usize,
}

impl<E: GqmvExec> GqmvExec for CountingExec<E> {
    fn gqmv(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) -> Result<()> {
        self.dispatches += 1;
        self.inner.gqmv(xq, xs, w, out)
    }

    fn gqmv_fused(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        ws: &[&QuantizedTensor],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        self.dispatches += 1;
        self.inner.gqmv_fused(xq, xs, ws, outs)
    }

    fn name(&self) -> &'static str {
        "counting"
    }
}

/// One transformer layer's seven matrices (split, the unfused baseline)
/// plus the activations feeding each of the four same-input groups.
struct LayerCase {
    wq: QuantizedTensor,
    wk: QuantizedTensor,
    wv: QuantizedTensor,
    wo: QuantizedTensor,
    w1: QuantizedTensor,
    w3: QuantizedTensor,
    w2: QuantizedTensor,
    x_att: Vec<f32>,
    x_o: Vec<f32>,
    x_ffn: Vec<f32>,
    x_h: Vec<f32>,
}

fn layer_case(cfg: &LlamaConfig, seed: u64) -> LayerCase {
    let (d, kv, h, gs) = (cfg.dim, cfg.kv_dim(), cfg.hidden_dim, cfg.gs);
    let mut rng = Rng::new(seed);
    let mut mk = |rows: usize, cols: usize| {
        QuantizedTensor::from_f32(&rng.normal_vec(rows * cols, 0.5), rows, cols, gs)
    };
    let (wq, wk, wv) = (mk(d, d), mk(kv, d), mk(kv, d));
    let (wo, w1, w3, w2) = (mk(d, d), mk(h, d), mk(h, d), mk(d, h));
    let mut rng2 = Rng::new(seed + 1);
    LayerCase {
        wq,
        wk,
        wv,
        wo,
        w1,
        w3,
        w2,
        x_att: rng2.normal_vec(d, 1.0),
        x_o: rng2.normal_vec(d, 1.0),
        x_ffn: rng2.normal_vec(d, 1.0),
        x_h: rng2.normal_vec(h, 1.0),
    }
}

/// The unfused baseline: seven isolated gqmv calls, each paying its own
/// activation quantization (the launch pattern the fused engine removes).
fn layer_unfused(exec: &mut dyn GqmvExec, c: &LayerCase, gs: usize) -> usize {
    let mut quants = 0usize;
    let mut run = |x: &[f32], w: &QuantizedTensor, out: &mut [f32]| {
        let (xq, xs) = quantize_activation(x, gs);
        quants += 1;
        exec.gqmv(&xq, &xs, w, out).unwrap();
    };
    let mut q = vec![0.0f32; c.wq.rows];
    let mut k = vec![0.0f32; c.wk.rows];
    let mut v = vec![0.0f32; c.wv.rows];
    run(&c.x_att, &c.wq, &mut q);
    run(&c.x_att, &c.wk, &mut k);
    run(&c.x_att, &c.wv, &mut v);
    let mut o = vec![0.0f32; c.wo.rows];
    run(&c.x_o, &c.wo, &mut o);
    let mut h1 = vec![0.0f32; c.w1.rows];
    let mut h3 = vec![0.0f32; c.w3.rows];
    run(&c.x_ffn, &c.w1, &mut h1);
    run(&c.x_ffn, &c.w3, &mut h3);
    let mut out2 = vec![0.0f32; c.w2.rows];
    run(&c.x_h, &c.w2, &mut out2);
    quants
}

/// The fused engine: Q/K/V share one quantization + one dispatch, W1/W3
/// likewise — four launches per layer.
fn layer_fused(exec: &mut dyn GqmvExec, c: &LayerCase, gs: usize) -> usize {
    let mut quants = 0usize;
    let (xq, xs) = quantize_activation(&c.x_att, gs);
    quants += 1;
    let mut q = vec![0.0f32; c.wq.rows];
    let mut k = vec![0.0f32; c.wk.rows];
    let mut v = vec![0.0f32; c.wv.rows];
    let qkv = [&c.wq, &c.wk, &c.wv];
    let mut qkv_outs = [&mut q[..], &mut k[..], &mut v[..]];
    exec.gqmv_fused(&xq, &xs, &qkv, &mut qkv_outs).unwrap();
    let (xq, xs) = quantize_activation(&c.x_o, gs);
    quants += 1;
    let mut o = vec![0.0f32; c.wo.rows];
    exec.gqmv(&xq, &xs, &c.wo, &mut o).unwrap();
    let (xq, xs) = quantize_activation(&c.x_ffn, gs);
    quants += 1;
    let mut h1 = vec![0.0f32; c.w1.rows];
    let mut h3 = vec![0.0f32; c.w3.rows];
    exec.gqmv_fused(&xq, &xs, &[&c.w1, &c.w3], &mut [&mut h1[..], &mut h3[..]]).unwrap();
    let (xq, xs) = quantize_activation(&c.x_h, gs);
    quants += 1;
    let mut out2 = vec![0.0f32; c.w2.rows];
    exec.gqmv(&xq, &xs, &c.w2, &mut out2).unwrap();
    quants
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || llamaf::bench::smoke();
    let b = if quick { Bench::quick() } else { Bench::default() };
    let mut report = llamaf::bench::Report::new("gqmv");
    let pool = Arc::new(ThreadPool::new(4));

    section("GQMV backends at nano shapes (Algorithm 2 launches)");
    for kind in [MatKind::Qkv, MatKind::Wo, MatKind::W13, MatKind::W2, MatKind::Cls] {
        let (m, n) = NANO.mat_shape(kind);
        bench_backend(&mut ScalarGqmv, m, n, NANO.gs, &b);
        let mut th = ThreadedGqmv::new(pool.clone());
        bench_backend(&mut th, m, n, NANO.gs, &b);
    }

    section("GQMV at the paper's logits shape (32000x2048) — Table VI GOPS column");
    let (m, n) = TINYLLAMA_1_1B.mat_shape(MatKind::Cls);
    let slow = Bench { budget_s: if quick { 0.5 } else { 4.0 }, min_iters: 3, ..Bench::default() };
    let scalar_gops = bench_backend(&mut ScalarGqmv, m, n, 256, &slow);
    let mut th = ThreadedGqmv::new(Arc::new(ThreadPool::new(4)));
    let th4 = bench_backend(&mut th, m, n, 256, &slow);
    let mut th_all = ThreadedGqmv::new(Arc::new(ThreadPool::new(
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(8),
    )));
    let th_all_gops = bench_backend(&mut th_all, m, n, 256, &slow);

    let pl = PlConfig::default();
    println!(
        "\nmodelled FPGA PL (205 MHz, 16 B/cyc): {:.3} GOPS (paper: 4.696)",
        pl.gops(m, n, 256)
    );
    println!("paper ZCU102 PS (4x A53 OpenMP):      0.201 GOPS");
    println!(
        "this CPU scalar: {scalar_gops:.3} | threaded x4: {th4:.3} | all cores: \
         {th_all_gops:.3}"
    );
    report.case("cls_scalar", scalar_gops, "GOPS");
    report.case("cls_threaded_x4", th4, "GOPS");
    report.case("cls_threaded_all", th_all_gops, "GOPS");

    section("fused vs unfused same-input dispatch (7 vs 4 launches per layer, NANO)");
    {
        let case = layer_case(&NANO, 33);
        let gs = NANO.gs;
        let mut counter = CountingExec { inner: ThreadedGqmv::new(pool.clone()), dispatches: 0 };
        counter.inner.min_parallel_macs = 0; // count real pool dispatches
        let unfused_quants = layer_unfused(&mut counter, &case, gs);
        let unfused_dispatches = counter.dispatches;
        counter.dispatches = 0;
        let fused_quants = layer_fused(&mut counter, &case, gs);
        let fused_dispatches = counter.dispatches;
        println!(
            "per layer: {unfused_dispatches} dispatches / {unfused_quants} quantizations \
             unfused  ->  {fused_dispatches} dispatches / {fused_quants} quantizations fused"
        );
        let mut th = ThreadedGqmv::new(pool.clone());
        th.min_parallel_macs = 0;
        let ru = b.run("layer unfused (7 launches)", || {
            layer_unfused(&mut th, &case, gs);
        });
        println!("{}", ru.row());
        let mut th = ThreadedGqmv::new(pool.clone());
        th.min_parallel_macs = 0;
        let rf = b.run("layer fused (4 launches)", || {
            layer_fused(&mut th, &case, gs);
        });
        println!("{}", rf.row());
        let speedup = ru.mean_s / rf.mean_s.max(1e-12);
        println!("fused layer speedup: {speedup:.3}x");
        report.case("layer_dispatches_unfused", unfused_dispatches as f64, "calls");
        report.case("layer_dispatches_fused", fused_dispatches as f64, "calls");
        report.case("layer_quants_unfused", unfused_quants as f64, "calls");
        report.case("layer_quants_fused", fused_quants as f64, "calls");
        report.case("fused_layer_speedup", speedup, "x");
    }

    section("blocked vs strided row kernel (single-thread, 512x256 g256)");
    {
        let (m, n, gs) = (512usize, 256usize, 256usize);
        let mut rng = Rng::new(5);
        let w = QuantizedTensor::from_f32(&rng.normal_vec(m * n, 0.5), m, n, gs);
        let (xq, xs) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
        let gpr = w.groups_per_row();
        let mut strided = vec![0.0f32; m];
        let rs = b.run("strided per-row loop", || {
            for i in 0..m {
                strided[i] = gqmv_row(
                    &xq,
                    &xs,
                    &w.q[i * n..(i + 1) * n],
                    &w.s[i * gpr..(i + 1) * gpr],
                    gs,
                );
            }
        });
        println!("{}", rs.row());
        let mut blocked = vec![0.0f32; m];
        let rb = b.run("blocked row kernel", || {
            gqmv_rows(&xq, &xs, &w.q, &w.s, gs, &mut blocked);
        });
        println!("{}", rb.row());
        assert_eq!(blocked, strided, "blocked kernel must stay bit-identical");
        let speedup = rs.mean_s / rb.mean_s.max(1e-12);
        println!("blocked speedup: {speedup:.3}x (bit-identical outputs verified)");
        report.case("blocked_row_speedup", speedup, "x");
    }

    section("matrix- vs layer-granular staging: first-matrix availability (NANO uploads)");
    #[cfg(not(feature = "pjrt"))]
    {
        // how long until the FIRST GQMV of a layer can launch: a
        // layer-granular stage uploads all four matrices before anything
        // runs; a matrix-granular stage needs only the QKV block.  The
        // ratio is the latency head-start of --stream-granularity matrix.
        use llamaf::model::QuantModel;
        let qm = QuantModel::synthetic(NANO, 9);
        let rt = llamaf::runtime::Runtime::with_shapes(&[]);
        let layer = &qm.layers[0];
        let rl = b.run("stage full layer (4 uploads)", || {
            std::hint::black_box(rt.upload(&layer.wqkv).unwrap());
            std::hint::black_box(rt.upload(&layer.wo).unwrap());
            std::hint::black_box(rt.upload(&layer.w13).unwrap());
            std::hint::black_box(rt.upload(&layer.w2).unwrap());
        });
        println!("{}", rl.row());
        let rq = b.run("stage first matrix (QKV only)", || {
            std::hint::black_box(rt.upload(&layer.wqkv).unwrap());
        });
        println!("{}", rq.row());
        let head_start = rl.mean_s / rq.mean_s.max(1e-12);
        println!("first-matrix availability: {head_start:.3}x earlier than whole-layer staging");
        report.case("stage_full_layer", rl.mean_s, "s");
        report.case("stage_first_matrix_qkv", rq.mean_s, "s");
        report.case("first_matrix_head_start", head_start, "x");
    }
    #[cfg(feature = "pjrt")]
    println!("(skipped under --features pjrt: uses the sim runtime's with_shapes)");

    section("PJRT kernel path (requires artifacts): upload vs execute split");
    if let Ok(rt) = llamaf::runtime::Runtime::load(std::path::Path::new("artifacts")) {
        let mut rng = Rng::new(7);
        for (m, n) in [(512usize, 256usize), (1536, 256)] {
            let gs = 256;
            let w = QuantizedTensor {
                q: rng.i8_vec(m * n),
                s: (0..m * n / gs).map(|_| rng.next_f32() * 1e-3).collect(),
                rows: m,
                cols: n,
                gs,
                fmt: llamaf::quant::FormatId::Q8,
            };
            let (xq, xs) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
            let mut out = vec![0.0f32; m];
            let up = b.run(&format!("pjrt upload {m}x{n}"), || {
                let dw = rt.upload(&w).unwrap();
                std::hint::black_box(&dw);
            });
            println!("{}", up.row());
            let dw = rt.upload(&w).unwrap();
            let ex = b.run(&format!("pjrt execute {m}x{n}"), || {
                rt.gqmv_device(&dw, &xq, &xs, &mut out).unwrap();
            });
            println!("{}  -> {:.3} GOPS", ex.row(), 2.0 * (m * n) as f64 / ex.mean_s / 1e9);
        }
    } else {
        println!("(skipped: run `make artifacts`)");
    }

    section("dataflow simulator functional throughput (host-side cost of simulation)");
    let mut sim = DataflowSim::new(PlConfig::default());
    let sim_gops = bench_backend(&mut sim, 512, 256, 256, &b);
    println!(
        "simulated PL time for those calls: {:.3} ms ({:.3} simulated GOPS)",
        sim.simulated_time_s() * 1e3,
        sim.achieved_gops()
    );
    report.case("dataflow_sim_host", sim_gops, "GOPS");
    match report.write() {
        Ok(p) => eprintln!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
