//! Batched-decoding macro-benchmark: aggregate throughput and weight
//! staging volume of the step-synchronous `BatchScheduler` as batch size
//! grows.
//!
//! The interesting column is `staged B/tok`: one layer walk per step is
//! shared by all B lanes, so bytes staged per decoded token should fall
//! ~B× versus B independent passes (the paper's DDR-bandwidth bound,
//! §III-B, attacked at serving scale).  Aggregate tok/s rises both from
//! the staging amortization and from the batched GQMV reusing each
//! weight row across lanes while it is cache-hot.
//!
//! Run: `cargo bench --bench batch_decode [-- --quick]`
//! (NANO geometry; TinyLlama-1.1B synthetic weights need ~1.1 GB and are
//! left to `table6_inference`.)

use std::sync::{Arc, Barrier};
use std::time::Instant;

use llamaf::bench::section;
use llamaf::engine::batch::{BatchOpts, BatchScheduler};
use llamaf::engine::session::Session;
use llamaf::model::{QuantModel, NANO};
use llamaf::ps::ScalarGqmv;

/// Decode `b` concurrent lanes of `steps` tokens at staging-ring depth
/// `prefetch_depth`; returns (aggregate tok/s, staged bytes/token, mean
/// lane occupancy, mean ring occupancy).
fn run_batch(
    model: &Arc<QuantModel>,
    b: usize,
    steps: usize,
    prefetch_depth: usize,
) -> (f64, f64, f64, f64) {
    let sched = BatchScheduler::new(
        Arc::clone(model),
        Box::new(ScalarGqmv),
        BatchOpts { max_batch: b, prefetch_depth, ..Default::default() },
    );
    let barrier = Arc::new(Barrier::new(b + 1));
    let handles: Vec<_> = (0..b)
        .map(|i| {
            let sched = Arc::clone(&sched);
            let model = Arc::clone(model);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let prompt = [1u32, (i as u32 % 60) + 2, 7];
                let (sess, out) =
                    sched.generate(Session::new(&model.cfg), &prompt, steps, |_, _| Ok(()));
                assert!(sess.is_some(), "session lost");
                out.expect("generation failed").generated.len()
            })
        })
        .collect();
    barrier.wait();
    let t = Instant::now();
    let tokens: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let dt = t.elapsed().as_secs_f64();
    let bpt = sched.metrics().bytes_per_token();
    let occ = sched.metrics().occupancy_mean();
    let ring = sched.metrics().ring_occupancy();
    sched.shutdown();
    (tokens as f64 / dt.max(1e-9), bpt, occ, ring)
}

fn main() {
    let smoke = llamaf::bench::smoke();
    let quick = std::env::args().any(|a| a == "--quick") || smoke;
    let steps = if smoke {
        8
    } else if quick {
        16
    } else {
        64
    };
    let model = Arc::new(QuantModel::synthetic(NANO, 42));
    let mut report = llamaf::bench::Report::new("batch_decode");

    section("step-synchronous batched decoding (NANO geometry, scalar GQMV)");
    println!("{steps} steps/lane, async weight streaming, one decode thread\n");
    let mut base_bpt = 0.0f64;
    for b in [1usize, 2, 4, 8] {
        let (tps, bpt, occ, ring) = run_batch(&model, b, steps, 2);
        if b == 1 {
            base_bpt = bpt;
        }
        let reduction = if bpt > 0.0 { base_bpt / bpt } else { 0.0 };
        println!(
            "B={b:<2}  mean_occupancy {occ:>5.2}  aggregate {tps:>9.1} tok/s  \
             staged {bpt:>12.0} B/tok  reduction {reduction:>5.2}x  ring_occ {ring:>4.2}"
        );
        report.case(&format!("B{b}_aggregate"), tps, "tok/s");
        report.case(&format!("B{b}_staged"), bpt, "B/tok");
        report.case(&format!("B{b}_ring_occ"), ring, "layers");
    }
    println!(
        "\n(reduction ≈ mean occupancy: each step stages every layer once, shared by B lanes)"
    );

    section("staging-ring depth sweep at B=4 (--prefetch-depth analogue)");
    for depth in [1usize, 2, 4] {
        let (tps, _bpt, _occ, ring) = run_batch(&model, 4, steps, depth);
        println!("depth={depth}  aggregate {tps:>9.1} tok/s  ring_occ {ring:>4.2}");
        report.case(&format!("depth{depth}_aggregate"), tps, "tok/s");
        report.case(&format!("depth{depth}_ring_occ"), ring, "layers");
    }
    println!("\n(ring_occ > 0 at depth >= 2: the prefetch pipeline genuinely runs ahead)");
    match report.write() {
        Ok(p) => eprintln!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
