//! Batched-decoding macro-benchmark: aggregate throughput and weight
//! staging volume of the step-synchronous `BatchScheduler` as batch size
//! grows, plus the staging-granularity sweep of the sub-layer pipeline.
//!
//! The interesting column is `staged B/tok`: one layer walk per step is
//! shared by all B lanes, so bytes staged per decoded token should fall
//! ~B× versus B independent passes (the paper's DDR-bandwidth bound,
//! §III-B, attacked at serving scale).  Aggregate tok/s rises both from
//! the staging amortization and from the batched GQMV reusing each
//! weight row across lanes while it is cache-hot.
//!
//! The granularity sweep drives a `Streamer` directly against a
//! simulated-DDR fetcher (per-byte transfer delay) and compares
//! `--stream-granularity layer` vs `matrix` at depths 2 and 4: matrix
//! granularity should slash the wait attributed to each layer's FIRST
//! matrix (the transfer gating its first GQMV) while keeping overall
//! overlap, because chunk *k+1* streams while chunk *k* computes.
//!
//! Run: `cargo bench --bench batch_decode [-- --quick]`
//! (NANO geometry; TinyLlama-1.1B synthetic weights need ~1.1 GB and are
//! left to `table6_inference`.)

use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use anyhow::Result;
use llamaf::bench::section;
use llamaf::engine::batch::{Admission, BatchOpts, BatchScheduler};
use llamaf::engine::session::Session;
use llamaf::model::{LayerChunk, MatrixUnit, QuantLayer, QuantModel, MATRIX_UNITS, NANO};
use llamaf::ps::ScalarGqmv;
use llamaf::runtime::Runtime;
use llamaf::sched::{LayerFetcher, SchedMode, StageGranularity, Streamer, StreamerStats};

/// Decode `b` concurrent lanes of `steps` tokens at staging-ring depth
/// `prefetch_depth` and granularity `gran`; returns (aggregate tok/s,
/// staged bytes/token, mean lane occupancy, mean ring occupancy, staging
/// MB/s).
fn run_batch(
    model: &Arc<QuantModel>,
    b: usize,
    steps: usize,
    prefetch_depth: usize,
    gran: StageGranularity,
) -> (f64, f64, f64, f64, f64) {
    let sched = BatchScheduler::new(
        Arc::clone(model),
        Box::new(ScalarGqmv),
        BatchOpts { max_batch: b, prefetch_depth, granularity: gran, ..Default::default() },
    );
    let barrier = Arc::new(Barrier::new(b + 1));
    let handles: Vec<_> = (0..b)
        .map(|i| {
            let sched = Arc::clone(&sched);
            let model = Arc::clone(model);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let prompt = [1u32, (i as u32 % 60) + 2, 7];
                let (sess, out) =
                    sched.generate(Session::new(&model.cfg), &prompt, steps, |_, _| Ok(()));
                assert!(sess.is_some(), "session lost");
                out.expect("generation failed").generated.len()
            })
        })
        .collect();
    barrier.wait();
    let t = Instant::now();
    let tokens: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let dt = t.elapsed().as_secs_f64();
    let bpt = sched.metrics().bytes_per_token();
    let occ = sched.metrics().occupancy_mean();
    let ring = sched.metrics().ring_occupancy();
    let mbs = sched.metrics().stage_mb_s();
    sched.shutdown();
    (tokens as f64 / dt.max(1e-9), bpt, occ, ring, mbs)
}

/// Ragged-arrival workload: 8 lanes with staggered submit times and
/// uneven step budgets through a max_batch=4 scheduler under the given
/// admission policy.  Returns (mean lane occupancy, staged bytes/token,
/// aggregate tok/s) — the A/B that motivates continuous admission: drain
/// mode leaves slots empty while stragglers finish, continuous refills
/// them the step a request arrives.
fn run_ragged(model: &Arc<QuantModel>, admission: Admission, steps: usize) -> (f64, f64, f64) {
    let sched = BatchScheduler::new(
        Arc::clone(model),
        Box::new(ScalarGqmv),
        BatchOpts { max_batch: 4, admission, ..Default::default() },
    );
    let t0 = Instant::now();
    let handles: Vec<_> = (0..8usize)
        .map(|i| {
            let sched = Arc::clone(&sched);
            let model = Arc::clone(model);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(i as u64 * 5));
                let prompt = [1u32, (i as u32 % 60) + 2, 7];
                let lane_steps = steps + (i % 3) * steps / 2;
                let (sess, out) =
                    sched.generate(Session::new(&model.cfg), &prompt, lane_steps, |_, _| Ok(()));
                assert!(sess.is_some(), "session lost");
                out.expect("generation failed").generated.len()
            })
        })
        .collect();
    let tokens: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let dt = t0.elapsed().as_secs_f64();
    let occ = sched.metrics().occupancy_mean();
    let bpt = sched.metrics().bytes_per_token();
    sched.shutdown();
    (occ, bpt, tokens as f64 / dt.max(1e-9))
}

/// Simulated-DDR fetcher: every fetch costs wall-clock time proportional
/// to the bytes moved, so staging waits behave like a bandwidth-bound
/// off-chip memory instead of a free memcpy.
struct DdrFetcher {
    layers: Arc<Vec<QuantLayer>>,
    ns_per_byte: f64,
}

impl DdrFetcher {
    fn stall(&self, bytes: usize) {
        std::thread::sleep(Duration::from_nanos((bytes as f64 * self.ns_per_byte) as u64));
    }
}

impl LayerFetcher for DdrFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        let lay = self.layers[layer].clone();
        self.stall(lay.stream_bytes());
        Ok(lay)
    }

    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn fetch_chunk(&mut self, layer: usize, unit: MatrixUnit) -> Result<LayerChunk> {
        let chunk = self.layers[layer].chunk(unit);
        self.stall(chunk.stream_bytes());
        Ok(chunk)
    }
}

/// Walk `tokens` full layer sweeps through a streamer over the simulated
/// DDR, modeling per-matrix kernel time with a sleep, and return the
/// staging counters.
fn run_ddr_stream(
    layers: &Arc<Vec<QuantLayer>>,
    gran: StageGranularity,
    depth: usize,
    tokens: usize,
    ns_per_byte: f64,
    compute_per_mat: Duration,
) -> StreamerStats {
    let rt = Arc::new(Runtime::with_shapes(&[]));
    let fetcher = DdrFetcher { layers: Arc::clone(layers), ns_per_byte };
    let mut st = Streamer::with_opts(rt, fetcher, SchedMode::Async, depth, gran).unwrap();
    let n = layers.len();
    for _tok in 0..tokens {
        for li in 0..n {
            for u in MATRIX_UNITS {
                st.unit(li, u).unwrap();
                if u != MatrixUnit::Norms {
                    std::thread::sleep(compute_per_mat); // the GQMV this chunk feeds
                }
            }
        }
    }
    let stats = st.stats;
    st.shutdown();
    stats
}

fn main() {
    let smoke = llamaf::bench::smoke();
    let quick = std::env::args().any(|a| a == "--quick") || smoke;
    let steps = if smoke {
        8
    } else if quick {
        16
    } else {
        64
    };
    let model = Arc::new(QuantModel::synthetic(NANO, 42));
    let mut report = llamaf::bench::Report::new("batch_decode");

    section("step-synchronous batched decoding (NANO geometry, scalar GQMV)");
    println!("{steps} steps/lane, async weight streaming, one decode thread\n");
    let mut base_bpt = 0.0f64;
    for b in [1usize, 2, 4, 8] {
        let (tps, bpt, occ, ring, _mbs) = run_batch(&model, b, steps, 2, StageGranularity::Layer);
        if b == 1 {
            base_bpt = bpt;
        }
        let reduction = if bpt > 0.0 { base_bpt / bpt } else { 0.0 };
        println!(
            "B={b:<2}  mean_occupancy {occ:>5.2}  aggregate {tps:>9.1} tok/s  \
             staged {bpt:>12.0} B/tok  reduction {reduction:>5.2}x  ring_occ {ring:>4.2}"
        );
        report.case(&format!("B{b}_aggregate"), tps, "tok/s");
        report.case(&format!("B{b}_staged"), bpt, "B/tok");
        report.case(&format!("B{b}_ring_occ"), ring, "layers");
    }
    println!(
        "\n(reduction ≈ mean occupancy: each step stages every layer once, shared by B lanes)"
    );

    section("quant-format sweep at B=4 (--quant-format analogue: bytes per token)");
    {
        use llamaf::quant::FormatId;
        let mut bpt_by_fmt = Vec::new();
        for fmt in FormatId::ALL {
            let m = Arc::new(QuantModel::synthetic_fmt(NANO, 42, fmt));
            let (tps, bpt, _occ, _ring, mbs) = run_batch(&m, 4, steps, 2, StageGranularity::Layer);
            println!(
                "format={:<5}  aggregate {tps:>9.1} tok/s  staged {bpt:>12.0} B/tok  \
                 staging {mbs:>8.1} MB/s",
                fmt.name()
            );
            report.case(&format!("fmt_{}_aggregate", fmt.name()), tps, "tok/s");
            report.case(&format!("fmt_{}_bytes_per_token", fmt.name()), bpt, "B/tok");
            bpt_by_fmt.push(bpt);
        }
        println!(
            "\n(a Q4_0 wire group is GS/2+4 bytes against Q8's GS+4: at GS=256 the staged \
             bytes per token drop to {:.2}x of INT8)",
            bpt_by_fmt[1] / bpt_by_fmt[0].max(1e-9)
        );
    }

    section("ragged arrivals: continuous vs drain admission (B=4, staggered joins)");
    println!("8 lanes, 5 ms arrival stagger, uneven step budgets\n");
    let mut occ_by_policy = [0.0f64; 2];
    for (pi, (label, adm)) in
        [("continuous", Admission::Continuous), ("drain", Admission::Drain)].iter().enumerate()
    {
        let (occ, bpt, tps) = run_ragged(&model, *adm, steps);
        occ_by_policy[pi] = occ;
        println!(
            "admission={label:<10}  mean_occupancy {occ:>5.2}  staged {bpt:>12.0} B/tok  \
             aggregate {tps:>9.1} tok/s"
        );
        report.case(&format!("ragged_{label}_occupancy"), occ, "lanes");
        report.case(&format!("ragged_{label}_staged"), bpt, "B/tok");
        report.case(&format!("ragged_{label}_aggregate"), tps, "tok/s");
    }
    println!(
        "\n(continuous admission refills freed slots the step a request arrives; drain \
         leaves them empty until the whole batch retires: occupancy {:.2} vs {:.2})",
        occ_by_policy[0], occ_by_policy[1]
    );

    section("staging-ring depth sweep at B=4 (--prefetch-depth analogue)");
    for depth in [1usize, 2, 4] {
        let (tps, _bpt, _occ, ring, _mbs) =
            run_batch(&model, 4, steps, depth, StageGranularity::Layer);
        println!("depth={depth}  aggregate {tps:>9.1} tok/s  ring_occ {ring:>4.2}");
        report.case(&format!("depth{depth}_aggregate"), tps, "tok/s");
        report.case(&format!("depth{depth}_ring_occ"), ring, "layers");
    }
    println!("\n(ring_occ > 0 at depth >= 2: the prefetch pipeline genuinely runs ahead)");

    section("stream-granularity sweep at B=4 (--stream-granularity analogue)");
    for gran in [StageGranularity::Layer, StageGranularity::Matrix] {
        for depth in [2usize, 4] {
            let (tps, _bpt, _occ, _ring, mbs) = run_batch(&model, 4, steps, depth, gran);
            println!(
                "granularity={:<6} depth={depth}  aggregate {tps:>9.1} tok/s  \
                 staging {mbs:>8.1} MB/s",
                gran.label()
            );
            report.case(&format!("sched_{}_d{depth}_aggregate", gran.label()), tps, "tok/s");
            report.case(&format!("sched_{}_d{depth}_stage_mb_s", gran.label()), mbs, "MB/s");
        }
    }

    section("sub-layer overlap under simulated DDR (first-matrix wait, layer vs matrix)");
    {
        // a bandwidth-bound regime: transfer > compute per layer, so the
        // schedule CANNOT hide everything — what matrix granularity
        // changes is WHERE the unavoidable wait lands (spread over the
        // five chunks instead of all gating the first matrix)
        let layers = Arc::new(QuantModel::synthetic(NANO, 7).layers);
        let tokens = 2;
        let ns_per_byte = 5.0; // ~4 ms per NANO layer
        let compute = Duration::from_micros(300); // ~1.2 ms per layer
        for gran in [StageGranularity::Layer, StageGranularity::Matrix] {
            for depth in [2usize, 4] {
                let stats = run_ddr_stream(&layers, gran, depth, tokens, ns_per_byte, compute);
                let overlap = if stats.total_transfer_s > 0.0 {
                    1.0 - (stats.blocked_transfer_s / stats.total_transfer_s).min(1.0)
                } else {
                    0.0
                };
                // the wait gating each layer's first GQMV: norms + QKV
                let first_wait_ms = 1e3 * (stats.wait_by_unit_s[0] + stats.wait_by_unit_s[1]);
                println!(
                    "granularity={:<6} depth={depth}  overlap {overlap:>5.2}  \
                     first-matrix wait {first_wait_ms:>8.2} ms  stage {:>6.1} MB/s",
                    gran.label(),
                    stats.stage_mb_s()
                );
                let tag = format!("ddr_{}_d{depth}", gran.label());
                report.case(&format!("{tag}_overlap"), overlap, "ratio");
                report.case(&format!("{tag}_first_mat_wait"), first_wait_ms, "ms");
                report.case(&format!("{tag}_stage_mb_s"), stats.stage_mb_s(), "MB/s");
            }
        }
        println!(
            "\n(matrix granularity: the first-matrix wait drops because a layer's tail \
             chunks stream while its head computes)"
        );
    }

    match report.write() {
        Ok(p) => eprintln!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
