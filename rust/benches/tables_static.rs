//! Bench target regenerating the static tables: I (weight specs),
//! III (resource utilization), IV (quantization error), V (PPL).

use llamaf::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&argv).expect("args");
    let mut report = llamaf::bench::Report::new("tables_static");
    let mut timed = |name: &str, run: &dyn Fn() -> anyhow::Result<()>| {
        let t = std::time::Instant::now();
        run().expect(name);
        report.case(name, t.elapsed().as_secs_f64(), "s");
    };
    timed("table1", &|| llamaf::exp::table1::run(&args));
    timed("table3", &|| llamaf::exp::table3::run(&args));
    timed("table4", &|| llamaf::exp::table4::run(&args));
    timed("table5", &|| llamaf::exp::table5::run(&args));
    match report.write() {
        Ok(p) => eprintln!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
