//! Bench target regenerating the static tables: I (weight specs),
//! III (resource utilization), IV (quantization error), V (PPL).

use llamaf::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&argv).expect("args");
    llamaf::exp::table1::run(&args).expect("table1");
    llamaf::exp::table3::run(&args).expect("table3");
    llamaf::exp::table4::run(&args).expect("table4");
    llamaf::exp::table5::run(&args).expect("table5");
}
