//! Gateway scale-out macro-benchmark: aggregate streaming throughput of
//! one `llamaf gateway` front as the replica pool grows 1 → 2 → 3.
//!
//! Each replica is a full `serve_shared` engine (NANO geometry, scalar
//! GQMV, continuous batching at max_batch=4), so a single replica
//! saturates at ~4 concurrent decode lanes.  The client swarm offers 3×
//! that concurrency; adding replicas should then scale aggregate tok/s
//! near-linearly, because the gateway's least-loaded routing spreads the
//! swarm across pools of lanes while each stream stays pinned to one
//! replica (sticky sessions keep KV local).  The gap from perfect
//! scaling is the gateway's proxy overhead plus batching edge effects.
//!
//! Run: `cargo bench --bench gateway [-- --quick]`
//! (synthetic weights; no artifacts required)

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use llamaf::bench::section;
use llamaf::model::{QuantModel, NANO};
use llamaf::ps::gqmv::GqmvExec;
use llamaf::ps::ScalarGqmv;
use llamaf::server::gateway::{Gateway, GatewayOpts};
use llamaf::server::{ServeOpts, Server};

fn scalar_exec() -> Box<dyn GqmvExec + Send> {
    Box::new(ScalarGqmv)
}

/// Send `SHUTDOWN` and wait for the ack.
fn shutdown(addr: SocketAddr) {
    if let Ok(mut conn) = TcpStream::connect(addr) {
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let _ = conn.write_all(b"SHUTDOWN\n");
        let mut line = String::new();
        let _ = reader.read_line(&mut line);
        let _ = conn.write_all(b"QUIT\n");
    }
}

/// Drive `clients` concurrent connections through a gateway fronting
/// `n_replicas` engine replicas; each client streams `reqs` generations
/// of `steps` tokens.  Returns aggregate tok/s over the whole swarm.
fn run_pool(
    model: &Arc<QuantModel>,
    n_replicas: usize,
    clients: usize,
    reqs: usize,
    steps: usize,
) -> f64 {
    let vocab = model.cfg.vocab_size;
    let mut replica_addrs = Vec::new();
    let mut replica_threads = Vec::new();
    for _ in 0..n_replicas {
        let server = Server::bind("127.0.0.1:0", vocab).unwrap();
        replica_addrs.push(server.local_addr().unwrap());
        let model = Arc::clone(model);
        replica_threads.push(std::thread::spawn(move || {
            let opts = ServeOpts {
                workers: 16,
                queue_depth: 64,
                max_sessions: 16,
                max_batch: 4,
                ..Default::default()
            };
            server.serve_shared(model, &scalar_exec, &opts, None).unwrap()
        }));
    }

    let gw = Gateway::bind("127.0.0.1:0").unwrap();
    let gw_addr = gw.local_addr().unwrap();
    let opts = GatewayOpts {
        backends: replica_addrs.iter().map(|a| a.to_string()).collect(),
        workers: 16,
        queue_depth: 64,
        max_queue: 16,
        ..Default::default()
    };
    let gw_thread = std::thread::spawn(move || gw.run(&opts, None).unwrap());

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|ci| {
            std::thread::spawn(move || -> usize {
                let mut conn = TcpStream::connect(gw_addr).unwrap();
                let mut reader = BufReader::new(conn.try_clone().unwrap());
                let mut tokens = 0usize;
                for ri in 0..reqs {
                    conn.write_all(format!("SGEN {steps} swarm {ci} {ri}\n").as_bytes())
                        .unwrap();
                    loop {
                        let mut line = String::new();
                        reader.read_line(&mut line).unwrap();
                        let line = line.trim_end();
                        if line.starts_with("TOK ") {
                            tokens += 1;
                        } else if line.starts_with("DONE ") {
                            break;
                        } else {
                            panic!("client {ci}: unexpected line {line:?}");
                        }
                    }
                }
                conn.write_all(b"QUIT\n").unwrap();
                tokens
            })
        })
        .collect();
    let tokens: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(tokens, clients * reqs * steps, "swarm lost tokens");

    shutdown(gw_addr);
    let report = gw_thread.join().unwrap();
    assert_eq!(report.in_flight_at_exit, 0, "gateway queues did not drain");
    for (addr, t) in replica_addrs.into_iter().zip(replica_threads) {
        shutdown(addr);
        let rep = t.join().unwrap();
        assert_eq!(rep.busy_at_exit, 0, "replica session leaked");
    }
    tokens as f64 / dt.max(1e-9)
}

fn main() {
    let smoke = llamaf::bench::smoke();
    let quick = std::env::args().any(|a| a == "--quick") || smoke;
    let (clients, reqs, steps) = if smoke {
        (6, 1, 8)
    } else if quick {
        (9, 2, 16)
    } else {
        (12, 3, 32)
    };
    let model = Arc::new(QuantModel::synthetic(NANO, 42));
    let mut report = llamaf::bench::Report::new("gateway");

    section("replica scaling through one gateway (NANO geometry, scalar GQMV)");
    println!(
        "{clients} clients x {reqs} requests x {steps} steps, max_batch=4 per replica, \
         least-loaded sticky routing\n"
    );
    let mut base = 0.0f64;
    for n in [1usize, 2, 3] {
        let tps = run_pool(&model, n, clients, reqs, steps);
        if n == 1 {
            base = tps;
        }
        let speedup = if base > 0.0 { tps / base } else { 0.0 };
        println!("replicas={n}  aggregate {tps:>9.1} tok/s  speedup {speedup:>5.2}x");
        report.case(&format!("scaling_{n}_tok_s"), tps, "tok/s");
    }
    println!(
        "\n(the swarm offers ~3x one replica's lane capacity, so tok/s should grow \
         near-linearly with the pool; the shortfall is proxy overhead + batching edges)"
    );

    match report.write() {
        Ok(p) => eprintln!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
