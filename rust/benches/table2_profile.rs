//! Bench target regenerating Table II (forward-pass runtime distribution).
//!
//!     cargo bench --bench table2_profile [-- --geometry tinyllama]

use llamaf::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&argv).expect("args");
    llamaf::exp::table2::run(&args).expect("table2");
}
