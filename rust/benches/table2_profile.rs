//! Bench target regenerating Table II (forward-pass runtime distribution).
//!
//!     cargo bench --bench table2_profile [-- --geometry tinyllama]

use llamaf::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&argv).expect("args");
    let mut report = llamaf::bench::Report::new("table2_profile");
    let t = std::time::Instant::now();
    llamaf::exp::table2::run(&args).expect("table2");
    report.case("table2", t.elapsed().as_secs_f64(), "s");
    match report.write() {
        Ok(p) => eprintln!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
