//! Tracing-overhead micro-benchmark: decode throughput with per-op
//! execution tracing (`--trace` / `Engine::trace_start`) off vs on.
//!
//! Tracing hashes every GQMV output vector (FNV-1a over the f32 bits)
//! and appends one 24-byte event per op, so the cost scales with
//! activation volume, not weight volume — it should be a small, flat
//! tax per decoded token.  The `trace_cost_ms_per_tok` case pins that
//! tax so `bench-diff` catches an accidentally-hot capture path (e.g.
//! hashing inside the disabled branch).
//!
//! Run: `cargo bench --bench trace_overhead [-- --quick]`

use llamaf::bench::section;
use llamaf::engine::forward::{CpuEngine, Engine};
use llamaf::engine::generate::{generate, Sampler};
use llamaf::model::{QuantModel, NANO};
use llamaf::ps::ScalarGqmv;

/// Greedy-decode `steps` tokens and return tok/s; with `traced` the
/// engine records (and this fn discards) a full execution trace.
fn decode_tok_s(engine: &mut CpuEngine, steps: usize, traced: bool) -> f64 {
    if traced {
        assert!(engine.trace_start("bench"), "CpuEngine must support tracing");
    }
    let out = generate(engine, &[1u32, 5, 9], steps, Sampler::Greedy, false)
        .expect("bench generation failed");
    if traced {
        let t = engine.trace_take().expect("tracing enabled but no trace produced");
        assert!(!t.is_empty(), "traced run recorded no ops");
    }
    out.tok_per_s
}

fn main() {
    let smoke = llamaf::bench::smoke();
    let quick = std::env::args().any(|a| a == "--quick") || smoke;
    let steps = if smoke {
        8
    } else if quick {
        16
    } else {
        64
    };
    let reps = if smoke { 2 } else { 3 };
    let mut engine = CpuEngine::new(QuantModel::synthetic(NANO, 42), Box::new(ScalarGqmv));
    let mut report = llamaf::bench::Report::new("trace_overhead");

    section("per-op execution tracing overhead (NANO geometry, scalar GQMV)");
    println!("{steps} greedy decode steps, best of {reps} runs per mode\n");

    // interleave warmup: one throwaway run per mode so neither mode pays
    // first-touch costs alone
    decode_tok_s(&mut engine, steps, false);
    decode_tok_s(&mut engine, steps, true);

    let mut best_off = 0.0f64;
    let mut best_on = 0.0f64;
    for _ in 0..reps {
        best_off = best_off.max(decode_tok_s(&mut engine, steps, false));
        best_on = best_on.max(decode_tok_s(&mut engine, steps, true));
    }
    // per-token cost of tracing: the latency delta, not the ratio, since
    // the absolute tax is what capture-path regressions move
    let cost_ms = if best_off > 0.0 && best_on > 0.0 {
        (1e3 / best_on - 1e3 / best_off).max(0.0)
    } else {
        0.0
    };
    let pct = if best_off > 0.0 { 100.0 * (1.0 - best_on / best_off).max(0.0) } else { 0.0 };
    println!("trace off  {best_off:>9.1} tok/s");
    println!("trace on   {best_on:>9.1} tok/s   (+{cost_ms:.3} ms/tok, -{pct:.1}% throughput)");

    report.case("decode_trace_off", best_off, "tok/s");
    report.case("decode_trace_on", best_on, "tok/s");
    report.case("trace_cost", cost_ms, "ms/tok");

    match report.write() {
        Ok(p) => eprintln!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
