//! Bench target regenerating Fig. 2 (sync vs async scheduling), both the
//! paper-scale modeled timeline and a measured nano wall-clock comparison.

use std::path::Path;
use std::sync::Arc;

use llamaf::cli::Args;
use llamaf::engine::forward::Engine;
use llamaf::engine::generate::{generate, Sampler};
use llamaf::engine::llamaf::LlamafEngine;
use llamaf::runtime::Runtime;
use llamaf::sched::SchedMode;
use llamaf::tokenizer::Tokenizer;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&argv).expect("args");
    let mut report = llamaf::bench::Report::new("fig2_sched");
    llamaf::exp::fig2::run(&args).expect("fig2");

    // headline modeled numbers for the JSON artifact (paper-scale Fig. 2)
    let (sync_s, async_s) = llamaf::sched::sim_token_time(
        &llamaf::model::TINYLLAMA_1_1B,
        &llamaf::fpga::PlConfig::default(),
        &llamaf::fpga::AxiModel::default(),
    );
    report.case("modeled_sync_token", sync_s, "s");
    report.case("modeled_async_token", async_s, "s");
    report.case("modeled_gain", sync_s / async_s.max(1e-12), "x");

    // measured: nano engine, sync vs async staging
    let art = Path::new("artifacts");
    let ckpt = art.join("nano_q8.lfq8");
    if !ckpt.exists() {
        println!("\n[measured section skipped: run `make artifacts`]");
        finish(report);
        return;
    }
    println!("\n=== measured on this testbed (nano, PJRT kernels) ===");
    let steps = if llamaf::bench::smoke() { 8 } else { 64 };
    let rt = Arc::new(Runtime::load(art).expect("runtime"));
    for (name, mode) in [("sync", SchedMode::Sync), ("async", SchedMode::Async)] {
        let mut eng = LlamafEngine::open(&ckpt, Arc::clone(&rt), mode).expect("engine");
        let tok = Tokenizer::new(eng.cfg().vocab_size);
        let ids = tok.encode("the engineer builds", true);
        let out = generate(&mut eng, &ids, steps, Sampler::Greedy, false).expect("generate");
        let (total, blocked, n) = eng.transfer_stats();
        println!(
            "  {name:<6} {:.2} tok/s | staging: {n} transfers, {:.1} ms total, {:.1} ms blocking ({:.0}% hidden)",
            out.tok_per_s,
            total * 1e3,
            blocked * 1e3,
            100.0 * (1.0 - blocked / total.max(1e-12)),
        );
        report.case(&format!("measured_{name}"), out.tok_per_s, "tok/s");
    }
    finish(report);
}

/// Write the JSON artifact, logging rather than failing on I/O errors.
fn finish(report: llamaf::bench::Report) {
    match report.write() {
        Ok(p) => eprintln!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
