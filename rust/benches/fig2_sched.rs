//! Bench target regenerating Fig. 2 (sync vs async scheduling), both the
//! paper-scale modeled timeline and a measured nano wall-clock comparison.

use std::path::Path;
use std::sync::Arc;

use llamaf::cli::Args;
use llamaf::engine::forward::Engine;
use llamaf::engine::generate::{generate, Sampler};
use llamaf::engine::llamaf::LlamafEngine;
use llamaf::runtime::Runtime;
use llamaf::sched::SchedMode;
use llamaf::tokenizer::Tokenizer;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&argv).expect("args");
    llamaf::exp::fig2::run(&args).expect("fig2");

    // measured: nano engine, sync vs async staging
    let art = Path::new("artifacts");
    let ckpt = art.join("nano_q8.lfq8");
    if !ckpt.exists() {
        println!("\n[measured section skipped: run `make artifacts`]");
        return;
    }
    println!("\n=== measured on this testbed (nano, PJRT kernels) ===");
    let rt = Arc::new(Runtime::load(art).expect("runtime"));
    for (name, mode) in [("sync", SchedMode::Sync), ("async", SchedMode::Async)] {
        let mut eng = LlamafEngine::open(&ckpt, Arc::clone(&rt), mode).expect("engine");
        let tok = Tokenizer::new(eng.cfg().vocab_size);
        let ids = tok.encode("the engineer builds", true);
        let out = generate(&mut eng, &ids, 64, Sampler::Greedy, false).expect("generate");
        let (total, blocked, n) = eng.transfer_stats();
        println!(
            "  {name:<6} {:.2} tok/s | staging: {n} transfers, {:.1} ms total, {:.1} ms blocking ({:.0}% hidden)",
            out.tok_per_s,
            total * 1e3,
            blocked * 1e3,
            100.0 * (1.0 - blocked / total.max(1e-12)),
        );
    }
}
