//! Bench target regenerating Table VI (inference speed & power): the
//! paper-scale model plus the measured nano end-to-end rows.
//!
//!     cargo bench --bench table6_inference

use llamaf::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&argv).expect("args");
    llamaf::exp::table6::run(&args).expect("table6");
}
