//! Bench target regenerating Table VI (inference speed & power): the
//! paper-scale model plus the measured nano end-to-end rows.
//!
//!     cargo bench --bench table6_inference

use llamaf::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&argv).expect("args");
    let mut report = llamaf::bench::Report::new("table6_inference");
    let t = std::time::Instant::now();
    llamaf::exp::table6::run(&args).expect("table6");
    report.case("table6", t.elapsed().as_secs_f64(), "s");
    match report.write() {
        Ok(p) => eprintln!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
