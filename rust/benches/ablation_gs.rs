//! Ablation: the group-size (GS) design choice.
//!
//! The paper picks GS=256 "based on its compatibility with the
//! dimensional parameters of TinyLlama".  This ablation quantifies the
//! trade-off GS controls across four axes: quantization accuracy
//! (Table IV), model size, PL bandwidth (scale traffic), and DSP cost
//! of the GS-wide SIMD dot-product stage (Table III).

use llamaf::exp::table4::stats_for_model;
use llamaf::fpga::{PlConfig, ResourceModel};
use llamaf::model::{FloatModel, LlamaConfig, NANO, TINYLLAMA_1_1B};

fn main() {
    let mut report = llamaf::bench::Report::new("ablation_gs");
    println!("=== GS ablation (nano weights for error; TinyLlama geometry for HW) ===\n");
    println!(
        "  {:>5} {:>10} {:>10} {:>12} {:>10} {:>12} {:>12}",
        "GS", "err% mean", "err% std", "q8 size MB", "PL GOPS", "DSP util%", "layer MB"
    );
    // smoke mode keeps one error-sweep GS and one hardware-only GS so the
    // full code path still runs without quantizing four nano models
    let gs_list: &[usize] =
        if llamaf::bench::smoke() { &[256, 512] } else { &[32, 64, 128, 256, 512] };
    for &gs in gs_list {
        // error stats on a trained-or-synthetic nano float model at this GS
        // (nano's dim=256 caps the error sweep at GS=256; the hardware
        // columns use the TinyLlama geometry where GS=512 is valid)
        let err = if 256 % gs == 0 {
            let cfg = LlamaConfig { gs, ..NANO };
            let fm = match llamaf::ckpt::read_f32_model(std::path::Path::new(
                "artifacts/nano_f32.lfck",
            )) {
                Ok(mut m) => {
                    m.cfg = cfg;
                    m
                }
                Err(_) => FloatModel::random(cfg, 7),
            };
            Some(stats_for_model(&fm))
        } else {
            None
        };
        let (pm, ps) = err
            .map(|st| (format!("{:.2}%", st.pct.mean()), format!("{:.2}%", st.pct.std())))
            .unwrap_or(("-".into(), "-".into()));

        // hardware consequences at TinyLlama geometry
        let tl = LlamaConfig { gs, ..TINYLLAMA_1_1B };
        let pl = PlConfig::default();
        let gops = pl.gops(tl.vocab_size, tl.dim, gs);
        let res = ResourceModel { gs: gs as u64, ..Default::default() };
        let dsp_pct = 100.0 * res.dsp() as f64 / llamaf::fpga::resources::ZCU102_DSP as f64;
        let q8_mb = tl.param_count() as f64 * (1.0 + 4.0 / gs as f64) / 1e6;
        println!(
            "  {:>5} {:>10} {:>10} {:>12.0} {:>10.3} {:>11.2}% {:>12.1}",
            gs,
            pm,
            ps,
            q8_mb,
            gops,
            dsp_pct,
            tl.layer_stream_bytes() as f64 / 1e6,
        );
        report.case(&format!("gs{gs}_pl"), gops, "GOPS");
    }
    println!(
        "\n  reading: smaller GS -> lower quantization error but more scale traffic\n\
         \x20 (lower PL GOPS) and a narrower SIMD stage; GS=256 sits where error has\n\
         \x20 plateaued while DSP cost and bandwidth overhead stay low — the paper's choice."
    );
    match report.write() {
        Ok(p) => eprintln!("bench json: {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
