//! Byte-level tokenizer — exact mirror of `python/compile/corpus.py`.
//!
//! ids: 0 = PAD, 1 = BOS, 2 = EOS, byte b -> b + 3.  The vocab is padded to
//! a GS multiple (512 for nano) so the classifier matrix stays GQMV-able.

pub const PAD_ID: u32 = 0;
pub const BOS_ID: u32 = 1;
pub const EOS_ID: u32 = 2;
pub const BYTE_OFFSET: u32 = 3;

/// Byte-level tokenizer with a fixed vocab size.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    pub vocab_size: usize,
}

impl Tokenizer {
    pub fn new(vocab_size: usize) -> Self {
        assert!(vocab_size >= 256 + BYTE_OFFSET as usize);
        Tokenizer { vocab_size }
    }

    pub fn encode(&self, text: &str, bos: bool) -> Vec<u32> {
        let mut ids = Vec::with_capacity(text.len() + 1);
        if bos {
            ids.push(BOS_ID);
        }
        ids.extend(text.bytes().map(|b| b as u32 + BYTE_OFFSET));
        ids
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&i| i >= BYTE_OFFSET && i < 256 + BYTE_OFFSET)
            .map(|&i| (i - BYTE_OFFSET) as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Decode a single token (empty for specials).
    pub fn decode_one(&self, id: u32) -> String {
        self.decode(&[id])
    }

    pub fn is_special(&self, id: u32) -> bool {
        id < BYTE_OFFSET
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = Tokenizer::new(512);
        let text = "the quick fox? 42 _#\n ok";
        let ids = t.encode(text, true);
        assert_eq!(ids[0], BOS_ID);
        assert_eq!(t.decode(&ids), text);
    }

    #[test]
    fn roundtrip_utf8() {
        let t = Tokenizer::new(512);
        let text = "héllo → 世界";
        let ids = t.encode(text, false);
        assert_eq!(ids.len(), text.len()); // bytes, not chars
        assert_eq!(t.decode(&ids), text);
    }

    #[test]
    fn specials_skipped_in_decode() {
        let t = Tokenizer::new(512);
        let ids = vec![BOS_ID, 'h' as u32 + 3, EOS_ID, 'i' as u32 + 3, PAD_ID];
        assert_eq!(t.decode(&ids), "hi");
    }

    #[test]
    fn matches_python_ids() {
        // python: corpus.encode("ab") == [1, 100, 101]
        let t = Tokenizer::new(512);
        assert_eq!(t.encode("ab", true), vec![1, 100, 101]);
    }

    #[test]
    #[should_panic]
    fn too_small_vocab_rejected() {
        Tokenizer::new(128);
    }
}
