//! Per-request observability: a start/finish-delta recorder that rides one
//! lane through the batch scheduler and comes back as a [`RequestTrace`].
//!
//! The decode thread owns shared lifetime counters (staged bytes, prefetch
//! waits, per-matrix-unit waits).  Per-request attribution uses the same
//! delta pattern the scheduler already applies per step: snapshot the
//! counters before a step, subtract after, and charge the *step delta* to
//! every lane that was active in that step.  A step's staged weights serve
//! all its lanes at once, so the same delta is deliberately charged to each
//! — summing `staged_bytes` across concurrent requests over-counts the wire
//! by design (each lane reports the bandwidth *it* observed).

use std::time::Instant;

use crate::metrics::MAT_WAIT_UNITS;

/// Accumulates one request's observability record while its lane lives in
/// the scheduler.  Created at submit time (starting the queue-wait clock),
/// updated once per batched step, and converted with
/// [`TraceBuilder::finish`] when the lane retires.
#[derive(Debug)]
pub struct TraceBuilder {
    id: u64,
    submitted: Instant,
    admitted: bool,
    queue_s: f64,
    prefill_steps: u64,
    decode_steps: u64,
    sched_steps: u64,
    chunk_feeds: u64,
    prefix_tokens: u64,
    prefill_s: f64,
    decode_s: f64,
    staged_bytes: u64,
    prefetch_wait_s: f64,
    unit_wait_s: [f64; MAT_WAIT_UNITS],
    occupancy_sum: u64,
    faults: u64,
}

impl TraceBuilder {
    /// Start the recorder for request `id`; the queue-wait clock starts now.
    pub fn new(id: u64) -> Self {
        TraceBuilder {
            id,
            submitted: Instant::now(),
            admitted: false,
            queue_s: 0.0,
            prefill_steps: 0,
            decode_steps: 0,
            sched_steps: 0,
            chunk_feeds: 0,
            prefix_tokens: 0,
            prefill_s: 0.0,
            decode_s: 0.0,
            staged_bytes: 0,
            prefetch_wait_s: 0.0,
            unit_wait_s: [0.0; MAT_WAIT_UNITS],
            occupancy_sum: 0,
            faults: 0,
        }
    }

    /// The request id this recorder was started for.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Mark the lane admitted into the active set, freezing the queue
    /// wait.  Idempotent: only the first call records, and only the first
    /// call returns the measured wait (so the scheduler can feed its
    /// admission-latency aggregate exactly once per request).
    pub fn admit(&mut self) -> Option<f64> {
        if !self.admitted {
            self.admitted = true;
            self.queue_s = self.submitted.elapsed().as_secs_f64();
            Some(self.queue_s)
        } else {
            None
        }
    }

    /// Record the prompt tokens this request adopted from the page pool's
    /// prefix cache instead of recomputing (0 = cold start).
    pub fn set_prefix_tokens(&mut self, n: u64) {
        self.prefix_tokens = n;
    }

    /// Charge one batched step to this lane.  The step fed
    /// `prefill_feeds` prompt tokens *without* sampling (under chunked
    /// prefill a step may feed several), plus one more feed that sampled
    /// when `produced`; the remaining deltas are the step's shared counter
    /// deltas (see module docs) plus the step's lane occupancy.
    pub fn record_step(
        &mut self,
        prefill_feeds: u64,
        produced: bool,
        wall_s: f64,
        staged_bytes: u64,
        prefetch_wait_s: f64,
        unit_wait_s: [f64; MAT_WAIT_UNITS],
        occupancy: usize,
    ) {
        self.prefill_steps += prefill_feeds;
        if produced {
            self.decode_steps += 1;
            self.decode_s += wall_s;
        } else {
            self.prefill_s += wall_s;
        }
        if prefill_feeds + u64::from(produced) > 1 {
            self.chunk_feeds += 1;
        }
        self.sched_steps += 1;
        self.staged_bytes += staged_bytes;
        self.prefetch_wait_s += prefetch_wait_s;
        for (acc, w) in self.unit_wait_s.iter_mut().zip(unit_wait_s) {
            *acc += w.max(0.0);
        }
        self.occupancy_sum += occupancy as u64;
    }

    /// Count one failed step attempt this lane lived through (the step
    /// was rolled back and retried, or the lane was shed).  A non-zero
    /// count on a *successful* request means it survived faults that were
    /// absorbed by retries.
    pub fn record_fault(&mut self) {
        self.faults += 1;
    }

    /// Snapshot the record as an immutable [`RequestTrace`].  `tok_per_s`
    /// is left at 0; the caller fills it from the lane's `TokenMeter`.
    pub fn finish(&self) -> RequestTrace {
        let steps = self.sched_steps;
        RequestTrace {
            id: self.id,
            queue_s: self.queue_s,
            prefill_steps: self.prefill_steps,
            decode_steps: self.decode_steps,
            chunk_feeds: self.chunk_feeds,
            prefix_tokens: self.prefix_tokens,
            prefill_s: self.prefill_s,
            decode_s: self.decode_s,
            staged_bytes: self.staged_bytes,
            prefetch_wait_s: self.prefetch_wait_s,
            unit_wait_s: self.unit_wait_s,
            batch_mean: if steps == 0 { 0.0 } else { self.occupancy_sum as f64 / steps as f64 },
            tok_per_s: 0.0,
            faults: self.faults,
        }
    }
}

/// One completed request's observability record — what the server returns
/// from the `TRACE` command and folds into the `METRICS` aggregates.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    /// Scheduler-assigned request id (monotonic per scheduler).
    pub id: u64,
    /// Seconds between submit and admission to the first step (queue wait).
    pub queue_s: f64,
    /// Prompt tokens fed without sampling (`prompt_len - 1 -
    /// prefix_tokens`); under chunked prefill one scheduler step may
    /// contribute several.
    pub prefill_steps: u64,
    /// Steps that sampled a token — equals the tokens generated.
    pub decode_steps: u64,
    /// Scheduler steps in which this request fed more than one token
    /// (chunked-prefill multi-lane feeds; 0 at `--prefill-chunk 1`).
    pub chunk_feeds: u64,
    /// Prompt tokens adopted from the page pool's shared-prefix cache
    /// instead of recomputed (0 = cold start or contiguous KV).
    pub prefix_tokens: u64,
    /// Wall seconds of the lane's prefill steps.
    pub prefill_s: f64,
    /// Wall seconds of the lane's decode steps.
    pub decode_s: f64,
    /// Weight bytes the shared streamer staged during the lane's steps
    /// (step deltas; shared with co-resident lanes — see module docs).
    pub staged_bytes: u64,
    /// Visible armed-prefetch wait during the lane's steps (step deltas).
    pub prefetch_wait_s: f64,
    /// Visible staging wait per matrix unit (norms/qkv/wo/w13/w2, step
    /// deltas) — which matrix stalled *this* request.
    pub unit_wait_s: [f64; MAT_WAIT_UNITS],
    /// Mean lanes active in this lane's steps (1.0 = it ran alone).
    pub batch_mean: f64,
    /// End-to-end decode throughput from the lane's `TokenMeter`.
    pub tok_per_s: f64,
    /// Failed step attempts this lane lived through (rolled back and
    /// retried, or shed).  Non-zero on a successful request means the
    /// faults were absorbed by retries.
    pub faults: u64,
}

impl RequestTrace {
    /// One-line `k=v` rendering — the payload of the server's `TRACE`
    /// reply.  Field names and units are documented in
    /// `docs/OBSERVABILITY.md` and pinned by `tests/protocol_stats.rs`.
    pub fn summary(&self) -> String {
        let w = &self.unit_wait_s;
        format!(
            "id={} queue_ms={:.3} prefill_tokens={} decode_tokens={} prefill_ms={:.3} \
             decode_ms={:.3} staged_bytes={} prefetch_wait_ms={:.3} \
             mat_wait_ms={:.3}/{:.3}/{:.3}/{:.3}/{:.3} batch_mean={:.2} tok_s={:.1} \
             chunk_feeds={} prefix_tokens={} faults={}",
            self.id,
            1e3 * self.queue_s,
            self.prefill_steps,
            self.decode_steps,
            1e3 * self.prefill_s,
            1e3 * self.decode_s,
            self.staged_bytes,
            1e3 * self.prefetch_wait_s,
            1e3 * w[0],
            1e3 * w[1],
            1e3 * w[2],
            1e3 * w[3],
            1e3 * w[4],
            self.batch_mean,
            self.tok_per_s,
            self.chunk_feeds,
            self.prefix_tokens,
            self.faults,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_accumulates_and_splits_phases() {
        let mut b = TraceBuilder::new(7);
        assert!(b.admit().is_some(), "first admit returns the measured wait");
        assert!(b.admit().is_none(), "idempotent");
        // 2 prefill steps, 3 decode steps, occupancy 2 throughout
        for _ in 0..2 {
            b.record_step(1, false, 0.010, 100, 0.001, [0.001, 0.0, 0.0, 0.0, 0.0], 2);
        }
        for _ in 0..3 {
            b.record_step(0, true, 0.020, 200, 0.002, [0.0, 0.0, 0.0, 0.003, 0.0], 2);
        }
        let t = b.finish();
        assert_eq!(t.id, 7);
        assert_eq!(t.prefill_steps, 2);
        assert_eq!(t.decode_steps, 3);
        assert_eq!(t.chunk_feeds, 0, "single-token feeds are not chunk feeds");
        assert!((t.prefill_s - 0.020).abs() < 1e-9);
        assert!((t.decode_s - 0.060).abs() < 1e-9);
        assert_eq!(t.staged_bytes, 800);
        assert!((t.prefetch_wait_s - 0.008).abs() < 1e-9);
        assert!((t.unit_wait_s[0] - 0.002).abs() < 1e-9);
        assert!((t.unit_wait_s[3] - 0.009).abs() < 1e-9);
        assert!((t.batch_mean - 2.0).abs() < 1e-9);
    }

    #[test]
    fn chunked_steps_count_feeds_not_steps() {
        // a 7-token prompt fed as chunks of 3+3+1(sampled), then 2 decode
        // steps: prefill_tokens == 6 == prompt_len - 1, decode == 3
        let mut b = TraceBuilder::new(9);
        b.admit();
        b.record_step(3, false, 0.010, 0, 0.0, [0.0; MAT_WAIT_UNITS], 3);
        b.record_step(3, false, 0.010, 0, 0.0, [0.0; MAT_WAIT_UNITS], 3);
        b.record_step(0, true, 0.010, 0, 0.0, [0.0; MAT_WAIT_UNITS], 1);
        b.record_step(0, true, 0.010, 0, 0.0, [0.0; MAT_WAIT_UNITS], 1);
        b.record_step(0, true, 0.010, 0, 0.0, [0.0; MAT_WAIT_UNITS], 1);
        b.set_prefix_tokens(4);
        let t = b.finish();
        assert_eq!(t.prefill_steps, 6);
        assert_eq!(t.decode_steps, 3);
        assert_eq!(t.chunk_feeds, 2, "two multi-token feeds");
        assert_eq!(t.prefix_tokens, 4);
        // batch_mean averages over scheduler steps (5), not feeds (9)
        assert!((t.batch_mean - 9.0 / 5.0).abs() < 1e-9, "{}", t.batch_mean);
    }

    #[test]
    fn summary_carries_every_documented_field() {
        let mut b = TraceBuilder::new(1);
        b.admit();
        b.record_step(1, false, 0.001, 10, 0.0, [0.0; MAT_WAIT_UNITS], 1);
        b.record_step(0, true, 0.002, 10, 0.0, [0.0; MAT_WAIT_UNITS], 1);
        let mut t = b.finish();
        t.tok_per_s = 42.0;
        let s = t.summary();
        for field in [
            "id=1",
            "queue_ms=",
            "prefill_tokens=1",
            "decode_tokens=1",
            "prefill_ms=",
            "decode_ms=",
            "staged_bytes=20",
            "prefetch_wait_ms=",
            "mat_wait_ms=",
            "batch_mean=1.00",
            "tok_s=42.0",
            "chunk_feeds=0",
            "prefix_tokens=0",
            "faults=0",
        ] {
            assert!(s.contains(field), "summary missing {field}: {s}");
        }
        // mat_wait_ms is 5 slash-separated buckets, like STATS
        let mw = s.split_whitespace().find_map(|f| f.strip_prefix("mat_wait_ms=")).unwrap();
        assert_eq!(mw.split('/').count(), 5);
    }

    #[test]
    fn empty_finish_is_all_zero() {
        let t = TraceBuilder::new(0).finish();
        assert_eq!(t.prefill_steps + t.decode_steps, 0);
        assert_eq!(t.batch_mean, 0.0);
        assert_eq!(t.staged_bytes, 0);
        assert_eq!(t.faults, 0);
    }

    #[test]
    fn faults_accumulate_and_render() {
        let mut b = TraceBuilder::new(3);
        b.admit();
        b.record_fault();
        b.record_fault();
        b.record_step(0, true, 0.001, 0, 0.0, [0.0; MAT_WAIT_UNITS], 1);
        let t = b.finish();
        assert_eq!(t.faults, 2);
        assert!(t.summary().contains("faults=2"), "{}", t.summary());
    }
}
