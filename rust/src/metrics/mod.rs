//! Runtime metrics: token throughput, GQMV GOPS accounting, latency
//! histograms — the quantities Table VI reports.

use std::time::Instant;

use crate::util::stats::percentile;

/// Counts GQMV work (the paper's GOPS metric: 2 int ops per MAC, measured
/// on matrix computation only).
#[derive(Clone, Debug, Default)]
pub struct GopsCounter {
    pub macs: u64,
    pub seconds: f64,
}

impl GopsCounter {
    pub fn record(&mut self, rows: usize, cols: usize, seconds: f64) {
        self.macs += (rows * cols) as u64;
        self.seconds += seconds;
    }

    pub fn gops(&self) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            2.0 * self.macs as f64 / self.seconds / 1e9
        }
    }
}

/// Per-token latency recorder -> tok/s + percentiles.
#[derive(Debug)]
pub struct TokenMeter {
    start: Instant,
    last: Instant,
    pub latencies_s: Vec<f64>,
}

impl Default for TokenMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl TokenMeter {
    pub fn new() -> Self {
        let now = Instant::now();
        TokenMeter { start: now, last: now, latencies_s: Vec::new() }
    }

    /// Mark one token produced.
    pub fn tick(&mut self) {
        let now = Instant::now();
        self.latencies_s.push(now.duration_since(self.last).as_secs_f64());
        self.last = now;
    }

    pub fn tokens(&self) -> usize {
        self.latencies_s.len()
    }

    pub fn tok_per_s(&self) -> f64 {
        let total = self.last.duration_since(self.start).as_secs_f64();
        if total == 0.0 {
            0.0
        } else {
            self.tokens() as f64 / total
        }
    }

    pub fn p50_p99(&self) -> (f64, f64) {
        if self.latencies_s.is_empty() {
            return (0.0, 0.0);
        }
        let mut v = self.latencies_s.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (percentile(&v, 50.0), percentile(&v, 99.0))
    }
}

/// Component timing breakdown of a forward pass (Table II rows).
#[derive(Clone, Debug, Default)]
pub struct ForwardProfile {
    pub matrix_s: f64,
    pub attention_s: f64,
    pub swiglu_s: f64,
    pub rope_s: f64,
    pub rmsnorm_s: f64,
    /// quantize + residual + embedding + sampling glue
    pub other_s: f64,
    /// time spent staging weights (transfer; 0 when resident)
    pub transfer_s: f64,
}

impl ForwardProfile {
    pub fn total(&self) -> f64 {
        self.matrix_s + self.attention_s + self.swiglu_s + self.rope_s + self.rmsnorm_s
            + self.other_s
            + self.transfer_s
    }

    /// Percentages over compute components (paper Table II excludes
    /// transfer and glue: it profiles the PS-only run's compute).
    pub fn table2_rows(&self) -> Vec<(&'static str, f64)> {
        let compute =
            self.matrix_s + self.attention_s + self.swiglu_s + self.rope_s + self.rmsnorm_s;
        let pct = |x: f64| if compute == 0.0 { 0.0 } else { 100.0 * x / compute };
        vec![
            ("Matrix Computation", pct(self.matrix_s)),
            ("Multi-head Attention", pct(self.attention_s)),
            ("SwiGLU", pct(self.swiglu_s)),
            ("RoPE", pct(self.rope_s)),
            ("RMSNorm", pct(self.rmsnorm_s)),
        ]
    }

    pub fn merge(&mut self, o: &ForwardProfile) {
        self.matrix_s += o.matrix_s;
        self.attention_s += o.attention_s;
        self.swiglu_s += o.swiglu_s;
        self.rope_s += o.rope_s;
        self.rmsnorm_s += o.rmsnorm_s;
        self.other_s += o.other_s;
        self.transfer_s += o.transfer_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gops_math() {
        let mut g = GopsCounter::default();
        g.record(1000, 1000, 0.001);
        // 2 * 1e6 MACs / 1e-3 s = 2e9 ops/s = 2 GOPS
        assert!((g.gops() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn token_meter_counts() {
        let mut m = TokenMeter::new();
        for _ in 0..5 {
            m.tick();
        }
        assert_eq!(m.tokens(), 5);
        assert!(m.tok_per_s() > 0.0);
        let (p50, p99) = m.p50_p99();
        assert!(p50 <= p99);
    }

    #[test]
    fn table2_percentages_sum_to_100() {
        let p = ForwardProfile {
            matrix_s: 0.97,
            attention_s: 0.02,
            swiglu_s: 0.005,
            rope_s: 0.003,
            rmsnorm_s: 0.002,
            other_s: 0.5, // excluded
            transfer_s: 0.3,
        };
        let sum: f64 = p.table2_rows().iter().map(|(_, v)| v).sum();
        assert!((sum - 100.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds() {
        let mut a = ForwardProfile { matrix_s: 1.0, ..Default::default() };
        let b = ForwardProfile { matrix_s: 2.0, attention_s: 0.5, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.matrix_s, 3.0);
        assert_eq!(a.attention_s, 0.5);
    }
}
