//! Runtime metrics: token throughput, GQMV GOPS accounting, latency
//! histograms — the quantities Table VI reports, plus the serving-side
//! counters (per-request latency/throughput histograms, queue-depth
//! gauges) the concurrent server exports via its `STATS` command.

pub mod request;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

pub use request::{RequestTrace, TraceBuilder};

use crate::util::stats::percentile;

/// Counts GQMV work (the paper's GOPS metric: 2 int ops per MAC, measured
/// on matrix computation only).
#[derive(Clone, Debug, Default)]
pub struct GopsCounter {
    /// Multiply-accumulates issued so far.
    pub macs: u64,
    /// Seconds spent issuing them.
    pub seconds: f64,
}

impl GopsCounter {
    /// Charge one `rows × cols` GQMV that took `seconds`.
    pub fn record(&mut self, rows: usize, cols: usize, seconds: f64) {
        self.macs += (rows * cols) as u64;
        self.seconds += seconds;
    }

    /// Giga-ops per second over everything recorded (2 ops per MAC).
    pub fn gops(&self) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            2.0 * self.macs as f64 / self.seconds / 1e9
        }
    }
}

/// Per-token latency recorder -> tok/s + percentiles.
#[derive(Debug)]
pub struct TokenMeter {
    start: Instant,
    last: Instant,
    /// Inter-token gaps in seconds, one entry per [`TokenMeter::tick`].
    pub latencies_s: Vec<f64>,
}

impl Default for TokenMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl TokenMeter {
    /// Start the clock now.
    pub fn new() -> Self {
        let now = Instant::now();
        TokenMeter { start: now, last: now, latencies_s: Vec::new() }
    }

    /// Mark one token produced.
    pub fn tick(&mut self) {
        let now = Instant::now();
        self.latencies_s.push(now.duration_since(self.last).as_secs_f64());
        self.last = now;
    }

    /// Tokens ticked so far.
    pub fn tokens(&self) -> usize {
        self.latencies_s.len()
    }

    /// Mean decode throughput from first tick to last.
    pub fn tok_per_s(&self) -> f64 {
        let total = self.last.duration_since(self.start).as_secs_f64();
        if total == 0.0 {
            0.0
        } else {
            self.tokens() as f64 / total
        }
    }

    /// (p50, p99) of the inter-token latencies, in seconds.
    pub fn p50_p99(&self) -> (f64, f64) {
        if self.latencies_s.is_empty() {
            return (0.0, 0.0);
        }
        let mut v = self.latencies_s.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (percentile(&v, 50.0), percentile(&v, 99.0))
    }
}

/// Component timing breakdown of a forward pass (Table II rows).
#[derive(Clone, Debug, Default)]
pub struct ForwardProfile {
    /// GQMV (matrix computation) seconds.
    pub matrix_s: f64,
    /// Multi-head attention seconds (scores + weighted sum).
    pub attention_s: f64,
    /// SwiGLU activation seconds.
    pub swiglu_s: f64,
    /// RoPE rotation seconds.
    pub rope_s: f64,
    /// RMSNorm seconds.
    pub rmsnorm_s: f64,
    /// quantize + residual + embedding + sampling glue
    pub other_s: f64,
    /// time spent staging weights (transfer; 0 when resident)
    pub transfer_s: f64,
}

impl ForwardProfile {
    /// Sum of every component, transfer and glue included.
    pub fn total(&self) -> f64 {
        self.matrix_s + self.attention_s + self.swiglu_s + self.rope_s + self.rmsnorm_s
            + self.other_s
            + self.transfer_s
    }

    /// Percentages over compute components (paper Table II excludes
    /// transfer and glue: it profiles the PS-only run's compute).
    pub fn table2_rows(&self) -> Vec<(&'static str, f64)> {
        let compute =
            self.matrix_s + self.attention_s + self.swiglu_s + self.rope_s + self.rmsnorm_s;
        let pct = |x: f64| if compute == 0.0 { 0.0 } else { 100.0 * x / compute };
        vec![
            ("Matrix Computation", pct(self.matrix_s)),
            ("Multi-head Attention", pct(self.attention_s)),
            ("SwiGLU", pct(self.swiglu_s)),
            ("RoPE", pct(self.rope_s)),
            ("RMSNorm", pct(self.rmsnorm_s)),
        ]
    }

    /// Add another profile's components into this one.
    pub fn merge(&mut self, o: &ForwardProfile) {
        self.matrix_s += o.matrix_s;
        self.attention_s += o.attention_s;
        self.swiglu_s += o.swiglu_s;
        self.rope_s += o.rope_s;
        self.rmsnorm_s += o.rmsnorm_s;
        self.other_s += o.other_s;
        self.transfer_s += o.transfer_s;
    }
}

/// Log₂ bucket count / base of [`Histogram`]: buckets span ~1 µs to ~2 min.
const HIST_BUCKETS: usize = 28;
const HIST_BASE: f64 = 1e-6;

/// Bounded log₂-bucketed histogram for positive samples (latencies in
/// seconds, rates in tok/s, ...).  Bucket `i` covers `(BASE·2^(i-1),
/// BASE·2^i]` with `BASE` = 1e-6 — constant memory however long the
/// server runs.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: [u64; HIST_BUCKETS],
    count: u64,
    sum: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: [0; HIST_BUCKETS], count: 0, sum: 0.0, max: 0.0 }
    }
}

impl Histogram {
    fn bucket_of(v: f64) -> usize {
        if v <= HIST_BASE {
            return 0;
        }
        let b = (v / HIST_BASE).log2().ceil() as usize;
        b.min(HIST_BUCKETS - 1)
    }

    /// Record one sample; non-finite or negative values are discarded.
    pub fn record(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.max = self.max.max(v);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact arithmetic mean of the recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Largest sample recorded (0 when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Upper edge of the bucket holding the q-quantile sample (q in 0..=1).
    /// Resolution is a factor of 2 — enough for serving dashboards.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return HIST_BASE * (1u64 << i) as f64;
            }
        }
        self.max
    }

    /// Fold another histogram's buckets and moments into this one.
    pub fn merge(&mut self, o: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&o.counts) {
            *a += b;
        }
        self.count += o.count;
        self.sum += o.sum;
        self.max = self.max.max(o.max);
    }
}

/// Shared serving metrics: request counters, token throughput, per-request
/// latency/throughput histograms and queue-depth gauges.  All methods take
/// `&self` so one instance can be shared by the accept loop and every
/// worker.
#[derive(Default)]
pub struct ServerMetrics {
    /// Completed generation requests.
    pub requests: AtomicU64,
    /// Connections rejected at the admission gate.
    pub rejected: AtomicU64,
    /// Tokens generated across all completed requests.
    pub tokens: AtomicU64,
    queue_depth: AtomicUsize,
    queue_peak: AtomicUsize,
    latency: Mutex<Histogram>,
    throughput: Mutex<Histogram>,
    // per-request trace aggregates (the `METRICS` endpoint's additions)
    traced: AtomicU64,
    queue_wait: Mutex<Histogram>,
    prefill_ns: AtomicU64,
    decode_ns: AtomicU64,
    prefill_tokens: AtomicU64,
    decode_tokens: AtomicU64,
}

impl ServerMetrics {
    /// Record one completed generation request.
    pub fn record_request(&self, wall_s: f64, tokens: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.tokens.fetch_add(tokens, Ordering::Relaxed);
        self.latency.lock().unwrap().record(wall_s);
        if wall_s > 0.0 {
            self.throughput.lock().unwrap().record(tokens as f64 / wall_s);
        }
    }

    /// Count one rejected connection.
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Fold one completed request's [`RequestTrace`] into the aggregates
    /// the `METRICS` endpoint exports.
    pub fn record_trace(&self, t: &RequestTrace) {
        self.traced.fetch_add(1, Ordering::Relaxed);
        self.queue_wait.lock().unwrap().record(t.queue_s);
        self.prefill_ns.fetch_add((t.prefill_s.max(0.0) * 1e9) as u64, Ordering::Relaxed);
        self.decode_ns.fetch_add((t.decode_s.max(0.0) * 1e9) as u64, Ordering::Relaxed);
        self.prefill_tokens.fetch_add(t.prefill_steps, Ordering::Relaxed);
        self.decode_tokens.fetch_add(t.decode_steps, Ordering::Relaxed);
    }

    /// Requests that came back with a per-request trace.
    pub fn traced(&self) -> u64 {
        self.traced.load(Ordering::Relaxed)
    }

    /// (p50, p99) of per-request queue wait, in milliseconds.
    pub fn queue_wait_ms_p50_p99(&self) -> (f64, f64) {
        let h = self.queue_wait.lock().unwrap();
        (1e3 * h.quantile(0.5), 1e3 * h.quantile(0.99))
    }

    /// Total wall seconds traced requests spent in prefill steps.
    pub fn prefill_s(&self) -> f64 {
        self.prefill_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Total wall seconds traced requests spent in decode steps.
    pub fn decode_s(&self) -> f64 {
        self.decode_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Prompt tokens consumed by prefill steps of traced requests.
    pub fn prefill_tokens(&self) -> u64 {
        self.prefill_tokens.load(Ordering::Relaxed)
    }

    /// Tokens sampled by decode steps of traced requests.
    pub fn decode_tokens(&self) -> u64 {
        self.decode_tokens.load(Ordering::Relaxed)
    }

    /// (p50, p99, mean) request latency in milliseconds.
    pub fn latency_ms(&self) -> (f64, f64, f64) {
        let lat = self.latency.lock().unwrap();
        (1e3 * lat.quantile(0.5), 1e3 * lat.quantile(0.99), 1e3 * lat.mean())
    }

    /// Median per-request decode throughput, tok/s.
    pub fn tok_s_p50(&self) -> f64 {
        self.throughput.lock().unwrap().quantile(0.5)
    }

    /// Gauge: current depth of the pending-connection queue.
    pub fn set_queue_depth(&self, depth: usize) {
        self.queue_depth.store(depth, Ordering::Relaxed);
        self.queue_peak.fetch_max(depth, Ordering::Relaxed);
    }

    /// Gauge: last reported pending-connection queue depth.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth.load(Ordering::Relaxed)
    }

    /// High-water mark of the pending-connection queue.
    pub fn queue_peak(&self) -> usize {
        self.queue_peak.load(Ordering::Relaxed)
    }

    /// One-line snapshot (the server prepends session-pool counts).
    pub fn summary(&self) -> String {
        let lat = self.latency.lock().unwrap().clone();
        let thr = self.throughput.lock().unwrap().clone();
        format!(
            "requests={} rejected={} tokens={} queue={} queue_peak={} \
             p50_ms={:.3} p99_ms={:.3} mean_ms={:.3} tok_s_p50={:.1}",
            self.requests.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.tokens.load(Ordering::Relaxed),
            self.queue_depth(),
            self.queue_peak(),
            1e3 * lat.quantile(0.5),
            1e3 * lat.quantile(0.99),
            1e3 * lat.mean(),
            thr.quantile(0.5),
        )
    }
}

/// Counters of the step-synchronous batch scheduler: occupancy histogram
/// plus the weight-staging volume that batching amortizes.  All methods
/// take `&self`; one instance is shared by the decode thread (writer) and
/// the `STATS` command (reader).
///
/// The headline derived quantity is [`BatchMetrics::bytes_per_token`]:
/// with B sessions decoding, one step stages each layer once but advances
/// B lane tokens, so bytes/token falls ~B× below the batch-1 figure
/// (`n_layers × layer_stream_bytes`).
#[derive(Default)]
pub struct BatchMetrics {
    steps: AtomicU64,
    lane_tokens: AtomicU64,
    bytes_staged: AtomicU64,
    /// Nanoseconds the decode thread spent waiting on *armed* prefetches
    /// from the persistent worker — the staging latency the async
    /// schedule failed to hide.  0 in resident mode and under sync
    /// staging (nothing is ever armed; inline staging waits show up in
    /// the step profile's `transfer_s` instead).
    prefetch_wait_ns: AtomicU64,
    /// Configured staging-ring depth (0 = resident serving: no staging
    /// pipeline exists).
    ring_depth: AtomicU64,
    /// Latest lifetime-mean armed-ring occupancy of the streamer,
    /// milli-units (gauge; 0 for sync staging and resident serving).
    ring_occ_milli: AtomicU64,
    /// Lifetime worker-side staging time of the shared streamer (ns,
    /// gauge) — the denominator of [`BatchMetrics::stage_mb_s`].
    transfer_ns: AtomicU64,
    /// Visible staging wait attributed to each matrix unit (ns, gauges),
    /// mirroring `StreamerStats::wait_by_unit_s` — "which matrix stalls".
    unit_wait_ns: [AtomicU64; MAT_WAIT_UNITS],
    /// Streaming granularity label; empty until the decode thread starts
    /// a streamer (resident serving never sets it).
    granularity: Mutex<&'static str>,
    /// Weight wire-format label of the serving model (`q8`, `q4_0`,
    /// `q5_0`); empty until the decode thread records it.
    quant: Mutex<&'static str>,
    occupancy: Mutex<Histogram>,
    profile: Mutex<ForwardProfile>,
    /// Requests admitted into the active set (once per request).
    admissions: AtomicU64,
    /// Total submit→admission wait across admitted requests (ns).
    admission_wait_ns: AtomicU64,
    /// Configured chunked-prefill budget (gauge; 1 = classic one token
    /// per step).
    prefill_chunk: AtomicU64,
    /// Scheduler steps in which some lane fed more than one prompt token
    /// (chunked-prefill multi-lane feeds, summed over requests).
    chunk_feeds: AtomicU64,
    /// Batched steps that failed and were retried (all lanes rolled back
    /// to the pre-step snapshot; counted once per failed attempt).
    step_retries: AtomicU64,
    /// Lanes shed because a step kept failing past the retry budget (each
    /// one is an `ERR fault:` surfaced to exactly one client).
    lane_faults: AtomicU64,
    /// Lanes shed because their per-request deadline expired mid-decode.
    deadline_expired: AtomicU64,
    /// Latest lifetime staged-read retry count of the shared streamer
    /// (gauge, mirrors `StreamerStats::retries`).
    stage_retries: AtomicU64,
    /// Latest lifetime count of staging requests that exhausted their
    /// retry budget (gauge, mirrors `StreamerStats::stage_faults`).
    stage_faults: AtomicU64,
    /// Latest lifetime count of staging requests that blew their stage
    /// deadline (gauge, mirrors `StreamerStats::stage_timeouts`).
    stage_timeouts: AtomicU64,
}

/// Matrix-granular wait buckets exported through `STATS` (`mat_wait_ms`):
/// norms, fused QKV, Wo, fused W1‖W3, W2 — must equal
/// `sched::STAGE_UNITS` (the compiler pins the array widths together at
/// the decode-loop call site).
pub const MAT_WAIT_UNITS: usize = 5;

impl BatchMetrics {
    /// Record one batched step that carried `occupancy` lanes, staged
    /// `bytes` of weights, waited `prefetch_wait_s` seconds on armed
    /// prefetches, and spent its time per `prof` (the step's component
    /// breakdown, merged into the lifetime profile).
    pub fn record_step(
        &self,
        occupancy: usize,
        bytes: u64,
        prefetch_wait_s: f64,
        prof: &ForwardProfile,
    ) {
        self.steps.fetch_add(1, Ordering::Relaxed);
        self.lane_tokens.fetch_add(occupancy as u64, Ordering::Relaxed);
        self.bytes_staged.fetch_add(bytes, Ordering::Relaxed);
        if prefetch_wait_s > 0.0 {
            self.prefetch_wait_ns.fetch_add((prefetch_wait_s * 1e9) as u64, Ordering::Relaxed);
        }
        self.occupancy.lock().unwrap().record(occupancy as f64);
        self.profile.lock().unwrap().merge(prof);
    }

    /// Lifetime component-time breakdown of the decode thread (Table II
    /// framing: where do batched steps spend their time?).
    pub fn profile(&self) -> ForwardProfile {
        self.profile.lock().unwrap().clone()
    }

    /// Batched steps executed so far.
    pub fn steps(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Lane-tokens processed (one per lane per step, prompt feeds
    /// included).
    pub fn lane_tokens(&self) -> u64 {
        self.lane_tokens.load(Ordering::Relaxed)
    }

    /// Total weight bytes staged by the shared streamer.
    pub fn bytes_staged(&self) -> u64 {
        self.bytes_staged.load(Ordering::Relaxed)
    }

    /// Seconds the decode thread spent waiting on armed prefetches — the
    /// latency the async schedule fails to hide (0 when fully hidden,
    /// under sync staging, or when serving resident weights).
    pub fn prefetch_wait_s(&self) -> f64 {
        self.prefetch_wait_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Record the staging-ring configuration (once, at decode-thread
    /// start).  Left at 0 for resident serving.
    pub fn set_ring_depth(&self, depth: usize) {
        self.ring_depth.store(depth as u64, Ordering::Relaxed);
    }

    /// Update the armed-ring occupancy gauge (the streamer's lifetime
    /// mean, sampled once per step).
    pub fn set_ring_occupancy(&self, occ: f64) {
        let milli = if occ.is_finite() && occ > 0.0 { (occ * 1e3) as u64 } else { 0 };
        self.ring_occ_milli.store(milli, Ordering::Relaxed);
    }

    /// Configured staging-ring depth (0 = resident serving).
    pub fn ring_depth(&self) -> u64 {
        self.ring_depth.load(Ordering::Relaxed)
    }

    /// Record the streamer's lifetime staging-transfer time (gauge,
    /// sampled once per step).
    pub fn set_staging_time(&self, total_s: f64) {
        let ns = if total_s.is_finite() && total_s > 0.0 { (total_s * 1e9) as u64 } else { 0 };
        self.transfer_ns.store(ns, Ordering::Relaxed);
    }

    /// Lifetime worker-side staging seconds (0 under resident serving).
    pub fn staging_time_s(&self) -> f64 {
        self.transfer_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Staging bandwidth in MB/s: bytes staged over worker transfer time.
    /// 0.0 whenever nothing has been transferred (resident serving, a
    /// fresh scheduler) — the zero case never divides by zero.
    pub fn stage_mb_s(&self) -> f64 {
        let t = self.staging_time_s();
        if t <= 0.0 {
            0.0
        } else {
            self.bytes_staged() as f64 / 1e6 / t
        }
    }

    /// Record the per-matrix-unit visible staging waits (gauges, sampled
    /// once per step from the streamer's lifetime counters).
    pub fn set_unit_waits(&self, waits_s: [f64; MAT_WAIT_UNITS]) {
        for (cell, w) in self.unit_wait_ns.iter().zip(waits_s) {
            let ns = if w.is_finite() && w > 0.0 { (w * 1e9) as u64 } else { 0 };
            cell.store(ns, Ordering::Relaxed);
        }
    }

    /// Per-matrix-unit visible staging waits in milliseconds (norms, QKV,
    /// Wo, W1‖W3, W2) — under layer-granular staging everything lands in
    /// the first bucket.
    pub fn unit_wait_ms(&self) -> [f64; MAT_WAIT_UNITS] {
        let mut out = [0.0; MAT_WAIT_UNITS];
        for (o, cell) in out.iter_mut().zip(&self.unit_wait_ns) {
            *o = cell.load(Ordering::Relaxed) as f64 / 1e6;
        }
        out
    }

    /// Record one request's admission into the active set with its
    /// measured submit→admission wait (call exactly once per request).
    pub fn record_admission(&self, wait_s: f64) {
        self.admissions.fetch_add(1, Ordering::Relaxed);
        if wait_s.is_finite() && wait_s > 0.0 {
            self.admission_wait_ns.fetch_add((wait_s * 1e9) as u64, Ordering::Relaxed);
        }
    }

    /// Requests admitted into the active set so far.
    pub fn admissions(&self) -> u64 {
        self.admissions.load(Ordering::Relaxed)
    }

    /// Mean submit→admission latency in milliseconds (0 before the first
    /// admission).
    pub fn admission_ms_mean(&self) -> f64 {
        let n = self.admissions();
        if n == 0 {
            0.0
        } else {
            self.admission_wait_ns.load(Ordering::Relaxed) as f64 / 1e6 / n as f64
        }
    }

    /// Record the configured chunked-prefill budget (once, at
    /// decode-thread start).
    pub fn set_prefill_chunk(&self, chunk: usize) {
        self.prefill_chunk.store(chunk as u64, Ordering::Relaxed);
    }

    /// Configured chunked-prefill budget (lanes a prefilling request may
    /// occupy in one step; 0 until the decode thread starts).
    pub fn prefill_chunk(&self) -> u64 {
        self.prefill_chunk.load(Ordering::Relaxed)
    }

    /// Count one multi-token chunked-prefill feed (a request consuming
    /// more than one prompt token in a single scheduler step).
    pub fn record_chunk_feed(&self) {
        self.chunk_feeds.fetch_add(1, Ordering::Relaxed);
    }

    /// Multi-token chunked-prefill feeds so far (0 at `--prefill-chunk 1`).
    pub fn chunk_feeds(&self) -> u64 {
        self.chunk_feeds.load(Ordering::Relaxed)
    }

    /// Count one failed-and-retried batched step (every active lane was
    /// rolled back to the pre-step snapshot before the retry).
    pub fn record_step_retry(&self) {
        self.step_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Failed-and-retried batched steps so far.
    pub fn step_retries(&self) -> u64 {
        self.step_retries.load(Ordering::Relaxed)
    }

    /// Count one lane shed after a step kept failing past the retry
    /// budget (its client got an `ERR fault:`; every other lane kept
    /// decoding).
    pub fn record_lane_fault(&self) {
        self.lane_faults.fetch_add(1, Ordering::Relaxed);
    }

    /// Lanes shed to isolate persistent step faults.
    pub fn lane_faults(&self) -> u64 {
        self.lane_faults.load(Ordering::Relaxed)
    }

    /// Count one lane shed because its per-request deadline expired
    /// mid-decode (its client got an `ERR deadline:`).
    pub fn record_deadline_expired(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Lanes shed on an expired per-request deadline.
    pub fn deadline_expired(&self) -> u64 {
        self.deadline_expired.load(Ordering::Relaxed)
    }

    /// Update the staging-fault gauges from the streamer's lifetime
    /// counters (sampled once per step, and again on step failure so a
    /// shed before idle still exports its cause).
    pub fn set_stage_faults(&self, retries: u64, faults: u64, timeouts: u64) {
        self.stage_retries.store(retries, Ordering::Relaxed);
        self.stage_faults.store(faults, Ordering::Relaxed);
        self.stage_timeouts.store(timeouts, Ordering::Relaxed);
    }

    /// Staged reads that failed transiently and were retried in place by
    /// the prefetch worker (lifetime streamer counter; 0 when resident).
    pub fn stage_retries(&self) -> u64 {
        self.stage_retries.load(Ordering::Relaxed)
    }

    /// Staging requests that exhausted their retry budget and surfaced an
    /// error to the decode thread (lifetime streamer counter).
    pub fn stage_faults(&self) -> u64 {
        self.stage_faults.load(Ordering::Relaxed)
    }

    /// Staging requests that blew the per-stage deadline and surfaced a
    /// timeout instead of hanging (lifetime streamer counter).
    pub fn stage_timeouts(&self) -> u64 {
        self.stage_timeouts.load(Ordering::Relaxed)
    }

    /// Record the streaming granularity label (once, at decode-thread
    /// start; never set under resident serving).
    pub fn set_granularity(&self, label: &'static str) {
        *self.granularity.lock().unwrap() = label;
    }

    /// Streaming granularity label: `layer`, `matrix`, or `none` when no
    /// staging pipeline exists (resident serving).
    pub fn granularity(&self) -> &'static str {
        let g = *self.granularity.lock().unwrap();
        if g.is_empty() {
            "none"
        } else {
            g
        }
    }

    /// Record the serving model's weight format label (once, at
    /// decode-thread start).
    pub fn set_quant(&self, label: &'static str) {
        *self.quant.lock().unwrap() = label;
    }

    /// Weight wire-format label of the serving model.  Historical
    /// deployments were all INT8, so an unset label reads as `q8`.
    pub fn quant(&self) -> &'static str {
        let q = *self.quant.lock().unwrap();
        if q.is_empty() {
            "q8"
        } else {
            q
        }
    }

    /// Mean armed-ring occupancy observed by the streamer — > 0 means the
    /// prefetch pipeline genuinely ran ahead of compute.
    pub fn ring_occupancy(&self) -> f64 {
        self.ring_occ_milli.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Mean lanes per step.
    pub fn occupancy_mean(&self) -> f64 {
        self.occupancy.lock().unwrap().mean()
    }

    /// Peak lanes in any single step.
    pub fn occupancy_max(&self) -> f64 {
        self.occupancy.lock().unwrap().max()
    }

    /// Weight bytes staged per lane-token — the bandwidth-amortization
    /// headline (0 until the first step).
    pub fn bytes_per_token(&self) -> f64 {
        let toks = self.lane_tokens();
        if toks == 0 {
            0.0
        } else {
            self.bytes_staged() as f64 / toks as f64
        }
    }

    /// One-line snapshot appended to the server's `STATS` reply.
    pub fn summary(&self) -> String {
        let prof = self.profile();
        let total = prof.total();
        let matrix_pct = if total > 0.0 { 100.0 * prof.matrix_s / total } else { 0.0 };
        let mw = self.unit_wait_ms();
        format!(
            "batch_steps={} batch_tokens={} batch_mean={:.2} batch_max={:.0} \
             bytes_staged={} bytes_per_tok={:.0} prefetch_wait_ms={:.3} \
             prefetch_depth={} ring_occ={:.2} granularity={} quant={} \
             stage_mb_s={:.2} \
             mat_wait_ms={:.3}/{:.3}/{:.3}/{:.3}/{:.3} matrix_pct={:.0} \
             admission_ms={:.3} prefill_chunk={} chunk_feeds={} \
             stage_retries={} stage_faults={} stage_timeouts={} \
             step_retries={} lane_faults={} deadline_expired={}",
            self.steps(),
            self.lane_tokens(),
            self.occupancy_mean(),
            self.occupancy_max(),
            self.bytes_staged(),
            self.bytes_per_token(),
            1e3 * self.prefetch_wait_s(),
            self.ring_depth(),
            self.ring_occupancy(),
            self.granularity(),
            self.quant(),
            self.stage_mb_s(),
            mw[0],
            mw[1],
            mw[2],
            mw[3],
            mw[4],
            matrix_pct,
            self.admission_ms_mean(),
            self.prefill_chunk(),
            self.chunk_feeds(),
            self.stage_retries(),
            self.stage_faults(),
            self.stage_timeouts(),
            self.step_retries(),
            self.lane_faults(),
            self.deadline_expired(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gops_math() {
        let mut g = GopsCounter::default();
        g.record(1000, 1000, 0.001);
        // 2 * 1e6 MACs / 1e-3 s = 2e9 ops/s = 2 GOPS
        assert!((g.gops() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn token_meter_counts() {
        let mut m = TokenMeter::new();
        for _ in 0..5 {
            m.tick();
        }
        assert_eq!(m.tokens(), 5);
        assert!(m.tok_per_s() > 0.0);
        let (p50, p99) = m.p50_p99();
        assert!(p50 <= p99);
    }

    #[test]
    fn table2_percentages_sum_to_100() {
        let p = ForwardProfile {
            matrix_s: 0.97,
            attention_s: 0.02,
            swiglu_s: 0.005,
            rope_s: 0.003,
            rmsnorm_s: 0.002,
            other_s: 0.5, // excluded
            transfer_s: 0.3,
        };
        let sum: f64 = p.table2_rows().iter().map(|(_, v)| v).sum();
        assert!((sum - 100.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds() {
        let mut a = ForwardProfile { matrix_s: 1.0, ..Default::default() };
        let b = ForwardProfile { matrix_s: 2.0, attention_s: 0.5, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.matrix_s, 3.0);
        assert_eq!(a.attention_s, 0.5);
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let mut h = Histogram::default();
        for _ in 0..90 {
            h.record(0.001); // 1 ms
        }
        for _ in 0..10 {
            h.record(0.1); // 100 ms
        }
        assert_eq!(h.count(), 100);
        // log2 buckets: answers are within a factor of 2 of the sample
        let p50 = h.quantile(0.5);
        assert!((0.0005..=0.002).contains(&p50), "p50 {p50}");
        let p99 = h.quantile(0.99);
        assert!((0.05..=0.2).contains(&p99), "p99 {p99}");
        assert!(p50 <= p99);
        let mean = h.mean();
        assert!((mean - 0.0109).abs() < 1e-4, "mean {mean}");
        assert_eq!(h.max(), 0.1);
    }

    #[test]
    fn histogram_ignores_garbage_and_merges() {
        let mut a = Histogram::default();
        a.record(f64::NAN);
        a.record(-1.0);
        assert_eq!(a.count(), 0);
        assert_eq!(a.quantile(0.5), 0.0);
        a.record(0.01);
        let mut b = Histogram::default();
        b.record(0.04);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.max() == 0.04);
    }

    #[test]
    fn batch_metrics_amortization_math() {
        let m = BatchMetrics::default();
        // 10 steps at occupancy 4, each staging 1000 bytes
        let prof = ForwardProfile { matrix_s: 0.9, attention_s: 0.1, ..Default::default() };
        for _ in 0..10 {
            m.record_step(4, 1000, 0.002, &prof);
        }
        assert!((m.profile().matrix_s - 9.0).abs() < 1e-9, "profile merges per step");
        assert_eq!(m.steps(), 10);
        assert_eq!(m.lane_tokens(), 40);
        assert_eq!(m.bytes_staged(), 10_000);
        assert!((m.bytes_per_token() - 250.0).abs() < 1e-9);
        assert!((m.occupancy_mean() - 4.0).abs() < 1e-9);
        assert_eq!(m.occupancy_max(), 4.0);
        assert!((m.prefetch_wait_s() - 0.02).abs() < 1e-6, "{}", m.prefetch_wait_s());
        m.set_ring_depth(4);
        m.set_ring_occupancy(2.25);
        m.set_granularity("matrix");
        m.set_quant("q4_0");
        m.set_staging_time(0.005);
        m.set_unit_waits([0.001, 0.002, 0.0, 0.0, 0.0005]);
        assert_eq!(m.ring_depth(), 4);
        assert!((m.ring_occupancy() - 2.25).abs() < 1e-9);
        // 10_000 bytes over 5 ms = 2 MB/s
        assert!((m.stage_mb_s() - 2.0).abs() < 1e-6, "{}", m.stage_mb_s());
        let s = m.summary();
        for field in [
            "batch_steps=10",
            "batch_tokens=40",
            "bytes_staged=10000",
            "bytes_per_tok=250",
            "prefetch_wait_ms=20.000",
            "prefetch_depth=4",
            "ring_occ=2.25",
            "granularity=matrix",
            "quant=q4_0",
            "stage_mb_s=2.00",
            "mat_wait_ms=1.000/2.000/0.000/0.000/0.500",
            "admission_ms=0.000",
            "prefill_chunk=0",
            "chunk_feeds=0",
            "stage_retries=0",
            "stage_faults=0",
            "stage_timeouts=0",
            "step_retries=0",
            "lane_faults=0",
            "deadline_expired=0",
        ] {
            assert!(s.contains(field), "summary missing {field}: {s}");
        }
        // continuous-admission counters: two admissions waiting 2 ms and
        // 4 ms average to 3 ms; chunk feeds count multi-token steps
        m.record_admission(0.002);
        m.record_admission(0.004);
        m.set_prefill_chunk(4);
        m.record_chunk_feed();
        assert_eq!(m.admissions(), 2);
        assert!((m.admission_ms_mean() - 3.0).abs() < 1e-6, "{}", m.admission_ms_mean());
        let s = m.summary();
        assert!(s.contains("admission_ms=3.000"), "{s}");
        assert!(s.contains("prefill_chunk=4"), "{s}");
        assert!(s.contains("chunk_feeds=1"), "{s}");
        // batch-1 baseline on the same workload stages 4x the bytes/token
        let b1 = BatchMetrics::default();
        for _ in 0..40 {
            b1.record_step(1, 1000, 0.0, &ForwardProfile::default());
        }
        assert!(b1.bytes_per_token() / m.bytes_per_token() >= 3.0);
    }

    #[test]
    fn fault_counters_count_and_export() {
        let m = BatchMetrics::default();
        m.record_step_retry();
        m.record_step_retry();
        m.record_lane_fault();
        m.record_deadline_expired();
        m.set_stage_faults(7, 1, 2);
        assert_eq!(m.step_retries(), 2);
        assert_eq!(m.lane_faults(), 1);
        assert_eq!(m.deadline_expired(), 1);
        assert_eq!(m.stage_retries(), 7);
        assert_eq!(m.stage_faults(), 1);
        assert_eq!(m.stage_timeouts(), 2);
        let s = m.summary();
        for field in [
            "stage_retries=7",
            "stage_faults=1",
            "stage_timeouts=2",
            "step_retries=2",
            "lane_faults=1",
            "deadline_expired=1",
        ] {
            assert!(s.contains(field), "summary missing {field}: {s}");
        }
        // gauges overwrite, they never accumulate
        m.set_stage_faults(9, 0, 0);
        assert_eq!(m.stage_retries(), 9);
        assert_eq!(m.stage_faults(), 0);
    }

    #[test]
    fn batch_metrics_empty_is_zero() {
        let m = BatchMetrics::default();
        assert_eq!(m.bytes_per_token(), 0.0);
        assert_eq!(m.occupancy_mean(), 0.0);
        assert_eq!(m.steps(), 0);
        assert_eq!(m.granularity(), "none", "unset granularity reads as none");
        assert_eq!(m.quant(), "q8", "unset quant label reads as the historical q8");
        assert_eq!(m.unit_wait_ms(), [0.0; MAT_WAIT_UNITS]);
    }

    #[test]
    fn stage_mb_s_zero_transfer_never_divides() {
        // bytes but no recorded transfer time (resident serving, or the
        // gauge not yet sampled): bandwidth must read 0, not inf/NaN
        let m = BatchMetrics::default();
        m.record_step(1, 1_000_000, 0.0, &ForwardProfile::default());
        assert_eq!(m.stage_mb_s(), 0.0);
        m.set_staging_time(0.0);
        assert_eq!(m.stage_mb_s(), 0.0);
        m.set_staging_time(f64::NAN);
        assert_eq!(m.stage_mb_s(), 0.0, "garbage staging time is discarded");
        // 1 MB in 0.5 s -> 2 MB/s
        m.set_staging_time(0.5);
        assert!((m.stage_mb_s() - 2.0).abs() < 1e-9, "{}", m.stage_mb_s());
    }

    #[test]
    fn server_metrics_counts_and_summary() {
        let m = ServerMetrics::default();
        m.record_request(0.050, 16);
        m.record_request(0.100, 16);
        m.record_rejected();
        m.set_queue_depth(3);
        m.set_queue_depth(1);
        assert_eq!(m.queue_depth(), 1);
        assert_eq!(m.queue_peak(), 3);
        let s = m.summary();
        assert!(s.contains("requests=2"), "{s}");
        assert!(s.contains("rejected=1"), "{s}");
        assert!(s.contains("tokens=32"), "{s}");
        assert!(s.contains("queue=1"), "{s}");
        assert!(s.contains("queue_peak=3"), "{s}");
    }

    #[test]
    fn record_trace_feeds_the_aggregates() {
        let m = ServerMetrics::default();
        assert_eq!(m.traced(), 0);
        assert_eq!(m.queue_wait_ms_p50_p99(), (0.0, 0.0));
        let t = RequestTrace {
            id: 0,
            queue_s: 0.004,
            prefill_steps: 5,
            decode_steps: 8,
            prefill_s: 0.050,
            decode_s: 0.080,
            staged_bytes: 1000,
            prefetch_wait_s: 0.0,
            unit_wait_s: [0.0; MAT_WAIT_UNITS],
            batch_mean: 1.0,
            tok_per_s: 100.0,
            chunk_feeds: 0,
            prefix_tokens: 0,
            faults: 0,
        };
        m.record_trace(&t);
        m.record_trace(&t);
        assert_eq!(m.traced(), 2);
        assert_eq!(m.prefill_tokens(), 10);
        assert_eq!(m.decode_tokens(), 16);
        assert!((m.prefill_s() - 0.100).abs() < 1e-6);
        assert!((m.decode_s() - 0.160).abs() < 1e-6);
        let (p50, p99) = m.queue_wait_ms_p50_p99();
        // log2 buckets: within a factor of 2 of the 4 ms sample
        assert!((2.0..=8.0).contains(&p50), "p50 {p50}");
        assert!(p50 <= p99);
    }
}
