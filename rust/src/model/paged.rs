//! Paged KV cache: fixed-size position pages drawn from a shared pool.
//!
//! The contiguous [`KvCache`](crate::model::KvCache) reserves
//! `n_layers × seq_len × kv_dim` floats per session up front — at paper
//! scale ~92 MB each — even when a session decodes ten tokens.  The page
//! pool breaks that allocation into **pages** of `page_size` consecutive
//! positions (across all layers), allocated on demand as a session's
//! context grows and returned when it resets, so resident KV memory
//! tracks *live context*, not the session count × `seq_len` worst case.
//!
//! On top of the block allocator sits **refcounted copy-on-write prefix
//! sharing**: when a session retires, the page-aligned prefix of its
//! prompt can be published to the pool's prefix cache
//! ([`PagedKv::cache_prefix`]).  A later session with the same prompt
//! prefix adopts those pages by `Arc` clone ([`PagedKv::adopt_prefix`]) —
//! zero copies, zero recompute — and the scheduler skips feeding the
//! covered tokens.  Shared pages are immutable through sharing: a write
//! to a page with other holders first replaces it with a private copy
//! ([`PagePool::cow_replace`]), so one session can never corrupt another
//! session's (or the cache's) view.  This is bit-exact by construction:
//! a cached page holds exactly the floats the same prompt prefix would
//! recompute, because KV at position *p* depends only on tokens `0..=p`.
//!
//! Under memory pressure the pool evicts prefix-cache entries in LRU
//! order.  `capacity` is a soft bound for *live* demand (a session that
//! genuinely needs one more page gets it rather than panicking the
//! decode thread) and a hard bound for cached memory: allocation evicts
//! the cache before overcommitting.  Hit/miss/eviction counters feed the
//! `STATS`/`METRICS` surfaces (`docs/OBSERVABILITY.md`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::model::kv::KvStore;
use crate::model::LlamaConfig;

/// Default positions per page (CLI `--kv-pages` counts pages of this
/// size unless a pool is built with an explicit `page_size`).
pub const DEFAULT_PAGE_POSITIONS: usize = 16;

/// One page: `page_size` consecutive positions × all layers × `kv_dim`
/// floats of keys and of values.  Pages are immutable while shared
/// (refcount > 1) — writers go through copy-on-write.
struct Page {
    /// Pool-unique id (monotone); lets tests account distinct pages.
    id: u64,
    k: Vec<f32>,
    v: Vec<f32>,
}

/// One published prompt prefix: the exact token ids it covers (always a
/// whole number of pages) and the shared pages holding their KV.
struct PrefixEntry {
    tokens: Vec<u32>,
    pages: Vec<Arc<Page>>,
    last_used: u64,
}

struct PoolState {
    /// Distinct live pages (session-held and/or cache-held).
    allocated: usize,
    next_id: u64,
    cache: Vec<PrefixEntry>,
    clock: u64,
}

/// Shared block allocator + prefix cache for paged KV storage.
///
/// One pool serves every session of a server (`serve --kv-pages N`).
/// All refcount transitions that affect the `allocated` ledger happen
/// under the pool mutex, so the ledger exactly equals the number of
/// distinct live pages at all times (pinned by `tests/property.rs`).
pub struct PagePool {
    /// Positions per page.
    pub page_size: usize,
    /// Soft page budget: allocation evicts cached prefixes to stay
    /// under it; live sessions may overcommit past it rather than fail.
    pub capacity: usize,
    n_layers: usize,
    kv_dim: usize,
    seq_len: usize,
    /// Floats per page per side (k or v).
    page_floats: usize,
    state: Mutex<PoolState>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl PagePool {
    /// Pool for `cfg`-shaped sessions with `capacity` pages of
    /// `page_size` positions each.
    pub fn new(cfg: &LlamaConfig, capacity: usize, page_size: usize) -> Self {
        assert!(page_size > 0, "page_size must be >= 1");
        assert!(capacity > 0, "page capacity must be >= 1");
        PagePool {
            page_size,
            capacity,
            n_layers: cfg.n_layers,
            kv_dim: cfg.kv_dim(),
            seq_len: cfg.seq_len,
            page_floats: cfg.n_layers * page_size * cfg.kv_dim(),
            state: Mutex::new(PoolState { allocated: 0, next_id: 0, cache: Vec::new(), clock: 0 }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Evict the least-recently-used cached prefix, releasing every page
    /// the cache was the last holder of.  Returns false when the cache
    /// is empty.
    fn evict_lru_locked(&self, st: &mut PoolState) -> bool {
        let Some(idx) = (0..st.cache.len()).min_by_key(|&i| st.cache[i].last_used) else {
            return false;
        };
        let entry = st.cache.swap_remove(idx);
        for page in entry.pages {
            if Arc::strong_count(&page) == 1 {
                st.allocated -= 1;
            }
        }
        self.evictions.fetch_add(1, Ordering::Relaxed);
        true
    }

    fn fresh_page_locked(&self, st: &mut PoolState) -> Arc<Page> {
        let id = st.next_id;
        st.next_id += 1;
        st.allocated += 1;
        Arc::new(Page { id, k: vec![0.0; self.page_floats], v: vec![0.0; self.page_floats] })
    }

    /// Allocate one page, evicting cached prefixes first when the pool
    /// is at capacity.  Live demand may overcommit past `capacity`.
    fn alloc(&self) -> Arc<Page> {
        let mut st = self.lock();
        while st.allocated >= self.capacity && self.evict_lru_locked(&mut st) {}
        self.fresh_page_locked(&mut st)
    }

    /// Replace a shared page behind `slot` with a private deep copy
    /// (copy-on-write).  No-op when the caller is already the sole
    /// holder.  Runs under the pool lock so the `allocated` ledger and
    /// the refcounts it mirrors change atomically together.
    fn cow_replace(&self, slot: &mut Arc<Page>) {
        let mut st = self.lock();
        if Arc::strong_count(slot) == 1 {
            return; // raced: the other holder vanished before we locked
        }
        while st.allocated >= self.capacity && self.evict_lru_locked(&mut st) {}
        let id = st.next_id;
        st.next_id += 1;
        st.allocated += 1;
        let copy = Arc::new(Page { id, k: slot.k.clone(), v: slot.v.clone() });
        // Dropping our ref to the shared page cannot free it (another
        // holder exists under this lock), so no ledger decrement here.
        *slot = copy;
    }

    /// Return a session's pages to the pool, decrementing the ledger
    /// for every page this was the last reference to.
    fn release(&self, pages: Vec<Arc<Page>>) {
        if pages.is_empty() {
            return;
        }
        let mut st = self.lock();
        for page in pages {
            if Arc::strong_count(&page) == 1 {
                st.allocated -= 1;
            }
        }
    }

    /// Longest cached prefix of `prompt` usable for admission: the
    /// match must leave at least one prompt token to feed (the final
    /// token's forward produces the first logits).  Counts a hit or a
    /// miss; hits refresh the entry's LRU stamp.
    fn fork(&self, prompt: &[u32]) -> Option<(Vec<Arc<Page>>, usize)> {
        let mut st = self.lock();
        st.clock += 1;
        let clock = st.clock;
        let best = st
            .cache
            .iter_mut()
            .filter(|e| e.tokens.len() < prompt.len() && prompt.starts_with(&e.tokens))
            .max_by_key(|e| e.tokens.len());
        match best {
            Some(entry) => {
                entry.last_used = clock;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some((entry.pages.clone(), entry.tokens.len()))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Publish `tokens` (a whole number of pages) backed by `pages`.
    /// An existing identical entry is refreshed instead of duplicated.
    fn cache_insert(&self, tokens: &[u32], pages: Vec<Arc<Page>>) {
        debug_assert_eq!(tokens.len(), pages.len() * self.page_size);
        let mut st = self.lock();
        st.clock += 1;
        let clock = st.clock;
        if let Some(entry) = st.cache.iter_mut().find(|e| e.tokens == tokens) {
            entry.last_used = clock;
            return;
        }
        st.cache.push(PrefixEntry { tokens: tokens.to_vec(), pages, last_used: clock });
    }

    /// Distinct live pages right now (session-held and/or cache-held).
    pub fn pages_used(&self) -> usize {
        self.lock().allocated
    }

    /// Cached prefix entries right now.
    pub fn cached_prefixes(&self) -> usize {
        self.lock().cache.len()
    }

    /// Prefix-cache hits (admissions that adopted cached pages).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Prefix-cache misses (admissions that found no usable prefix).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Prefix-cache entries evicted under memory pressure (or by
    /// [`PagePool::clear_cache`]).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Evict every cached prefix (testing / explicit drain).
    pub fn clear_cache(&self) {
        let mut st = self.lock();
        while self.evict_lru_locked(&mut st) {}
    }

    /// Page ids currently held by the prefix cache (test accounting).
    pub fn cached_page_ids(&self) -> Vec<u64> {
        let st = self.lock();
        st.cache.iter().flat_map(|e| e.pages.iter().map(|p| p.id)).collect()
    }
}

/// A session's view of pool-backed KV storage: an ordered run of pages
/// covering positions `0..filled`, growing on demand.
///
/// Reads (`key`/`value`) touch no lock — the session owns `Arc`s to its
/// pages.  Writes to a page shared with the prefix cache (or another
/// session) go through copy-on-write first.
pub struct PagedKv {
    pool: Arc<PagePool>,
    pages: Vec<Arc<Page>>,
    filled: usize,
}

impl PagedKv {
    /// Empty paged cache drawing from `pool`.
    pub fn new(pool: Arc<PagePool>) -> Self {
        PagedKv { pool, pages: Vec::new(), filled: 0 }
    }

    #[inline]
    fn offset(&self, layer: usize, pos: usize) -> (usize, usize) {
        let ps = self.pool.page_size;
        (pos / ps, (layer * ps + pos % ps) * self.pool.kv_dim)
    }

    fn page_mut(&mut self, idx: usize) -> &mut Page {
        if Arc::get_mut(&mut self.pages[idx]).is_none() {
            self.pool.cow_replace(&mut self.pages[idx]);
        }
        Arc::get_mut(&mut self.pages[idx]).expect("page uniquely owned after copy-on-write")
    }

    /// Adopt the longest cached prefix of `prompt` from the pool's
    /// prefix cache.  Must be called on an empty (reset) cache; returns
    /// the number of positions now pre-filled (0 on a cache miss) — the
    /// scheduler skips feeding that many prompt tokens.
    pub fn adopt_prefix(&mut self, prompt: &[u32]) -> usize {
        assert_eq!(self.filled, 0, "adopt_prefix requires a reset cache");
        match self.pool.fork(prompt) {
            Some((pages, len)) => {
                self.pages = pages;
                self.filled = len;
                len
            }
            None => 0,
        }
    }

    /// Publish the page-aligned prefix of `prompt` this session computed
    /// to the pool's prefix cache, sharing its pages (no copy).  A
    /// prefix shorter than one page is not cached.
    pub fn cache_prefix(&self, prompt: &[u32]) {
        let ps = self.pool.page_size;
        // Cacheable span: fully-computed prompt positions, whole pages
        // only, and never the final prompt token (an adopter must still
        // feed at least one token to get logits).
        let span = prompt.len().saturating_sub(1).min(self.filled) / ps * ps;
        if span == 0 {
            return;
        }
        let pages: Vec<Arc<Page>> = self.pages[..span / ps].to_vec();
        self.pool.cache_insert(&prompt[..span], pages);
    }

    /// Pages currently held by this session.
    pub fn n_pages(&self) -> usize {
        self.pages.len()
    }

    /// Ids of the pages currently held (test accounting).
    pub fn page_ids(&self) -> Vec<u64> {
        self.pages.iter().map(|p| p.id).collect()
    }

    /// The shared pool this cache draws from.
    pub fn pool(&self) -> &Arc<PagePool> {
        &self.pool
    }
}

impl KvStore for PagedKv {
    fn store(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.pool.seq_len, "pos {pos} >= seq_len {}", self.pool.seq_len);
        assert_eq!(k.len(), self.pool.kv_dim);
        assert_eq!(v.len(), self.pool.kv_dim);
        let (pi, off) = self.offset(layer, pos);
        while self.pages.len() <= pi {
            let page = self.pool.alloc();
            self.pages.push(page);
        }
        let kv_dim = self.pool.kv_dim;
        let page = self.page_mut(pi);
        page.k[off..off + kv_dim].copy_from_slice(k);
        page.v[off..off + kv_dim].copy_from_slice(v);
        self.filled = self.filled.max(pos + 1);
    }

    #[inline]
    fn key(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32] {
        let (pi, off) = self.offset(layer, pos);
        let i = off + kv_head * head_dim;
        &self.pages[pi].k[i..i + head_dim]
    }

    #[inline]
    fn value(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32] {
        let (pi, off) = self.offset(layer, pos);
        let i = off + kv_head * head_dim;
        &self.pages[pi].v[i..i + head_dim]
    }

    fn filled(&self) -> usize {
        self.filled
    }

    fn reset(&mut self) {
        self.pool.release(std::mem::take(&mut self.pages));
        self.filled = 0;
    }

    fn bytes(&self) -> usize {
        self.pages.len() * self.pool.page_floats * 2 * 4
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        self.pool.release(std::mem::take(&mut self.pages));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::NANO;
    use crate::model::KvCache;

    fn pool(cap: usize, ps: usize) -> Arc<PagePool> {
        Arc::new(PagePool::new(&NANO, cap, ps))
    }

    fn fill(kv: &mut dyn KvStore, positions: usize, seed: f32) {
        let kd = NANO.kv_dim();
        for pos in 0..positions {
            for layer in 0..NANO.n_layers {
                let k: Vec<f32> =
                    (0..kd).map(|i| seed + (layer * 100 + pos * 10 + i) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                kv.store(layer, pos, &k, &v);
            }
        }
    }

    #[test]
    fn paged_reads_match_contiguous() {
        let p = pool(64, 4);
        let mut paged = PagedKv::new(Arc::clone(&p));
        let mut flat = KvCache::new(&NANO);
        fill(&mut paged, 10, 0.5);
        fill(&mut flat, 10, 0.5);
        let hd = NANO.head_dim();
        for layer in 0..NANO.n_layers {
            for pos in 0..10 {
                for h in 0..NANO.n_kv_heads {
                    assert_eq!(paged.key(layer, pos, h, hd), flat.key(layer, pos, h, hd));
                    assert_eq!(paged.value(layer, pos, h, hd), flat.value(layer, pos, h, hd));
                }
            }
        }
        assert_eq!(paged.filled(), 10);
        assert_eq!(paged.n_pages(), 3); // ceil(10 / 4)
    }

    #[test]
    fn release_returns_every_page() {
        let p = pool(16, 4);
        let mut a = PagedKv::new(Arc::clone(&p));
        let mut b = PagedKv::new(Arc::clone(&p));
        fill(&mut a, 9, 1.0);
        fill(&mut b, 5, 2.0);
        assert_eq!(p.pages_used(), 3 + 2);
        a.reset();
        assert_eq!(p.pages_used(), 2);
        drop(b);
        assert_eq!(p.pages_used(), 0);
    }

    #[test]
    fn prefix_adoption_shares_pages_and_cow_isolates_writes() {
        let p = pool(64, 4);
        let mut a = PagedKv::new(Arc::clone(&p));
        fill(&mut a, 9, 3.0);
        let prompt: Vec<u32> = (0..9).collect();
        a.cache_prefix(&prompt); // caches 2 pages = 8 positions
        assert_eq!(p.cached_prefixes(), 1);

        let mut b = PagedKv::new(Arc::clone(&p));
        let adopted = b.adopt_prefix(&prompt);
        assert_eq!(adopted, 8);
        assert_eq!(p.hits(), 1);
        // shared pages: same ids, no new allocation
        assert_eq!(b.page_ids(), a.page_ids()[..2].to_vec());
        let used_before = p.pages_used();

        // writing into a shared page must COW, not corrupt a's view
        let hd = NANO.head_dim();
        let before: Vec<f32> = a.key(0, 0, 0, hd).to_vec();
        let z = vec![9.9f32; NANO.kv_dim()];
        b.store(0, 0, &z, &z);
        assert_eq!(a.key(0, 0, 0, hd), &before[..], "COW failed to isolate the writer");
        assert_eq!(b.key(0, 0, 0, hd), &z[..hd]);
        assert_ne!(b.page_ids()[0], a.page_ids()[0]);
        assert_eq!(p.pages_used(), used_before + 1);
    }

    #[test]
    fn lru_eviction_frees_cache_only_pages() {
        let p = pool(4, 2);
        let mut a = PagedKv::new(Arc::clone(&p));
        fill(&mut a, 5, 4.0); // 3 pages
        let prompt: Vec<u32> = (0..5).collect();
        a.cache_prefix(&prompt); // caches 2 pages (4 positions)
        a.reset(); // cache is now the sole holder of those 2 pages
        assert_eq!(p.pages_used(), 2);

        // demand past capacity evicts the cached prefix
        let mut b = PagedKv::new(Arc::clone(&p));
        fill(&mut b, 6, 5.0); // needs 3 pages; cap 4 forces eviction
        assert_eq!(p.evictions(), 1);
        assert_eq!(p.cached_prefixes(), 0);
        assert_eq!(p.pages_used(), 3);
    }

    #[test]
    fn short_or_unaligned_prefixes_are_not_adopted_past_the_last_token() {
        let p = pool(16, 4);
        let mut a = PagedKv::new(Arc::clone(&p));
        fill(&mut a, 4, 6.0);
        // prompt of 4: only 3 positions are cacheable (the adopter must
        // feed the final token), which rounds down to 0 whole pages
        a.cache_prefix(&[1, 2, 3, 4]);
        assert_eq!(p.cached_prefixes(), 0);

        fill(&mut a, 9, 6.0);
        let prompt: Vec<u32> = (10..19).collect();
        a.cache_prefix(&prompt); // 8 positions = 2 pages cached
        let mut b = PagedKv::new(Arc::clone(&p));
        // a prompt equal to the cached prefix alone leaves no token to
        // feed -> must NOT adopt the full entry
        assert_eq!(b.adopt_prefix(&prompt[..8]), 0);
        assert_eq!(p.misses(), 1);
    }
}
