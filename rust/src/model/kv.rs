//! KV cache — lives on the PS (paper §III-B: "transformer controller with
//! KV caches runs on the PS").

use crate::model::LlamaConfig;

/// Abstract KV storage the forward pass decodes against.
///
/// Implemented by the contiguous per-session [`KvCache`] (the paper's
/// layout: one `n_layers × seq_len × kv_dim` slab per session) and by the
/// paged view [`crate::model::PagedKv`] (fixed-size position pages drawn
/// from a shared [`crate::model::PagePool`] with copy-on-write prefix
/// sharing).  `forward_batch` and `attention` consume this trait, so every
/// backend — host and device — goes through the same interface regardless
/// of how the cache is laid out.
pub trait KvStore {
    /// Store k/v vectors (each `kv_dim` long) for (layer, pos).
    fn store(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]);
    /// Key vector of one kv-head at (layer, pos).
    fn key(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32];
    /// Value vector of one kv-head at (layer, pos).
    fn value(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32];
    /// Highest position written + 1.
    fn filled(&self) -> usize;
    /// Forget all cached positions (storage may be retained or released).
    fn reset(&mut self);
    /// Memory footprint in bytes currently held by this cache.
    fn bytes(&self) -> usize;
}

/// Per-layer key/value cache for incremental decoding, batch size 1.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub n_layers: usize,
    pub seq_len: usize,
    pub kv_dim: usize,
    /// Highest position written + 1.
    pub filled: usize,
}

impl KvCache {
    pub fn new(cfg: &LlamaConfig) -> Self {
        let size = cfg.n_layers * cfg.seq_len * cfg.kv_dim();
        KvCache {
            k: vec![0.0; size],
            v: vec![0.0; size],
            n_layers: cfg.n_layers,
            seq_len: cfg.seq_len,
            kv_dim: cfg.kv_dim(),
            filled: 0,
        }
    }

    pub fn reset(&mut self) {
        self.filled = 0;
        // No need to zero: positions > filled are never read.
    }

    #[inline]
    fn idx(&self, layer: usize, pos: usize) -> usize {
        (layer * self.seq_len + pos) * self.kv_dim
    }

    /// Store k/v for (layer, pos).
    pub fn store(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.seq_len, "pos {pos} >= seq_len {}", self.seq_len);
        assert_eq!(k.len(), self.kv_dim);
        assert_eq!(v.len(), self.kv_dim);
        let i = self.idx(layer, pos);
        self.k[i..i + self.kv_dim].copy_from_slice(k);
        self.v[i..i + self.kv_dim].copy_from_slice(v);
        self.filled = self.filled.max(pos + 1);
    }

    /// Key vector of one kv-head at (layer, pos).
    #[inline]
    pub fn key(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32] {
        let i = self.idx(layer, pos) + kv_head * head_dim;
        &self.k[i..i + head_dim]
    }

    #[inline]
    pub fn value(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32] {
        let i = self.idx(layer, pos) + kv_head * head_dim;
        &self.v[i..i + head_dim]
    }

    /// Memory footprint in bytes (PS DDR budget accounting).
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

impl KvStore for KvCache {
    fn store(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        KvCache::store(self, layer, pos, k, v);
    }

    fn key(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32] {
        KvCache::key(self, layer, pos, kv_head, head_dim)
    }

    fn value(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32] {
        KvCache::value(self, layer, pos, kv_head, head_dim)
    }

    fn filled(&self) -> usize {
        self.filled
    }

    fn reset(&mut self) {
        KvCache::reset(self);
    }

    fn bytes(&self) -> usize {
        KvCache::bytes(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::NANO;

    #[test]
    fn store_and_read_back() {
        let mut kv = KvCache::new(&NANO);
        let hd = NANO.head_dim();
        let k: Vec<f32> = (0..NANO.kv_dim()).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..NANO.kv_dim()).map(|i| -(i as f32)).collect();
        kv.store(2, 5, &k, &v);
        assert_eq!(kv.key(2, 5, 0, hd), &k[..hd]);
        assert_eq!(kv.key(2, 5, 1, hd), &k[hd..2 * hd]);
        assert_eq!(kv.value(2, 5, 1, hd), &v[hd..2 * hd]);
        assert_eq!(kv.filled, 6);
    }

    #[test]
    fn layers_do_not_alias() {
        let mut kv = KvCache::new(&NANO);
        let hd = NANO.head_dim();
        let a = vec![1.0; NANO.kv_dim()];
        let b = vec![2.0; NANO.kv_dim()];
        kv.store(0, 0, &a, &a);
        kv.store(1, 0, &b, &b);
        assert_eq!(kv.key(0, 0, 0, hd)[0], 1.0);
        assert_eq!(kv.key(1, 0, 0, hd)[0], 2.0);
    }

    #[test]
    #[should_panic(expected = "pos")]
    fn out_of_range_pos_panics() {
        let mut kv = KvCache::new(&NANO);
        let z = vec![0.0; NANO.kv_dim()];
        kv.store(0, NANO.seq_len, &z, &z);
    }

    #[test]
    fn bytes_matches_paper_scale() {
        // TinyLlama KV cache at 2048 ctx: 22*2048*256*2*4 bytes ~ 92 MB
        let kv = KvCache::new(&crate::model::TINYLLAMA_1_1B);
        assert_eq!(kv.bytes(), 22 * 2048 * 256 * 2 * 4);
    }

    #[test]
    fn reset_clears_fill() {
        let mut kv = KvCache::new(&NANO);
        let z = vec![0.0; NANO.kv_dim()];
        kv.store(0, 3, &z, &z);
        kv.reset();
        assert_eq!(kv.filled, 0);
    }
}
