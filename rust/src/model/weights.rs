//! Weight containers.
//!
//! `QuantLayer` already stores the *fused* matrices the paper's host code
//! uses (§III-B): Wq‖Wk‖Wv as one (dim + 2·kv_dim, dim) tensor and W1‖W3
//! as one (2·hidden, dim) tensor, so each becomes a single kernel launch.

use crate::model::LlamaConfig;
use crate::quant::{FormatId, QuantizedTensor};
use crate::util::Rng;

/// One transformer layer, quantized + fused.
#[derive(Clone, Debug)]
pub struct QuantLayer {
    pub att_norm: Vec<f32>,
    /// Wq‖Wk‖Wv  (dim + 2*kv_dim, dim)
    pub wqkv: QuantizedTensor,
    /// Wo (dim, dim)
    pub wo: QuantizedTensor,
    pub ffn_norm: Vec<f32>,
    /// W1‖W3  (2*hidden_dim, dim)
    pub w13: QuantizedTensor,
    /// W2 (dim, hidden_dim)
    pub w2: QuantizedTensor,
}

impl QuantLayer {
    /// Bytes of the streamed representation (AXI billing / buffer sizing).
    pub fn stream_bytes(&self) -> usize {
        self.wqkv.stream_bytes()
            + self.wo.stream_bytes()
            + self.w13.stream_bytes()
            + self.w2.stream_bytes()
            + 4 * (self.att_norm.len() + self.ffn_norm.len())
    }

    /// Clone one matrix-granular chunk of this layer — how in-memory
    /// fetchers serve sub-layer staging requests (the disk path reads the
    /// same chunks directly via `ckpt::CkptSource::fetch_matrix`).
    pub fn chunk(&self, unit: MatrixUnit) -> LayerChunk {
        match unit {
            MatrixUnit::Norms => LayerChunk::Norms {
                att_norm: self.att_norm.clone(),
                ffn_norm: self.ffn_norm.clone(),
            },
            MatrixUnit::Qkv => LayerChunk::Mat(self.wqkv.clone()),
            MatrixUnit::Wo => LayerChunk::Mat(self.wo.clone()),
            MatrixUnit::W13 => LayerChunk::Mat(self.w13.clone()),
            MatrixUnit::W2 => LayerChunk::Mat(self.w2.clone()),
        }
    }
}

/// Matrix-granular staging unit within one transformer layer — the
/// sub-layer pipeline's unit of transfer (`--stream-granularity matrix`).
/// Order matches Algorithm 2's first use of each piece, which is also the
/// order the streaming ring delivers chunks in.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MatrixUnit {
    /// Both norm vectors (`att_norm` + `ffn_norm`) — tiny, staged first so
    /// the attention RMSNorm can start before any matrix arrives.
    Norms,
    /// The fused Wq‖Wk‖Wv block.
    Qkv,
    /// Wo.
    Wo,
    /// The fused W1‖W3 block.
    W13,
    /// W2.
    W2,
}

/// All matrix-granular units of one layer, in consumption order.
pub const MATRIX_UNITS: [MatrixUnit; 5] =
    [MatrixUnit::Norms, MatrixUnit::Qkv, MatrixUnit::Wo, MatrixUnit::W13, MatrixUnit::W2];

impl MatrixUnit {
    /// Position of this unit in the per-layer consumption order
    /// (0 = [`MatrixUnit::Norms`] … 4 = [`MatrixUnit::W2`]).
    pub fn index(self) -> usize {
        match self {
            MatrixUnit::Norms => 0,
            MatrixUnit::Qkv => 1,
            MatrixUnit::Wo => 2,
            MatrixUnit::W13 => 3,
            MatrixUnit::W2 => 4,
        }
    }

    /// Short stable label (STATS / bench output).
    pub fn name(self) -> &'static str {
        match self {
            MatrixUnit::Norms => "norms",
            MatrixUnit::Qkv => "qkv",
            MatrixUnit::Wo => "wo",
            MatrixUnit::W13 => "w13",
            MatrixUnit::W2 => "w2",
        }
    }
}

/// One fetched matrix-granular chunk: either the two norm vectors or one
/// fused weight matrix (which one is determined by the [`MatrixUnit`] the
/// caller requested).
pub enum LayerChunk {
    /// The layer's two norm vectors.
    Norms {
        /// Attention RMSNorm weights (dim).
        att_norm: Vec<f32>,
        /// FFN RMSNorm weights (dim).
        ffn_norm: Vec<f32>,
    },
    /// One (possibly fused) quantized weight matrix.
    Mat(QuantizedTensor),
}

impl LayerChunk {
    /// Bytes of this chunk's streamed representation — the per-chunk
    /// analogue of [`QuantLayer::stream_bytes`]; the five units of a layer
    /// sum exactly to the whole layer's figure.
    pub fn stream_bytes(&self) -> usize {
        match self {
            LayerChunk::Norms { att_norm, ffn_norm } => 4 * (att_norm.len() + ffn_norm.len()),
            LayerChunk::Mat(t) => t.stream_bytes(),
        }
    }
}

/// Full quantized model (all layers resident).
#[derive(Clone, Debug)]
pub struct QuantModel {
    pub cfg: LlamaConfig,
    pub tok_emb: QuantizedTensor,
    pub layers: Vec<QuantLayer>,
    pub final_norm: Vec<f32>,
    pub cls: QuantizedTensor,
}

/// One float32 layer (W32A32 baseline for Table V).
#[derive(Clone, Debug)]
pub struct FloatLayer {
    pub att_norm: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub ffn_norm: Vec<f32>,
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
    pub w3: Vec<f32>,
}

/// Full float model.
#[derive(Clone, Debug)]
pub struct FloatModel {
    pub cfg: LlamaConfig,
    pub tok_emb: Vec<f32>,
    pub layers: Vec<FloatLayer>,
    pub final_norm: Vec<f32>,
    pub cls: Vec<f32>,
}

impl QuantModel {
    /// Synthetic quantized model with N(0, std)-shaped weights, used for
    /// the TinyLlama-geometry performance experiments (DESIGN.md §5.2).
    pub fn synthetic(cfg: LlamaConfig, seed: u64) -> Self {
        Self::synthetic_fmt(cfg, seed, FormatId::Q8)
    }

    /// [`QuantModel::synthetic`] on an arbitrary weight lattice.  The Q8
    /// draw sequence is unchanged (same seed => same Q8 model as before);
    /// narrower formats fold the same int8 draws onto their lattice so
    /// the weight spread survives the clamp.
    pub fn synthetic_fmt(cfg: LlamaConfig, seed: u64, fmt: FormatId) -> Self {
        let mut rng = Rng::new(seed);
        let gs = cfg.gs;
        let std = 0.02f32;
        let qmax = fmt.qmax() as i32;
        let mk = |rng: &mut Rng, rows: usize, cols: usize| {
            // draw int8 + scales directly: statistically equivalent to
            // quantizing N(0, std) weights, ~30x faster to build at 1.1B
            let mut q = rng.i8_vec(rows * cols);
            if fmt != FormatId::Q8 {
                // fold onto the narrow lattice instead of clamping, which
                // would pile ~90% of draws onto the endpoints
                for v in &mut q {
                    *v = ((*v as i32 + 128) % (2 * qmax + 1) - qmax) as i8;
                }
            }
            let s = (0..rows * cols / gs)
                .map(|_| (rng.next_f32() * 0.5 + 0.75) * (3.0 * std / qmax as f32))
                .collect();
            QuantizedTensor { q, s, rows, cols, gs, fmt }
        };
        let layers = (0..cfg.n_layers)
            .map(|_| QuantLayer {
                att_norm: vec![1.0; cfg.dim],
                wqkv: mk(&mut rng, cfg.dim + 2 * cfg.kv_dim(), cfg.dim),
                wo: mk(&mut rng, cfg.dim, cfg.dim),
                ffn_norm: vec![1.0; cfg.dim],
                w13: mk(&mut rng, 2 * cfg.hidden_dim, cfg.dim),
                w2: mk(&mut rng, cfg.dim, cfg.hidden_dim),
            })
            .collect();
        QuantModel {
            cfg,
            tok_emb: mk(&mut rng, cfg.vocab_size, cfg.dim),
            layers,
            final_norm: vec![1.0; cfg.dim],
            cls: mk(&mut rng, cfg.vocab_size, cfg.dim),
        }
    }

    /// Quantize a float model (post-training quantization, paper §III-A).
    pub fn from_float(fm: &FloatModel) -> Self {
        Self::from_float_fmt(fm, FormatId::Q8)
    }

    /// Post-training quantization onto an arbitrary [`FormatId`] lattice.
    pub fn from_float_fmt(fm: &FloatModel, fmt: FormatId) -> Self {
        let cfg = fm.cfg;
        let gs = cfg.gs;
        let kv = cfg.kv_dim();
        let q = |data: &[f32], rows: usize, cols: usize| {
            QuantizedTensor::from_f32_fmt(data, rows, cols, gs, fmt)
        };
        let layers = fm
            .layers
            .iter()
            .map(|l| {
                let wq = q(&l.wq, cfg.dim, cfg.dim);
                let wk = q(&l.wk, kv, cfg.dim);
                let wv = q(&l.wv, kv, cfg.dim);
                let w1 = q(&l.w1, cfg.hidden_dim, cfg.dim);
                let w3 = q(&l.w3, cfg.hidden_dim, cfg.dim);
                QuantLayer {
                    att_norm: l.att_norm.clone(),
                    wqkv: QuantizedTensor::concat_rows(&[&wq, &wk, &wv]),
                    wo: q(&l.wo, cfg.dim, cfg.dim),
                    ffn_norm: l.ffn_norm.clone(),
                    w13: QuantizedTensor::concat_rows(&[&w1, &w3]),
                    w2: q(&l.w2, cfg.dim, cfg.hidden_dim),
                }
            })
            .collect();
        QuantModel {
            cfg,
            tok_emb: q(&fm.tok_emb, cfg.vocab_size, cfg.dim),
            layers,
            final_norm: fm.final_norm.clone(),
            cls: q(&fm.cls, cfg.vocab_size, cfg.dim),
        }
    }

    /// Weight lattice / wire format of this model (uniform across
    /// tensors by construction).
    pub fn fmt(&self) -> FormatId {
        self.tok_emb.fmt
    }

    pub fn total_stream_bytes(&self) -> usize {
        self.tok_emb.stream_bytes()
            + self.cls.stream_bytes()
            + 4 * self.final_norm.len()
            + self.layers.iter().map(|l| l.stream_bytes()).sum::<usize>()
    }
}

impl FloatModel {
    /// Small random float model for tests.
    pub fn random(cfg: LlamaConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let std = 0.02f32;
        let kv = cfg.kv_dim();
        let layers = (0..cfg.n_layers)
            .map(|_| FloatLayer {
                att_norm: vec![1.0; cfg.dim],
                wq: rng.normal_vec(cfg.dim * cfg.dim, std),
                wk: rng.normal_vec(kv * cfg.dim, std),
                wv: rng.normal_vec(kv * cfg.dim, std),
                wo: rng.normal_vec(cfg.dim * cfg.dim, std),
                ffn_norm: vec![1.0; cfg.dim],
                w1: rng.normal_vec(cfg.hidden_dim * cfg.dim, std),
                w2: rng.normal_vec(cfg.dim * cfg.hidden_dim, std),
                w3: rng.normal_vec(cfg.hidden_dim * cfg.dim, std),
            })
            .collect();
        FloatModel {
            cfg,
            tok_emb: rng.normal_vec(cfg.vocab_size * cfg.dim, std),
            layers,
            final_norm: vec![1.0; cfg.dim],
            cls: rng.normal_vec(cfg.vocab_size * cfg.dim, std),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::NANO;

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    #[test]
    fn from_float_fuses_shapes() {
        let cfg = tiny_cfg();
        let fm = FloatModel::random(cfg, 1);
        let qm = QuantModel::from_float(&fm);
        assert_eq!(qm.layers.len(), 2);
        assert_eq!(qm.layers[0].wqkv.rows, cfg.dim + 2 * cfg.kv_dim());
        assert_eq!(qm.layers[0].wqkv.cols, cfg.dim);
        assert_eq!(qm.layers[0].w13.rows, 2 * cfg.hidden_dim);
        assert_eq!(qm.layers[0].w2.cols, cfg.hidden_dim);
    }

    #[test]
    fn fused_qkv_rows_match_parts() {
        let cfg = tiny_cfg();
        let fm = FloatModel::random(cfg, 2);
        let qm = QuantModel::from_float(&fm);
        let wq = QuantizedTensor::from_f32(&fm.layers[0].wq, cfg.dim, cfg.dim, cfg.gs);
        // first dim rows of fused tensor == standalone Wq quantization
        assert_eq!(&qm.layers[0].wqkv.q[..wq.q.len()], &wq.q[..]);
        assert_eq!(&qm.layers[0].wqkv.s[..wq.s.len()], &wq.s[..]);
    }

    #[test]
    fn synthetic_model_shapes() {
        let qm = QuantModel::synthetic(NANO, 3);
        assert_eq!(qm.tok_emb.rows, NANO.vocab_size);
        assert_eq!(qm.layers.len(), NANO.n_layers);
        assert_eq!(qm.layers[0].w2.cols, NANO.hidden_dim);
    }

    #[test]
    fn stream_bytes_consistent_with_config() {
        let qm = QuantModel::synthetic(NANO, 4);
        let per_layer = qm.layers[0].stream_bytes();
        assert_eq!(per_layer, NANO.layer_stream_bytes());
    }

    #[test]
    fn chunks_partition_the_layer() {
        let cfg = tiny_cfg();
        let qm = QuantModel::from_float(&FloatModel::random(cfg, 6));
        let layer = &qm.layers[0];
        let total: usize = MATRIX_UNITS.iter().map(|&u| layer.chunk(u).stream_bytes()).sum();
        assert_eq!(total, layer.stream_bytes(), "unit chunks must tile the layer exactly");
        match layer.chunk(MatrixUnit::Qkv) {
            LayerChunk::Mat(t) => assert_eq!(t, layer.wqkv),
            _ => panic!("Qkv chunk must be a matrix"),
        }
        match layer.chunk(MatrixUnit::Norms) {
            LayerChunk::Norms { att_norm, ffn_norm } => {
                assert_eq!(att_norm, layer.att_norm);
                assert_eq!(ffn_norm, layer.ffn_norm);
            }
            _ => panic!("Norms chunk must carry both norm vectors"),
        }
    }

    #[test]
    fn matrix_unit_order_is_consumption_order() {
        for (i, u) in MATRIX_UNITS.iter().enumerate() {
            assert_eq!(u.index(), i);
        }
        assert_eq!(MatrixUnit::W2.name(), "w2");
    }

    #[test]
    fn synthetic_fmt_q8_is_plain_synthetic() {
        // the Q8 draw sequence is pinned: benches and golden runs depend
        // on synthetic(NANO, seed) producing the same model as ever
        let a = QuantModel::synthetic(NANO, 7);
        let b = QuantModel::synthetic_fmt(NANO, 7, FormatId::Q8);
        assert_eq!(a.tok_emb, b.tok_emb);
        assert_eq!(a.layers[0].wqkv, b.layers[0].wqkv);
        assert_eq!(a.fmt(), FormatId::Q8);
    }

    #[test]
    fn synthetic_fmt_respects_lattice_and_keeps_spread() {
        for fmt in FormatId::ALL {
            let qm = QuantModel::synthetic_fmt(tiny_cfg(), 11, fmt);
            assert_eq!(qm.fmt(), fmt);
            let qmax = fmt.qmax() as i8;
            let w = &qm.layers[0].w13;
            assert!(w.q.iter().all(|&v| (-qmax..=qmax).contains(&v)), "{fmt}");
            // folding (not clamping) must keep interior lattice points common
            let interior =
                w.q.iter().filter(|&&v| v.abs() < qmax).count() as f64 / w.q.len() as f64;
            assert!(interior > 0.5, "{fmt}: only {interior:.2} interior points");
        }
    }

    #[test]
    fn from_float_fmt_narrows_the_lattice() {
        let fm = FloatModel::random(tiny_cfg(), 12);
        let q8 = QuantModel::from_float_fmt(&fm, FormatId::Q8);
        let q4 = QuantModel::from_float_fmt(&fm, FormatId::Q40);
        assert_eq!(q8.layers[0].w2.q.len(), q4.layers[0].w2.q.len());
        assert!(q4.layers[0].w2.q.iter().all(|&v| (-7..=7).contains(&v)));
        // same reals, narrower lattice => larger step => larger scales
        assert!(q4.layers[0].w2.s[0] > q8.layers[0].w2.s[0]);
        // and the streamed footprint roughly halves
        let ratio = q4.total_stream_bytes() as f64 / q8.total_stream_bytes() as f64;
        assert!(ratio < 0.62, "q4/q8 stream bytes ratio {ratio:.3}");
    }

    #[test]
    fn quantized_model_4x_smaller() {
        let cfg = tiny_cfg();
        let fm = FloatModel::random(cfg, 5);
        let qm = QuantModel::from_float(&fm);
        let float_bytes = cfg.param_count() * 4;
        let q_bytes = qm.total_stream_bytes();
        let ratio = float_bytes as f64 / q_bytes as f64;
        assert!(ratio > 3.0 && ratio < 4.2, "ratio {ratio}");
    }
}
