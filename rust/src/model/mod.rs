//! Model definition: configuration presets, weight containers, KV cache.

pub mod config;
pub mod kv;
pub mod paged;
pub mod weights;

pub use config::{LlamaConfig, MatKind, NANO, TINYLLAMA_1_1B};
pub use kv::{KvCache, KvStore};
pub use paged::{PagePool, PagedKv, DEFAULT_PAGE_POSITIONS};
pub use weights::{
    FloatLayer, FloatModel, LayerChunk, MatrixUnit, QuantLayer, QuantModel, MATRIX_UNITS,
};
