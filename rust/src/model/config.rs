//! Llama2 architecture configuration (paper Table I geometry).

/// Hyper-parameters of a Llama2-architecture model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LlamaConfig {
    pub dim: usize,
    pub hidden_dim: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub vocab_size: usize,
    pub seq_len: usize,
    /// Quantization group size (paper uses 256).
    pub gs: usize,
}

/// The trained E2E model: every architectural feature of TinyLlama (GQA,
/// RoPE, SwiGLU, RMSNorm) with dims divisible by GS=256.
pub const NANO: LlamaConfig = LlamaConfig {
    dim: 256,
    hidden_dim: 768,
    n_layers: 4,
    n_heads: 4,
    n_kv_heads: 2,
    vocab_size: 512,
    seq_len: 256,
    gs: 256,
};

/// TinyLlama 1.1B geometry (paper §II-A / Table I): dim 2048, hidden 5632,
/// 22 layers, 32 heads with 4 KV heads, vocab 32000.  Used with synthetic
/// weights for the performance experiments.
pub const TINYLLAMA_1_1B: LlamaConfig = LlamaConfig {
    dim: 2048,
    hidden_dim: 5632,
    n_layers: 22,
    n_heads: 32,
    n_kv_heads: 4,
    vocab_size: 32000,
    seq_len: 2048,
    gs: 256,
};

/// Which GQMV the engine is issuing — determines (rows, cols) and which of
/// the paper's two kernels (kernel1: n=dim, kernel2: n=hidden_dim) serves it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MatKind {
    /// Fused Wq‖Wk‖Wv: (dim + 2*kv_dim, dim)
    Qkv,
    /// Wo: (dim, dim)
    Wo,
    /// Fused W1‖W3: (2*hidden_dim, dim)
    W13,
    /// W2: (dim, hidden_dim) — the only kernel2 user
    W2,
    /// Classifier: (vocab_size, dim)
    Cls,
}

impl LlamaConfig {
    pub fn head_dim(&self) -> usize {
        self.dim / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.head_dim() * self.n_kv_heads
    }

    /// Heads per KV head (GQA sharing factor).
    pub fn kv_rep(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.dim % self.n_heads != 0 {
            return Err(format!("dim {} % n_heads {} != 0", self.dim, self.n_heads));
        }
        if self.n_heads % self.n_kv_heads != 0 {
            return Err(format!(
                "n_heads {} % n_kv_heads {} != 0",
                self.n_heads, self.n_kv_heads
            ));
        }
        for (name, v) in [
            ("dim", self.dim),
            ("hidden_dim", self.hidden_dim),
            ("vocab_size", self.vocab_size),
        ] {
            if v % self.gs != 0 {
                return Err(format!("{name}={v} not divisible by gs={}", self.gs));
            }
        }
        if self.head_dim() % 2 != 0 {
            return Err("head_dim must be even for RoPE".into());
        }
        Ok(())
    }

    /// (rows, cols) of each GQMV the forward pass issues.
    pub fn mat_shape(&self, kind: MatKind) -> (usize, usize) {
        match kind {
            MatKind::Qkv => (self.dim + 2 * self.kv_dim(), self.dim),
            MatKind::Wo => (self.dim, self.dim),
            MatKind::W13 => (2 * self.hidden_dim, self.dim),
            MatKind::W2 => (self.dim, self.hidden_dim),
            MatKind::Cls => (self.vocab_size, self.dim),
        }
    }

    /// All distinct GQMV shapes (what the AOT manifest must provide).
    pub fn all_mat_shapes(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> =
            [MatKind::Qkv, MatKind::Wo, MatKind::W13, MatKind::W2, MatKind::Cls]
                .iter()
                .map(|&k| self.mat_shape(k))
                .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Total parameter count (float elements).
    pub fn param_count(&self) -> usize {
        let per_layer = self.dim // att_norm
            + self.dim * self.dim // wq
            + 2 * self.kv_dim() * self.dim // wk, wv
            + self.dim * self.dim // wo
            + self.dim // ffn_norm
            + 3 * self.hidden_dim * self.dim; // w1, w2, w3
        2 * self.vocab_size * self.dim + self.n_layers * per_layer + self.dim
    }

    /// Size of one transformer layer's INT8 quantized stream (int8 + f32
    /// scales + f32 norms) — the paper's per-layer DDR buffer (§III-B:
    /// 111.5 MB for all-layers-resident TinyLlama would be 1.1 GB).
    pub fn layer_stream_bytes(&self) -> usize {
        self.layer_stream_bytes_fmt(crate::quant::FormatId::Q8)
    }

    /// [`LlamaConfig::layer_stream_bytes`] on an arbitrary weight wire
    /// format — packed payload + f32 scales + f32 norms.
    pub fn layer_stream_bytes_fmt(&self, fmt: crate::quant::FormatId) -> usize {
        let f = fmt.format();
        let q = |elems: usize| elems / self.gs * (f.group_payload_bytes(self.gs) + 4);
        2 * self.dim * 4 // att_norm + ffn_norm (f32)
            + q(self.dim * self.dim) // wq
            + q(2 * self.kv_dim() * self.dim) // wk, wv
            + q(self.dim * self.dim) // wo
            + q(3 * self.hidden_dim * self.dim) // w1, w2, w3
    }

    /// Paper Table I rows: (name, rows, cols, quantized).
    pub fn table1_rows(&self) -> Vec<(&'static str, usize, usize, bool)> {
        vec![
            ("W_embeddings", self.vocab_size, self.dim, true),
            ("W_classifier", self.vocab_size, self.dim, true),
            ("W_q, W_o", self.dim, self.dim, true),
            ("W_k, W_v", self.kv_dim(), self.dim, true),
            ("W_1, W_3", self.hidden_dim, self.dim, true),
            ("W_2", self.dim, self.hidden_dim, true),
            ("W_att_norm", self.dim, 1, false),
            ("W_ffn_norm", self.dim, 1, false),
            ("W_norm_output", self.dim, 1, false),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_valid() {
        NANO.validate().unwrap();
        TINYLLAMA_1_1B.validate().unwrap();
    }

    #[test]
    fn tinyllama_geometry_matches_paper() {
        let c = TINYLLAMA_1_1B;
        assert_eq!(c.head_dim(), 64);
        assert_eq!(c.kv_dim(), 256);
        assert_eq!(c.mat_shape(MatKind::Qkv), (2560, 2048));
        assert_eq!(c.mat_shape(MatKind::W13), (11264, 2048));
        assert_eq!(c.mat_shape(MatKind::W2), (2048, 5632));
        assert_eq!(c.mat_shape(MatKind::Cls), (32000, 2048));
        // ~1.1B parameters
        let p = c.param_count();
        assert!(p > 1_000_000_000 && p < 1_200_000_000, "params {p}");
    }

    #[test]
    fn nano_geometry() {
        let c = NANO;
        assert_eq!(c.head_dim(), 64);
        assert_eq!(c.kv_dim(), 128);
        assert_eq!(c.kv_rep(), 2);
        assert_eq!(c.mat_shape(MatKind::Qkv), (512, 256));
        assert_eq!(c.mat_shape(MatKind::W13), (1536, 256));
        assert!(c.param_count() > 3_000_000 && c.param_count() < 4_000_000);
    }

    #[test]
    fn layer_stream_bytes_paper_scale() {
        // Paper §III-B: the quoted 111.5MB buffer covers ~2 layer slots +
        // embeddings; one TinyLlama layer block is ~45 MB.
        let b = TINYLLAMA_1_1B.layer_stream_bytes();
        assert!(b > 40_000_000 && b < 50_000_000, "bytes {b}");
    }

    #[test]
    fn q4_layer_stream_roughly_halves_q8() {
        use crate::quant::FormatId;
        let c = TINYLLAMA_1_1B;
        assert_eq!(c.layer_stream_bytes(), c.layer_stream_bytes_fmt(FormatId::Q8));
        let q8 = c.layer_stream_bytes_fmt(FormatId::Q8) as f64;
        let q4 = c.layer_stream_bytes_fmt(FormatId::Q40) as f64;
        let q5 = c.layer_stream_bytes_fmt(FormatId::Q50) as f64;
        assert!(q4 / q8 <= 0.55, "q4/q8 = {:.3}", q4 / q8);
        assert!(q5 < q8 && q4 < q5);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = NANO;
        c.dim = 250; // not divisible by gs / heads
        assert!(c.validate().is_err());
        let mut c2 = NANO;
        c2.n_kv_heads = 3;
        assert!(c2.validate().is_err());
    }

    #[test]
    fn all_mat_shapes_dedup() {
        // nano: qkv (512,256) == cls (512,256) -> deduped
        let shapes = NANO.all_mat_shapes();
        assert_eq!(shapes.len(), 4);
    }
}
