//! Wall-clock timing helpers.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn reset(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::new();
    let out = f();
    (out, t.elapsed_secs())
}

/// Human-readable duration (e.g. "1.23 ms").
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::new();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(2.5).ends_with(" s"));
        assert!(fmt_duration(2.5e-3).ends_with(" ms"));
        assert!(fmt_duration(2.5e-6).ends_with(" us"));
        assert!(fmt_duration(2.5e-9).ends_with(" ns"));
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
