//! Online and batch statistics (Welford), used by the bench harness,
//! quantization error analysis (Table IV) and metrics.

/// Numerically stable online mean/variance/min/max accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: impl IntoIterator<Item = f64>) {
        for x in xs {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (the paper's Table IV reports population std).
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample (linear interpolation, p in [0, 100]).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Sort a copy and return (p50, p90, p99).
pub fn p50_p90_p99(samples: &[f64]) -> (f64, f64, f64) {
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (percentile(&v, 50.0), percentile(&v, 90.0), percentile(&v, 99.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        let mut s = OnlineStats::new();
        s.extend([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn merge_equals_concat() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) * 0.37 - 3.0).collect();
        let mut all = OnlineStats::new();
        all.extend(xs.iter().copied());
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        a.extend(xs[..37].iter().copied());
        b.extend(xs[37..].iter().copied());
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.std() - all.std()).abs() < 1e-9);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&v, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
    }

    #[test]
    fn empty_variance_is_zero() {
        let s = OnlineStats::new();
        assert_eq!(s.variance(), 0.0);
    }
}
