//! Small self-contained substrates: PRNG, statistics, timers, thread pool.
//!
//! The offline build has no `rand`/`rayon`/`criterion`, so these are
//! implemented from scratch and unit-tested here.

pub mod pool;
pub mod rng;
pub mod stats;
pub mod timer;

pub use pool::ThreadPool;
pub use rng::Rng;
pub use stats::OnlineStats;
pub use timer::Timer;
