//! Persistent scoped thread pool — the OpenMP analogue for the PS engine.
//!
//! The paper's PS baseline parallelizes GQMV row loops over the four
//! Cortex-A53 cores with OpenMP (`#pragma omp parallel for`).  `rayon` is
//! not available offline, so this is a small persistent pool with a scoped
//! `parallel_for`: the calling thread blocks until every chunk completes,
//! which is what makes lending non-`'static` closures to the workers sound.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Done {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

/// Fixed-size persistent worker pool.
pub struct ThreadPool {
    senders: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (the PS model uses 4, matching the A53 cluster).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = channel::<Job>();
            senders.push(tx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("llamaf-worker-{i}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { senders, handles }
    }

    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Run `f` over `0..n` split into one contiguous chunk per worker and
    /// block until all chunks finish.  `f` may borrow from the caller's
    /// stack: the blocking wait guarantees those borrows outlive the jobs.
    ///
    /// Falls back to inline execution when `n < serial_below` (threading a
    /// 256-row nano matvec costs more than it saves; see EXPERIMENTS.md
    /// §Perf).
    pub fn parallel_for<F>(&self, n: usize, serial_below: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let k = self.senders.len().min(n);
        if n < serial_below || k == 1 {
            f(0..n);
            return;
        }
        let done = Arc::new(Done {
            remaining: Mutex::new(k),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let chunk = n.div_ceil(k);
        // SAFETY: every job signals `done` (even on panic, via Guard), and
        // we block below until all k jobs have signalled, so the borrowed
        // `f` outlives every use inside the workers.
        let f_ptr: &(dyn Fn(Range<usize>) + Sync) = &f;
        let f_static: &'static (dyn Fn(Range<usize>) + Sync) =
            unsafe { std::mem::transmute(f_ptr) };
        for (i, tx) in self.senders.iter().take(k).enumerate() {
            let lo = i * chunk;
            let hi = ((i + 1) * chunk).min(n);
            let done = Arc::clone(&done);
            let job: Job = Box::new(move || {
                struct Guard(Arc<Done>);
                impl Drop for Guard {
                    fn drop(&mut self) {
                        if std::thread::panicking() {
                            self.0.panicked.store(true, Ordering::SeqCst);
                        }
                        let mut rem = self.0.remaining.lock().unwrap();
                        *rem -= 1;
                        if *rem == 0 {
                            self.0.cv.notify_all();
                        }
                    }
                }
                let _guard = Guard(done);
                if lo < hi {
                    f_static(lo..hi);
                }
            });
            tx.send(job).expect("worker channel closed");
        }
        let mut rem = done.remaining.lock().unwrap();
        while *rem > 0 {
            rem = done.cv.wait(rem).unwrap();
        }
        drop(rem);
        if done.panicked.load(Ordering::SeqCst) {
            panic!("worker panicked inside parallel_for");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.senders.clear(); // closes channels, workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn covers_all_indices_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(1000, 0, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn serial_fallback() {
        let pool = ThreadPool::new(4);
        let count = AtomicUsize::new(0);
        pool.parallel_for(10, 100, |range| {
            count.fetch_add(range.len(), Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn reusable_many_times() {
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let sum = AtomicUsize::new(0);
            pool.parallel_for(round + 1, 0, |range| {
                sum.fetch_add(range.map(|i| i + 1).sum::<usize>(), Ordering::Relaxed);
            });
            let n = round + 1;
            assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2);
        }
    }

    #[test]
    fn writes_to_disjoint_slices() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0u64; 4096];
        let ptr = SendPtr(out.as_mut_ptr());
        pool.parallel_for(4096, 0, |range| {
            let p = &ptr;
            for i in range {
                // SAFETY: ranges are disjoint per worker.
                unsafe { *p.0.add(i) = i as u64 * 3 };
            }
        });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64 * 3));
    }

    struct SendPtr(*mut u64);
    unsafe impl Sync for SendPtr {}

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn propagates_panic() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(2, 0, |range| {
            if range.start == 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn n_zero_is_noop() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, 0, |_| panic!("should not run"));
    }
}
