//! Persistent scoped thread pool — the OpenMP analogue for the PS engine.
//!
//! The paper's PS baseline parallelizes GQMV row loops over the four
//! Cortex-A53 cores with OpenMP (`#pragma omp parallel for`).  `rayon` is
//! not available offline, so this is a small persistent pool with a scoped
//! `parallel_for`: the calling thread blocks until every chunk completes,
//! which is what makes lending non-`'static` closures to the workers sound.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Done {
    remaining: Mutex<usize>,
    cv: Condvar,
    panicked: AtomicBool,
}

/// Signals `Done` when a job finishes — even on panic, via `Drop` — so the
/// dispatching thread's blocking wait always terminates.
struct DoneGuard(Arc<Done>);

impl Drop for DoneGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.panicked.store(true, Ordering::SeqCst);
        }
        let mut rem = self.0.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.0.cv.notify_all();
        }
    }
}

/// Fixed-size persistent worker pool.
pub struct ThreadPool {
    senders: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (the PS model uses 4, matching the A53 cluster).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = channel::<Job>();
            senders.push(tx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("llamaf-worker-{i}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool { senders, handles }
    }

    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Run `f` over `0..n` split into one contiguous chunk per worker and
    /// block until all chunks finish.  `f` may borrow from the caller's
    /// stack: the blocking wait guarantees those borrows outlive the jobs.
    ///
    /// Falls back to inline execution when `n < serial_below` (threading a
    /// 256-row nano matvec costs more than it saves; see EXPERIMENTS.md
    /// §Perf).
    pub fn parallel_for<F>(&self, n: usize, serial_below: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let k = self.senders.len().min(n);
        if n < serial_below || k == 1 {
            f(0..n);
            return;
        }
        let done = Arc::new(Done {
            remaining: Mutex::new(k),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let chunk = n.div_ceil(k);
        // SAFETY: every job signals `done` (even on panic, via DoneGuard),
        // and we block below until all k jobs have signalled, so the
        // borrowed `f` outlives every use inside the workers.
        let f_ptr: &(dyn Fn(Range<usize>) + Sync) = &f;
        let f_static: &'static (dyn Fn(Range<usize>) + Sync) =
            unsafe { std::mem::transmute(f_ptr) };
        for (i, tx) in self.senders.iter().take(k).enumerate() {
            let lo = i * chunk;
            let hi = ((i + 1) * chunk).min(n);
            // The guard is created BEFORE the job is queued and travels
            // inside it, so a job that is dropped unexecuted (its worker
            // died unwinding an earlier panic) still signals `done` when
            // the dead worker's queue is torn down — the wait below can
            // never hang on a job that will never run.  A rejected send
            // (worker already gone) drops the job here, same effect.
            let guard = DoneGuard(Arc::clone(&done));
            let job: Job = Box::new(move || {
                let _guard = guard;
                if lo < hi {
                    f_static(lo..hi);
                }
            });
            if tx.send(job).is_err() {
                // dropping the rejected job signalled `done`; record the
                // dead worker so wait() panics instead of silently
                // returning with this chunk's work skipped
                done.panicked.store(true, Ordering::SeqCst);
            }
        }
        self.wait(&done, "parallel_for");
    }

    /// Run `f` once per element of `parts`, distributing the parts across
    /// the workers and blocking until every invocation finishes.
    ///
    /// This is the *safe* disjoint-work primitive: the caller pre-splits
    /// its mutable state into owned per-part values (e.g. contiguous
    /// `&mut [f32]` output chunks obtained with `split_at_mut`), so no two
    /// workers can alias — no raw-pointer `Sync` wrappers needed.  Like
    /// [`ThreadPool::parallel_for`], parts may borrow from the caller's
    /// stack: the blocking wait keeps those borrows alive past every use.
    pub fn run_parts<W, F>(&self, parts: Vec<W>, f: F)
    where
        W: Send,
        F: Fn(W) + Sync,
    {
        let k = parts.len();
        if k == 0 {
            return;
        }
        if k == 1 || self.senders.len() == 1 {
            for part in parts {
                f(part);
            }
            return;
        }
        let done = Arc::new(Done {
            remaining: Mutex::new(k),
            cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let workers = self.senders.len();
        for (i, part) in parts.into_iter().enumerate() {
            // As in parallel_for: the guard rides inside the job, so a
            // part stranded in a panicked worker's queue (more parts than
            // workers) signals `done` when the queue is dropped instead
            // of hanging the wait; a rejected send drops the job (and
            // signals) right here.  The (part, guard) tuple pins the drop
            // order — tuple elements drop first-to-last — so the part is
            // fully dropped BEFORE `done` is signalled and the caller's
            // borrowed data can never be freed under a still-dropping W.
            let payload = (part, DoneGuard(Arc::clone(&done)));
            let f_ref = &f;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let (part, _guard) = payload;
                f_ref(part);
            });
            // SAFETY: we block below until every job has signalled `done`,
            // so the borrows of `f` and the parts outlive every use inside
            // the workers; the transmute only erases that lifetime.
            let job: Job = unsafe { std::mem::transmute(job) };
            if self.senders[i % workers].send(job).is_err() {
                // dead worker: the dropped job signalled `done`; fail the
                // wait loudly rather than skip this part's work silently
                done.panicked.store(true, Ordering::SeqCst);
            }
        }
        self.wait(&done, "run_parts");
    }

    /// Block until all jobs tracked by `done` have signalled, then
    /// propagate any worker panic to the caller.
    fn wait(&self, done: &Done, what: &str) {
        let mut rem = done.remaining.lock().unwrap();
        while *rem > 0 {
            rem = done.cv.wait(rem).unwrap();
        }
        drop(rem);
        if done.panicked.load(Ordering::SeqCst) {
            panic!("worker panicked inside {what}");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.senders.clear(); // closes channels, workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn covers_all_indices_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(1000, 0, |range| {
            for i in range {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn serial_fallback() {
        let pool = ThreadPool::new(4);
        let count = AtomicUsize::new(0);
        pool.parallel_for(10, 100, |range| {
            count.fetch_add(range.len(), Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn reusable_many_times() {
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let sum = AtomicUsize::new(0);
            pool.parallel_for(round + 1, 0, |range| {
                sum.fetch_add(range.map(|i| i + 1).sum::<usize>(), Ordering::Relaxed);
            });
            let n = round + 1;
            assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2);
        }
    }

    #[test]
    fn writes_to_disjoint_slices() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0u64; 4096];
        let ptr = SendPtr(out.as_mut_ptr());
        pool.parallel_for(4096, 0, |range| {
            let p = &ptr;
            for i in range {
                // SAFETY: ranges are disjoint per worker.
                unsafe { *p.0.add(i) = i as u64 * 3 };
            }
        });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64 * 3));
    }

    struct SendPtr(*mut u64);
    unsafe impl Sync for SendPtr {}

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn propagates_panic() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(2, 0, |range| {
            if range.start == 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn n_zero_is_noop() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, 0, |_| panic!("should not run"));
    }

    #[test]
    fn run_parts_covers_disjoint_slices() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0u64; 1000];
        let mut parts: Vec<(usize, &mut [u64])> = Vec::new();
        let mut rest = &mut out[..];
        let mut off = 0;
        while !rest.is_empty() {
            let take = 137.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            parts.push((off, head));
            off += take;
            rest = tail;
        }
        pool.run_parts(parts, |(off, chunk)| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v = (off + i) as u64 * 3;
            }
        });
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64 * 3));
    }

    #[test]
    fn run_parts_empty_and_single() {
        let pool = ThreadPool::new(2);
        pool.run_parts(Vec::<usize>::new(), |_| panic!("should not run"));
        let count = AtomicUsize::new(0);
        pool.run_parts(vec![7usize], |v| {
            count.fetch_add(v, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn run_parts_more_parts_than_workers() {
        let pool = ThreadPool::new(2);
        let sum = AtomicUsize::new(0);
        pool.run_parts((1..=20usize).collect(), |v| {
            sum.fetch_add(v, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 210);
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn run_parts_propagates_panic() {
        let pool = ThreadPool::new(2);
        pool.run_parts(vec![0usize, 1], |v| {
            if v == 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn run_parts_panic_with_queued_parts_does_not_hang() {
        // part 0 panics worker 0 while part 2 is still queued behind it;
        // the stranded job is dropped unexecuted when the worker unwinds
        // and must still signal completion — a hang here (instead of the
        // propagated panic) is the regression this test pins
        let pool = ThreadPool::new(2);
        pool.run_parts((0..4usize).collect(), |v| {
            if v == 0 {
                panic!("boom");
            }
        });
    }
}
