//! Deterministic PRNG: xoshiro256++ seeded via splitmix64.
//!
//! Used for synthetic weights, workload generation and property tests.
//! Determinism across runs/platforms is required so experiments and
//! property-test failures are reproducible from a printed seed.

/// xoshiro256++ generator (public-domain algorithm by Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's nearly-divisionless method would be overkill; modulo bias
        // is negligible for n << 2^64 (we never exceed ~2^32).
        self.next_u64() % n
    }

    /// Uniform in [lo, hi).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + (self.below((hi - lo) as u64) as i64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let mut u1 = self.next_f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of standard normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Vector of uniform int8 values in [-127, 127].
    pub fn i8_vec(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.range_i64(-127, 128) as i8).collect()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}
