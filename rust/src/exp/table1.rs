//! Table I — Llama2 weight matrix specifications.

use anyhow::Result;

use crate::cli::Args;
use crate::exp::header;
use crate::model::{NANO, TINYLLAMA_1_1B};

pub fn run(args: &Args) -> Result<()> {
    header("Table I: Llama2 weight matrix specifications");
    for (name, cfg) in
        [("TinyLlama 1.1B (paper)", TINYLLAMA_1_1B), ("nano (trained E2E model)", NANO)]
    {
        println!(
            "\n  {name}:  dim={} hidden={} layers={} heads={}/{} vocab={}",
            cfg.dim, cfg.hidden_dim, cfg.n_layers, cfg.n_heads, cfg.n_kv_heads, cfg.vocab_size
        );
        println!("  {:<16} {:>10} {:>10}   {:<10}", "Matrix", "rows", "cols", "quantized");
        for (mname, rows, cols, quant) in cfg.table1_rows() {
            println!(
                "  {:<16} {:>10} {:>10}   {}",
                mname,
                rows,
                cols,
                if quant { "yes" } else { "no" }
            );
        }
        println!(
            "  params: {:.2}M   f32 size: {:.2} GB   W8A8 (GS={}) size: {:.2} GB",
            cfg.param_count() as f64 / 1e6,
            cfg.param_count() as f64 * 4.0 / 1e9,
            cfg.gs,
            (cfg.param_count() as f64 * (1.0 + 4.0 / cfg.gs as f64)) / 1e9,
        );
    }
    let _ = args;
    Ok(())
}
