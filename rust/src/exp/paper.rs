//! The paper's reported numbers, used for side-by-side printing and for
//! the shape assertions in tests.  Source: LlamaF (CS.AR 2024), §V.

/// Table II — PS-only forward-pass runtime distribution (%).
pub const TABLE2: [(&str, [f64; 3]); 5] = [
    ("Matrix Computation", [98.98, 98.53, 97.64]),
    ("Multi-head Attention", [0.47, 0.92, 1.82]),
    ("SwiGLU", [0.13, 0.13, 0.13]),
    ("RoPE", [0.07, 0.07, 0.07]),
    ("RMSNorm", [0.06, 0.06, 0.05]),
];
pub const TABLE2_POSITIONS: [usize; 3] = [63, 127, 255];

/// Table III — utilization % on ZCU102.
pub const TABLE3: [(&str, f64); 4] =
    [("LUT", 59.72), ("FF", 31.31), ("BRAM", 24.45), ("DSP", 20.95)];

/// Table IV — group-wise quantization error stats (GS=256) on TinyLlama.
pub const TABLE4_MAX: f64 = 0.0115;
pub const TABLE4_MIN: f64 = 0.0;
pub const TABLE4_MEAN: f64 = 0.000265;
pub const TABLE4_STD: f64 = 0.000173;
pub const ERR_PCT_MEAN: f64 = 3.30;
pub const ERR_PCT_STD: f64 = 11.57;

/// Table V — TinyLlama WikiText-2 PPL.
pub const TABLE5_PPL_F32: f64 = 7.05;
pub const TABLE5_PPL_Q8: f64 = 7.09;

/// Table VI — inference speed & power.
pub const PS_GOPS: f64 = 0.201;
pub const LLAMAF_GOPS: f64 = 4.696;
pub const PS_TOKS: [f64; 3] = [0.0935, 0.0933, 0.0928]; // steps 64/128/256
pub const LLAMAF_NOSCHED_TOKS: [f64; 3] = [0.936, 0.915, 0.853];
pub const LLAMAF_TOKS: [f64; 3] = [1.478, 1.424, 1.328];
pub const PS_EFF: f64 = 0.0480;
pub const LLAMAF_EFF: f64 = 0.291;
pub const STEPS: [usize; 3] = [64, 128, 256];

/// Calibrated PS GQMV throughput (GOPS) used by the paper-scale model —
/// back-derived from Table II/VI: matrix time = 98.98% of 1/0.0935 s.
pub const PS_MODEL_GOPS: f64 = 0.1954;

/// Multi-head-attention time per position step on the PS (seconds/pos,
/// all layers, OpenMP x4) — from Table II: 0.47% of 10.695 s at pos 63.
pub const PS_MHA_S_PER_POS: f64 = 0.0503 / 64.0;

/// Constant small-op time per token on the PS (SwiGLU+RoPE+RMSNorm).
pub const PS_SMALLOPS_S: f64 = 0.0278;
