//! Table V — PPL of the quantized vs float model (WikiText-2 → held-out
//! synthetic corpus; DESIGN.md §5 substitution 3).

use anyhow::Result;
use std::path::Path;

use crate::cli::Args;
use crate::ckpt;
use crate::engine::forward::CpuEngine;
use crate::engine::ppl::perplexity;
use crate::exp::{header, paper};
use crate::ps::float::FloatEngine;
use crate::ps::ScalarGqmv;
use crate::tokenizer::Tokenizer;

pub struct PplResult {
    pub ppl_f32: f64,
    pub ppl_q8: f64,
}

pub fn eval(
    f32_ckpt: &Path,
    q8_ckpt: &Path,
    corpus: &Path,
    max_tokens: usize,
) -> Result<PplResult> {
    let fm = ckpt::read_f32_model(f32_ckpt)?;
    let qm = ckpt::read_q8(q8_ckpt)?;
    anyhow::ensure!(fm.cfg == qm.cfg, "checkpoint configs differ");
    let text = std::fs::read_to_string(corpus)?;
    let tok = Tokenizer::new(fm.cfg.vocab_size);
    let ids = tok.encode(&text, true);

    let mut fe = FloatEngine::new(fm);
    let ppl_f32 = perplexity(&mut fe, &ids, max_tokens)?;
    let mut qe = CpuEngine::new(qm, Box::new(ScalarGqmv));
    let ppl_q8 = perplexity(&mut qe, &ids, max_tokens)?;
    Ok(PplResult { ppl_f32, ppl_q8 })
}

pub fn run(args: &Args) -> Result<()> {
    header("Table V: model perplexity, W32A32 vs W8A8 (lower is better)");
    let f32_ckpt = args.get_or("f32-ckpt", "artifacts/nano_f32.lfck");
    let q8_ckpt = args.get_or("ckpt", "artifacts/nano_q8.lfq8");
    let corpus = args.get_or("corpus", "artifacts/corpus_val.txt");
    let max_tokens = args.get_usize("ppl-tokens", 2048)?;
    for p in [f32_ckpt, q8_ckpt, corpus] {
        if !Path::new(p).exists() {
            println!("  missing {p}; run `make artifacts` first");
            return Ok(());
        }
    }
    println!("  eval: {max_tokens} predictions on held-out synthetic corpus ({corpus})\n");
    let r = eval(Path::new(f32_ckpt), Path::new(q8_ckpt), Path::new(corpus), max_tokens)?;
    let delta = 100.0 * (r.ppl_q8 - r.ppl_f32) / r.ppl_f32;
    println!("  {:<28} {:>14} {:>18}", "Model", "W32A32 PPL", "W8A8 (GS=256) PPL");
    println!(
        "  {:<28} {:>14.4} {:>18.4}   (delta {:+.2}%)",
        "nano (this repro)", r.ppl_f32, r.ppl_q8, delta
    );
    println!(
        "  {:<28} {:>14.2} {:>18.2}   (delta {:+.2}%)",
        "TinyLlama / WikiText-2 (paper)",
        paper::TABLE5_PPL_F32,
        paper::TABLE5_PPL_Q8,
        100.0 * (paper::TABLE5_PPL_Q8 - paper::TABLE5_PPL_F32) / paper::TABLE5_PPL_F32
    );
    println!("\n  shape check: quantization costs well under ~2% PPL.");
    Ok(())
}
