//! Experiment drivers — one per paper table/figure (DESIGN.md §4).
//!
//! Every driver prints the paper's reported values next to this
//! reproduction's measured/modeled values so the "shape" claims are
//! auditable from the terminal:
//!
//! ```bash
//! llamaf tables --table 6          # inference speed & power (Table VI)
//! llamaf tables --fig 2            # sync vs async timeline (Fig. 2)
//! llamaf tables --all
//! ```

pub mod fig2;
pub mod paper;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;

use anyhow::Result;

use crate::cli::Args;

/// Dispatch `llamaf tables ...`.
pub fn run(args: &Args) -> Result<()> {
    if let Some(fig) = args.get("fig") {
        match fig {
            "2" => return fig2::run(args),
            other => anyhow::bail!("unknown figure {other} (have: 2)"),
        }
    }
    let table = args.get("table");
    let all = table.is_none();
    let want = |t: &str| all || table == Some(t);
    if want("1") {
        table1::run(args)?;
    }
    if want("2") {
        table2::run(args)?;
    }
    if want("3") {
        table3::run(args)?;
    }
    if want("4") {
        table4::run(args)?;
    }
    if want("5") {
        table5::run(args)?;
    }
    if want("6") {
        table6::run(args)?;
    }
    if all {
        fig2::run(args)?;
    }
    Ok(())
}

pub(crate) fn header(title: &str) {
    println!("\n================================================================");
    println!("{title}");
    println!("================================================================");
}
