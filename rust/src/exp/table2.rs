//! Table II — forward-pass runtime distribution on the PS baseline.
//!
//! Measures the component breakdown at positions 63/127/255 by running the
//! threaded PS engine and profiling single-token forwards at those
//! positions.  Default geometry is the trained nano checkpoint (fast);
//! `--geometry tinyllama` runs the paper geometry with synthetic weights.

use anyhow::Result;
use std::sync::Arc;

use crate::cli::Args;
use crate::engine::forward::{CpuEngine, Engine};
use crate::exp::{header, paper};
use crate::metrics::ForwardProfile;
use crate::model::QuantModel;
use crate::ps::ThreadedGqmv;
use crate::util::ThreadPool;

pub fn load_model(args: &Args) -> Result<QuantModel> {
    match args.get_or("geometry", "nano") {
        "tinyllama" => Ok(QuantModel::synthetic(crate::model::TINYLLAMA_1_1B, 42)),
        _ => {
            let ckpt = args.get_or("ckpt", "artifacts/nano_q8.lfq8");
            let path = std::path::Path::new(ckpt);
            if path.exists() {
                crate::ckpt::read_q8(path)
            } else {
                eprintln!("  (checkpoint {ckpt} missing; using synthetic nano weights)");
                Ok(QuantModel::synthetic(crate::model::NANO, 42))
            }
        }
    }
}

/// Measured per-position profiles: Vec of (pos, profile).
pub fn measure(
    model: QuantModel,
    positions: &[usize],
    threads: usize,
) -> Result<Vec<(usize, ForwardProfile)>> {
    let pool = Arc::new(ThreadPool::new(threads));
    let mut engine = CpuEngine::new(model, Box::new(ThreadedGqmv::new(pool)));
    let max_pos = *positions.iter().max().unwrap();
    anyhow::ensure!(max_pos < engine.cfg().seq_len, "position beyond seq_len");
    let vocab = engine.cfg().vocab_size as u64;
    let mut rng = crate::util::Rng::new(123);
    let mut out = Vec::new();
    let mut scrap = ForwardProfile::default();
    let mut tok = 1u32;
    for pos in 0..=max_pos {
        if positions.contains(&pos) {
            let mut prof = ForwardProfile::default();
            let logits = engine.forward(tok, pos, &mut prof)?;
            tok = crate::tensor::argmax(logits) as u32;
            out.push((pos, prof));
        } else {
            let logits = engine.forward(tok, pos, &mut scrap)?;
            // greedy continuation keeps the run realistic; random fallback
            tok = if pos % 7 == 0 {
                rng.below(vocab) as u32
            } else {
                crate::tensor::argmax(logits) as u32
            };
        }
    }
    Ok(out)
}

pub fn run(args: &Args) -> Result<()> {
    header("Table II: Llama2 forward-pass profiling (PS baseline)");
    let model = load_model(args)?;
    let geometry = args.get_or("geometry", "nano");
    let threads = args.get_usize("threads", 4)?; // quad A53 analogue
    println!("  geometry={geometry}  threads={threads}  (paper: TinyLlama on 4x A53 + OpenMP)\n");
    let profiles = measure(model, &paper::TABLE2_POSITIONS, threads)?;

    println!(
        "  {:<22} {:>16} {:>16} {:>16}",
        "Computation", "pos=63", "pos=127", "pos=255"
    );
    let rows: Vec<(&str, Box<dyn Fn(&ForwardProfile) -> f64>)> = vec![
        ("Matrix Computation", Box::new(|p: &ForwardProfile| p.matrix_s)),
        ("Multi-head Attention", Box::new(|p: &ForwardProfile| p.attention_s)),
        ("SwiGLU", Box::new(|p: &ForwardProfile| p.swiglu_s)),
        ("RoPE", Box::new(|p: &ForwardProfile| p.rope_s)),
        ("RMSNorm", Box::new(|p: &ForwardProfile| p.rmsnorm_s)),
    ];
    for (i, (name, get)) in rows.iter().enumerate() {
        let mut cells = String::new();
        for (_, prof) in &profiles {
            let compute =
                prof.matrix_s + prof.attention_s + prof.swiglu_s + prof.rope_s + prof.rmsnorm_s;
            cells.push_str(&format!("{:>8.2}% ", 100.0 * get(prof) / compute));
            let paper_vals = paper::TABLE2[i].1;
            let _ = paper_vals;
        }
        let paper_row = paper::TABLE2[i].1;
        println!(
            "  {:<22} {}   (paper: {:.2}/{:.2}/{:.2})",
            name, cells, paper_row[0], paper_row[1], paper_row[2]
        );
    }
    println!("\n  shape check: matrix computation dominates; attention share grows with pos.");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LlamaConfig, QuantModel};

    #[test]
    fn matrix_dominates_and_attention_grows() {
        let cfg = LlamaConfig {
            dim: 256,
            hidden_dim: 768,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            vocab_size: 512,
            seq_len: 128,
            gs: 256,
        };
        let model = QuantModel::synthetic(cfg, 1);
        let profiles = measure(model, &[15, 100], 2).unwrap();
        for (_, p) in &profiles {
            let compute = p.matrix_s + p.attention_s + p.swiglu_s + p.rope_s + p.rmsnorm_s;
            assert!(p.matrix_s / compute > 0.5, "matrix share {}", p.matrix_s / compute);
        }
        // attention time grows with position
        assert!(profiles[1].1.attention_s > profiles[0].1.attention_s);
    }
}
