//! Table III — hardware utilization of LlamaF on ZCU102 (analytic model).

use anyhow::Result;

use crate::cli::Args;
use crate::exp::header;
use crate::fpga::ResourceModel;

pub fn run(args: &Args) -> Result<()> {
    header("Table III: hardware utilization of LlamaF on ZCU102 (model vs paper)");
    let gs = args.get_usize("gs", 256)? as u64;
    let model = ResourceModel { gs, ..Default::default() };
    let u = model.utilization();
    println!(
        "  design: GS={gs}, {} kernels, max {} groups/row, max n={}\n",
        model.kernels, model.max_groups, model.max_n
    );
    println!(
        "  {:<6} {:>12} {:>12} {:>12} {:>12}",
        "", "total", "model used", "model %", "paper %"
    );
    let totals = [
        ("LUT", crate::fpga::resources::ZCU102_LUT, u.lut),
        ("FF", crate::fpga::resources::ZCU102_FF, u.ff),
        ("BRAM", crate::fpga::resources::ZCU102_BRAM, u.bram),
        ("DSP", crate::fpga::resources::ZCU102_DSP, u.dsp),
    ];
    for ((name, total, used), (_, model_pct, paper_pct)) in totals.iter().zip(model.table3()) {
        println!(
            "  {:<6} {:>12} {:>12} {:>11.2}% {:>11.2}%",
            name, total, used, model_pct, paper_pct
        );
    }
    println!("\n  (component estimates documented in rust/src/fpga/resources.rs)");
    Ok(())
}
