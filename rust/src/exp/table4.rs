//! Table IV — statistics of group-wise quantization error (GS=256).

use anyhow::Result;
use std::path::Path;

use crate::cli::Args;
use crate::ckpt;
use crate::exp::{header, paper};
use crate::model::FloatModel;
use crate::quant::QuantErrorStats;

/// Accumulate Table IV stats over every quantized tensor of a float model.
pub fn stats_for_model(fm: &FloatModel) -> QuantErrorStats {
    let cfg = fm.cfg;
    let gs = cfg.gs;
    let mut st = QuantErrorStats::default();
    st.add_tensor(&fm.tok_emb, cfg.vocab_size, cfg.dim, gs);
    st.add_tensor(&fm.cls, cfg.vocab_size, cfg.dim, gs);
    for l in &fm.layers {
        st.add_tensor(&l.wq, cfg.dim, cfg.dim, gs);
        st.add_tensor(&l.wk, cfg.kv_dim(), cfg.dim, gs);
        st.add_tensor(&l.wv, cfg.kv_dim(), cfg.dim, gs);
        st.add_tensor(&l.wo, cfg.dim, cfg.dim, gs);
        st.add_tensor(&l.w1, cfg.hidden_dim, cfg.dim, gs);
        st.add_tensor(&l.w2, cfg.dim, cfg.hidden_dim, gs);
        st.add_tensor(&l.w3, cfg.hidden_dim, cfg.dim, gs);
    }
    st
}

pub fn run(args: &Args) -> Result<()> {
    header("Table IV: statistics of group-wise quantization error (GS=256)");
    let ckpt_path = args.get_or("f32-ckpt", "artifacts/nano_f32.lfck");
    let fm = if Path::new(ckpt_path).exists() {
        println!("  checkpoint: {ckpt_path} (trained nano)");
        ckpt::read_f32_model(Path::new(ckpt_path))?
    } else {
        println!("  checkpoint {ckpt_path} missing; using synthetic N(0, 0.02) nano weights");
        FloatModel::random(crate::model::NANO, 7)
    };
    let st = stats_for_model(&fm);
    println!("\n  {:<24} {:>12} {:>12} {:>12} {:>12}", "Method", "Max", "Min", "Mean", "Std");
    println!(
        "  {:<24} {:>12.6} {:>12.6} {:>12.6} {:>12.6}",
        "INT8 (this repro)",
        st.abs.max(),
        st.abs.min(),
        st.abs.mean(),
        st.abs.std()
    );
    println!(
        "  {:<24} {:>12.6} {:>12.6} {:>12.6} {:>12.6}",
        "INT8 (paper, TinyLlama)",
        paper::TABLE4_MAX,
        paper::TABLE4_MIN,
        paper::TABLE4_MEAN,
        paper::TABLE4_STD
    );
    println!(
        "\n  error %%: mean {:.2}% std {:.2}%   (paper: mean {:.2}% std {:.2}%)",
        st.pct.mean(),
        st.pct.std(),
        paper::ERR_PCT_MEAN,
        paper::ERR_PCT_STD
    );
    println!("  note: absolute stats scale with weight magnitude (1.1B vs 4M params);");
    println!("  the relative (percentage) distribution is the transferable quantity.");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_pct_in_paper_range() {
        // The % error distribution is weight-scale invariant; for trained
        // or N(0,sigma) weights at GS=256 it lands near the paper's 3.3%.
        let fm = FloatModel::random(crate::model::NANO, 3);
        let st = stats_for_model(&fm);
        assert!(st.pct.mean() > 1.0 && st.pct.mean() < 8.0, "pct mean {}", st.pct.mean());
        assert!(st.abs.min() >= 0.0);
        assert!(st.abs.max() < 0.02); // sigma=0.02 weights: max err ~ max|w|/254
    }
}
