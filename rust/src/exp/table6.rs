//! Table VI — inference speed and power consumption.
//!
//! Two sections:
//!  1. **Paper-scale model**: PS analytic model + PL dataflow model + AXI
//!     staging model + power model at TinyLlama-1.1B geometry — reproduces
//!     the paper's own magnitudes (0.0935 → 1.478 tok/s etc.).
//!  2. **Testbed measurement** (needs `make artifacts`): the trained nano
//!     model run end-to-end on this machine — PS-threaded baseline vs the
//!     LlamaF engine (PJRT Pallas kernel) with sync vs async scheduling.

use anyhow::Result;
use std::path::Path;
use std::sync::Arc;

use crate::cli::Args;
use crate::engine::forward::CpuEngine;
use crate::engine::generate::{generate, Sampler};
use crate::engine::llamaf::LlamafEngine;
use crate::exp::{header, paper};
use crate::fpga::power::ExecMode;
use crate::fpga::{AxiModel, PlConfig, PowerModel};
use crate::model::{LlamaConfig, MatKind, TINYLLAMA_1_1B};
use crate::ps::ThreadedGqmv;
use crate::runtime::Runtime;
use crate::sched::{sim_token_time, SchedMode};
use crate::tokenizer::Tokenizer;
use crate::util::ThreadPool;

/// MAC count of one token's matrix pipeline.
pub fn token_macs(cfg: &LlamaConfig) -> f64 {
    let per_layer: usize = [MatKind::Qkv, MatKind::Wo, MatKind::W13, MatKind::W2]
        .iter()
        .map(|&k| {
            let (m, n) = cfg.mat_shape(k);
            m * n
        })
        .sum();
    let (mc, nc) = cfg.mat_shape(MatKind::Cls);
    (cfg.n_layers * per_layer + mc * nc) as f64
}

/// PS multi-head-attention model time at `pos` (scaled from the paper's
/// Table II measurement by the geometry's attention FLOP ratio = 1 here).
fn mha_time(pos: usize) -> f64 {
    paper::PS_MHA_S_PER_POS * (pos + 1) as f64
}

/// Modeled per-token time on the PS at `pos`.
pub fn ps_token_time(cfg: &LlamaConfig, pos: usize) -> f64 {
    2.0 * token_macs(cfg) / (paper::PS_MODEL_GOPS * 1e9) + mha_time(pos) + paper::PS_SMALLOPS_S
}

/// Modeled per-token time on LlamaF at `pos`.
pub fn llamaf_token_time(cfg: &LlamaConfig, pos: usize, scheduled: bool) -> f64 {
    let (sync_s, async_s) = sim_token_time(cfg, &PlConfig::default(), &AxiModel::default());
    let matrix = if scheduled { async_s } else { sync_s };
    matrix + mha_time(pos) + paper::PS_SMALLOPS_S
}

/// tok/s over a fixed-step generation = steps / total wall time — exactly
/// what the paper measures.  MHA grows linearly with position, which is
/// why tok/s declines with larger step counts.
pub fn toks_over_steps(_cfg: &LlamaConfig, steps: usize, f: impl Fn(usize) -> f64) -> f64 {
    let total: f64 = (0..steps).map(f).sum();
    steps as f64 / total
}

/// The full paper-scale modeled table.
pub struct ModeledTable {
    pub ps_gops: f64,
    pub lf_gops: f64,
    pub ps_toks: [f64; 3],
    pub lf_nosched_toks: [f64; 3],
    pub lf_toks: [f64; 3],
    pub ps_eff: f64,
    pub lf_eff: f64,
}

pub fn modeled_table() -> ModeledTable {
    let cfg = TINYLLAMA_1_1B;
    let pl = PlConfig::default();
    let (mc, nc) = cfg.mat_shape(MatKind::Cls);
    let power = PowerModel::default();
    let mut t = ModeledTable {
        ps_gops: paper::PS_MODEL_GOPS,
        lf_gops: pl.gops(mc, nc, cfg.gs),
        ps_toks: [0.0; 3],
        lf_nosched_toks: [0.0; 3],
        lf_toks: [0.0; 3],
        ps_eff: 0.0,
        lf_eff: 0.0,
    };
    for (i, &steps) in paper::STEPS.iter().enumerate() {
        t.ps_toks[i] = toks_over_steps(&cfg, steps, |p| ps_token_time(&cfg, p));
        t.lf_nosched_toks[i] = toks_over_steps(&cfg, steps, |p| llamaf_token_time(&cfg, p, false));
        t.lf_toks[i] = toks_over_steps(&cfg, steps, |p| llamaf_token_time(&cfg, p, true));
    }
    t.ps_eff = power.efficiency(t.ps_toks[2], ExecMode::PsOnly);
    t.lf_eff = power.efficiency(t.lf_toks[2], ExecMode::PsPlusPl);
    t
}

fn print_row(name: &str, gops: f64, toks: &[f64; 3], eff: f64) {
    println!(
        "  {:<24} {:>7.3} {:>10.4} {:>10.4} {:>10.4} {:>12.4}",
        name, gops, toks[0], toks[1], toks[2], eff
    );
}

pub fn run(args: &Args) -> Result<()> {
    header("Table VI: inference speed and power (paper-scale model)");
    println!(
        "  {:<24} {:>7} {:>10} {:>10} {:>10} {:>12}",
        "Method", "GOPS", "64 tok/s", "128 tok/s", "256 tok/s", "tok/s/W"
    );
    let m = modeled_table();
    print_row("ZCU102 PS (model)", m.ps_gops, &m.ps_toks, m.ps_eff);
    print_row("paper", paper::PS_GOPS, &paper::PS_TOKS, paper::PS_EFF);
    println!();
    print_row(
        "LlamaF no-sched (model)",
        m.lf_gops,
        &m.lf_nosched_toks,
        PowerModel::default().efficiency(m.lf_nosched_toks[2], ExecMode::PsPlusPl),
    );
    print_row("paper", paper::LLAMAF_GOPS, &paper::LLAMAF_NOSCHED_TOKS, 0.207);
    println!();
    print_row("LlamaF (model)", m.lf_gops, &m.lf_toks, m.lf_eff);
    print_row("paper", paper::LLAMAF_GOPS, &paper::LLAMAF_TOKS, paper::LLAMAF_EFF);
    println!(
        "\n  modeled speedup @256: {:.1}x (paper 14.3x)   sched gain: {:.1}%/{:.1}%/{:.1}% (paper 55.6-57.9%)",
        m.lf_toks[2] / m.ps_toks[2],
        100.0 * (m.lf_toks[0] / m.lf_nosched_toks[0] - 1.0),
        100.0 * (m.lf_toks[1] / m.lf_nosched_toks[1] - 1.0),
        100.0 * (m.lf_toks[2] / m.lf_nosched_toks[2] - 1.0),
    );

    // ---------------- testbed measurement (nano, real PJRT) -------------
    let ckpt = args.get_or("ckpt", "artifacts/nano_q8.lfq8");
    let art = args.get_or("artifacts", "artifacts");
    if !Path::new(ckpt).exists() || !Path::new(art).join("manifest.json").exists() {
        println!("\n  [testbed section skipped: run `make artifacts` to build {ckpt}]");
        return Ok(());
    }
    header("Table VI (testbed): nano model end-to-end on this machine");
    let steps_list: Vec<usize> = if args.flag("quiet") { vec![16] } else { vec![64, 128, 224] };
    let prompt_text = "what does the engineer build? ";
    let qm = crate::ckpt::read_q8(Path::new(ckpt))?;
    let tok = Tokenizer::new(qm.cfg.vocab_size);
    let prompt = tok.encode(prompt_text, true);

    println!(
        "  {:<28} {:>12} {:>12} {:>12}",
        "Method",
        format!("{} tok/s", steps_list[0]),
        format!("{} tok/s", steps_list.get(1).copied().unwrap_or(0)),
        format!("{} tok/s", steps_list.get(2).copied().unwrap_or(0)),
    );

    // PS baseline (threaded, 4 workers = A53 analogue)
    let pool = Arc::new(ThreadPool::new(args.get_usize("threads", 4)?));
    let mut ps = CpuEngine::new(qm.clone(), Box::new(ThreadedGqmv::new(pool)));
    let mut row = vec![];
    for &s in &steps_list {
        row.push(generate(&mut ps, &prompt, s, Sampler::Greedy, false)?.tok_per_s);
    }
    print_measured("PS baseline (threaded x4)", &row);

    let rt = Arc::new(Runtime::load(Path::new(art))?);
    for (name, mode) in [
        ("LlamaF no-sched (PJRT sync)", SchedMode::Sync),
        ("LlamaF (PJRT async sched)", SchedMode::Async),
    ] {
        let mut eng = LlamafEngine::open(Path::new(ckpt), Arc::clone(&rt), mode)?;
        let mut row = vec![];
        for &s in &steps_list {
            row.push(generate(&mut eng, &prompt, s, Sampler::Greedy, false)?.tok_per_s);
        }
        print_measured(name, &row);
    }
    println!("\n  note: at nano scale kernels are microseconds, so PJRT call overhead");
    println!("  dominates; the paper-scale model above carries the Table VI claims.");
    Ok(())
}

fn print_measured(name: &str, row: &[f64]) {
    print!("  {:<28}", name);
    for v in row {
        print!(" {:>12.2}", v);
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_table_matches_paper_shape() {
        let m = modeled_table();
        // GOPS within 2%
        assert!((m.lf_gops - paper::LLAMAF_GOPS).abs() / paper::LLAMAF_GOPS < 0.02);
        // PS tok/s within 5% at every step
        for i in 0..3 {
            let rel = (m.ps_toks[i] - paper::PS_TOKS[i]).abs() / paper::PS_TOKS[i];
            assert!(rel < 0.05, "ps step {i}: {} vs {}", m.ps_toks[i], paper::PS_TOKS[i]);
        }
        // LlamaF rows within 10%
        for i in 0..3 {
            let rel = (m.lf_toks[i] - paper::LLAMAF_TOKS[i]).abs() / paper::LLAMAF_TOKS[i];
            assert!(rel < 0.10, "lf step {i}: {} vs {}", m.lf_toks[i], paper::LLAMAF_TOKS[i]);
            let rel = (m.lf_nosched_toks[i] - paper::LLAMAF_NOSCHED_TOKS[i]).abs()
                / paper::LLAMAF_NOSCHED_TOKS[i];
            assert!(rel < 0.10, "lf-ns step {i}: {}", m.lf_nosched_toks[i]);
        }
        // headline ratios
        let speedup = m.lf_toks[2] / m.ps_toks[2];
        assert!(speedup > 12.0 && speedup < 18.0, "speedup {speedup}");
        let eff_gain = m.lf_eff / m.ps_eff;
        assert!(eff_gain > 5.0 && eff_gain < 7.5, "eff gain {eff_gain}");
        // scheduling gain in the paper's 40-75% window
        for i in 0..3 {
            let gain = m.lf_toks[i] / m.lf_nosched_toks[i] - 1.0;
            assert!(gain > 0.40 && gain < 0.75, "sched gain {gain}");
        }
    }

    #[test]
    fn tok_s_declines_with_steps() {
        let m = modeled_table();
        assert!(m.ps_toks[0] >= m.ps_toks[2]);
        assert!(m.lf_toks[0] > m.lf_toks[2]);
    }

    #[test]
    fn token_macs_tinyllama() {
        // ~1.03e9 MACs per token (22 layers + classifier)
        let macs = token_macs(&TINYLLAMA_1_1B);
        assert!(macs > 1.00e9 && macs < 1.07e9, "{macs}");
    }
}
