//! Fig. 2 — synchronous vs asynchronous FPGA computation timeline.

use anyhow::Result;

use crate::cli::Args;
use crate::exp::header;
use crate::fpga::{AxiModel, PlConfig};
use crate::model::TINYLLAMA_1_1B;
use crate::sched::{model_layer_times, sim_token_time};

fn bar(len_ms: f64, scale: f64, ch: char) -> String {
    let n = (len_ms * scale).round().max(1.0) as usize;
    std::iter::repeat(ch).take(n.min(80)).collect()
}

pub fn run(args: &Args) -> Result<()> {
    header("Fig. 2: synchronous vs asynchronous FPGA computation (modeled timeline)");
    let cfg = TINYLLAMA_1_1B;
    let pl = PlConfig::default();
    let axi = AxiModel::default();
    let lt = model_layer_times(&cfg, &pl, &axi);
    let (t_ms, k_ms) = (lt.transfer_s * 1e3, lt.kernel_s * 1e3);
    let scale = 0.8; // chars per ms

    println!("  per layer: transfer {:.1} ms, kernel {:.1} ms (TinyLlama geometry)\n", t_ms, k_ms);
    println!("  SYNC      (transfer then compute, repeated per layer):");
    println!("    xfer[l]   {}", bar(t_ms, scale, 'T'));
    println!("    kern[l]   {}{}", " ".repeat((t_ms * scale) as usize), bar(k_ms, scale, 'K'));
    println!("    layer period: {:.1} ms\n", t_ms + k_ms);
    println!("  ASYNC     (transfer of layer l+1 overlaps kernel of layer l):");
    println!("    xfer[l+1] {}", bar(t_ms, scale, 'T'));
    println!("    kern[l]   {}", bar(k_ms, scale, 'K'));
    println!("    layer period: {:.1} ms (= max of the two)\n", t_ms.max(k_ms));

    let (sync_s, async_s) = sim_token_time(&cfg, &pl, &axi);
    println!(
        "  full token matrix pipeline: sync {:.0} ms vs async {:.0} ms ({:.1}% faster)",
        sync_s * 1e3,
        async_s * 1e3,
        100.0 * (sync_s / async_s - 1.0)
    );
    println!("  (paper reports 55.6-57.9% end-to-end tok/s gain from scheduling)");
    let _ = args;
    Ok(())
}
