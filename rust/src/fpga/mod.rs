//! Simulated ZCU102 programmable logic (PL).
//!
//! The physical FPGA is unavailable on this testbed (DESIGN.md §5,
//! substitution 1), so the paper's accelerator exists here twice:
//!
//! * [`dataflow`] — a *functional + timing* simulator of the paper's
//!   three-stage HLS pipeline (pre-processing → dot-product with adder
//!   tree → accumulate).  Functionally bit-exact with Algorithm 1; the
//!   cycle model reproduces the paper's 4.696 GOPS at TinyLlama geometry.
//! * [`crate::runtime`] — the *executable* path: the Pallas GQMV kernel
//!   AOT-lowered to HLO and run through PJRT.
//!
//! [`axi`], [`resources`] and [`power`] model the platform: AXI HP
//! transfer time, Table III utilization, and the SCUI power figures.

pub mod axi;
pub mod dataflow;
pub mod power;
pub mod resources;

pub use axi::AxiModel;
pub use dataflow::{DataflowSim, PlConfig};
pub use power::PowerModel;
pub use resources::ResourceModel;
