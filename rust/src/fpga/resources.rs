//! Analytic FPGA resource model — regenerates paper Table III.
//!
//! The paper reports post-implementation utilization on the XCZU9EG:
//!
//! |        | LUT    | FF     | BRAM   | DSP    |
//! | total  | 274080 | 548160 | 912    | 2520   |
//! | used % | 59.72  | 31.31  | 24.45  | 20.95  |
//!
//! We estimate each component from the design parameters (GS-lane SIMD,
//! adder-tree depth, stream FIFOs, dual kernels, AXI shell).  Component
//! constants are engineering estimates documented inline; the test asserts
//! the model lands within ±15 % of the paper on every resource class, and
//! the Table III driver prints model vs. paper side by side.

/// ZCU102 (XCZU9EG) totals — paper Table III.
pub const ZCU102_LUT: u64 = 274_080;
pub const ZCU102_FF: u64 = 548_160;
pub const ZCU102_BRAM: u64 = 912; // 36Kb blocks
pub const ZCU102_DSP: u64 = 2_520;

/// Resource estimate for the LlamaF accelerator configuration.
#[derive(Clone, Copy, Debug)]
pub struct ResourceModel {
    /// Quantization group size (SIMD width of the dot-product stage).
    pub gs: u64,
    /// Number of statically instantiated GQMV kernels (kernel1 + kernel2).
    pub kernels: u64,
    /// Largest n/GS (groups per row) any kernel must buffer (22 for
    /// hidden_dim=5632).
    pub max_groups: u64,
    /// Largest column size (xq BRAM cache), 5632 for TinyLlama.
    pub max_n: u64,
}

impl Default for ResourceModel {
    fn default() -> Self {
        ResourceModel { gs: 256, kernels: 2, max_groups: 22, max_n: 5632 }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct Utilization {
    pub lut: u64,
    pub ff: u64,
    pub bram: u64,
    pub dsp: u64,
}

impl ResourceModel {
    /// DSP48E2 count.  The dot-product stage instantiates one INT16×INT16
    /// multiplier per SIMD lane (GS lanes); the accumulate stage uses ~8
    /// DSPs per kernel for the FP32 scale multiply/accumulate datapath.
    pub fn dsp(&self) -> u64 {
        self.kernels * (self.gs + 8)
    }

    /// LUT count.
    pub fn lut(&self) -> u64 {
        // per kernel:
        //  adder tree: gs-1 adders, average width ~24 bit, ~1 LUT/bit
        let adder_tree = (self.gs - 1) * 24;
        //  INT8->INT16 cast + lane routing for GS lanes (~6 LUT/lane)
        let lanes = self.gs * 6;
        //  FP32 accumulate datapath (cast, mul, add control): ~3.5k
        let fp32 = 3_500;
        //  stream FIFO glue + dataflow handshakes (~45 LUT/FIFO-word ctrl)
        let streams = (self.max_groups + 2) * 160;
        let per_kernel = adder_tree + lanes + fp32 + streams;
        // shell: AXI HP DMA engines, interconnect, control regs — dominated
        // by 4 wide (128-bit) HP masters with burst logic (~34k each in the
        // Vitis-generated shell at this width)
        let shell = 136_000;
        self.kernels * per_kernel + shell
    }

    /// FF count — pipeline registers track LUTs at roughly 1 FF/LUT in the
    /// datapath plus the shell's ~120k (the paper's FF% is much lower than
    /// LUT%, indicating a LUT-heavy adder-tree/interconnect design).
    pub fn ff(&self) -> u64 {
        let datapath = self.kernels * (self.gs * 40); // lane regs across stages
        let shell = 150_000;
        datapath + shell
    }

    /// BRAM36 count: xq/xs caches (INT16 × max_n), stream FIFOs, and the
    /// DMA burst buffers of the AXI shell.
    pub fn bram(&self) -> u64 {
        // xq cache: max_n * 2 B = 11 KB -> 3 BRAM36 (dual kernel: 6)
        let xq = self.kernels * 3;
        // stream FIFOs: w_stream (GS*2B wide x depth 2) implemented as
        // width-partitioned BRAM: GS*2*2/4.5KB ~ 1 BRAM36 per 18 lanes
        let fifos = self.kernels * (self.gs / 18);
        // AXI DMA burst/reorder buffers: ~45 BRAM per wide HP channel x4
        let shell = 180;
        xq + fifos + shell
    }

    pub fn utilization(&self) -> Utilization {
        Utilization { lut: self.lut(), ff: self.ff(), bram: self.bram(), dsp: self.dsp() }
    }

    /// Percent-of-device rows, (model %, paper %), for Table III printing.
    pub fn table3(&self) -> Vec<(&'static str, f64, f64)> {
        let u = self.utilization();
        vec![
            ("LUT", 100.0 * u.lut as f64 / ZCU102_LUT as f64, 59.72),
            ("FF", 100.0 * u.ff as f64 / ZCU102_FF as f64, 31.31),
            ("BRAM", 100.0 * u.bram as f64 / ZCU102_BRAM as f64, 24.45),
            ("DSP", 100.0 * u.dsp as f64 / ZCU102_DSP as f64, 20.95),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_within_15pct_of_paper() {
        for (name, model, paper) in ResourceModel::default().table3() {
            let rel = (model - paper).abs() / paper;
            assert!(rel < 0.15, "{name}: model {model:.2}% vs paper {paper:.2}% ({rel:.2})");
        }
    }

    #[test]
    fn fits_on_device() {
        let u = ResourceModel::default().utilization();
        assert!(u.lut < ZCU102_LUT);
        assert!(u.ff < ZCU102_FF);
        assert!(u.bram < ZCU102_BRAM);
        assert!(u.dsp < ZCU102_DSP);
    }

    #[test]
    fn dsp_scales_with_gs() {
        let small = ResourceModel { gs: 128, ..Default::default() };
        let big = ResourceModel { gs: 512, ..Default::default() };
        assert!(small.dsp() < big.dsp());
    }

    #[test]
    fn single_kernel_halves_datapath_dsp() {
        let one = ResourceModel { kernels: 1, ..Default::default() };
        let two = ResourceModel::default();
        assert_eq!(two.dsp(), 2 * one.dsp());
    }
}
