//! AXI / DDR transfer-time models.
//!
//! Two distinct data movements exist in the paper's system, and each gets
//! a model here:
//!
//! 1. **Kernel-side AXI HP streaming** (DDR → PL while the GQMV kernel
//!    runs): already billed inside `dataflow::PlConfig` as 16 B/cycle ×
//!    efficiency.  `AxiModel::hp_stream_time` exposes the same math for
//!    standalone analysis (Fig. 2 timelines).
//! 2. **Host-side buffer staging** (model file → pinned DDR kernel
//!    buffers, the per-layer copy of §III-B that async scheduling hides):
//!    `AxiModel::staging_time`, a bandwidth + latency model of the A53
//!    memcpy path.  1.93 GB/s calibrates LlamaF(no-sched) → LlamaF in
//!    Table VI and is consistent with measured A53 DDR4 copy bandwidth.

/// Transfer-time model for the ZCU102 memory system.
#[derive(Clone, Copy, Debug)]
pub struct AxiModel {
    /// Peak full-duplex HP bandwidth (paper §V-A: 85 Gbps).
    pub hp_peak_gbps: f64,
    /// Effective fraction of HP peak.
    pub hp_efficiency: f64,
    /// Host-side staging copy bandwidth, bytes/s (A53 memcpy into pinned
    /// buffers; calibration constant, see module docs).
    pub staging_bw: f64,
    /// Fixed per-transfer latency (descriptor setup, cache maintenance).
    pub latency_s: f64,
}

impl Default for AxiModel {
    fn default() -> Self {
        AxiModel {
            hp_peak_gbps: 85.0,
            hp_efficiency: 0.727,
            staging_bw: 1.80e9,
            latency_s: 20e-6,
        }
    }
}

impl AxiModel {
    /// Seconds to stream `bytes` DDR→PL over the HP ports.
    pub fn hp_stream_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 * 8.0 / (self.hp_peak_gbps * 1e9 * self.hp_efficiency)
    }

    /// Seconds for the host to stage `bytes` into a kernel buffer.
    pub fn staging_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.staging_bw
    }

    /// Effective HP bytes/s.
    pub fn hp_effective_bps(&self) -> f64 {
        self.hp_peak_gbps * 1e9 * self.hp_efficiency / 8.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_bytes() {
        let m = AxiModel::default();
        assert!(m.staging_time(1 << 20) < m.staging_time(1 << 24));
        assert!(m.hp_stream_time(1 << 20) < m.hp_stream_time(1 << 24));
    }

    #[test]
    fn latency_floor() {
        let m = AxiModel::default();
        assert!(m.staging_time(0) >= m.latency_s);
    }

    #[test]
    fn paper_scale_staging() {
        // staging one TinyLlama layer (~50 MB) must take ~26 ms so that a
        // full 22-layer pass costs ~0.58 s — the gap between LlamaF
        // no-sched (0.853 tok/s) and scheduled (1.328 tok/s) in Table VI.
        let m = AxiModel::default();
        let layer = crate::model::TINYLLAMA_1_1B.layer_stream_bytes();
        let t = m.staging_time(layer);
        assert!(t > 0.020 && t < 0.032, "layer staging {t}");
    }

    #[test]
    fn hp_effective_near_16b_per_cycle() {
        // 16 B/cycle at 205 MHz x efficiency ~ 2.38 GB/s; the 85 Gbps
        // full-duplex figure with the same efficiency is ~7.7 GB/s across
        // all ports — per-kernel streaming uses a single port pair.
        let m = AxiModel::default();
        assert!(m.hp_effective_bps() > 5e9);
    }
}
