//! Power/energy model — the SCUI substitute (DESIGN.md §5, substitution 4).
//!
//! The ZCU102 system-controller power rails are not available here, so
//! power is modelled with two constants back-derived from the paper's own
//! Table VI (efficiency = tok/s ÷ W):
//!
//!   PS-only:   0.0935 tok/s ÷ 0.0480 tok/s/W = **1.948 W**
//!   PS + PL:   1.328  tok/s ÷ 0.291  tok/s/W = **4.564 W**
//!
//! (Both are consistent with typical ZCU102 measurements: ~2 W for the A53
//! cluster + DDR under load, +~2.6 W for a 60 %-LUT PL design at 205 MHz.)

/// Platform power draw by execution mode.
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// PS cluster + DDR, watts (A53s busy).
    pub ps_watts: f64,
    /// Additional PL + AXI power when the accelerator is active, watts.
    pub pl_extra_watts: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel { ps_watts: 1.948, pl_extra_watts: 2.616 }
    }
}

/// Which parts of the MPSoC a run keeps busy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    PsOnly,
    PsPlusPl,
}

impl PowerModel {
    pub fn watts(&self, mode: ExecMode) -> f64 {
        match mode {
            ExecMode::PsOnly => self.ps_watts,
            ExecMode::PsPlusPl => self.ps_watts + self.pl_extra_watts,
        }
    }

    /// tok/s/W — the paper's efficiency column.
    pub fn efficiency(&self, tok_per_s: f64, mode: ExecMode) -> f64 {
        tok_per_s / self.watts(mode)
    }

    /// Joules consumed per generated token.
    pub fn energy_per_token(&self, tok_per_s: f64, mode: ExecMode) -> f64 {
        self.watts(mode) / tok_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_efficiency() {
        let pm = PowerModel::default();
        // PS row: 0.0935 tok/s -> 0.0480 tok/s/W
        let e_ps = pm.efficiency(0.0935, ExecMode::PsOnly);
        assert!((e_ps - 0.0480).abs() < 0.001, "{e_ps}");
        // LlamaF row: 1.328 tok/s -> 0.291 tok/s/W
        let e_lf = pm.efficiency(1.328, ExecMode::PsPlusPl);
        assert!((e_lf - 0.291).abs() < 0.002, "{e_lf}");
        // 6.1x improvement
        assert!((e_lf / e_ps - 6.06).abs() < 0.15);
    }

    #[test]
    fn energy_per_token_paper_scale() {
        let pm = PowerModel::default();
        // PS: ~20.8 J/token; LlamaF: ~3.4 J/token
        assert!((pm.energy_per_token(0.0935, ExecMode::PsOnly) - 20.8).abs() < 0.5);
        assert!((pm.energy_per_token(1.328, ExecMode::PsPlusPl) - 3.44).abs() < 0.1);
    }

    #[test]
    fn pl_mode_draws_more() {
        let pm = PowerModel::default();
        assert!(pm.watts(ExecMode::PsPlusPl) > pm.watts(ExecMode::PsOnly));
    }
}
