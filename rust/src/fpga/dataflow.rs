//! Cycle-approximate simulator of the LlamaF GQMV accelerator (paper §IV).
//!
//! **Functional model.**  The three HLS dataflow stages are executed
//! explicitly, with the same dataflow and cast chain as the hardware:
//!
//!   pre-processing: cache xq (INT8→INT16) + xs in "BRAM"; per row,
//!                   stream GS-wide INT16 weight vectors into `w_stream`
//!                   and n/GS scale vectors into `ws_stream`;
//!   dot-product:    GS-lane SIMD multiply (INT16), then a binary adder
//!                   tree of depth log2(GS) whose first level widens to
//!                   INT32 — one INT32 group sum per group;
//!   accumulate:     float_scale = ws ⊙ xs, dot with FP32-cast group sums,
//!                   sequential over groups, one FP32 output per row.
//!
//! **Timing model.**  The accelerator is DDR-bandwidth bound: each row
//! must stream `n` weight bytes + `4·n/GS` scale bytes over AXI HP ports
//! that move 16 bytes/cycle at 205 MHz (paper §V-B "transfers 16 8-bit
//! values per cycle").  `axi_efficiency` (< 1) captures burst gaps,
//! refresh and arbitration; 0.727 calibrates the model to the paper's
//! measured 4.696 GOPS on the 32000×2048 logits GQMV and is within the
//! 70–80 % range typically quoted for Zynq HP ports.

use anyhow::Result;

use crate::ps::gqmv::{check_shapes, GqmvExec};
use crate::quant::QuantizedTensor;

/// PL clock/bandwidth parameters (defaults = paper's ZCU102 design).
#[derive(Clone, Copy, Debug)]
pub struct PlConfig {
    pub freq_mhz: f64,
    /// AXI HP payload bytes per PL cycle (128-bit ports).
    pub bytes_per_cycle: f64,
    /// Effective fraction of peak AXI bandwidth (calibration constant).
    pub axi_efficiency: f64,
    /// Pipeline fill: stage latency + adder-tree depth + stream priming.
    pub fill_cycles: u64,
}

impl Default for PlConfig {
    fn default() -> Self {
        PlConfig {
            freq_mhz: 205.0,
            bytes_per_cycle: 16.0,
            axi_efficiency: 0.727,
            fill_cycles: 64,
        }
    }
}

impl PlConfig {
    /// Streamed bytes for one output row: int8 weights + f32 group scales.
    pub fn row_bytes(&self, n: usize, gs: usize) -> f64 {
        n as f64 + 4.0 * (n / gs) as f64
    }

    /// Cycles to compute a full (m, n) GQMV.
    pub fn kernel_cycles(&self, m: usize, n: usize, gs: usize) -> f64 {
        let per_row = self.row_bytes(n, gs) / (self.bytes_per_cycle * self.axi_efficiency);
        self.fill_cycles as f64 + m as f64 * per_row
    }

    pub fn kernel_time_s(&self, m: usize, n: usize, gs: usize) -> f64 {
        self.kernel_cycles(m, n, gs) / (self.freq_mhz * 1e6)
    }

    /// GOPS of one GQMV call (2 int ops per MAC, the paper's metric).
    pub fn gops(&self, m: usize, n: usize, gs: usize) -> f64 {
        2.0 * m as f64 * n as f64 / self.kernel_time_s(m, n, gs) / 1e9
    }
}

/// Depth-log2(GS) binary adder tree; first level widens INT16→INT32
/// exactly as the hardware does (paper §IV-C).
fn adder_tree(products: &[i16]) -> i32 {
    debug_assert!(products.len().is_power_of_two());
    // first layer: pairwise INT16 + INT16 -> INT32
    let mut level: Vec<i32> = products
        .chunks_exact(2)
        .map(|p| p[0] as i32 + p[1] as i32)
        .collect();
    while level.len() > 1 {
        level = level.chunks_exact(2).map(|p| p[0] + p[1]).collect();
    }
    level[0]
}

/// Functional + timing simulator; implements [`GqmvExec`] so engines can
/// run on it directly.  Accumulates simulated cycles across calls.
pub struct DataflowSim {
    pub cfg: PlConfig,
    /// Total simulated PL cycles since construction/reset.
    pub cycles: f64,
    /// Total MAC ops processed (for GOPS reporting).
    pub macs: u64,
    /// Peak stream occupancy observed (w_stream FIFO high-water, groups).
    pub peak_stream_depth: usize,
}

impl DataflowSim {
    pub fn new(cfg: PlConfig) -> Self {
        DataflowSim { cfg, cycles: 0.0, macs: 0, peak_stream_depth: 0 }
    }

    pub fn reset_counters(&mut self) {
        self.cycles = 0.0;
        self.macs = 0;
        self.peak_stream_depth = 0;
    }

    pub fn simulated_time_s(&self) -> f64 {
        self.cycles / (self.cfg.freq_mhz * 1e6)
    }

    pub fn achieved_gops(&self) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            2.0 * self.macs as f64 / self.simulated_time_s() / 1e9
        }
    }

    /// Algorithm 3 (GQMV accelerator) — functional execution.
    fn run(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) {
        let gs = w.gs;
        let groups = w.cols / gs;
        // --- pre-fetch stage: cache x in BRAM, cast INT8 -> INT16 -------
        let xq16: Vec<i16> = xq.iter().map(|&v| v as i16).collect();

        let mut w_stream: Vec<i16> = Vec::with_capacity(gs); // hls::vector<GS>
        let mut group_sum_stream: Vec<i32> = Vec::with_capacity(groups);
        for i in 0..w.rows {
            group_sum_stream.clear();
            // --- read_cast / read_scale: stream one row ----------------
            let row = &w.q[i * w.cols..(i + 1) * w.cols];
            let ws_row = &w.s[i * groups..(i + 1) * groups];
            for g in 0..groups {
                w_stream.clear();
                w_stream.extend(row[g * gs..(g + 1) * gs].iter().map(|&v| v as i16));
                // --- dot-product stage: SIMD mult + adder tree ---------
                let prods: Vec<i16> = w_stream
                    .iter()
                    .zip(&xq16[g * gs..(g + 1) * gs])
                    .map(|(&a, &b)| a * b) // |p| <= 127*127 fits i16
                    .collect();
                group_sum_stream.push(adder_tree(&prods));
                self.peak_stream_depth = self.peak_stream_depth.max(group_sum_stream.len());
            }
            // --- accumulate stage: FP32 scale dot, sequential ----------
            let mut sum = 0.0f32;
            for g in 0..groups {
                let float_scale = ws_row[g] * xs[g];
                sum += group_sum_stream[g] as f32 * float_scale;
            }
            out[i] = sum;
        }
        self.cycles += self.cfg.kernel_cycles(w.rows, w.cols, gs);
        self.macs += (w.rows * w.cols) as u64;
    }
}

impl GqmvExec for DataflowSim {
    fn gqmv(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) -> Result<()> {
        check_shapes(xq, xs, w, out)?;
        anyhow::ensure!(w.gs.is_power_of_two(), "adder tree needs power-of-two GS");
        self.run(xq, xs, w, out);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "fpga-dataflow-sim"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::gqmv::ScalarGqmv;
    use crate::quant::quantize_activation;
    use crate::util::Rng;

    #[test]
    fn functional_bit_exact_with_scalar() {
        let mut rng = Rng::new(1);
        for (m, n, gs) in [(8, 256, 256), (64, 512, 128), (256, 768, 256), (16, 64, 16)] {
            let w = QuantizedTensor::from_f32(&rng.normal_vec(m * n, 0.4), m, n, gs);
            let (xq, xs) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
            let mut a = vec![0.0; m];
            let mut b = vec![0.0; m];
            ScalarGqmv.gqmv(&xq, &xs, &w, &mut a).unwrap();
            DataflowSim::new(PlConfig::default()).gqmv(&xq, &xs, &w, &mut b).unwrap();
            assert_eq!(a, b, "m={m} n={n} gs={gs}");
        }
    }

    #[test]
    fn adder_tree_equals_sum() {
        let mut rng = Rng::new(2);
        for len in [2usize, 4, 16, 256] {
            let v: Vec<i16> = (0..len).map(|_| rng.range_i64(-16129, 16130) as i16).collect();
            let expect: i32 = v.iter().map(|&x| x as i32).sum();
            assert_eq!(adder_tree(&v), expect);
        }
    }

    #[test]
    fn paper_gops_reproduced() {
        // The paper measures GOPS on the logits GQMV (32000 x 2048, GS=256)
        // and reports 4.696.  The calibrated model must land within 2%.
        let cfg = PlConfig::default();
        let gops = cfg.gops(32000, 2048, 256);
        assert!((gops - 4.696).abs() / 4.696 < 0.02, "model gops {gops}");
    }

    #[test]
    fn gops_independent_of_m_for_large_m() {
        // streaming-bound design: throughput saturates with row count
        let cfg = PlConfig::default();
        let a = cfg.gops(2048, 2048, 256);
        let b = cfg.gops(32000, 2048, 256);
        assert!((a - b).abs() / b < 0.01);
    }

    #[test]
    fn counters_accumulate() {
        let mut sim = DataflowSim::new(PlConfig::default());
        let mut rng = Rng::new(3);
        let w = QuantizedTensor::from_f32(&rng.normal_vec(16 * 256, 0.3), 16, 256, 256);
        let (xq, xs) = quantize_activation(&rng.normal_vec(256, 1.0), 256);
        let mut out = vec![0.0; 16];
        sim.gqmv(&xq, &xs, &w, &mut out).unwrap();
        sim.gqmv(&xq, &xs, &w, &mut out).unwrap();
        assert_eq!(sim.macs, 2 * 16 * 256);
        assert!(sim.cycles > 0.0);
        assert!(sim.achieved_gops() > 0.0);
        sim.reset_counters();
        assert_eq!(sim.macs, 0);
    }

    #[test]
    fn non_power_of_two_gs_rejected() {
        let w = QuantizedTensor {
            q: vec![0; 96],
            s: vec![0.0; 2],
            rows: 1,
            cols: 96,
            gs: 48,
            fmt: crate::quant::FormatId::Q8,
        };
        let xq = vec![0i8; 96];
        let xs = vec![0f32; 2];
        let mut out = vec![0.0; 1];
        assert!(DataflowSim::new(PlConfig::default()).gqmv(&xq, &xs, &w, &mut out).is_err());
    }

    #[test]
    fn stream_depth_bounded_by_groups() {
        let mut sim = DataflowSim::new(PlConfig::default());
        let mut rng = Rng::new(4);
        // n=5632 (hidden_dim) -> 22 groups, the paper's kernel2 case
        let w = QuantizedTensor::from_f32(&rng.normal_vec(8 * 5632, 0.3), 8, 5632, 256);
        let (xq, xs) = quantize_activation(&rng.normal_vec(5632, 1.0), 256);
        let mut out = vec![0.0; 8];
        sim.gqmv(&xq, &xs, &w, &mut out).unwrap();
        assert_eq!(sim.peak_stream_depth, 22);
    }
}
