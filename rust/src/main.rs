//! LlamaF CLI — the layer-3 coordinator entrypoint.
//!
//! Subcommands:
//!   generate  — greedy/top-p text generation (PS / LlamaF engines)
//!   serve     — line-oriented TCP generation server (batch=1 realtime)
//!   gateway   — front N serve replicas: health-checked least-loaded
//!               routing with failover (see server::gateway)
//!   tables    — regenerate every paper table/figure (see exp/)
//!   ppl       — Table V perplexity evaluation
//!   profile   — Table II component profiling
//!   synth     — write a synthetic quantized checkpoint at a chosen geometry
//!   import-gguf — convert a GGUF checkpoint to a native quantized one
//!   quant-error — per-matrix quantization error of a float checkpoint
//!   info      — runtime/artifact inventory
//!   verify-ckpt — offline integrity pass over a checkpoint's CRC footer
//!   trace-diff — compare two execution traces (`generate --trace`)

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use llamaf::cli::Args;
use llamaf::engine::forward::{CpuEngine, Engine};
use llamaf::engine::generate::{generate, Sampler};
use llamaf::engine::llamaf::LlamafEngine;
use llamaf::ps::{ScalarGqmv, ThreadedGqmv};
use llamaf::runtime::Runtime;
use llamaf::sched::{SchedMode, StageGranularity};
use llamaf::tokenizer::Tokenizer;
use llamaf::util::ThreadPool;

const USAGE: &str = "\
llamaf — LlamaF (Llama2-on-FPGA) reproduction

USAGE: llamaf <command> [options]

COMMANDS
  generate  --ckpt <lfq*> --prompt <text> [--steps N] [--engine ps|llamaf]
            [--sync|--async] [--prefetch-depth N]
            [--stream-granularity layer|matrix]
            [--top-p P --temperature T --seed S]
            [--trace <out.trace>]  record a per-op execution trace (the
            digest of every GQMV output) for trace-diff
            [--inject-faults <spec>]  deterministic staged-read fault
            injection (llamaf engine): spec is comma-separated
            p=<prob>, seed=<u64>, stall_ms=<ms> and
            at=<layer>/<unit>/<kind>[/<count|always>] triggers with
            kind readerr|truncated|corrupt|stall and unit
            norms|qkv|wo|w13|w2|layer|any — transient faults are
            absorbed by the staging retry, persistent ones surface
  serve     --ckpt <lfq*> [--addr 127.0.0.1:7077] [--engine ps|ps-scalar|sim|llamaf]
            [--workers N] [--queue-depth N] [--max-sessions N] [--threads N]
            [--max-batch B] [--prefetch-depth N]
            [--stream-granularity layer|matrix] [--sync | --resident]
            [--kv-pages P] [--prefill-chunk C] [--request-timeout MS]
            [--inject-faults <spec>]
            ps/ps-scalar/sim: concurrent requests are folded into
            continuously batched decoding over one shared weight
            copy (requests join at the next step, up to B lanes/step,
            weights staged once per step by a persistent prefetch
            worker running a depth-N staging ring: --prefetch-depth N
            keeps N-1 transfers in flight, default 2 = double
            buffering; --stream-granularity matrix streams per-matrix
            chunks so transfers overlap compute WITHIN a layer, layer
            streams whole layers; --sync disables the async prefetch,
            --resident skips staging entirely and serves zero-copy
            resident weights; --kv-pages P draws session KV from a
            shared pool of P 16-position pages with copy-on-write
            prompt-prefix reuse instead of per-session slabs;
            --prefill-chunk C lets one prompt prefill up to C tokens
            per step — bit-identical either way; --request-timeout MS
            sheds any request still decoding MS ms after submission
            with ERR deadline:, --inject-faults injects deterministic
            staged-read faults — a lane whose step keeps failing is
            shed with ERR fault: while the rest of the batch keeps
            decoding bit-identically); llamaf: sequential batch-1
            streaming
  gateway   --backends <addr,addr,...> [--addr 127.0.0.1:7078]
            [--workers N] [--queue-depth N] [--max-queue N]
            [--probe-interval-ms MS] [--probe-timeout-ms MS]
            [--connect-timeout-ms MS] [--chaos <spec>]
            front N `serve` replicas behind one address: periodic HEALTH
            probes drive an up/degraded/down table, generations are
            routed least-loaded with sticky per-connection replica
            pinning, per-backend queues are bounded by --max-queue
            (overflow answered ERR busy, never silently dropped),
            generations whose replica dies before any output are
            transparently redirected to a survivor, in-flight streams
            are shed honestly with `ERR fault: backend lost`, and
            SHUTDOWN drains (stop admitting, finish what's in flight,
            exit — replicas stay up); --chaos injects deterministic
            backend faults for drills: comma-separated p=<prob>,
            seed=<u64>, stall_ms=<ms>, after=<routed-requests> and
            at=<backend>/<kind>[/<count|always>] triggers with kind
            kill|stall|slowaccept
  tables    [--table 1..6 | --fig 2] [--geometry nano|tinyllama]
  ppl       [--f32-ckpt <lfck>] [--ckpt <lfq8>] [--corpus <txt>] [--ppl-tokens N]
  profile   [--geometry nano|tinyllama] [--threads N]
  synth     --out <path.lfq*> [--geometry nano|tinyllama] [--seed S]
            [--quant-format q8|q4_0|q5_0]
  import-gguf --gguf <model.gguf> --out <path.lfq*>
            [--quant-format q8|q4_0|q5_0] [--gs N]
            dequantize a GGUF (F32/F16/Q8_0/Q4_0/Q5_0 tensors) and
            re-quantize onto the model's own group lattice as a native
            streaming checkpoint
  quant-error --f32-ckpt <path.lfck> [--quant-format q8|q4_0|q5_0]
            per-matrix and whole-model quantization error (RMS + the
            paper's error-percentage stats) of a float checkpoint on
            the chosen weight lattice
  info      [--artifacts <dir>]
  verify-ckpt <path.lfq*>
            stream every CRC32-checksummed segment of a quantized
            checkpoint against its integrity footer; names the first
            corrupt segment and exits nonzero on mismatch (footer-less
            legacy files report 'no integrity footer')
  bench-diff --prev <dir> --cur <dir> [--threshold 0.20]
            compare two bench-json/ directories case by case and fail
            on regressions beyond the threshold (CI runs this
            advisorily against the previous run's artifact)
  trace-diff <a.trace> <b.trace>
            compare two execution traces op by op; prints the first
            divergent op with (step, layer, matrix, lane) coordinates
            and exits nonzero unless the traces are identical
";

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn build_engine(args: &Args) -> Result<Box<dyn Engine>> {
    let ckpt = args.get_or("ckpt", "artifacts/nano_q8.lfq8");
    let path = Path::new(ckpt);
    anyhow::ensure!(path.exists(), "checkpoint {ckpt} not found (run `make artifacts`)");
    let engine_kind = args.get_or("engine", "llamaf");
    anyhow::ensure!(
        engine_kind == "llamaf" || args.get("inject-faults").is_none(),
        "--inject-faults requires the streaming llamaf engine \
         (resident CPU engines have no staged reads to fail)"
    );
    match engine_kind {
        "ps" => {
            let qm = llamaf::ckpt::read_ckpt(path)?;
            let pool = Arc::new(ThreadPool::new(args.get_usize("threads", 4)?));
            Ok(Box::new(CpuEngine::new(qm, Box::new(ThreadedGqmv::new(pool)))))
        }
        "ps-scalar" => {
            let qm = llamaf::ckpt::read_ckpt(path)?;
            Ok(Box::new(CpuEngine::new(qm, Box::new(ScalarGqmv))))
        }
        "sim" => {
            let qm = llamaf::ckpt::read_ckpt(path)?;
            Ok(Box::new(CpuEngine::new(
                qm,
                Box::new(llamaf::fpga::DataflowSim::new(llamaf::fpga::PlConfig::default())),
            )))
        }
        "llamaf" => {
            let art = args.get_or("artifacts", "artifacts");
            let rt = Arc::new(Runtime::load(Path::new(art))?);
            let mode = if args.flag("sync") { SchedMode::Sync } else { SchedMode::Async };
            let depth = prefetch_depth(args)?;
            let gran = stream_granularity(args)?;
            let faults = fault_plan(args)?;
            Ok(Box::new(LlamafEngine::open_with_faults(path, rt, mode, depth, gran, faults)?))
        }
        other => bail!("unknown engine '{other}' (ps | ps-scalar | sim | llamaf)"),
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    if args.flag("help") || args.command.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    match args.command.as_deref().unwrap() {
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        "gateway" => cmd_gateway(&args),
        "tables" => llamaf::exp::run(&args),
        "ppl" => llamaf::exp::table5::run(&args),
        "profile" => llamaf::exp::table2::run(&args),
        "synth" => cmd_synth(&args),
        "import-gguf" => cmd_import_gguf(&args),
        "quant-error" => cmd_quant_error(&args),
        "info" => cmd_info(&args),
        "verify-ckpt" => cmd_verify_ckpt(&args),
        "bench-diff" => cmd_bench_diff(&args),
        "trace-diff" => cmd_trace_diff(&args),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

/// Parse and validate `--prefetch-depth` (staging-ring depth, default 2).
fn prefetch_depth(args: &Args) -> Result<usize> {
    let depth = args.get_usize("prefetch-depth", llamaf::sched::DEFAULT_PREFETCH_DEPTH)?;
    anyhow::ensure!(depth >= 1, "--prefetch-depth must be >= 1");
    Ok(depth)
}

/// Parse `--quant-format` (weight wire format, default q8).
fn quant_format(args: &Args) -> Result<llamaf::quant::FormatId> {
    let s = args.get_or("quant-format", "q8");
    llamaf::quant::FormatId::parse(s)
        .with_context(|| format!("--quant-format must be q8, q4_0 or q5_0 (got '{s}')"))
}

/// Parse `--inject-faults` into a [`llamaf::sched::FaultPlan`] (None
/// when the flag is absent).
fn fault_plan(args: &Args) -> Result<Option<llamaf::sched::FaultPlan>> {
    match args.get("inject-faults") {
        None => Ok(None),
        Some(spec) => Ok(Some(
            llamaf::sched::FaultPlan::parse(spec)
                .with_context(|| format!("--inject-faults '{spec}'"))?,
        )),
    }
}

/// Parse `--stream-granularity` (staging unit, default layer).
fn stream_granularity(args: &Args) -> Result<StageGranularity> {
    match args.get_or("stream-granularity", "layer") {
        "layer" => Ok(StageGranularity::Layer),
        "matrix" => Ok(StageGranularity::Matrix),
        other => bail!("--stream-granularity must be 'layer' or 'matrix' (got '{other}')"),
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let prompt = args.get("prompt").context("--prompt required")?.to_string();
    let steps = args.get_usize("steps", 64)?;
    let mut engine = build_engine(args)?;
    let tok = Tokenizer::new(engine.cfg().vocab_size);
    let prompt_ids = tok.encode(&prompt, true);
    let sampler = if let Some(p) = args.get("top-p") {
        Sampler::TopP {
            p: p.parse().context("--top-p")?,
            temperature: args.get_f64("temperature", 1.0)? as f32,
            seed: args.get_usize("seed", 0)? as u64,
        }
    } else {
        Sampler::Greedy
    };
    eprintln!("engine: {}  prompt tokens: {}  steps: {steps}", engine.name(), prompt_ids.len());
    let trace_out = args.get("trace").map(|s| s.to_string());
    if trace_out.is_some() {
        let label = engine.name();
        anyhow::ensure!(
            engine.trace_start(&label),
            "engine '{label}' does not support --trace execution tracing"
        );
    }
    let out = generate(engine.as_mut(), &prompt_ids, steps, sampler, !args.flag("greedy"))?;
    if let Some(path) = trace_out {
        let trace = engine.trace_take().context("tracing was enabled but no trace was produced")?;
        trace.save(Path::new(&path))?;
        eprintln!("wrote execution trace ({} ops, {} steps) to {path}", trace.len(), trace.steps());
    }
    println!("{}{}", prompt, tok.decode(&out.generated));
    eprintln!(
        "\n[{} tokens  {:.3} tok/s  p50 {:.2} ms  p99 {:.2} ms  matrix {:.0}%]",
        out.generated.len(),
        out.tok_per_s,
        out.latency_p50_s * 1e3,
        out.latency_p99_s * 1e3,
        100.0 * out.profile.matrix_s / out.profile.total().max(1e-12),
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7077");
    let engine_kind = args.get_or("engine", "llamaf").to_string();
    match engine_kind.as_str() {
        // CPU-backend engines share one Arc'd weight copy across N workers
        "ps" | "ps-scalar" | "sim" => {
            let ckpt = args.get_or("ckpt", "artifacts/nano_q8.lfq8");
            let path = Path::new(ckpt);
            anyhow::ensure!(path.exists(), "checkpoint {ckpt} not found (run `make artifacts`)");
            let qm = Arc::new(llamaf::ckpt::read_ckpt(path)?);
            let opts = llamaf::server::ServeOpts {
                workers: args.get_usize("workers", 4)?,
                queue_depth: args.get_usize("queue-depth", 64)?,
                max_sessions: args.get_usize("max-sessions", 16)?,
                max_batch: args.get_usize("max-batch", 8)?,
                sync_staging: args.flag("sync"),
                prefetch_depth: prefetch_depth(args)?,
                granularity: stream_granularity(args)?,
                resident: args.flag("resident"),
                kv_pages: args.get_usize("kv-pages", 0)?,
                prefill_chunk: {
                    let c = args.get_usize("prefill-chunk", 1)?;
                    anyhow::ensure!(c >= 1, "--prefill-chunk must be >= 1");
                    c
                },
                request_timeout_ms: match args.get("request-timeout") {
                    None => None,
                    Some(_) => {
                        let ms = args.get_usize("request-timeout", 0)? as u64;
                        anyhow::ensure!(ms >= 1, "--request-timeout must be >= 1 ms");
                        Some(ms)
                    }
                },
                faults: fault_plan(args)?,
            };
            anyhow::ensure!(
                !(opts.resident && opts.faults.is_some()),
                "--inject-faults needs streamed weights (--resident has no staged reads)"
            );
            let threads = args.get_usize("threads", 4)?;
            let make_exec: Box<llamaf::server::ExecFactory> = match engine_kind.as_str() {
                "ps" => {
                    let pool = Arc::new(ThreadPool::new(threads));
                    Box::new(move || Box::new(ThreadedGqmv::new(Arc::clone(&pool))))
                }
                "ps-scalar" => Box::new(|| Box::new(ScalarGqmv)),
                _ => Box::new(|| {
                    Box::new(llamaf::fpga::DataflowSim::new(llamaf::fpga::PlConfig::default()))
                }),
            };
            let server = llamaf::server::Server::bind(addr, qm.cfg.vocab_size)?;
            eprintln!(
                "llamaf serving on {} ({} x{} workers, batch<= {}, {} weights, prefetch \
                 depth {}, {}-granular staging, {} pooled sessions, queue {}) — \
                 protocol: GEN/SGEN <steps> <prompt> | STATS | TRACE | METRICS | PING | \
                 SHUTDOWN | QUIT",
                server.local_addr()?,
                engine_kind,
                opts.workers,
                opts.max_batch,
                if opts.resident { "resident" } else { "streamed" },
                opts.prefetch_depth,
                opts.granularity.label(),
                opts.max_sessions,
                opts.queue_depth,
            );
            let report = server.serve_shared(qm, make_exec.as_ref(), &opts, None)?;
            eprintln!(
                "llamaf serve done: {} conns, {} requests ({} rejected), {} tokens",
                report.accepted, report.requests, report.rejected, report.tokens
            );
        }
        // the streamed-weight engine is single-owner: sequential batch-1
        _ => {
            let mut engine = build_engine(args)?;
            let server = llamaf::server::Server::bind(addr, engine.cfg().vocab_size)?;
            eprintln!(
                "llamaf serving on {} (engine: {}, batch-1) — protocol: GEN <steps> <prompt> | PING | QUIT",
                server.local_addr()?,
                engine.name()
            );
            server.serve(engine.as_mut(), None)?;
        }
    }
    Ok(())
}

/// `llamaf gateway`: front N `serve` replicas with the health-checked,
/// least-loaded, failover-capable gateway (see [`llamaf::server::gateway`]).
fn cmd_gateway(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7078");
    let spec = args
        .get("backends")
        .or_else(|| args.get("backend"))
        .context("--backends <addr,addr,...> required")?;
    let backends: Vec<String> =
        spec.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect();
    anyhow::ensure!(!backends.is_empty(), "--backends needs at least one address");
    let chaos = match args.get("chaos") {
        None => None,
        Some(spec) => Some(
            llamaf::server::gateway::ChaosPlan::parse(spec)
                .with_context(|| format!("--chaos '{spec}'"))?,
        ),
    };
    let opts = llamaf::server::gateway::GatewayOpts {
        backends,
        workers: args.get_usize("workers", 4)?,
        queue_depth: args.get_usize("queue-depth", 64)?,
        max_queue: args.get_usize("max-queue", 8)?,
        probe_interval_ms: args.get_usize("probe-interval-ms", 50)? as u64,
        probe_timeout_ms: args.get_usize("probe-timeout-ms", 1000)? as u64,
        connect_timeout_ms: args.get_usize("connect-timeout-ms", 1000)? as u64,
        chaos,
    };
    let max_conns = match args.get("max-conns") {
        None => None,
        Some(_) => Some(args.get_usize("max-conns", 0)?),
    };
    let gw = llamaf::server::gateway::Gateway::bind(addr)?;
    eprintln!(
        "llamaf gateway on {} fronting {} replica(s) ({} workers, per-backend bound {}, \
         probe every {} ms) — protocol: GEN/SGEN <steps> <prompt> | STATS | TRACE | \
         METRICS | PING | HEALTH | SHUTDOWN | QUIT",
        gw.local_addr()?,
        opts.backends.len(),
        opts.workers,
        opts.max_queue,
        opts.probe_interval_ms,
    );
    for (i, b) in opts.backends.iter().enumerate() {
        eprintln!("  backend {i}: {b}");
    }
    let report = gw.run(&opts, max_conns)?;
    eprintln!(
        "llamaf gateway done: {} conns, {} routed ({} redirected, {} shed, {} rejected), \
         probes {} ok / {} failed",
        report.accepted,
        report.routed,
        report.redirected,
        report.shed,
        report.rejected,
        report.probes_ok,
        report.probes_failed,
    );
    Ok(())
}

fn cmd_synth(args: &Args) -> Result<()> {
    let out = args.get("out").context("--out required")?;
    let cfg = match args.get_or("geometry", "nano") {
        "tinyllama" => llamaf::model::TINYLLAMA_1_1B,
        _ => llamaf::model::NANO,
    };
    let seed = args.get_usize("seed", 42)? as u64;
    let fmt = quant_format(args)?;
    eprintln!(
        "building synthetic float model ({:.1}M params) and quantizing to {fmt}...",
        cfg.param_count() as f64 / 1e6
    );
    let fm = llamaf::model::FloatModel::random(cfg, seed);
    llamaf::ckpt::write_ckpt_from_float(Path::new(out), &fm, fmt)?;
    eprintln!("wrote {out}");
    Ok(())
}

/// Convert a GGUF checkpoint into a native quantized streaming
/// checkpoint: dequantize every tensor, then re-quantize on the model's
/// own group lattice (ggml's fixed 32-element blocks cannot be streamed
/// through the GQMV cast chain, whose weight scale groups must match the
/// activation groups).
fn cmd_import_gguf(args: &Args) -> Result<()> {
    let gguf = args.get("gguf").context("--gguf <model.gguf> required")?;
    let out = args.get("out").context("--out <path> required")?;
    let fmt = quant_format(args)?;
    let gs = match args.get("gs") {
        Some(_) => Some(args.get_usize("gs", 0)?),
        None => None,
    };
    let cfg = llamaf::ckpt::gguf::import_gguf(Path::new(gguf), Path::new(out), fmt, gs)?;
    let layout = llamaf::ckpt::CkptLayout::new(cfg, fmt);
    eprintln!(
        "imported {gguf}: dim={} hidden={} layers={} vocab={} gs={} -> {out} ({fmt}, {:.1} MB)",
        cfg.dim,
        cfg.hidden_dim,
        cfg.n_layers,
        cfg.vocab_size,
        cfg.gs,
        layout.total_bytes() as f64 / 1e6
    );
    Ok(())
}

/// Per-matrix quantization error of a float checkpoint on a chosen
/// weight lattice (generalizes the paper's Table IV error statistics to
/// sub-INT8 formats).
fn cmd_quant_error(args: &Args) -> Result<()> {
    let ckpt = args.get("f32-ckpt").context("--f32-ckpt <path.lfck> required")?;
    let fmt = quant_format(args)?;
    let fm = llamaf::ckpt::read_f32_model(Path::new(ckpt))?;
    let cfg = fm.cfg;
    let gs = cfg.gs;
    println!("quantization error of {ckpt} on the {fmt} lattice (gs={gs}):");
    let mut total = llamaf::quant::QuantErrorStats::default();
    qe_row(&mut total, "tok_emb", &fm.tok_emb, cfg.vocab_size, cfg.dim, gs, fmt);
    for (i, l) in fm.layers.iter().enumerate() {
        qe_row(&mut total, &format!("L{i}.wq"), &l.wq, cfg.dim, cfg.dim, gs, fmt);
        qe_row(&mut total, &format!("L{i}.wk"), &l.wk, cfg.kv_dim(), cfg.dim, gs, fmt);
        qe_row(&mut total, &format!("L{i}.wv"), &l.wv, cfg.kv_dim(), cfg.dim, gs, fmt);
        qe_row(&mut total, &format!("L{i}.wo"), &l.wo, cfg.dim, cfg.dim, gs, fmt);
        qe_row(&mut total, &format!("L{i}.w1"), &l.w1, cfg.hidden_dim, cfg.dim, gs, fmt);
        qe_row(&mut total, &format!("L{i}.w2"), &l.w2, cfg.dim, cfg.hidden_dim, gs, fmt);
        qe_row(&mut total, &format!("L{i}.w3"), &l.w3, cfg.hidden_dim, cfg.dim, gs, fmt);
    }
    qe_row(&mut total, "cls", &fm.cls, cfg.vocab_size, cfg.dim, gs, fmt);
    println!("  {:<14} rms {:.6}  {}", "TOTAL", total.rms(), total.row());
    Ok(())
}

/// Print one `quant-error` table row and fold the tensor into `total`.
fn qe_row(
    total: &mut llamaf::quant::QuantErrorStats,
    name: &str,
    data: &[f32],
    rows: usize,
    cols: usize,
    gs: usize,
    fmt: llamaf::quant::FormatId,
) {
    let st = llamaf::quant::error_stats_fmt(data, rows, cols, gs, fmt);
    println!("  {name:<14} rms {:.6}  {}", st.rms(), st.row());
    total.add_tensor_fmt(data, rows, cols, gs, fmt);
}

/// Offline integrity pass: verify every CRC32-checksummed segment of a
/// quantized checkpoint against its footer.  Exits nonzero on the first
/// mismatch (with the corrupt segment named); footer-less legacy files
/// are reported but pass, matching the loader's lenient-open behaviour.
fn cmd_verify_ckpt(args: &Args) -> Result<()> {
    let path = match args.positional.first().map(String::as_str).or_else(|| args.get("ckpt")) {
        Some(p) => p.to_string(),
        None => bail!("usage: llamaf verify-ckpt <path.lfq*>"),
    };
    match llamaf::ckpt::verify_ckpt(Path::new(&path))
        .with_context(|| format!("verifying {path}"))?
    {
        llamaf::ckpt::VerifyOutcome::Ok { segments } => {
            println!("{path}: OK ({segments} segments verified)");
        }
        llamaf::ckpt::VerifyOutcome::NoFooter => {
            println!("{path}: no integrity footer (legacy file; loads unverified)");
        }
    }
    Ok(())
}

/// Compare two `bench-json/` directories (previous vs current run) case
/// by case; exit nonzero when any case regressed beyond `--threshold`
/// (fractional, default 0.20).  CI runs this with `continue-on-error` so
/// the signal is advisory — smoke-mode numbers are noisy by design.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    let prev_dir = Path::new(args.get("prev").context("--prev <dir> required")?);
    let cur_dir = Path::new(args.get("cur").context("--cur <dir> required")?);
    let threshold = args.get_f64("threshold", 0.20)?;
    anyhow::ensure!(threshold > 0.0, "--threshold must be positive");
    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(cur_dir)
        .with_context(|| format!("read {}", cur_dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    files.sort();
    let mut compared = 0usize;
    let mut regressed = 0usize;
    for cur_path in &files {
        let stem = cur_path.file_name().unwrap_or_default();
        let prev_path = prev_dir.join(stem);
        if !prev_path.exists() {
            println!("{}: no previous report, skipping", stem.to_string_lossy());
            continue;
        }
        let prev = llamaf::bench::parse_report(&std::fs::read_to_string(&prev_path)?);
        let cur = llamaf::bench::parse_report(&std::fs::read_to_string(cur_path)?);
        for d in llamaf::bench::diff_cases(&prev, &cur) {
            compared += 1;
            let flagged = d.regression > threshold;
            if flagged {
                regressed += 1;
            }
            println!(
                "{}:{}{}",
                stem.to_string_lossy(),
                d.row(),
                if flagged { "  << REGRESSION" } else { "" }
            );
        }
    }
    println!(
        "bench-diff: {compared} cases compared, {regressed} regressed beyond {:.0}%",
        100.0 * threshold
    );
    anyhow::ensure!(regressed == 0, "{regressed} bench regression(s) beyond the threshold");
    Ok(())
}

/// Compare two execution traces written by `generate --trace` op by op.
/// Prints both trace labels and the diff verdict; exits nonzero unless
/// the traces are bit-identical over the same op schedule, so CI (and
/// scripts) can assert cross-backend equivalence directly.
fn cmd_trace_diff(args: &Args) -> Result<()> {
    anyhow::ensure!(args.positional.len() == 2, "usage: llamaf trace-diff <a.trace> <b.trace>");
    let a = llamaf::trace::ExecTrace::load(Path::new(&args.positional[0]))?;
    let b = llamaf::trace::ExecTrace::load(Path::new(&args.positional[1]))?;
    println!("a: {} ({} ops, {} steps)", a.label(), a.len(), a.steps());
    println!("b: {} ({} ops, {} steps)", b.label(), b.len(), b.steps());
    let report = llamaf::trace::diff(&a, &b);
    println!("{}", report.summary());
    anyhow::ensure!(report.identical(), "traces diverge");
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let art = args.get_or("artifacts", "artifacts");
    println!(
        "llamaf {} — three-layer Rust+JAX+Pallas LlamaF reproduction",
        env!("CARGO_PKG_VERSION")
    );
    println!("artifacts dir: {art}");
    match Runtime::load(Path::new(art)) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("compiled GQMV kernels:");
            for (m, n) in rt.compiled_shapes() {
                println!("  {m:>6} x {n:<6} (g{})", rt.gs);
            }
        }
        Err(e) => println!("runtime unavailable: {e:#}"),
    }
    for ck in ["nano_q8.lfq8", "nano_f32.lfck"] {
        let p = Path::new(art).join(ck);
        if p.exists() {
            let (cfg, fmt) = llamaf::ckpt::peek_config(&p)?;
            println!(
                "checkpoint {ck}: dim={} hidden={} layers={} vocab={} ({})",
                cfg.dim,
                cfg.hidden_dim,
                cfg.n_layers,
                cfg.vocab_size,
                match fmt {
                    Some(f) => f.name(),
                    None => "f32",
                }
            );
        }
    }
    Ok(())
}
