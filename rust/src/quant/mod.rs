//! Group-wise symmetric INT8 quantization (paper §II-B, Eq. 1–2).
//!
//! Bit-exact twin of `python/compile/kernels/ref.py`:
//!   scale  S = max(|r|_group) / 127
//!   q      = clip(round_half_away(r / S), -127, 127)
//!   rhat   = q * S
//!
//! `QuantizedTensor` stores a row-major (rows, cols) int8 matrix with one
//! f32 scale per GS-sized group; rows are what GQMV iterates over, so a
//! fused tensor (e.g. Wq‖Wk‖Wv) is just a row-wise concatenation.

pub mod error;
pub mod format;

pub use error::{error_stats, error_stats_fmt, QuantErrorStats};
pub use format::{FormatId, PackedTensor, QuantFormat};

/// A group-quantized matrix (weights) or vector (activations, rows == 1).
///
/// This is the in-memory **compute** form for every [`FormatId`]: one
/// `i8` per weight regardless of format (sub-INT8 lattices are subsets
/// of INT8, so kernels run unchanged).  `fmt` records which lattice the
/// values live on and which packed **wire** encoding the tensor uses on
/// disk and across the staging path — see [`format::PackedTensor`].
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedTensor {
    pub q: Vec<i8>,
    pub s: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
    pub gs: usize,
    /// Quantization format: lattice of `q` and wire encoding.
    pub fmt: FormatId,
}

impl QuantizedTensor {
    pub fn groups_per_row(&self) -> usize {
        self.cols / self.gs
    }

    /// Quantize a row-major float matrix onto the INT8 lattice.
    pub fn from_f32(data: &[f32], rows: usize, cols: usize, gs: usize) -> Self {
        Self::from_f32_fmt(data, rows, cols, gs, FormatId::Q8)
    }

    /// Quantize a row-major float matrix onto `fmt`'s lattice (scale
    /// `max|r|/qmax` per group; bit-exact with the legacy INT8 path for
    /// [`FormatId::Q8`]).
    pub fn from_f32_fmt(data: &[f32], rows: usize, cols: usize, gs: usize, fmt: FormatId) -> Self {
        assert_eq!(data.len(), rows * cols);
        assert!(cols % gs == 0, "cols={cols} not divisible by gs={gs}");
        let f = fmt.format();
        let n_groups = data.len() / gs;
        let mut q = vec![0i8; data.len()];
        let mut s = vec![0f32; n_groups];
        for g in 0..n_groups {
            s[g] = f.quantize_group_into(&data[g * gs..(g + 1) * gs], &mut q[g * gs..(g + 1) * gs]);
        }
        QuantizedTensor { q, s, rows, cols, gs, fmt }
    }

    /// Dequantize everything back to f32 (Eq. 2).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.q.len()];
        for g in 0..self.s.len() {
            let scale = self.s[g];
            for k in 0..self.gs {
                out[g * self.gs + k] = self.q[g * self.gs + k] as f32 * scale;
            }
        }
        out
    }

    /// Dequantize a single row (used for the token-embedding lookup).
    pub fn dequantize_row(&self, row: usize, out: &mut [f32]) {
        assert!(row < self.rows);
        assert_eq!(out.len(), self.cols);
        let gpr = self.groups_per_row();
        for j in 0..gpr {
            let scale = self.s[row * gpr + j];
            let base = row * self.cols + j * self.gs;
            for k in 0..self.gs {
                out[j * self.gs + k] = self.q[base + k] as f32 * scale;
            }
        }
    }

    /// Row-wise concatenation (paper §III-B fuses Wq‖Wk‖Wv and W1‖W3 so a
    /// single kernel launch consumes a shared input vector).
    pub fn concat_rows(parts: &[&QuantizedTensor]) -> Self {
        assert!(!parts.is_empty());
        let cols = parts[0].cols;
        let gs = parts[0].gs;
        let fmt = parts[0].fmt;
        for p in parts {
            assert_eq!(p.cols, cols);
            assert_eq!(p.gs, gs);
            assert_eq!(p.fmt, fmt);
        }
        let rows = parts.iter().map(|p| p.rows).sum();
        let mut q = Vec::with_capacity(rows * cols);
        let mut s = Vec::with_capacity(rows * cols / gs);
        for p in parts {
            q.extend_from_slice(&p.q);
            s.extend_from_slice(&p.s);
        }
        QuantizedTensor { q, s, rows, cols, gs, fmt }
    }

    /// Bytes this tensor occupies in its packed wire form (the format's
    /// payload encoding + f32 scales) — the quantity the checkpoint
    /// stores and the AXI/DDR transfer model bills.  Delegates to
    /// [`QuantFormat::bytes_for`], so sub-INT8 formats report their real
    /// (halved) wire size even while computing on unpacked i8.
    pub fn stream_bytes(&self) -> usize {
        self.fmt.format().bytes_for(self.rows, self.cols, self.gs)
    }
}

/// Round half away from zero — matches numpy-side `round_half_away` and is
/// exactly `f32::round` semantics (kept explicit for documentation).
#[inline]
pub fn round_half_away(x: f32) -> f32 {
    x.round()
}

/// Quantize one group, returning (int8 values, scale).
pub fn quantize_group(chunk: &[f32]) -> (Vec<i8>, f32) {
    let mut max = 0f32;
    for &v in chunk {
        max = max.max(v.abs());
    }
    let scale = max / 127.0;
    let inv = if scale == 0.0 { 0.0 } else { 1.0 / scale };
    let q = chunk
        .iter()
        .map(|&v| round_half_away(v * inv).clamp(-127.0, 127.0) as i8)
        .collect();
    (q, scale)
}

/// Quantize an activation vector into caller-provided buffers (the hot-path
/// version: zero allocation per token).  x.len() must be a multiple of gs.
pub fn quantize_activation_into(x: &[f32], gs: usize, q: &mut [i8], s: &mut [f32]) {
    debug_assert_eq!(x.len() % gs, 0);
    debug_assert_eq!(q.len(), x.len());
    debug_assert_eq!(s.len(), x.len() / gs);
    for g in 0..s.len() {
        let chunk = &x[g * gs..(g + 1) * gs];
        let mut max = 0f32;
        for &v in chunk {
            max = max.max(v.abs());
        }
        let scale = max / 127.0;
        s[g] = scale;
        let inv = if scale == 0.0 { 0.0 } else { 1.0 / scale };
        let qc = &mut q[g * gs..(g + 1) * gs];
        for k in 0..gs {
            qc[k] = round_half_away(chunk[k] * inv).clamp(-127.0, 127.0) as i8;
        }
    }
}

/// Convenience allocating wrapper around `quantize_activation_into`.
pub fn quantize_activation(x: &[f32], gs: usize) -> (Vec<i8>, Vec<f32>) {
    let mut q = vec![0i8; x.len()];
    let mut s = vec![0f32; x.len() / gs];
    quantize_activation_into(x, gs, &mut q, &mut s);
    (q, s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let mut rng = Rng::new(1);
        for gs in [32, 64, 256] {
            let x = rng.normal_vec(4 * gs, 1.7);
            let t = QuantizedTensor::from_f32(&x, 4, gs, gs);
            let back = t.dequantize();
            for g in 0..t.s.len() {
                for k in 0..gs {
                    let i = g * gs + k;
                    assert!(
                        (back[i] - x[i]).abs() <= t.s[g] / 2.0 + 1e-7,
                        "err {} > S/2 {}",
                        (back[i] - x[i]).abs(),
                        t.s[g] / 2.0
                    );
                }
            }
        }
    }

    #[test]
    fn max_value_maps_to_127() {
        let mut x = vec![0.25f32; 64];
        x[10] = -2.0; // group max
        let (q, s) = quantize_group(&x);
        assert_eq!(q[10], -127);
        assert!((s - 2.0 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn zero_group_quantizes_to_zero() {
        let (q, s) = quantize_group(&[0.0; 32]);
        assert!(q.iter().all(|&v| v == 0));
        assert_eq!(s, 0.0);
    }

    #[test]
    fn idempotent_on_lattice() {
        let mut rng = Rng::new(5);
        let x = rng.normal_vec(256, 3.0);
        let t = QuantizedTensor::from_f32(&x, 1, 256, 64);
        let back = t.dequantize();
        let t2 = QuantizedTensor::from_f32(&back, 1, 256, 64);
        assert_eq!(t.q, t2.q);
        for (a, b) in t.s.iter().zip(&t2.s) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn concat_rows_matches_block_layout() {
        let mut rng = Rng::new(2);
        let a = QuantizedTensor::from_f32(&rng.normal_vec(2 * 64, 1.0), 2, 64, 32);
        let b = QuantizedTensor::from_f32(&rng.normal_vec(3 * 64, 1.0), 3, 64, 32);
        let c = QuantizedTensor::concat_rows(&[&a, &b]);
        assert_eq!(c.rows, 5);
        assert_eq!(&c.q[..a.q.len()], &a.q[..]);
        assert_eq!(&c.q[a.q.len()..], &b.q[..]);
        assert_eq!(&c.s[..a.s.len()], &a.s[..]);
    }

    #[test]
    fn dequantize_row_matches_full() {
        let mut rng = Rng::new(3);
        let t = QuantizedTensor::from_f32(&rng.normal_vec(4 * 128, 1.0), 4, 128, 64);
        let full = t.dequantize();
        let mut row = vec![0f32; 128];
        for r in 0..4 {
            t.dequantize_row(r, &mut row);
            assert_eq!(&row[..], &full[r * 128..(r + 1) * 128]);
        }
    }

    #[test]
    fn activation_into_matches_tensor_path() {
        let mut rng = Rng::new(4);
        let x = rng.normal_vec(512, 2.0);
        let (q, s) = quantize_activation(&x, 256);
        let t = QuantizedTensor::from_f32(&x, 1, 512, 256);
        assert_eq!(q, t.q);
        assert_eq!(s, t.s);
    }

    #[test]
    fn round_half_away_semantics() {
        assert_eq!(round_half_away(0.5), 1.0);
        assert_eq!(round_half_away(-0.5), -1.0);
        assert_eq!(round_half_away(2.5), 3.0);
        assert_eq!(round_half_away(-2.5), -3.0);
        assert_eq!(round_half_away(2.4), 2.0);
    }

    #[test]
    fn stream_bytes_accounts_scales() {
        let t = QuantizedTensor::from_f32(&vec![1.0; 512], 2, 256, 256);
        assert_eq!(t.stream_bytes(), 512 + 4 * 2);
    }
}
