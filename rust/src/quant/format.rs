//! The quantization-format abstraction: `QuantFormat`.
//!
//! PR 5's streaming machinery (matrix-granular offsets, the staging
//! ring) and the GQMV kernels are format-agnostic in *shape* — they only
//! care about rows, group counts and byte totals — but until this module
//! every byte count in the tree hardcoded INT8's "one byte per weight".
//! `QuantFormat` owns everything a format actually decides:
//!
//! * the **lattice** — `qmax`, so quantize/dequantize share one generic
//!   group routine (scale `S = max|r| / qmax`, `q = clamp(round(r/S))`);
//! * the **wire encoding** — `pack_group`/`unpack_group` turn lattice
//!   values into the packed bytes a checkpoint stores and the AXI/DDR
//!   transfer model bills (`bytes_for`), Q4_0 packing two weights per
//!   byte and Q5_0 adding a 1-bit plane;
//! * a **`gqmv_rows`-compatible packed row kernel** — group-outer /
//!   row-inner in [`ROW_BLOCK`]-row cache blocks over the *packed* bytes,
//!   unpacking each group inline, with the exact Algorithm-1 cast chain
//!   (i16 products, i32 group sums, f32 scaled accumulation in ascending
//!   group order) so it is bit-identical to the unpacked kernel.
//!
//! The in-memory compute form stays one unpacked `i8` per weight
//! ([`QuantizedTensor`]) for every format: sub-INT8 lattices are subsets
//! of INT8, so the entire forward path — host and device — runs
//! unchanged and stays bit-exact per format.  What a format changes is
//! the *wire* form: checkpoint bytes, staged bytes, bytes per token.
//! (On the FPGA this is the post-DDR nibble-unpack stage; in the host
//! sim it is [`PackedTensor::unpack`] at the staging boundary.)
//!
//! Block geometry note: ggml's Q4_0/Q5_0 use 32-element blocks; here a
//! block is one quantization **group** of the model's `gs` (the paper's
//! g = 256), because the GQMV cast chain requires weight scale groups to
//! coincide with activation groups.  The GGUF importer re-groups on
//! import (`ckpt/gguf.rs`).

use crate::quant::{round_half_away, QuantizedTensor};

/// Rows per cache block of the packed row kernels — kept equal to the
/// unpacked kernel's [`crate::ps::gqmv::ROW_BLOCK`] so the two loop
/// nests are step-for-step twins.
pub const ROW_BLOCK: usize = crate::ps::gqmv::ROW_BLOCK;

/// Identifies a quantization format (the `--quant-format` values).
///
/// This is the plain-old-data handle stored on tensors and checkpoints;
/// behaviour lives behind [`FormatId::format`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FormatId {
    /// Group-wise symmetric INT8 (the paper's format; magic `LFQ8`).
    Q8,
    /// 4-bit group format, two weights per byte (magic `LFQ4`).
    Q40,
    /// 5-bit group format, nibble plane + 1-bit plane (magic `LFQ5`).
    Q50,
}

static Q8_FORMAT: Q8Format = Q8Format;
static Q40_FORMAT: Q40Format = Q40Format;
static Q50_FORMAT: Q50Format = Q50Format;

impl FormatId {
    /// Every supported format, in CLI/doc order.
    pub const ALL: [FormatId; 3] = [FormatId::Q8, FormatId::Q40, FormatId::Q50];

    /// Stable lowercase name (CLI values, STATS `quant=` label, bench
    /// case tags).
    pub fn name(self) -> &'static str {
        match self {
            FormatId::Q8 => "q8",
            FormatId::Q40 => "q4_0",
            FormatId::Q50 => "q5_0",
        }
    }

    /// Parse a CLI/config spelling.
    pub fn parse(s: &str) -> Option<FormatId> {
        match s {
            "q8" | "q8_0" | "int8" => Some(FormatId::Q8),
            "q4" | "q4_0" => Some(FormatId::Q40),
            "q5" | "q5_0" => Some(FormatId::Q50),
            _ => None,
        }
    }

    /// Checkpoint magic for this format (`ckpt` module file headers).
    pub fn magic(self) -> [u8; 4] {
        match self {
            FormatId::Q8 => *b"LFQ8",
            FormatId::Q40 => *b"LFQ4",
            FormatId::Q50 => *b"LFQ5",
        }
    }

    /// Inverse of [`FormatId::magic`].
    pub fn from_magic(magic: &[u8; 4]) -> Option<FormatId> {
        FormatId::ALL.into_iter().find(|f| &f.magic() == magic)
    }

    /// The behaviour object for this format.
    pub fn format(self) -> &'static dyn QuantFormat {
        match self {
            FormatId::Q8 => &Q8_FORMAT,
            FormatId::Q40 => &Q40_FORMAT,
            FormatId::Q50 => &Q50_FORMAT,
        }
    }

    /// Largest lattice magnitude (`127` / `7` / `15`).
    pub fn qmax(self) -> i8 {
        self.format().qmax()
    }
}

impl std::fmt::Display for FormatId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Behaviour of one quantization format: lattice, wire encoding, byte
/// accounting, and a packed row kernel.  Implementations are stateless
/// statics reached through [`FormatId::format`].
pub trait QuantFormat: Sync {
    /// The identifier this behaviour object belongs to.
    fn id(&self) -> FormatId;

    /// Stable lowercase name (same as [`FormatId::name`]).
    fn name(&self) -> &'static str {
        self.id().name()
    }

    /// Largest representable lattice magnitude: quantization clamps to
    /// `[-qmax, qmax]` and scales by `max|r| / qmax`.
    fn qmax(&self) -> i8;

    /// Packed payload bytes for one `gs`-sized group, excluding the f32
    /// scale.  Panics if `gs` is incompatible with the format's packing
    /// (Q4 needs `gs % 2 == 0`, Q5 needs `gs % 8 == 0`).
    fn group_payload_bytes(&self, gs: usize) -> usize;

    /// Total wire bytes of a `rows × cols` tensor at group size `gs`:
    /// packed payload plus one f32 scale per group.  This is what the
    /// checkpoint stores per tensor and what the AXI/DDR transfer model
    /// bills per staged copy.
    fn bytes_for(&self, rows: usize, cols: usize, gs: usize) -> usize {
        let groups = rows * cols / gs;
        groups * (self.group_payload_bytes(gs) + 4)
    }

    /// Quantize one group onto this format's lattice, returning the
    /// scale.  Generic over `qmax`; for [`FormatId::Q8`] this is
    /// bit-exact with [`crate::quant::quantize_group`].
    fn quantize_group_into(&self, chunk: &[f32], q: &mut [i8]) -> f32 {
        debug_assert_eq!(chunk.len(), q.len());
        let qmax = self.qmax() as f32;
        let mut max = 0f32;
        for &v in chunk {
            max = max.max(v.abs());
        }
        let scale = max / qmax;
        let inv = if scale == 0.0 { 0.0 } else { 1.0 / scale };
        for (dst, &v) in q.iter_mut().zip(chunk) {
            *dst = round_half_away(v * inv).clamp(-qmax, qmax) as i8;
        }
        scale
    }

    /// Pack one group of lattice values (each in `[-qmax, qmax]`) into
    /// `group_payload_bytes(q.len())` wire bytes.
    fn pack_group(&self, q: &[i8], out: &mut [u8]);

    /// Inverse of [`QuantFormat::pack_group`]; exact for lattice values.
    fn unpack_group(&self, packed: &[u8], q: &mut [i8]);

    /// Cache-blocked row kernel over the **packed** bytes: computes
    /// `out.len()` consecutive output rows of Algorithm 1 starting at
    /// row `row0` of `w`, unpacking each weight group inline.  The loop
    /// nest and cast chain mirror [`crate::ps::gqmv::gqmv_rows`]
    /// exactly, so outputs are bit-identical to unpacking first and
    /// running the unpacked kernel (pinned by tests).
    fn gqmv_rows_packed(
        &self,
        xq: &[i8],
        xs: &[f32],
        w: &PackedTensor,
        row0: usize,
        out: &mut [f32],
    ) {
        let gs = w.gs;
        let groups = xq.len() / gs;
        let gpb = self.group_payload_bytes(gs);
        let row_payload = groups * gpb;
        let mut scratch = vec![0i8; gs];
        let rows = out.len();
        let mut r = 0;
        while r < rows {
            let rb = ROW_BLOCK.min(rows - r);
            let mut acc = [0.0f32; ROW_BLOCK];
            for g in 0..groups {
                let base = g * gs;
                let xg = &xq[base..base + gs];
                let xscale = xs[g];
                for (i, a) in acc.iter_mut().enumerate().take(rb) {
                    let row = row0 + r + i;
                    let pbase = row * row_payload + g * gpb;
                    self.unpack_group(&w.data[pbase..pbase + gpb], &mut scratch);
                    let group_sum: i32 = scratch
                        .iter()
                        .zip(xg)
                        .map(|(&wv, &x)| ((wv as i16) * (x as i16)) as i32)
                        .sum();
                    *a += group_sum as f32 * (w.s[row * groups + g] * xscale);
                }
            }
            out[r..r + rb].copy_from_slice(&acc[..rb]);
            r += rb;
        }
    }
}

/// Group-wise symmetric INT8 — the paper's format (§II-B Eq. 1–2), one
/// byte per weight on the wire.
pub struct Q8Format;

impl QuantFormat for Q8Format {
    fn id(&self) -> FormatId {
        FormatId::Q8
    }

    fn qmax(&self) -> i8 {
        127
    }

    fn group_payload_bytes(&self, gs: usize) -> usize {
        gs
    }

    fn pack_group(&self, q: &[i8], out: &mut [u8]) {
        debug_assert_eq!(out.len(), q.len());
        for (dst, &v) in out.iter_mut().zip(q) {
            *dst = v as u8;
        }
    }

    fn unpack_group(&self, packed: &[u8], q: &mut [i8]) {
        debug_assert_eq!(packed.len(), q.len());
        for (dst, &b) in q.iter_mut().zip(packed) {
            *dst = b as i8;
        }
    }
}

/// 4-bit group format: lattice `[-7, 7]`, packed two weights per byte
/// (weight `2k` in the low nibble of byte `k`, `2k+1` in the high
/// nibble, biased by +8).  Halves the wire bytes of Q8 at the cost of
/// ~16× coarser steps.
pub struct Q40Format;

impl QuantFormat for Q40Format {
    fn id(&self) -> FormatId {
        FormatId::Q40
    }

    fn qmax(&self) -> i8 {
        7
    }

    fn group_payload_bytes(&self, gs: usize) -> usize {
        assert!(gs % 2 == 0, "q4_0 packs two weights per byte; gs={gs} must be even");
        gs / 2
    }

    fn pack_group(&self, q: &[i8], out: &mut [u8]) {
        debug_assert_eq!(out.len(), q.len() / 2);
        for (k, dst) in out.iter_mut().enumerate() {
            let lo = (q[2 * k] + 8) as u8;
            let hi = (q[2 * k + 1] + 8) as u8;
            *dst = lo | (hi << 4);
        }
    }

    fn unpack_group(&self, packed: &[u8], q: &mut [i8]) {
        debug_assert_eq!(packed.len(), q.len() / 2);
        for (k, &b) in packed.iter().enumerate() {
            q[2 * k] = (b & 0x0F) as i8 - 8;
            q[2 * k + 1] = (b >> 4) as i8 - 8;
        }
    }

    fn gqmv_rows_packed(
        &self,
        xq: &[i8],
        xs: &[f32],
        w: &PackedTensor,
        row0: usize,
        out: &mut [f32],
    ) {
        // Specialized nibble-inline variant: no scratch buffer, each
        // packed byte feeds two MACs directly.  Same blocked loop nest
        // and cast chain as the generic path, so still bit-identical.
        let gs = w.gs;
        let groups = xq.len() / gs;
        let gpb = gs / 2;
        let row_payload = groups * gpb;
        let rows = out.len();
        let mut r = 0;
        while r < rows {
            let rb = ROW_BLOCK.min(rows - r);
            let mut acc = [0.0f32; ROW_BLOCK];
            for g in 0..groups {
                let base = g * gs;
                let xg = &xq[base..base + gs];
                let xscale = xs[g];
                for (i, a) in acc.iter_mut().enumerate().take(rb) {
                    let row = row0 + r + i;
                    let pbase = row * row_payload + g * gpb;
                    let bytes = &w.data[pbase..pbase + gpb];
                    let group_sum: i32 = bytes
                        .iter()
                        .zip(xg.chunks_exact(2))
                        .map(|(&b, x2)| {
                            let lo = ((b & 0x0F) as i16 - 8) * (x2[0] as i16);
                            let hi = ((b >> 4) as i16 - 8) * (x2[1] as i16);
                            lo as i32 + hi as i32
                        })
                        .sum();
                    *a += group_sum as f32 * (w.s[row * groups + g] * xscale);
                }
            }
            out[r..r + rb].copy_from_slice(&acc[..rb]);
            r += rb;
        }
    }
}

/// 5-bit group format: lattice `[-15, 15]`, packed as a Q4-style nibble
/// plane (low 4 bits of the +16-biased value) plus a 1-bit high plane
/// (`gs/8` bytes, weight `8b + j` in bit `j` of plane byte `b`).
pub struct Q50Format;

impl QuantFormat for Q50Format {
    fn id(&self) -> FormatId {
        FormatId::Q50
    }

    fn qmax(&self) -> i8 {
        15
    }

    fn group_payload_bytes(&self, gs: usize) -> usize {
        assert!(gs % 8 == 0, "q5_0 packs a 1-bit plane per 8 weights; gs={gs} % 8 != 0");
        gs / 2 + gs / 8
    }

    fn pack_group(&self, q: &[i8], out: &mut [u8]) {
        let gs = q.len();
        debug_assert_eq!(out.len(), gs / 2 + gs / 8);
        let (nibbles, plane) = out.split_at_mut(gs / 2);
        for (k, dst) in nibbles.iter_mut().enumerate() {
            let lo = (q[2 * k] + 16) as u8 & 0x0F;
            let hi = (q[2 * k + 1] + 16) as u8 & 0x0F;
            *dst = lo | (hi << 4);
        }
        for (b, dst) in plane.iter_mut().enumerate() {
            let mut bits = 0u8;
            for j in 0..8 {
                bits |= (((q[8 * b + j] + 16) as u8 >> 4) & 1) << j;
            }
            *dst = bits;
        }
    }

    fn unpack_group(&self, packed: &[u8], q: &mut [i8]) {
        let gs = q.len();
        debug_assert_eq!(packed.len(), gs / 2 + gs / 8);
        let (nibbles, plane) = packed.split_at(gs / 2);
        for (k, &b) in nibbles.iter().enumerate() {
            q[2 * k] = (b & 0x0F) as i8 - 16;
            q[2 * k + 1] = (b >> 4) as i8 - 16;
        }
        for (b, &bits) in plane.iter().enumerate() {
            for j in 0..8 {
                q[8 * b + j] += (((bits >> j) & 1) as i8) << 4;
            }
        }
    }
}

/// A tensor in its packed wire form: what a checkpoint stores per
/// matrix and what the staging path transfers.  `data` is row-major
/// groups of `fmt`'s packed payload; `s` is one f32 scale per group in
/// the same order as [`QuantizedTensor::s`].
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    /// Wire encoding of `data`.
    pub fmt: FormatId,
    /// Packed payload: `rows × (cols/gs)` groups of
    /// `group_payload_bytes(gs)` each, row-major.
    pub data: Vec<u8>,
    /// One f32 scale per group, row-major.
    pub s: Vec<f32>,
    /// Output rows.
    pub rows: usize,
    /// Input columns.
    pub cols: usize,
    /// Quantization group size (equals the model's activation gs).
    pub gs: usize,
}

impl PackedTensor {
    /// Pack an unpacked tensor into its format's wire encoding.
    pub fn pack(t: &QuantizedTensor) -> PackedTensor {
        let f = t.fmt.format();
        let gpb = f.group_payload_bytes(t.gs);
        let groups = t.s.len();
        let mut data = vec![0u8; groups * gpb];
        for g in 0..groups {
            f.pack_group(&t.q[g * t.gs..(g + 1) * t.gs], &mut data[g * gpb..(g + 1) * gpb]);
        }
        PackedTensor { fmt: t.fmt, data, s: t.s.clone(), rows: t.rows, cols: t.cols, gs: t.gs }
    }

    /// Unpack back to the i8 compute form; exact (pack is lossless on
    /// the lattice).
    pub fn unpack(&self) -> QuantizedTensor {
        let f = self.fmt.format();
        let gpb = f.group_payload_bytes(self.gs);
        let groups = self.s.len();
        let mut q = vec![0i8; groups * self.gs];
        for g in 0..groups {
            f.unpack_group(
                &self.data[g * gpb..(g + 1) * gpb],
                &mut q[g * self.gs..(g + 1) * self.gs],
            );
        }
        QuantizedTensor {
            q,
            s: self.s.clone(),
            rows: self.rows,
            cols: self.cols,
            gs: self.gs,
            fmt: self.fmt,
        }
    }

    /// Wire bytes of this tensor (packed payload + scales) — equals
    /// `fmt.format().bytes_for(rows, cols, gs)`.
    pub fn wire_bytes(&self) -> usize {
        self.data.len() + 4 * self.s.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::gqmv::gqmv_rows;
    use crate::quant::quantize_activation;
    use crate::util::Rng;

    #[test]
    fn names_magics_and_parse_round_trip() {
        for fmt in FormatId::ALL {
            assert_eq!(FormatId::parse(fmt.name()), Some(fmt));
            assert_eq!(FormatId::from_magic(&fmt.magic()), Some(fmt));
            assert_eq!(fmt.format().id(), fmt);
        }
        assert_eq!(FormatId::parse("q4"), Some(FormatId::Q40));
        assert_eq!(FormatId::parse("int8"), Some(FormatId::Q8));
        assert_eq!(FormatId::parse("fp16"), None);
        assert_eq!(FormatId::from_magic(b"LFCK"), None);
    }

    #[test]
    fn bytes_for_matches_hand_counts() {
        // one 2x256 tensor at gs=256: 2 groups
        let (r, c, gs) = (2, 256, 256);
        assert_eq!(FormatId::Q8.format().bytes_for(r, c, gs), 2 * (256 + 4));
        assert_eq!(FormatId::Q40.format().bytes_for(r, c, gs), 2 * (128 + 4));
        assert_eq!(FormatId::Q50.format().bytes_for(r, c, gs), 2 * (128 + 32 + 4));
        // the acceptance ratio: q4_0 <= 0.55x q8 at the paper's gs
        let q8 = FormatId::Q8.format().bytes_for(64, 256, 256) as f64;
        let q4 = FormatId::Q40.format().bytes_for(64, 256, 256) as f64;
        assert!(q4 / q8 <= 0.55, "q4/q8 = {}", q4 / q8);
    }

    #[test]
    fn q8_quantize_group_matches_legacy() {
        let mut rng = Rng::new(11);
        let x = rng.normal_vec(256, 1.3);
        let (legacy_q, legacy_s) = crate::quant::quantize_group(&x);
        let mut q = vec![0i8; 256];
        let s = FormatId::Q8.format().quantize_group_into(&x, &mut q);
        assert_eq!(q, legacy_q);
        assert_eq!(s, legacy_s);
    }

    #[test]
    fn quantize_error_bounded_by_half_step() {
        let mut rng = Rng::new(12);
        for fmt in FormatId::ALL {
            let f = fmt.format();
            let x = rng.normal_vec(512, 2.1);
            for chunk in x.chunks(64) {
                let mut q = vec![0i8; chunk.len()];
                let s = f.quantize_group_into(chunk, &mut q);
                for (qi, &v) in q.iter().zip(chunk) {
                    assert!(qi.abs() <= f.qmax(), "{fmt}: |{qi}| > qmax");
                    let err = (*qi as f32 * s - v).abs();
                    assert!(err <= s / 2.0 + 1e-7, "{fmt}: err {err} > S/2 {}", s / 2.0);
                }
            }
        }
    }

    #[test]
    fn pack_unpack_exact_on_full_lattice() {
        for fmt in FormatId::ALL {
            let f = fmt.format();
            let qmax = f.qmax() as i32;
            // every lattice value appears, plus the extremes at the ends
            let gs = 64;
            let q: Vec<i8> =
                (0..gs).map(|i| ((i as i32 * 7 + 3) % (2 * qmax + 1) - qmax) as i8).collect();
            let mut packed = vec![0u8; f.group_payload_bytes(gs)];
            f.pack_group(&q, &mut packed);
            let mut back = vec![0i8; gs];
            f.unpack_group(&packed, &mut back);
            assert_eq!(back, q, "{fmt}: pack/unpack not lossless");
        }
    }

    #[test]
    fn packed_tensor_round_trips_and_counts_bytes() {
        let mut rng = Rng::new(13);
        for fmt in FormatId::ALL {
            let (rows, cols, gs) = (5, 128, 32);
            let x = rng.normal_vec(rows * cols, 0.9);
            let t = QuantizedTensor::from_f32_fmt(&x, rows, cols, gs, fmt);
            let p = PackedTensor::pack(&t);
            assert_eq!(p.wire_bytes(), fmt.format().bytes_for(rows, cols, gs));
            assert_eq!(p.wire_bytes(), t.stream_bytes());
            assert_eq!(p.unpack(), t, "{fmt}: packed round trip diverged");
        }
    }

    #[test]
    fn packed_kernel_bit_identical_to_unpacked() {
        let mut rng = Rng::new(14);
        // row counts off the ROW_BLOCK boundary on purpose
        for (rows, cols, gs) in [(1usize, 256usize, 256usize), (7, 256, 64), (21, 512, 128)] {
            let x = rng.normal_vec(cols, 1.0);
            let (xq, xs) = quantize_activation(&x, gs);
            for fmt in FormatId::ALL {
                let t = QuantizedTensor::from_f32_fmt(
                    &rng.normal_vec(rows * cols, 0.5),
                    rows,
                    cols,
                    gs,
                    fmt,
                );
                let mut want = vec![0.0f32; rows];
                gqmv_rows(&xq, &xs, &t.q, &t.s, gs, &mut want);
                let p = PackedTensor::pack(&t);
                let mut got = vec![0.0f32; rows];
                fmt.format().gqmv_rows_packed(&xq, &xs, &p, 0, &mut got);
                assert_eq!(got, want, "{fmt} rows={rows} cols={cols} gs={gs}");
                // nonzero row0: the tail half of the matrix alone
                let half = rows / 2;
                let mut tail = vec![0.0f32; rows - half];
                fmt.format().gqmv_rows_packed(&xq, &xs, &p, half, &mut tail);
                assert_eq!(tail, want[half..], "{fmt} row0={half}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "q4_0")]
    fn q4_rejects_odd_group_size() {
        FormatId::Q40.format().group_payload_bytes(33);
    }
}
