//! Quantization error statistics — reproduces paper Table IV and the
//! error-percentage figures quoted in §V-B (mean 3.30%, std 11.57%).

use super::QuantizedTensor;
use crate::util::OnlineStats;

/// Statistics of |rhat - r| and of the relative error percentage.
#[derive(Clone, Debug, Default)]
pub struct QuantErrorStats {
    pub abs: OnlineStats,
    pub pct: OnlineStats,
}

impl QuantErrorStats {
    /// Accumulate errors for one float tensor quantized at group size `gs`.
    pub fn add_tensor(&mut self, data: &[f32], rows: usize, cols: usize, gs: usize) {
        let t = QuantizedTensor::from_f32(data, rows, cols, gs);
        let back = t.dequantize();
        for i in 0..data.len() {
            let err = (back[i] - data[i]).abs() as f64;
            self.abs.push(err);
            let r = data[i].abs() as f64;
            if r > 1e-12 {
                self.pct.push(err / r * 100.0);
            }
        }
    }

    pub fn row(&self) -> String {
        format!(
            "max {:.6}  min {:.6}  mean {:.6}  std {:.6}  |  err%: mean {:.2}%  std {:.2}%",
            self.abs.max(),
            self.abs.min(),
            self.abs.mean(),
            self.abs.std(),
            self.pct.mean(),
            self.pct.std()
        )
    }
}

/// One-shot helper for a single tensor.
pub fn error_stats(data: &[f32], rows: usize, cols: usize, gs: usize) -> QuantErrorStats {
    let mut s = QuantErrorStats::default();
    s.add_tensor(data, rows, cols, gs);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn normal_weights_error_scale() {
        // For N(0, sigma) weights with GS=256, the group max is ~2.9 sigma,
        // so scale ~ 2.9 sigma/127 and mean |err| ~ scale/4 ~ 0.0057 sigma.
        let mut rng = Rng::new(1);
        let sigma = 0.02f32; // typical trained-weight std
        let data = rng.normal_vec(256 * 256, sigma);
        let st = error_stats(&data, 256, 256, 256);
        assert!(st.abs.max() < 3.0 * sigma as f64 / 127.0 * 2.0);
        assert!(st.abs.mean() > 0.0);
        assert!(st.abs.mean() < st.abs.max());
        // paper-order percentages: a few percent mean
        assert!(st.pct.mean() > 0.1 && st.pct.mean() < 20.0, "pct {}", st.pct.mean());
    }

    #[test]
    fn exact_lattice_zero_error() {
        // values already on the quantization lattice
        let t = QuantizedTensor::from_f32(&vec![0.5, -0.5, 0.25, 0.0].repeat(16), 1, 64, 64);
        let back = t.dequantize();
        let st = error_stats(&back, 1, 64, 64);
        assert!(st.abs.max() < 1e-7);
    }
}
