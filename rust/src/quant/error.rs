//! Quantization error statistics — reproduces paper Table IV and the
//! error-percentage figures quoted in §V-B (mean 3.30%, std 11.57%).

use super::{FormatId, QuantizedTensor};
use crate::util::OnlineStats;

/// Statistics of |rhat - r| and of the relative error percentage.
#[derive(Clone, Debug, Default)]
pub struct QuantErrorStats {
    pub abs: OnlineStats,
    pub pct: OnlineStats,
}

impl QuantErrorStats {
    /// Accumulate errors for one float tensor quantized at group size `gs`
    /// on the INT8 lattice.
    pub fn add_tensor(&mut self, data: &[f32], rows: usize, cols: usize, gs: usize) {
        self.add_tensor_fmt(data, rows, cols, gs, FormatId::Q8)
    }

    /// [`QuantErrorStats::add_tensor`] on an arbitrary weight lattice —
    /// what `llamaf quant-error --format` sweeps to compare formats.
    pub fn add_tensor_fmt(
        &mut self,
        data: &[f32],
        rows: usize,
        cols: usize,
        gs: usize,
        fmt: FormatId,
    ) {
        let t = QuantizedTensor::from_f32_fmt(data, rows, cols, gs, fmt);
        let back = t.dequantize();
        for i in 0..data.len() {
            let err = (back[i] - data[i]).abs() as f64;
            self.abs.push(err);
            let r = data[i].abs() as f64;
            if r > 1e-12 {
                self.pct.push(err / r * 100.0);
            }
        }
    }

    /// Root-mean-square absolute error (the per-matrix figure
    /// `llamaf quant-error` prints).
    pub fn rms(&self) -> f64 {
        (self.abs.mean().powi(2) + self.abs.std().powi(2)).sqrt()
    }

    pub fn row(&self) -> String {
        format!(
            "max {:.6}  min {:.6}  mean {:.6}  std {:.6}  |  err%: mean {:.2}%  std {:.2}%",
            self.abs.max(),
            self.abs.min(),
            self.abs.mean(),
            self.abs.std(),
            self.pct.mean(),
            self.pct.std()
        )
    }
}

/// One-shot helper for a single tensor (INT8 lattice).
pub fn error_stats(data: &[f32], rows: usize, cols: usize, gs: usize) -> QuantErrorStats {
    error_stats_fmt(data, rows, cols, gs, FormatId::Q8)
}

/// One-shot helper for a single tensor on an arbitrary lattice.
pub fn error_stats_fmt(
    data: &[f32],
    rows: usize,
    cols: usize,
    gs: usize,
    fmt: FormatId,
) -> QuantErrorStats {
    let mut s = QuantErrorStats::default();
    s.add_tensor_fmt(data, rows, cols, gs, fmt);
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn normal_weights_error_scale() {
        // For N(0, sigma) weights with GS=256, the group max is ~2.9 sigma,
        // so scale ~ 2.9 sigma/127 and mean |err| ~ scale/4 ~ 0.0057 sigma.
        let mut rng = Rng::new(1);
        let sigma = 0.02f32; // typical trained-weight std
        let data = rng.normal_vec(256 * 256, sigma);
        let st = error_stats(&data, 256, 256, 256);
        assert!(st.abs.max() < 3.0 * sigma as f64 / 127.0 * 2.0);
        assert!(st.abs.mean() > 0.0);
        assert!(st.abs.mean() < st.abs.max());
        // paper-order percentages: a few percent mean
        assert!(st.pct.mean() > 0.1 && st.pct.mean() < 20.0, "pct {}", st.pct.mean());
    }

    #[test]
    fn narrower_lattices_cost_monotonically_more_error() {
        let mut rng = Rng::new(3);
        let data = rng.normal_vec(128 * 128, 0.02f32);
        let errs: Vec<f64> = FormatId::ALL
            .iter()
            .map(|&f| error_stats_fmt(&data, 128, 128, 64, f).abs.mean())
            .collect();
        // ALL = [Q8, Q40, Q50]: q8 < q5_0 < q4_0 mean error
        assert!(errs[0] < errs[2] && errs[2] < errs[1], "{errs:?}");
        // and each format's mean error is about step/4:
        // step = group_absmax/qmax ~ 3 sigma/qmax for gs=64
        for (&fmt, &e) in FormatId::ALL.iter().zip(&errs) {
            let step = 3.0 * 0.02 / fmt.qmax() as f64;
            assert!(e < step, "{fmt}: mean {e} vs step {step}");
            assert!(e > step / 16.0, "{fmt}: mean {e} suspiciously small");
        }
    }

    #[test]
    fn rms_between_mean_and_max() {
        let mut rng = Rng::new(4);
        let data = rng.normal_vec(64 * 64, 0.02f32);
        let st = error_stats(&data, 64, 64, 32);
        assert!(st.rms() >= st.abs.mean());
        assert!(st.rms() <= st.abs.max());
    }

    #[test]
    fn exact_lattice_zero_error() {
        // values already on the quantization lattice
        let t = QuantizedTensor::from_f32(&vec![0.5, -0.5, 0.25, 0.0].repeat(16), 1, 64, 64);
        let back = t.dequantize();
        let st = error_stats(&back, 1, 64, 64);
        assert!(st.abs.max() < 1e-7);
    }
}
