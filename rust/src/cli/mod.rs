//! Hand-rolled CLI argument parser (no `clap` in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed command line: subcommand, options, positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

/// Flags that take no value (everything else consumes the next token).
const BOOL_FLAGS: &[&str] = &[
    "help", "full", "no-sched", "sync", "async", "quiet", "verbose", "json",
    "stream", "greedy", "resident", "quick",
];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if BOOL_FLAGS.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else {
                    if i + 1 >= argv.len() {
                        bail!("option --{rest} requires a value");
                    }
                    out.options.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                }
            } else if out.command.is_none() {
                out.command = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        let argv: Vec<String> = s.split_whitespace().map(String::from).collect();
        Args::parse(&argv).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("generate --ckpt model.lfq8 --steps 64 --prompt hello");
        assert_eq!(a.command.as_deref(), Some("generate"));
        assert_eq!(a.get("ckpt"), Some("model.lfq8"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 64);
        assert_eq!(a.get("prompt"), Some("hello"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("tables --table=6 --json");
        assert_eq!(a.get("table"), Some("6"));
        assert!(a.flag("json"));
    }

    #[test]
    fn bool_flags_consume_nothing() {
        let a = parse("bench --no-sched --steps 10");
        assert!(a.flag("no-sched"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 10);
    }

    #[test]
    fn missing_value_is_error() {
        let argv = vec!["x".to_string(), "--ckpt".to_string()];
        assert!(Args::parse(&argv).is_err());
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse("x --steps abc");
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("mode", "sync"), "sync");
        assert_eq!(a.get_usize("steps", 7).unwrap(), 7);
        assert!((a.get_f64("top-p", 0.9).unwrap() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn positionals() {
        let a = parse("run file1 file2 --k v");
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }

    #[test]
    fn fault_injection_flags_take_values() {
        // the serve/generate fault-tolerance flags are ordinary
        // value-taking options, not BOOL_FLAGS
        let a = parse("serve --inject-faults p=0.01,seed=7 --request-timeout 250");
        assert_eq!(a.get("inject-faults"), Some("p=0.01,seed=7"));
        assert_eq!(a.get_usize("request-timeout", 0).unwrap(), 250);
        let b = parse("verify-ckpt model.lfq8");
        assert_eq!(b.command.as_deref(), Some("verify-ckpt"));
        assert_eq!(b.positional, vec!["model.lfq8"]);
    }
}
