//! The LlamaF engine: Algorithm 2 with streamed weights and GQMV executed
//! by the AOT-compiled Pallas kernel via PJRT (the functional PL).
//!
//! Since the device-path unification there is **no private copy of the
//! Algorithm-2 arithmetic here**: decoding runs through the same
//! [`forward_batch`](crate::engine::forward::forward_batch) as every CPU engine (one lane), with a device-aware
//! provider/executor pair replacing the resident model:
//!
//! * [`DeviceLayers`] streams layer weights through the staging
//!   [`Streamer`] (sync/async, `--prefetch-depth`,
//!   `--stream-granularity`), lends the HOST copies to the pass (norm
//!   vectors, activation quantization) and registers each staged matrix's
//!   DEVICE buffer;
//! * [`DeviceGqmv`] executes every GQMV on those pre-staged device
//!   buffers via [`Runtime::gqmv_device`] — including the split-tensor
//!   fused launch ([`Runtime::gqmv_device_fused`]) when a same-input
//!   group arrives as separate tensors.
//!
//! Control flow (RMSNorm, RoPE, attention, SwiGLU, sampling) stays on the
//! "PS" (this thread); kernels consume device-resident weight buffers.
//! The device path stays dispatch-minimal — four kernel launches per
//! layer, because Wq‖Wk‖Wv and W1‖W3 ship as storage-fused buffers.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::ckpt;
use crate::engine::forward::{forward_batch_traced, BatchLane, BatchScratch, Engine, LayerProvider};
use crate::metrics::ForwardProfile;
use crate::model::{KvCache, KvStore, LlamaConfig, MatrixUnit, QuantModel};
use crate::ps::gqmv::{check_shapes, check_shapes_fused, GqmvExec};
use crate::quant::QuantizedTensor;
use crate::runtime::{DeviceWeights, Runtime};
use crate::sched::{
    DiskFetcher, FaultPlan, FaultyFetcher, MemFetcher, PreparedMatrix, RetryPolicy, SchedMode,
    StageGranularity, Streamer, StreamerStats,
};
use crate::trace::{ExecTrace, TraceSink};

/// Host-tensor → device-buffer map shared by the [`DeviceLayers`]
/// provider (which registers buffers as the streamer stages them) and the
/// [`DeviceGqmv`] executor (which launches kernels on them).  Keyed by
/// the host tensor's data pointer: the provider lends exactly the host
/// copies whose buffers it registered, so a lookup miss means the
/// provider/executor pairing is broken — an error, never a re-upload.
#[derive(Clone)]
pub struct DevRegistry {
    inner: Arc<Mutex<DevRegistryInner>>,
}

struct DevRegistryInner {
    /// Permanently resident buffers (the classifier).
    pinned: HashMap<usize, Arc<DeviceWeights>>,
    /// Buffers of the layer walk currently in flight; evicted wholesale at
    /// the start of the next layer walk so the map stays bounded (≤ 4
    /// entries + pinned) — even on a 1-layer model that restages the same
    /// layer index every token.
    layer: HashMap<usize, Arc<DeviceWeights>>,
}

fn key(host: &QuantizedTensor) -> usize {
    host.q.as_ptr() as usize
}

impl DevRegistry {
    /// Empty registry (no pinned or layer buffers yet).
    pub fn new() -> Self {
        DevRegistry {
            inner: Arc::new(Mutex::new(DevRegistryInner {
                pinned: HashMap::new(),
                layer: HashMap::new(),
            })),
        }
    }

    /// Register a permanently resident buffer (survives layer turnover).
    pub fn pin(&self, host: &QuantizedTensor, dev: Arc<DeviceWeights>) {
        self.inner.lock().unwrap().pinned.insert(key(host), dev);
    }

    /// Register one staged layer matrix.  `first_of_layer` (the fused QKV
    /// block, the first matrix every layer walk registers) evicts the
    /// previous walk's entries, keeping the map bounded and its buffers
    /// droppable.
    fn register(&self, first_of_layer: bool, host: &QuantizedTensor, dev: Arc<DeviceWeights>) {
        let mut inner = self.inner.lock().unwrap();
        if first_of_layer {
            inner.layer.clear();
        }
        inner.layer.insert(key(host), dev);
    }

    /// Device buffer registered for this host tensor, if any.
    fn lookup(&self, host: &QuantizedTensor) -> Option<Arc<DeviceWeights>> {
        let inner = self.inner.lock().unwrap();
        let k = key(host);
        inner.layer.get(&k).or_else(|| inner.pinned.get(&k)).cloned()
    }
}

impl Default for DevRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// Device-aware [`LayerProvider`]: streams layer weights through the
/// staging [`Streamer`] at its configured granularity, lends the host
/// copies to [`forward_batch`](crate::engine::forward::forward_batch) and registers every staged matrix's device
/// buffer in the shared [`DevRegistry`] so the paired [`DeviceGqmv`]
/// launches kernels on pre-staged weights — never re-uploading on the
/// decode hot path.
pub struct DeviceLayers<'a> {
    streamer: &'a mut Streamer,
    registry: DevRegistry,
}

impl<'a> DeviceLayers<'a> {
    /// Pair a streamer with the registry shared with a [`DeviceGqmv`].
    pub fn new(streamer: &'a mut Streamer, registry: &DevRegistry) -> Self {
        DeviceLayers { streamer, registry: registry.clone() }
    }

    fn mat(&mut self, li: usize, unit: MatrixUnit) -> Result<&QuantizedTensor> {
        let staged = self.streamer.unit(li, unit)?;
        let pm: &PreparedMatrix = match unit {
            MatrixUnit::Qkv => staged.wqkv(),
            MatrixUnit::Wo => staged.wo(),
            MatrixUnit::W13 => staged.w13(),
            MatrixUnit::W2 => staged.w2(),
            MatrixUnit::Norms => anyhow::bail!("norms are host-side, not a device matrix"),
        };
        // the QKV block is the first matrix of every layer walk: its
        // registration retires the previous walk's buffers
        self.registry.register(unit == MatrixUnit::Qkv, &pm.host, Arc::clone(&pm.dev));
        Ok(&pm.host)
    }
}

impl LayerProvider for DeviceLayers<'_> {
    fn att_norm(&mut self, li: usize) -> Result<&[f32]> {
        Ok(self.streamer.unit(li, MatrixUnit::Norms)?.att_norm())
    }

    fn wqkv(&mut self, li: usize) -> Result<&QuantizedTensor> {
        self.mat(li, MatrixUnit::Qkv)
    }

    fn wo(&mut self, li: usize) -> Result<&QuantizedTensor> {
        self.mat(li, MatrixUnit::Wo)
    }

    fn ffn_norm(&mut self, li: usize) -> Result<&[f32]> {
        Ok(self.streamer.unit(li, MatrixUnit::Norms)?.ffn_norm())
    }

    fn w13(&mut self, li: usize) -> Result<&QuantizedTensor> {
        self.mat(li, MatrixUnit::W13)
    }

    fn w2(&mut self, li: usize) -> Result<&QuantizedTensor> {
        self.mat(li, MatrixUnit::W2)
    }
}

/// GQMV backend that launches device kernels on weights pre-staged by the
/// paired [`DeviceLayers`] provider.  Same-input groups of *split*
/// tensors go through the split-tensor fused launch
/// ([`Runtime::gqmv_device_fused`]): one device dispatch over the
/// group's stacked row space, bit-identical to per-matrix launches.
pub struct DeviceGqmv {
    rt: Arc<Runtime>,
    registry: DevRegistry,
}

impl DeviceGqmv {
    /// Pair a runtime with the registry shared with a [`DeviceLayers`].
    pub fn new(rt: Arc<Runtime>, registry: DevRegistry) -> Self {
        DeviceGqmv { rt, registry }
    }

    fn dev(&self, w: &QuantizedTensor) -> Result<Arc<DeviceWeights>> {
        self.registry.lookup(w).ok_or_else(|| {
            anyhow::anyhow!(
                "no device buffer staged for a {}x{} matrix (provider/executor desync)",
                w.rows,
                w.cols
            )
        })
    }
}

impl GqmvExec for DeviceGqmv {
    fn gqmv(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) -> Result<()> {
        check_shapes(xq, xs, w, out)?;
        let dev = self.dev(w)?;
        self.rt.gqmv_device(&dev, xq, xs, out)
    }

    fn gqmv_fused(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        ws: &[&QuantizedTensor],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        check_shapes_fused(xq, xs, ws, outs)?;
        let devs = ws.iter().map(|w| self.dev(w)).collect::<Result<Vec<_>>>()?;
        let dev_refs: Vec<&DeviceWeights> = devs.iter().map(|d| d.as_ref()).collect();
        self.rt.gqmv_device_fused(&dev_refs, xq, xs, outs)
    }

    fn name(&self) -> &'static str {
        "pjrt-staged"
    }
}

/// The full LlamaF system engine: streamed layer weights + device GQMV,
/// decoding through the unified [`forward_batch`](crate::engine::forward::forward_batch) (one lane).
pub struct LlamafEngine {
    cfg: LlamaConfig,
    /// Resident tensors (embeddings, final norm, classifier) viewed as a
    /// layer-less [`QuantModel`] so the unified pass can serve them; layer
    /// weights never live here — they stream through `streamer`.  The
    /// host classifier copy doubles as the registry key for its pinned
    /// device buffer (the "DDR" copy every real deployment keeps anyway).
    resident: QuantModel,
    registry: DevRegistry,
    exec: DeviceGqmv,
    streamer: Streamer,
    kv: KvCache,
    s: BatchScratch,
    tracer: Option<ExecTrace>,
}

impl LlamafEngine {
    /// Open a quantized checkpoint (any [`crate::quant::FormatId`],
    /// identified by its magic), compile/validate kernels, stage the
    /// first unit, with the default double-buffer staging depth and
    /// layer granularity.
    pub fn open(ckpt_path: &Path, rt: Arc<Runtime>, mode: SchedMode) -> Result<Self> {
        Self::open_with_depth(ckpt_path, rt, mode, crate::sched::DEFAULT_PREFETCH_DEPTH)
    }

    /// [`LlamafEngine::open`] with an explicit staging-pipeline depth
    /// (CLI `--prefetch-depth`): the async schedule keeps up to
    /// `depth - 1` staging units in flight ahead of compute.
    pub fn open_with_depth(
        ckpt_path: &Path,
        rt: Arc<Runtime>,
        mode: SchedMode,
        depth: usize,
    ) -> Result<Self> {
        Self::open_with_opts(ckpt_path, rt, mode, depth, StageGranularity::default())
    }

    /// [`LlamafEngine::open_with_depth`] with an explicit staging
    /// granularity (CLI `--stream-granularity`): `matrix` streams each
    /// layer as five independent chunks so compute overlaps transfers
    /// *within* a layer.
    pub fn open_with_opts(
        ckpt_path: &Path,
        rt: Arc<Runtime>,
        mode: SchedMode,
        depth: usize,
        gran: StageGranularity,
    ) -> Result<Self> {
        Self::open_with_faults(ckpt_path, rt, mode, depth, gran, None)
    }

    /// [`LlamafEngine::open_with_opts`] with a deterministic I/O
    /// fault-injection plan (CLI `--inject-faults`) wrapped around the
    /// disk fetcher.  Injected faults exercise the staging retry path and
    /// the engine's error surface end to end; `None` (or an empty plan)
    /// is a passthrough.
    pub fn open_with_faults(
        ckpt_path: &Path,
        rt: Arc<Runtime>,
        mode: SchedMode,
        depth: usize,
        gran: StageGranularity,
        faults: Option<FaultPlan>,
    ) -> Result<Self> {
        let probe = DiskFetcher::open(ckpt_path)?;
        let cfg = probe.cfg();
        // validate all kernel shapes up front (fail fast before serving)
        for (m, n) in cfg.all_mat_shapes() {
            rt.ensure_shape(m, n)
                .with_context(|| format!("kernel for GQMV {m}x{n}"))?;
        }
        let mut src = ckpt::CkptSource::open(ckpt_path)?;
        let (tok_emb, final_norm, cls) = src.fetch_resident()?;
        let cls_dev = Arc::new(rt.upload(&cls)?);
        let resident = QuantModel { cfg, tok_emb, layers: Vec::new(), final_norm, cls };
        let registry = DevRegistry::new();
        registry.pin(&resident.cls, cls_dev);
        // probe re-used as the streaming fetcher, wrapped in the fault
        // injector when a non-empty plan was supplied
        let streamer = match faults {
            Some(plan) if !plan.is_empty() => Streamer::with_retry(
                Arc::clone(&rt),
                FaultyFetcher::new(probe, plan),
                mode,
                depth,
                gran,
                RetryPolicy::default(),
            )?,
            _ => Streamer::with_opts(Arc::clone(&rt), probe, mode, depth, gran)?,
        };
        Ok(LlamafEngine {
            cfg,
            resident,
            exec: DeviceGqmv::new(rt, registry.clone()),
            registry,
            streamer,
            kv: KvCache::new(&cfg),
            s: BatchScratch::new(&cfg, 1),
            tracer: None,
        })
    }

    /// Build from an in-memory model (tests / synthetic geometry): layers
    /// are "staged" by cloning from memory, still exercising the
    /// upload-per-chunk path.
    pub fn from_model(
        model: crate::model::QuantModel,
        rt: Arc<Runtime>,
        mode: SchedMode,
    ) -> Result<Self> {
        Self::from_model_with_depth(model, rt, mode, crate::sched::DEFAULT_PREFETCH_DEPTH)
    }

    /// [`LlamafEngine::from_model`] with an explicit staging depth.
    pub fn from_model_with_depth(
        model: crate::model::QuantModel,
        rt: Arc<Runtime>,
        mode: SchedMode,
        depth: usize,
    ) -> Result<Self> {
        Self::from_model_with_opts(model, rt, mode, depth, StageGranularity::default())
    }

    /// [`LlamafEngine::from_model_with_depth`] with an explicit staging
    /// granularity.
    pub fn from_model_with_opts(
        mut model: crate::model::QuantModel,
        rt: Arc<Runtime>,
        mode: SchedMode,
        depth: usize,
        gran: StageGranularity,
    ) -> Result<Self> {
        let cfg = model.cfg;
        for (m, n) in cfg.all_mat_shapes() {
            rt.ensure_shape(m, n)?;
        }
        // the layers move into the fetcher ("DDR"); everything else stays
        // resident
        let layers = std::mem::take(&mut model.layers);
        let cls_dev = Arc::new(rt.upload(&model.cls)?);
        let registry = DevRegistry::new();
        registry.pin(&model.cls, cls_dev);
        let fetcher = MemFetcher { layers: Arc::new(layers) };
        let streamer = Streamer::with_opts(Arc::clone(&rt), fetcher, mode, depth, gran)?;
        Ok(LlamafEngine {
            cfg,
            resident: model,
            exec: DeviceGqmv::new(rt, registry.clone()),
            registry,
            streamer,
            kv: KvCache::new(&cfg),
            s: BatchScratch::new(&cfg, 1),
            tracer: None,
        })
    }

    /// Weight-staging schedule this engine runs with.
    pub fn mode(&self) -> SchedMode {
        self.streamer.mode
    }

    /// Staging granularity this engine streams at.
    pub fn granularity(&self) -> StageGranularity {
        self.streamer.granularity()
    }

    /// Total/blocked staging seconds so far (Fig. 2 accounting).
    pub fn transfer_stats(&self) -> (f64, f64, u64) {
        (
            self.streamer.stats.total_transfer_s,
            self.streamer.stats.blocked_transfer_s,
            self.streamer.stats.transfers,
        )
    }

    /// Full staging counters, including ring occupancy, the per-depth
    /// prefetch-wait buckets and the per-matrix wait attribution of the
    /// staging ring.
    pub fn streamer_stats(&self) -> StreamerStats {
        self.streamer.stats
    }
}

impl Engine for LlamafEngine {
    fn cfg(&self) -> &LlamaConfig {
        &self.cfg
    }

    fn forward(&mut self, token: u32, pos: usize, prof: &mut ForwardProfile) -> Result<&[f32]> {
        // One lane through the unified Algorithm-2 pass: DeviceLayers
        // streams + registers weights, DeviceGqmv launches the kernels on
        // the staged buffers.  There is no device-private op sequence.
        let mut provider = DeviceLayers::new(&mut self.streamer, &self.registry);
        let lanes = [BatchLane { kv: 0, pos, token }];
        let mut kvs: [&mut dyn KvStore; 1] = [&mut self.kv];
        forward_batch_traced(
            &self.resident,
            &mut provider,
            &mut self.exec,
            &mut self.s,
            &lanes,
            &mut kvs,
            prof,
            self.tracer.as_mut().map(|t| t as &mut dyn TraceSink),
        )?;
        Ok(self.s.logits(0))
    }

    fn reset(&mut self) {
        self.kv.reset();
        // Re-arm the weight prefetch for the next generation's first unit;
        // without this, a reset that lands mid-token leaves a stale pending
        // staging and the first layers pay blocked (sync-style) transfers.
        self.streamer.reset();
    }

    fn name(&self) -> String {
        format!(
            "llamaf/pjrt-{}",
            match self.streamer.mode {
                SchedMode::Sync => "sync",
                SchedMode::Async => "async",
            }
        )
    }

    fn trace_start(&mut self, label: &str) -> bool {
        self.tracer = Some(ExecTrace::new(&self.cfg, label));
        true
    }

    fn trace_take(&mut self) -> Option<ExecTrace> {
        self.tracer.take()
    }
}

// Offline (sim-runtime) coverage lives in rust/tests/forward_unification.rs
// (device path == CPU path bitwise, at every granularity × depth);
// artifact-backed integration tests live in rust/tests/engine_e2e.rs.
