//! The LlamaF engine: Algorithm 2 with streamed weights and GQMV executed
//! by the AOT-compiled Pallas kernel via PJRT (the functional PL).
//!
//! Control flow (RMSNorm, RoPE, attention, SwiGLU, sampling) stays on the
//! "PS" (this thread); weight staging follows the configured
//! [`SchedMode`] and ring depth ([`Streamer::with_depth`], CLI
//! `--prefetch-depth`); kernels consume device-resident weight buffers.
//!
//! The device path is already dispatch-minimal — four kernel launches per
//! layer, because Wq‖Wk‖Wv and W1‖W3 ship as storage-fused buffers.  That
//! is the device twin of the CPU backends' dispatch-time fusion
//! ([`crate::ps::gqmv::GqmvExec::gqmv_fused`]); both are bit-identical to
//! seven per-matrix launches by row independence.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::ckpt;
use crate::engine::forward::{Engine, Scratch};
use crate::metrics::ForwardProfile;
use crate::model::{KvCache, LlamaConfig};
use crate::ps::float::attention;
use crate::quant::{quantize_activation_into, QuantizedTensor};
use crate::runtime::{DeviceWeights, Runtime};
use crate::sched::{DiskFetcher, MemFetcher, SchedMode, Streamer};
use crate::tensor;

/// Weights that stay resident (paper: embeddings live host-side; we keep
/// the classifier device-resident since it is reused every token).
struct Resident {
    tok_emb: QuantizedTensor,
    final_norm: Vec<f32>,
    cls_dev: DeviceWeights,
    cls_rows: usize,
}

/// The full LlamaF system engine.
pub struct LlamafEngine {
    cfg: LlamaConfig,
    rt: Arc<Runtime>,
    resident: Resident,
    streamer: Streamer,
    kv: KvCache,
    s: Scratch,
    /// blocked transfer time snapshot for per-token accounting
    last_blocked_s: f64,
}

impl LlamafEngine {
    /// Open an LFQ8 checkpoint, compile/validate kernels, stage layer 0,
    /// with the default double-buffer staging depth.
    pub fn open(ckpt_path: &Path, rt: Arc<Runtime>, mode: SchedMode) -> Result<Self> {
        Self::open_with_depth(ckpt_path, rt, mode, crate::sched::DEFAULT_PREFETCH_DEPTH)
    }

    /// [`LlamafEngine::open`] with an explicit staging-pipeline depth
    /// (CLI `--prefetch-depth`): the async schedule keeps up to
    /// `depth - 1` layer transfers in flight ahead of compute.
    pub fn open_with_depth(
        ckpt_path: &Path,
        rt: Arc<Runtime>,
        mode: SchedMode,
        depth: usize,
    ) -> Result<Self> {
        let mut probe = DiskFetcher::open(ckpt_path)?;
        let cfg = probe.cfg();
        // validate all kernel shapes up front (fail fast before serving)
        for (m, n) in cfg.all_mat_shapes() {
            rt.ensure_shape(m, n)
                .with_context(|| format!("kernel for GQMV {m}x{n}"))?;
        }
        let mut src = ckpt::Q8LayerSource::open(ckpt_path)?;
        let (tok_emb, final_norm, cls) = src.fetch_resident()?;
        let cls_dev = rt.upload(&cls)?;
        let resident = Resident { tok_emb, final_norm, cls_dev, cls_rows: cls.rows };
        // probe re-used as the streaming fetcher
        let _ = &mut probe;
        let streamer = Streamer::with_depth(Arc::clone(&rt), probe, mode, depth)?;
        Ok(LlamafEngine {
            cfg,
            rt,
            resident,
            streamer,
            kv: KvCache::new(&cfg),
            s: Scratch::new(&cfg),
            last_blocked_s: 0.0,
        })
    }

    /// Build from an in-memory model (tests / synthetic geometry): layers
    /// are "staged" by cloning from memory, still exercising the
    /// upload-per-layer path.
    pub fn from_model(
        model: crate::model::QuantModel,
        rt: Arc<Runtime>,
        mode: SchedMode,
    ) -> Result<Self> {
        Self::from_model_with_depth(model, rt, mode, crate::sched::DEFAULT_PREFETCH_DEPTH)
    }

    /// [`LlamafEngine::from_model`] with an explicit staging depth.
    pub fn from_model_with_depth(
        model: crate::model::QuantModel,
        rt: Arc<Runtime>,
        mode: SchedMode,
        depth: usize,
    ) -> Result<Self> {
        let cfg = model.cfg;
        for (m, n) in cfg.all_mat_shapes() {
            rt.ensure_shape(m, n)?;
        }
        let cls_dev = rt.upload(&model.cls)?;
        let resident = Resident {
            tok_emb: model.tok_emb,
            final_norm: model.final_norm,
            cls_dev,
            cls_rows: model.cls.rows,
        };
        let fetcher = MemFetcher { layers: Arc::new(model.layers) };
        let streamer = Streamer::with_depth(Arc::clone(&rt), fetcher, mode, depth)?;
        Ok(LlamafEngine {
            cfg,
            rt,
            resident,
            streamer,
            kv: KvCache::new(&cfg),
            s: Scratch::new(&cfg),
            last_blocked_s: 0.0,
        })
    }

    /// Weight-staging schedule this engine runs with.
    pub fn mode(&self) -> SchedMode {
        self.streamer.mode
    }

    /// Total/blocked staging seconds so far (Fig. 2 accounting).
    pub fn transfer_stats(&self) -> (f64, f64, u64) {
        (
            self.streamer.stats.total_transfer_s,
            self.streamer.stats.blocked_transfer_s,
            self.streamer.stats.transfers,
        )
    }

    /// Full staging counters, including ring occupancy and the per-depth
    /// prefetch-wait buckets of the staging ring.
    pub fn streamer_stats(&self) -> crate::sched::StreamerStats {
        self.streamer.stats
    }

    fn quant_gqmv_dev(
        rt: &Runtime,
        dw: &DeviceWeights,
        x: &[f32],
        out: &mut [f32],
        qbuf: &mut [i8],
        sbuf: &mut [f32],
        gs: usize,
        prof: &mut ForwardProfile,
    ) -> Result<()> {
        let t = Instant::now();
        let n = x.len();
        quantize_activation_into(x, gs, &mut qbuf[..n], &mut sbuf[..n / gs]);
        rt.gqmv_device(dw, &qbuf[..n], &sbuf[..n / gs], out)?;
        prof.matrix_s += t.elapsed().as_secs_f64();
        Ok(())
    }
}

impl Engine for LlamafEngine {
    fn cfg(&self) -> &LlamaConfig {
        &self.cfg
    }

    fn forward(&mut self, token: u32, pos: usize, prof: &mut ForwardProfile) -> Result<&[f32]> {
        let cfg = self.cfg;
        let (d, kv_d, hd, gs) = (cfg.dim, cfg.kv_dim(), cfg.head_dim(), cfg.gs);
        anyhow::ensure!((token as usize) < cfg.vocab_size, "token {token} out of range");
        anyhow::ensure!(pos < cfg.seq_len, "pos {pos} >= seq_len {}", cfg.seq_len);

        let t0 = Instant::now();
        self.resident.tok_emb.dequantize_row(token as usize, &mut self.s.x);
        prof.other_s += t0.elapsed().as_secs_f64();

        for li in 0..cfg.n_layers {
            // stage (or receive prefetched) layer weights
            let blocked_before = self.streamer.stats.blocked_transfer_s;
            let layer = self.streamer.layer(li)?;
            // (borrow of streamer ends when layer refs are copied below)
            let att_norm = layer.host.att_norm.clone();
            let ffn_norm = layer.host.ffn_norm.clone();
            // SAFETY-free re-borrow dance: DeviceWeights are behind the
            // streamer's current slot; clone the Arc-less handles by
            // splitting the call sequence instead.
            let t = Instant::now();
            tensor::rmsnorm(&mut self.s.xb, &self.s.x, &att_norm);
            prof.rmsnorm_s += t.elapsed().as_secs_f64();

            let layer = self.streamer.layer(li)?; // re-borrow (no-op)
            Self::quant_gqmv_dev(
                &self.rt, &layer.wqkv, &self.s.xb, &mut self.s.qkv,
                &mut self.s.qbuf, &mut self.s.sbuf, gs, prof,
            )?;

            let t = Instant::now();
            let (q, kvs) = self.s.qkv.split_at_mut(d);
            let (k, v) = kvs.split_at_mut(kv_d);
            tensor::rope(q, pos, hd);
            tensor::rope(k, pos, hd);
            prof.rope_s += t.elapsed().as_secs_f64();
            self.kv.store(li, pos, k, v);

            let t = Instant::now();
            attention(&cfg, &self.kv, li, pos, q, &mut self.s.att_out);
            prof.attention_s += t.elapsed().as_secs_f64();

            let layer = self.streamer.layer(li)?;
            Self::quant_gqmv_dev(
                &self.rt, &layer.wo, &self.s.att_out, &mut self.s.xb,
                &mut self.s.qbuf, &mut self.s.sbuf, gs, prof,
            )?;
            let t = Instant::now();
            tensor::add_assign(&mut self.s.x, &self.s.xb);
            tensor::rmsnorm(&mut self.s.xb, &self.s.x, &ffn_norm);
            prof.rmsnorm_s += t.elapsed().as_secs_f64();

            let layer = self.streamer.layer(li)?;
            Self::quant_gqmv_dev(
                &self.rt, &layer.w13, &self.s.xb, &mut self.s.h13,
                &mut self.s.qbuf, &mut self.s.sbuf, gs, prof,
            )?;
            let t = Instant::now();
            let (h1, h3) = self.s.h13.split_at_mut(cfg.hidden_dim);
            tensor::swiglu(h1, h3);
            prof.swiglu_s += t.elapsed().as_secs_f64();

            let layer = self.streamer.layer(li)?;
            let h1 = &self.s.h13[..cfg.hidden_dim];
            Self::quant_gqmv_dev(
                &self.rt, &layer.w2, h1, &mut self.s.xb,
                &mut self.s.qbuf, &mut self.s.sbuf, gs, prof,
            )?;
            let t = Instant::now();
            tensor::add_assign(&mut self.s.x, &self.s.xb);
            prof.other_s += t.elapsed().as_secs_f64();

            prof.transfer_s += self.streamer.stats.blocked_transfer_s - blocked_before;
        }

        let t = Instant::now();
        tensor::rmsnorm(&mut self.s.xb, &self.s.x, &self.resident.final_norm);
        prof.rmsnorm_s += t.elapsed().as_secs_f64();
        anyhow::ensure!(self.s.logits.len() == self.resident.cls_rows);
        Self::quant_gqmv_dev(
            &self.rt, &self.resident.cls_dev, &self.s.xb, &mut self.s.logits,
            &mut self.s.qbuf, &mut self.s.sbuf, gs, prof,
        )?;
        self.last_blocked_s = self.streamer.stats.blocked_transfer_s;
        Ok(&self.s.logits)
    }

    fn reset(&mut self) {
        self.kv.reset();
        // Re-arm the weight prefetch for the next generation's first layer;
        // without this, a reset that lands mid-token leaves a stale pending
        // staging and the first layers pay blocked (sync-style) transfers.
        self.streamer.reset();
    }

    fn name(&self) -> String {
        format!(
            "llamaf/pjrt-{}",
            match self.streamer.mode {
                SchedMode::Sync => "sync",
                SchedMode::Async => "async",
            }
        )
    }
}

// Integration tests live in rust/tests/ (require artifacts + PJRT).
