//! Algorithm 2 on resident weights with a pluggable CPU GQMV backend.
//!
//! The quantized weights are immutable and shared: `CpuEngine` holds its
//! [`QuantModel`] behind an `Arc`, so N engines (one per serving worker)
//! reference one copy — the scarce resource on an embedded board is weight
//! memory, not compute.  Mutable decode state lives in a
//! [`Session`](crate::engine::session::Session) (KV cache + position); the
//! engine keeps a private one for the classic batch-1 [`Engine`] API and
//! can also drive external sessions via [`CpuEngine::forward_session`].

use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

use crate::engine::session::Session;
use crate::metrics::ForwardProfile;
use crate::model::{KvStore, LlamaConfig, QuantModel};
use crate::ps::float::attention;
use crate::ps::gqmv::GqmvExec;
use crate::quant::{quantize_activation_into, QuantizedTensor};
use crate::tensor;
use crate::trace::{ExecTrace, TraceOp, TraceSink};

/// A single-token incremental inference engine (batch = 1).
pub trait Engine {
    /// Model geometry this engine serves.
    fn cfg(&self) -> &LlamaConfig;
    /// Decode one token at `pos`, returning logits.  Component timings are
    /// accumulated into `prof` (Table II / VI accounting).
    fn forward(&mut self, token: u32, pos: usize, prof: &mut ForwardProfile) -> Result<&[f32]>;
    /// Rewind to an empty context (new generation).
    fn reset(&mut self);
    /// Human-readable engine/backend identifier.
    fn name(&self) -> String;
    /// Start recording an execution trace (per-matrix activation digests)
    /// labeled `label`; any previous recording is discarded.  Returns
    /// `false` if this engine cannot trace (the default).
    fn trace_start(&mut self, label: &str) -> bool {
        let _ = label;
        false
    }
    /// Detach and return the trace recorded since [`Engine::trace_start`],
    /// stopping recording.  `None` if tracing was never started or is
    /// unsupported.
    fn trace_take(&mut self) -> Option<ExecTrace> {
        None
    }
}

/// One full Algorithm-2 forward pass for a single (token, pos, KV) lane:
/// shared weights in, per-session KV in/out, logits left in
/// `s.logits(0)`.
///
/// Since the forward-path unification this is a thin adapter: it drives
/// [`forward_batch`] with exactly **one lane** over the resident model
/// layers, so the batch-1 and batched paths share a single copy of the
/// Algorithm-2 arithmetic.  Outputs are bit-identical to the historical
/// dedicated batch-1 op sequence, pinned by
/// `rust/tests/forward_unification.rs` against an op-for-op reference of
/// the pre-unification pass.
#[allow(clippy::too_many_arguments)]
fn forward_pass(
    model: &QuantModel,
    exec: &mut dyn GqmvExec,
    s: &mut BatchScratch,
    kv: &mut dyn KvStore,
    token: u32,
    pos: usize,
    prof: &mut ForwardProfile,
    tracer: Option<&mut dyn TraceSink>,
) -> Result<()> {
    let mut layers = ModelLayers { model };
    let lanes = [BatchLane { kv: 0, pos, token }];
    let mut kvs: [&mut dyn KvStore; 1] = [kv];
    forward_batch_traced(model, &mut layers, exec, s, &lanes, &mut kvs, prof, tracer)
}

// ---------------------------------------------------------------------------
// Step-synchronous batched forward pass
// ---------------------------------------------------------------------------

/// Supplies each transformer layer's weights to [`forward_batch`] at
/// **matrix granularity**: the pass asks for each piece right before its
/// first use (att-norm → Wqkv → Wo → ffn-norm → W1‖W3 → W2), so a
/// sub-layer streaming provider can lend matrix *k* while matrix *k+1* is
/// still in flight.
///
/// Implementations: [`ResidentLayers`] / [`ModelLayers`] hand out fields
/// of the already-loaded layer (zero staging, every accessor instant),
/// [`crate::sched::Streamer`] consumes its staging ring — whole layers or
/// per-matrix chunks depending on `--stream-granularity` — and
/// `engine::llamaf::DeviceLayers` additionally registers each staged
/// matrix's device buffer for the paired device executor.
pub trait LayerProvider {
    /// Attention RMSNorm vector of layer `li` (staged first if necessary).
    fn att_norm(&mut self, li: usize) -> Result<&[f32]>;
    /// Fused Wq‖Wk‖Wv of layer `li`.
    fn wqkv(&mut self, li: usize) -> Result<&QuantizedTensor>;
    /// Wo of layer `li`.
    fn wo(&mut self, li: usize) -> Result<&QuantizedTensor>;
    /// FFN RMSNorm vector of layer `li`.
    fn ffn_norm(&mut self, li: usize) -> Result<&[f32]>;
    /// Fused W1‖W3 of layer `li`.
    fn w13(&mut self, li: usize) -> Result<&QuantizedTensor>;
    /// W2 of layer `li`.
    fn w2(&mut self, li: usize) -> Result<&QuantizedTensor>;
}

/// Resident-weight [`LayerProvider`]: layers come straight out of the
/// shared quantized model, nothing is staged.
pub struct ResidentLayers {
    /// The shared quantized model whose layers are lent out.
    pub model: Arc<QuantModel>,
}

impl ResidentLayers {
    fn layer(&self, li: usize) -> Result<&crate::model::QuantLayer> {
        self.model
            .layers
            .get(li)
            .ok_or_else(|| anyhow::anyhow!("layer {li} out of range"))
    }
}

/// Borrowed resident-weight [`LayerProvider`]: like [`ResidentLayers`]
/// but over a plain `&QuantModel`, so the unified batch-1 path
/// ([`CpuEngine`]) can lend its own model without an `Arc` round-trip.
pub struct ModelLayers<'a> {
    /// The borrowed quantized model whose layers are lent out.
    pub model: &'a QuantModel,
}

impl ModelLayers<'_> {
    fn layer(&self, li: usize) -> Result<&crate::model::QuantLayer> {
        self.model
            .layers
            .get(li)
            .ok_or_else(|| anyhow::anyhow!("layer {li} out of range"))
    }
}

/// Forward the six [`LayerProvider`] accessors to an inherent
/// `layer(li) -> Result<&QuantLayer>` lookup — the resident providers
/// differ only in how they hold the model, so one forwarding body serves
/// both (and any future accessor is added in exactly one place).
macro_rules! provide_from_resident_layer {
    ($ty:ty) => {
        impl LayerProvider for $ty {
            fn att_norm(&mut self, li: usize) -> Result<&[f32]> {
                Ok(&self.layer(li)?.att_norm)
            }

            fn wqkv(&mut self, li: usize) -> Result<&QuantizedTensor> {
                Ok(&self.layer(li)?.wqkv)
            }

            fn wo(&mut self, li: usize) -> Result<&QuantizedTensor> {
                Ok(&self.layer(li)?.wo)
            }

            fn ffn_norm(&mut self, li: usize) -> Result<&[f32]> {
                Ok(&self.layer(li)?.ffn_norm)
            }

            fn w13(&mut self, li: usize) -> Result<&QuantizedTensor> {
                Ok(&self.layer(li)?.w13)
            }

            fn w2(&mut self, li: usize) -> Result<&QuantizedTensor> {
                Ok(&self.layer(li)?.w2)
            }
        }
    };
}

provide_from_resident_layer!(ResidentLayers);
provide_from_resident_layer!(ModelLayers<'_>);

/// One decoding lane of a batched step: the index of the KV cache it
/// writes (into the `kvs` slice passed alongside) plus the token to feed
/// at its position.  Distinct sessions use distinct `kv` indices and are
/// fully independent — only the weight traversal is shared.  **Chunked
/// prefill** maps several lanes onto *one* `kv` index: such lanes must be
/// adjacent with consecutive ascending positions, and the pass is then
/// bit-identical to feeding those tokens one step at a time (each lane's
/// attention at position *p* sees exactly positions `0..=p`, the earlier
/// ones stored this very step by its left-hand siblings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchLane {
    /// Index of this lane's KV cache in the step's `kvs` slice.
    pub kv: usize,
    /// Decode position of `token` (the lane's session position).
    pub pos: usize,
    /// Token fed to the embedding lookup this step.
    pub token: u32,
}

/// Pre-allocated working buffers for up to `max_batch` lanes — nothing
/// allocates on the hot path.  Per-GQMV inputs/outputs are packed
/// contiguously (`nb × len`) so one [`GqmvExec::gqmv_batch`] call serves
/// the whole step.  Every engine uses this (the batch-1 paths at 1 lane)
/// since the forward-path unification.
pub struct BatchScratch {
    /// Maximum number of lanes a single step may carry.
    pub max_batch: usize,
    dim: usize,
    qkv_w: usize,
    h2: usize,
    vocab: usize,
    x: Vec<f32>,
    xb: Vec<f32>,
    qkv: Vec<f32>,
    att_out: Vec<f32>,
    h13: Vec<f32>,
    logits: Vec<f32>,
    qbuf: Vec<i8>,
    sbuf: Vec<f32>,
}

impl BatchScratch {
    /// Allocate buffers for `max_batch` lanes of `cfg`-shaped decoding.
    pub fn new(cfg: &LlamaConfig, max_batch: usize) -> Self {
        assert!(max_batch >= 1);
        let b = max_batch;
        let max_in = cfg.dim.max(cfg.hidden_dim);
        BatchScratch {
            max_batch,
            dim: cfg.dim,
            qkv_w: cfg.dim + 2 * cfg.kv_dim(),
            h2: 2 * cfg.hidden_dim,
            vocab: cfg.vocab_size,
            x: vec![0.0; b * cfg.dim],
            xb: vec![0.0; b * cfg.dim],
            qkv: vec![0.0; b * (cfg.dim + 2 * cfg.kv_dim())],
            att_out: vec![0.0; b * cfg.dim],
            h13: vec![0.0; b * 2 * cfg.hidden_dim],
            logits: vec![0.0; b * cfg.vocab_size],
            qbuf: vec![0; b * max_in],
            sbuf: vec![0.0; b * (max_in / cfg.gs)],
        }
    }

    /// Logits of lane `b` after a [`forward_batch`] call.
    pub fn logits(&self, b: usize) -> &[f32] {
        &self.logits[b * self.vocab..(b + 1) * self.vocab]
    }
}

/// Quantize `nb` lane vectors (lane `b` at `x[b*x_stride .. +cols]`) ONCE
/// and run one fused-group GQMV dispatch: every matrix in `ws` consumes
/// the same quantized activation.  Quantize + matmul are billed to
/// `matrix_s`.
///
/// This is the dispatch-level half of the paper's §III-B fusion: the
/// QKV and W1|W3 groups of Algorithm 2 cost one activation quantization
/// and one backend dispatch each, whether the group arrives as one
/// row-concatenated tensor (how [`crate::model::QuantLayer`] stores it —
/// the singleton fast path below) or as separate per-matrix tensors
/// (the [`GqmvExec::gqmv_fused`] path, bit-identical by construction).
#[allow(clippy::too_many_arguments)]
fn quant_gqmv_fused_batch(
    exec: &mut dyn GqmvExec,
    x: &[f32],
    x_stride: usize,
    ws: &[&crate::quant::QuantizedTensor],
    outs: &mut [&mut [f32]],
    qbuf: &mut [i8],
    sbuf: &mut [f32],
    gs: usize,
    nb: usize,
    prof: &mut ForwardProfile,
) -> Result<()> {
    let t = Instant::now();
    anyhow::ensure!(!ws.is_empty() && ws.len() == outs.len(), "malformed fused group");
    let n = ws[0].cols;
    let gpr = n / gs;
    for b in 0..nb {
        quantize_activation_into(
            &x[b * x_stride..b * x_stride + n],
            gs,
            &mut qbuf[b * n..(b + 1) * n],
            &mut sbuf[b * gpr..(b + 1) * gpr],
        );
    }
    let (xq, xs) = (&qbuf[..nb * n], &sbuf[..nb * gpr]);
    if ws.len() == 1 {
        // singleton group: the storage-fused tensor already makes the
        // batched kernel a single dispatch
        exec.gqmv_batch(xq, xs, ws[0], &mut outs[0][..nb * ws[0].rows], nb)?;
    } else {
        let mut trimmed: Vec<&mut [f32]> = ws
            .iter()
            .zip(outs.iter_mut())
            .map(|(w, out)| &mut out[..nb * w.rows])
            .collect();
        if nb == 1 {
            exec.gqmv_fused(xq, xs, ws, &mut trimmed)?;
        } else {
            exec.gqmv_fused_batch(xq, xs, ws, &mut trimmed, nb)?;
        }
    }
    prof.matrix_s += t.elapsed().as_secs_f64();
    Ok(())
}

/// One step-synchronous batched forward pass: a single walk over the
/// layers serves every lane before moving on, so a streamed
/// [`LayerProvider`] stages each layer's weights exactly once per step
/// instead of once per lane.  Each weight piece is requested right before
/// its first use, so a matrix-granular provider overlaps the transfer of
/// a layer's tail matrices with compute on its head matrices.
///
/// Per-lane arithmetic is the exact batch-1 sequence of `forward_pass`
/// operations (same RMSNorm/RoPE/attention/SwiGLU calls, same
/// quantization, same [`crate::ps::gqmv::gqmv_row`] cast chain), so each
/// lane's logits — left in `s.logits(b)` — are **bit-identical** to a
/// dedicated batch-1 forward of that lane's (token, pos, KV) state.
/// Lane sessions' positions are *not* advanced; the caller does that
/// after consuming the logits.
///
/// `kvs` carries one mutable KV-store handle per distinct session in the
/// step; `lanes[i].kv` indexes into it (see [`BatchLane`] for the
/// shared-index chunked-prefill contract).
pub fn forward_batch(
    model: &QuantModel,
    layers: &mut dyn LayerProvider,
    exec: &mut dyn GqmvExec,
    s: &mut BatchScratch,
    lanes: &[BatchLane],
    kvs: &mut [&mut dyn KvStore],
    prof: &mut ForwardProfile,
) -> Result<()> {
    forward_batch_traced(model, layers, exec, s, lanes, kvs, prof, None)
}

/// [`forward_batch`] with optional digest tracing: when `tracer` is `Some`,
/// every GQMV output of the step is hashed with
/// [`digest64`](crate::trace::digest64) into the trace, per lane —
/// Wq‖Wk‖Wv pre-RoPE, Wo and W2 pre-residual, W1‖W3 pre-SwiGLU, and the
/// classifier logits (at layer index `n_layers`).  With `tracer == None`
/// the cost is one skipped branch per matrix group: no hashing, no
/// allocation (`benches/trace_overhead.rs` measures exactly this).
#[allow(clippy::too_many_arguments)]
pub fn forward_batch_traced(
    model: &QuantModel,
    layers: &mut dyn LayerProvider,
    exec: &mut dyn GqmvExec,
    s: &mut BatchScratch,
    lanes: &[BatchLane],
    kvs: &mut [&mut dyn KvStore],
    prof: &mut ForwardProfile,
    mut tracer: Option<&mut dyn TraceSink>,
) -> Result<()> {
    let cfg = model.cfg;
    let nb = lanes.len();
    anyhow::ensure!(nb >= 1, "empty batch");
    anyhow::ensure!(nb <= s.max_batch, "batch {nb} exceeds scratch capacity {}", s.max_batch);
    let (d, kv_d, hd, gs) = (cfg.dim, cfg.kv_dim(), cfg.head_dim(), cfg.gs);
    let (qkv_w, h2) = (s.qkv_w, s.h2);
    debug_assert_eq!(d, s.dim);
    let mut last_pos: Vec<Option<usize>> = vec![None; kvs.len()];
    for lane in lanes.iter() {
        anyhow::ensure!(
            (lane.token as usize) < cfg.vocab_size,
            "token {} out of range",
            lane.token
        );
        anyhow::ensure!(lane.pos < cfg.seq_len, "pos {} >= seq_len {}", lane.pos, cfg.seq_len);
        anyhow::ensure!(lane.kv < kvs.len(), "lane kv index {} out of range", lane.kv);
        // chunked-prefill contract: lanes sharing one KV cache feed
        // consecutive positions, left to right — anything else would make
        // this step's store/attention order diverge from one-at-a-time
        if let Some(prev) = last_pos[lane.kv].replace(lane.pos) {
            anyhow::ensure!(
                lane.pos == prev + 1,
                "lanes sharing kv {} must advance consecutive positions (got {} after {})",
                lane.kv,
                lane.pos,
                prev
            );
        }
    }

    if let Some(t) = tracer.as_mut() {
        t.begin_step();
    }

    let t0 = Instant::now();
    for (b, lane) in lanes.iter().enumerate() {
        model.tok_emb.dequantize_row(lane.token as usize, &mut s.x[b * d..(b + 1) * d]);
    }
    prof.other_s += t0.elapsed().as_secs_f64();

    for li in 0..cfg.n_layers {
        // Each piece below is staged (or received prefetched) ONCE for
        // all lanes, right before its first use; waits are billed as
        // transfer time (~0 for resident providers; the visible remainder
        // of the staging for streamed ones).

        // RMSNorm + quantize + fused QKV GQMV (Alg. 2 l.3-4, batched)
        let t = Instant::now();
        let att_norm = layers.att_norm(li)?;
        prof.transfer_s += t.elapsed().as_secs_f64();
        let t = Instant::now();
        for b in 0..nb {
            tensor::rmsnorm(&mut s.xb[b * d..(b + 1) * d], &s.x[b * d..(b + 1) * d], att_norm);
        }
        prof.rmsnorm_s += t.elapsed().as_secs_f64();
        // fused QKV group: Wq|Wk|Wv is one storage-fused tensor, so the
        // whole group is one quantization + one dispatch
        let t = Instant::now();
        let wqkv = layers.wqkv(li)?;
        prof.transfer_s += t.elapsed().as_secs_f64();
        quant_gqmv_fused_batch(
            exec,
            &s.xb,
            d,
            &[wqkv],
            &mut [&mut s.qkv[..]],
            &mut s.qbuf,
            &mut s.sbuf,
            gs,
            nb,
            prof,
        )?;
        if let Some(t) = tracer.as_mut() {
            for b in 0..nb {
                t.record(li, TraceOp::Qkv, b, &s.qkv[b * qkv_w..(b + 1) * qkv_w]);
            }
        }

        // RoPE + KV store (l.5), per lane at its own position.  Stores run
        // in lane order, so chunked-prefill siblings have already written
        // their (earlier) positions by the time attention below reads them.
        let t = Instant::now();
        for (b, lane) in lanes.iter().enumerate() {
            let qkv = &mut s.qkv[b * qkv_w..(b + 1) * qkv_w];
            let (q, rest) = qkv.split_at_mut(d);
            let (k, v) = rest.split_at_mut(kv_d);
            tensor::rope(q, lane.pos, hd);
            tensor::rope(k, lane.pos, hd);
            kvs[lane.kv].store(li, lane.pos, k, v);
        }
        prof.rope_s += t.elapsed().as_secs_f64();

        // multi-head attention on the PS (l.6-7), per lane on its KV
        let t = Instant::now();
        for (b, lane) in lanes.iter().enumerate() {
            let q = &s.qkv[b * qkv_w..b * qkv_w + d];
            attention(&cfg, &*kvs[lane.kv], li, lane.pos, q, &mut s.att_out[b * d..(b + 1) * d]);
        }
        prof.attention_s += t.elapsed().as_secs_f64();

        // quantize + Wo GQMV + residual (l.8-10)
        let t = Instant::now();
        let wo = layers.wo(li)?;
        prof.transfer_s += t.elapsed().as_secs_f64();
        quant_gqmv_fused_batch(
            exec,
            &s.att_out,
            d,
            &[wo],
            &mut [&mut s.xb[..]],
            &mut s.qbuf,
            &mut s.sbuf,
            gs,
            nb,
            prof,
        )?;
        if let Some(t) = tracer.as_mut() {
            for b in 0..nb {
                t.record(li, TraceOp::Wo, b, &s.xb[b * d..(b + 1) * d]);
            }
        }
        let t = Instant::now();
        for b in 0..nb {
            tensor::add_assign(&mut s.x[b * d..(b + 1) * d], &s.xb[b * d..(b + 1) * d]);
        }
        prof.other_s += t.elapsed().as_secs_f64();

        // FFN: RMSNorm + fused W1|W3 + SwiGLU + W2 + residual (l.11-15)
        let t = Instant::now();
        let ffn_norm = layers.ffn_norm(li)?;
        prof.transfer_s += t.elapsed().as_secs_f64();
        let t = Instant::now();
        for b in 0..nb {
            tensor::rmsnorm(&mut s.xb[b * d..(b + 1) * d], &s.x[b * d..(b + 1) * d], ffn_norm);
        }
        prof.rmsnorm_s += t.elapsed().as_secs_f64();
        // fused FFN-in group: W1|W3 is one storage-fused tensor (one
        // quantization + one dispatch for both projections)
        let t = Instant::now();
        let w13 = layers.w13(li)?;
        prof.transfer_s += t.elapsed().as_secs_f64();
        quant_gqmv_fused_batch(
            exec,
            &s.xb,
            d,
            &[w13],
            &mut [&mut s.h13[..]],
            &mut s.qbuf,
            &mut s.sbuf,
            gs,
            nb,
            prof,
        )?;
        if let Some(t) = tracer.as_mut() {
            for b in 0..nb {
                t.record(li, TraceOp::W13, b, &s.h13[b * h2..(b + 1) * h2]);
            }
        }
        let t = Instant::now();
        for b in 0..nb {
            let lane_h = &mut s.h13[b * h2..(b + 1) * h2];
            let (h1, h3) = lane_h.split_at_mut(cfg.hidden_dim);
            tensor::swiglu(h1, h3);
        }
        prof.swiglu_s += t.elapsed().as_secs_f64();
        let t = Instant::now();
        let w2 = layers.w2(li)?;
        prof.transfer_s += t.elapsed().as_secs_f64();
        quant_gqmv_fused_batch(
            exec,
            &s.h13,
            h2,
            &[w2],
            &mut [&mut s.xb[..]],
            &mut s.qbuf,
            &mut s.sbuf,
            gs,
            nb,
            prof,
        )?;
        if let Some(t) = tracer.as_mut() {
            for b in 0..nb {
                t.record(li, TraceOp::W2, b, &s.xb[b * d..(b + 1) * d]);
            }
        }
        let t = Instant::now();
        for b in 0..nb {
            tensor::add_assign(&mut s.x[b * d..(b + 1) * d], &s.xb[b * d..(b + 1) * d]);
        }
        prof.other_s += t.elapsed().as_secs_f64();
    }

    // final RMSNorm + classifier (l.16-17)
    let t = Instant::now();
    for b in 0..nb {
        tensor::rmsnorm(&mut s.xb[b * d..(b + 1) * d], &s.x[b * d..(b + 1) * d], &model.final_norm);
    }
    prof.rmsnorm_s += t.elapsed().as_secs_f64();
    quant_gqmv_fused_batch(
        exec,
        &s.xb,
        d,
        &[&model.cls],
        &mut [&mut s.logits[..]],
        &mut s.qbuf,
        &mut s.sbuf,
        gs,
        nb,
        prof,
    )?;
    if let Some(t) = tracer.as_mut() {
        for b in 0..nb {
            t.record(cfg.n_layers, TraceOp::Cls, b, s.logits(b));
        }
    }
    Ok(())
}

/// Resident-weight engine with a CPU GQMV backend.  Weights are shared
/// (`Arc`); scratch and the default session are private per engine.
///
/// Decoding runs through the unified forward path: every call is a 1-lane
/// [`forward_batch`], so this engine and the batch scheduler execute the
/// same arithmetic.
pub struct CpuEngine {
    /// Shared (read-only) quantized weights.
    pub model: Arc<QuantModel>,
    /// GQMV backend executing Algorithm 1.
    pub exec: Box<dyn GqmvExec>,
    session: Session,
    s: BatchScratch,
    tracer: Option<ExecTrace>,
}

impl CpuEngine {
    /// Accepts an owned `QuantModel` (wrapped into a fresh `Arc`) or an
    /// `Arc<QuantModel>` already shared with other engines.
    pub fn new(model: impl Into<Arc<QuantModel>>, exec: Box<dyn GqmvExec>) -> Self {
        let model = model.into();
        let cfg = model.cfg;
        CpuEngine {
            exec,
            session: Session::new(&cfg),
            s: BatchScratch::new(&cfg, 1),
            tracer: None,
            model,
        }
    }

    /// Name of the GQMV backend this engine runs on.
    pub fn backend_name(&self) -> &'static str {
        self.exec.name()
    }

    /// Handle to the shared weights — clone to build sibling engines
    /// (serving workers) on the same weight copy.
    pub fn shared_model(&self) -> Arc<QuantModel> {
        Arc::clone(&self.model)
    }

    /// Decode one token against an *external* session at the session's own
    /// position, advancing it on success.  This is the multi-session
    /// serving path: one engine (scratch + backend) time-slices any number
    /// of pooled sessions.
    pub fn forward_session(
        &mut self,
        sess: &mut Session,
        token: u32,
        prof: &mut ForwardProfile,
    ) -> Result<&[f32]> {
        forward_pass(
            &self.model,
            self.exec.as_mut(),
            &mut self.s,
            &mut sess.kv,
            token,
            sess.pos,
            prof,
            self.tracer.as_mut().map(|t| t as &mut dyn TraceSink),
        )?;
        sess.pos += 1;
        Ok(self.s.logits(0))
    }
}

impl Engine for CpuEngine {
    fn cfg(&self) -> &LlamaConfig {
        &self.model.cfg
    }

    fn forward(&mut self, token: u32, pos: usize, prof: &mut ForwardProfile) -> Result<&[f32]> {
        forward_pass(
            &self.model,
            self.exec.as_mut(),
            &mut self.s,
            &mut self.session.kv,
            token,
            pos,
            prof,
            self.tracer.as_mut().map(|t| t as &mut dyn TraceSink),
        )?;
        self.session.pos = pos + 1;
        Ok(self.s.logits(0))
    }

    fn reset(&mut self) {
        self.session.reset();
    }

    fn name(&self) -> String {
        format!("cpu-resident/{}", self.exec.name())
    }

    fn trace_start(&mut self, label: &str) -> bool {
        self.tracer = Some(ExecTrace::new(&self.model.cfg, label));
        true
    }

    fn trace_take(&mut self) -> Option<ExecTrace> {
        self.tracer.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{FloatModel, LlamaConfig};
    use crate::ps::{ScalarGqmv, ThreadedGqmv};
    use crate::util::ThreadPool;
    use std::sync::Arc;

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    fn tiny_model(seed: u64) -> QuantModel {
        QuantModel::from_float(&FloatModel::random(tiny_cfg(), seed))
    }

    #[test]
    fn deterministic_and_finite() {
        let qm = tiny_model(1);
        let mut e1 = CpuEngine::new(qm.clone(), Box::new(ScalarGqmv));
        let mut e2 = CpuEngine::new(qm, Box::new(ScalarGqmv));
        let mut p = ForwardProfile::default();
        for (pos, t) in [5u32, 8, 2, 60].iter().enumerate() {
            let a = e1.forward(*t, pos, &mut p).unwrap().to_vec();
            let b = e2.forward(*t, pos, &mut p).unwrap().to_vec();
            assert_eq!(a, b);
            assert!(a.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn shared_arc_engines_match_owned_engines() {
        // one weight copy, two engines — identical logits to engines with
        // their own copies, and actually shared (strong count check)
        let qm = Arc::new(tiny_model(9));
        let mut owned = CpuEngine::new((*qm).clone(), Box::new(ScalarGqmv));
        let mut s1 = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let mut s2 = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        assert_eq!(Arc::strong_count(&qm), 3, "engines must share, not clone");
        assert!(Arc::ptr_eq(&s1.shared_model(), &s2.shared_model()));
        let mut p = ForwardProfile::default();
        for (pos, t) in [5u32, 8, 2].iter().enumerate() {
            let a = owned.forward(*t, pos, &mut p).unwrap().to_vec();
            let b = s1.forward(*t, pos, &mut p).unwrap().to_vec();
            let c = s2.forward(*t, pos, &mut p).unwrap().to_vec();
            assert_eq!(a, b);
            assert_eq!(a, c);
        }
    }

    #[test]
    fn scalar_and_threaded_backends_agree() {
        let qm = tiny_model(2);
        let pool = Arc::new(ThreadPool::new(4));
        let mut th = ThreadedGqmv::new(pool);
        th.min_parallel_macs = 0;
        let mut e1 = CpuEngine::new(qm.clone(), Box::new(ScalarGqmv));
        let mut e2 = CpuEngine::new(qm, Box::new(th));
        let mut p = ForwardProfile::default();
        for (pos, t) in [3u32, 40, 7].iter().enumerate() {
            let a = e1.forward(*t, pos, &mut p).unwrap().to_vec();
            let b = e2.forward(*t, pos, &mut p).unwrap().to_vec();
            assert_eq!(a, b, "pos {pos}");
        }
    }

    #[test]
    fn dataflow_sim_backend_agrees() {
        use crate::fpga::{DataflowSim, PlConfig};
        let qm = tiny_model(3);
        let mut e1 = CpuEngine::new(qm.clone(), Box::new(ScalarGqmv));
        let mut e2 = CpuEngine::new(qm, Box::new(DataflowSim::new(PlConfig::default())));
        let mut p = ForwardProfile::default();
        for (pos, t) in [11u32, 22, 33].iter().enumerate() {
            let a = e1.forward(*t, pos, &mut p).unwrap().to_vec();
            let b = e2.forward(*t, pos, &mut p).unwrap().to_vec();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn quantized_tracks_float_logits() {
        let fm = FloatModel::random(tiny_cfg(), 4);
        let qm = QuantModel::from_float(&fm);
        let mut fe = crate::ps::float::FloatEngine::new(fm);
        let mut qe = CpuEngine::new(qm, Box::new(ScalarGqmv));
        let mut p = ForwardProfile::default();
        for (pos, t) in [9u32, 14, 3, 50, 21].iter().enumerate() {
            let lf = fe.forward(*t, pos).unwrap().to_vec();
            let lq = qe.forward(*t, pos, &mut p).unwrap().to_vec();
            // correlation, not equality: quantization noise is expected
            let corr = correlation(&lf, &lq);
            assert!(corr > 0.98, "pos {pos}: corr {corr}");
        }
    }

    fn correlation(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
        let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
        let mut num = 0.0;
        let mut da = 0.0;
        let mut db = 0.0;
        for i in 0..a.len() {
            let xa = a[i] as f64 - ma;
            let xb = b[i] as f64 - mb;
            num += xa * xb;
            da += xa * xa;
            db += xb * xb;
        }
        num / (da.sqrt() * db.sqrt())
    }

    #[test]
    fn profile_is_populated() {
        let qm = tiny_model(5);
        let mut e = CpuEngine::new(qm, Box::new(ScalarGqmv));
        let mut p = ForwardProfile::default();
        e.forward(1, 0, &mut p).unwrap();
        assert!(p.matrix_s > 0.0);
        assert!(p.rmsnorm_s > 0.0);
        assert!(p.attention_s > 0.0);
        // matrix computation dominates even at nano scale
        assert!(p.matrix_s > p.rope_s);
    }

    #[test]
    fn forward_batch_bit_identical_to_sequential_sessions() {
        use crate::engine::session::Session;
        // 3 lanes at *different* positions and tokens, decoded batched,
        // must equal 3 dedicated batch-1 session decodes bit for bit
        let qm = Arc::new(tiny_model(11));
        let cfg = qm.cfg;
        let seqs = [[5u32, 8, 2, 60], [3, 40, 7, 1], [9, 9, 9, 9]];
        let mut prof = ForwardProfile::default();

        // reference: one engine per lane, sequential
        let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
        for seq in &seqs {
            let mut e = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
            let mut lane = Vec::new();
            for (pos, &t) in seq.iter().enumerate() {
                lane.push(e.forward(t, pos, &mut prof).unwrap().to_vec());
            }
            want.push(lane);
        }

        // batched: one scratch + exec, lanes share each layer walk.
        // lane 2 "joins late": it only enters the batch from step 2 on,
        // at its own (earlier) position — the step barrier semantics.
        let mut sessions: Vec<Session> = (0..3).map(|_| Session::new(&cfg)).collect();
        let mut exec = ScalarGqmv;
        let mut provider = ResidentLayers { model: Arc::clone(&qm) };
        let mut bs = BatchScratch::new(&cfg, 4);
        for step in 0..4 {
            let joined: Vec<usize> =
                if step < 2 { vec![0, 1] } else { vec![0, 1, 2] };
            // late lane catches up on its missed steps first (sequentially)
            if step == 2 {
                for catchup in 0..2 {
                    let lanes =
                        [BatchLane { pos: sessions[2].pos, token: seqs[2][catchup], kv: 0 }];
                    let mut kvs: [&mut dyn KvStore; 1] = [&mut sessions[2].kv];
                    forward_batch(&qm, &mut provider, &mut exec, &mut bs, &lanes, &mut kvs, &mut prof)
                        .unwrap();
                    sessions[2].pos += 1;
                    assert_eq!(bs.logits(0), &want[2][catchup][..], "catchup {catchup}");
                }
            }
            let mut lanes: Vec<BatchLane> = Vec::new();
            let mut kvs: Vec<&mut dyn KvStore> = Vec::new();
            for (idx, sess) in sessions.iter_mut().enumerate() {
                if joined.contains(&idx) {
                    lanes.push(BatchLane {
                        pos: sess.pos,
                        token: seqs[idx][sess.pos],
                        kv: kvs.len(),
                    });
                    kvs.push(&mut sess.kv);
                }
            }
            forward_batch(&qm, &mut provider, &mut exec, &mut bs, &lanes, &mut kvs, &mut prof)
                .unwrap();
            drop(kvs);
            for (b, &lane_idx) in joined.iter().enumerate() {
                let pos = sessions[lane_idx].pos;
                assert_eq!(
                    bs.logits(b),
                    &want[lane_idx][pos][..],
                    "lane {lane_idx} diverged at pos {pos}"
                );
                sessions[lane_idx].pos += 1;
            }
        }
    }

    #[test]
    fn fused_group_helper_bit_identical_to_singleton_groups() {
        // a split Wq/Wk/Wv-style group through quant_gqmv_fused_batch must
        // equal per-matrix singleton groups bit for bit, at 1 lane and at
        // several lanes — the dispatch-count reduction is free of drift
        use crate::quant::QuantizedTensor;
        use crate::util::Rng;
        let (n, gs) = (64usize, 32usize);
        let mut rng = Rng::new(77);
        let wa = QuantizedTensor::from_f32(&rng.normal_vec(16 * n, 0.5), 16, n, gs);
        let wb = QuantizedTensor::from_f32(&rng.normal_vec(8 * n, 0.5), 8, n, gs);
        for nb in [1usize, 3] {
            let x: Vec<f32> = rng.normal_vec(nb * n, 1.0);
            let mut qbuf = vec![0i8; nb * n];
            let mut sbuf = vec![0.0f32; nb * (n / gs)];
            let mut prof = ForwardProfile::default();
            let mut exec = crate::ps::ScalarGqmv;

            let mut want_a = vec![0.0f32; nb * 16];
            let mut want_b = vec![0.0f32; nb * 8];
            quant_gqmv_fused_batch(
                &mut exec,
                &x,
                n,
                &[&wa],
                &mut [&mut want_a[..]],
                &mut qbuf,
                &mut sbuf,
                gs,
                nb,
                &mut prof,
            )
            .unwrap();
            quant_gqmv_fused_batch(
                &mut exec,
                &x,
                n,
                &[&wb],
                &mut [&mut want_b[..]],
                &mut qbuf,
                &mut sbuf,
                gs,
                nb,
                &mut prof,
            )
            .unwrap();

            let mut got_a = vec![0.0f32; nb * 16];
            let mut got_b = vec![0.0f32; nb * 8];
            quant_gqmv_fused_batch(
                &mut exec,
                &x,
                n,
                &[&wa, &wb],
                &mut [&mut got_a[..], &mut got_b[..]],
                &mut qbuf,
                &mut sbuf,
                gs,
                nb,
                &mut prof,
            )
            .unwrap();
            assert_eq!(got_a, want_a, "nb={nb}");
            assert_eq!(got_b, want_b, "nb={nb}");
        }
    }

    #[test]
    fn forward_batch_rejects_bad_lanes() {
        use crate::engine::session::Session;
        let qm = Arc::new(tiny_model(12));
        let cfg = qm.cfg;
        let mut sess = Session::new(&cfg);
        let mut exec = ScalarGqmv;
        let mut provider = ResidentLayers { model: Arc::clone(&qm) };
        let mut bs = BatchScratch::new(&cfg, 2);
        let mut prof = ForwardProfile::default();
        // bad token
        let lanes = [BatchLane { pos: 0, token: 9999, kv: 0 }];
        let mut kvs: [&mut dyn KvStore; 1] = [&mut sess.kv];
        assert!(forward_batch(&qm, &mut provider, &mut exec, &mut bs, &lanes, &mut kvs, &mut prof)
            .is_err());
        // empty batch
        assert!(forward_batch(&qm, &mut provider, &mut exec, &mut bs, &[], &mut kvs, &mut prof)
            .is_err());
        // kv index out of range
        let lanes = [BatchLane { pos: 0, token: 1, kv: 3 }];
        assert!(forward_batch(&qm, &mut provider, &mut exec, &mut bs, &lanes, &mut kvs, &mut prof)
            .is_err());
        // lanes sharing a kv with non-consecutive positions
        let lanes =
            [BatchLane { pos: 0, token: 1, kv: 0 }, BatchLane { pos: 2, token: 1, kv: 0 }];
        assert!(forward_batch(&qm, &mut provider, &mut exec, &mut bs, &lanes, &mut kvs, &mut prof)
            .is_err());
        // lanes sharing a kv at the *same* position (would double-store)
        let lanes =
            [BatchLane { pos: 0, token: 1, kv: 0 }, BatchLane { pos: 0, token: 2, kv: 0 }];
        assert!(forward_batch(&qm, &mut provider, &mut exec, &mut bs, &lanes, &mut kvs, &mut prof)
            .is_err());
    }

    #[test]
    fn chunked_prefill_lanes_share_one_kv_bit_exactly() {
        use crate::engine::session::Session;
        // feeding a 3-token prompt as 3 lanes over ONE kv in a single step
        // must be bit-identical (logits AND stored KV) to feeding it one
        // token per step
        let qm = Arc::new(tiny_model(13));
        let cfg = qm.cfg;
        let prompt = [5u32, 8, 2];
        let mut exec = ScalarGqmv;
        let mut provider = ResidentLayers { model: Arc::clone(&qm) };
        let mut prof = ForwardProfile::default();

        // reference: one token at a time
        let mut ref_sess = Session::new(&cfg);
        let mut bs1 = BatchScratch::new(&cfg, 1);
        let mut want: Vec<Vec<f32>> = Vec::new();
        for (pos, &t) in prompt.iter().enumerate() {
            let lanes = [BatchLane { pos, token: t, kv: 0 }];
            let mut kvs: [&mut dyn KvStore; 1] = [&mut ref_sess.kv];
            forward_batch(&qm, &mut provider, &mut exec, &mut bs1, &lanes, &mut kvs, &mut prof)
                .unwrap();
            want.push(bs1.logits(0).to_vec());
        }

        // chunked: all 3 positions in one forward_batch call
        let mut sess = Session::new(&cfg);
        let mut bs3 = BatchScratch::new(&cfg, 3);
        let lanes: Vec<BatchLane> = prompt
            .iter()
            .enumerate()
            .map(|(pos, &t)| BatchLane { pos, token: t, kv: 0 })
            .collect();
        let mut kvs: [&mut dyn KvStore; 1] = [&mut sess.kv];
        forward_batch(&qm, &mut provider, &mut exec, &mut bs3, &lanes, &mut kvs, &mut prof)
            .unwrap();
        for (b, w) in want.iter().enumerate() {
            assert_eq!(bs3.logits(b), &w[..], "lane {b} logits diverged");
        }
        // KV contents identical at every (layer, pos)
        let hd = cfg.head_dim();
        for li in 0..cfg.n_layers {
            for pos in 0..prompt.len() {
                for h in 0..cfg.n_kv_heads {
                    assert_eq!(
                        sess.kv.key(li, pos, h, hd),
                        ref_sess.kv.key(li, pos, h, hd),
                        "key layer {li} pos {pos}"
                    );
                    assert_eq!(
                        sess.kv.value(li, pos, h, hd),
                        ref_sess.kv.value(li, pos, h, hd),
                        "value layer {li} pos {pos}"
                    );
                }
            }
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        let qm = tiny_model(6);
        let mut e = CpuEngine::new(qm, Box::new(ScalarGqmv));
        let mut p = ForwardProfile::default();
        assert!(e.forward(9999, 0, &mut p).is_err());
        assert!(e.forward(1, 10_000, &mut p).is_err());
    }

    #[test]
    fn tracing_captures_every_matrix_op_and_reruns_identically() {
        let qm = tiny_model(8);
        let cfg = qm.cfg;
        let mut e = CpuEngine::new(qm, Box::new(ScalarGqmv));
        let mut p = ForwardProfile::default();
        assert!(e.trace_take().is_none(), "no trace before trace_start");
        assert!(e.trace_start("run1"));
        for (pos, t) in [5u32, 8, 2].iter().enumerate() {
            e.forward(*t, pos, &mut p).unwrap();
        }
        let t1 = e.trace_take().unwrap();
        assert!(e.trace_take().is_none(), "trace_take detaches the trace");
        // 4 per-layer matrix ops + 1 classifier per step, one lane
        let per_step = cfg.n_layers * 4 + 1;
        assert_eq!(t1.steps(), 3);
        assert_eq!(t1.len(), 3 * per_step);
        let e0 = t1.events()[0];
        assert_eq!((e0.step, e0.layer, e0.op, e0.lane), (0, 0, crate::trace::TraceOp::Qkv, 0));
        let last = *t1.events().last().unwrap();
        assert_eq!(last.op, crate::trace::TraceOp::Cls);
        assert_eq!(last.layer as usize, cfg.n_layers);
        // an identical rerun digests identically (digest stability)
        e.reset();
        assert!(e.trace_start("run2"));
        for (pos, t) in [5u32, 8, 2].iter().enumerate() {
            e.forward(*t, pos, &mut p).unwrap();
        }
        let t2 = e.trace_take().unwrap();
        let r = crate::trace::diff(&t1, &t2);
        assert!(r.identical(), "{}", r.summary());
    }

    #[test]
    fn reset_reproduces_first_token() {
        let qm = tiny_model(7);
        let mut e = CpuEngine::new(qm, Box::new(ScalarGqmv));
        let mut p = ForwardProfile::default();
        let a = e.forward(4, 0, &mut p).unwrap().to_vec();
        e.forward(5, 1, &mut p).unwrap();
        e.reset();
        let b = e.forward(4, 0, &mut p).unwrap().to_vec();
        assert_eq!(a, b);
    }
}
