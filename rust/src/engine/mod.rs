//! Inference engines implementing Algorithm 2 (FPGA-accelerated
//! transformer forward pass, host side).
//!
//! * [`CpuEngine`] — weights resident, GQMV on a pluggable CPU backend
//!   (scalar / threaded = the PS baseline; dataflow sim = the modeled PL).
//! * [`LlamafEngine`] — the paper's system: PS control flow + streamed
//!   weights (layer- or matrix-granular, [`crate::sched`]) + GQMV
//!   executed by the AOT Pallas kernel via PJRT, routed through the same
//!   unified [`forward::forward_batch`] as the CPU engines.
//! * [`BatchScheduler`] — the serving hot path: step-synchronous batched
//!   decoding, one weight-streaming pass per step shared by every active
//!   session ([`forward::forward_batch`]).
//!
//! All produce identical logits (integration-tested) because every GQMV
//! backend is bit-exact with Algorithm 1, batched or not.

pub mod batch;
pub mod forward;
pub mod generate;
pub mod llamaf;
pub mod ppl;
pub mod session;

pub use batch::{BatchOpts, BatchScheduler, WeightMode};
pub use forward::{CpuEngine, Engine};
pub use generate::{generate, GenOutput, Sampler};
pub use llamaf::LlamafEngine;
pub use ppl::perplexity;
pub use session::{generate_session, PoolBusy, Session, SessionGen, SessionPool};

use crate::metrics::ForwardProfile;

impl Engine for crate::ps::float::FloatEngine {
    fn cfg(&self) -> &crate::model::LlamaConfig {
        &self.model.cfg
    }

    fn forward(
        &mut self,
        token: u32,
        pos: usize,
        _prof: &mut ForwardProfile,
    ) -> anyhow::Result<&[f32]> {
        crate::ps::float::FloatEngine::forward(self, token, pos)
    }

    fn reset(&mut self) {
        crate::ps::float::FloatEngine::reset(self)
    }

    fn name(&self) -> String {
        "float-w32a32".into()
    }
}
