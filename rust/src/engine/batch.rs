//! Continuously batched decoding: one weight-streaming pass per step,
//! shared by every active session, with admission at every step.
//!
//! The paper's bottleneck analysis (§III-B, Fig. 2) says TinyLlama decode
//! on the ZCU102 is off-chip-bandwidth bound: per token, every layer's
//! weights must cross DDR→PL once.  Concurrent serving with a private
//! forward pass per session multiplies that cost by the session count —
//! the same layer is staged N times per wall-clock step.  The
//! [`BatchScheduler`] removes the multiplier: a dedicated decode thread
//! collects every session with a pending token into *lanes*, then drives
//! **one** [`forward_batch`](crate::engine::forward::forward_batch) walk
//! over the layers, staging each layer
//! exactly once (via the async [`Streamer`] prefetch) and applying it to
//! all B activation vectors before moving on.
//!
//! Admission is **continuous**: at the top of every step the scheduler
//! tops the active set up from the pending queue, so a request joins the
//! very next forward after it arrives — it never waits for the resident
//! batch to drain — and each lane retires independently the moment its
//! own step budget is met.  ([`Admission::Drain`] restores the
//! static-batch baseline for A/B occupancy measurements.)  A prompt may
//! prefill in **bounded chunks**: with [`BatchOpts::prefill_chunk`] = C,
//! a prefilling request occupies up to C lanes of one step at
//! consecutive positions over ONE shared KV ([`BatchLane::kv`]), cutting
//! its time-to-first-token by ~C× while decode lanes ride the same
//! weight pass.  Because every lane's math is the exact batch-1
//! operation sequence (see
//! [`forward_batch`](crate::engine::forward::forward_batch), including
//! the chunked-prefill ordering argument there), token streams are
//! **bit-identical** to sequential batch-1 generation no matter how
//! lanes interleave — integration-tested in
//! `rust/tests/batched_decoding.rs` and, against randomized arrival
//! schedules with per-op digest traces, `rust/tests/continuous_batching.rs`.
//!
//! Sessions backed by the paged KV pool (`serve --kv-pages`) also get
//! **prefix reuse** here: at admission the scheduler adopts the longest
//! cached page-aligned prompt prefix
//! ([`SessionKv::adopt_prefix`](crate::engine::session::SessionKv::adopt_prefix))
//! and skips feeding those tokens; at successful retirement it publishes
//! the session's own prefix back
//! ([`SessionKv::cache_prefix`](crate::engine::session::SessionKv::cache_prefix)).
//!
//! Occupancy, admission latency, chunk feeds and staging volume are
//! exported through [`BatchMetrics`] (the server appends them to
//! `STATS`): with B sessions active, the weight-bytes-staged-per-token
//! counter drops by ~B× relative to B independent passes.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::engine::forward::{
    forward_batch_traced, BatchLane, BatchScratch, LayerProvider, ResidentLayers,
};
use crate::engine::session::{Session, SessionGen};
use crate::metrics::{BatchMetrics, ForwardProfile, RequestTrace, TokenMeter, TraceBuilder};
use crate::model::{KvStore, LlamaConfig, QuantModel};
use crate::ps::gqmv::GqmvExec;
use crate::runtime::Runtime;
use crate::sched::{
    FaultPlan, FaultyFetcher, ModelFetcher, RetryPolicy, SchedMode, StageGranularity, Streamer,
    STAGE_UNITS,
};
use crate::tensor;
use crate::trace::{ExecTrace, TraceOp, TraceSink};

/// How the decode thread obtains each layer's weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightMode {
    /// Stage every layer host→device through the shared
    /// [`Streamer`] once per step (the paper's DDR→PL economics; the
    /// async prefetch worker hides the copies).  The default.
    Streamed,
    /// Serve layers zero-copy out of the `Arc`'d model
    /// ([`ResidentLayers`]) — for deployments where the weights truly fit
    /// device-side and staging would be pure overhead.
    Resident,
}

/// When pending requests may join the active set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Top the batch up from the pending queue at the start of EVERY
    /// step: a request joins the next forward after it arrives and lanes
    /// retire independently.  The default.
    Continuous,
    /// Admit only when the active set is empty (the classic static
    /// batch: collect, run to completion, drain).  Kept as the baseline
    /// the ragged-arrival occupancy bench compares against.
    Drain,
}

/// Knobs of the continuous batch scheduler.
#[derive(Clone, Copy, Debug)]
pub struct BatchOpts {
    /// Maximum lanes decoded per step (bounds scratch memory and the
    /// per-step latency envelope).
    pub max_batch: usize,
    /// Maximum lanes waiting for a step slot before
    /// [`BatchScheduler::generate`] rejects with a saturation error —
    /// overload is explicit, never unbounded queue growth (each queued
    /// lane holds a full KV cache).
    pub max_pending: usize,
    /// Weight-staging schedule of the shared streamer.  [`SchedMode::Async`]
    /// prefetches upcoming layers while the batched kernels of layer *l*
    /// run.  Ignored under [`WeightMode::Resident`].
    pub sched: SchedMode,
    /// Staging-ring depth of the shared streamer (CLI `--prefetch-depth`):
    /// 1 resident unit + `prefetch_depth - 1` transfers in flight.  2 is
    /// the classic double buffer; deeper rings absorb transfer jitter at
    /// the cost of extra staged memory.  Ignored under
    /// [`WeightMode::Resident`] and (effectively) under [`SchedMode::Sync`].
    pub prefetch_depth: usize,
    /// Unit of staging the shared streamer pipelines (CLI
    /// `--stream-granularity`): whole layers (the classic schedule) or
    /// per-matrix chunks, which overlap transfers *within* a layer and
    /// shrink the wait gating each layer's first GQMV.  Bit-identical
    /// either way; ignored under [`WeightMode::Resident`].
    pub granularity: StageGranularity,
    /// Streamed (staged-per-step) vs resident (zero-copy) weights.
    pub weights: WeightMode,
    /// Continuous (default) vs drain-then-refill admission.
    pub admission: Admission,
    /// Maximum prompt tokens one prefilling request may consume in a
    /// single step (CLI `--prefill-chunk`), as extra lanes at
    /// consecutive positions over its one KV.  1 = classic one token per
    /// step; larger values cut time-to-first-token when spare lane
    /// capacity exists.  Bit-identical at any value.
    pub prefill_chunk: usize,
    /// Record a per-op digest [`ExecTrace`] for every request and return
    /// it in [`SessionGen::exec_trace`] — the equivalence harness's
    /// divergence localizer.  Off in production serving (small but
    /// nonzero per-op cost).
    pub trace: bool,
}

impl Default for BatchOpts {
    fn default() -> Self {
        BatchOpts {
            max_batch: 8,
            max_pending: 64,
            sched: SchedMode::Async,
            prefetch_depth: crate::sched::DEFAULT_PREFETCH_DEPTH,
            granularity: StageGranularity::default(),
            weights: WeightMode::Streamed,
            admission: Admission::Continuous,
            prefill_chunk: 1,
            trace: false,
        }
    }
}

/// The decode thread's layer source: a zero-copy resident provider or the
/// staging streamer, with uniform access to the staging counters.
enum StepLayers {
    /// Zero-copy layers out of the shared model.
    Resident(ResidentLayers),
    /// Per-step staging through the persistent prefetch worker.
    Streamed(Streamer),
}

impl StepLayers {
    fn provider(&mut self) -> &mut dyn LayerProvider {
        match self {
            StepLayers::Resident(r) => r,
            StepLayers::Streamed(s) => s,
        }
    }

    fn staged_bytes(&self) -> u64 {
        match self {
            StepLayers::Resident(_) => 0,
            StepLayers::Streamed(s) => s.stats.staged_bytes,
        }
    }

    fn prefetch_wait_s(&self) -> f64 {
        match self {
            StepLayers::Resident(_) => 0.0,
            StepLayers::Streamed(s) => s.stats.prefetch_wait_s,
        }
    }

    fn ring_occupancy_mean(&self) -> f64 {
        match self {
            StepLayers::Resident(_) => 0.0,
            StepLayers::Streamed(s) => s.stats.ring_occupancy_mean(),
        }
    }

    fn total_transfer_s(&self) -> f64 {
        match self {
            StepLayers::Resident(_) => 0.0,
            StepLayers::Streamed(s) => s.stats.total_transfer_s,
        }
    }

    fn wait_by_unit_s(&self) -> [f64; STAGE_UNITS] {
        match self {
            StepLayers::Resident(_) => [0.0; STAGE_UNITS],
            StepLayers::Streamed(s) => s.stats.wait_by_unit_s,
        }
    }

    /// (retries, faults, timeouts) of the staging layer — resident
    /// serving has no I/O to fault.
    fn fault_counters(&self) -> (u64, u64, u64) {
        match self {
            StepLayers::Resident(_) => (0, 0, 0),
            StepLayers::Streamed(s) => {
                (s.stats.retries, s.stats.stage_faults, s.stats.stage_timeouts)
            }
        }
    }
}

/// Prefix of load-shedding errors from [`BatchScheduler::generate`]
/// (scheduler saturation).  The server matches on this to count
/// rejections; keep the two in lockstep via this constant.
pub const BUSY_ERR_PREFIX: &str = "busy:";

/// Prefix of lane-failure errors caused by an unrecoverable I/O fault:
/// a step that kept failing after [`MAX_STEP_ATTEMPTS`] attempts sheds
/// one lane with this prefix while the rest of the batch keeps decoding.
pub const FAULT_ERR_PREFIX: &str = "fault:";

/// Prefix of per-request deadline expiries
/// ([`BatchScheduler::generate_with_deadline`], server
/// `--request-timeout`).
pub const DEADLINE_ERR_PREFIX: &str = "deadline:";

/// Consecutive failed attempts at one batched step before the scheduler
/// stops retrying and sheds a lane.  The staging layer below retries
/// transient I/O itself ([`RetryPolicy`]); attempts here are full-step
/// retries, so by the time this trips the fault has survived
/// `MAX_STEP_ATTEMPTS × RetryPolicy::max_attempts` reads.
pub const MAX_STEP_ATTEMPTS: u32 = 3;

/// Messages from the decode thread back to a waiting [`BatchScheduler::generate`].
enum LaneMsg {
    /// One greedy token was produced for this lane.
    Token { step: usize, id: u32 },
    /// The lane retired; its session is returned to the caller along
    /// with the decode-side cadence meter and (on success) the lane's
    /// per-request observability trace.  `Err` carries a human-readable
    /// reason (step failure, cancellation, ...).
    Done {
        sess: Box<Session>,
        meter: Option<TokenMeter>,
        trace: Option<Box<RequestTrace>>,
        /// Per-op digest trace ([`BatchOpts::trace`] runs only).
        exec: Option<Box<ExecTrace>>,
        result: Result<(), String>,
    },
}

/// One queued/active generation request.
struct LaneJob {
    sess: Box<Session>,
    prompt: Vec<u32>,
    /// Forward passes done so far (prompt consumption + decoding).
    fed: usize,
    /// Last sampled token — the next feed once the prompt is consumed.
    last: u32,
    steps: usize,
    produced: usize,
    /// Decode-side cadence meter, baselined at this lane's first sampled
    /// token — measures true decode cadence, independent of how fast the
    /// caller drains its channel (a slow client must not skew rates).
    meter: Option<TokenMeter>,
    /// Per-request observability recorder (queue wait, prefill/decode
    /// split, staged-byte and stall attribution) — becomes the
    /// [`RequestTrace`] returned with the lane's [`SessionGen`].
    trace: TraceBuilder,
    /// Per-op digest trace, armed at admission when [`BatchOpts::trace`]
    /// is set; lanes of this job are renumbered to chunk offsets so the
    /// trace diffs cleanly against a batch-1 reference.
    exec: Option<Box<ExecTrace>>,
    /// Forward steps that actually *completed* since `exec` was armed —
    /// the fault path rolls the trace back to exactly this many steps,
    /// whether or not the aborted attempt got as far as `begin_step`.
    exec_steps: u32,
    /// Absolute completion deadline ([`BatchScheduler::generate_with_deadline`]);
    /// swept before every step and failed with [`DEADLINE_ERR_PREFIX`].
    deadline: Option<Instant>,
    tx: Sender<LaneMsg>,
    cancel: Arc<AtomicBool>,
}

struct SchedState {
    pending: VecDeque<LaneJob>,
    shutdown: bool,
}

/// Step-synchronous batched decoder shared by all serving workers.
///
/// Construction spawns one decode thread that owns the GQMV backend, the
/// batch scratch and a weight [`Streamer`] over the shared model.  Callers
/// submit work with [`BatchScheduler::generate`] (blocking, one call per
/// request); the scheduler multiplexes all concurrent calls onto batched
/// steps.  Call [`BatchScheduler::shutdown`] when done — the decode
/// thread holds an `Arc` to the scheduler, so dropping the last external
/// handle alone will not stop it.
pub struct BatchScheduler {
    cfg: LlamaConfig,
    max_pending: usize,
    state: Mutex<SchedState>,
    cv: Condvar,
    metrics: BatchMetrics,
    /// Monotonic request-id source for per-request traces.
    next_id: AtomicU64,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl BatchScheduler {
    /// Spawn the decode thread over `model` with the given GQMV backend.
    pub fn new(
        model: Arc<QuantModel>,
        exec: Box<dyn GqmvExec + Send>,
        opts: BatchOpts,
    ) -> Arc<Self> {
        Self::with_faults(model, exec, opts, None)
    }

    /// [`BatchScheduler::new`] with a deterministic I/O fault-injection
    /// plan (CLI `--inject-faults`): when `faults` is set, the decode
    /// thread's weight staging runs through a [`FaultyFetcher`], so the
    /// retry/isolation machinery is exercised on demand.  `None` is a
    /// passthrough.  Ignored under [`WeightMode::Resident`] (there is no
    /// I/O to fault).
    pub fn with_faults(
        model: Arc<QuantModel>,
        exec: Box<dyn GqmvExec + Send>,
        opts: BatchOpts,
        faults: Option<FaultPlan>,
    ) -> Arc<Self> {
        assert!(opts.max_batch >= 1);
        assert!(opts.max_pending >= 1);
        assert!(opts.prefetch_depth >= 1, "prefetch depth must be >= 1");
        assert!(opts.prefill_chunk >= 1, "prefill chunk must be >= 1");
        let sched = Arc::new(BatchScheduler {
            cfg: model.cfg,
            max_pending: opts.max_pending,
            state: Mutex::new(SchedState { pending: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
            metrics: BatchMetrics::default(),
            next_id: AtomicU64::new(0),
            worker: Mutex::new(None),
        });
        let thread_sched = Arc::clone(&sched);
        let handle = std::thread::Builder::new()
            .name("llamaf-batch-decode".into())
            .spawn(move || {
                // Whatever takes this thread down — normal shutdown, an
                // init failure, or a panic mid-step — the guard marks the
                // scheduler shut down and rejects queued lanes, so no
                // caller ever blocks on a decode thread that is gone.
                let _guard = ExitGuard(Arc::clone(&thread_sched));
                decode_loop(thread_sched, model, exec, opts, faults);
            })
            .expect("spawn batch decode thread");
        *sched.worker.lock().unwrap() = Some(handle);
        sched
    }

    /// Batch-occupancy / staging counters of the decode thread.
    pub fn metrics(&self) -> &BatchMetrics {
        &self.metrics
    }

    /// Run one greedy generation through the batch: token semantics
    /// (reset, prompt consumption, argmax, step count) match
    /// [`crate::engine::session::generate_session`] exactly, so outputs
    /// are bit-identical to batch-1 serving.  Timing differs by design:
    /// the returned rate/latency are metered on the decode thread —
    /// inter-token decode cadence baselined at the lane's first sampled
    /// token — so queue wait, prompt time, and the caller's own drain
    /// speed do not skew them.  `on_token(step, id)` runs on *this*
    /// thread per streamed token; returning an error cancels the lane at
    /// the next step barrier (remaining tokens are discarded).
    ///
    /// Returns the session (so the caller can release it back to its
    /// pool) plus the generation result.  The session is `None` only if
    /// the decode thread died with the lane in flight.
    pub fn generate(
        &self,
        sess: Session,
        prompt_ids: &[u32],
        steps: usize,
        on_token: impl FnMut(usize, u32) -> Result<()>,
    ) -> (Option<Session>, Result<SessionGen>) {
        self.generate_with_deadline(sess, prompt_ids, steps, None, on_token)
    }

    /// [`BatchScheduler::generate`] with a completion deadline (server
    /// `--request-timeout`): a lane still decoding when `timeout` elapses
    /// is failed with a [`DEADLINE_ERR_PREFIX`] error at the next step
    /// barrier — its KV pages return to the pool and every other lane
    /// keeps decoding.  The clock starts at submission, so time spent in
    /// the pending queue counts against the budget (an overloaded server
    /// sheds honestly instead of queueing work it cannot finish in time).
    pub fn generate_with_deadline(
        &self,
        mut sess: Session,
        prompt_ids: &[u32],
        steps: usize,
        timeout: Option<Duration>,
        mut on_token: impl FnMut(usize, u32) -> Result<()>,
    ) -> (Option<Session>, Result<SessionGen>) {
        // Validation mirrors generate_session; a bad request must never
        // reach the decode thread where it would poison a whole step.
        if prompt_ids.is_empty() {
            return (Some(sess), Err(anyhow!("empty prompt")));
        }
        if steps == 0 {
            return (Some(sess), Err(anyhow!("steps must be >= 1")));
        }
        if prompt_ids.len() + steps > self.cfg.seq_len {
            return (
                Some(sess),
                Err(anyhow!(
                    "prompt ({}) + steps ({steps}) exceeds seq_len {}",
                    prompt_ids.len(),
                    self.cfg.seq_len
                )),
            );
        }
        if let Some(&bad) = prompt_ids.iter().find(|&&t| t as usize >= self.cfg.vocab_size) {
            return (Some(sess), Err(anyhow!("prompt token {bad} out of range")));
        }
        sess.reset();
        let (tx, rx) = channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let job = LaneJob {
            sess: Box::new(sess),
            prompt: prompt_ids.to_vec(),
            fed: 0,
            last: *prompt_ids.last().unwrap(),
            steps,
            produced: 0,
            meter: None,
            trace: TraceBuilder::new(id),
            exec: None,
            exec_steps: 0,
            deadline: timeout.map(|t| Instant::now() + t),
            tx,
            cancel: Arc::clone(&cancel),
        };
        {
            let mut st =
                self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            if st.shutdown {
                return (Some(*job.sess), Err(anyhow!("batch scheduler is shut down")));
            }
            if st.pending.len() >= self.max_pending {
                return (
                    Some(*job.sess),
                    Err(anyhow!(
                        "{BUSY_ERR_PREFIX} batch scheduler saturated ({} lanes pending)",
                        st.pending.len()
                    )),
                );
            }
            st.pending.push_back(job);
        }
        self.cv.notify_all();

        let mut generated = Vec::with_capacity(steps);
        let mut cb_err: Option<anyhow::Error> = None;
        loop {
            match rx.recv() {
                Ok(LaneMsg::Token { step, id }) => {
                    generated.push(id);
                    if cb_err.is_none() {
                        if let Err(e) = on_token(step, id) {
                            // client went away mid-stream: stop decoding
                            // this lane at the next barrier, keep draining
                            // so the session comes back
                            cb_err = Some(e);
                            cancel.store(true, Ordering::Relaxed);
                        }
                    }
                }
                Ok(LaneMsg::Done { sess, meter, trace, exec, result }) => {
                    let sess = Some(*sess);
                    return match (cb_err, result) {
                        (Some(e), _) => (sess, Err(e)),
                        (None, Err(m)) => (sess, Err(anyhow!(m))),
                        (None, Ok(())) => {
                            // decode-side meter: true decode cadence,
                            // baselined at the first sampled token —
                            // excludes queue wait, prompt consumption and
                            // the caller's own drain speed.  (steps == 1
                            // reports rate 0: one token has no cadence.)
                            let meter = meter.unwrap_or_default();
                            let (p50, p99) = meter.p50_p99();
                            (
                                sess,
                                Ok(SessionGen {
                                    generated,
                                    tok_per_s: meter.tok_per_s(),
                                    latency_p50_s: p50,
                                    latency_p99_s: p99,
                                    trace: trace.map(|t| *t),
                                    exec_trace: exec.map(|t| *t),
                                }),
                            )
                        }
                    };
                }
                Err(_) => return (None, Err(anyhow!("batch decode thread died"))),
            }
        }
    }

    /// Stop accepting work, finish every in-flight lane, and join the
    /// decode thread.  Idempotent.
    pub fn shutdown(&self) {
        {
            self.state
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .shutdown = true;
        }
        self.cv.notify_all();
        let handle = self.worker.lock().unwrap().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

/// Routes per-op digest records from a batched forward to the per-job
/// [`ExecTrace`]s: forward lanes are renumbered to each job's chunk
/// offset, so at `prefill_chunk == 1` every job's trace reads exactly
/// like a batch-1 lane-0 trace no matter how the step was shared.
struct LaneTraceRouter<'a> {
    /// Per-job trace slots, indexed by job position in the active set.
    traces: Vec<Option<&'a mut ExecTrace>>,
    /// Forward-lane index → owning job index.
    lane_job: &'a [usize],
    /// Forward-lane index → offset within the job's chunk this step.
    lane_off: &'a [usize],
}

impl TraceSink for LaneTraceRouter<'_> {
    fn begin_step(&mut self) {
        for t in self.traces.iter_mut().flatten() {
            t.begin_step();
        }
    }

    fn record(&mut self, layer: usize, op: TraceOp, lane: usize, vals: &[f32]) {
        if let Some(t) = self.traces[self.lane_job[lane]].as_mut() {
            t.record(layer, op, self.lane_off[lane], vals);
        }
    }
}

/// The decode thread: top the active set up from the pending queue, run
/// one batched forward (decode lanes plus bounded prefill chunks), emit
/// tokens, retire finished lanes, repeat.
fn decode_loop(
    sched: Arc<BatchScheduler>,
    model: Arc<QuantModel>,
    mut exec: Box<dyn GqmvExec + Send>,
    opts: BatchOpts,
    faults: Option<FaultPlan>,
) {
    let cfg = model.cfg;
    sched.metrics.set_prefill_chunk(opts.prefill_chunk);
    sched.metrics.set_quant(model.fmt().name());
    // Streamed mode stages layers out of the Arc'd model ("DDR") into the
    // device runtime, hiding the copy behind the batched kernels in async
    // mode.  No compiled-kernel shapes are needed: the batched GQMV runs
    // on the staged host copy through `exec`.
    //
    // Cost model, deliberately: staging copies every layer once per STEP
    // (host fetch + device upload, exactly like `LlamafEngine` does per
    // token) because the paper's PL cannot hold the model — streaming is
    // the workload being amortized, and the persistent prefetch worker
    // hides it.  Resident mode ([`WeightMode::Resident`], `serve
    // --resident`) skips staging entirely for deployments where the
    // weights genuinely fit.
    let mut layers = if opts.weights == WeightMode::Resident {
        StepLayers::Resident(ResidentLayers { model: Arc::clone(&model) })
    } else {
        #[cfg(not(feature = "pjrt"))]
        let rt = Arc::new(Runtime::with_shapes(&[]));
        // Known pjrt-feature limitation: the real device runtime needs the
        // AOT artifacts and performs real uploads the CPU exec never
        // reads; a missing artifacts dir fails every request with a clear
        // error rather than serving.  (The pjrt feature additionally
        // requires the vendored `xla` bindings to build at all — see
        // rust/Cargo.toml.)
        #[cfg(feature = "pjrt")]
        let rt = match Runtime::load(std::path::Path::new(crate::ARTIFACTS_DIR)) {
            Ok(rt) => Arc::new(rt),
            Err(e) => {
                fail_pending_forever(&sched, format!("batch runtime init failed: {e:#}"));
                return;
            }
        };
        let fetcher = ModelFetcher { model: Arc::clone(&model) };
        let retry = RetryPolicy::default();
        // the injector decorates the fetcher *below* the retry layer, so
        // injected faults exercise the exact retry/backoff/timeout path
        // real I/O errors take
        let streamer = match faults {
            Some(plan) if !plan.is_empty() => Streamer::with_retry(
                rt,
                FaultyFetcher::new(fetcher, plan),
                opts.sched,
                opts.prefetch_depth,
                opts.granularity,
                retry,
            ),
            _ => Streamer::with_retry(
                rt,
                fetcher,
                opts.sched,
                opts.prefetch_depth,
                opts.granularity,
                retry,
            ),
        };
        match streamer {
            Ok(s) => {
                sched.metrics.set_ring_depth(opts.prefetch_depth);
                sched.metrics.set_granularity(opts.granularity.label());
                StepLayers::Streamed(s)
            }
            Err(e) => {
                fail_pending_forever(&sched, format!("batch streamer init failed: {e:#}"));
                return;
            }
        }
    };
    let mut scratch = BatchScratch::new(&cfg, opts.max_batch);
    let mut active: Vec<LaneJob> = Vec::new();
    // staging high-waters already attributed to a recorded step; starting
    // at 0 charges the construction-time layer-0 staging to the first
    // step, keeping BatchMetrics.bytes_staged == StreamerStats.staged_bytes
    let mut bytes_attributed = 0u64;
    let mut wait_attributed = 0.0f64;
    let mut unit_attributed = [0.0f64; STAGE_UNITS];
    // consecutive failed attempts at the CURRENT step; reset by success
    // and by shedding a lane
    let mut step_failures = 0u32;

    loop {
        // ---- continuous admission: top the batch up every step -------
        let mut newly: Vec<usize> = Vec::new();
        {
            let mut st = sched.state.lock().unwrap();
            loop {
                // Drain mode (the static-batch baseline) only admits into
                // an empty set; continuous mode admits whenever a slot is
                // free — a request never waits for the batch to drain.
                if opts.admission == Admission::Continuous || active.is_empty() {
                    while active.len() < opts.max_batch {
                        match st.pending.pop_front() {
                            Some(j) => {
                                newly.push(active.len());
                                active.push(j);
                            }
                            None => break,
                        }
                    }
                }
                if !active.is_empty() {
                    break;
                }
                if st.shutdown {
                    return; // nothing active, nothing pending: drained
                }
                st = sched.cv.wait(st).unwrap();
            }
        }
        // Admission work runs outside the lock: stamp the queue→admit
        // latency, adopt any cached KV prefix of the prompt (paged
        // sessions; the adopted positions are never fed), arm the per-op
        // digest trace.
        for &ji in &newly {
            let j = &mut active[ji];
            if let Some(wait_s) = j.trace.admit() {
                sched.metrics.record_admission(wait_s);
            }
            let adopted = j.sess.kv.adopt_prefix(&j.prompt);
            if adopted > 0 {
                j.fed = adopted;
                j.sess.pos = adopted;
                j.trace.set_prefix_tokens(adopted as u64);
            }
            if opts.trace {
                j.exec =
                    Some(Box::new(ExecTrace::new(&cfg, &format!("lane-{}", j.trace.id()))));
            }
        }
        // lanes whose client vanished leave before the next forward, and
        // lanes past their completion deadline are shed with their KV
        // donated back — both before any further weight staging is spent
        // on them
        let now = Instant::now();
        let mut i = 0;
        while i < active.len() {
            let expired = active[i].deadline.map(|d| now >= d).unwrap_or(false);
            if active[i].cancel.load(Ordering::Relaxed) || expired {
                let mut j = active.swap_remove(i);
                let result = if expired {
                    j.sess.reset(); // donate KV pages back to the pool now
                    sched.metrics.record_deadline_expired();
                    Err(format!("{DEADLINE_ERR_PREFIX} request deadline expired mid-decode"))
                } else {
                    Err("canceled by client".into())
                };
                let meter = j.meter.take();
                let _ = j.tx.send(LaneMsg::Done {
                    sess: j.sess,
                    meter,
                    trace: None,
                    exec: None,
                    result,
                });
            } else {
                i += 1;
            }
        }
        if active.is_empty() {
            continue;
        }

        // ---- lane plan: one lane per job, plus bounded prefill chunks
        // (extra lanes at consecutive positions over the job's one KV,
        // drawn from whatever step capacity is spare) ------------------
        let n_jobs = active.len();
        let mut feeds: Vec<usize> = vec![1; n_jobs];
        let mut spare = opts.max_batch - n_jobs;
        for (ji, j) in active.iter().enumerate() {
            if j.fed < j.prompt.len() {
                let remaining = j.prompt.len() - j.fed;
                let extra = opts.prefill_chunk.min(remaining).saturating_sub(1).min(spare);
                spare -= extra;
                feeds[ji] = 1 + extra;
            }
        }
        let n_lanes: usize = feeds.iter().sum();
        let mut last_lane: Vec<usize> = vec![0; n_jobs];

        // ---- one continuously-batched forward ------------------------
        let mut prof = ForwardProfile::default();
        let step_t = Instant::now();
        let step_result = {
            let mut lanes: Vec<BatchLane> = Vec::with_capacity(n_lanes);
            let mut lane_job: Vec<usize> = Vec::with_capacity(n_lanes);
            let mut lane_off: Vec<usize> = Vec::with_capacity(n_lanes);
            let mut kvs: Vec<&mut dyn KvStore> = Vec::with_capacity(n_jobs);
            let mut traces: Vec<Option<&mut ExecTrace>> = Vec::with_capacity(n_jobs);
            for (ji, j) in active.iter_mut().enumerate() {
                for k in 0..feeds[ji] {
                    let fed = j.fed + k;
                    let token = if fed < j.prompt.len() { j.prompt[fed] } else { j.last };
                    lanes.push(BatchLane { kv: ji, pos: j.sess.pos + k, token });
                    lane_job.push(ji);
                    lane_off.push(k);
                }
                last_lane[ji] = lanes.len() - 1;
                kvs.push(&mut j.sess.kv);
                traces.push(j.exec.as_deref_mut());
            }
            let any_trace = traces.iter().any(|t| t.is_some());
            let mut router =
                LaneTraceRouter { traces, lane_job: &lane_job, lane_off: &lane_off };
            let tracer: Option<&mut dyn TraceSink> =
                if any_trace { Some(&mut router) } else { None };
            forward_batch_traced(
                &model,
                layers.provider(),
                exec.as_mut(),
                &mut scratch,
                &lanes,
                &mut kvs,
                &mut prof,
                tracer,
            )
        };
        let step_wall = step_t.elapsed().as_secs_f64();
        if let Err(e) = step_result {
            // Lane-level fault isolation: a failed step does NOT fail the
            // batch.  Roll every per-op trace back to its last completed
            // step (the aborted attempt may or may not have reached
            // `begin_step`), then retry the identical step — nothing was
            // advanced, and KV writes at the same positions are
            // overwritten idempotently, so a successful retry leaves
            // every surviving lane bit-identical to a fault-free run.
            // After MAX_STEP_ATTEMPTS consecutive failures the lane at
            // the tail of the active set is shed with a FAULT_ERR_PREFIX
            // error (KV pages donated back) and the rest keep decoding.
            for j in active.iter_mut() {
                if let Some(t) = j.exec.as_deref_mut() {
                    while t.steps() > j.exec_steps {
                        t.rollback_step();
                    }
                }
                j.trace.record_fault();
            }
            sched.metrics.record_step_retry();
            // the failed attempt still moved the staging counters; export
            // them now — this may be the last activity before going idle
            let (s_retries, s_faults, s_timeouts) = layers.fault_counters();
            sched.metrics.set_stage_faults(s_retries, s_faults, s_timeouts);
            step_failures += 1;
            if step_failures >= MAX_STEP_ATTEMPTS {
                step_failures = 0;
                let mut j = active.pop().expect("error path requires an active lane");
                j.sess.reset(); // donate KV pages back to the pool now
                sched.metrics.record_lane_fault();
                let meter = j.meter.take();
                let _ = j.tx.send(LaneMsg::Done {
                    sess: j.sess,
                    meter,
                    trace: None,
                    exec: None,
                    result: Err(format!(
                        "{FAULT_ERR_PREFIX} decode step failed {MAX_STEP_ATTEMPTS} times: {e:#}"
                    )),
                });
            }
            continue;
        }
        step_failures = 0;
        let staged = layers.staged_bytes();
        let waited = layers.prefetch_wait_s();
        let units = layers.wait_by_unit_s();
        let step_bytes = staged - bytes_attributed;
        let step_wait = waited - wait_attributed;
        // same delta pattern, per matrix unit: the step's share of the
        // streamer's lifetime wait gauges, charged to this step's lanes
        let mut unit_delta = [0.0f64; STAGE_UNITS];
        for i in 0..STAGE_UNITS {
            unit_delta[i] = units[i] - unit_attributed[i];
        }
        sched.metrics.record_step(n_lanes, step_bytes, step_wait, &prof);
        sched.metrics.set_ring_occupancy(layers.ring_occupancy_mean());
        sched.metrics.set_staging_time(layers.total_transfer_s());
        sched.metrics.set_unit_waits(units);
        let (s_retries, s_faults, s_timeouts) = layers.fault_counters();
        sched.metrics.set_stage_faults(s_retries, s_faults, s_timeouts);
        bytes_attributed = staged;
        wait_attributed = waited;
        unit_attributed = units;

        // ---- per-job post-step: advance, sample, emit, retire --------
        let mut keep = Vec::with_capacity(active.len());
        for (ji, mut j) in active.drain(..).enumerate() {
            let c = feeds[ji];
            let fed_after = j.fed + c;
            // the chunk samples iff it reached the prompt's end: its last
            // lane's logits continue the sequence.  prefill_steps counts
            // non-sampling prompt feeds, so prefill + decode == forwards.
            let sampled = fed_after >= j.prompt.len();
            let prefill_feeds = (c - usize::from(sampled)) as u64;
            j.trace.record_step(
                prefill_feeds,
                sampled,
                step_wall,
                step_bytes,
                step_wait,
                unit_delta,
                n_lanes,
            );
            if c > 1 {
                sched.metrics.record_chunk_feed();
            }
            j.sess.pos += c;
            j.fed = fed_after;
            if j.exec.is_some() {
                j.exec_steps += 1; // this step completed; rollback floor moves up
            }
            let mut done = false;
            if sampled {
                let next = tensor::argmax(scratch.logits(last_lane[ji])) as u32;
                // cadence is metered HERE on the decode thread: baseline
                // at the first sample, tick on each subsequent one
                if j.meter.is_none() {
                    j.meter = Some(TokenMeter::new());
                } else if let Some(m) = j.meter.as_mut() {
                    m.tick();
                }
                let step = j.produced;
                j.produced += 1;
                j.last = next;
                let _ = j.tx.send(LaneMsg::Token { step, id: next });
                done = j.produced >= j.steps;
            }
            if done {
                // publish this session's page-aligned prompt prefix so a
                // later admission with the same prefix can adopt the
                // pages instead of recomputing (paged sessions only)
                j.sess.kv.cache_prefix(&j.prompt);
                let meter = j.meter.take();
                let mut trace = j.trace.finish();
                trace.tok_per_s = meter.as_ref().map(|m| m.tok_per_s()).unwrap_or(0.0);
                let _ = j.tx.send(LaneMsg::Done {
                    sess: j.sess,
                    meter,
                    trace: Some(Box::new(trace)),
                    exec: j.exec.take(),
                    result: Ok(()),
                });
            } else {
                keep.push(j);
            }
        }
        active = keep;
    }
}

/// Terminal decode-thread failure: reject everything queued and mark the
/// scheduler shut down so future submissions fail fast.  Tolerates a
/// poisoned mutex (the decode thread may have panicked while holding it).
fn fail_pending_forever(sched: &BatchScheduler, msg: String) {
    let mut st = sched.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    st.shutdown = true;
    for mut j in st.pending.drain(..) {
        let meter = j.meter.take();
        let _ = j.tx.send(LaneMsg::Done {
            sess: j.sess,
            meter,
            trace: None,
            exec: None,
            result: Err(msg.clone()),
        });
    }
}

/// Runs [`fail_pending_forever`] when the decode thread exits by ANY path
/// (drop runs on panic unwind too).  Lanes active at a panic lose their
/// senders when the unwinding drops them, so their callers get a
/// "decode thread died" error instead of blocking forever.
struct ExitGuard(Arc<BatchScheduler>);

impl Drop for ExitGuard {
    fn drop(&mut self) {
        fail_pending_forever(&self.0, "batch decode thread exited".into());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::forward::CpuEngine;
    use crate::engine::generate::{generate, Sampler};
    use crate::model::FloatModel;
    use crate::ps::ScalarGqmv;

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    fn tiny_model(seed: u64) -> Arc<QuantModel> {
        Arc::new(QuantModel::from_float(&FloatModel::random(tiny_cfg(), seed)))
    }

    #[test]
    fn single_lane_matches_batch1_generate() {
        let qm = tiny_model(1);
        let prompt = [1u32, 10, 11];
        let mut ref_engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let want = generate(&mut ref_engine, &prompt, 8, Sampler::Greedy, false).unwrap();

        let sched =
            BatchScheduler::new(Arc::clone(&qm), Box::new(ScalarGqmv), BatchOpts::default());
        let mut streamed = Vec::new();
        let (sess, out) = sched.generate(Session::new(&qm.cfg), &prompt, 8, |step, id| {
            assert_eq!(step, streamed.len());
            streamed.push(id);
            Ok(())
        });
        let out = out.unwrap();
        assert_eq!(out.generated, want.generated);
        assert_eq!(streamed, want.generated);
        // len-1 prompt feeds + 8 sampled forwards (the last generated
        // token is never fed back)
        assert_eq!(sess.expect("session returned").pos, prompt.len() - 1 + 8);
        sched.shutdown();
    }

    #[test]
    fn bad_requests_rejected_with_session_returned() {
        let qm = tiny_model(2);
        let sched =
            BatchScheduler::new(Arc::clone(&qm), Box::new(ScalarGqmv), BatchOpts::default());
        let cfg = qm.cfg;
        let (s, r) = sched.generate(Session::new(&cfg), &[], 4, |_, _| Ok(()));
        assert!(s.is_some() && r.is_err(), "empty prompt");
        let (s, r) = sched.generate(Session::new(&cfg), &[1, 2], 0, |_, _| Ok(()));
        assert!(s.is_some() && r.is_err(), "zero steps");
        let (s, r) = sched.generate(Session::new(&cfg), &[1, 2], 1000, |_, _| Ok(()));
        assert!(s.is_some() && r.is_err(), "context overflow");
        let (s, r) = sched.generate(Session::new(&cfg), &[9999], 4, |_, _| Ok(()));
        assert!(s.is_some() && r.is_err(), "bad token");
        sched.shutdown();
        let (s, r) = sched.generate(Session::new(&cfg), &[1, 2], 4, |_, _| Ok(()));
        assert!(s.is_some() && r.is_err(), "post-shutdown submit");
    }

    #[test]
    fn callback_error_cancels_lane_and_returns_session() {
        let qm = tiny_model(3);
        let sched =
            BatchScheduler::new(Arc::clone(&qm), Box::new(ScalarGqmv), BatchOpts::default());
        let (sess, r) = sched.generate(Session::new(&qm.cfg), &[1, 5], 16, |step, _| {
            anyhow::ensure!(step < 2, "client hung up");
            Ok(())
        });
        assert!(sess.is_some(), "session must come back after cancel");
        assert!(r.is_err());
        sched.shutdown();
    }

    #[test]
    fn resident_mode_bit_identical_and_stages_nothing() {
        let qm = tiny_model(5);
        let prompt = [1u32, 10, 11];
        let mut ref_engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let want = generate(&mut ref_engine, &prompt, 8, Sampler::Greedy, false).unwrap();
        let sched = BatchScheduler::new(
            Arc::clone(&qm),
            Box::new(ScalarGqmv),
            BatchOpts { weights: WeightMode::Resident, ..Default::default() },
        );
        let (sess, out) = sched.generate(Session::new(&qm.cfg), &prompt, 8, |_, _| Ok(()));
        assert!(sess.is_some());
        assert_eq!(out.unwrap().generated, want.generated, "resident lane diverged");
        assert_eq!(sched.metrics().bytes_staged(), 0, "resident mode must stage nothing");
        assert_eq!(sched.metrics().prefetch_wait_s(), 0.0);
        assert!(sched.metrics().steps() > 0);
        sched.shutdown();
    }

    #[test]
    fn streamed_mode_reports_staging_counters() {
        let qm = tiny_model(6);
        let sched =
            BatchScheduler::new(Arc::clone(&qm), Box::new(ScalarGqmv), BatchOpts::default());
        let (_sess, out) = sched.generate(Session::new(&qm.cfg), &[1, 2, 3], 4, |_, _| Ok(()));
        out.unwrap();
        assert!(sched.metrics().bytes_staged() > 0, "streamed mode stages per step");
        sched.shutdown();
    }

    #[test]
    fn prefetch_depth_is_a_latency_knob_not_a_data_path() {
        // depths 1, 2 and 4 must generate identical token streams; at
        // depth >= 2 the ring must be observed running ahead (occupancy
        // gauge > 0) and STATS must carry the configured depth
        let qm = tiny_model(7);
        let prompt = [1u32, 10, 11];
        let mut ref_engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let want = generate(&mut ref_engine, &prompt, 8, Sampler::Greedy, false).unwrap();
        for depth in [1usize, 2, 4] {
            let sched = BatchScheduler::new(
                Arc::clone(&qm),
                Box::new(ScalarGqmv),
                BatchOpts { prefetch_depth: depth, ..Default::default() },
            );
            let (sess, out) = sched.generate(Session::new(&qm.cfg), &prompt, 8, |_, _| Ok(()));
            assert!(sess.is_some());
            assert_eq!(out.unwrap().generated, want.generated, "depth {depth} diverged");
            let summary = sched.metrics().summary();
            assert!(
                summary.contains(&format!("prefetch_depth={depth}")),
                "summary missing depth: {summary}"
            );
            assert_eq!(sched.metrics().ring_depth(), depth as u64);
            if depth >= 2 {
                assert!(
                    sched.metrics().ring_occupancy() > 0.0,
                    "depth {depth}: ring never ran ahead: {summary}"
                );
            } else {
                assert_eq!(sched.metrics().ring_occupancy(), 0.0);
            }
            sched.shutdown();
        }
    }

    #[test]
    fn resident_mode_reports_no_ring() {
        let qm = tiny_model(8);
        let sched = BatchScheduler::new(
            Arc::clone(&qm),
            Box::new(ScalarGqmv),
            BatchOpts { weights: WeightMode::Resident, ..Default::default() },
        );
        let (_s, out) = sched.generate(Session::new(&qm.cfg), &[1, 2], 4, |_, _| Ok(()));
        out.unwrap();
        assert_eq!(sched.metrics().ring_depth(), 0, "resident serving has no staging ring");
        assert_eq!(sched.metrics().ring_occupancy(), 0.0);
        assert_eq!(sched.metrics().granularity(), "none", "no staging pipeline exists");
        assert_eq!(sched.metrics().stage_mb_s(), 0.0, "zero transfer must not divide");
        sched.shutdown();
    }

    #[test]
    fn matrix_granularity_bit_identical_and_reports_bandwidth() {
        // sub-layer streaming through the shared scheduler: token streams
        // must stay byte-identical to batch-1 at depths 2 and 4, and the
        // STATS-side gauges must reflect the configured granularity plus a
        // derivable staging bandwidth
        let qm = tiny_model(9);
        let prompt = [1u32, 10, 11];
        let mut ref_engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let want = generate(&mut ref_engine, &prompt, 8, Sampler::Greedy, false).unwrap();
        for depth in [2usize, 4] {
            let sched = BatchScheduler::new(
                Arc::clone(&qm),
                Box::new(ScalarGqmv),
                BatchOpts {
                    prefetch_depth: depth,
                    granularity: StageGranularity::Matrix,
                    ..Default::default()
                },
            );
            let (sess, out) = sched.generate(Session::new(&qm.cfg), &prompt, 8, |_, _| Ok(()));
            assert!(sess.is_some());
            assert_eq!(out.unwrap().generated, want.generated, "depth {depth} diverged");
            let summary = sched.metrics().summary();
            assert!(summary.contains("granularity=matrix"), "{summary}");
            assert!(summary.contains("stage_mb_s="), "{summary}");
            assert!(sched.metrics().stage_mb_s() > 0.0, "{summary}");
            sched.shutdown();
        }
    }

    #[test]
    fn request_trace_attributes_queue_prefill_and_decode() {
        let qm = tiny_model(10);
        let sched =
            BatchScheduler::new(Arc::clone(&qm), Box::new(ScalarGqmv), BatchOpts::default());
        let prompt = [3u32, 4, 5];
        let (_s, out) = sched.generate(Session::new(&qm.cfg), &prompt, 4, |_, _| Ok(()));
        let gen = out.unwrap();
        let t = gen.trace.expect("batched generation carries a request trace");
        assert_eq!(t.prefill_steps, prompt.len() as u64 - 1, "prefill = non-sampling feeds");
        assert_eq!(t.decode_steps, 4, "decode steps == tokens produced");
        assert_eq!(t.chunk_feeds, 0, "prefill_chunk=1 never multi-feeds");
        assert_eq!(t.prefix_tokens, 0, "contiguous sessions never adopt a prefix");
        assert!(t.queue_s >= 0.0);
        assert!(t.prefill_s + t.decode_s > 0.0, "step wall time was attributed");
        assert!(t.staged_bytes > 0, "streamed serving stages weights");
        assert!(t.batch_mean >= 1.0);
        assert!((t.tok_per_s - gen.tok_per_s).abs() < 1e-9, "trace carries the lane's rate");
        // ids are monotonic across requests
        let (_s2, out2) = sched.generate(Session::new(&qm.cfg), &prompt, 2, |_, _| Ok(()));
        let t2 = out2.unwrap().trace.unwrap();
        assert!(t2.id > t.id, "ids must be monotonic: {} then {}", t.id, t2.id);
        assert_eq!(sched.metrics().admissions(), 2, "each request admitted exactly once");
        assert!(sched.metrics().summary().contains("admission_ms="));
        sched.shutdown();
    }

    #[test]
    fn chunked_prefill_streams_bit_identical_and_counts_feeds() {
        let qm = tiny_model(11);
        let prompt = [1u32, 10, 11, 12, 13];
        let mut ref_engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let want = generate(&mut ref_engine, &prompt, 6, Sampler::Greedy, false).unwrap();
        for chunk in [1usize, 3, 16] {
            let sched = BatchScheduler::new(
                Arc::clone(&qm),
                Box::new(ScalarGqmv),
                BatchOpts { prefill_chunk: chunk, ..Default::default() },
            );
            let (sess, out) =
                sched.generate(Session::new(&qm.cfg), &prompt, 6, |_, _| Ok(()));
            assert!(sess.is_some());
            let gen = out.unwrap();
            assert_eq!(gen.generated, want.generated, "chunk {chunk} diverged");
            let t = gen.trace.unwrap();
            assert_eq!(t.prefill_steps, prompt.len() as u64 - 1, "feeds counted, not steps");
            assert_eq!(t.decode_steps, 6);
            if chunk == 1 {
                assert_eq!(t.chunk_feeds, 0);
                assert_eq!(sched.metrics().chunk_feeds(), 0);
            } else {
                assert!(t.chunk_feeds > 0, "chunk {chunk} recorded no multi-token feeds");
                assert!(sched.metrics().chunk_feeds() > 0);
            }
            assert!(
                sched.metrics().summary().contains(&format!("prefill_chunk={chunk}")),
                "summary missing the configured chunk"
            );
            sched.shutdown();
        }
    }

    #[test]
    fn drain_admission_stays_bit_identical() {
        let qm = tiny_model(12);
        let prompt = [2u32, 7, 9];
        let mut ref_engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let want = generate(&mut ref_engine, &prompt, 5, Sampler::Greedy, false).unwrap();
        let sched = BatchScheduler::new(
            Arc::clone(&qm),
            Box::new(ScalarGqmv),
            BatchOpts { admission: Admission::Drain, ..Default::default() },
        );
        let (sess, out) = sched.generate(Session::new(&qm.cfg), &prompt, 5, |_, _| Ok(()));
        assert!(sess.is_some());
        assert_eq!(out.unwrap().generated, want.generated, "drain admission diverged");
        sched.shutdown();
    }

    #[test]
    fn paged_sessions_adopt_cached_prefixes_across_requests() {
        let qm = tiny_model(13);
        let prompt: Vec<u32> = (1..=9).collect();
        let mut ref_engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let want = generate(&mut ref_engine, &prompt, 4, Sampler::Greedy, false).unwrap();
        // page_size 4: positions 0..8 of the 9-token prompt are cacheable
        let pool = Arc::new(crate::model::PagePool::new(&qm.cfg, 64, 4));
        let sched =
            BatchScheduler::new(Arc::clone(&qm), Box::new(ScalarGqmv), BatchOpts::default());

        let (s1, out1) =
            sched.generate(Session::paged(Arc::clone(&pool)), &prompt, 4, |_, _| Ok(()));
        let g1 = out1.unwrap();
        assert_eq!(g1.generated, want.generated, "cold paged run diverged");
        assert_eq!(g1.trace.unwrap().prefix_tokens, 0, "nothing cached yet");
        assert_eq!(pool.hits(), 0);
        assert!(pool.cached_prefixes() >= 1, "retirement published the prompt prefix");

        let (s2, out2) =
            sched.generate(Session::paged(Arc::clone(&pool)), &prompt, 4, |_, _| Ok(()));
        let g2 = out2.unwrap();
        assert_eq!(g2.generated, want.generated, "warm paged run diverged");
        let t2 = g2.trace.unwrap();
        assert_eq!(t2.prefix_tokens, 8, "two cached pages adopted");
        assert_eq!(t2.prefill_steps, 0, "adopted positions are never fed");
        assert_eq!(pool.hits(), 1);
        sched.shutdown();

        drop(s1);
        drop(s2);
        assert_eq!(
            pool.pages_used(),
            pool.cached_page_ids().len(),
            "after both sessions drop, only the prefix cache holds pages"
        );
        pool.clear_cache();
        assert_eq!(pool.pages_used(), 0, "cache drain frees every page");
    }

    #[test]
    fn exec_trace_opt_in_matches_batch1_reference() {
        use crate::engine::forward::Engine;
        let qm = tiny_model(14);
        let prompt = [1u32, 10, 11];
        let mut ref_engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        assert!(ref_engine.trace_start("ref"));
        let want = generate(&mut ref_engine, &prompt, 4, Sampler::Greedy, false).unwrap();
        let ref_trace = ref_engine.trace_take().unwrap();

        let sched = BatchScheduler::new(
            Arc::clone(&qm),
            Box::new(ScalarGqmv),
            BatchOpts { trace: true, ..Default::default() },
        );
        let (_s, out) = sched.generate(Session::new(&qm.cfg), &prompt, 4, |_, _| Ok(()));
        let gen = out.unwrap();
        assert_eq!(gen.generated, want.generated);
        let exec = gen.exec_trace.expect("trace: true returns a per-request op trace");
        let report = crate::trace::diff(&ref_trace, &exec);
        assert!(report.identical(), "op trace diverged from batch-1: {}", report.summary());
        sched.shutdown();
    }

    #[test]
    fn transient_injected_fault_is_absorbed_bit_identically() {
        // a one-shot read error at layer 1 is retried inside the staging
        // worker: the caller sees nothing but the retry counter moving
        let qm = tiny_model(20);
        let prompt = [1u32, 10, 11];
        let mut ref_engine = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let want = generate(&mut ref_engine, &prompt, 8, Sampler::Greedy, false).unwrap();
        let plan = FaultPlan::parse("at=1/any/readerr").unwrap();
        let sched = BatchScheduler::with_faults(
            Arc::clone(&qm),
            Box::new(ScalarGqmv),
            BatchOpts::default(),
            Some(plan),
        );
        let (sess, out) = sched.generate(Session::new(&qm.cfg), &prompt, 8, |_, _| Ok(()));
        assert!(sess.is_some());
        assert_eq!(out.unwrap().generated, want.generated, "retried fault changed tokens");
        assert!(sched.metrics().stage_retries() >= 1, "the retry must be visible in STATS");
        assert_eq!(sched.metrics().lane_faults(), 0, "no lane failed");
        assert_eq!(sched.metrics().stage_faults(), 0, "no stage exhausted its retries");
        sched.shutdown();
    }

    #[test]
    fn persistent_fault_sheds_the_lane_with_a_fault_error() {
        // a layer that NEVER reads exhausts staging retries, then step
        // retries, then sheds exactly one lane with the "fault:" prefix —
        // and the scheduler stays alive for later requests
        let qm = tiny_model(21);
        let plan = FaultPlan::parse("at=1/any/readerr/always").unwrap();
        let sched = BatchScheduler::with_faults(
            Arc::clone(&qm),
            Box::new(ScalarGqmv),
            BatchOpts::default(),
            Some(plan),
        );
        let (sess, out) = sched.generate(Session::new(&qm.cfg), &[1, 2, 3], 4, |_, _| Ok(()));
        assert!(sess.is_some(), "the session must come back from a shed lane");
        let e = out.unwrap_err().to_string();
        assert!(e.starts_with(FAULT_ERR_PREFIX), "{e}");
        assert!(e.contains("injected fault"), "cause must be preserved: {e}");
        assert_eq!(sched.metrics().lane_faults(), 1);
        assert_eq!(sched.metrics().step_retries(), u64::from(MAX_STEP_ATTEMPTS));
        assert!(sched.metrics().stage_faults() >= 1, "staging-layer faults surfaced");
        assert_eq!(sess.unwrap().pos, 0, "shed session was reset (pages donated)");
        sched.shutdown();
    }

    #[test]
    fn expired_deadline_fails_the_lane_cleanly() {
        let qm = tiny_model(22);
        let sched =
            BatchScheduler::new(Arc::clone(&qm), Box::new(ScalarGqmv), BatchOpts::default());
        let (sess, out) = sched.generate_with_deadline(
            Session::new(&qm.cfg),
            &[1, 2, 3],
            4,
            Some(Duration::from_millis(0)),
            |_, _| Ok(()),
        );
        assert!(sess.is_some());
        let e = out.unwrap_err().to_string();
        assert!(e.starts_with(DEADLINE_ERR_PREFIX), "{e}");
        assert_eq!(sched.metrics().deadline_expired(), 1);
        // a sane deadline does not interfere
        let (_s, out) = sched.generate_with_deadline(
            Session::new(&qm.cfg),
            &[1, 2, 3],
            4,
            Some(Duration::from_secs(3600)),
            |_, _| Ok(()),
        );
        assert!(out.is_ok(), "generous deadline must not fire");
        assert_eq!(sched.metrics().deadline_expired(), 1);
        sched.shutdown();
    }

    #[test]
    fn drain_admission_sheds_an_expired_pending_request_before_decoding() {
        // Under Admission::Drain a pending request waits for the active
        // set to empty; one whose deadline expired while it waited must
        // be shed with ERR deadline: at the admission barrier — never
        // decoded late.
        let qm = tiny_model(23);
        let sched = BatchScheduler::new(
            Arc::clone(&qm),
            Box::new(ScalarGqmv),
            BatchOpts { admission: Admission::Drain, ..Default::default() },
        );
        let mut streamed = 0usize;
        let (sess, out) = sched.generate_with_deadline(
            Session::new(&qm.cfg),
            &[1, 2, 3],
            4,
            Some(Duration::from_millis(0)),
            |_, _| {
                streamed += 1;
                Ok(())
            },
        );
        assert!(sess.is_some(), "the session comes back from a swept pending lane");
        let e = out.unwrap_err().to_string();
        assert!(e.starts_with(DEADLINE_ERR_PREFIX), "{e}");
        assert_eq!(streamed, 0, "an expired request must not stream tokens late");
        assert_eq!(sched.metrics().deadline_expired(), 1);
        sched.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_drains() {
        let qm = tiny_model(4);
        let sched =
            BatchScheduler::new(Arc::clone(&qm), Box::new(ScalarGqmv), BatchOpts::default());
        let (sess, r) = sched.generate(Session::new(&qm.cfg), &[3, 4, 5], 4, |_, _| Ok(()));
        assert!(r.is_ok());
        assert!(sess.is_some());
        sched.shutdown();
        sched.shutdown();
        assert_eq!(sched.metrics().steps(), 6, "3-token prompt + 4 steps = 6 forwards");
    }
}
