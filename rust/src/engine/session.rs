//! Per-session decode state and the shared-weight session pool.
//!
//! The paper's system is batch-1: one engine owns one KV cache.  Serving
//! many users from one embedded board inverts the scarcity — the quantized
//! weights are the large, read-only resource (shared via `Arc` by every
//! engine/worker), while the per-user state is small and mutable.  That
//! state is [`Session`]: a KV cache plus decode position, checked out of a
//! capacity-bounded [`SessionPool`] with LRU eviction of idle sessions.
//!
//! Workers own the compute (an engine with its scratch buffers); sessions
//! own the conversation.  Any worker can drive any session, so N clients
//! produce outputs byte-identical to N sequential batch-1 runs.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::engine::forward::{CpuEngine, Engine};
use crate::metrics::{ForwardProfile, TokenMeter};
use crate::model::{KvCache, KvStore, LlamaConfig, PagePool, PagedKv};
use crate::tensor;

/// A session's KV storage: the contiguous per-session slab (the paper's
/// layout, and the default), or a paged view drawing from a shared
/// [`PagePool`] (`serve --kv-pages N`).  Both implement [`KvStore`], and
/// the forward path only ever sees the trait — backends cannot tell the
/// layouts apart, which is what keeps them bit-identical.
pub enum SessionKv {
    /// One private `n_layers × seq_len × kv_dim` slab.
    Contiguous(KvCache),
    /// On-demand pages from a shared pool, with copy-on-write prefix
    /// sharing (see `model::paged`).
    Paged(PagedKv),
}

impl SessionKv {
    /// Adopt the longest cached prompt prefix from the page pool's
    /// prefix cache; returns positions pre-filled (0 for contiguous
    /// storage or a cache miss).  Called by the batch scheduler at
    /// admission, after the session reset.
    pub fn adopt_prefix(&mut self, prompt: &[u32]) -> usize {
        match self {
            SessionKv::Contiguous(_) => 0,
            SessionKv::Paged(kv) => kv.adopt_prefix(prompt),
        }
    }

    /// Publish this session's page-aligned prompt prefix to the pool's
    /// prefix cache (no-op for contiguous storage).  Called by the batch
    /// scheduler when a lane retires successfully.
    pub fn cache_prefix(&self, prompt: &[u32]) {
        if let SessionKv::Paged(kv) = self {
            kv.cache_prefix(prompt);
        }
    }
}

impl KvStore for SessionKv {
    fn store(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        match self {
            SessionKv::Contiguous(kv) => kv.store(layer, pos, k, v),
            SessionKv::Paged(kv) => kv.store(layer, pos, k, v),
        }
    }

    fn key(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32] {
        match self {
            SessionKv::Contiguous(kv) => kv.key(layer, pos, kv_head, head_dim),
            SessionKv::Paged(kv) => kv.key(layer, pos, kv_head, head_dim),
        }
    }

    fn value(&self, layer: usize, pos: usize, kv_head: usize, head_dim: usize) -> &[f32] {
        match self {
            SessionKv::Contiguous(kv) => kv.value(layer, pos, kv_head, head_dim),
            SessionKv::Paged(kv) => kv.value(layer, pos, kv_head, head_dim),
        }
    }

    fn filled(&self) -> usize {
        match self {
            SessionKv::Contiguous(kv) => kv.filled,
            SessionKv::Paged(kv) => kv.filled(),
        }
    }

    fn reset(&mut self) {
        match self {
            SessionKv::Contiguous(kv) => kv.reset(),
            SessionKv::Paged(kv) => kv.reset(),
        }
    }

    fn bytes(&self) -> usize {
        match self {
            SessionKv::Contiguous(kv) => kv.bytes(),
            SessionKv::Paged(kv) => kv.bytes(),
        }
    }
}

impl fmt::Debug for SessionKv {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SessionKv::Contiguous(kv) => write!(f, "Contiguous(filled={})", kv.filled),
            SessionKv::Paged(kv) => {
                write!(f, "Paged(filled={}, pages={})", kv.filled(), kv.n_pages())
            }
        }
    }
}

/// Mutable per-user decode state (everything `Arc`-shared weights are not).
#[derive(Debug)]
pub struct Session {
    /// This session's private KV state.
    pub kv: SessionKv,
    /// Next decode position (== tokens consumed so far).
    pub pos: usize,
    /// LRU stamp, maintained by the pool on release.
    last_used: u64,
}

impl Session {
    /// Fresh session at position 0 with an empty contiguous KV cache.
    pub fn new(cfg: &LlamaConfig) -> Self {
        Session { kv: SessionKv::Contiguous(KvCache::new(cfg)), pos: 0, last_used: 0 }
    }

    /// Fresh session at position 0 drawing KV pages from `pool`.
    pub fn paged(pool: Arc<PagePool>) -> Self {
        Session { kv: SessionKv::Paged(PagedKv::new(pool)), pos: 0, last_used: 0 }
    }

    /// Rewind to an empty context (contiguous storage is lazily
    /// overwritten; paged storage returns its pages to the pool).
    pub fn reset(&mut self) {
        self.kv.reset();
        self.pos = 0;
    }

    /// KV memory footprint in bytes (pool capacity budgeting).
    pub fn bytes(&self) -> usize {
        self.kv.bytes()
    }
}

/// All sessions are currently checked out and none can be evicted.
#[derive(Clone, Copy, Debug)]
pub struct PoolBusy;

impl fmt::Display for PoolBusy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "session pool exhausted (all sessions in use)")
    }
}

impl std::error::Error for PoolBusy {}

struct PoolInner {
    idle: HashMap<u64, Session>,
    in_use: usize,
    clock: u64,
}

/// Capacity-bounded pool of [`Session`]s keyed by caller id.
///
/// * `acquire(id)` returns the caller's existing idle session, or a fresh
///   one — evicting the least-recently-used *idle* session when at
///   capacity.  If every session is checked out, it fails with [`PoolBusy`]
///   instead of blocking (the server surfaces this as `ERR busy`).
/// * `release(id)` returns the session for later reuse by the same id.
pub struct SessionPool {
    cfg: LlamaConfig,
    capacity: usize,
    pages: Option<Arc<PagePool>>,
    inner: Mutex<PoolInner>,
}

impl SessionPool {
    /// Pool for `cfg`-shaped sessions, at most `capacity` alive at once.
    pub fn new(cfg: LlamaConfig, capacity: usize) -> Self {
        assert!(capacity >= 1);
        SessionPool {
            cfg,
            capacity,
            pages: None,
            inner: Mutex::new(PoolInner { idle: HashMap::new(), in_use: 0, clock: 0 }),
        }
    }

    /// Pool whose sessions draw KV storage from a shared [`PagePool`]
    /// instead of owning contiguous slabs (`serve --kv-pages N`).
    pub fn with_pages(cfg: LlamaConfig, capacity: usize, pages: Arc<PagePool>) -> Self {
        let mut pool = SessionPool::new(cfg, capacity);
        pool.pages = Some(pages);
        pool
    }

    /// The shared KV page pool, when paged storage is configured.
    pub fn page_pool(&self) -> Option<&Arc<PagePool>> {
        self.pages.as_ref()
    }

    /// Maximum number of sessions (idle + checked out).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// (idle, in_use) session counts.
    pub fn counts(&self) -> (usize, usize) {
        let g = self.inner.lock().unwrap();
        (g.idle.len(), g.in_use)
    }

    /// Check out `id`'s session (or a fresh/recycled one).  See the type
    /// docs for the eviction/busy rules.
    pub fn acquire(&self, id: u64) -> Result<Session, PoolBusy> {
        let mut g = self.inner.lock().unwrap();
        if let Some(sess) = g.idle.remove(&id) {
            g.in_use += 1;
            return Ok(sess);
        }
        if g.idle.len() + g.in_use >= self.capacity {
            // evict the least-recently-used idle session and recycle its
            // KV allocation for the new owner (a reset is enough: stale
            // positions are never read)
            let lru = g.idle.iter().min_by_key(|(_, s)| s.last_used).map(|(&k, _)| k);
            match lru {
                Some(k) => {
                    let mut sess = g.idle.remove(&k).expect("lru key just observed");
                    sess.reset();
                    g.in_use += 1;
                    return Ok(sess);
                }
                None => return Err(PoolBusy),
            }
        }
        g.in_use += 1;
        Ok(match &self.pages {
            Some(pool) => Session::paged(Arc::clone(pool)),
            None => Session::new(&self.cfg),
        })
    }

    /// Return `id`'s session for later reuse (stamps it most recently
    /// used).
    pub fn release(&self, id: u64, mut sess: Session) {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        sess.last_used = g.clock;
        g.in_use = g.in_use.saturating_sub(1);
        g.idle.insert(id, sess);
    }

    /// A checked-out session was lost and can never be released (e.g. the
    /// decode thread died holding it): give its capacity slot back so
    /// `in_use` accounting stays truthful.
    pub fn forget(&self, _id: u64) {
        let mut g = self.inner.lock().unwrap();
        g.in_use = g.in_use.saturating_sub(1);
    }
}

/// Result of a session-driven generation.
#[derive(Debug)]
pub struct SessionGen {
    /// Generated token ids (prompt excluded).
    pub generated: Vec<u32>,
    /// End-to-end decode throughput.
    pub tok_per_s: f64,
    /// Median per-token latency in seconds.
    pub latency_p50_s: f64,
    /// 99th-percentile per-token latency in seconds.
    pub latency_p99_s: f64,
    /// Per-request observability record — populated by the batch
    /// scheduler's decode thread; `None` on the session-pool path, which
    /// has no shared step counters to attribute.
    pub trace: Option<crate::metrics::RequestTrace>,
    /// Per-op digest trace of this request's forwards (batch scheduler
    /// with `BatchOpts::trace` only) — diffable against a batch-1
    /// reference trace to localize any scheduling divergence.
    pub exec_trace: Option<crate::trace::ExecTrace>,
}

/// Greedy generation against an external [`Session`] — the serving path.
///
/// Semantics match [`crate::engine::generate::generate`] with
/// `Sampler::Greedy` / `stop_at_eos = false` exactly (same reset, same
/// prompt consumption, same argmax), so concurrent sessions reproduce
/// batch-1 outputs token for token.  `on_token(step, id)` fires per
/// generated token, letting the server stream `TOK` lines.
pub fn generate_session(
    engine: &mut CpuEngine,
    sess: &mut Session,
    prompt_ids: &[u32],
    steps: usize,
    mut on_token: impl FnMut(usize, u32) -> Result<()>,
) -> Result<SessionGen> {
    anyhow::ensure!(!prompt_ids.is_empty(), "empty prompt");
    let seq_len = engine.cfg().seq_len;
    anyhow::ensure!(
        prompt_ids.len() + steps <= seq_len,
        "prompt ({}) + steps ({steps}) exceeds seq_len {seq_len}",
        prompt_ids.len()
    );
    sess.reset();
    let mut prof = ForwardProfile::default();
    for &t in &prompt_ids[..prompt_ids.len() - 1] {
        engine.forward_session(sess, t, &mut prof)?;
    }
    let mut meter = TokenMeter::new();
    let mut cur = *prompt_ids.last().unwrap();
    let mut generated = Vec::with_capacity(steps);
    for step in 0..steps {
        let logits = engine.forward_session(sess, cur, &mut prof)?;
        let next = tensor::argmax(logits) as u32;
        meter.tick();
        cur = next;
        generated.push(next);
        on_token(step, next)?;
    }
    let (p50, p99) = meter.p50_p99();
    Ok(SessionGen {
        generated,
        tok_per_s: meter.tok_per_s(),
        latency_p50_s: p50,
        latency_p99_s: p99,
        trace: None,
        exec_trace: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::generate::{generate, Sampler};
    use crate::model::{FloatModel, QuantModel};
    use crate::ps::ScalarGqmv;
    use std::sync::Arc;

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    fn tiny_model(seed: u64) -> Arc<QuantModel> {
        Arc::new(QuantModel::from_float(&FloatModel::random(tiny_cfg(), seed)))
    }

    #[test]
    fn session_generation_matches_batch1_generate() {
        let qm = tiny_model(1);
        let prompt = [1u32, 10, 11];
        let mut batch1 = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let expect = generate(&mut batch1, &prompt, 8, Sampler::Greedy, false).unwrap();

        let mut engine = CpuEngine::new(qm, Box::new(ScalarGqmv));
        let mut sess = Session::new(engine.cfg());
        let mut streamed = Vec::new();
        let out = generate_session(&mut engine, &mut sess, &prompt, 8, |_, id| {
            streamed.push(id);
            Ok(())
        })
        .unwrap();
        assert_eq!(out.generated, expect.generated);
        assert_eq!(streamed, expect.generated);
        // len-1 prompt feeds + 8 sampled forwards advance the position
        // (the final generated token is never fed back)
        assert_eq!(sess.pos, prompt.len() - 1 + 8);
    }

    #[test]
    fn interleaved_sessions_are_isolated() {
        // two sessions time-sliced on ONE engine must reproduce two
        // dedicated batch-1 engines step for step
        let qm = tiny_model(2);
        let seq_a = [5u32, 8, 2, 60];
        let seq_b = [3u32, 40, 7, 1];
        let mut prof = ForwardProfile::default();

        let mut e_a = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let mut e_b = CpuEngine::new(Arc::clone(&qm), Box::new(ScalarGqmv));
        let mut want_a = Vec::new();
        let mut want_b = Vec::new();
        for (pos, (&ta, &tb)) in seq_a.iter().zip(&seq_b).enumerate() {
            want_a.push(e_a.forward(ta, pos, &mut prof).unwrap().to_vec());
            want_b.push(e_b.forward(tb, pos, &mut prof).unwrap().to_vec());
        }

        let mut shared = CpuEngine::new(qm, Box::new(ScalarGqmv));
        let mut sa = Session::new(shared.cfg());
        let mut sb = Session::new(shared.cfg());
        for (step, (&ta, &tb)) in seq_a.iter().zip(&seq_b).enumerate() {
            let la = shared.forward_session(&mut sa, ta, &mut prof).unwrap().to_vec();
            assert_eq!(la, want_a[step], "session A diverged at step {step}");
            let lb = shared.forward_session(&mut sb, tb, &mut prof).unwrap().to_vec();
            assert_eq!(lb, want_b[step], "session B diverged at step {step}");
        }
    }

    #[test]
    fn pool_reuses_sessions_by_id() {
        let pool = SessionPool::new(tiny_cfg(), 2);
        let mut s = pool.acquire(7).unwrap();
        s.pos = 5;
        pool.release(7, s);
        let s = pool.acquire(7).unwrap();
        assert_eq!(s.pos, 5, "same id must get its session back");
        assert_eq!(pool.counts(), (0, 1));
    }

    #[test]
    fn pool_evicts_lru_idle_at_capacity() {
        let pool = SessionPool::new(tiny_cfg(), 2);
        let s1 = pool.acquire(1).unwrap();
        pool.release(1, s1);
        let s2 = pool.acquire(2).unwrap();
        pool.release(2, s2);
        // capacity reached; id 1 is least recently used -> evicted
        let _s3 = pool.acquire(3).unwrap();
        let (idle, in_use) = pool.counts();
        assert_eq!((idle, in_use), (1, 1));
        // id 2 survived; a fresh acquire(2) keeps its state
        let s2 = pool.acquire(2).unwrap();
        assert_eq!(s2.pos, 0);
    }

    #[test]
    fn pool_forget_restores_capacity() {
        let pool = SessionPool::new(tiny_cfg(), 1);
        let _lost = pool.acquire(1).unwrap();
        assert!(pool.acquire(2).is_err(), "at capacity");
        // the checkout can never be released (owner gone): forget frees
        // the slot for a fresh session
        pool.forget(1);
        assert!(pool.acquire(2).is_ok());
    }

    #[test]
    fn pool_busy_when_all_checked_out() {
        let pool = SessionPool::new(tiny_cfg(), 1);
        let held = pool.acquire(1).unwrap();
        assert!(pool.acquire(2).is_err(), "no idle session to evict -> busy");
        pool.release(1, held);
        assert!(pool.acquire(2).is_ok(), "idle session is evictable");
    }
}
