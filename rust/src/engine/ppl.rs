//! Perplexity evaluation (paper Table V: WikiText-2 → our held-out
//! synthetic corpus; the *relative* W32A32 vs W8A8 degradation transfers).

use anyhow::Result;

use crate::engine::forward::Engine;
use crate::metrics::ForwardProfile;
use crate::tensor::log_sum_exp;

/// Compute PPL of `tokens` under `engine`, processing non-overlapping
/// context windows of `engine.cfg().seq_len` (the standard stride=ctx
/// protocol).  At most `max_tokens` predictions are scored.
pub fn perplexity(engine: &mut dyn Engine, tokens: &[u32], max_tokens: usize) -> Result<f64> {
    anyhow::ensure!(tokens.len() >= 2, "need at least 2 tokens");
    let ctx = engine.cfg().seq_len;
    let mut prof = ForwardProfile::default();
    let mut nll = 0.0f64;
    let mut count = 0usize;
    let mut start = 0usize;
    while start + 1 < tokens.len() && count < max_tokens {
        let end = (start + ctx).min(tokens.len());
        engine.reset();
        for (pos, i) in (start..end - 1).enumerate() {
            let logits = engine.forward(tokens[i], pos, &mut prof)?;
            let target = tokens[i + 1] as usize;
            anyhow::ensure!(target < logits.len(), "target token out of range");
            let lse = log_sum_exp(logits) as f64;
            nll += lse - logits[target] as f64;
            count += 1;
            if count >= max_tokens {
                break;
            }
        }
        start = end;
    }
    anyhow::ensure!(count > 0, "no predictions scored");
    Ok((nll / count as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::forward::CpuEngine;
    use crate::model::{FloatModel, LlamaConfig, QuantModel};
    use crate::ps::float::FloatEngine;
    use crate::ps::ScalarGqmv;

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 16,
            gs: 32,
        }
    }

    #[test]
    fn random_model_ppl_near_vocab() {
        // an untrained model's PPL over random tokens ~ vocab size
        let fm = FloatModel::random(tiny_cfg(), 1);
        let mut e = FloatEngine::new(fm);
        let mut rng = crate::util::Rng::new(2);
        let toks: Vec<u32> = (0..200).map(|_| rng.below(64) as u32).collect();
        let ppl = perplexity(&mut e, &toks, 150).unwrap();
        assert!(ppl > 64.0 * 0.5 && ppl < 64.0 * 1.5, "ppl {ppl}");
    }

    #[test]
    fn quantized_ppl_close_to_float() {
        // Table V's shape: W8A8 PPL within ~2% of W32A32 on the same data
        let fm = FloatModel::random(tiny_cfg(), 3);
        let qm = QuantModel::from_float(&fm);
        let mut fe = FloatEngine::new(fm);
        let mut qe = CpuEngine::new(qm, Box::new(ScalarGqmv));
        let mut rng = crate::util::Rng::new(4);
        let toks: Vec<u32> = (0..150).map(|_| rng.below(64) as u32).collect();
        let p_f = perplexity(&mut fe, &toks, 100).unwrap();
        let p_q = perplexity(&mut qe, &toks, 100).unwrap();
        let delta = (p_q - p_f).abs() / p_f;
        assert!(delta < 0.05, "float {p_f} quant {p_q} delta {delta}");
    }

    #[test]
    fn too_short_input_rejected() {
        let fm = FloatModel::random(tiny_cfg(), 5);
        let mut e = FloatEngine::new(fm);
        assert!(perplexity(&mut e, &[1], 10).is_err());
    }

    #[test]
    fn windows_reset_context() {
        // ppl over a sequence longer than seq_len must not panic
        let fm = FloatModel::random(tiny_cfg(), 6);
        let mut e = FloatEngine::new(fm);
        let toks: Vec<u32> = (0..64).map(|i| (i % 60) as u32).collect();
        let ppl = perplexity(&mut e, &toks, 60).unwrap();
        assert!(ppl.is_finite() && ppl > 1.0);
    }
}
