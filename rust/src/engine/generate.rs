//! Token generation loop (paper §II-A / §V-C): consume the prompt, then
//! decode `steps` tokens with greedy or top-p sampling.  The SQuAD-style
//! evaluation omits the EOS stop and uses greedy sampling; both behaviours
//! are options here.

use anyhow::Result;

use crate::engine::forward::Engine;
use crate::metrics::{ForwardProfile, TokenMeter};
use crate::tensor;
use crate::tokenizer::EOS_ID;
use crate::util::Rng;

/// Sampling strategy.
#[derive(Clone, Copy, Debug)]
pub enum Sampler {
    /// argmax (paper's evaluation mode)
    Greedy,
    /// nucleus sampling
    TopP {
        /// cumulative-probability cutoff
        p: f32,
        /// softmax temperature (> 0)
        temperature: f32,
        /// RNG seed (reproducible sampling)
        seed: u64,
    },
}

/// Result of a generation run.
#[derive(Debug)]
pub struct GenOutput {
    /// prompt + generated ids
    pub ids: Vec<u32>,
    /// generated-only ids
    pub generated: Vec<u32>,
    /// End-to-end decode throughput.
    pub tok_per_s: f64,
    /// Median per-token latency in seconds.
    pub latency_p50_s: f64,
    /// 99th-percentile per-token latency in seconds.
    pub latency_p99_s: f64,
    /// Component timing breakdown accumulated over the run.
    pub profile: ForwardProfile,
}

/// Generate `steps` tokens after the prompt.  `stop_at_eos=false`
/// reproduces the paper's fixed-step measurement mode.
pub fn generate(
    engine: &mut dyn Engine,
    prompt_ids: &[u32],
    steps: usize,
    sampler: Sampler,
    stop_at_eos: bool,
) -> Result<GenOutput> {
    anyhow::ensure!(!prompt_ids.is_empty(), "empty prompt");
    let seq_len = engine.cfg().seq_len;
    anyhow::ensure!(
        prompt_ids.len() + steps <= seq_len,
        "prompt ({}) + steps ({steps}) exceeds seq_len {seq_len}",
        prompt_ids.len()
    );
    engine.reset();
    let mut prof = ForwardProfile::default();
    let mut ids = prompt_ids.to_vec();
    let mut rng = match sampler {
        Sampler::TopP { seed, .. } => Rng::new(seed),
        _ => Rng::new(0),
    };

    // consume the prompt (logits ignored except for the last position)
    let mut pos = 0;
    for &t in &prompt_ids[..prompt_ids.len() - 1] {
        engine.forward(t, pos, &mut prof)?;
        pos += 1;
    }

    let mut meter = TokenMeter::new();
    let mut cur = *prompt_ids.last().unwrap();
    let mut generated = Vec::with_capacity(steps);
    for _ in 0..steps {
        let logits = engine.forward(cur, pos, &mut prof)?;
        let next = match sampler {
            Sampler::Greedy => tensor::argmax(logits) as u32,
            Sampler::TopP { p, temperature, .. } => {
                tensor::sample_top_p(logits, p, temperature, rng.next_f32()) as u32
            }
        };
        meter.tick();
        pos += 1;
        cur = next;
        ids.push(next);
        generated.push(next);
        if stop_at_eos && next == EOS_ID {
            break;
        }
    }
    let (p50, p99) = meter.p50_p99();
    Ok(GenOutput {
        ids,
        generated,
        tok_per_s: meter.tok_per_s(),
        latency_p50_s: p50,
        latency_p99_s: p99,
        profile: prof,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::forward::CpuEngine;
    use crate::model::{FloatModel, LlamaConfig, QuantModel};
    use crate::ps::ScalarGqmv;

    fn tiny_engine(seed: u64) -> CpuEngine {
        let cfg = LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        };
        CpuEngine::new(
            QuantModel::from_float(&FloatModel::random(cfg, seed)),
            Box::new(ScalarGqmv),
        )
    }

    #[test]
    fn greedy_is_deterministic() {
        let mut e1 = tiny_engine(1);
        let mut e2 = tiny_engine(1);
        let p = [1u32, 10, 11];
        let a = generate(&mut e1, &p, 8, Sampler::Greedy, false).unwrap();
        let b = generate(&mut e2, &p, 8, Sampler::Greedy, false).unwrap();
        assert_eq!(a.ids, b.ids);
        assert_eq!(a.generated.len(), 8);
        assert!(a.tok_per_s > 0.0);
    }

    #[test]
    fn top_p_seeded_deterministic() {
        let mut e1 = tiny_engine(2);
        let mut e2 = tiny_engine(2);
        let s = Sampler::TopP { p: 0.9, temperature: 1.0, seed: 7 };
        let p = [1u32, 5];
        let a = generate(&mut e1, &p, 6, s, false).unwrap();
        let b = generate(&mut e2, &p, 6, s, false).unwrap();
        assert_eq!(a.ids, b.ids);
    }

    #[test]
    fn context_overflow_rejected() {
        let mut e = tiny_engine(3);
        let p = [1u32; 10];
        assert!(generate(&mut e, &p, 30, Sampler::Greedy, false).is_err());
    }

    #[test]
    fn empty_prompt_rejected() {
        let mut e = tiny_engine(4);
        assert!(generate(&mut e, &[], 4, Sampler::Greedy, false).is_err());
    }

    #[test]
    fn profile_accumulates_across_generation() {
        let mut e = tiny_engine(5);
        let out = generate(&mut e, &[1, 2, 3], 5, Sampler::Greedy, false).unwrap();
        assert!(out.profile.matrix_s > 0.0);
        assert!(out.profile.total() > 0.0);
    }
}
