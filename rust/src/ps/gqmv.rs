//! CPU implementations of Algorithm 1 (GQMV) — the PS baseline.
//!
//! Both implementations keep the paper's exact cast chain
//! (INT8→INT16 products, INT32 group sums, FP32 scaled accumulation in
//! ascending group order), so they are bit-exact with the Pallas kernel,
//! the numpy oracle and the dataflow simulator.

use anyhow::Result;
use std::sync::Arc;

use crate::quant::QuantizedTensor;
use crate::util::ThreadPool;

/// A GQMV execution backend.  `xq`/`xs` are the run-time-quantized
/// activation; `w` the streamed weight matrix; `out` receives f32 rows.
pub trait GqmvExec {
    /// Multiply `w` by one quantized activation vector (Algorithm 1).
    fn gqmv(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) -> Result<()>;

    /// Multiply `w` by `batch` quantized activation vectors at once — the
    /// batched-decoding hot path that amortizes one weight traversal over
    /// a whole step.  Layouts are row-major and contiguous: `xq` is
    /// `batch × w.cols`, `xs` is `batch × groups_per_row`, `out` is
    /// `batch × w.rows`.
    ///
    /// Every output element must be produced by the exact Algorithm-1
    /// cast chain of [`gqmv_row`], so results are **bit-identical** to
    /// `batch` separate [`GqmvExec::gqmv`] calls regardless of backend or
    /// loop order.  The default implementation is that per-vector loop;
    /// backends override it to reuse each streamed weight row across the
    /// batch (one DDR fetch of the row serves all `batch` MAC chains).
    fn gqmv_batch(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        w: &QuantizedTensor,
        out: &mut [f32],
        batch: usize,
    ) -> Result<()> {
        check_shapes_batch(xq, xs, w, out, batch)?;
        let (rows, cols, gpr) = (w.rows, w.cols, w.groups_per_row());
        for b in 0..batch {
            self.gqmv(
                &xq[b * cols..(b + 1) * cols],
                &xs[b * gpr..(b + 1) * gpr],
                w,
                &mut out[b * rows..(b + 1) * rows],
            )?;
        }
        Ok(())
    }

    /// Stable backend identifier (Table VI rows, serving banner).
    fn name(&self) -> &'static str;
}

/// One output row of Algorithm 1.
#[inline]
pub fn gqmv_row(xq: &[i8], xs: &[f32], wq_row: &[i8], ws_row: &[f32], gs: usize) -> f32 {
    let groups = xq.len() / gs;
    let mut sum = 0.0f32;
    for g in 0..groups {
        let base = g * gs;
        // Iterator form lets LLVM drop the bounds checks and auto-vectorize
        // the widening multiply-accumulate.  The i16 intermediate product
        // is exact (|q| <= 127 so |p| <= 16129) and mirrors the hardware's
        // INT16 product lane (§IV-C).  Perf iterations (indexed loop,
        // 4-wide manual unroll, i32 products) are logged in
        // EXPERIMENTS.md §Perf; this variant won.
        let group_sum: i32 = wq_row[base..base + gs]
            .iter()
            .zip(&xq[base..base + gs])
            .map(|(&w, &x)| ((w as i16) * (x as i16)) as i32)
            .sum();
        // float_scale (= ws ⊙ xs) is computed FIRST, then applied to the
        // group sum — the accumulate-stage order of the hardware (§IV-D).
        // Every backend uses this exact association so results are
        // bit-identical across scalar/threaded/dataflow/Pallas paths.
        sum += group_sum as f32 * (ws_row[g] * xs[g]);
    }
    sum
}

/// Single-threaded reference implementation.
#[derive(Default)]
pub struct ScalarGqmv;

impl GqmvExec for ScalarGqmv {
    fn gqmv(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) -> Result<()> {
        check_shapes(xq, xs, w, out)?;
        let gpr = w.groups_per_row();
        for i in 0..w.rows {
            out[i] = gqmv_row(
                xq,
                xs,
                &w.q[i * w.cols..(i + 1) * w.cols],
                &w.s[i * gpr..(i + 1) * gpr],
                w.gs,
            );
        }
        Ok(())
    }

    fn gqmv_batch(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        w: &QuantizedTensor,
        out: &mut [f32],
        batch: usize,
    ) -> Result<()> {
        check_shapes_batch(xq, xs, w, out, batch)?;
        let gpr = w.groups_per_row();
        // Row-outer / batch-inner: each weight row is read from memory once
        // and applied to every activation vector while hot — the CPU mirror
        // of staging a weight row once per batched step (§III-B at B > 1).
        for i in 0..w.rows {
            let wq_row = &w.q[i * w.cols..(i + 1) * w.cols];
            let ws_row = &w.s[i * gpr..(i + 1) * gpr];
            for b in 0..batch {
                out[b * w.rows + i] = gqmv_row(
                    &xq[b * w.cols..(b + 1) * w.cols],
                    &xs[b * gpr..(b + 1) * gpr],
                    wq_row,
                    ws_row,
                    w.gs,
                );
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "ps-scalar"
    }
}

/// Row-parallel implementation — the OpenMP `parallel for` analogue.
/// The paper's PS baseline uses all four A53 cores; pool size is the knob.
pub struct ThreadedGqmv {
    pool: Arc<ThreadPool>,
    /// Matrices below this many MACs run on the calling thread: dispatching
    /// the pool costs ~30 us, which scalar GQMV beats on anything under
    /// ~1 MMAC (every nano-model matrix).  Measured in EXPERIMENTS.md §Perf.
    pub min_parallel_macs: usize,
}

impl ThreadedGqmv {
    pub fn new(pool: Arc<ThreadPool>) -> Self {
        ThreadedGqmv { pool, min_parallel_macs: 1 << 20 }
    }
}

impl GqmvExec for ThreadedGqmv {
    fn gqmv(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) -> Result<()> {
        check_shapes(xq, xs, w, out)?;
        let gpr = w.groups_per_row();
        let serial_below = if w.rows * w.cols < self.min_parallel_macs { w.rows + 1 } else { 0 };
        let out_ptr = SendMutPtr(out.as_mut_ptr());
        self.pool.parallel_for(w.rows, serial_below, |range| {
            let p = &out_ptr;
            for i in range {
                let v = gqmv_row(
                    xq,
                    xs,
                    &w.q[i * w.cols..(i + 1) * w.cols],
                    &w.s[i * gpr..(i + 1) * gpr],
                    w.gs,
                );
                // SAFETY: each row index i is visited by exactly one chunk.
                unsafe { *p.0.add(i) = v };
            }
        });
        Ok(())
    }

    fn gqmv_batch(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        w: &QuantizedTensor,
        out: &mut [f32],
        batch: usize,
    ) -> Result<()> {
        check_shapes_batch(xq, xs, w, out, batch)?;
        let gpr = w.groups_per_row();
        let macs = batch * w.rows * w.cols;
        let serial_below = if macs < self.min_parallel_macs { w.rows + 1 } else { 0 };
        let out_ptr = SendMutPtr(out.as_mut_ptr());
        self.pool.parallel_for(w.rows, serial_below, |range| {
            let p = &out_ptr;
            for i in range {
                let wq_row = &w.q[i * w.cols..(i + 1) * w.cols];
                let ws_row = &w.s[i * gpr..(i + 1) * gpr];
                for b in 0..batch {
                    let v = gqmv_row(
                        &xq[b * w.cols..(b + 1) * w.cols],
                        &xs[b * gpr..(b + 1) * gpr],
                        wq_row,
                        ws_row,
                        w.gs,
                    );
                    // SAFETY: row ranges are disjoint per chunk, so every
                    // (b, i) output index is written by exactly one worker.
                    unsafe { *p.0.add(b * w.rows + i) = v };
                }
            }
        });
        Ok(())
    }

    fn name(&self) -> &'static str {
        "ps-threaded"
    }
}

struct SendMutPtr(*mut f32);
unsafe impl Sync for SendMutPtr {}

pub(crate) fn check_shapes(
    xq: &[i8],
    xs: &[f32],
    w: &QuantizedTensor,
    out: &mut [f32],
) -> Result<()> {
    if xq.len() != w.cols {
        anyhow::bail!("xq len {} != cols {}", xq.len(), w.cols);
    }
    if xs.len() != w.cols / w.gs {
        anyhow::bail!("xs len {} != groups {}", xs.len(), w.cols / w.gs);
    }
    if out.len() != w.rows {
        anyhow::bail!("out len {} != rows {}", out.len(), w.rows);
    }
    Ok(())
}

pub(crate) fn check_shapes_batch(
    xq: &[i8],
    xs: &[f32],
    w: &QuantizedTensor,
    out: &[f32],
    batch: usize,
) -> Result<()> {
    if batch == 0 {
        anyhow::bail!("batch must be >= 1");
    }
    if xq.len() != batch * w.cols {
        anyhow::bail!("xq len {} != batch {batch} x cols {}", xq.len(), w.cols);
    }
    if xs.len() != batch * (w.cols / w.gs) {
        anyhow::bail!("xs len {} != batch {batch} x groups {}", xs.len(), w.cols / w.gs);
    }
    if out.len() != batch * w.rows {
        anyhow::bail!("out len {} != batch {batch} x rows {}", out.len(), w.rows);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_activation;
    use crate::util::Rng;

    fn random_case(
        m: usize,
        n: usize,
        gs: usize,
        seed: u64,
    ) -> (Vec<i8>, Vec<f32>, QuantizedTensor) {
        let mut rng = Rng::new(seed);
        let w = rng.normal_vec(m * n, 0.5);
        let x = rng.normal_vec(n, 1.0);
        let wt = QuantizedTensor::from_f32(&w, m, n, gs);
        let (xq, xs) = quantize_activation(&x, gs);
        (xq, xs, wt)
    }

    #[test]
    fn scalar_matches_manual_small() {
        // 1 row, 1 group of 4, hand-computed
        let w = QuantizedTensor {
            q: vec![1, -2, 3, 4],
            s: vec![0.5],
            rows: 1,
            cols: 4,
            gs: 4,
        };
        let xq = vec![10i8, 20, -30, 40];
        let xs = vec![0.1f32];
        let mut out = vec![0.0];
        ScalarGqmv.gqmv(&xq, &xs, &w, &mut out).unwrap();
        // group_sum = 10 - 40 - 90 + 160 = 40; 40 * 0.5 * 0.1 = 2.0
        assert!((out[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn threaded_matches_scalar() {
        let pool = Arc::new(ThreadPool::new(4));
        for (m, n, gs) in [(8, 256, 256), (512, 256, 256), (256, 768, 256), (40, 512, 128)] {
            let (xq, xs, w) = random_case(m, n, gs, (m + n) as u64);
            let mut a = vec![0.0; m];
            let mut b = vec![0.0; m];
            ScalarGqmv.gqmv(&xq, &xs, &w, &mut a).unwrap();
            let mut th = ThreadedGqmv::new(pool.clone());
            th.min_parallel_macs = 0; // force threading
            th.gqmv(&xq, &xs, &w, &mut b).unwrap();
            assert_eq!(a, b, "m={m} n={n} gs={gs}");
        }
    }

    #[test]
    fn extreme_values_no_overflow() {
        // per-group i32 sum may reach 256 * 16129 ~ 4.1e6, far below i32 max
        let gs = 256;
        let n = 2048;
        let w = QuantizedTensor {
            q: vec![127i8; n],
            s: vec![0.01; n / gs],
            rows: 1,
            cols: n,
            gs,
        };
        let xq = vec![127i8; n];
        let xs = vec![0.02f32; n / gs];
        let mut out = vec![0.0];
        ScalarGqmv.gqmv(&xq, &xs, &w, &mut out).unwrap();
        let expect = 127.0 * 127.0 * n as f32 * 0.01 * 0.02;
        assert!((out[0] - expect).abs() / expect < 1e-5);
    }

    fn random_batch(
        m: usize,
        n: usize,
        gs: usize,
        batch: usize,
        seed: u64,
    ) -> (Vec<i8>, Vec<f32>, QuantizedTensor) {
        let mut rng = Rng::new(seed);
        let w = QuantizedTensor::from_f32(&rng.normal_vec(m * n, 0.5), m, n, gs);
        let mut xq = Vec::with_capacity(batch * n);
        let mut xs = Vec::with_capacity(batch * n / gs);
        for _ in 0..batch {
            let (q, s) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
            xq.extend(q);
            xs.extend(s);
        }
        (xq, xs, w)
    }

    /// Reference: `batch` independent per-vector calls.
    fn per_vector(xq: &[i8], xs: &[f32], w: &QuantizedTensor, batch: usize) -> Vec<f32> {
        let gpr = w.groups_per_row();
        let mut out = vec![0.0; batch * w.rows];
        for b in 0..batch {
            ScalarGqmv
                .gqmv(
                    &xq[b * w.cols..(b + 1) * w.cols],
                    &xs[b * gpr..(b + 1) * gpr],
                    w,
                    &mut out[b * w.rows..(b + 1) * w.rows],
                )
                .unwrap();
        }
        out
    }

    #[test]
    fn scalar_batch_bit_identical_to_per_vector() {
        for batch in [1usize, 2, 4, 8] {
            let (xq, xs, w) = random_batch(40, 512, 128, batch, batch as u64);
            let want = per_vector(&xq, &xs, &w, batch);
            let mut got = vec![0.0; batch * w.rows];
            ScalarGqmv.gqmv_batch(&xq, &xs, &w, &mut got, batch).unwrap();
            assert_eq!(got, want, "batch={batch}");
        }
    }

    #[test]
    fn threaded_batch_bit_identical_to_per_vector() {
        let pool = Arc::new(ThreadPool::new(4));
        for batch in [2usize, 4, 8] {
            let (xq, xs, w) = random_batch(64, 256, 256, batch, 100 + batch as u64);
            let want = per_vector(&xq, &xs, &w, batch);
            let mut th = ThreadedGqmv::new(pool.clone());
            th.min_parallel_macs = 0; // force threading
            let mut got = vec![0.0; batch * w.rows];
            th.gqmv_batch(&xq, &xs, &w, &mut got, batch).unwrap();
            assert_eq!(got, want, "batch={batch}");
        }
    }

    #[test]
    fn default_batch_impl_bit_identical() {
        // a backend without an override (the dataflow sim) goes through the
        // trait's default per-vector loop
        let mut sim = crate::fpga::DataflowSim::new(crate::fpga::PlConfig::default());
        let (xq, xs, w) = random_batch(16, 256, 256, 3, 7);
        let want = per_vector(&xq, &xs, &w, 3);
        let mut got = vec![0.0; 3 * w.rows];
        sim.gqmv_batch(&xq, &xs, &w, &mut got, 3).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn batch_shape_mismatches_rejected() {
        let (xq, xs, w) = random_batch(8, 256, 256, 2, 9);
        let mut out = vec![0.0; 2 * 8];
        assert!(ScalarGqmv.gqmv_batch(&xq, &xs, &w, &mut out, 0).is_err());
        assert!(ScalarGqmv.gqmv_batch(&xq[..256], &xs, &w, &mut out, 2).is_err());
        assert!(ScalarGqmv.gqmv_batch(&xq, &xs[..1], &w, &mut out, 2).is_err());
        let mut short = vec![0.0; 8];
        assert!(ScalarGqmv.gqmv_batch(&xq, &xs, &w, &mut short, 2).is_err());
    }

    #[test]
    fn shape_mismatches_rejected() {
        let (xq, xs, w) = random_case(8, 256, 256, 1);
        let mut out = vec![0.0; 8];
        assert!(ScalarGqmv.gqmv(&xq[..128], &xs, &w, &mut out).is_err());
        assert!(ScalarGqmv.gqmv(&xq, &xs[..0], &w, &mut out).is_err());
        let mut short = vec![0.0; 4];
        assert!(ScalarGqmv.gqmv(&xq, &xs, &w, &mut short).is_err());
    }

    #[test]
    fn matches_golden_fixture_if_present() {
        // artifacts/golden_gqmv_*.bin are written by python aot.py from the
        // numpy oracle; when built, verify bit-level agreement.
        let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let paths = ["xq", "xs", "wq", "ws", "out"]
            .map(|n| art.join(format!("golden_gqmv_{n}.bin")));
        if !paths.iter().all(|p| p.exists()) {
            eprintln!("skipping golden fixture test (artifacts not built)");
            return;
        }
        let read_i8 = |p: &std::path::Path| -> Vec<i8> {
            std::fs::read(p).unwrap().into_iter().map(|b| b as i8).collect()
        };
        let read_f32 = |p: &std::path::Path| -> Vec<f32> {
            std::fs::read(p)
                .unwrap()
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect()
        };
        let xq = read_i8(&paths[0]);
        let xs = read_f32(&paths[1]);
        let wq = read_i8(&paths[2]);
        let ws = read_f32(&paths[3]);
        let expect = read_f32(&paths[4]);
        let (m, gs) = (expect.len(), 256);
        let n = wq.len() / m;
        let w = QuantizedTensor { q: wq, s: ws, rows: m, cols: n, gs };
        let mut out = vec![0.0; m];
        ScalarGqmv.gqmv(&xq, &xs, &w, &mut out).unwrap();
        for i in 0..m {
            assert!(
                (out[i] - expect[i]).abs() <= 1e-5 + expect[i].abs() * 1e-6,
                "row {i}: {} vs {}",
                out[i],
                expect[i]
            );
        }
    }
}
