//! CPU implementations of Algorithm 1 (GQMV) — the PS baseline.
//!
//! Both implementations keep the paper's exact cast chain
//! (INT8→INT16 products, INT32 group sums, FP32 scaled accumulation in
//! ascending group order), so they are bit-exact with the Pallas kernel,
//! the numpy oracle and the dataflow simulator.
//!
//! Two dispatch-efficiency layers sit on top of the per-row math:
//!
//! * **Row blocking** ([`gqmv_rows`]) — workers process contiguous
//!   [`ROW_BLOCK`]-row cache blocks (group-outer / row-inner) instead of
//!   striding rows, reusing each activation group from L1 across the
//!   block.
//! * **Fused dispatch** ([`GqmvExec::gqmv_fused`]) — matrices that share
//!   one input (Wq/Wk/Wv, W1/W3) run as a single quantization + a single
//!   backend dispatch over their stacked row space, the dispatch-time
//!   twin of the storage-time `QuantizedTensor::concat_rows` fusion.
//!
//! Both are bit-identical to the strided per-matrix path by construction
//! (every output row goes through [`gqmv_row`]'s cast chain), pinned by
//! unit tests.
//!
//! Threading note: the threaded backend pre-splits its outputs into
//! disjoint per-worker slices (`split_at_mut`) and hands them to
//! [`crate::util::ThreadPool::run_parts`] — there is no shared-pointer
//! `unsafe` in this module.

use anyhow::Result;
use std::sync::Arc;

use crate::quant::{PackedTensor, QuantizedTensor};
use crate::util::ThreadPool;

/// A GQMV execution backend.  `xq`/`xs` are the run-time-quantized
/// activation; `w` the streamed weight matrix; `out` receives f32 rows.
pub trait GqmvExec {
    /// Multiply `w` by one quantized activation vector (Algorithm 1).
    fn gqmv(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) -> Result<()>;

    /// Multiply `w` by `batch` quantized activation vectors at once — the
    /// batched-decoding hot path that amortizes one weight traversal over
    /// a whole step.  Layouts are row-major and contiguous: `xq` is
    /// `batch × w.cols`, `xs` is `batch × groups_per_row`, `out` is
    /// `batch × w.rows`.
    ///
    /// Every output element must be produced by the exact Algorithm-1
    /// cast chain of [`gqmv_row`], so results are **bit-identical** to
    /// `batch` separate [`GqmvExec::gqmv`] calls regardless of backend or
    /// loop order.  The default implementation is that per-vector loop;
    /// backends override it to reuse each streamed weight row across the
    /// batch (one DDR fetch of the row serves all `batch` MAC chains).
    fn gqmv_batch(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        w: &QuantizedTensor,
        out: &mut [f32],
        batch: usize,
    ) -> Result<()> {
        check_shapes_batch(xq, xs, w, out, batch)?;
        let (rows, cols, gpr) = (w.rows, w.cols, w.groups_per_row());
        for b in 0..batch {
            self.gqmv(
                &xq[b * cols..(b + 1) * cols],
                &xs[b * gpr..(b + 1) * gpr],
                w,
                &mut out[b * rows..(b + 1) * rows],
            )?;
        }
        Ok(())
    }

    /// Multiply several weight matrices by **one** quantized activation
    /// vector in a single fused dispatch (the Wq/Wk/Wv and W1/W3 pattern
    /// of Algorithm 2: matrices that consume the same input share one
    /// activation quantization and one backend dispatch, cutting the
    /// per-layer launch count from 7 to 4).
    ///
    /// `ws[i]` must all have the same `cols`/`gs` (they read the same
    /// `xq`/`xs`); `outs[i]` receives `ws[i].rows` f32 results.  Every
    /// output row must come from the exact [`gqmv_row`] cast chain, so
    /// results are **bit-identical** to `ws.len()` separate
    /// [`GqmvExec::gqmv`] calls — and, because group-quantized rows are
    /// independent, also to one `gqmv` over the row-wise concatenation of
    /// `ws` (see `QuantizedTensor::concat_rows`).  The default is the
    /// per-matrix loop; backends override it to issue one combined
    /// dispatch over the stacked row space.
    ///
    /// Backends may leave the `outs` *slice handles* empty after the call
    /// (disjoint-split dispatch consumes them); the underlying buffers
    /// are always fully written.  Build the slice list per call.
    fn gqmv_fused(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        ws: &[&QuantizedTensor],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        check_shapes_fused(xq, xs, ws, outs)?;
        for (w, out) in ws.iter().zip(outs.iter_mut()) {
            self.gqmv(xq, xs, w, out)?;
        }
        Ok(())
    }

    /// Batched analogue of [`GqmvExec::gqmv_fused`]: `batch` activation
    /// vectors against every matrix of the fused group.  Layouts follow
    /// [`GqmvExec::gqmv_batch`] per matrix (`outs[i]` is
    /// `batch × ws[i].rows`, packed).  Bit-identical to per-matrix
    /// `gqmv_batch` calls by the same row-independence argument.
    fn gqmv_fused_batch(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        ws: &[&QuantizedTensor],
        outs: &mut [&mut [f32]],
        batch: usize,
    ) -> Result<()> {
        check_shapes_fused_batch(xq, xs, ws, outs, batch)?;
        for (w, out) in ws.iter().zip(outs.iter_mut()) {
            self.gqmv_batch(xq, xs, w, out, batch)?;
        }
        Ok(())
    }

    /// Multiply a **packed** weight tensor by one quantized activation
    /// vector, running the format's packed row kernel
    /// ([`crate::quant::QuantFormat::gqmv_rows_packed`]) directly over
    /// the wire bytes — no unpacked staging copy.  Bit-identical to
    /// unpacking `w` and calling [`GqmvExec::gqmv`]: the packed kernels
    /// replay the same blocked loop nest and cast chain.  The default
    /// runs single-threaded; backends override to parallelize rows.
    fn gqmv_packed(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        w: &PackedTensor,
        out: &mut [f32],
    ) -> Result<()> {
        check_shapes_packed(xq, xs, w, out)?;
        w.fmt.format().gqmv_rows_packed(xq, xs, w, 0, out);
        Ok(())
    }

    /// Stable backend identifier (Table VI rows, serving banner).
    fn name(&self) -> &'static str;
}

/// One output row of Algorithm 1.
#[inline]
pub fn gqmv_row(xq: &[i8], xs: &[f32], wq_row: &[i8], ws_row: &[f32], gs: usize) -> f32 {
    let groups = xq.len() / gs;
    let mut sum = 0.0f32;
    for g in 0..groups {
        let base = g * gs;
        // Iterator form lets LLVM drop the bounds checks and auto-vectorize
        // the widening multiply-accumulate.  The i16 intermediate product
        // is exact (|q| <= 127 so |p| <= 16129) and mirrors the hardware's
        // INT16 product lane (§IV-C).  Perf iterations (indexed loop,
        // 4-wide manual unroll, i32 products) are logged in
        // EXPERIMENTS.md §Perf; this variant won.
        let group_sum: i32 = wq_row[base..base + gs]
            .iter()
            .zip(&xq[base..base + gs])
            .map(|(&w, &x)| ((w as i16) * (x as i16)) as i32)
            .sum();
        // float_scale (= ws ⊙ xs) is computed FIRST, then applied to the
        // group sum — the accumulate-stage order of the hardware (§IV-D).
        // Every backend uses this exact association so results are
        // bit-identical across scalar/threaded/dataflow/Pallas paths.
        sum += group_sum as f32 * (ws_row[g] * xs[g]);
    }
    sum
}

/// Rows per cache block of [`gqmv_rows`].  Eight rows keep the block's
/// accumulators in registers while one `gs`-sized activation group (256 B
/// at the paper's g=256) is reused from L1 across all of them.
pub const ROW_BLOCK: usize = 8;

/// A contiguous block of output rows of Algorithm 1, cache-blocked.
///
/// Computes `out.len()` consecutive rows whose weights start at
/// `wq_rows`/`ws_rows` (row-major, `xq.len()` columns).  The loop nest is
/// group-outer / row-inner in blocks of [`ROW_BLOCK`]: each activation
/// group is loaded once and multiplied against up to eight weight rows
/// while hot, instead of being re-fetched per row as the strided per-row
/// loop does.  Per row, partial sums still accumulate in ascending group
/// order through the identical cast chain, so every output is
/// **bit-identical** to [`gqmv_row`] on that row (pinned by
/// `blocked_rows_bit_identical_to_per_row`).
pub fn gqmv_rows(
    xq: &[i8],
    xs: &[f32],
    wq_rows: &[i8],
    ws_rows: &[f32],
    gs: usize,
    out: &mut [f32],
) {
    let cols = xq.len();
    let groups = cols / gs;
    let rows = out.len();
    debug_assert_eq!(wq_rows.len(), rows * cols);
    debug_assert_eq!(ws_rows.len(), rows * groups);
    let mut r0 = 0;
    while r0 < rows {
        let rb = ROW_BLOCK.min(rows - r0);
        let mut acc = [0.0f32; ROW_BLOCK];
        for g in 0..groups {
            let base = g * gs;
            let xg = &xq[base..base + gs];
            let xscale = xs[g];
            for (r, a) in acc.iter_mut().enumerate().take(rb) {
                let row = r0 + r;
                let wbase = row * cols + base;
                let group_sum: i32 = wq_rows[wbase..wbase + gs]
                    .iter()
                    .zip(xg)
                    .map(|(&w, &x)| ((w as i16) * (x as i16)) as i32)
                    .sum();
                *a += group_sum as f32 * (ws_rows[row * groups + g] * xscale);
            }
        }
        out[r0..r0 + rb].copy_from_slice(&acc[..rb]);
        r0 += rb;
    }
}

/// Single-threaded reference implementation.
#[derive(Default)]
pub struct ScalarGqmv;

impl GqmvExec for ScalarGqmv {
    fn gqmv(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) -> Result<()> {
        check_shapes(xq, xs, w, out)?;
        gqmv_rows(xq, xs, &w.q, &w.s, w.gs, out);
        Ok(())
    }

    fn gqmv_batch(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        w: &QuantizedTensor,
        out: &mut [f32],
        batch: usize,
    ) -> Result<()> {
        check_shapes_batch(xq, xs, w, out, batch)?;
        let gpr = w.groups_per_row();
        // Row-outer / batch-inner: each weight row is read from memory once
        // and applied to every activation vector while hot — the CPU mirror
        // of staging a weight row once per batched step (§III-B at B > 1).
        for i in 0..w.rows {
            let wq_row = &w.q[i * w.cols..(i + 1) * w.cols];
            let ws_row = &w.s[i * gpr..(i + 1) * gpr];
            for b in 0..batch {
                out[b * w.rows + i] = gqmv_row(
                    &xq[b * w.cols..(b + 1) * w.cols],
                    &xs[b * gpr..(b + 1) * gpr],
                    wq_row,
                    ws_row,
                    w.gs,
                );
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "ps-scalar"
    }
}

/// Row-parallel implementation — the OpenMP `parallel for` analogue.
/// The paper's PS baseline uses all four A53 cores; pool size is the knob.
pub struct ThreadedGqmv {
    pool: Arc<ThreadPool>,
    /// Matrices below this many MACs run on the calling thread: dispatching
    /// the pool costs ~30 us, which scalar GQMV beats on anything under
    /// ~1 MMAC (every nano-model matrix).  Measured in EXPERIMENTS.md §Perf.
    pub min_parallel_macs: usize,
}

impl ThreadedGqmv {
    pub fn new(pool: Arc<ThreadPool>) -> Self {
        ThreadedGqmv { pool, min_parallel_macs: 1 << 20 }
    }
}

/// Split `out` into contiguous `(start_row, chunk)` pieces of at most
/// `chunk` rows — the safe disjoint-slice partition handed to
/// [`crate::util::ThreadPool::run_parts`] (no two workers can alias).
fn split_rows(out: &mut [f32], chunk: usize) -> Vec<(usize, &mut [f32])> {
    let mut parts = Vec::with_capacity(out.len().div_ceil(chunk.max(1)));
    let mut rest = out;
    let mut row0 = 0;
    while !rest.is_empty() {
        let take = chunk.min(rest.len());
        let (head, tail) = rest.split_at_mut(take);
        parts.push((row0, head));
        row0 += take;
        rest = tail;
    }
    parts
}

impl GqmvExec for ThreadedGqmv {
    fn gqmv(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) -> Result<()> {
        check_shapes(xq, xs, w, out)?;
        let gpr = w.groups_per_row();
        if w.rows * w.cols < self.min_parallel_macs {
            gqmv_rows(xq, xs, &w.q, &w.s, w.gs, out);
            return Ok(());
        }
        // One contiguous row block per worker wakeup: each part owns its
        // disjoint output slice (safe split, no shared mutable state) and
        // runs the cache-blocked kernel over its rows.
        let k = self.pool.workers().min(w.rows).max(1);
        let parts = split_rows(out, w.rows.div_ceil(k));
        self.pool.run_parts(parts, |(row0, chunk)| {
            let rows = chunk.len();
            gqmv_rows(
                xq,
                xs,
                &w.q[row0 * w.cols..(row0 + rows) * w.cols],
                &w.s[row0 * gpr..(row0 + rows) * gpr],
                w.gs,
                chunk,
            );
        });
        Ok(())
    }

    fn gqmv_batch(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        w: &QuantizedTensor,
        out: &mut [f32],
        batch: usize,
    ) -> Result<()> {
        check_shapes_batch(xq, xs, w, out, batch)?;
        let gpr = w.groups_per_row();
        if batch * w.rows * w.cols < self.min_parallel_macs {
            batch_rows(xq, xs, w, 0, &mut split_lanes_full(out, w.rows), batch);
            return Ok(());
        }
        // Row-parallel with the row-outer/batch-inner reuse of the scalar
        // backend: split EVERY lane's output at the same row boundaries so
        // each worker owns one disjoint sub-slice per lane.
        let k = self.pool.workers().min(w.rows).max(1);
        let chunk = w.rows.div_ceil(k);
        let mut lane_rests: Vec<&mut [f32]> = out.chunks_mut(w.rows).collect();
        let mut parts: Vec<(usize, Vec<&mut [f32]>)> = Vec::with_capacity(k);
        let mut row0 = 0;
        while row0 < w.rows {
            let take = chunk.min(w.rows - row0);
            let mut lanes = Vec::with_capacity(batch);
            for rest in lane_rests.iter_mut() {
                let slice = std::mem::take(rest);
                let (head, tail) = slice.split_at_mut(take);
                lanes.push(head);
                *rest = tail;
            }
            parts.push((row0, lanes));
            row0 += take;
        }
        self.pool.run_parts(parts, |(row0, mut lanes)| {
            batch_rows(xq, xs, w, row0, &mut lanes, batch);
        });
        Ok(())
    }

    fn gqmv_fused(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        ws: &[&QuantizedTensor],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        check_shapes_fused(xq, xs, ws, outs)?;
        let cols = xq.len();
        let total_rows: usize = ws.iter().map(|w| w.rows).sum();
        if total_rows * cols < self.min_parallel_macs {
            for (w, out) in ws.iter().zip(outs.iter_mut()) {
                gqmv_rows(xq, xs, &w.q, &w.s, w.gs, out);
            }
            return Ok(());
        }
        // ONE pooled dispatch over the virtual concatenation of every
        // matrix's rows: chunk the stacked row space, splitting each
        // output at the chunk boundaries, so a group of same-input
        // matrices costs a single wakeup instead of one per matrix.
        struct Seg<'a> {
            w: &'a QuantizedTensor,
            row0: usize,
            out: &'a mut [f32],
        }
        let k = self.pool.workers().min(total_rows).max(1);
        let chunk = total_rows.div_ceil(k).max(1);
        let mut parts: Vec<Vec<Seg>> = Vec::with_capacity(k);
        let mut cur: Vec<Seg> = Vec::new();
        let mut room = chunk;
        for (w, out) in ws.iter().copied().zip(outs.iter_mut()) {
            let mut rest: &mut [f32] = std::mem::take(out);
            let mut row0 = 0;
            while !rest.is_empty() {
                let take = room.min(rest.len());
                let (head, tail) = rest.split_at_mut(take);
                cur.push(Seg { w, row0, out: head });
                rest = tail;
                row0 += take;
                room -= take;
                if room == 0 {
                    parts.push(std::mem::take(&mut cur));
                    room = chunk;
                }
            }
        }
        if !cur.is_empty() {
            parts.push(cur);
        }
        self.pool.run_parts(parts, |segs| {
            for Seg { w, row0, out } in segs {
                let gpr = w.groups_per_row();
                let rows = out.len();
                gqmv_rows(
                    xq,
                    xs,
                    &w.q[row0 * w.cols..(row0 + rows) * w.cols],
                    &w.s[row0 * gpr..(row0 + rows) * gpr],
                    w.gs,
                    out,
                );
            }
        });
        Ok(())
    }

    fn gqmv_packed(
        &mut self,
        xq: &[i8],
        xs: &[f32],
        w: &PackedTensor,
        out: &mut [f32],
    ) -> Result<()> {
        check_shapes_packed(xq, xs, w, out)?;
        let f = w.fmt.format();
        if w.rows * w.cols < self.min_parallel_macs {
            f.gqmv_rows_packed(xq, xs, w, 0, out);
            return Ok(());
        }
        // Same disjoint row-block split as the unpacked path; each part
        // runs the packed kernel from its own row0 over the shared bytes.
        let k = self.pool.workers().min(w.rows).max(1);
        let parts = split_rows(out, w.rows.div_ceil(k));
        self.pool.run_parts(parts, |(row0, chunk)| {
            f.gqmv_rows_packed(xq, xs, w, row0, chunk);
        });
        Ok(())
    }

    fn name(&self) -> &'static str {
        "ps-threaded"
    }
}

/// Row-outer / batch-inner kernel over one row block: `lanes[b]` receives
/// rows `row0..row0 + lanes[b].len()` of lane `b`'s output.  Each weight
/// row is read once and applied to every activation vector while hot.
fn batch_rows(
    xq: &[i8],
    xs: &[f32],
    w: &QuantizedTensor,
    row0: usize,
    lanes: &mut [&mut [f32]],
    batch: usize,
) {
    let gpr = w.groups_per_row();
    let rows = lanes.first().map_or(0, |l| l.len());
    for i in 0..rows {
        let wq_row = &w.q[(row0 + i) * w.cols..(row0 + i + 1) * w.cols];
        let ws_row = &w.s[(row0 + i) * gpr..(row0 + i + 1) * gpr];
        for (b, lane) in lanes.iter_mut().enumerate().take(batch) {
            lane[i] = gqmv_row(
                &xq[b * w.cols..(b + 1) * w.cols],
                &xs[b * gpr..(b + 1) * gpr],
                wq_row,
                ws_row,
                w.gs,
            );
        }
    }
}

/// View a packed `batch × rows` output as one full-size slice per lane.
fn split_lanes_full(out: &mut [f32], rows: usize) -> Vec<&mut [f32]> {
    out.chunks_mut(rows).collect()
}

pub(crate) fn check_shapes(
    xq: &[i8],
    xs: &[f32],
    w: &QuantizedTensor,
    out: &mut [f32],
) -> Result<()> {
    if xq.len() != w.cols {
        anyhow::bail!("xq len {} != cols {}", xq.len(), w.cols);
    }
    if xs.len() != w.cols / w.gs {
        anyhow::bail!("xs len {} != groups {}", xs.len(), w.cols / w.gs);
    }
    if out.len() != w.rows {
        anyhow::bail!("out len {} != rows {}", out.len(), w.rows);
    }
    Ok(())
}

pub(crate) fn check_shapes_packed(
    xq: &[i8],
    xs: &[f32],
    w: &PackedTensor,
    out: &mut [f32],
) -> Result<()> {
    if xq.len() != w.cols {
        anyhow::bail!("xq len {} != cols {}", xq.len(), w.cols);
    }
    if xs.len() != w.cols / w.gs {
        anyhow::bail!("xs len {} != groups {}", xs.len(), w.cols / w.gs);
    }
    if out.len() != w.rows {
        anyhow::bail!("out len {} != rows {}", out.len(), w.rows);
    }
    Ok(())
}

pub(crate) fn check_shapes_batch(
    xq: &[i8],
    xs: &[f32],
    w: &QuantizedTensor,
    out: &[f32],
    batch: usize,
) -> Result<()> {
    if batch == 0 {
        anyhow::bail!("batch must be >= 1");
    }
    if xq.len() != batch * w.cols {
        anyhow::bail!("xq len {} != batch {batch} x cols {}", xq.len(), w.cols);
    }
    if xs.len() != batch * (w.cols / w.gs) {
        anyhow::bail!("xs len {} != batch {batch} x groups {}", xs.len(), w.cols / w.gs);
    }
    if out.len() != batch * w.rows {
        anyhow::bail!("out len {} != batch {batch} x rows {}", out.len(), w.rows);
    }
    Ok(())
}

pub(crate) fn check_shapes_fused(
    xq: &[i8],
    xs: &[f32],
    ws: &[&QuantizedTensor],
    outs: &[&mut [f32]],
) -> Result<()> {
    if ws.is_empty() {
        anyhow::bail!("fused group must contain at least one matrix");
    }
    if ws.len() != outs.len() {
        anyhow::bail!("{} matrices but {} outputs", ws.len(), outs.len());
    }
    let (cols, gs) = (ws[0].cols, ws[0].gs);
    if xq.len() != cols {
        anyhow::bail!("xq len {} != cols {cols}", xq.len());
    }
    if xs.len() != cols / gs {
        anyhow::bail!("xs len {} != groups {}", xs.len(), cols / gs);
    }
    for (i, w) in ws.iter().enumerate() {
        if w.cols != cols || w.gs != gs {
            anyhow::bail!("fused matrix {i} shape ({}, g{}) != ({cols}, g{gs})", w.cols, w.gs);
        }
        if outs[i].len() != w.rows {
            anyhow::bail!("out {i} len {} != rows {}", outs[i].len(), w.rows);
        }
    }
    Ok(())
}

pub(crate) fn check_shapes_fused_batch(
    xq: &[i8],
    xs: &[f32],
    ws: &[&QuantizedTensor],
    outs: &[&mut [f32]],
    batch: usize,
) -> Result<()> {
    if batch == 0 {
        anyhow::bail!("batch must be >= 1");
    }
    if ws.is_empty() {
        anyhow::bail!("fused group must contain at least one matrix");
    }
    if ws.len() != outs.len() {
        anyhow::bail!("{} matrices but {} outputs", ws.len(), outs.len());
    }
    let (cols, gs) = (ws[0].cols, ws[0].gs);
    if xq.len() != batch * cols {
        anyhow::bail!("xq len {} != batch {batch} x cols {cols}", xq.len());
    }
    if xs.len() != batch * (cols / gs) {
        anyhow::bail!("xs len {} != batch {batch} x groups {}", xs.len(), cols / gs);
    }
    for (i, w) in ws.iter().enumerate() {
        if w.cols != cols || w.gs != gs {
            anyhow::bail!("fused matrix {i} shape ({}, g{}) != ({cols}, g{gs})", w.cols, w.gs);
        }
        if outs[i].len() != batch * w.rows {
            anyhow::bail!("out {i} len {} != batch {batch} x rows {}", outs[i].len(), w.rows);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_activation;
    use crate::util::Rng;

    fn random_case(
        m: usize,
        n: usize,
        gs: usize,
        seed: u64,
    ) -> (Vec<i8>, Vec<f32>, QuantizedTensor) {
        let mut rng = Rng::new(seed);
        let w = rng.normal_vec(m * n, 0.5);
        let x = rng.normal_vec(n, 1.0);
        let wt = QuantizedTensor::from_f32(&w, m, n, gs);
        let (xq, xs) = quantize_activation(&x, gs);
        (xq, xs, wt)
    }

    #[test]
    fn scalar_matches_manual_small() {
        // 1 row, 1 group of 4, hand-computed
        let w = QuantizedTensor {
            q: vec![1, -2, 3, 4],
            s: vec![0.5],
            rows: 1,
            cols: 4,
            gs: 4,
            fmt: crate::quant::FormatId::Q8,
        };
        let xq = vec![10i8, 20, -30, 40];
        let xs = vec![0.1f32];
        let mut out = vec![0.0];
        ScalarGqmv.gqmv(&xq, &xs, &w, &mut out).unwrap();
        // group_sum = 10 - 40 - 90 + 160 = 40; 40 * 0.5 * 0.1 = 2.0
        assert!((out[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn threaded_matches_scalar() {
        let pool = Arc::new(ThreadPool::new(4));
        for (m, n, gs) in [(8, 256, 256), (512, 256, 256), (256, 768, 256), (40, 512, 128)] {
            let (xq, xs, w) = random_case(m, n, gs, (m + n) as u64);
            let mut a = vec![0.0; m];
            let mut b = vec![0.0; m];
            ScalarGqmv.gqmv(&xq, &xs, &w, &mut a).unwrap();
            let mut th = ThreadedGqmv::new(pool.clone());
            th.min_parallel_macs = 0; // force threading
            th.gqmv(&xq, &xs, &w, &mut b).unwrap();
            assert_eq!(a, b, "m={m} n={n} gs={gs}");
        }
    }

    #[test]
    fn extreme_values_no_overflow() {
        // per-group i32 sum may reach 256 * 16129 ~ 4.1e6, far below i32 max
        let gs = 256;
        let n = 2048;
        let w = QuantizedTensor {
            q: vec![127i8; n],
            s: vec![0.01; n / gs],
            rows: 1,
            cols: n,
            gs,
            fmt: crate::quant::FormatId::Q8,
        };
        let xq = vec![127i8; n];
        let xs = vec![0.02f32; n / gs];
        let mut out = vec![0.0];
        ScalarGqmv.gqmv(&xq, &xs, &w, &mut out).unwrap();
        let expect = 127.0 * 127.0 * n as f32 * 0.01 * 0.02;
        assert!((out[0] - expect).abs() / expect < 1e-5);
    }

    fn random_batch(
        m: usize,
        n: usize,
        gs: usize,
        batch: usize,
        seed: u64,
    ) -> (Vec<i8>, Vec<f32>, QuantizedTensor) {
        let mut rng = Rng::new(seed);
        let w = QuantizedTensor::from_f32(&rng.normal_vec(m * n, 0.5), m, n, gs);
        let mut xq = Vec::with_capacity(batch * n);
        let mut xs = Vec::with_capacity(batch * n / gs);
        for _ in 0..batch {
            let (q, s) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
            xq.extend(q);
            xs.extend(s);
        }
        (xq, xs, w)
    }

    /// Reference: `batch` independent per-vector calls.
    fn per_vector(xq: &[i8], xs: &[f32], w: &QuantizedTensor, batch: usize) -> Vec<f32> {
        let gpr = w.groups_per_row();
        let mut out = vec![0.0; batch * w.rows];
        for b in 0..batch {
            ScalarGqmv
                .gqmv(
                    &xq[b * w.cols..(b + 1) * w.cols],
                    &xs[b * gpr..(b + 1) * gpr],
                    w,
                    &mut out[b * w.rows..(b + 1) * w.rows],
                )
                .unwrap();
        }
        out
    }

    #[test]
    fn scalar_batch_bit_identical_to_per_vector() {
        for batch in [1usize, 2, 4, 8] {
            let (xq, xs, w) = random_batch(40, 512, 128, batch, batch as u64);
            let want = per_vector(&xq, &xs, &w, batch);
            let mut got = vec![0.0; batch * w.rows];
            ScalarGqmv.gqmv_batch(&xq, &xs, &w, &mut got, batch).unwrap();
            assert_eq!(got, want, "batch={batch}");
        }
    }

    #[test]
    fn threaded_batch_bit_identical_to_per_vector() {
        let pool = Arc::new(ThreadPool::new(4));
        for batch in [2usize, 4, 8] {
            let (xq, xs, w) = random_batch(64, 256, 256, batch, 100 + batch as u64);
            let want = per_vector(&xq, &xs, &w, batch);
            let mut th = ThreadedGqmv::new(pool.clone());
            th.min_parallel_macs = 0; // force threading
            let mut got = vec![0.0; batch * w.rows];
            th.gqmv_batch(&xq, &xs, &w, &mut got, batch).unwrap();
            assert_eq!(got, want, "batch={batch}");
        }
    }

    #[test]
    fn default_batch_impl_bit_identical() {
        // a backend without an override (the dataflow sim) goes through the
        // trait's default per-vector loop
        let mut sim = crate::fpga::DataflowSim::new(crate::fpga::PlConfig::default());
        let (xq, xs, w) = random_batch(16, 256, 256, 3, 7);
        let want = per_vector(&xq, &xs, &w, 3);
        let mut got = vec![0.0; 3 * w.rows];
        sim.gqmv_batch(&xq, &xs, &w, &mut got, 3).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn batch_shape_mismatches_rejected() {
        let (xq, xs, w) = random_batch(8, 256, 256, 2, 9);
        let mut out = vec![0.0; 2 * 8];
        assert!(ScalarGqmv.gqmv_batch(&xq, &xs, &w, &mut out, 0).is_err());
        assert!(ScalarGqmv.gqmv_batch(&xq[..256], &xs, &w, &mut out, 2).is_err());
        assert!(ScalarGqmv.gqmv_batch(&xq, &xs[..1], &w, &mut out, 2).is_err());
        let mut short = vec![0.0; 8];
        assert!(ScalarGqmv.gqmv_batch(&xq, &xs, &w, &mut short, 2).is_err());
    }

    #[test]
    fn shape_mismatches_rejected() {
        let (xq, xs, w) = random_case(8, 256, 256, 1);
        let mut out = vec![0.0; 8];
        assert!(ScalarGqmv.gqmv(&xq[..128], &xs, &w, &mut out).is_err());
        assert!(ScalarGqmv.gqmv(&xq, &xs[..0], &w, &mut out).is_err());
        let mut short = vec![0.0; 4];
        assert!(ScalarGqmv.gqmv(&xq, &xs, &w, &mut short).is_err());
    }

    #[test]
    fn blocked_rows_bit_identical_to_per_row() {
        // gqmv_rows (group-outer, ROW_BLOCK-row cache blocks) must equal
        // the strided per-row loop bit for bit — including row counts that
        // are not multiples of the block size
        let cases = [(1usize, 256usize, 256usize), (7, 256, 128), (8, 512, 256), (21, 256, 64)];
        for (m, n, gs) in cases {
            let (xq, xs, w) = random_case(m, n, gs, (3 * m + n) as u64);
            let mut strided = vec![0.0f32; m];
            let gpr = w.groups_per_row();
            for i in 0..m {
                strided[i] = gqmv_row(
                    &xq,
                    &xs,
                    &w.q[i * n..(i + 1) * n],
                    &w.s[i * gpr..(i + 1) * gpr],
                    gs,
                );
            }
            let mut blocked = vec![0.0f32; m];
            gqmv_rows(&xq, &xs, &w.q, &w.s, gs, &mut blocked);
            assert_eq!(blocked, strided, "m={m} n={n} gs={gs}");
        }
    }

    /// Build a same-input fused group (the Wq/Wk/Wv shape pattern) plus
    /// one quantized activation.
    fn fused_case(
        row_counts: &[usize],
        n: usize,
        gs: usize,
        seed: u64,
    ) -> (Vec<i8>, Vec<f32>, Vec<QuantizedTensor>) {
        let mut rng = Rng::new(seed);
        let ws: Vec<QuantizedTensor> = row_counts
            .iter()
            .map(|&m| QuantizedTensor::from_f32(&rng.normal_vec(m * n, 0.5), m, n, gs))
            .collect();
        let (xq, xs) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
        (xq, xs, ws)
    }

    fn fused_outputs(
        exec: &mut dyn GqmvExec,
        xq: &[i8],
        xs: &[f32],
        ws: &[QuantizedTensor],
    ) -> Vec<Vec<f32>> {
        let refs: Vec<&QuantizedTensor> = ws.iter().collect();
        let mut bufs: Vec<Vec<f32>> = ws.iter().map(|w| vec![0.0; w.rows]).collect();
        let mut outs: Vec<&mut [f32]> = bufs.iter_mut().map(|b| &mut b[..]).collect();
        exec.gqmv_fused(xq, xs, &refs, &mut outs).unwrap();
        bufs
    }

    #[test]
    fn fused_bit_identical_to_separate_and_to_concat() {
        // the three equivalent execution strategies of a same-input group:
        // N separate dispatches, one fused dispatch, one dispatch over the
        // row-concatenated tensor (how QuantLayer stores Wq|Wk|Wv) — all
        // must agree bit for bit, on every backend
        let (xq, xs, ws) = fused_case(&[40, 8, 8], 256, 64, 9);
        let mut separate: Vec<Vec<f32>> = Vec::new();
        for w in &ws {
            let mut out = vec![0.0; w.rows];
            ScalarGqmv.gqmv(&xq, &xs, w, &mut out).unwrap();
            separate.push(out);
        }
        let concat = QuantizedTensor::concat_rows(&ws.iter().collect::<Vec<_>>());
        let mut concat_out = vec![0.0; concat.rows];
        ScalarGqmv.gqmv(&xq, &xs, &concat, &mut concat_out).unwrap();
        let flat: Vec<f32> = separate.iter().flatten().copied().collect();
        assert_eq!(concat_out, flat, "concat dispatch != separate dispatches");

        let pool = Arc::new(ThreadPool::new(4));
        let mut th = ThreadedGqmv::new(pool);
        th.min_parallel_macs = 0; // force the combined pooled dispatch
        for exec in [&mut ScalarGqmv as &mut dyn GqmvExec, &mut th] {
            let name = exec.name();
            let got = fused_outputs(exec, &xq, &xs, &ws);
            assert_eq!(got, separate, "{name} fused dispatch diverged");
        }
    }

    #[test]
    fn fused_default_impl_bit_identical() {
        // a backend without an override (the dataflow sim) rides the
        // trait's default per-matrix loop
        let mut sim = crate::fpga::DataflowSim::new(crate::fpga::PlConfig::default());
        let (xq, xs, ws) = fused_case(&[16, 4, 4], 256, 256, 11);
        let want: Vec<Vec<f32>> = ws
            .iter()
            .map(|w| {
                let mut out = vec![0.0; w.rows];
                ScalarGqmv.gqmv(&xq, &xs, w, &mut out).unwrap();
                out
            })
            .collect();
        assert_eq!(fused_outputs(&mut sim, &xq, &xs, &ws), want);
    }

    #[test]
    fn fused_batch_bit_identical_to_per_matrix_batch() {
        let n = 256;
        let gs = 64;
        let batch = 3;
        let mut rng = Rng::new(21);
        let ws = [
            QuantizedTensor::from_f32(&rng.normal_vec(24 * n, 0.5), 24, n, gs),
            QuantizedTensor::from_f32(&rng.normal_vec(8 * n, 0.5), 8, n, gs),
        ];
        let mut xq = Vec::new();
        let mut xs = Vec::new();
        for _ in 0..batch {
            let (q, s) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
            xq.extend(q);
            xs.extend(s);
        }
        let mut want: Vec<Vec<f32>> = Vec::new();
        for w in &ws {
            let mut out = vec![0.0; batch * w.rows];
            ScalarGqmv.gqmv_batch(&xq, &xs, w, &mut out, batch).unwrap();
            want.push(out);
        }
        let refs: Vec<&QuantizedTensor> = ws.iter().collect();
        let mut bufs: Vec<Vec<f32>> = ws.iter().map(|w| vec![0.0; batch * w.rows]).collect();
        let mut outs: Vec<&mut [f32]> = bufs.iter_mut().map(|b| &mut b[..]).collect();
        ScalarGqmv.gqmv_fused_batch(&xq, &xs, &refs, &mut outs, batch).unwrap();
        assert_eq!(bufs, want);
    }

    #[test]
    fn fused_shape_mismatches_rejected() {
        let (xq, xs, ws) = fused_case(&[8, 8], 256, 64, 13);
        let refs: Vec<&QuantizedTensor> = ws.iter().collect();
        // outs count mismatch
        let mut one = vec![0.0; 8];
        let mut outs: Vec<&mut [f32]> = vec![&mut one[..]];
        assert!(ScalarGqmv.gqmv_fused(&xq, &xs, &refs, &mut outs).is_err());
        // empty group
        let mut none: Vec<&mut [f32]> = Vec::new();
        assert!(ScalarGqmv.gqmv_fused(&xq, &xs, &[], &mut none).is_err());
        // wrong out length
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 7];
        let mut outs: Vec<&mut [f32]> = vec![&mut a[..], &mut b[..]];
        assert!(ScalarGqmv.gqmv_fused(&xq, &xs, &refs, &mut outs).is_err());
        // mixed column counts across the group
        let flat = vec![0.1f32; 8 * 128];
        let narrow = QuantizedTensor::from_f32(&flat, 8, 128, 64);
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        let mut outs: Vec<&mut [f32]> = vec![&mut a[..], &mut b[..]];
        assert!(ScalarGqmv.gqmv_fused(&xq, &xs, &[&ws[0], &narrow], &mut outs).is_err());
    }

    #[test]
    fn packed_dispatch_bit_identical_to_unpacked_per_format() {
        // GqmvExec::gqmv_packed must agree bit-for-bit with gqmv on the
        // unpacked tensor, for every format and backend (scalar default
        // impl + the threaded row-split override)
        use crate::quant::FormatId;
        let pool = Arc::new(ThreadPool::new(4));
        let mut rng = Rng::new(77);
        for fmt in FormatId::ALL {
            for (m, n, gs) in [(8usize, 256usize, 256usize), (21, 256, 64)] {
                let w = QuantizedTensor::from_f32_fmt(&rng.normal_vec(m * n, 0.5), m, n, gs, fmt);
                let (xq, xs) = quantize_activation(&rng.normal_vec(n, 1.0), gs);
                let p = PackedTensor::pack(&w);
                let mut want = vec![0.0; m];
                ScalarGqmv.gqmv(&xq, &xs, &w, &mut want).unwrap();
                let mut got = vec![0.0; m];
                ScalarGqmv.gqmv_packed(&xq, &xs, &p, &mut got).unwrap();
                assert_eq!(got, want, "scalar packed {} m={m} n={n} gs={gs}", fmt.name());
                let mut th = ThreadedGqmv::new(pool.clone());
                th.min_parallel_macs = 0; // force threading
                let mut got_th = vec![0.0; m];
                th.gqmv_packed(&xq, &xs, &p, &mut got_th).unwrap();
                assert_eq!(got_th, want, "threaded packed {} m={m} n={n} gs={gs}", fmt.name());
            }
        }
    }

    #[test]
    fn packed_shape_mismatches_rejected() {
        let (xq, xs, w) = random_case(8, 256, 256, 31);
        let p = PackedTensor::pack(&w);
        let mut out = vec![0.0; 8];
        assert!(ScalarGqmv.gqmv_packed(&xq[..128], &xs, &p, &mut out).is_err());
        assert!(ScalarGqmv.gqmv_packed(&xq, &xs[..0], &p, &mut out).is_err());
        let mut short = vec![0.0; 4];
        assert!(ScalarGqmv.gqmv_packed(&xq, &xs, &p, &mut short).is_err());
    }

    #[test]
    fn matches_golden_fixture_if_present() {
        // artifacts/golden_gqmv_*.bin are written by python aot.py from the
        // numpy oracle; when built, verify bit-level agreement.
        let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let paths = ["xq", "xs", "wq", "ws", "out"]
            .map(|n| art.join(format!("golden_gqmv_{n}.bin")));
        if !paths.iter().all(|p| p.exists()) {
            eprintln!("skipping golden fixture test (artifacts not built)");
            return;
        }
        let read_i8 = |p: &std::path::Path| -> Vec<i8> {
            std::fs::read(p).unwrap().into_iter().map(|b| b as i8).collect()
        };
        let read_f32 = |p: &std::path::Path| -> Vec<f32> {
            std::fs::read(p)
                .unwrap()
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect()
        };
        let xq = read_i8(&paths[0]);
        let xs = read_f32(&paths[1]);
        let wq = read_i8(&paths[2]);
        let ws = read_f32(&paths[3]);
        let expect = read_f32(&paths[4]);
        let (m, gs) = (expect.len(), 256);
        let n = wq.len() / m;
        let w =
            QuantizedTensor { q: wq, s: ws, rows: m, cols: n, gs, fmt: crate::quant::FormatId::Q8 };
        let mut out = vec![0.0; m];
        ScalarGqmv.gqmv(&xq, &xs, &w, &mut out).unwrap();
        for i in 0..m {
            assert!(
                (out[i] - expect[i]).abs() <= 1e-5 + expect[i].abs() * 1e-6,
                "row {i}: {} vs {}",
                out[i],
                expect[i]
            );
        }
    }
}
