//! W32A32 float inference engine — the unquantized baseline of Table V.
//!
//! Same Algorithm-2 structure as the quantized engines, but every matvec is
//! plain f32.  Used to measure the PPL delta caused by W8A8 quantization.

use anyhow::Result;

use crate::model::{FloatModel, KvCache, KvStore};
use crate::tensor;

/// Incremental float forward pass with KV cache.
pub struct FloatEngine {
    pub model: FloatModel,
    kv: KvCache,
    // scratch
    x: Vec<f32>,
    xb: Vec<f32>,
    qkv: Vec<f32>,
    att_out: Vec<f32>,
    h13: Vec<f32>,
    logits: Vec<f32>,
}

impl FloatEngine {
    pub fn new(model: FloatModel) -> Self {
        let cfg = model.cfg;
        FloatEngine {
            kv: KvCache::new(&cfg),
            x: vec![0.0; cfg.dim],
            xb: vec![0.0; cfg.dim],
            qkv: vec![0.0; cfg.dim + 2 * cfg.kv_dim()],
            att_out: vec![0.0; cfg.dim],
            h13: vec![0.0; 2 * cfg.hidden_dim],
            logits: vec![0.0; cfg.vocab_size],
            model,
        }
    }

    pub fn reset(&mut self) {
        self.kv.reset();
    }

    /// One decode step; returns the logits slice.
    pub fn forward(&mut self, token: u32, pos: usize) -> Result<&[f32]> {
        let cfg = self.model.cfg;
        let (d, kv_d, hd) = (cfg.dim, cfg.kv_dim(), cfg.head_dim());
        anyhow::ensure!((token as usize) < cfg.vocab_size, "token {token} out of range");
        anyhow::ensure!(pos < cfg.seq_len, "pos {pos} >= seq_len");

        self.x.copy_from_slice(&self.model.tok_emb[token as usize * d..(token as usize + 1) * d]);

        for li in 0..cfg.n_layers {
            let layer = &self.model.layers[li];
            tensor::rmsnorm(&mut self.xb, &self.x, &layer.att_norm);
            // fused QKV (single input vector, three matrices)
            tensor::matvec_f32(&mut self.qkv[..d], &layer.wq, &self.xb);
            tensor::matvec_f32(&mut self.qkv[d..d + kv_d], &layer.wk, &self.xb);
            tensor::matvec_f32(&mut self.qkv[d + kv_d..], &layer.wv, &self.xb);
            let (q, kvs) = self.qkv.split_at_mut(d);
            let (k, v) = kvs.split_at_mut(kv_d);
            tensor::rope(q, pos, hd);
            tensor::rope(k, pos, hd);
            self.kv.store(li, pos, k, v);

            attention(&cfg, &self.kv, li, pos, q, &mut self.att_out);
            tensor::matvec_f32(&mut self.xb, &layer.wo, &self.att_out);
            tensor::add_assign(&mut self.x, &self.xb);

            tensor::rmsnorm(&mut self.xb, &self.x, &layer.ffn_norm);
            let h = cfg.hidden_dim;
            tensor::matvec_f32(&mut self.h13[..h], &layer.w1, &self.xb);
            tensor::matvec_f32(&mut self.h13[h..], &layer.w3, &self.xb);
            let (h1, h3) = self.h13.split_at_mut(h);
            tensor::swiglu(h1, h3);
            tensor::matvec_f32(&mut self.xb, &layer.w2, h1);
            tensor::add_assign(&mut self.x, &self.xb);
        }

        tensor::rmsnorm(&mut self.xb, &self.x, &self.model.final_norm);
        tensor::matvec_f32(&mut self.logits, &self.model.cls, &self.xb);
        Ok(&self.logits)
    }
}

/// Multi-head GQA attention over any [`KvStore`] (shared by float and
/// quantized engines — both run it on the PS, per the paper; contiguous
/// and paged caches go through the same loop).
pub fn attention(
    cfg: &crate::model::LlamaConfig,
    kv: &dyn KvStore,
    layer: usize,
    pos: usize,
    q: &[f32],
    out: &mut [f32],
) {
    let hd = cfg.head_dim();
    let rep = cfg.kv_rep();
    let scale = 1.0 / (hd as f32).sqrt();
    let mut scores = vec![0.0f32; pos + 1];
    for h in 0..cfg.n_heads {
        let kv_h = h / rep;
        let qh = &q[h * hd..(h + 1) * hd];
        for (t, s) in scores.iter_mut().enumerate() {
            *s = tensor::dot(kv.key(layer, t, kv_h, hd), qh) * scale;
        }
        tensor::softmax(&mut scores);
        let oh = &mut out[h * hd..(h + 1) * hd];
        oh.fill(0.0);
        for (t, &p) in scores.iter().enumerate() {
            let vh = kv.value(layer, t, kv_h, hd);
            for i in 0..hd {
                oh[i] += p * vh[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LlamaConfig;

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    #[test]
    fn forward_finite_and_deterministic() {
        let fm = FloatModel::random(tiny_cfg(), 1);
        let mut e1 = FloatEngine::new(fm.clone());
        let mut e2 = FloatEngine::new(fm);
        for (pos, tok) in [3u32, 9, 12, 1].iter().enumerate() {
            let a = e1.forward(*tok, pos).unwrap().to_vec();
            let b = e2.forward(*tok, pos).unwrap().to_vec();
            assert_eq!(a, b);
            assert!(a.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn context_changes_logits() {
        let fm = FloatModel::random(tiny_cfg(), 2);
        let mut e = FloatEngine::new(fm);
        let l0 = e.forward(5, 0).unwrap().to_vec();
        let l1 = e.forward(5, 1).unwrap().to_vec();
        // same token, different position/context => different logits
        assert_ne!(l0, l1);
    }

    #[test]
    fn reset_restores_initial_state() {
        let fm = FloatModel::random(tiny_cfg(), 3);
        let mut e = FloatEngine::new(fm);
        let first = e.forward(7, 0).unwrap().to_vec();
        e.forward(8, 1).unwrap();
        e.reset();
        let again = e.forward(7, 0).unwrap().to_vec();
        assert_eq!(first, again);
    }

    #[test]
    fn invalid_token_rejected() {
        let fm = FloatModel::random(tiny_cfg(), 4);
        let mut e = FloatEngine::new(fm);
        assert!(e.forward(9999, 0).is_err());
    }

    #[test]
    fn attention_at_pos0_returns_v() {
        // with a single cached position, softmax is 1 and out == V
        let cfg = tiny_cfg();
        let mut kv = KvCache::new(&cfg);
        let k: Vec<f32> = (0..cfg.kv_dim()).map(|i| 0.1 * i as f32).collect();
        let v: Vec<f32> = (0..cfg.kv_dim()).map(|i| -0.2 * i as f32).collect();
        kv.store(0, 0, &k, &v);
        let q = vec![0.3; cfg.dim];
        let mut out = vec![0.0; cfg.dim];
        attention(&cfg, &kv, 0, 0, &q, &mut out);
        let hd = cfg.head_dim();
        // both heads share kv head 0 (GQA): head h output == v[0..hd]
        for h in 0..cfg.n_heads {
            for i in 0..hd {
                assert!((out[h * hd + i] - v[i]).abs() < 1e-6);
            }
        }
    }
}
