//! PS-side compute: the ZCU102 processing-system baseline.
//!
//! The paper's comparison point runs the *same* W8A8-quantized TinyLlama
//! entirely on the quad-core Cortex-A53 PS, with OpenMP parallelizing the
//! GQMV row loop.  [`gqmv`] provides the scalar and threaded CPU
//! implementations of Algorithm 1 (both bit-exact with the oracle), and
//! [`float`] the W32A32 float engine used by Table V.

pub mod float;
pub mod gqmv;

pub use gqmv::{GqmvExec, ScalarGqmv, ThreadedGqmv};
