//! Deterministic I/O fault injection for the weight-staging path.
//!
//! Production serving streams every layer's weights from "DDR" (a
//! checkpoint file or the shared in-memory model) on the critical path of
//! every token, so a flaky read, a truncated file, a corrupted segment or
//! a stuck transfer must surface as a *bounded, recoverable* error — not
//! a hang and not silent garbage.  This module provides the test double
//! for all of those: a [`FaultPlan`] (seeded probability plus scripted
//! per-(layer, matrix) triggers) and a [`FaultyFetcher`] decorator that
//! injects the planned faults around any [`LayerFetcher`].
//!
//! Fault model:
//!
//! * [`FaultKind::ReadErr`] — the fetch fails outright (an I/O error on
//!   the DDR/disk path).
//! * [`FaultKind::Truncated`] — the fetch observes fewer bytes than the
//!   layout promises (a truncated checkpoint / short DMA).
//! * [`FaultKind::Corrupt`] — the fetched bytes were flipped in flight
//!   and the integrity layer (the per-segment CRC32 footer verified at
//!   staging time, see [`crate::ckpt`]) *caught* the mismatch.  The
//!   decorator injects the detected outcome — a checksum-mismatch error —
//!   because a fetcher-level decorator sits above the checksum
//!   verification; genuine on-disk bit flips are exercised separately
//!   against [`crate::ckpt::CkptSource`] in the mutation-corpus tests.
//! * [`FaultKind::Stall`] — the fetch completes correctly but only after
//!   sleeping a configured number of milliseconds, modelling a stuck
//!   transfer.  The streamer's per-stage deadline
//!   ([`crate::sched::RetryPolicy::stage_timeout_ms`]) turns a stall past
//!   the deadline into a timeout error instead of a hang.
//!
//! All three error kinds are *retryable*: the prefetch worker retries a
//! failed stage with capped exponential backoff before surfacing the
//! error, so a one-shot injected fault is absorbed transparently (and
//! counted in [`crate::sched::StreamerStats::retries`]), while a
//! persistent one exhausts the budget and fails the stage.
//!
//! Plans parse from the `--inject-faults` CLI spec — see
//! [`FaultPlan::parse`].  Everything is seeded and deterministic: the
//! same spec produces the same fault sequence on every run, which is what
//! lets CI assert survivor bit-exactness under injected faults.

use anyhow::{bail, Context, Result};

use crate::model::{LayerChunk, MatrixUnit, QuantLayer};
use crate::sched::LayerFetcher;
use crate::util::Rng;

/// One kind of injected staging fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The fetch fails with an I/O-style read error.
    ReadErr,
    /// The fetch observes a truncated source (short read).
    Truncated,
    /// The fetched bytes were corrupted and the checksum layer caught it
    /// (surfaces as a checksum-mismatch error; see the module docs).
    Corrupt,
    /// The fetch succeeds, but only after stalling for this many
    /// milliseconds (models a stuck DDR/disk transfer).
    Stall(u64),
}

impl FaultKind {
    /// Stable spec/CLI label for this kind.
    pub fn label(self) -> &'static str {
        match self {
            FaultKind::ReadErr => "readerr",
            FaultKind::Truncated => "truncated",
            FaultKind::Corrupt => "corrupt",
            FaultKind::Stall(_) => "stall",
        }
    }

    fn parse(s: &str, stall_ms: u64) -> Result<FaultKind> {
        Ok(match s {
            "readerr" => FaultKind::ReadErr,
            "truncated" => FaultKind::Truncated,
            "corrupt" => FaultKind::Corrupt,
            "stall" => FaultKind::Stall(stall_ms),
            other => bail!(
                "unknown fault kind '{other}' (expected readerr|truncated|corrupt|stall)"
            ),
        })
    }
}

/// A scripted fault: fire `kind` on fetches of (`layer`, `unit`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultTrigger {
    /// Transformer layer the trigger matches.
    pub layer: usize,
    /// Matrix unit the trigger matches; `None` matches any unit and
    /// whole-layer fetches (a whole-layer fetch contains every unit, so
    /// unit-specific triggers match it too).
    pub unit: Option<MatrixUnit>,
    /// Fault to inject when the trigger matches.
    pub kind: FaultKind,
    /// Remaining fires; `u32::MAX` means "always".
    pub times: u32,
}

/// A deterministic fault schedule: seeded background probability plus
/// scripted triggers.  Parsed from the `--inject-faults` spec.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Per-fetch probability of a random retryable fault (alternating
    /// [`FaultKind::ReadErr`] / [`FaultKind::Corrupt`], seeded).
    pub p: f64,
    /// PRNG seed for the probabilistic faults.
    pub seed: u64,
    /// Stall duration used by `stall` triggers, in milliseconds.
    pub stall_ms: u64,
    /// Scripted (layer, unit, kind) triggers, checked before the
    /// probabilistic draw.
    pub triggers: Vec<FaultTrigger>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan { p: 0.0, seed: 0x5eed, stall_ms: 50, triggers: Vec::new() }
    }
}

fn parse_unit(s: &str) -> Result<Option<MatrixUnit>> {
    Ok(Some(match s {
        "any" | "layer" => return Ok(None),
        "norms" => MatrixUnit::Norms,
        "qkv" => MatrixUnit::Qkv,
        "wo" => MatrixUnit::Wo,
        "w13" => MatrixUnit::W13,
        "w2" => MatrixUnit::W2,
        other => bail!("unknown matrix unit '{other}' (expected norms|qkv|wo|w13|w2|any)"),
    }))
}

impl FaultPlan {
    /// Parse an `--inject-faults` spec: comma-separated items of
    ///
    /// * `p=<f64>` — per-fetch probability of a random retryable fault,
    /// * `seed=<u64>` — PRNG seed for the probabilistic draws,
    /// * `stall_ms=<u64>` — duration injected stalls sleep for,
    /// * `at=<layer>/<unit>/<kind>[/<count>]` — a scripted trigger:
    ///   `unit` is `norms|qkv|wo|w13|w2|any`, `kind` is
    ///   `readerr|truncated|corrupt|stall`, `count` is a fire count
    ///   (default 1) or `always`.
    ///
    /// Examples: `p=0.01,seed=42` (1% random faults),
    /// `at=1/qkv/readerr` (fail the first fetch of layer 1's QKV block
    /// once), `stall_ms=200,at=0/any/stall/always` (every layer-0 fetch
    /// stalls 200 ms).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        let mut raw_triggers: Vec<String> = Vec::new();
        for item in spec.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (key, val) =
                item.split_once('=').with_context(|| format!("bad fault spec item '{item}'"))?;
            match key {
                "p" => {
                    plan.p = val.parse().with_context(|| format!("bad probability '{val}'"))?;
                    anyhow::ensure!(
                        (0.0..=1.0).contains(&plan.p),
                        "fault probability {} outside [0, 1]",
                        plan.p
                    );
                }
                "seed" => {
                    plan.seed = val.parse().with_context(|| format!("bad seed '{val}'"))?;
                }
                "stall_ms" => {
                    plan.stall_ms =
                        val.parse().with_context(|| format!("bad stall_ms '{val}'"))?;
                }
                // triggers are parsed after the scalar keys so `stall_ms`
                // applies regardless of item order in the spec
                "at" => raw_triggers.push(val.to_string()),
                other => bail!("unknown fault spec key '{other}' (expected p|seed|stall_ms|at)"),
            }
        }
        for val in raw_triggers {
            let parts: Vec<&str> = val.split('/').collect();
            anyhow::ensure!(
                parts.len() == 3 || parts.len() == 4,
                "bad trigger '{val}' (expected <layer>/<unit>/<kind>[/<count>])"
            );
            let layer: usize =
                parts[0].parse().with_context(|| format!("bad trigger layer '{}'", parts[0]))?;
            let unit = parse_unit(parts[1])?;
            let kind = FaultKind::parse(parts[2], plan.stall_ms)?;
            let times = match parts.get(3) {
                None => 1,
                Some(&"always") => u32::MAX,
                Some(n) => {
                    let n: u32 =
                        n.parse().with_context(|| format!("bad trigger count '{n}'"))?;
                    anyhow::ensure!(n >= 1, "trigger count must be >= 1");
                    n
                }
            };
            plan.triggers.push(FaultTrigger { layer, unit, kind, times });
        }
        Ok(plan)
    }

    /// True when this plan can never inject anything.
    pub fn is_empty(&self) -> bool {
        self.p <= 0.0 && self.triggers.is_empty()
    }
}

/// [`LayerFetcher`] decorator that injects the faults of a [`FaultPlan`]
/// around an inner fetcher.  Scripted triggers are consulted first (and
/// consume a fire), then the seeded probabilistic draw.  Deterministic:
/// the fault sequence depends only on the plan and the order of fetches.
pub struct FaultyFetcher<F: LayerFetcher> {
    inner: F,
    plan: FaultPlan,
    rng: Rng,
}

impl<F: LayerFetcher> FaultyFetcher<F> {
    /// Wrap `inner` with the faults of `plan`.
    pub fn new(inner: F, plan: FaultPlan) -> Self {
        let rng = Rng::new(plan.seed);
        FaultyFetcher { inner, plan, rng }
    }

    /// Decide whether this fetch faults; scripted triggers consume a fire.
    fn decide(&mut self, layer: usize, unit: Option<MatrixUnit>) -> Option<FaultKind> {
        for t in &mut self.plan.triggers {
            if t.times == 0 || t.layer != layer {
                continue;
            }
            // a whole-layer fetch (unit None) contains every unit, so any
            // trigger on this layer matches it; unit-specific fetches
            // match wildcard triggers and their own unit
            let matches = match (t.unit, unit) {
                (None, _) | (Some(_), None) => true,
                (Some(tu), Some(fu)) => tu == fu,
            };
            if !matches {
                continue;
            }
            if t.times != u32::MAX {
                t.times -= 1;
            }
            return Some(t.kind);
        }
        if self.plan.p > 0.0 && self.rng.next_f64() < self.plan.p {
            // probabilistic faults alternate between the two retryable
            // error kinds; stalls are scripted-only so probabilistic soak
            // runs stay fast
            return Some(if self.rng.next_u64() & 1 == 0 {
                FaultKind::ReadErr
            } else {
                FaultKind::Corrupt
            });
        }
        None
    }

    /// Fire one injected fault (error kinds bail, stalls sleep then pass).
    fn trip(&self, kind: FaultKind, layer: usize, what: &str) -> Result<()> {
        match kind {
            FaultKind::ReadErr => bail!("injected fault: read error at layer {layer} ({what})"),
            FaultKind::Truncated => {
                bail!("injected fault: truncated read at layer {layer} ({what})")
            }
            FaultKind::Corrupt => bail!(
                "injected fault: segment checksum mismatch at layer {layer} ({what}) [corrupt]"
            ),
            FaultKind::Stall(ms) => {
                std::thread::sleep(std::time::Duration::from_millis(ms));
                Ok(())
            }
        }
    }
}

impl<F: LayerFetcher> LayerFetcher for FaultyFetcher<F> {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        if let Some(kind) = self.decide(layer, None) {
            self.trip(kind, layer, "layer")?;
        }
        self.inner.fetch(layer)
    }

    fn n_layers(&self) -> usize {
        self.inner.n_layers()
    }

    fn fetch_chunk(&mut self, layer: usize, unit: MatrixUnit) -> Result<LayerChunk> {
        if let Some(kind) = self.decide(layer, Some(unit)) {
            self.trip(kind, layer, unit.name())?;
        }
        self.inner.fetch_chunk(layer, unit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    use crate::model::{FloatModel, LlamaConfig, QuantModel};
    use crate::sched::MemFetcher;

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 4,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    fn mem_fetcher() -> MemFetcher {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
        MemFetcher { layers: Arc::new(qm.layers) }
    }

    #[test]
    fn spec_round_trips_every_field() {
        let p = FaultPlan::parse("p=0.25,seed=7,stall_ms=120,at=2/qkv/readerr/3").unwrap();
        assert_eq!(p.p, 0.25);
        assert_eq!(p.seed, 7);
        assert_eq!(p.stall_ms, 120);
        assert_eq!(
            p.triggers,
            vec![FaultTrigger {
                layer: 2,
                unit: Some(MatrixUnit::Qkv),
                kind: FaultKind::ReadErr,
                times: 3,
            }]
        );
        assert!(!p.is_empty());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn stall_ms_applies_regardless_of_item_order() {
        // the trigger appears BEFORE stall_ms in the spec but must still
        // pick up the configured duration
        let p = FaultPlan::parse("at=0/any/stall,stall_ms=250").unwrap();
        assert_eq!(p.triggers[0].kind, FaultKind::Stall(250));
    }

    #[test]
    fn bad_specs_rejected() {
        for bad in [
            "nope",
            "p=2.0",
            "p=x",
            "at=0/qkv",
            "at=0/qkv/explode",
            "at=0/huh/readerr",
            "at=0/qkv/readerr/0",
            "wat=1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec '{bad}' must be rejected");
        }
    }

    #[test]
    fn scripted_trigger_fires_exactly_n_times() {
        let plan = FaultPlan::parse("at=1/any/readerr/2").unwrap();
        let mut f = FaultyFetcher::new(mem_fetcher(), plan);
        assert!(f.fetch(0).is_ok(), "untargeted layer passes through");
        let e = f.fetch(1).unwrap_err().to_string();
        assert!(e.contains("injected fault: read error"), "{e}");
        assert!(f.fetch(1).is_err(), "second fire");
        assert!(f.fetch(1).is_ok(), "budget exhausted: layer 1 fetches cleanly again");
    }

    #[test]
    fn unit_triggers_match_their_unit_and_whole_layer_fetches() {
        let plan = FaultPlan::parse("at=0/w2/corrupt/always").unwrap();
        let mut f = FaultyFetcher::new(mem_fetcher(), plan);
        assert!(f.fetch_chunk(0, MatrixUnit::Qkv).is_ok(), "other units unaffected");
        let e = f.fetch_chunk(0, MatrixUnit::W2).unwrap_err().to_string();
        assert!(e.contains("checksum mismatch"), "{e}");
        assert!(f.fetch(0).is_err(), "whole-layer fetch contains the targeted unit");
        assert!(f.fetch(1).is_ok());
    }

    #[test]
    fn probabilistic_faults_are_seed_deterministic() {
        let decisions = |seed: u64| {
            let plan = FaultPlan { p: 0.3, seed, ..FaultPlan::default() };
            let mut f = FaultyFetcher::new(mem_fetcher(), plan);
            (0..64).map(|i| f.fetch(i % 4).is_err()).collect::<Vec<bool>>()
        };
        assert_eq!(decisions(9), decisions(9), "same seed, same fault sequence");
        assert_ne!(decisions(9), decisions(10), "different seeds diverge");
        assert!(decisions(9).iter().any(|&e| e), "p=0.3 over 64 draws faults at least once");
        assert!(!decisions(9).iter().all(|&e| e), "...but not always");
    }

    #[test]
    fn empty_plan_is_a_passthrough() {
        let mut f = FaultyFetcher::new(mem_fetcher(), FaultPlan::default());
        for li in 0..4 {
            assert!(f.fetch(li).is_ok());
            assert!(f.fetch_chunk(li, MatrixUnit::Norms).is_ok());
        }
        assert_eq!(f.n_layers(), 4);
    }
}
