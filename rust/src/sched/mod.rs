//! Task-level weight-streaming scheduler (paper §III-B, Fig. 2).
//!
//! The quantized model lives in "DDR" (the LFQ8 file / an in-memory layer
//! store); only a small number of per-layer buffers exist device-side.
//! For every token, each layer's weights must be staged host→device before
//! its GQMV kernels can run.  Two schedules:
//!
//! * **Sync** — stage layer *l*, then compute layer *l* (Fig. 2 top).
//! * **Async** — while layer *l* computes, the prefetch worker stages
//!   upcoming work (wrapping to layer 0 for the next token), hiding the
//!   transfer behind the kernel (Fig. 2 bottom).  First-layer weights are
//!   staged at start-up, exactly as the paper initializes its buffers.
//!
//! All staging runs on one **persistent prefetch worker** — a long-lived
//! thread owning the fetcher, fed requests over a channel with explicit
//! reset/shutdown handshakes — so steady-state decode performs zero
//! thread spawns (the old design spawned and joined one OS thread per
//! staged layer).
//!
//! The async schedule runs the worker ahead through a **depth-N staging
//! ring** ([`Streamer::with_depth`], CLI `--prefetch-depth N`): up to
//! N−1 future staging units are requested while the current one computes,
//! so a single slow transfer (a DDR stall, a disk hiccup in
//! [`DiskFetcher`]) drains the ring instead of stalling the compute
//! thread.  Depth 2 is the classic double buffer and the default; depth 1
//! degenerates to inline staging.
//!
//! What a *unit* of staging is depends on the [`StageGranularity`]
//! (CLI `--stream-granularity`):
//!
//! * **Layer** (default) — the ring holds whole layers, exactly the
//!   classic schedule: within a layer, the first GQMV waits on the full
//!   ~5-chunk transfer.
//! * **Matrix** — the **matrix is the unit of staging**: each layer is
//!   streamed as five independent chunks (norm vectors, fused Wq‖Wk‖Wv,
//!   Wo, fused W1‖W3, W2, see [`MatrixUnit`]) and the ring depth counts
//!   matrices.  The worker streams chunk *k+1* while compute runs on
//!   chunk *k*, so the wait that gates a layer's *first* GQMV shrinks
//!   from "the whole layer" to "the first chunk" — the paper's fully
//!   pipelined MVM engine, applied below layer granularity.  Chunks are
//!   fused exactly as the layer reader fuses them, so matrix-granular
//!   staging is bit-identical to layer-granular at every depth.
//!
//! The consume side ([`Streamer::unit`] / [`Streamer::layer`]) pops the
//! ring strictly in walk order, discarding it wholesale whenever the
//! requested sequence breaks (out-of-order access, [`Streamer::reset`]);
//! [`StreamerStats`] tracks ring occupancy, buckets every prefetch wait
//! by the occupancy at the time of the wait, and attributes every visible
//! wait to the matrix unit being consumed (`wait_by_unit_s`) so STATS can
//! show exactly which matrix stalls.
//!
//! The same module also provides the *modeled* timeline
//! ([`sim_token_time`]) used to regenerate Fig. 2 / Table VI at paper
//! scale, where transfer and kernel times come from the AXI and dataflow
//! models rather than wall-clock.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

pub mod fault;

pub use fault::{FaultKind, FaultPlan, FaultTrigger, FaultyFetcher};

use crate::ckpt::CkptSource;
use crate::fpga::{AxiModel, PlConfig};
use crate::model::{LayerChunk, LlamaConfig, MatKind, MatrixUnit, QuantLayer, MATRIX_UNITS};
use crate::quant::QuantizedTensor;
use crate::runtime::{DeviceWeights, Runtime};

/// Scheduling policy for weight staging.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Stage layer *l*, then compute layer *l* (Fig. 2 top).
    Sync,
    /// Prefetch upcoming staging units while layer *l* computes (Fig. 2
    /// bottom, generalized to the depth-N ring).
    Async,
}

/// Unit of staging the ring pipelines (CLI `--stream-granularity`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StageGranularity {
    /// Whole layers — the classic Fig. 2 schedule (default).
    #[default]
    Layer,
    /// Matrix-granular chunks ([`MatrixUnit`]): the ring depth counts
    /// matrices and compute overlaps transfers *within* a layer.
    Matrix,
}

impl StageGranularity {
    /// Stable label for STATS / bench output.
    pub fn label(self) -> &'static str {
        match self {
            StageGranularity::Layer => "layer",
            StageGranularity::Matrix => "matrix",
        }
    }
}

/// Matrix-granular units per layer ([`MATRIX_UNITS`]) — the size of
/// [`StreamerStats::wait_by_unit_s`].
pub const STAGE_UNITS: usize = MATRIX_UNITS.len();

/// One unit of staging work the prefetch worker performs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageUnit {
    /// Stage one whole layer (layer granularity).
    Layer(usize),
    /// Stage one matrix-granular chunk of a layer (matrix granularity).
    Matrix(usize, MatrixUnit),
}

/// A staged weight matrix: the host copy (norm-free quantized tensor the
/// CPU backends consume) plus its device buffer.  The buffer is behind an
/// `Arc` so a device-side executor can hold it across provider calls
/// (see `engine::llamaf::DeviceGqmv`).
pub struct PreparedMatrix {
    /// Host-side staged copy of the (possibly fused) matrix.
    pub host: QuantizedTensor,
    /// Device-resident buffer uploaded by the prefetch worker.
    pub dev: Arc<DeviceWeights>,
}

/// The staged layer currently lent to compute.  Under matrix granularity
/// its parts fill in one chunk at a time — in consumption order, so
/// compute can run on the fused QKV block while W2 is still in flight;
/// under layer granularity everything arrives at once.
pub struct StagedLayer {
    li: usize,
    /// Staging units consumed so far (contiguous from the walk start:
    /// 0..=1 under layer granularity, 0..=[`STAGE_UNITS`] under matrix).
    filled: usize,
    att_norm: Option<Vec<f32>>,
    ffn_norm: Option<Vec<f32>>,
    wqkv: Option<PreparedMatrix>,
    wo: Option<PreparedMatrix>,
    w13: Option<PreparedMatrix>,
    w2: Option<PreparedMatrix>,
}

impl StagedLayer {
    fn empty(li: usize) -> Self {
        StagedLayer {
            li,
            filled: 0,
            att_norm: None,
            ffn_norm: None,
            wqkv: None,
            wo: None,
            w13: None,
            w2: None,
        }
    }

    /// Layer index this staged layer serves.
    pub fn li(&self) -> usize {
        self.li
    }

    /// Attention RMSNorm vector.  Panics if the norms chunk has not been
    /// staged yet (obtain the layer via [`Streamer::unit`] first).
    pub fn att_norm(&self) -> &[f32] {
        self.att_norm.as_deref().expect("norms not staged")
    }

    /// FFN RMSNorm vector.  Panics if the norms chunk is not staged.
    pub fn ffn_norm(&self) -> &[f32] {
        self.ffn_norm.as_deref().expect("norms not staged")
    }

    /// Fused Wq‖Wk‖Wv.  Panics if the chunk is not staged.
    pub fn wqkv(&self) -> &PreparedMatrix {
        self.wqkv.as_ref().expect("wqkv not staged")
    }

    /// Wo.  Panics if the chunk is not staged.
    pub fn wo(&self) -> &PreparedMatrix {
        self.wo.as_ref().expect("wo not staged")
    }

    /// Fused W1‖W3.  Panics if the chunk is not staged.
    pub fn w13(&self) -> &PreparedMatrix {
        self.w13.as_ref().expect("w13 not staged")
    }

    /// W2.  Panics if the chunk is not staged.
    pub fn w2(&self) -> &PreparedMatrix {
        self.w2.as_ref().expect("w2 not staged")
    }

    /// Fill one staged payload into this layer, enforcing walk order.
    fn fill(&mut self, payload: StagedPayload) -> Result<()> {
        match payload {
            StagedPayload::Layer(p) => {
                anyhow::ensure!(self.filled == 0, "whole-layer payload into a partial layer");
                let LayerParts { att_norm, ffn_norm, wqkv, wo, w13, w2 } = *p;
                self.att_norm = Some(att_norm);
                self.ffn_norm = Some(ffn_norm);
                self.wqkv = Some(wqkv);
                self.wo = Some(wo);
                self.w13 = Some(w13);
                self.w2 = Some(w2);
                self.filled = 1; // layer granularity: one unit covers everything
            }
            StagedPayload::Norms { att_norm, ffn_norm } => {
                anyhow::ensure!(self.filled == MatrixUnit::Norms.index(), "norms out of order");
                self.att_norm = Some(att_norm);
                self.ffn_norm = Some(ffn_norm);
                self.filled += 1;
            }
            StagedPayload::Mat(u, pm) => {
                anyhow::ensure!(
                    self.filled == u.index(),
                    "chunk {u:?} out of order (filled {})",
                    self.filled
                );
                match u {
                    MatrixUnit::Qkv => self.wqkv = Some(pm),
                    MatrixUnit::Wo => self.wo = Some(pm),
                    MatrixUnit::W13 => self.w13 = Some(pm),
                    MatrixUnit::W2 => self.w2 = Some(pm),
                    MatrixUnit::Norms => bail!("norms delivered as a matrix chunk"),
                }
                self.filled += 1;
            }
        }
        Ok(())
    }
}

/// Source of host-side layer weights ("DDR").
pub trait LayerFetcher: Send {
    /// Produce a host copy of layer `layer`'s weights.
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer>;

    /// Number of transformer layers this source serves.
    fn n_layers(&self) -> usize;

    /// Produce one matrix-granular chunk of layer `layer`.  The default
    /// fetches the whole layer and carves the chunk out (correct but
    /// unamortized); real sources override it with targeted reads
    /// ([`CkptSource::fetch_matrix`]) or per-chunk clones.
    fn fetch_chunk(&mut self, layer: usize, unit: MatrixUnit) -> Result<LayerChunk> {
        Ok(self.fetch(layer)?.chunk(unit))
    }
}

/// Streams layers from a quantized checkpoint file of any
/// [`crate::quant::FormatId`] (real disk I/O per fetch).
pub struct DiskFetcher {
    src: CkptSource,
}

impl DiskFetcher {
    /// Open a quantized checkpoint for layer-at-a-time streaming; the
    /// wire format is identified from the file magic.
    pub fn open(path: &std::path::Path) -> Result<Self> {
        Ok(DiskFetcher { src: CkptSource::open(path)? })
    }

    /// Model geometry read from the checkpoint header.
    pub fn cfg(&self) -> LlamaConfig {
        self.src.cfg
    }

    /// Weight wire format of the underlying checkpoint.
    pub fn fmt(&self) -> crate::quant::FormatId {
        self.src.fmt
    }
}

impl LayerFetcher for DiskFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.src.fetch_layer(layer)
    }

    fn n_layers(&self) -> usize {
        self.src.cfg.n_layers
    }

    fn fetch_chunk(&mut self, layer: usize, unit: MatrixUnit) -> Result<LayerChunk> {
        // targeted read: only the chunk's own byte segments leave the disk
        self.src.fetch_matrix(layer, unit)
    }
}

/// Serves layers from memory, cloning on fetch (models the memcpy from the
/// mmap'd model into the pinned kernel buffer — the staging the paper's
/// async schedule hides).
pub struct MemFetcher {
    /// The in-memory layer store shared with the owner of the weights.
    pub layers: Arc<Vec<QuantLayer>>,
}

impl LayerFetcher for MemFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.layers
            .get(layer)
            .cloned()
            .with_context(|| format!("layer {layer} out of range"))
    }

    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn fetch_chunk(&mut self, layer: usize, unit: MatrixUnit) -> Result<LayerChunk> {
        self.layers
            .get(layer)
            .map(|l| l.chunk(unit))
            .with_context(|| format!("layer {layer} out of range"))
    }
}

/// Serves layers out of a shared [`crate::model::QuantModel`]
/// (clone-on-fetch, like
/// [`MemFetcher`], without duplicating the layer store).  This is how the
/// batch scheduler streams weights: the `Arc`'d model *is* the "DDR", and
/// each fetch is the staging memcpy that the async prefetch thread hides
/// behind the batched kernels.
pub struct ModelFetcher {
    /// The shared quantized model whose layers are streamed.
    pub model: Arc<crate::model::QuantModel>,
}

impl LayerFetcher for ModelFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.model
            .layers
            .get(layer)
            .cloned()
            .with_context(|| format!("layer {layer} out of range"))
    }

    fn n_layers(&self) -> usize {
        self.model.layers.len()
    }

    fn fetch_chunk(&mut self, layer: usize, unit: MatrixUnit) -> Result<LayerChunk> {
        self.model
            .layers
            .get(layer)
            .map(|l| l.chunk(unit))
            .with_context(|| format!("layer {layer} out of range"))
    }
}

/// Default staging-pipeline depth: the classic double buffer (one unit
/// resident, one prefetch in flight).
pub const DEFAULT_PREFETCH_DEPTH: usize = 2;

/// Bucket count of [`StreamerStats::prefetch_wait_by_occ_s`]: waits are
/// indexed by the ring occupancy observed when the wait began, clamped to
/// the last bucket.
pub const RING_WAIT_BUCKETS: usize = 9;

/// Retry and deadline policy of the staged-read path.
///
/// The prefetch worker retries a failed stage (an I/O error, an injected
/// fault, a checksum mismatch caught by the ckpt integrity layer) with
/// capped exponential backoff before surfacing the error — the ring is
/// never torn down for a transient fault.  Independently, the compute
/// side bounds every wait on the worker with `stage_timeout_ms`, so a
/// stalled transfer surfaces as a timeout error instead of a hang.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts per stage (1 = no retry).  Default 3.
    pub max_attempts: u32,
    /// Initial backoff between attempts, in milliseconds (doubles per
    /// retry).  Default 2.
    pub backoff_ms: u64,
    /// Backoff cap in milliseconds.  Default 50.
    pub backoff_cap_ms: u64,
    /// Compute-side deadline for one stage wait, in milliseconds; a wait
    /// past it fails with a timeout error.  Default 30 000.
    pub stage_timeout_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, backoff_ms: 2, backoff_cap_ms: 50, stage_timeout_ms: 30_000 }
    }
}

/// Sleep source of the staged-read retry backoff.
///
/// Production code uses [`SystemClock`] (a real [`std::thread::sleep`]);
/// tests inject a recording fake through [`Streamer::with_clock`] so
/// backoff *schedules* are asserted exactly — no wall-clock measurement,
/// no dependence on CI runner speed.
pub trait Clock: Send + Sync {
    /// Block the calling thread for `d` (or just record it, in tests).
    fn sleep(&self, d: Duration);
}

/// The production [`Clock`]: delegates to [`std::thread::sleep`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SystemClock;

impl Clock for SystemClock {
    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Staging counters of a [`Streamer`] (Fig. 2 accounting plus the serving
/// metrics exported through `STATS`).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamerStats {
    /// Time the compute thread *blocked* on staging (visible latency:
    /// inline stagings plus waits on armed prefetches).
    pub blocked_transfer_s: f64,
    /// Of [`StreamerStats::blocked_transfer_s`], the part spent waiting on
    /// an *armed* (background) prefetch — ~0 when the async schedule hides
    /// transfers fully, rising toward the full staging time when the
    /// design is transfer-bound.
    pub prefetch_wait_s: f64,
    /// [`StreamerStats::prefetch_wait_s`] broken down by the ring
    /// occupancy (armed stagings in flight or ready) at the moment the
    /// wait began — the per-depth accounting of the staging ring.  A
    /// deeper ring should move waits into higher-occupancy buckets and
    /// shrink them: a wait at occupancy N−1 means even a full ring could
    /// not hide the transfer (truly bandwidth-bound), while waits piled
    /// at occupancy 1 mean more depth would help.
    pub prefetch_wait_by_occ_s: [f64; RING_WAIT_BUCKETS],
    /// Visible (blocked) staging wait attributed to the [`MatrixUnit`]
    /// being consumed when the wait occurred — "which matrix stalls".
    /// Under layer granularity the whole-layer wait gates the layer's
    /// first unit, so it all lands in bucket 0; under matrix granularity
    /// waits spread across the five buckets and the *first-matrix* share
    /// (buckets 0+1: norms + QKV) is what the sub-layer pipeline shrinks.
    pub wait_by_unit_s: [f64; STAGE_UNITS],
    /// Total staging work performed by the worker (foreground +
    /// background).
    pub total_transfer_s: f64,
    /// Number of stagings performed (whole layers under layer
    /// granularity, per-matrix chunks under matrix granularity).
    pub transfers: u64,
    /// Total weight bytes staged host→device (streamed representation:
    /// int8 data + f32 scales + norms).  The batched-decoding win is this
    /// counter growing per *step* instead of per *session-token*.
    pub staged_bytes: u64,
    /// OS threads this streamer has spawned over its lifetime.  Exactly 1
    /// (the persistent prefetch worker, spawned at construction): the
    /// steady-state decode path performs **zero** thread spawns.
    pub spawns: u64,
    /// Configured staging-pipeline depth (resident slot + ring capacity).
    pub ring_depth: usize,
    /// Sum over staged-unit consumes of the armed ring occupancy at
    /// consume time (0 whenever the needed unit was not armed — inline
    /// stagings and all of sync mode).
    pub ring_occupancy_sum: u64,
    /// Number of occupancy samples (one per staged-unit consume).
    pub ring_samples: u64,
    /// Failed stage attempts the worker retried (capped exponential
    /// backoff, [`RetryPolicy`]).  Retries never increment
    /// [`StreamerStats::transfers`] or [`StreamerStats::staged_bytes`] —
    /// only the final successful payload is billed — so a fault-free run
    /// and a run whose faults were all absorbed report identical transfer
    /// counters, with the recovery cost visible here.
    pub retries: u64,
    /// Stages that kept failing after every retry and surfaced an error
    /// to the compute side.
    pub stage_faults: u64,
    /// Stage waits that hit the per-stage deadline
    /// ([`RetryPolicy::stage_timeout_ms`]) — stalled transfers surfaced
    /// as timeout errors instead of hangs.
    pub stage_timeouts: u64,
}

impl StreamerStats {
    /// Mean armed-ring occupancy observed when staging units were
    /// consumed: > 0 means the prefetch pipeline was actually running
    /// ahead (0 for sync staging and resident serving; approaches
    /// `ring_depth - 1` when transfers outpace compute).
    pub fn ring_occupancy_mean(&self) -> f64 {
        if self.ring_samples == 0 {
            0.0
        } else {
            self.ring_occupancy_sum as f64 / self.ring_samples as f64
        }
    }

    /// Staging bandwidth in MB/s: bytes staged over worker-side transfer
    /// time.  0.0 before anything has been transferred (a fresh streamer,
    /// resident serving), so the zero case never divides by zero.
    pub fn stage_mb_s(&self) -> f64 {
        if self.total_transfer_s <= 0.0 {
            0.0
        } else {
            self.staged_bytes as f64 / 1e6 / self.total_transfer_s
        }
    }
}

/// Requests the compute side sends to the persistent prefetch worker.
enum StageReq {
    /// Fetch + stage one unit and send it back.  `slot` is the ring-walk
    /// index echoed in the response (consume-order sanity check).
    Stage { slot: usize, unit: StageUnit },
    /// Exit the worker loop (shutdown handshake).
    Shutdown,
}

/// A fully staged layer's parts (the whole-layer payload).
struct LayerParts {
    att_norm: Vec<f32>,
    ffn_norm: Vec<f32>,
    wqkv: PreparedMatrix,
    wo: PreparedMatrix,
    w13: PreparedMatrix,
    w2: PreparedMatrix,
}

/// What one staging request produced.
enum StagedPayload {
    /// A whole layer (layer granularity).
    Layer(Box<LayerParts>),
    /// The two norm vectors (matrix granularity).
    Norms { att_norm: Vec<f32>, ffn_norm: Vec<f32> },
    /// One fused weight matrix (matrix granularity).
    Mat(MatrixUnit, PreparedMatrix),
}

impl StagedPayload {
    /// Streamed bytes of this payload (chunks of one layer sum exactly to
    /// the whole layer's `stream_bytes`).
    fn stream_bytes(&self) -> usize {
        match self {
            StagedPayload::Layer(p) => {
                4 * (p.att_norm.len() + p.ffn_norm.len())
                    + p.wqkv.host.stream_bytes()
                    + p.wo.host.stream_bytes()
                    + p.w13.host.stream_bytes()
                    + p.w2.host.stream_bytes()
            }
            StagedPayload::Norms { att_norm, ffn_norm } => 4 * (att_norm.len() + ffn_norm.len()),
            StagedPayload::Mat(_, pm) => pm.host.stream_bytes(),
        }
    }
}

/// One completed staging, sent back from the worker.
struct StagedResp {
    /// Which ring slot this response answers (sanity-checked on consume).
    slot: usize,
    /// The staged payload, or the fetch/upload failure.
    result: Result<StagedPayload>,
    /// Worker-side wall time of the fetch + upload (including retries).
    staged_s: f64,
    /// Failed attempts retried before this response (0 on the fault-free
    /// path).
    retries: u32,
}

/// The long-lived staging thread plus its request/response channels.  Up
/// to `depth - 1` requests may be queued at once (the staging ring); the
/// worker serves them strictly in order, so responses arrive FIFO.
struct PrefetchWorker {
    /// `None` after shutdown — dropping the sender also stops the worker.
    req_tx: Option<Sender<StageReq>>,
    resp_rx: Receiver<StagedResp>,
    handle: Option<JoinHandle<()>>,
    /// Slots whose wait hit the stage deadline: their responses are still
    /// in flight and must be received-and-dropped before younger ones.
    /// Responses arrive strictly in request order and a timed-out request
    /// is always older than everything still pending, so this queue is
    /// drained positionally (front first) as late answers arrive.
    abandoned: VecDeque<usize>,
}

/// Upload one host matrix to the device, pairing the host copy with its
/// device buffer.
fn stage_matrix(rt: &Runtime, host: QuantizedTensor) -> Result<PreparedMatrix> {
    let dev = Arc::new(rt.upload(&host)?);
    Ok(PreparedMatrix { host, dev })
}

/// Fetch + upload one staging unit (runs on the worker thread).
fn stage_unit(
    rt: &Runtime,
    fetcher: &mut dyn LayerFetcher,
    unit: StageUnit,
) -> Result<StagedPayload> {
    match unit {
        StageUnit::Layer(li) => {
            let QuantLayer { att_norm, wqkv, wo, ffn_norm, w13, w2 } = fetcher.fetch(li)?;
            Ok(StagedPayload::Layer(Box::new(LayerParts {
                att_norm,
                ffn_norm,
                wqkv: stage_matrix(rt, wqkv)?,
                wo: stage_matrix(rt, wo)?,
                w13: stage_matrix(rt, w13)?,
                w2: stage_matrix(rt, w2)?,
            })))
        }
        StageUnit::Matrix(li, u) => match fetcher.fetch_chunk(li, u)? {
            LayerChunk::Norms { att_norm, ffn_norm } => {
                anyhow::ensure!(u == MatrixUnit::Norms, "fetcher returned norms for {u:?}");
                Ok(StagedPayload::Norms { att_norm, ffn_norm })
            }
            LayerChunk::Mat(t) => {
                anyhow::ensure!(u != MatrixUnit::Norms, "fetcher returned a matrix for norms");
                Ok(StagedPayload::Mat(u, stage_matrix(rt, t)?))
            }
        },
    }
}

/// Body of the persistent prefetch worker: owns the fetcher ("DDR") and
/// the device runtime handle, serves staging requests until told to stop.
/// A *failed* stage (I/O error, injected fault, checksum mismatch) is
/// retried in place with capped exponential backoff — the ring survives
/// transient faults without being torn down.  A panic inside
/// `fetch`/upload drops `resp_tx`, which the compute side observes as a
/// disconnected channel — an error, never a hang.
fn prefetch_worker_loop(
    rt: Arc<Runtime>,
    mut fetcher: Box<dyn LayerFetcher>,
    req_rx: Receiver<StageReq>,
    resp_tx: Sender<StagedResp>,
    policy: RetryPolicy,
    clock: Arc<dyn Clock>,
) {
    while let Ok(StageReq::Stage { slot, unit }) = req_rx.recv() {
        let t = Instant::now();
        let mut retries = 0u32;
        let mut backoff = policy.backoff_ms;
        let mut result = stage_unit(&rt, fetcher.as_mut(), unit);
        while result.is_err() && retries + 1 < policy.max_attempts.max(1) {
            clock.sleep(Duration::from_millis(backoff));
            backoff = (backoff.saturating_mul(2)).min(policy.backoff_cap_ms);
            retries += 1;
            result = stage_unit(&rt, fetcher.as_mut(), unit);
        }
        let result = result
            .with_context(|| format!("staging {unit:?} failed after {} attempts", retries + 1));
        let staged_s = t.elapsed().as_secs_f64();
        if resp_tx.send(StagedResp { slot, result, staged_s, retries }).is_err() {
            break; // streamer gone without the handshake; nothing to serve
        }
    }
}

/// Ring-buffered weight streamer over a **persistent prefetch worker**.
///
/// One long-lived thread (spawned at construction) owns the fetcher and
/// performs every staging — synchronous stagings block on the worker's
/// reply, asynchronous prefetches are requested early and collected when
/// the unit is needed.  The steady-state decode path therefore performs
/// zero thread spawns: where the previous design spawned and joined one OS
/// thread per staged layer (~`n_layers` spawns per batched step), requests
/// now travel over a channel to the worker spawned once per engine.
///
/// Async mode keeps a **staging ring** of up to `depth - 1` in-flight or
/// ready units ahead of the resident one ([`Streamer::with_depth`]).  The
/// ring always holds a consecutive (wrapping) run of the units the walk
/// will need next — possibly spanning token boundaries, so layer 0 of the
/// *next* token is staged during the current token's tail layers.  Any
/// access that breaks the sequence discards the ring wholesale and
/// restarts it.
///
/// Under [`StageGranularity::Matrix`] the walk order interleaves the five
/// [`MatrixUnit`]s of each layer, and [`Streamer::unit`] lets compute
/// start on a layer's first chunks while its tail chunks (and the next
/// layer's head) are still in flight — the sub-layer pipeline.
pub struct Streamer {
    /// Staging schedule ([`SchedMode::Sync`] or [`SchedMode::Async`]).
    pub mode: SchedMode,
    n_layers: usize,
    /// Pipeline depth: 1 resident unit + `depth - 1` ring slots.
    depth: usize,
    gran: StageGranularity,
    current: Option<StagedLayer>,
    /// Ring-walk slot indices requested from the worker, oldest first (in
    /// flight or already completed and parked in the response channel).
    pending: VecDeque<usize>,
    worker: PrefetchWorker,
    /// Retry/backoff policy of the worker plus the compute-side stage
    /// deadline ([`RetryPolicy::stage_timeout_ms`]).
    retry: RetryPolicy,
    /// Staging counters (time, transfers, bytes, spawns, ring occupancy).
    pub stats: StreamerStats,
}

impl Streamer {
    /// Spawn the prefetch worker and stage the first unit ("buffers
    /// initialized and loaded at program start", paper §III-B), with the
    /// default double-buffer depth ([`DEFAULT_PREFETCH_DEPTH`]) and layer
    /// granularity.
    pub fn new(
        rt: Arc<Runtime>,
        fetcher: impl LayerFetcher + 'static,
        mode: SchedMode,
    ) -> Result<Self> {
        Self::with_depth(rt, fetcher, mode, DEFAULT_PREFETCH_DEPTH)
    }

    /// [`Streamer::new`] with an explicit staging-pipeline depth and layer
    /// granularity.
    ///
    /// `depth` counts the resident unit plus the ring: depth 2 is the
    /// classic double buffer (today's default), depth 1 disables
    /// prefetching entirely (every staging is inline, even in async
    /// mode), deeper rings absorb transfer-time jitter at the cost of
    /// `depth - 1` staged units of memory.  Depths beyond the walk length
    /// are legal — the ring then spans token boundaries.
    pub fn with_depth(
        rt: Arc<Runtime>,
        fetcher: impl LayerFetcher + 'static,
        mode: SchedMode,
        depth: usize,
    ) -> Result<Self> {
        Self::with_opts(rt, fetcher, mode, depth, StageGranularity::Layer)
    }

    /// [`Streamer::with_depth`] with an explicit [`StageGranularity`]
    /// (CLI `--stream-granularity`).  Matrix granularity streams each
    /// layer as five independent chunks; the ring depth then counts
    /// matrices, and memory cost per ring slot drops from a whole layer
    /// to one matrix.  Bit-identical to layer granularity at every depth.
    pub fn with_opts(
        rt: Arc<Runtime>,
        fetcher: impl LayerFetcher + 'static,
        mode: SchedMode,
        depth: usize,
        gran: StageGranularity,
    ) -> Result<Self> {
        Self::with_retry(rt, fetcher, mode, depth, gran, RetryPolicy::default())
    }

    /// [`Streamer::with_opts`] with an explicit staged-read
    /// [`RetryPolicy`]: how many times the worker retries a failed stage
    /// (capped exponential backoff) and how long the compute side waits
    /// on any one stage before surfacing a timeout error.
    pub fn with_retry(
        rt: Arc<Runtime>,
        fetcher: impl LayerFetcher + 'static,
        mode: SchedMode,
        depth: usize,
        gran: StageGranularity,
        retry: RetryPolicy,
    ) -> Result<Self> {
        Self::with_clock(rt, fetcher, mode, depth, gran, retry, Arc::new(SystemClock))
    }

    /// [`Streamer::with_retry`] with an explicit [`Clock`] driving the
    /// worker's retry-backoff sleeps.  Production callers stay on
    /// [`SystemClock`] (via [`Streamer::with_retry`]); tests inject a
    /// recording fake so backoff-timing assertions check the *schedule*
    /// the worker requested instead of measuring wall-clock time.
    pub fn with_clock(
        rt: Arc<Runtime>,
        fetcher: impl LayerFetcher + 'static,
        mode: SchedMode,
        depth: usize,
        gran: StageGranularity,
        retry: RetryPolicy,
        clock: Arc<dyn Clock>,
    ) -> Result<Self> {
        anyhow::ensure!(depth >= 1, "prefetch depth must be >= 1 (got {depth})");
        let n_layers = fetcher.n_layers();
        anyhow::ensure!(n_layers >= 1, "cannot stream a zero-layer model");
        let (req_tx, req_rx) = channel();
        let (resp_tx, resp_rx) = channel();
        let fetcher: Box<dyn LayerFetcher> = Box::new(fetcher);
        let handle = std::thread::Builder::new()
            .name("llamaf-prefetch".into())
            .spawn(move || prefetch_worker_loop(rt, fetcher, req_rx, resp_tx, retry, clock))
            .expect("spawn prefetch worker");
        let mut s = Streamer {
            mode,
            n_layers,
            depth,
            gran,
            current: None,
            pending: VecDeque::with_capacity(depth),
            worker: PrefetchWorker {
                req_tx: Some(req_tx),
                resp_rx,
                handle: Some(handle),
                abandoned: VecDeque::new(),
            },
            retry,
            stats: StreamerStats { spawns: 1, ring_depth: depth, ..StreamerStats::default() },
        };
        // stage the walk's first unit (construction staging is billed to
        // the worker totals but not to the blocked/decode counters)
        s.request(0)?;
        let (payload, staged_s, _wait_s) = s.wait_front()?;
        s.stats.total_transfer_s += staged_s;
        s.stats.transfers += 1;
        s.stats.staged_bytes += payload.stream_bytes() as u64;
        let mut cur = StagedLayer::empty(0);
        cur.fill(payload)?;
        s.current = Some(cur);
        Ok(s)
    }

    /// Staging units per layer (1 at layer granularity, [`STAGE_UNITS`]
    /// at matrix granularity).
    fn units_per_layer(&self) -> usize {
        match self.gran {
            StageGranularity::Layer => 1,
            StageGranularity::Matrix => STAGE_UNITS,
        }
    }

    /// Total ring-walk slots in one token (all layers).
    fn slot_count(&self) -> usize {
        self.n_layers * self.units_per_layer()
    }

    /// Map a ring-walk slot index to the staging unit it stands for.
    fn slot_unit(&self, slot: usize) -> StageUnit {
        match self.gran {
            StageGranularity::Layer => StageUnit::Layer(slot),
            StageGranularity::Matrix => {
                StageUnit::Matrix(slot / STAGE_UNITS, MATRIX_UNITS[slot % STAGE_UNITS])
            }
        }
    }

    /// Per-layer walk index a consumer's request for `u` maps to.
    fn target_idx(&self, u: MatrixUnit) -> usize {
        match self.gran {
            StageGranularity::Layer => 0, // one chunk carries everything
            StageGranularity::Matrix => u.index(),
        }
    }

    /// Ask the worker to stage slot `slot` (non-blocking; queued behind
    /// any earlier ring requests).
    fn request(&mut self, slot: usize) -> Result<()> {
        let tx = self
            .worker
            .req_tx
            .as_ref()
            .ok_or_else(|| anyhow!("streamer is shut down"))?;
        tx.send(StageReq::Stage { slot, unit: self.slot_unit(slot) })
            .map_err(|_| anyhow!("prefetch worker is gone (staging thread exited)"))?;
        self.pending.push_back(slot);
        Ok(())
    }

    /// Block until the *oldest* ring staging completes, bounded by the
    /// per-stage deadline ([`RetryPolicy::stage_timeout_ms`]).  Returns
    /// the staged payload, the worker-side staging seconds, and the
    /// seconds *this* thread spent waiting.  A dead worker (panicked
    /// fetcher/runtime) surfaces as an error here instead of a hang, and
    /// a stalled transfer surfaces as a timeout error — the slot is
    /// parked on the abandoned queue so its late answer is dropped
    /// without desequencing the ring.
    fn wait_front(&mut self) -> Result<(StagedPayload, f64, f64)> {
        let slot = self.pending.pop_front().expect("no staging in flight");
        let t = Instant::now();
        let deadline = Duration::from_millis(self.retry.stage_timeout_ms);
        loop {
            let Some(remaining) = deadline.checked_sub(t.elapsed()) else {
                self.worker.abandoned.push_back(slot);
                self.stats.stage_timeouts += 1;
                return Err(anyhow!(
                    "staging {:?} timed out after {} ms (stalled transfer?)",
                    self.slot_unit(slot),
                    self.retry.stage_timeout_ms
                ));
            };
            match self.worker.resp_rx.recv_timeout(remaining) {
                Ok(resp) => {
                    if !self.worker.abandoned.is_empty() {
                        // a late answer to a previously timed-out request:
                        // responses are FIFO and abandoned slots are older
                        // than everything pending, so drop positionally
                        self.worker.abandoned.pop_front();
                        continue;
                    }
                    let wait_s = t.elapsed().as_secs_f64();
                    self.stats.retries += u64::from(resp.retries);
                    anyhow::ensure!(
                        resp.slot == slot,
                        "prefetch worker answered slot {} for request {slot}",
                        resp.slot
                    );
                    match resp.result {
                        Ok(p) => return Ok((p, resp.staged_s, wait_s)),
                        Err(e) => {
                            self.stats.stage_faults += 1;
                            return Err(e);
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    self.worker.abandoned.push_back(slot);
                    self.stats.stage_timeouts += 1;
                    return Err(anyhow!(
                        "staging {:?} timed out after {} ms (stalled transfer?)",
                        self.slot_unit(slot),
                        self.retry.stage_timeout_ms
                    ));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(anyhow!(
                        "prefetch worker died while staging {:?} (panicked?)",
                        self.slot_unit(slot)
                    ));
                }
            }
        }
    }

    /// Drain the whole ring: every queued staging — including late
    /// answers to abandoned (timed-out) requests — is received and
    /// dropped (stale after a reset or an out-of-order access).  Discards
    /// are not billed to any counter; a dead worker is tolerated (the
    /// next `request` reports it).
    fn discard_all(&mut self) {
        while !self.worker.abandoned.is_empty() || !self.pending.is_empty() {
            match self.worker.resp_rx.recv() {
                Ok(_) => {
                    // FIFO: abandoned slots are older than pending ones
                    if self.worker.abandoned.pop_front().is_none() {
                        self.pending.pop_front();
                    }
                }
                Err(_) => {
                    self.worker.abandoned.clear();
                    self.pending.clear();
                    break;
                }
            }
        }
    }

    /// Obtain layer `li` with at least unit `u` staged, for compute.  In
    /// async mode this also tops the staging ring back up with the units
    /// the walk needs next (wrapping across token boundaries).  Under
    /// matrix granularity this is the sub-layer pipeline's consume point:
    /// asking for the QKV block does not wait for W2.
    pub fn unit(&mut self, li: usize, u: MatrixUnit) -> Result<&StagedLayer> {
        let target = self.target_idx(u);
        self.ensure(li, target)?;
        Ok(self.current.as_ref().expect("ensured above"))
    }

    /// Obtain layer `li` with EVERY unit staged (the layer-granular
    /// consume point; also used by whole-layer consumers under matrix
    /// granularity).
    pub fn layer(&mut self, li: usize) -> Result<&StagedLayer> {
        let target = self.units_per_layer() - 1;
        self.ensure(li, target)?;
        Ok(self.current.as_ref().expect("ensured above"))
    }

    /// Make `current` hold layer `li` staged through per-layer walk index
    /// `target`, consuming ring slots in order, then re-arm the ring.
    fn ensure(&mut self, li: usize, target: usize) -> Result<()> {
        if li >= self.n_layers {
            bail!("layer {li} out of range ({} layers)", self.n_layers);
        }
        let keep = matches!(&self.current, Some(sl) if sl.li == li);
        if !keep {
            // a different (or no) layer is current: start assembling `li`
            self.current = Some(StagedLayer::empty(li));
        }
        let upl = self.units_per_layer();
        loop {
            let filled = self.current.as_ref().expect("set above").filled;
            if filled > target {
                break;
            }
            self.consume(li * upl + filled)?;
        }
        if self.mode == SchedMode::Async && self.worker.req_tx.is_some() {
            self.rearm();
        }
        Ok(())
    }

    /// Consume ring slot `slot` into `current`, staging it inline (after
    /// discarding a stale ring) when the ring does not lead with it.
    fn consume(&mut self, slot: usize) -> Result<()> {
        let armed = self.pending.front() == Some(&slot);
        let occ = if armed { self.pending.len() } else { 0 };
        if !armed {
            // the ring does not lead with the needed unit (out-of-order
            // jump or broken sequence): discard it wholesale and stage
            // the unit inline via the worker
            self.discard_all();
            self.request(slot)?;
        }
        self.stats.ring_occupancy_sum += occ as u64;
        self.stats.ring_samples += 1;
        let (payload, staged_s, wait_s) = self.wait_front()?;
        self.stats.blocked_transfer_s += wait_s;
        if armed {
            // the staging ran in the background; we only waited for the
            // remainder (0 when the transfer was fully hidden).  Bucket
            // the wait by how full the ring was: waits at high occupancy
            // mean even a full ring cannot hide transfers.
            self.stats.prefetch_wait_s += wait_s;
            self.stats.prefetch_wait_by_occ_s[occ.min(RING_WAIT_BUCKETS - 1)] += wait_s;
        }
        // attribute the visible wait to the matrix unit it gated
        self.stats.wait_by_unit_s[slot % self.units_per_layer()] += wait_s;
        self.stats.total_transfer_s += staged_s;
        self.stats.transfers += 1;
        self.stats.staged_bytes += payload.stream_bytes() as u64;
        self.current.as_mut().expect("current set in ensure").fill(payload)
    }

    /// Ring-walk slot the consumer will need next (steady-state re-arm
    /// origin).
    fn next_slot(&self) -> usize {
        let upl = self.units_per_layer();
        match &self.current {
            Some(sl) => (sl.li * upl + sl.filled) % self.slot_count(),
            None => 0,
        }
    }

    /// Bring the ring back to "the next `depth - 1` units after the
    /// current consume point, in order".
    fn rearm(&mut self) {
        self.top_up(self.next_slot());
    }

    /// Make the ring hold the consecutive wrapping run starting at
    /// `first_needed`, up to its `depth - 1` capacity.  A ring that no
    /// longer matches that sequence (a reset or out-of-order access broke
    /// it) is discarded wholesale — otherwise the streamer would silently
    /// degrade to inline staging.  Send failures are deferred: the next
    /// consume that actually needs the worker reports them.  Shared by
    /// the steady-state re-arm and [`Streamer::reset`] so the two paths
    /// cannot drift apart.
    fn top_up(&mut self, first_needed: usize) {
        let cap = self.depth - 1;
        if cap == 0 {
            return; // depth 1: inline staging only, nothing to arm
        }
        let total = self.slot_count();
        let mut expect = first_needed;
        let mut consecutive = true;
        for &p in &self.pending {
            if p != expect {
                consecutive = false;
                break;
            }
            expect = (expect + 1) % total;
        }
        if !consecutive {
            self.discard_all();
        }
        let mut next = match self.pending.back() {
            Some(&p) => (p + 1) % total,
            None => first_needed,
        };
        while self.pending.len() < cap {
            if self.request(next).is_err() {
                break; // dead/shut-down worker: deferred to the next consume
            }
            next = (next + 1) % total;
        }
    }

    /// Rewind for a new generation (engine `reset`).  Drains any ring
    /// contents the post-reset walk cannot use and re-arms the ring from
    /// the unit the next token will need first, so async scheduling
    /// keeps hiding transfers across generations — including resets that
    /// land mid-token (and, under matrix granularity, mid-layer).
    pub fn reset(&mut self) {
        if self.mode != SchedMode::Async {
            return; // sync mode stages inline; nothing is in flight
        }
        // Units of layer 0 already staged in `current` are reused by the
        // post-reset walk (weights do not depend on the generation), so
        // the next staging needed is the first one `current` lacks;
        // anything else restarts at slot 0.
        let desired = match &self.current {
            Some(sl) if sl.li == 0 => sl.filled % self.slot_count(),
            _ => 0,
        };
        // re-point the ring at the post-reset walk: a ring already armed
        // for it (reset on a token boundary) is kept, anything else is
        // drained and re-requested; a dead/shut-down worker never panics
        // a reset (top_up defers send failures to the next consume)
        self.top_up(desired);
    }

    /// Shutdown handshake: drain the staging ring, tell the worker to
    /// exit, and join it.  Idempotent; [`Drop`] runs it too.  After
    /// shutdown every staging attempt fails fast instead of hanging.
    pub fn shutdown(&mut self) {
        self.discard_all();
        if let Some(tx) = self.worker.req_tx.take() {
            let _ = tx.send(StageReq::Shutdown);
        }
        if let Some(h) = self.worker.handle.take() {
            let _ = h.join();
        }
    }

    /// Layer index of the *oldest* ring staging, if any (the next one the
    /// walk would consume; test observability).
    pub fn pending_layer(&self) -> Option<usize> {
        self.pending.front().map(|&s| s / self.units_per_layer())
    }

    /// Oldest ring staging as a [`StageUnit`] (matrix-granular
    /// observability).
    pub fn pending_unit(&self) -> Option<StageUnit> {
        self.pending.front().map(|&s| self.slot_unit(s))
    }

    /// Number of armed stagings currently in the ring (in flight or
    /// completed and waiting to be consumed).
    pub fn ring_len(&self) -> usize {
        self.pending.len()
    }

    /// Configured staging-pipeline depth (resident slot + ring capacity).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Unit of staging this streamer pipelines.
    pub fn granularity(&self) -> StageGranularity {
        self.gran
    }

    /// Number of transformer layers this streamer cycles through.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Lifetime thread spawns (always 1: the persistent worker).  Pinned
    /// by tests so the per-layer spawn/join pattern cannot creep back into
    /// the decode hot path.
    pub fn thread_spawns(&self) -> u64 {
        self.stats.spawns
    }
}

impl crate::engine::forward::LayerProvider for Streamer {
    /// Streamed provision, matrix-granular: each accessor consumes the
    /// staging ring only up to the unit the forward pass actually needs,
    /// so compute on a layer's head matrices overlaps the transfer of its
    /// tail matrices (and the next layer's head).  One consume per
    /// (unit, step) regardless of how many lanes are decoded — the ~B×
    /// staging reduction of batched decoding.
    fn att_norm(&mut self, li: usize) -> Result<&[f32]> {
        Ok(self.unit(li, MatrixUnit::Norms)?.att_norm())
    }

    fn wqkv(&mut self, li: usize) -> Result<&QuantizedTensor> {
        Ok(&self.unit(li, MatrixUnit::Qkv)?.wqkv().host)
    }

    fn wo(&mut self, li: usize) -> Result<&QuantizedTensor> {
        Ok(&self.unit(li, MatrixUnit::Wo)?.wo().host)
    }

    fn ffn_norm(&mut self, li: usize) -> Result<&[f32]> {
        Ok(self.unit(li, MatrixUnit::Norms)?.ffn_norm())
    }

    fn w13(&mut self, li: usize) -> Result<&QuantizedTensor> {
        Ok(&self.unit(li, MatrixUnit::W13)?.w13().host)
    }

    fn w2(&mut self, li: usize) -> Result<&QuantizedTensor> {
        Ok(&self.unit(li, MatrixUnit::W2)?.w2().host)
    }
}

impl Drop for Streamer {
    fn drop(&mut self) {
        // Run the full handshake so no worker thread outlives the
        // streamer or touches PJRT state during process/engine teardown.
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Modeled timelines (paper-scale Fig. 2 / Table VI)
// ---------------------------------------------------------------------------

/// Per-layer modeled times.
#[derive(Clone, Copy, Debug)]
pub struct LayerTimes {
    /// Modeled DDR→PL staging time of one layer's weights.
    pub transfer_s: f64,
    /// Modeled kernel time of one layer's four GQMV launches.
    pub kernel_s: f64,
}

/// Kernel time of one layer = the four GQMV calls (Algorithm 2).
pub fn model_layer_kernel_time(cfg: &LlamaConfig, pl: &PlConfig) -> f64 {
    [MatKind::Qkv, MatKind::Wo, MatKind::W13, MatKind::W2]
        .iter()
        .map(|&k| {
            let (m, n) = cfg.mat_shape(k);
            pl.kernel_time_s(m, n, cfg.gs)
        })
        .sum()
}

/// Modeled per-layer transfer + kernel times.
pub fn model_layer_times(cfg: &LlamaConfig, pl: &PlConfig, axi: &AxiModel) -> LayerTimes {
    LayerTimes {
        transfer_s: axi.staging_time(cfg.layer_stream_bytes()),
        kernel_s: model_layer_kernel_time(cfg, pl),
    }
}

/// Modeled time of one token's *matrix pipeline* (all layers + classifier)
/// under each schedule.  Returns (sync_s, async_s).
pub fn sim_token_time(cfg: &LlamaConfig, pl: &PlConfig, axi: &AxiModel) -> (f64, f64) {
    let lt = model_layer_times(cfg, pl, axi);
    let (mc, nc) = cfg.mat_shape(MatKind::Cls);
    let cls = pl.kernel_time_s(mc, nc, cfg.gs);
    let l = cfg.n_layers as f64;
    // Sync: every layer pays transfer then kernel.
    let sync = l * (lt.transfer_s + lt.kernel_s) + cls;
    // Async: steady state pays max(transfer, kernel) per layer; transfers
    // wrap across tokens so even layer 0 is prefetched.
    let async_ = l * lt.transfer_s.max(lt.kernel_s) + cls;
    (sync, async_)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TINYLLAMA_1_1B;

    #[test]
    fn async_never_slower_in_model() {
        let (sync, async_) =
            sim_token_time(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        assert!(async_ <= sync);
    }

    #[test]
    fn paper_schedule_gain_shape() {
        // Paper: async scheduling gives 55.6-57.9% tok/s improvement over
        // no-scheduling *on the full token time*.  On the matrix pipeline
        // alone the gain is larger; assert the direction and magnitude
        // window here (full-token check lives in exp/table6).
        let (sync, async_) =
            sim_token_time(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        let gain = sync / async_;
        assert!(gain > 1.3 && gain < 2.2, "gain {gain}");
    }

    #[test]
    fn transfer_bound_regime() {
        // TinyLlama staging (~26ms/layer) vs kernel (~20ms/layer): the
        // design is transfer-bound, matching the paper's observation that
        // async hides *kernel-side* stalls (transfer > kernel).
        let lt = model_layer_times(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        assert!(lt.transfer_s > lt.kernel_s * 0.8, "{lt:?}");
        assert!(lt.transfer_s < lt.kernel_s * 2.5, "{lt:?}");
    }

    #[test]
    fn stage_mb_s_math_including_zero_transfer() {
        // the zero case must never divide by zero
        assert_eq!(StreamerStats::default().stage_mb_s(), 0.0);
        let s = StreamerStats {
            staged_bytes: 10_000_000,
            total_transfer_s: 2.0,
            ..StreamerStats::default()
        };
        assert!((s.stage_mb_s() - 5.0).abs() < 1e-12, "{}", s.stage_mb_s());
        let zero_bytes = StreamerStats { total_transfer_s: 1.0, ..StreamerStats::default() };
        assert_eq!(zero_bytes.stage_mb_s(), 0.0);
    }

    // Wall-clock Streamer behaviour at scale is covered by rust/tests/
    // integration tests (requires artifacts); prefetch-sequencing
    // regressions are pinned below on the sim runtime.
}

// The sim runtime can be constructed without artifacts (`with_shapes`), so
// the prefetch state machine is testable offline; the PJRT build covers
// the same paths through rust/tests/engine_e2e.rs.
#[cfg(all(test, not(feature = "pjrt")))]
mod streamer_tests {
    use super::*;
    use crate::model::{FloatModel, LlamaConfig, QuantModel};

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 4,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    fn setup(mode: SchedMode) -> (Streamer, Arc<Vec<QuantLayer>>) {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let s = Streamer::new(rt, MemFetcher { layers: Arc::clone(&layers) }, mode).unwrap();
        (s, layers)
    }

    fn assert_layer_is(s: &mut Streamer, li: usize, layers: &[QuantLayer]) {
        let got = s.layer(li).unwrap();
        assert_eq!(got.wqkv().host.q, layers[li].wqkv.q, "layer {li} staged wrong weights");
    }

    #[test]
    fn sequential_walk_keeps_prefetch_one_ahead() {
        let (mut s, layers) = setup(SchedMode::Async);
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
            assert_eq!(s.pending_layer(), Some((li + 1) % 4));
            // repeated access (the engine hits each layer 4x) must not
            // disturb the armed prefetch
            assert_layer_is(&mut s, li, &layers);
            assert_eq!(s.pending_layer(), Some((li + 1) % 4));
        }
        // wrap: next token's layer 0 is already in flight
        assert_layer_is(&mut s, 0, &layers);
    }

    #[test]
    fn wrong_prefetch_discard_rearms_next_layer() {
        let (mut s, layers) = setup(SchedMode::Async);
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), Some(1));
        // out-of-order jump: pending layer 1 is wrong for layer 2 ->
        // inline staging, and the prefetch must re-arm for layer 3
        assert_layer_is(&mut s, 2, &layers);
        assert_eq!(s.pending_layer(), Some(3), "prefetch not re-armed after discard");
        assert_layer_is(&mut s, 3, &layers);
        assert_eq!(s.pending_layer(), Some(0));
    }

    #[test]
    fn stale_pending_on_repeated_layer_is_replaced() {
        let (mut s, layers) = setup(SchedMode::Async);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers); // pending now 2
        s.reset(); // pending re-armed to 0
        assert_eq!(s.pending_layer(), Some(0));
        // current is layer 1; re-requesting it must not leave the stale
        // layer-0 prefetch parked forever
        assert_layer_is(&mut s, 1, &layers);
        assert_eq!(s.pending_layer(), Some(2), "stale pending must be replaced, not kept");
    }

    #[test]
    fn reset_mid_token_prefetches_layer0() {
        let (mut s, layers) = setup(SchedMode::Async);
        // mid-token: stop after layer 1 of a 4-layer model
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        assert_eq!(s.pending_layer(), Some(2));
        s.reset();
        assert_eq!(s.pending_layer(), Some(0), "reset must re-arm staging of layer 0");
        let transfers_before = s.stats.transfers;
        // the new generation consumes the prefetched layer 0 (one transfer,
        // not an extra discarded one) and keeps streaming ahead
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.stats.transfers, transfers_before + 1);
        assert_eq!(s.pending_layer(), Some(1));
        assert_layer_is(&mut s, 1, &layers);
        assert_layer_is(&mut s, 2, &layers);
    }

    #[test]
    fn reset_with_layer0_resident_prefetches_layer1() {
        let (mut s, layers) = setup(SchedMode::Async);
        // fresh streamer: layer 0 staged at construction, nothing pending
        s.reset();
        assert_eq!(s.pending_layer(), Some(1), "layer 0 resident -> stage layer 1");
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), Some(1));
    }

    #[test]
    fn staged_bytes_tracks_every_transfer() {
        let (mut s, layers) = setup(SchedMode::Async);
        let per = layers[0].stream_bytes() as u64;
        assert_eq!(s.stats.staged_bytes, per, "layer 0 staged at construction");
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
            // repeated access must not re-stage
            assert_layer_is(&mut s, li, &layers);
        }
        assert_eq!(s.stats.staged_bytes, s.stats.transfers * per);
        assert_eq!(s.stats.transfers, 4, "one staging per distinct layer");
        assert!(s.stats.stage_mb_s() > 0.0, "bandwidth derivable once transfers ran");
    }

    #[test]
    fn sync_mode_reset_arms_nothing() {
        let (mut s, layers) = setup(SchedMode::Sync);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        s.reset();
        assert_eq!(s.pending_layer(), None);
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), None);
    }

    /// Fetcher that records which OS thread performs each fetch — the
    /// behavioral probe behind the zero-spawn guarantee.
    struct TidFetcher {
        inner: MemFetcher,
        tids: Arc<std::sync::Mutex<std::collections::HashSet<std::thread::ThreadId>>>,
    }

    impl LayerFetcher for TidFetcher {
        fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
            self.tids.lock().unwrap().insert(std::thread::current().id());
            self.inner.fetch(layer)
        }

        fn n_layers(&self) -> usize {
            self.inner.n_layers()
        }
    }

    #[test]
    fn steady_state_decode_spawns_zero_threads() {
        // The acceptance criterion of the persistent-worker refactor:
        // across a multi-step run (several full layer walks, resets
        // between generations, an out-of-order jump), EVERY staging runs
        // on one long-lived worker thread — reintroducing a per-layer
        // spawn/join pattern would record one fresh ThreadId per staging
        // and fail the distinct-thread assertion below.
        for mode in [SchedMode::Async, SchedMode::Sync] {
            let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
            let layers = Arc::new(qm.layers);
            let tids = Arc::new(std::sync::Mutex::new(std::collections::HashSet::new()));
            let fetcher = TidFetcher {
                inner: MemFetcher { layers: Arc::clone(&layers) },
                tids: Arc::clone(&tids),
            };
            let rt = Arc::new(Runtime::with_shapes(&[]));
            let mut s = Streamer::new(rt, fetcher, mode).unwrap();
            assert_eq!(s.thread_spawns(), 1, "one worker spawned at construction");
            for _gen in 0..3 {
                for li in 0..4 {
                    assert_layer_is(&mut s, li, &layers);
                }
                s.reset();
            }
            assert_layer_is(&mut s, 2, &layers); // out-of-order: inline path
            assert!(s.stats.transfers >= 12, "the walks really staged layers");
            s.shutdown(); // join so no fetch is mid-flight while we read
            let tids = tids.lock().unwrap();
            assert_eq!(
                tids.len(),
                1,
                "all stagings must run on ONE persistent thread ({mode:?}), saw {tids:?}"
            );
            assert!(
                !tids.contains(&std::thread::current().id()),
                "staging must happen off the compute thread ({mode:?})"
            );
            assert_eq!(s.thread_spawns(), 1, "spawn counter stays at the worker ({mode:?})");
        }
    }

    #[test]
    fn shutdown_joins_cleanly_and_fails_fast_after() {
        let (mut s, layers) = setup(SchedMode::Async);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers); // a prefetch is now in flight
        s.shutdown();
        s.shutdown(); // idempotent
        assert_eq!(s.pending_layer(), None, "shutdown discards in-flight staging");
        // the resident layer is still readable (no use-after-shutdown of
        // staged buffers)...
        assert_layer_is(&mut s, 1, &layers);
        // ...but anything needing the worker errors instead of hanging
        let err = s.layer(2).unwrap_err().to_string();
        assert!(err.contains("shut down"), "{err}");
        s.reset(); // must not panic after shutdown
    }

    /// Fetcher that panics when asked for one specific layer — models a
    /// staging-path bug inside the worker.
    struct PanicFetcher {
        layers: Arc<Vec<QuantLayer>>,
        panic_on: usize,
    }

    impl LayerFetcher for PanicFetcher {
        fn fetch(&mut self, layer: usize) -> anyhow::Result<QuantLayer> {
            assert_ne!(layer, self.panic_on, "injected staging panic");
            Ok(self.layers[layer].clone())
        }

        fn n_layers(&self) -> usize {
            self.layers.len()
        }
    }

    #[test]
    fn panicked_worker_surfaces_error_not_hang() {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 43));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = PanicFetcher { layers: Arc::clone(&layers), panic_on: 2 };
        let mut s = Streamer::new(rt, fetcher, SchedMode::Async).unwrap();
        s.layer(0).unwrap(); // arms 1
        s.layer(1).unwrap(); // consumes 1, arms 2 -> worker panics
        let err = s.layer(2).unwrap_err().to_string();
        assert!(err.contains("worker died"), "{err}");
        // every later staging attempt keeps failing fast (worker is gone)
        let err = s.layer(3).unwrap_err().to_string();
        assert!(err.contains("worker"), "{err}");
        s.reset(); // tolerated: reset never panics on a dead worker
    }

    #[test]
    fn worker_panic_during_construction_is_an_error() {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 44));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = PanicFetcher { layers, panic_on: 0 };
        assert!(Streamer::new(rt, fetcher, SchedMode::Sync).is_err());
    }

    // ------------------------------------------------------------------
    // Depth-N staging ring
    // ------------------------------------------------------------------

    fn setup_depth(mode: SchedMode, depth: usize) -> (Streamer, Arc<Vec<QuantLayer>>) {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = MemFetcher { layers: Arc::clone(&layers) };
        let s = Streamer::with_depth(rt, fetcher, mode, depth).unwrap();
        (s, layers)
    }

    #[test]
    fn depth_walks_bit_identical() {
        // depth 1 (inline), 2 (double buffer) and 4 (deep ring) must all
        // hand out exactly the same layer bytes over a multi-generation
        // walk — pipeline depth is a latency knob, never a data path
        for depth in [1usize, 2, 4] {
            let (mut s, layers) = setup_depth(SchedMode::Async, depth);
            assert_eq!(s.depth(), depth);
            for _gen in 0..3 {
                for li in 0..4 {
                    assert_layer_is(&mut s, li, &layers);
                    assert!(s.ring_len() <= depth.saturating_sub(1), "ring over capacity");
                }
                s.reset();
            }
            if depth == 1 {
                assert_eq!(s.pending_layer(), None, "depth 1 must never arm a prefetch");
                assert_eq!(s.stats.ring_occupancy_mean(), 0.0);
            } else {
                assert!(
                    s.stats.ring_occupancy_mean() > 0.0,
                    "depth {depth}: armed consumes must be observed"
                );
            }
        }
    }

    #[test]
    fn deep_ring_runs_ahead_and_wraps_tokens() {
        let (mut s, layers) = setup_depth(SchedMode::Async, 4);
        // first access fills the ring with the NEXT THREE layers
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.ring_len(), 3);
        assert_eq!(s.pending_layer(), Some(1));
        // walking consumes from the front while the tail tops up — across
        // the token boundary (4-layer model: ring after layer 2 holds
        // [3, 0, 1], i.e. next token's head layers)
        assert_layer_is(&mut s, 1, &layers);
        assert_layer_is(&mut s, 2, &layers);
        assert_eq!(s.pending_layer(), Some(3));
        assert_eq!(s.ring_len(), 3);
        assert_layer_is(&mut s, 3, &layers);
        assert_eq!(s.pending_layer(), Some(0), "ring wraps into the next token");
        // second token consumes the wrapped prefetches without re-staging
        let transfers = s.stats.transfers;
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.stats.transfers, transfers + 1, "wrapped prefetch must be consumed");
    }

    #[test]
    fn reset_mid_ring_rearms_cleanly() {
        let (mut s, layers) = setup_depth(SchedMode::Async, 4);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        assert_eq!(s.pending_layer(), Some(2), "ring leads with layer 2 mid-token");
        s.reset();
        // current is layer 1, so the post-reset walk needs 0 first; the
        // stale [2, 3, 0] ring must be drained and re-armed as [0, 1, 2]
        assert_eq!(s.pending_layer(), Some(0), "reset must re-arm the ring at layer 0");
        assert_eq!(s.ring_len(), 3);
        let transfers = s.stats.transfers;
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
        }
        assert_eq!(s.stats.transfers, transfers + 4, "post-reset walk stages each layer once");
    }

    #[test]
    fn reset_preserves_usable_ring() {
        // a reset landing exactly at a token boundary finds the ring
        // already armed for the next token — it must keep it, not thrash
        let (mut s, layers) = setup_depth(SchedMode::Async, 3);
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
        }
        // after layer 3 the ring holds [0, 1] — exactly the post-reset need
        assert_eq!(s.pending_layer(), Some(0));
        let transfers = s.stats.transfers;
        s.reset();
        assert_eq!(s.pending_layer(), Some(0), "usable ring survives reset");
        assert_eq!(s.ring_len(), 2);
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.stats.transfers, transfers + 1, "no extra stagings after no-op reset");
    }

    #[test]
    fn worker_panic_with_full_ring_surfaces_error() {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 45));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = PanicFetcher { layers: Arc::clone(&layers), panic_on: 2 };
        let mut s = Streamer::with_depth(rt, fetcher, SchedMode::Async, 4).unwrap();
        // layer(0) arms [1, 2, 3]; the worker stages 1, then dies on 2
        s.layer(0).unwrap();
        // layer 1 was staged before the panic: still consumable
        s.layer(1).unwrap();
        // layer 2's staging died with the worker: error, never a hang
        let err = s.layer(2).unwrap_err().to_string();
        assert!(err.contains("worker died"), "{err}");
        let err = s.layer(3).unwrap_err().to_string();
        assert!(err.contains("worker"), "{err}");
        s.reset(); // tolerated on a dead worker
        s.shutdown(); // drains whatever the dead worker left behind
    }

    #[test]
    fn invalid_depth_rejected() {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 46));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = MemFetcher { layers };
        assert!(Streamer::with_depth(rt, fetcher, SchedMode::Async, 0).is_err());
    }

    #[test]
    fn ring_wait_accounting_buckets_by_occupancy() {
        let (mut s, layers) = setup_depth(SchedMode::Async, 4);
        for _gen in 0..2 {
            for li in 0..4 {
                assert_layer_is(&mut s, li, &layers);
            }
        }
        let by_occ: f64 = s.stats.prefetch_wait_by_occ_s.iter().sum();
        assert!(
            (by_occ - s.stats.prefetch_wait_s).abs() <= 1e-9,
            "bucketed waits {by_occ} must sum to prefetch_wait_s {}",
            s.stats.prefetch_wait_s
        );
        assert_eq!(s.stats.ring_depth, 4);
        assert!(s.stats.ring_samples >= 7, "every staged consume sampled");
        assert!(s.stats.ring_occupancy_mean() > 0.0);
        assert!(s.stats.ring_occupancy_mean() <= 3.0, "occupancy bounded by ring capacity");
    }

    // ------------------------------------------------------------------
    // Matrix-granular staging (the sub-layer pipeline)
    // ------------------------------------------------------------------

    fn setup_matrix(mode: SchedMode, depth: usize) -> (Streamer, Arc<Vec<QuantLayer>>) {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = MemFetcher { layers: Arc::clone(&layers) };
        let s = Streamer::with_opts(rt, fetcher, mode, depth, StageGranularity::Matrix).unwrap();
        (s, layers)
    }

    /// Full-layer equality check against the fused source layer — every
    /// chunk, not just wqkv.
    fn assert_full_layer_is(s: &mut Streamer, li: usize, layers: &[QuantLayer]) {
        let got = s.layer(li).unwrap();
        assert_eq!(got.att_norm(), &layers[li].att_norm[..], "layer {li} att_norm");
        assert_eq!(got.ffn_norm(), &layers[li].ffn_norm[..], "layer {li} ffn_norm");
        assert_eq!(got.wqkv().host, layers[li].wqkv, "layer {li} wqkv");
        assert_eq!(got.wo().host, layers[li].wo, "layer {li} wo");
        assert_eq!(got.w13().host, layers[li].w13, "layer {li} w13");
        assert_eq!(got.w2().host, layers[li].w2, "layer {li} w2");
    }

    #[test]
    fn matrix_granularity_walks_bit_identical_at_every_depth() {
        // matrix-granular staging is a latency knob, never a data path:
        // every chunk handed out must equal the fused layer bytes, across
        // depths, generations and resets
        for depth in [1usize, 2, 4, 8] {
            let (mut s, layers) = setup_matrix(SchedMode::Async, depth);
            assert_eq!(s.granularity(), StageGranularity::Matrix);
            for _gen in 0..3 {
                for li in 0..4 {
                    assert_full_layer_is(&mut s, li, &layers);
                    assert!(s.ring_len() <= depth.saturating_sub(1), "ring over capacity");
                }
                s.reset();
            }
            // 3 generations x 4 layers x 5 chunks, each staged exactly once
            assert_eq!(s.stats.transfers, 3 * 4 * STAGE_UNITS as u64);
        }
    }

    #[test]
    fn matrix_chunks_consumed_in_order_while_ring_runs_ahead() {
        let (mut s, layers) = setup_matrix(SchedMode::Async, 4);
        // construction staged layer 0's norms; asking for the QKV block
        // consumes through it WITHOUT waiting for wo/w13/w2
        let sl = s.unit(0, MatrixUnit::Qkv).unwrap();
        assert_eq!(sl.wqkv().host, layers[0].wqkv);
        // the ring leads with the next chunk of the same layer
        assert_eq!(s.pending_unit(), Some(StageUnit::Matrix(0, MatrixUnit::Wo)));
        assert_eq!(s.ring_len(), 3);
        // consuming the rest of the layer rolls the ring into layer 1
        assert_full_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_unit(), Some(StageUnit::Matrix(1, MatrixUnit::Norms)));
        assert_eq!(s.pending_layer(), Some(1));
        // repeated access to an already-staged unit consumes nothing
        let transfers = s.stats.transfers;
        s.unit(0, MatrixUnit::Wo).unwrap();
        assert_eq!(s.stats.transfers, transfers);
    }

    #[test]
    fn matrix_ring_spans_layer_and_token_boundaries() {
        // a deep ring in matrix granularity runs across layers AND the
        // token wrap: after the last chunk of layer 3, the ring holds the
        // next token's layer-0 chunks
        let (mut s, layers) = setup_matrix(SchedMode::Async, 6);
        for li in 0..4 {
            assert_full_layer_is(&mut s, li, &layers);
        }
        assert_eq!(s.pending_unit(), Some(StageUnit::Matrix(0, MatrixUnit::Norms)));
        assert_eq!(s.ring_len(), 5);
        let transfers = s.stats.transfers;
        // next token consumes the wrapped prefetches without re-staging
        assert_full_layer_is(&mut s, 0, &layers);
        assert_eq!(s.stats.transfers, transfers + STAGE_UNITS as u64);
    }

    #[test]
    fn matrix_out_of_order_jump_discards_and_restages() {
        let (mut s, layers) = setup_matrix(SchedMode::Async, 3);
        assert_full_layer_is(&mut s, 0, &layers);
        // jump over layer 1: the armed layer-1 chunks are stale
        assert_full_layer_is(&mut s, 2, &layers);
        // the ring must lead with layer 3's first chunk afterwards
        assert_eq!(s.pending_unit(), Some(StageUnit::Matrix(3, MatrixUnit::Norms)));
        assert_full_layer_is(&mut s, 3, &layers);
    }

    #[test]
    fn matrix_reset_mid_layer_rearms_from_missing_chunk() {
        let (mut s, _layers) = setup_matrix(SchedMode::Async, 4);
        // consume layer 0 fully, then only the head of layer 1
        s.layer(0).unwrap();
        s.unit(1, MatrixUnit::Qkv).unwrap();
        s.reset();
        // post-reset walk starts at layer 0 unit 0; current (partial
        // layer 1) cannot serve it, so the ring re-arms at slot 0
        assert_eq!(s.pending_unit(), Some(StageUnit::Matrix(0, MatrixUnit::Norms)));
        // a reset with PARTIAL layer 0 keeps the staged head and re-arms
        // at the first missing chunk
        let (mut s2, _layers2) = setup_matrix(SchedMode::Async, 4);
        // fresh streamer: only layer 0's norms staged at construction
        s2.reset();
        assert_eq!(
            s2.pending_unit(),
            Some(StageUnit::Matrix(0, MatrixUnit::Qkv)),
            "reset must not re-stage the already-resident norms chunk"
        );
    }

    #[test]
    fn matrix_wait_attribution_sums_and_lands_per_unit() {
        let (mut s, layers) = setup_matrix(SchedMode::Async, 4);
        for _gen in 0..2 {
            for li in 0..4 {
                assert_full_layer_is(&mut s, li, &layers);
            }
        }
        let by_unit: f64 = s.stats.wait_by_unit_s.iter().sum();
        assert!(
            (by_unit - s.stats.blocked_transfer_s).abs() <= 1e-9,
            "per-unit waits {by_unit} must sum to blocked_transfer_s {}",
            s.stats.blocked_transfer_s
        );
        // layer granularity attributes everything to the first unit
        let (mut sl, layers_l) = setup_depth(SchedMode::Async, 2);
        for li in 0..4 {
            assert_layer_is(&mut sl, li, &layers_l);
        }
        let tail: f64 = sl.stats.wait_by_unit_s[1..].iter().sum();
        assert_eq!(tail, 0.0, "layer granularity waits land in unit bucket 0 only");
        let head: f64 = sl.stats.wait_by_unit_s[0];
        assert!((head - sl.stats.blocked_transfer_s).abs() <= 1e-9);
    }

    #[test]
    fn matrix_granularity_sync_mode_stages_inline() {
        let (mut s, layers) = setup_matrix(SchedMode::Sync, 2);
        for li in 0..4 {
            assert_full_layer_is(&mut s, li, &layers);
            assert_eq!(s.pending_layer(), None, "sync mode must never arm the ring");
        }
    }

    #[test]
    fn matrix_staged_bytes_sum_to_layer_bytes() {
        let (mut s, layers) = setup_matrix(SchedMode::Async, 2);
        let per_layer = layers[0].stream_bytes() as u64;
        for li in 0..4 {
            assert_full_layer_is(&mut s, li, &layers);
        }
        assert_eq!(
            s.stats.staged_bytes,
            4 * per_layer,
            "five chunks per layer must sum exactly to the layer's stream bytes"
        );
    }

    // ------------------------------------------------------------------
    // Staged-read retry, fault surfacing, and the stage deadline
    // ------------------------------------------------------------------

    /// Streamer over a [`FaultyFetcher`]-wrapped [`MemFetcher`] with an
    /// explicit retry policy (backoff zeroed so tests run fast).
    fn setup_faulty(
        spec: &str,
        retry: RetryPolicy,
    ) -> Result<(Streamer, Arc<Vec<QuantLayer>>)> {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let plan = FaultPlan::parse(spec).unwrap();
        let fetcher = FaultyFetcher::new(MemFetcher { layers: Arc::clone(&layers) }, plan);
        let s = Streamer::with_retry(
            rt,
            fetcher,
            SchedMode::Async,
            DEFAULT_PREFETCH_DEPTH,
            StageGranularity::Layer,
            retry,
        )?;
        Ok((s, layers))
    }

    fn fast_retry() -> RetryPolicy {
        RetryPolicy { backoff_ms: 0, backoff_cap_ms: 0, ..RetryPolicy::default() }
    }

    #[test]
    fn flaky_fetch_is_retried_transparently() {
        // one scripted read error at layer 1: the worker's retry absorbs
        // it, the walk sees no error, and the recovery cost is visible
        // only in the retry counter — transfers/bytes match a clean run
        let (mut s, layers) = setup_faulty("at=1/any/readerr", fast_retry()).unwrap();
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
        }
        assert_eq!(s.stats.retries, 1, "exactly one failed attempt retried");
        assert_eq!(s.stats.stage_faults, 0, "no fault surfaced to compute");
        assert_eq!(s.stats.stage_timeouts, 0);
        assert_eq!(s.stats.transfers, 4, "retries are not billed as transfers");
    }

    #[test]
    fn exhausted_retries_surface_error_and_ring_survives() {
        // layer 2 fails on EVERY attempt: after max_attempts the error
        // surfaces to the compute side, but the worker and ring stay up —
        // other layers keep staging
        let (mut s, layers) = setup_faulty("at=2/any/readerr/always", fast_retry()).unwrap();
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        let e = s.layer(2).unwrap_err();
        let chain = format!("{e:#}");
        assert!(chain.contains("injected fault"), "{chain}");
        assert!(chain.contains("failed after 3 attempts"), "{chain}");
        assert_eq!(s.stats.stage_faults, 1);
        assert_eq!(s.stats.retries, 2, "two retries before giving up");
        // the ring recovers: a different layer stages fine afterwards
        assert_layer_is(&mut s, 3, &layers);
    }

    #[test]
    fn stall_past_deadline_is_a_timeout_not_a_hang() {
        // layer 1 stalls 300 ms on every fetch; the stage deadline is
        // 40 ms, so the wait surfaces as a timeout error — and the late
        // answer is drained (abandoned-slot discipline), letting the
        // walk continue on other layers
        let retry = RetryPolicy { stage_timeout_ms: 40, ..fast_retry() };
        let (mut s, layers) =
            setup_faulty("stall_ms=300,at=1/any/stall/always", retry).unwrap();
        assert_layer_is(&mut s, 0, &layers);
        let e = s.layer(1).unwrap_err().to_string();
        assert!(e.contains("timed out after 40 ms"), "{e}");
        assert_eq!(s.stats.stage_timeouts, 1);
        // skipping the stalled layer works: discard_all absorbs the late
        // response before restaging, so the ring never desequences
        assert_layer_is(&mut s, 2, &layers);
        assert_layer_is(&mut s, 3, &layers);
        s.shutdown(); // clean join even after an abandoned slot
    }

    #[test]
    fn default_fault_plan_is_a_passthrough() {
        let (mut s, layers) = setup_faulty("p=0.0", RetryPolicy::default()).unwrap();
        for _gen in 0..2 {
            for li in 0..4 {
                assert_layer_is(&mut s, li, &layers);
            }
            s.reset();
        }
        assert_eq!(s.stats.retries, 0);
        assert_eq!(s.stats.stage_faults, 0);
        assert_eq!(s.stats.stage_timeouts, 0);
    }

    // ------------------------------------------------------------------
    // Deterministic backoff: assert the requested schedule, not the wall
    // clock — these tests are immune to CI runner speed
    // ------------------------------------------------------------------

    /// Recording [`Clock`]: never sleeps, just logs each requested
    /// duration in milliseconds.
    #[derive(Default)]
    struct TestClock {
        sleeps_ms: std::sync::Mutex<Vec<u64>>,
    }

    impl Clock for TestClock {
        fn sleep(&self, d: Duration) {
            self.sleeps_ms.lock().unwrap().push(d.as_millis() as u64);
        }
    }

    /// [`setup_faulty`] with an injected recording clock in place of
    /// [`SystemClock`].
    fn setup_faulty_clock(
        spec: &str,
        retry: RetryPolicy,
        clock: Arc<TestClock>,
    ) -> Result<(Streamer, Arc<Vec<QuantLayer>>)> {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let plan = FaultPlan::parse(spec).unwrap();
        let fetcher = FaultyFetcher::new(MemFetcher { layers: Arc::clone(&layers) }, plan);
        let s = Streamer::with_clock(
            rt,
            fetcher,
            SchedMode::Async,
            DEFAULT_PREFETCH_DEPTH,
            StageGranularity::Layer,
            retry,
            clock,
        )?;
        Ok((s, layers))
    }

    #[test]
    fn one_shot_retry_sleeps_exactly_once_at_initial_backoff() {
        // the PR 9 transparent-retry contract, now with the REAL default
        // backoff (2 ms) instead of a zeroed one: the worker requests
        // exactly one sleep of backoff_ms, and staging stays bit-exact
        let clock = Arc::new(TestClock::default());
        let (mut s, layers) =
            setup_faulty_clock("at=1/any/readerr", RetryPolicy::default(), Arc::clone(&clock))
                .unwrap();
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
        }
        assert_eq!(s.stats.retries, 1);
        assert_eq!(*clock.sleeps_ms.lock().unwrap(), vec![2], "one sleep at backoff_ms");
    }

    #[test]
    fn backoff_schedule_doubles_exactly_under_a_mock_clock() {
        // layer 1 fails every attempt; max_attempts 4 means 3 retries,
        // each preceded by one backoff sleep: exactly 2, 4, 8 ms
        let clock = Arc::new(TestClock::default());
        let retry = RetryPolicy { max_attempts: 4, ..RetryPolicy::default() };
        let (mut s, layers) =
            setup_faulty_clock("at=1/any/readerr/always", retry, Arc::clone(&clock)).unwrap();
        assert_layer_is(&mut s, 0, &layers);
        let e = s.layer(1).unwrap_err();
        assert!(format!("{e:#}").contains("failed after 4 attempts"), "{e:#}");
        assert_eq!(s.stats.retries, 3);
        assert_eq!(*clock.sleeps_ms.lock().unwrap(), vec![2, 4, 8], "exact doubling schedule");
    }

    #[test]
    fn backoff_cap_clamps_the_schedule() {
        // cap at 4 ms: the doubling sequence 2, 4, 8, 16 clamps to
        // 2, 4, 4, 4 from the third sleep on
        let clock = Arc::new(TestClock::default());
        let retry =
            RetryPolicy { max_attempts: 5, backoff_cap_ms: 4, ..RetryPolicy::default() };
        let (mut s, layers) =
            setup_faulty_clock("at=2/any/readerr/always", retry, Arc::clone(&clock)).unwrap();
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        let e = s.layer(2).unwrap_err();
        assert!(format!("{e:#}").contains("failed after 5 attempts"), "{e:#}");
        assert_eq!(s.stats.retries, 4);
        assert_eq!(*clock.sleeps_ms.lock().unwrap(), vec![2, 4, 4, 4], "cap binds from 8 on");
    }
}
