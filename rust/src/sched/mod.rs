//! Task-level weight-streaming scheduler (paper §III-B, Fig. 2).
//!
//! The quantized model lives in "DDR" (the LFQ8 file / an in-memory layer
//! store); only a small number of per-layer buffers exist device-side.
//! For every token, each layer's weights must be staged host→device before
//! its GQMV kernels can run.  Two schedules:
//!
//! * **Sync** — stage layer *l*, then compute layer *l* (Fig. 2 top).
//! * **Async** — while layer *l* computes, the prefetch worker stages
//!   layer *l+1* (wrapping to layer 0 for the next token), hiding the
//!   transfer behind the kernel (Fig. 2 bottom).  First-layer weights are
//!   staged at start-up, exactly as the paper initializes its buffers.
//!
//! All staging runs on one **persistent prefetch worker** — a long-lived
//! thread owning the fetcher, fed requests over a channel with explicit
//! reset/shutdown handshakes — so steady-state decode performs zero
//! thread spawns (the old design spawned and joined one OS thread per
//! staged layer).
//!
//! The async schedule runs the worker ahead through a **depth-N staging
//! ring** ([`Streamer::with_depth`], CLI `--prefetch-depth N`): up to
//! N−1 future layers are requested while the current one computes, so a
//! single slow transfer (a DDR stall, a disk hiccup in `DiskFetcher`)
//! drains the ring instead of stalling the compute thread.  Depth 2 is
//! the classic double buffer (one resident layer + one in flight) and
//! the default; depth 1 degenerates to inline staging.  `layer(li)` pops
//! the ring in order, discarding it wholesale whenever the requested
//! sequence breaks (out-of-order access, [`Streamer::reset`]);
//! [`StreamerStats`] tracks ring occupancy and buckets every prefetch
//! wait by the occupancy at the time of the wait.
//!
//! The same module also provides the *modeled* timeline
//! ([`sim_token_time`]) used to regenerate Fig. 2 / Table VI at paper
//! scale, where transfer and kernel times come from the AXI and dataflow
//! models rather than wall-clock.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::ckpt::Q8LayerSource;
use crate::fpga::{AxiModel, PlConfig};
use crate::model::{LlamaConfig, MatKind, QuantLayer};
use crate::runtime::{DeviceWeights, Runtime};

/// Scheduling policy for weight staging.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Stage layer *l*, then compute layer *l* (Fig. 2 top).
    Sync,
    /// Prefetch layer *l+1* while layer *l* computes (Fig. 2 bottom).
    Async,
}

/// A layer staged on the device: host copies (norm vectors + shapes) plus
/// device-resident GQMV weight buffers.
pub struct PreparedLayer {
    /// Host-side staged copy (norm vectors + the quantized matrices).
    pub host: QuantLayer,
    /// Device buffer of the fused Wq‖Wk‖Wv matrix.
    pub wqkv: DeviceWeights,
    /// Device buffer of Wo.
    pub wo: DeviceWeights,
    /// Device buffer of the fused W1‖W3 matrix.
    pub w13: DeviceWeights,
    /// Device buffer of W2.
    pub w2: DeviceWeights,
}

/// Source of host-side layer weights ("DDR").
pub trait LayerFetcher: Send {
    /// Produce a host copy of layer `layer`'s weights.
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer>;
    /// Number of transformer layers this source serves.
    fn n_layers(&self) -> usize;
}

/// Streams layers from an LFQ8 file (real disk I/O per fetch).
pub struct DiskFetcher {
    src: Q8LayerSource,
}

impl DiskFetcher {
    /// Open an LFQ8 checkpoint for layer-at-a-time streaming.
    pub fn open(path: &std::path::Path) -> Result<Self> {
        Ok(DiskFetcher { src: Q8LayerSource::open(path)? })
    }

    /// Model geometry read from the checkpoint header.
    pub fn cfg(&self) -> LlamaConfig {
        self.src.cfg
    }
}

impl LayerFetcher for DiskFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.src.fetch_layer(layer)
    }

    fn n_layers(&self) -> usize {
        self.src.cfg.n_layers
    }
}

/// Serves layers from memory, cloning on fetch (models the memcpy from the
/// mmap'd model into the pinned kernel buffer — the staging the paper's
/// async schedule hides).
pub struct MemFetcher {
    /// The in-memory layer store shared with the owner of the weights.
    pub layers: Arc<Vec<QuantLayer>>,
}

impl LayerFetcher for MemFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.layers
            .get(layer)
            .cloned()
            .with_context(|| format!("layer {layer} out of range"))
    }

    fn n_layers(&self) -> usize {
        self.layers.len()
    }
}

/// Serves layers out of a shared [`crate::model::QuantModel`]
/// (clone-on-fetch, like
/// [`MemFetcher`], without duplicating the layer store).  This is how the
/// batch scheduler streams weights: the `Arc`'d model *is* the "DDR", and
/// each fetch is the staging memcpy that the async prefetch thread hides
/// behind the batched kernels.
pub struct ModelFetcher {
    /// The shared quantized model whose layers are streamed.
    pub model: Arc<crate::model::QuantModel>,
}

impl LayerFetcher for ModelFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.model
            .layers
            .get(layer)
            .cloned()
            .with_context(|| format!("layer {layer} out of range"))
    }

    fn n_layers(&self) -> usize {
        self.model.layers.len()
    }
}

fn stage(rt: &Runtime, host: QuantLayer) -> Result<PreparedLayer> {
    let wqkv = rt.upload(&host.wqkv)?;
    let wo = rt.upload(&host.wo)?;
    let w13 = rt.upload(&host.w13)?;
    let w2 = rt.upload(&host.w2)?;
    Ok(PreparedLayer { host, wqkv, wo, w13, w2 })
}

/// Default staging-pipeline depth: the classic double buffer (one layer
/// resident, one prefetch in flight).
pub const DEFAULT_PREFETCH_DEPTH: usize = 2;

/// Bucket count of [`StreamerStats::prefetch_wait_by_occ_s`]: waits are
/// indexed by the ring occupancy observed when the wait began, clamped to
/// the last bucket.
pub const RING_WAIT_BUCKETS: usize = 9;

/// Staging counters of a [`Streamer`] (Fig. 2 accounting plus the serving
/// metrics exported through `STATS`).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamerStats {
    /// Time the compute thread *blocked* on staging (visible latency:
    /// inline stagings plus waits on armed prefetches).
    pub blocked_transfer_s: f64,
    /// Of [`StreamerStats::blocked_transfer_s`], the part spent waiting on
    /// an *armed* (background) prefetch — ~0 when the async schedule hides
    /// transfers fully, rising toward the full staging time when the
    /// design is transfer-bound.
    pub prefetch_wait_s: f64,
    /// [`StreamerStats::prefetch_wait_s`] broken down by the ring
    /// occupancy (armed stagings in flight or ready) at the moment the
    /// wait began — the per-depth accounting of the staging ring.  A
    /// deeper ring should move waits into higher-occupancy buckets and
    /// shrink them: a wait at occupancy N−1 means even a full ring could
    /// not hide the transfer (truly bandwidth-bound), while waits piled
    /// at occupancy 1 mean more depth would help.
    pub prefetch_wait_by_occ_s: [f64; RING_WAIT_BUCKETS],
    /// Total staging work performed by the worker (foreground +
    /// background).
    pub total_transfer_s: f64,
    /// Number of layer stagings performed.
    pub transfers: u64,
    /// Total weight bytes staged host→device (streamed representation:
    /// int8 data + f32 scales + norms).  The batched-decoding win is this
    /// counter growing per *step* instead of per *session-token*.
    pub staged_bytes: u64,
    /// OS threads this streamer has spawned over its lifetime.  Exactly 1
    /// (the persistent prefetch worker, spawned at construction): the
    /// steady-state decode path performs **zero** thread spawns.
    pub spawns: u64,
    /// Configured staging-pipeline depth (resident slot + ring capacity).
    pub ring_depth: usize,
    /// Sum over staged-layer consumes of the armed ring occupancy at
    /// consume time (0 whenever the needed layer was not armed — inline
    /// stagings and all of sync mode).
    pub ring_occupancy_sum: u64,
    /// Number of occupancy samples (one per staged-layer consume).
    pub ring_samples: u64,
}

impl StreamerStats {
    /// Mean armed-ring occupancy observed when layers were consumed:
    /// > 0 means the prefetch pipeline was actually running ahead
    /// (0 for sync staging and resident serving; approaches
    /// `ring_depth - 1` when transfers outpace compute).
    pub fn ring_occupancy_mean(&self) -> f64 {
        if self.ring_samples == 0 {
            0.0
        } else {
            self.ring_occupancy_sum as f64 / self.ring_samples as f64
        }
    }
}

/// Requests the compute side sends to the persistent prefetch worker.
enum StageReq {
    /// Fetch + stage one layer and send it back.
    Stage(usize),
    /// Exit the worker loop (shutdown handshake).
    Shutdown,
}

/// One completed staging, sent back from the worker.
struct StagedResp {
    /// Which layer this response answers (sanity-checked by the receiver).
    layer: usize,
    /// The staged layer, or the fetch/upload failure.
    result: Result<PreparedLayer>,
    /// Worker-side wall time of the fetch + upload.
    staged_s: f64,
}

/// The long-lived staging thread plus its request/response channels.  Up
/// to `depth - 1` requests may be queued at once (the staging ring); the
/// worker serves them strictly in order, so responses arrive FIFO.
struct PrefetchWorker {
    /// `None` after shutdown — dropping the sender also stops the worker.
    req_tx: Option<Sender<StageReq>>,
    resp_rx: Receiver<StagedResp>,
    handle: Option<JoinHandle<()>>,
}

/// Body of the persistent prefetch worker: owns the fetcher ("DDR") and
/// the device runtime handle, serves staging requests until told to stop.
/// A panic inside `fetch`/`stage` drops `resp_tx`, which the compute side
/// observes as a disconnected channel — an error, never a hang.
fn prefetch_worker_loop(
    rt: Arc<Runtime>,
    mut fetcher: Box<dyn LayerFetcher>,
    req_rx: Receiver<StageReq>,
    resp_tx: Sender<StagedResp>,
) {
    while let Ok(StageReq::Stage(li)) = req_rx.recv() {
        let t = Instant::now();
        let result = fetcher.fetch(li).and_then(|host| stage(&rt, host));
        let staged_s = t.elapsed().as_secs_f64();
        if resp_tx.send(StagedResp { layer: li, result, staged_s }).is_err() {
            break; // streamer gone without the handshake; nothing to serve
        }
    }
}

/// Ring-buffered layer streamer over a **persistent prefetch worker**.
///
/// One long-lived thread (spawned at construction) owns the layer fetcher
/// and performs every staging — synchronous stagings block on the worker's
/// reply, asynchronous prefetches are requested early and collected when
/// the layer is needed.  The steady-state decode path therefore performs
/// zero thread spawns: where the previous design spawned and joined one OS
/// thread per staged layer (~`n_layers` spawns per batched step), requests
/// now travel over a channel to the worker spawned once per engine.
///
/// Async mode keeps a **staging ring** of up to `depth - 1` in-flight or
/// ready layers ahead of the resident one ([`Streamer::with_depth`]).
/// The ring always holds a consecutive (wrapping) run of the layers the
/// walk will need next — possibly spanning token boundaries, so layer 0
/// of the *next* token is staged during the current token's tail layers.
/// Any access that breaks the sequence discards the ring wholesale and
/// restarts it.
pub struct Streamer {
    /// Staging schedule ([`SchedMode::Sync`] or [`SchedMode::Async`]).
    pub mode: SchedMode,
    n_layers: usize,
    /// Pipeline depth: 1 resident slot + `depth - 1` ring slots.
    depth: usize,
    current: Option<(usize, PreparedLayer)>,
    /// Layer indices requested from the worker, oldest first (in flight
    /// or already completed and parked in the response channel).
    pending: VecDeque<usize>,
    worker: PrefetchWorker,
    /// Staging counters (time, transfers, bytes, spawns, ring occupancy).
    pub stats: StreamerStats,
}

impl Streamer {
    /// Spawn the prefetch worker and stage layer 0 ("buffers initialized
    /// and loaded at program start", paper §III-B), with the default
    /// double-buffer depth ([`DEFAULT_PREFETCH_DEPTH`]).
    pub fn new(
        rt: Arc<Runtime>,
        fetcher: impl LayerFetcher + 'static,
        mode: SchedMode,
    ) -> Result<Self> {
        Self::with_depth(rt, fetcher, mode, DEFAULT_PREFETCH_DEPTH)
    }

    /// [`Streamer::new`] with an explicit staging-pipeline depth.
    ///
    /// `depth` counts the resident layer plus the ring: depth 2 is the
    /// classic double buffer (today's default), depth 1 disables
    /// prefetching entirely (every staging is inline, even in async
    /// mode), deeper rings absorb transfer-time jitter at the cost of
    /// `depth - 1` staged layers of memory.  Depths beyond `n_layers`
    /// are legal — the ring then spans token boundaries.
    pub fn with_depth(
        rt: Arc<Runtime>,
        fetcher: impl LayerFetcher + 'static,
        mode: SchedMode,
        depth: usize,
    ) -> Result<Self> {
        anyhow::ensure!(depth >= 1, "prefetch depth must be >= 1 (got {depth})");
        let n_layers = fetcher.n_layers();
        anyhow::ensure!(n_layers >= 1, "cannot stream a zero-layer model");
        let (req_tx, req_rx) = channel();
        let (resp_tx, resp_rx) = channel();
        let fetcher: Box<dyn LayerFetcher> = Box::new(fetcher);
        let handle = std::thread::Builder::new()
            .name("llamaf-prefetch".into())
            .spawn(move || prefetch_worker_loop(rt, fetcher, req_rx, resp_tx))
            .expect("spawn prefetch worker");
        let mut s = Streamer {
            mode,
            n_layers,
            depth,
            current: None,
            pending: VecDeque::with_capacity(depth),
            worker: PrefetchWorker { req_tx: Some(req_tx), resp_rx, handle: Some(handle) },
            stats: StreamerStats { spawns: 1, ring_depth: depth, ..StreamerStats::default() },
        };
        s.request(0)?;
        let (l0, staged_s, _wait_s) = s.wait_front()?;
        s.stats.total_transfer_s += staged_s;
        s.stats.transfers += 1;
        s.stats.staged_bytes += l0.host.stream_bytes() as u64;
        s.current = Some((0, l0));
        Ok(s)
    }

    /// Ask the worker to stage layer `li` (non-blocking; queued behind any
    /// earlier ring requests).
    fn request(&mut self, li: usize) -> Result<()> {
        let tx = self
            .worker
            .req_tx
            .as_ref()
            .ok_or_else(|| anyhow!("streamer is shut down"))?;
        tx.send(StageReq::Stage(li))
            .map_err(|_| anyhow!("prefetch worker is gone (staging thread exited)"))?;
        self.pending.push_back(li);
        Ok(())
    }

    /// Block until the *oldest* ring staging completes.  Returns the
    /// staged layer, the worker-side staging seconds, and the seconds
    /// *this* thread spent waiting.  A dead worker (panicked
    /// fetcher/runtime) surfaces as an error here instead of a hang.
    fn wait_front(&mut self) -> Result<(PreparedLayer, f64, f64)> {
        let li = self.pending.pop_front().expect("no staging in flight");
        let t = Instant::now();
        let resp = self
            .worker
            .resp_rx
            .recv()
            .map_err(|_| anyhow!("prefetch worker died while staging layer {li} (panicked?)"))?;
        let wait_s = t.elapsed().as_secs_f64();
        anyhow::ensure!(
            resp.layer == li,
            "prefetch worker answered layer {} for request {li}",
            resp.layer
        );
        Ok((resp.result?, resp.staged_s, wait_s))
    }

    /// Drain the whole ring: every queued staging is received and dropped
    /// (stale after a reset or an out-of-order access).  Discards are not
    /// billed to any counter; a dead worker is tolerated (the next
    /// `request` reports it).
    fn discard_all(&mut self) {
        while self.pending.pop_front().is_some() {
            let _ = self.worker.resp_rx.recv();
        }
    }

    /// Obtain layer `li` for compute.  In async mode this also tops the
    /// staging ring back up with the layers the walk needs next
    /// (wrapping, so layer 0 of the next token is staged during the
    /// current token's tail layers).
    pub fn layer(&mut self, li: usize) -> Result<&PreparedLayer> {
        if li >= self.n_layers {
            bail!("layer {li} out of range ({} layers)", self.n_layers);
        }
        let have = self.current.as_ref().map(|(i, _)| *i);
        if have != Some(li) {
            let armed = self.pending.front() == Some(&li);
            let occ = if armed { self.pending.len() } else { 0 };
            if !armed {
                // the ring does not lead with `li` (out-of-order jump or
                // broken sequence): discard it wholesale and stage `li`
                // inline via the worker
                self.discard_all();
                self.request(li)?;
            }
            self.stats.ring_occupancy_sum += occ as u64;
            self.stats.ring_samples += 1;
            let (lay, staged_s, wait_s) = self.wait_front()?;
            self.stats.blocked_transfer_s += wait_s;
            if armed {
                // the staging ran in the background; we only waited for
                // the remainder (0 when the transfer was fully hidden).
                // Bucket the wait by how full the ring was: waits at high
                // occupancy mean even a full ring cannot hide transfers.
                self.stats.prefetch_wait_s += wait_s;
                self.stats.prefetch_wait_by_occ_s[occ.min(RING_WAIT_BUCKETS - 1)] += wait_s;
            }
            self.stats.total_transfer_s += staged_s;
            self.stats.transfers += 1;
            self.stats.staged_bytes += lay.host.stream_bytes() as u64;
            self.current = Some((li, lay));
        }
        if self.mode == SchedMode::Async && self.worker.req_tx.is_some() {
            self.rearm(li);
        }
        Ok(&self.current.as_ref().expect("staged above").1)
    }

    /// Bring the ring back to "the next `depth - 1` layers after `li`, in
    /// order" (steady-state re-arm after serving layer `li`).
    fn rearm(&mut self, li: usize) {
        self.top_up((li + 1) % self.n_layers);
    }

    /// Make the ring hold the consecutive wrapping run starting at
    /// `first_needed`, up to its `depth - 1` capacity.  A ring that no
    /// longer matches that sequence (a reset or out-of-order access broke
    /// it) is discarded wholesale — otherwise the streamer would silently
    /// degrade to inline staging.  Send failures are deferred: the next
    /// `layer()` that actually needs the worker reports them.  Shared by
    /// [`Streamer::layer`]'s re-arm and [`Streamer::reset`] so the two
    /// paths cannot drift apart.
    fn top_up(&mut self, first_needed: usize) {
        let cap = self.depth - 1;
        if cap == 0 {
            return; // depth 1: inline staging only, nothing to arm
        }
        let mut expect = first_needed;
        let mut consecutive = true;
        for &p in &self.pending {
            if p != expect {
                consecutive = false;
                break;
            }
            expect = (expect + 1) % self.n_layers;
        }
        if !consecutive {
            self.discard_all();
        }
        let mut next = match self.pending.back() {
            Some(&p) => (p + 1) % self.n_layers,
            None => first_needed,
        };
        while self.pending.len() < cap {
            if self.request(next).is_err() {
                break; // dead/shut-down worker: deferred to the next layer()
            }
            next = (next + 1) % self.n_layers;
        }
    }

    /// Rewind for a new generation (engine `reset`).  Drains any ring
    /// contents the post-reset walk cannot use and re-arms the ring from
    /// the layer the next token will need first, so async scheduling
    /// keeps hiding transfers across generations — including resets that
    /// land mid-token.
    pub fn reset(&mut self) {
        if self.mode != SchedMode::Async {
            return; // sync mode stages inline; nothing is in flight
        }
        // If layer 0 is already resident, the next staging needed is layer
        // 1 (layer(0) will not consume the ring); otherwise 0.
        let desired = match self.current {
            Some((0, _)) => 1 % self.n_layers,
            _ => 0,
        };
        // re-point the ring at the post-reset walk: a ring already armed
        // for it (reset on a token boundary) is kept, anything else is
        // drained and re-requested; a dead/shut-down worker never panics
        // a reset (top_up defers send failures to the next layer() call)
        self.top_up(desired);
    }

    /// Shutdown handshake: drain the staging ring, tell the worker to
    /// exit, and join it.  Idempotent; [`Drop`] runs it too.  After
    /// shutdown every `layer()` call fails fast instead of hanging.
    pub fn shutdown(&mut self) {
        self.discard_all();
        if let Some(tx) = self.worker.req_tx.take() {
            let _ = tx.send(StageReq::Shutdown);
        }
        if let Some(h) = self.worker.handle.take() {
            let _ = h.join();
        }
    }

    /// Layer index of the *oldest* ring staging, if any (the next one
    /// `layer()` would consume; test observability).
    pub fn pending_layer(&self) -> Option<usize> {
        self.pending.front().copied()
    }

    /// Number of armed stagings currently in the ring (in flight or
    /// completed and waiting to be consumed).
    pub fn ring_len(&self) -> usize {
        self.pending.len()
    }

    /// Configured staging-pipeline depth (resident slot + ring capacity).
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Number of transformer layers this streamer cycles through.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Lifetime thread spawns (always 1: the persistent worker).  Pinned
    /// by tests so the per-layer spawn/join pattern cannot creep back into
    /// the decode hot path.
    pub fn thread_spawns(&self) -> u64 {
        self.stats.spawns
    }
}

impl crate::engine::forward::LayerProvider for Streamer {
    /// Streamed provision: obtain the staged layer (possibly consuming the
    /// async prefetch) and hand its host copy to the batched forward pass.
    /// One call per (layer, step) regardless of how many lanes are decoded,
    /// which is exactly the ~B× staging reduction of batched decoding.
    fn provide(&mut self, li: usize) -> Result<&QuantLayer> {
        Ok(&Streamer::layer(self, li)?.host)
    }
}

impl Drop for Streamer {
    fn drop(&mut self) {
        // Run the full handshake so no worker thread outlives the
        // streamer or touches PJRT state during process/engine teardown.
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Modeled timelines (paper-scale Fig. 2 / Table VI)
// ---------------------------------------------------------------------------

/// Per-layer modeled times.
#[derive(Clone, Copy, Debug)]
pub struct LayerTimes {
    /// Modeled DDR→PL staging time of one layer's weights.
    pub transfer_s: f64,
    /// Modeled kernel time of one layer's four GQMV launches.
    pub kernel_s: f64,
}

/// Kernel time of one layer = the four GQMV calls (Algorithm 2).
pub fn model_layer_kernel_time(cfg: &LlamaConfig, pl: &PlConfig) -> f64 {
    [MatKind::Qkv, MatKind::Wo, MatKind::W13, MatKind::W2]
        .iter()
        .map(|&k| {
            let (m, n) = cfg.mat_shape(k);
            pl.kernel_time_s(m, n, cfg.gs)
        })
        .sum()
}

/// Modeled per-layer transfer + kernel times.
pub fn model_layer_times(cfg: &LlamaConfig, pl: &PlConfig, axi: &AxiModel) -> LayerTimes {
    LayerTimes {
        transfer_s: axi.staging_time(cfg.layer_stream_bytes()),
        kernel_s: model_layer_kernel_time(cfg, pl),
    }
}

/// Modeled time of one token's *matrix pipeline* (all layers + classifier)
/// under each schedule.  Returns (sync_s, async_s).
pub fn sim_token_time(cfg: &LlamaConfig, pl: &PlConfig, axi: &AxiModel) -> (f64, f64) {
    let lt = model_layer_times(cfg, pl, axi);
    let (mc, nc) = cfg.mat_shape(MatKind::Cls);
    let cls = pl.kernel_time_s(mc, nc, cfg.gs);
    let l = cfg.n_layers as f64;
    // Sync: every layer pays transfer then kernel.
    let sync = l * (lt.transfer_s + lt.kernel_s) + cls;
    // Async: steady state pays max(transfer, kernel) per layer; transfers
    // wrap across tokens so even layer 0 is prefetched.
    let async_ = l * lt.transfer_s.max(lt.kernel_s) + cls;
    (sync, async_)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TINYLLAMA_1_1B;

    #[test]
    fn async_never_slower_in_model() {
        let (sync, async_) =
            sim_token_time(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        assert!(async_ <= sync);
    }

    #[test]
    fn paper_schedule_gain_shape() {
        // Paper: async scheduling gives 55.6-57.9% tok/s improvement over
        // no-scheduling *on the full token time*.  On the matrix pipeline
        // alone the gain is larger; assert the direction and magnitude
        // window here (full-token check lives in exp/table6).
        let (sync, async_) =
            sim_token_time(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        let gain = sync / async_;
        assert!(gain > 1.3 && gain < 2.2, "gain {gain}");
    }

    #[test]
    fn transfer_bound_regime() {
        // TinyLlama staging (~26ms/layer) vs kernel (~20ms/layer): the
        // design is transfer-bound, matching the paper's observation that
        // async hides *kernel-side* stalls (transfer > kernel).
        let lt = model_layer_times(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        assert!(lt.transfer_s > lt.kernel_s * 0.8, "{lt:?}");
        assert!(lt.transfer_s < lt.kernel_s * 2.5, "{lt:?}");
    }

    // Wall-clock Streamer behaviour at scale is covered by rust/tests/
    // integration tests (requires artifacts); prefetch-sequencing
    // regressions are pinned below on the sim runtime.
}

// The sim runtime can be constructed without artifacts (`with_shapes`), so
// the prefetch state machine is testable offline; the PJRT build covers
// the same paths through rust/tests/engine_e2e.rs.
#[cfg(all(test, not(feature = "pjrt")))]
mod streamer_tests {
    use super::*;
    use crate::model::{FloatModel, LlamaConfig, QuantModel};

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 4,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    fn setup(mode: SchedMode) -> (Streamer, Arc<Vec<QuantLayer>>) {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let s = Streamer::new(rt, MemFetcher { layers: Arc::clone(&layers) }, mode).unwrap();
        (s, layers)
    }

    fn assert_layer_is(s: &mut Streamer, li: usize, layers: &[QuantLayer]) {
        let got = s.layer(li).unwrap();
        assert_eq!(got.host.wqkv.q, layers[li].wqkv.q, "layer {li} staged wrong weights");
    }

    #[test]
    fn sequential_walk_keeps_prefetch_one_ahead() {
        let (mut s, layers) = setup(SchedMode::Async);
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
            assert_eq!(s.pending_layer(), Some((li + 1) % 4));
            // repeated access (the engine hits each layer 4x) must not
            // disturb the armed prefetch
            assert_layer_is(&mut s, li, &layers);
            assert_eq!(s.pending_layer(), Some((li + 1) % 4));
        }
        // wrap: next token's layer 0 is already in flight
        assert_layer_is(&mut s, 0, &layers);
    }

    #[test]
    fn wrong_prefetch_discard_rearms_next_layer() {
        let (mut s, layers) = setup(SchedMode::Async);
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), Some(1));
        // out-of-order jump: pending layer 1 is wrong for layer 2 ->
        // inline staging, and the prefetch must re-arm for layer 3
        assert_layer_is(&mut s, 2, &layers);
        assert_eq!(s.pending_layer(), Some(3), "prefetch not re-armed after discard");
        assert_layer_is(&mut s, 3, &layers);
        assert_eq!(s.pending_layer(), Some(0));
    }

    #[test]
    fn stale_pending_on_repeated_layer_is_replaced() {
        let (mut s, layers) = setup(SchedMode::Async);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers); // pending now 2
        s.reset(); // pending re-armed to 0
        assert_eq!(s.pending_layer(), Some(0));
        // current is layer 1; re-requesting it must not leave the stale
        // layer-0 prefetch parked forever
        assert_layer_is(&mut s, 1, &layers);
        assert_eq!(s.pending_layer(), Some(2), "stale pending must be replaced, not kept");
    }

    #[test]
    fn reset_mid_token_prefetches_layer0() {
        let (mut s, layers) = setup(SchedMode::Async);
        // mid-token: stop after layer 1 of a 4-layer model
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        assert_eq!(s.pending_layer(), Some(2));
        s.reset();
        assert_eq!(s.pending_layer(), Some(0), "reset must re-arm staging of layer 0");
        let transfers_before = s.stats.transfers;
        // the new generation consumes the prefetched layer 0 (one transfer,
        // not an extra discarded one) and keeps streaming ahead
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.stats.transfers, transfers_before + 1);
        assert_eq!(s.pending_layer(), Some(1));
        assert_layer_is(&mut s, 1, &layers);
        assert_layer_is(&mut s, 2, &layers);
    }

    #[test]
    fn reset_with_layer0_resident_prefetches_layer1() {
        let (mut s, layers) = setup(SchedMode::Async);
        // fresh streamer: layer 0 staged at construction, nothing pending
        s.reset();
        assert_eq!(s.pending_layer(), Some(1), "layer 0 resident -> stage layer 1");
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), Some(1));
    }

    #[test]
    fn staged_bytes_tracks_every_transfer() {
        let (mut s, layers) = setup(SchedMode::Async);
        let per = layers[0].stream_bytes() as u64;
        assert_eq!(s.stats.staged_bytes, per, "layer 0 staged at construction");
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
            // repeated access must not re-stage
            assert_layer_is(&mut s, li, &layers);
        }
        assert_eq!(s.stats.staged_bytes, s.stats.transfers * per);
        assert_eq!(s.stats.transfers, 4, "one staging per distinct layer");
    }

    #[test]
    fn sync_mode_reset_arms_nothing() {
        let (mut s, layers) = setup(SchedMode::Sync);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        s.reset();
        assert_eq!(s.pending_layer(), None);
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), None);
    }

    /// Fetcher that records which OS thread performs each fetch — the
    /// behavioral probe behind the zero-spawn guarantee.
    struct TidFetcher {
        inner: MemFetcher,
        tids: Arc<std::sync::Mutex<std::collections::HashSet<std::thread::ThreadId>>>,
    }

    impl LayerFetcher for TidFetcher {
        fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
            self.tids.lock().unwrap().insert(std::thread::current().id());
            self.inner.fetch(layer)
        }

        fn n_layers(&self) -> usize {
            self.inner.n_layers()
        }
    }

    #[test]
    fn steady_state_decode_spawns_zero_threads() {
        // The acceptance criterion of the persistent-worker refactor:
        // across a multi-step run (several full layer walks, resets
        // between generations, an out-of-order jump), EVERY staging runs
        // on one long-lived worker thread — reintroducing a per-layer
        // spawn/join pattern would record one fresh ThreadId per staging
        // and fail the distinct-thread assertion below.
        for mode in [SchedMode::Async, SchedMode::Sync] {
            let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
            let layers = Arc::new(qm.layers);
            let tids = Arc::new(std::sync::Mutex::new(std::collections::HashSet::new()));
            let fetcher = TidFetcher {
                inner: MemFetcher { layers: Arc::clone(&layers) },
                tids: Arc::clone(&tids),
            };
            let rt = Arc::new(Runtime::with_shapes(&[]));
            let mut s = Streamer::new(rt, fetcher, mode).unwrap();
            assert_eq!(s.thread_spawns(), 1, "one worker spawned at construction");
            for _gen in 0..3 {
                for li in 0..4 {
                    assert_layer_is(&mut s, li, &layers);
                }
                s.reset();
            }
            assert_layer_is(&mut s, 2, &layers); // out-of-order: inline path
            assert!(s.stats.transfers >= 12, "the walks really staged layers");
            s.shutdown(); // join so no fetch is mid-flight while we read
            let tids = tids.lock().unwrap();
            assert_eq!(
                tids.len(),
                1,
                "all stagings must run on ONE persistent thread ({mode:?}), saw {tids:?}"
            );
            assert!(
                !tids.contains(&std::thread::current().id()),
                "staging must happen off the compute thread ({mode:?})"
            );
            assert_eq!(s.thread_spawns(), 1, "spawn counter stays at the worker ({mode:?})");
        }
    }

    #[test]
    fn shutdown_joins_cleanly_and_fails_fast_after() {
        let (mut s, layers) = setup(SchedMode::Async);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers); // a prefetch is now in flight
        s.shutdown();
        s.shutdown(); // idempotent
        assert_eq!(s.pending_layer(), None, "shutdown discards in-flight staging");
        // the resident layer is still readable (no use-after-shutdown of
        // staged buffers)...
        assert_layer_is(&mut s, 1, &layers);
        // ...but anything needing the worker errors instead of hanging
        let err = s.layer(2).unwrap_err().to_string();
        assert!(err.contains("shut down"), "{err}");
        s.reset(); // must not panic after shutdown
    }

    /// Fetcher that panics when asked for one specific layer — models a
    /// staging-path bug inside the worker.
    struct PanicFetcher {
        layers: Arc<Vec<QuantLayer>>,
        panic_on: usize,
    }

    impl LayerFetcher for PanicFetcher {
        fn fetch(&mut self, layer: usize) -> anyhow::Result<QuantLayer> {
            assert_ne!(layer, self.panic_on, "injected staging panic");
            Ok(self.layers[layer].clone())
        }

        fn n_layers(&self) -> usize {
            self.layers.len()
        }
    }

    #[test]
    fn panicked_worker_surfaces_error_not_hang() {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 43));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = PanicFetcher { layers: Arc::clone(&layers), panic_on: 2 };
        let mut s = Streamer::new(rt, fetcher, SchedMode::Async).unwrap();
        s.layer(0).unwrap(); // arms 1
        s.layer(1).unwrap(); // consumes 1, arms 2 -> worker panics
        let err = s.layer(2).unwrap_err().to_string();
        assert!(err.contains("worker died"), "{err}");
        // every later staging attempt keeps failing fast (worker is gone)
        let err = s.layer(3).unwrap_err().to_string();
        assert!(err.contains("worker"), "{err}");
        s.reset(); // tolerated: reset never panics on a dead worker
    }

    #[test]
    fn worker_panic_during_construction_is_an_error() {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 44));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = PanicFetcher { layers, panic_on: 0 };
        assert!(Streamer::new(rt, fetcher, SchedMode::Sync).is_err());
    }

    // ------------------------------------------------------------------
    // Depth-N staging ring
    // ------------------------------------------------------------------

    fn setup_depth(mode: SchedMode, depth: usize) -> (Streamer, Arc<Vec<QuantLayer>>) {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = MemFetcher { layers: Arc::clone(&layers) };
        let s = Streamer::with_depth(rt, fetcher, mode, depth).unwrap();
        (s, layers)
    }

    #[test]
    fn depth_walks_bit_identical() {
        // depth 1 (inline), 2 (double buffer) and 4 (deep ring) must all
        // hand out exactly the same layer bytes over a multi-generation
        // walk — pipeline depth is a latency knob, never a data path
        for depth in [1usize, 2, 4] {
            let (mut s, layers) = setup_depth(SchedMode::Async, depth);
            assert_eq!(s.depth(), depth);
            for _gen in 0..3 {
                for li in 0..4 {
                    assert_layer_is(&mut s, li, &layers);
                    assert!(s.ring_len() <= depth.saturating_sub(1), "ring over capacity");
                }
                s.reset();
            }
            if depth == 1 {
                assert_eq!(s.pending_layer(), None, "depth 1 must never arm a prefetch");
                assert_eq!(s.stats.ring_occupancy_mean(), 0.0);
            } else {
                assert!(
                    s.stats.ring_occupancy_mean() > 0.0,
                    "depth {depth}: armed consumes must be observed"
                );
            }
        }
    }

    #[test]
    fn deep_ring_runs_ahead_and_wraps_tokens() {
        let (mut s, layers) = setup_depth(SchedMode::Async, 4);
        // first access fills the ring with the NEXT THREE layers
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.ring_len(), 3);
        assert_eq!(s.pending_layer(), Some(1));
        // walking consumes from the front while the tail tops up — across
        // the token boundary (4-layer model: ring after layer 2 holds
        // [3, 0, 1], i.e. next token's head layers)
        assert_layer_is(&mut s, 1, &layers);
        assert_layer_is(&mut s, 2, &layers);
        assert_eq!(s.pending_layer(), Some(3));
        assert_eq!(s.ring_len(), 3);
        assert_layer_is(&mut s, 3, &layers);
        assert_eq!(s.pending_layer(), Some(0), "ring wraps into the next token");
        // second token consumes the wrapped prefetches without re-staging
        let transfers = s.stats.transfers;
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.stats.transfers, transfers + 1, "wrapped prefetch must be consumed");
    }

    #[test]
    fn reset_mid_ring_rearms_cleanly() {
        let (mut s, layers) = setup_depth(SchedMode::Async, 4);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        assert_eq!(s.pending_layer(), Some(2), "ring leads with layer 2 mid-token");
        s.reset();
        // current is layer 1, so the post-reset walk needs 0 first; the
        // stale [2, 3, 0] ring must be drained and re-armed as [0, 1, 2]
        assert_eq!(s.pending_layer(), Some(0), "reset must re-arm the ring at layer 0");
        assert_eq!(s.ring_len(), 3);
        let transfers = s.stats.transfers;
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
        }
        assert_eq!(s.stats.transfers, transfers + 4, "post-reset walk stages each layer once");
    }

    #[test]
    fn reset_preserves_usable_ring() {
        // a reset landing exactly at a token boundary finds the ring
        // already armed for the next token — it must keep it, not thrash
        let (mut s, layers) = setup_depth(SchedMode::Async, 3);
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
        }
        // after layer 3 the ring holds [0, 1] — exactly the post-reset need
        assert_eq!(s.pending_layer(), Some(0));
        let transfers = s.stats.transfers;
        s.reset();
        assert_eq!(s.pending_layer(), Some(0), "usable ring survives reset");
        assert_eq!(s.ring_len(), 2);
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.stats.transfers, transfers + 1, "no extra stagings after no-op reset");
    }

    #[test]
    fn worker_panic_with_full_ring_surfaces_error() {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 45));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = PanicFetcher { layers: Arc::clone(&layers), panic_on: 2 };
        let mut s = Streamer::with_depth(rt, fetcher, SchedMode::Async, 4).unwrap();
        // layer(0) arms [1, 2, 3]; the worker stages 1, then dies on 2
        s.layer(0).unwrap();
        // layer 1 was staged before the panic: still consumable
        s.layer(1).unwrap();
        // layer 2's staging died with the worker: error, never a hang
        let err = s.layer(2).unwrap_err().to_string();
        assert!(err.contains("worker died"), "{err}");
        let err = s.layer(3).unwrap_err().to_string();
        assert!(err.contains("worker"), "{err}");
        s.reset(); // tolerated on a dead worker
        s.shutdown(); // drains whatever the dead worker left behind
    }

    #[test]
    fn invalid_depth_rejected() {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 46));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = MemFetcher { layers };
        assert!(Streamer::with_depth(rt, fetcher, SchedMode::Async, 0).is_err());
    }

    #[test]
    fn ring_wait_accounting_buckets_by_occupancy() {
        let (mut s, layers) = setup_depth(SchedMode::Async, 4);
        for _gen in 0..2 {
            for li in 0..4 {
                assert_layer_is(&mut s, li, &layers);
            }
        }
        let by_occ: f64 = s.stats.prefetch_wait_by_occ_s.iter().sum();
        assert!(
            (by_occ - s.stats.prefetch_wait_s).abs() <= 1e-9,
            "bucketed waits {by_occ} must sum to prefetch_wait_s {}",
            s.stats.prefetch_wait_s
        );
        assert_eq!(s.stats.ring_depth, 4);
        assert!(s.stats.ring_samples >= 7, "every staged consume sampled");
        assert!(s.stats.ring_occupancy_mean() > 0.0);
        assert!(s.stats.ring_occupancy_mean() <= 3.0, "occupancy bounded by ring capacity");
    }
}
