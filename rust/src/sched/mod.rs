//! Task-level weight-streaming scheduler (paper §III-B, Fig. 2).
//!
//! The quantized model lives in "DDR" (the LFQ8 file / an in-memory layer
//! store); only a small number of per-layer buffers exist device-side.
//! For every token, each layer's weights must be staged host→device before
//! its GQMV kernels can run.  Two schedules:
//!
//! * **Sync** — stage layer *l*, then compute layer *l* (Fig. 2 top).
//! * **Async** — while layer *l* computes, a prefetch thread stages layer
//!   *l+1* (wrapping to layer 0 for the next token), hiding the transfer
//!   behind the kernel (Fig. 2 bottom).  First-layer weights are staged at
//!   start-up, exactly as the paper initializes its buffers.
//!
//! The same module also provides the *modeled* timeline
//! ([`sim_token_time`]) used to regenerate Fig. 2 / Table VI at paper
//! scale, where transfer and kernel times come from the AXI and dataflow
//! models rather than wall-clock.

use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::ckpt::Q8LayerSource;
use crate::fpga::{AxiModel, PlConfig};
use crate::model::{LlamaConfig, MatKind, QuantLayer};
use crate::runtime::{DeviceWeights, Runtime};

/// Scheduling policy for weight staging.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Stage layer *l*, then compute layer *l* (Fig. 2 top).
    Sync,
    /// Prefetch layer *l+1* while layer *l* computes (Fig. 2 bottom).
    Async,
}

/// A layer staged on the device: host copies (norm vectors + shapes) plus
/// device-resident GQMV weight buffers.
pub struct PreparedLayer {
    /// Host-side staged copy (norm vectors + the quantized matrices).
    pub host: QuantLayer,
    /// Device buffer of the fused Wq‖Wk‖Wv matrix.
    pub wqkv: DeviceWeights,
    /// Device buffer of Wo.
    pub wo: DeviceWeights,
    /// Device buffer of the fused W1‖W3 matrix.
    pub w13: DeviceWeights,
    /// Device buffer of W2.
    pub w2: DeviceWeights,
}

/// Source of host-side layer weights ("DDR").
pub trait LayerFetcher: Send {
    /// Produce a host copy of layer `layer`'s weights.
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer>;
    /// Number of transformer layers this source serves.
    fn n_layers(&self) -> usize;
}

/// Streams layers from an LFQ8 file (real disk I/O per fetch).
pub struct DiskFetcher {
    src: Q8LayerSource,
}

impl DiskFetcher {
    /// Open an LFQ8 checkpoint for layer-at-a-time streaming.
    pub fn open(path: &std::path::Path) -> Result<Self> {
        Ok(DiskFetcher { src: Q8LayerSource::open(path)? })
    }

    /// Model geometry read from the checkpoint header.
    pub fn cfg(&self) -> LlamaConfig {
        self.src.cfg
    }
}

impl LayerFetcher for DiskFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.src.fetch_layer(layer)
    }

    fn n_layers(&self) -> usize {
        self.src.cfg.n_layers
    }
}

/// Serves layers from memory, cloning on fetch (models the memcpy from the
/// mmap'd model into the pinned kernel buffer — the staging the paper's
/// async schedule hides).
pub struct MemFetcher {
    /// The in-memory layer store shared with the owner of the weights.
    pub layers: Arc<Vec<QuantLayer>>,
}

impl LayerFetcher for MemFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.layers
            .get(layer)
            .cloned()
            .with_context(|| format!("layer {layer} out of range"))
    }

    fn n_layers(&self) -> usize {
        self.layers.len()
    }
}

/// Serves layers out of a shared [`crate::model::QuantModel`]
/// (clone-on-fetch, like
/// [`MemFetcher`], without duplicating the layer store).  This is how the
/// batch scheduler streams weights: the `Arc`'d model *is* the "DDR", and
/// each fetch is the staging memcpy that the async prefetch thread hides
/// behind the batched kernels.
pub struct ModelFetcher {
    /// The shared quantized model whose layers are streamed.
    pub model: Arc<crate::model::QuantModel>,
}

impl LayerFetcher for ModelFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.model
            .layers
            .get(layer)
            .cloned()
            .with_context(|| format!("layer {layer} out of range"))
    }

    fn n_layers(&self) -> usize {
        self.model.layers.len()
    }
}

fn stage(rt: &Runtime, host: QuantLayer) -> Result<PreparedLayer> {
    let wqkv = rt.upload(&host.wqkv)?;
    let wo = rt.upload(&host.wo)?;
    let w13 = rt.upload(&host.w13)?;
    let w2 = rt.upload(&host.w2)?;
    Ok(PreparedLayer { host, wqkv, wo, w13, w2 })
}

/// Double-buffered layer streamer.
pub struct Streamer {
    rt: Arc<Runtime>,
    fetcher: Arc<Mutex<dyn LayerFetcher>>,
    /// Staging schedule ([`SchedMode::Sync`] or [`SchedMode::Async`]).
    pub mode: SchedMode,
    n_layers: usize,
    current: Option<(usize, PreparedLayer)>,
    pending: Option<(usize, JoinHandle<Result<(PreparedLayer, f64)>>)>,
    /// Time the compute thread *blocked* on staging (visible latency).
    pub blocked_transfer_s: f64,
    /// Total staging work performed (foreground + background).
    pub total_transfer_s: f64,
    /// Number of layer stagings performed.
    pub transfers: u64,
    /// Total weight bytes staged host→device (streamed representation:
    /// int8 data + f32 scales + norms).  The batched-decoding win is this
    /// counter growing per *step* instead of per *session-token*.
    pub staged_bytes: u64,
}

impl Streamer {
    /// Create the streamer and stage layer 0 ("buffers initialized and
    /// loaded at program start", paper §III-B).
    pub fn new(
        rt: Arc<Runtime>,
        fetcher: impl LayerFetcher + 'static,
        mode: SchedMode,
    ) -> Result<Self> {
        let n_layers = fetcher.n_layers();
        let mut s = Streamer {
            rt,
            fetcher: Arc::new(Mutex::new(fetcher)),
            mode,
            n_layers,
            current: None,
            pending: None,
            blocked_transfer_s: 0.0,
            total_transfer_s: 0.0,
            transfers: 0,
            staged_bytes: 0,
        };
        let t = Instant::now();
        let l0 = s.fetch_and_stage(0)?;
        s.total_transfer_s += t.elapsed().as_secs_f64();
        s.transfers += 1;
        s.staged_bytes += l0.host.stream_bytes() as u64;
        s.current = Some((0, l0));
        Ok(s)
    }

    fn fetch_and_stage(&self, li: usize) -> Result<PreparedLayer> {
        let host = self.fetcher.lock().unwrap().fetch(li)?;
        stage(&self.rt, host)
    }

    fn spawn_prefetch(&mut self, li: usize) {
        let rt = Arc::clone(&self.rt);
        let fetcher = Arc::clone(&self.fetcher);
        let handle = std::thread::Builder::new()
            .name(format!("llamaf-prefetch-{li}"))
            .spawn(move || {
                let t = Instant::now();
                let host = fetcher.lock().unwrap().fetch(li)?;
                let staged = stage(&rt, host)?;
                Ok((staged, t.elapsed().as_secs_f64()))
            })
            .expect("spawn prefetch thread");
        self.pending = Some((li, handle));
    }

    /// Obtain layer `li` for compute.  In async mode this also kicks off
    /// the prefetch of the *next* layer (wrapping, so layer 0 of the next
    /// token is staged during the current token's last layer).
    pub fn layer(&mut self, li: usize) -> Result<&PreparedLayer> {
        if li >= self.n_layers {
            bail!("layer {li} out of range ({} layers)", self.n_layers);
        }
        let have = self.current.as_ref().map(|(i, _)| *i);
        if have != Some(li) {
            // need to obtain it
            let staged = if let Some((pi, handle)) = self.pending.take() {
                if pi == li {
                    let t = Instant::now();
                    let (lay, bg_s) =
                        handle.join().map_err(|_| anyhow::anyhow!("prefetch panicked"))??;
                    // we only *blocked* for the remaining join time; the
                    // background staging work is billed to total.
                    self.blocked_transfer_s += t.elapsed().as_secs_f64();
                    self.total_transfer_s += bg_s;
                    self.transfers += 1;
                    self.staged_bytes += lay.host.stream_bytes() as u64;
                    lay
                } else {
                    // wrong prefetch (e.g. after reset): discard, fetch inline
                    let _ = handle.join();
                    let t = Instant::now();
                    let lay = self.fetch_and_stage(li)?;
                    let dt = t.elapsed().as_secs_f64();
                    self.blocked_transfer_s += dt;
                    self.total_transfer_s += dt;
                    self.transfers += 1;
                    self.staged_bytes += lay.host.stream_bytes() as u64;
                    lay
                }
            } else {
                let t = Instant::now();
                let lay = self.fetch_and_stage(li)?;
                let dt = t.elapsed().as_secs_f64();
                self.blocked_transfer_s += dt;
                self.total_transfer_s += dt;
                self.transfers += 1;
                self.staged_bytes += lay.host.stream_bytes() as u64;
                lay
            };
            self.current = Some((li, staged));
        }
        if self.mode == SchedMode::Async {
            let next = (li + 1) % self.n_layers;
            // Re-arm the prefetch.  A pending staging for any layer other
            // than `next` is stale (a reset or out-of-order access broke
            // the sequence): discard it and spawn the right one, otherwise
            // the streamer silently degrades to inline (sync) staging for
            // the rest of the run.
            if matches!(&self.pending, Some((pi, _)) if *pi != next) {
                if let Some((_, handle)) = self.pending.take() {
                    let _ = handle.join();
                }
            }
            if self.pending.is_none() {
                self.spawn_prefetch(next);
            }
        }
        Ok(&self.current.as_ref().unwrap().1)
    }

    /// Rewind for a new generation (engine `reset`).  Discards a stale
    /// in-flight prefetch and re-arms the staging of the layer the next
    /// token will need first, so async scheduling keeps hiding transfers
    /// across generations — including resets that land mid-token.
    pub fn reset(&mut self) {
        if self.mode != SchedMode::Async {
            return; // sync mode stages inline; nothing is in flight
        }
        // If layer 0 is already resident, the next staging needed is layer
        // 1 (layer(0) will not consume the pending slot); otherwise 0.
        let desired = match self.current {
            Some((0, _)) => 1 % self.n_layers,
            _ => 0,
        };
        match &self.pending {
            Some((pi, _)) if *pi == desired => {}
            _ => {
                if let Some((_, handle)) = self.pending.take() {
                    let _ = handle.join();
                }
                self.spawn_prefetch(desired);
            }
        }
    }

    /// Layer index of the in-flight prefetch, if any (test observability).
    pub fn pending_layer(&self) -> Option<usize> {
        self.pending.as_ref().map(|(pi, _)| *pi)
    }

    /// Number of transformer layers this streamer cycles through.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }
}

impl crate::engine::forward::LayerProvider for Streamer {
    /// Streamed provision: obtain the staged layer (possibly consuming the
    /// async prefetch) and hand its host copy to the batched forward pass.
    /// One call per (layer, step) regardless of how many lanes are decoded,
    /// which is exactly the ~B× staging reduction of batched decoding.
    fn provide(&mut self, li: usize) -> Result<&QuantLayer> {
        Ok(&Streamer::layer(self, li)?.host)
    }
}

impl Drop for Streamer {
    fn drop(&mut self) {
        // A prefetch may still be in flight; join it so no thread touches
        // PJRT state during process/engine teardown.
        if let Some((_, handle)) = self.pending.take() {
            let _ = handle.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Modeled timelines (paper-scale Fig. 2 / Table VI)
// ---------------------------------------------------------------------------

/// Per-layer modeled times.
#[derive(Clone, Copy, Debug)]
pub struct LayerTimes {
    /// Modeled DDR→PL staging time of one layer's weights.
    pub transfer_s: f64,
    /// Modeled kernel time of one layer's four GQMV launches.
    pub kernel_s: f64,
}

/// Kernel time of one layer = the four GQMV calls (Algorithm 2).
pub fn model_layer_kernel_time(cfg: &LlamaConfig, pl: &PlConfig) -> f64 {
    [MatKind::Qkv, MatKind::Wo, MatKind::W13, MatKind::W2]
        .iter()
        .map(|&k| {
            let (m, n) = cfg.mat_shape(k);
            pl.kernel_time_s(m, n, cfg.gs)
        })
        .sum()
}

/// Modeled per-layer transfer + kernel times.
pub fn model_layer_times(cfg: &LlamaConfig, pl: &PlConfig, axi: &AxiModel) -> LayerTimes {
    LayerTimes {
        transfer_s: axi.staging_time(cfg.layer_stream_bytes()),
        kernel_s: model_layer_kernel_time(cfg, pl),
    }
}

/// Modeled time of one token's *matrix pipeline* (all layers + classifier)
/// under each schedule.  Returns (sync_s, async_s).
pub fn sim_token_time(cfg: &LlamaConfig, pl: &PlConfig, axi: &AxiModel) -> (f64, f64) {
    let lt = model_layer_times(cfg, pl, axi);
    let (mc, nc) = cfg.mat_shape(MatKind::Cls);
    let cls = pl.kernel_time_s(mc, nc, cfg.gs);
    let l = cfg.n_layers as f64;
    // Sync: every layer pays transfer then kernel.
    let sync = l * (lt.transfer_s + lt.kernel_s) + cls;
    // Async: steady state pays max(transfer, kernel) per layer; transfers
    // wrap across tokens so even layer 0 is prefetched.
    let async_ = l * lt.transfer_s.max(lt.kernel_s) + cls;
    (sync, async_)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TINYLLAMA_1_1B;

    #[test]
    fn async_never_slower_in_model() {
        let (sync, async_) = sim_token_time(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        assert!(async_ <= sync);
    }

    #[test]
    fn paper_schedule_gain_shape() {
        // Paper: async scheduling gives 55.6-57.9% tok/s improvement over
        // no-scheduling *on the full token time*.  On the matrix pipeline
        // alone the gain is larger; assert the direction and magnitude
        // window here (full-token check lives in exp/table6).
        let (sync, async_) = sim_token_time(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        let gain = sync / async_;
        assert!(gain > 1.3 && gain < 2.2, "gain {gain}");
    }

    #[test]
    fn transfer_bound_regime() {
        // TinyLlama staging (~26ms/layer) vs kernel (~20ms/layer): the
        // design is transfer-bound, matching the paper's observation that
        // async hides *kernel-side* stalls (transfer > kernel).
        let lt = model_layer_times(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        assert!(lt.transfer_s > lt.kernel_s * 0.8, "{lt:?}");
        assert!(lt.transfer_s < lt.kernel_s * 2.5, "{lt:?}");
    }

    // Wall-clock Streamer behaviour at scale is covered by rust/tests/
    // integration tests (requires artifacts); prefetch-sequencing
    // regressions are pinned below on the sim runtime.
}

// The sim runtime can be constructed without artifacts (`with_shapes`), so
// the prefetch state machine is testable offline; the PJRT build covers
// the same paths through rust/tests/engine_e2e.rs.
#[cfg(all(test, not(feature = "pjrt")))]
mod streamer_tests {
    use super::*;
    use crate::model::{FloatModel, LlamaConfig, QuantModel};

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 4,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    fn setup(mode: SchedMode) -> (Streamer, Arc<Vec<QuantLayer>>) {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let s = Streamer::new(rt, MemFetcher { layers: Arc::clone(&layers) }, mode).unwrap();
        (s, layers)
    }

    fn assert_layer_is(s: &mut Streamer, li: usize, layers: &[QuantLayer]) {
        let got = s.layer(li).unwrap();
        assert_eq!(got.host.wqkv.q, layers[li].wqkv.q, "layer {li} staged wrong weights");
    }

    #[test]
    fn sequential_walk_keeps_prefetch_one_ahead() {
        let (mut s, layers) = setup(SchedMode::Async);
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
            assert_eq!(s.pending_layer(), Some((li + 1) % 4));
            // repeated access (the engine hits each layer 4x) must not
            // disturb the armed prefetch
            assert_layer_is(&mut s, li, &layers);
            assert_eq!(s.pending_layer(), Some((li + 1) % 4));
        }
        // wrap: next token's layer 0 is already in flight
        assert_layer_is(&mut s, 0, &layers);
    }

    #[test]
    fn wrong_prefetch_discard_rearms_next_layer() {
        let (mut s, layers) = setup(SchedMode::Async);
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), Some(1));
        // out-of-order jump: pending layer 1 is wrong for layer 2 ->
        // inline staging, and the prefetch must re-arm for layer 3
        assert_layer_is(&mut s, 2, &layers);
        assert_eq!(s.pending_layer(), Some(3), "prefetch not re-armed after discard");
        assert_layer_is(&mut s, 3, &layers);
        assert_eq!(s.pending_layer(), Some(0));
    }

    #[test]
    fn stale_pending_on_repeated_layer_is_replaced() {
        let (mut s, layers) = setup(SchedMode::Async);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers); // pending now 2
        s.reset(); // pending re-armed to 0
        assert_eq!(s.pending_layer(), Some(0));
        // current is layer 1; re-requesting it must not leave the stale
        // layer-0 prefetch parked forever
        assert_layer_is(&mut s, 1, &layers);
        assert_eq!(s.pending_layer(), Some(2), "stale pending must be replaced, not kept");
    }

    #[test]
    fn reset_mid_token_prefetches_layer0() {
        let (mut s, layers) = setup(SchedMode::Async);
        // mid-token: stop after layer 1 of a 4-layer model
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        assert_eq!(s.pending_layer(), Some(2));
        s.reset();
        assert_eq!(s.pending_layer(), Some(0), "reset must re-arm staging of layer 0");
        let transfers_before = s.transfers;
        // the new generation consumes the prefetched layer 0 (one transfer,
        // not an extra discarded one) and keeps streaming ahead
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.transfers, transfers_before + 1);
        assert_eq!(s.pending_layer(), Some(1));
        assert_layer_is(&mut s, 1, &layers);
        assert_layer_is(&mut s, 2, &layers);
    }

    #[test]
    fn reset_with_layer0_resident_prefetches_layer1() {
        let (mut s, layers) = setup(SchedMode::Async);
        // fresh streamer: layer 0 staged at construction, nothing pending
        s.reset();
        assert_eq!(s.pending_layer(), Some(1), "layer 0 resident -> stage layer 1");
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), Some(1));
    }

    #[test]
    fn staged_bytes_tracks_every_transfer() {
        let (mut s, layers) = setup(SchedMode::Async);
        let per = layers[0].stream_bytes() as u64;
        assert_eq!(s.staged_bytes, per, "layer 0 staged at construction");
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
            // repeated access must not re-stage
            assert_layer_is(&mut s, li, &layers);
        }
        assert_eq!(s.staged_bytes, s.transfers * per);
        assert_eq!(s.transfers, 4, "one staging per distinct layer");
    }

    #[test]
    fn sync_mode_reset_spawns_nothing() {
        let (mut s, layers) = setup(SchedMode::Sync);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        s.reset();
        assert_eq!(s.pending_layer(), None);
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), None);
    }
}
