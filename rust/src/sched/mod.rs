//! Task-level weight-streaming scheduler (paper §III-B, Fig. 2).
//!
//! The quantized model lives in "DDR" (the LFQ8 file / an in-memory layer
//! store); only a small number of per-layer buffers exist device-side.
//! For every token, each layer's weights must be staged host→device before
//! its GQMV kernels can run.  Two schedules:
//!
//! * **Sync** — stage layer *l*, then compute layer *l* (Fig. 2 top).
//! * **Async** — while layer *l* computes, the prefetch worker stages
//!   layer *l+1* (wrapping to layer 0 for the next token), hiding the
//!   transfer behind the kernel (Fig. 2 bottom).  First-layer weights are
//!   staged at start-up, exactly as the paper initializes its buffers.
//!
//! All staging runs on one **persistent prefetch worker** — a long-lived
//! thread owning the fetcher, fed requests over a channel with explicit
//! reset/shutdown handshakes — so steady-state decode performs zero
//! thread spawns (the old design spawned and joined one OS thread per
//! staged layer).
//!
//! The same module also provides the *modeled* timeline
//! ([`sim_token_time`]) used to regenerate Fig. 2 / Table VI at paper
//! scale, where transfer and kernel times come from the AXI and dataflow
//! models rather than wall-clock.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::ckpt::Q8LayerSource;
use crate::fpga::{AxiModel, PlConfig};
use crate::model::{LlamaConfig, MatKind, QuantLayer};
use crate::runtime::{DeviceWeights, Runtime};

/// Scheduling policy for weight staging.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Stage layer *l*, then compute layer *l* (Fig. 2 top).
    Sync,
    /// Prefetch layer *l+1* while layer *l* computes (Fig. 2 bottom).
    Async,
}

/// A layer staged on the device: host copies (norm vectors + shapes) plus
/// device-resident GQMV weight buffers.
pub struct PreparedLayer {
    /// Host-side staged copy (norm vectors + the quantized matrices).
    pub host: QuantLayer,
    /// Device buffer of the fused Wq‖Wk‖Wv matrix.
    pub wqkv: DeviceWeights,
    /// Device buffer of Wo.
    pub wo: DeviceWeights,
    /// Device buffer of the fused W1‖W3 matrix.
    pub w13: DeviceWeights,
    /// Device buffer of W2.
    pub w2: DeviceWeights,
}

/// Source of host-side layer weights ("DDR").
pub trait LayerFetcher: Send {
    /// Produce a host copy of layer `layer`'s weights.
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer>;
    /// Number of transformer layers this source serves.
    fn n_layers(&self) -> usize;
}

/// Streams layers from an LFQ8 file (real disk I/O per fetch).
pub struct DiskFetcher {
    src: Q8LayerSource,
}

impl DiskFetcher {
    /// Open an LFQ8 checkpoint for layer-at-a-time streaming.
    pub fn open(path: &std::path::Path) -> Result<Self> {
        Ok(DiskFetcher { src: Q8LayerSource::open(path)? })
    }

    /// Model geometry read from the checkpoint header.
    pub fn cfg(&self) -> LlamaConfig {
        self.src.cfg
    }
}

impl LayerFetcher for DiskFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.src.fetch_layer(layer)
    }

    fn n_layers(&self) -> usize {
        self.src.cfg.n_layers
    }
}

/// Serves layers from memory, cloning on fetch (models the memcpy from the
/// mmap'd model into the pinned kernel buffer — the staging the paper's
/// async schedule hides).
pub struct MemFetcher {
    /// The in-memory layer store shared with the owner of the weights.
    pub layers: Arc<Vec<QuantLayer>>,
}

impl LayerFetcher for MemFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.layers
            .get(layer)
            .cloned()
            .with_context(|| format!("layer {layer} out of range"))
    }

    fn n_layers(&self) -> usize {
        self.layers.len()
    }
}

/// Serves layers out of a shared [`crate::model::QuantModel`]
/// (clone-on-fetch, like
/// [`MemFetcher`], without duplicating the layer store).  This is how the
/// batch scheduler streams weights: the `Arc`'d model *is* the "DDR", and
/// each fetch is the staging memcpy that the async prefetch thread hides
/// behind the batched kernels.
pub struct ModelFetcher {
    /// The shared quantized model whose layers are streamed.
    pub model: Arc<crate::model::QuantModel>,
}

impl LayerFetcher for ModelFetcher {
    fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
        self.model
            .layers
            .get(layer)
            .cloned()
            .with_context(|| format!("layer {layer} out of range"))
    }

    fn n_layers(&self) -> usize {
        self.model.layers.len()
    }
}

fn stage(rt: &Runtime, host: QuantLayer) -> Result<PreparedLayer> {
    let wqkv = rt.upload(&host.wqkv)?;
    let wo = rt.upload(&host.wo)?;
    let w13 = rt.upload(&host.w13)?;
    let w2 = rt.upload(&host.w2)?;
    Ok(PreparedLayer { host, wqkv, wo, w13, w2 })
}

/// Staging counters of a [`Streamer`] (Fig. 2 accounting plus the serving
/// metrics exported through `STATS`).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamerStats {
    /// Time the compute thread *blocked* on staging (visible latency:
    /// inline stagings plus waits on armed prefetches).
    pub blocked_transfer_s: f64,
    /// Of [`StreamerStats::blocked_transfer_s`], the part spent waiting on
    /// an *armed* (background) prefetch — ~0 when the async schedule hides
    /// transfers fully, rising toward the full staging time when the
    /// design is transfer-bound.
    pub prefetch_wait_s: f64,
    /// Total staging work performed by the worker (foreground +
    /// background).
    pub total_transfer_s: f64,
    /// Number of layer stagings performed.
    pub transfers: u64,
    /// Total weight bytes staged host→device (streamed representation:
    /// int8 data + f32 scales + norms).  The batched-decoding win is this
    /// counter growing per *step* instead of per *session-token*.
    pub staged_bytes: u64,
    /// OS threads this streamer has spawned over its lifetime.  Exactly 1
    /// (the persistent prefetch worker, spawned at construction): the
    /// steady-state decode path performs **zero** thread spawns.
    pub spawns: u64,
}

/// Requests the compute side sends to the persistent prefetch worker.
enum StageReq {
    /// Fetch + stage one layer and send it back.
    Stage(usize),
    /// Exit the worker loop (shutdown handshake).
    Shutdown,
}

/// One completed staging, sent back from the worker.
struct StagedResp {
    /// Which layer this response answers (sanity-checked by the receiver).
    layer: usize,
    /// The staged layer, or the fetch/upload failure.
    result: Result<PreparedLayer>,
    /// Worker-side wall time of the fetch + upload.
    staged_s: f64,
}

/// The long-lived staging thread plus its request/response channels.  At
/// most one request is in flight at a time (double buffering: one layer
/// resident in [`Streamer::current`], one being staged here).
struct PrefetchWorker {
    /// `None` after shutdown — dropping the sender also stops the worker.
    req_tx: Option<Sender<StageReq>>,
    resp_rx: Receiver<StagedResp>,
    handle: Option<JoinHandle<()>>,
}

/// Body of the persistent prefetch worker: owns the fetcher ("DDR") and
/// the device runtime handle, serves staging requests until told to stop.
/// A panic inside `fetch`/`stage` drops `resp_tx`, which the compute side
/// observes as a disconnected channel — an error, never a hang.
fn prefetch_worker_loop(
    rt: Arc<Runtime>,
    mut fetcher: Box<dyn LayerFetcher>,
    req_rx: Receiver<StageReq>,
    resp_tx: Sender<StagedResp>,
) {
    while let Ok(StageReq::Stage(li)) = req_rx.recv() {
        let t = Instant::now();
        let result = fetcher.fetch(li).and_then(|host| stage(&rt, host));
        let staged_s = t.elapsed().as_secs_f64();
        if resp_tx.send(StagedResp { layer: li, result, staged_s }).is_err() {
            break; // streamer gone without the handshake; nothing to serve
        }
    }
}

/// Double-buffered layer streamer over a **persistent prefetch worker**.
///
/// One long-lived thread (spawned at construction) owns the layer fetcher
/// and performs every staging — synchronous stagings block on the worker's
/// reply, asynchronous prefetches are requested early and collected when
/// the layer is needed.  The steady-state decode path therefore performs
/// zero thread spawns: where the previous design spawned and joined one OS
/// thread per staged layer (~`n_layers` spawns per batched step), requests
/// now travel over a channel to the worker spawned once per engine.
pub struct Streamer {
    /// Staging schedule ([`SchedMode::Sync`] or [`SchedMode::Async`]).
    pub mode: SchedMode,
    n_layers: usize,
    current: Option<(usize, PreparedLayer)>,
    /// Layer index of the staging request in flight, if any.
    pending: Option<usize>,
    worker: PrefetchWorker,
    /// Staging counters (time, transfers, bytes, spawns).
    pub stats: StreamerStats,
}

impl Streamer {
    /// Spawn the prefetch worker and stage layer 0 ("buffers initialized
    /// and loaded at program start", paper §III-B).
    pub fn new(
        rt: Arc<Runtime>,
        fetcher: impl LayerFetcher + 'static,
        mode: SchedMode,
    ) -> Result<Self> {
        let n_layers = fetcher.n_layers();
        anyhow::ensure!(n_layers >= 1, "cannot stream a zero-layer model");
        let (req_tx, req_rx) = channel();
        let (resp_tx, resp_rx) = channel();
        let fetcher: Box<dyn LayerFetcher> = Box::new(fetcher);
        let handle = std::thread::Builder::new()
            .name("llamaf-prefetch".into())
            .spawn(move || prefetch_worker_loop(rt, fetcher, req_rx, resp_tx))
            .expect("spawn prefetch worker");
        let mut s = Streamer {
            mode,
            n_layers,
            current: None,
            pending: None,
            worker: PrefetchWorker { req_tx: Some(req_tx), resp_rx, handle: Some(handle) },
            stats: StreamerStats { spawns: 1, ..StreamerStats::default() },
        };
        s.request(0)?;
        let (l0, staged_s, _wait_s) = s.wait_pending()?;
        s.stats.total_transfer_s += staged_s;
        s.stats.transfers += 1;
        s.stats.staged_bytes += l0.host.stream_bytes() as u64;
        s.current = Some((0, l0));
        Ok(s)
    }

    /// Ask the worker to stage layer `li` (non-blocking).
    fn request(&mut self, li: usize) -> Result<()> {
        debug_assert!(self.pending.is_none(), "one staging in flight at a time");
        let tx = self
            .worker
            .req_tx
            .as_ref()
            .ok_or_else(|| anyhow!("streamer is shut down"))?;
        tx.send(StageReq::Stage(li))
            .map_err(|_| anyhow!("prefetch worker is gone (staging thread exited)"))?;
        self.pending = Some(li);
        Ok(())
    }

    /// Block until the in-flight staging completes.  Returns the staged
    /// layer, the worker-side staging seconds, and the seconds *this*
    /// thread spent waiting.  A dead worker (panicked fetcher/runtime)
    /// surfaces as an error here instead of a hang.
    fn wait_pending(&mut self) -> Result<(PreparedLayer, f64, f64)> {
        let li = self.pending.take().expect("no staging in flight");
        let t = Instant::now();
        let resp = self
            .worker
            .resp_rx
            .recv()
            .map_err(|_| anyhow!("prefetch worker died while staging layer {li} (panicked?)"))?;
        let wait_s = t.elapsed().as_secs_f64();
        anyhow::ensure!(
            resp.layer == li,
            "prefetch worker answered layer {} for request {li}",
            resp.layer
        );
        Ok((resp.result?, resp.staged_s, wait_s))
    }

    /// Drop an in-flight staging whose layer is no longer wanted (stale
    /// after a reset or an out-of-order access).  Discards are not billed
    /// to any counter; a dead worker is tolerated (the next `request`
    /// reports it).
    fn discard_pending(&mut self) {
        if self.pending.take().is_some() {
            let _ = self.worker.resp_rx.recv();
        }
    }

    /// Obtain layer `li` for compute.  In async mode this also re-arms
    /// the prefetch of the *next* layer (wrapping, so layer 0 of the next
    /// token is staged during the current token's last layer).
    pub fn layer(&mut self, li: usize) -> Result<&PreparedLayer> {
        if li >= self.n_layers {
            bail!("layer {li} out of range ({} layers)", self.n_layers);
        }
        let have = self.current.as_ref().map(|(i, _)| *i);
        if have != Some(li) {
            let armed = self.pending == Some(li);
            if !armed {
                // wrong staging in flight (e.g. after an out-of-order
                // jump): discard it and stage `li` inline via the worker
                self.discard_pending();
                self.request(li)?;
            }
            let (lay, staged_s, wait_s) = self.wait_pending()?;
            self.stats.blocked_transfer_s += wait_s;
            if armed {
                // the staging ran in the background; we only waited for
                // the remainder (0 when the transfer was fully hidden)
                self.stats.prefetch_wait_s += wait_s;
            }
            self.stats.total_transfer_s += staged_s;
            self.stats.transfers += 1;
            self.stats.staged_bytes += lay.host.stream_bytes() as u64;
            self.current = Some((li, lay));
        }
        if self.mode == SchedMode::Async && self.worker.req_tx.is_some() {
            let next = (li + 1) % self.n_layers;
            // Re-arm the prefetch.  A pending staging for any layer other
            // than `next` is stale (a reset or out-of-order access broke
            // the sequence): discard it and request the right one,
            // otherwise the streamer silently degrades to inline (sync)
            // staging for the rest of the run.  (After shutdown the
            // already-resident layer stays readable; only new stagings
            // fail.)
            if self.pending.is_some() && self.pending != Some(next) {
                self.discard_pending();
            }
            if self.pending.is_none() {
                self.request(next)?;
            }
        }
        Ok(&self.current.as_ref().expect("staged above").1)
    }

    /// Rewind for a new generation (engine `reset`).  Discards a stale
    /// in-flight staging and re-arms the layer the next token will need
    /// first, so async scheduling keeps hiding transfers across
    /// generations — including resets that land mid-token.
    pub fn reset(&mut self) {
        if self.mode != SchedMode::Async {
            return; // sync mode stages inline; nothing is in flight
        }
        // If layer 0 is already resident, the next staging needed is layer
        // 1 (layer(0) will not consume the pending slot); otherwise 0.
        let desired = match self.current {
            Some((0, _)) => 1 % self.n_layers,
            _ => 0,
        };
        if self.pending != Some(desired) {
            self.discard_pending();
            // a dead/shut-down worker must not panic a reset; the next
            // layer() call surfaces the error
            let _ = self.request(desired);
        }
    }

    /// Shutdown handshake: discard any in-flight staging, tell the worker
    /// to exit, and join it.  Idempotent; [`Drop`] runs it too.  After
    /// shutdown every `layer()` call fails fast instead of hanging.
    pub fn shutdown(&mut self) {
        self.discard_pending();
        if let Some(tx) = self.worker.req_tx.take() {
            let _ = tx.send(StageReq::Shutdown);
        }
        if let Some(h) = self.worker.handle.take() {
            let _ = h.join();
        }
    }

    /// Layer index of the in-flight staging request, if any (test
    /// observability).
    pub fn pending_layer(&self) -> Option<usize> {
        self.pending
    }

    /// Number of transformer layers this streamer cycles through.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Lifetime thread spawns (always 1: the persistent worker).  Pinned
    /// by tests so the per-layer spawn/join pattern cannot creep back into
    /// the decode hot path.
    pub fn thread_spawns(&self) -> u64 {
        self.stats.spawns
    }
}

impl crate::engine::forward::LayerProvider for Streamer {
    /// Streamed provision: obtain the staged layer (possibly consuming the
    /// async prefetch) and hand its host copy to the batched forward pass.
    /// One call per (layer, step) regardless of how many lanes are decoded,
    /// which is exactly the ~B× staging reduction of batched decoding.
    fn provide(&mut self, li: usize) -> Result<&QuantLayer> {
        Ok(&Streamer::layer(self, li)?.host)
    }
}

impl Drop for Streamer {
    fn drop(&mut self) {
        // Run the full handshake so no worker thread outlives the
        // streamer or touches PJRT state during process/engine teardown.
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Modeled timelines (paper-scale Fig. 2 / Table VI)
// ---------------------------------------------------------------------------

/// Per-layer modeled times.
#[derive(Clone, Copy, Debug)]
pub struct LayerTimes {
    /// Modeled DDR→PL staging time of one layer's weights.
    pub transfer_s: f64,
    /// Modeled kernel time of one layer's four GQMV launches.
    pub kernel_s: f64,
}

/// Kernel time of one layer = the four GQMV calls (Algorithm 2).
pub fn model_layer_kernel_time(cfg: &LlamaConfig, pl: &PlConfig) -> f64 {
    [MatKind::Qkv, MatKind::Wo, MatKind::W13, MatKind::W2]
        .iter()
        .map(|&k| {
            let (m, n) = cfg.mat_shape(k);
            pl.kernel_time_s(m, n, cfg.gs)
        })
        .sum()
}

/// Modeled per-layer transfer + kernel times.
pub fn model_layer_times(cfg: &LlamaConfig, pl: &PlConfig, axi: &AxiModel) -> LayerTimes {
    LayerTimes {
        transfer_s: axi.staging_time(cfg.layer_stream_bytes()),
        kernel_s: model_layer_kernel_time(cfg, pl),
    }
}

/// Modeled time of one token's *matrix pipeline* (all layers + classifier)
/// under each schedule.  Returns (sync_s, async_s).
pub fn sim_token_time(cfg: &LlamaConfig, pl: &PlConfig, axi: &AxiModel) -> (f64, f64) {
    let lt = model_layer_times(cfg, pl, axi);
    let (mc, nc) = cfg.mat_shape(MatKind::Cls);
    let cls = pl.kernel_time_s(mc, nc, cfg.gs);
    let l = cfg.n_layers as f64;
    // Sync: every layer pays transfer then kernel.
    let sync = l * (lt.transfer_s + lt.kernel_s) + cls;
    // Async: steady state pays max(transfer, kernel) per layer; transfers
    // wrap across tokens so even layer 0 is prefetched.
    let async_ = l * lt.transfer_s.max(lt.kernel_s) + cls;
    (sync, async_)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TINYLLAMA_1_1B;

    #[test]
    fn async_never_slower_in_model() {
        let (sync, async_) =
            sim_token_time(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        assert!(async_ <= sync);
    }

    #[test]
    fn paper_schedule_gain_shape() {
        // Paper: async scheduling gives 55.6-57.9% tok/s improvement over
        // no-scheduling *on the full token time*.  On the matrix pipeline
        // alone the gain is larger; assert the direction and magnitude
        // window here (full-token check lives in exp/table6).
        let (sync, async_) =
            sim_token_time(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        let gain = sync / async_;
        assert!(gain > 1.3 && gain < 2.2, "gain {gain}");
    }

    #[test]
    fn transfer_bound_regime() {
        // TinyLlama staging (~26ms/layer) vs kernel (~20ms/layer): the
        // design is transfer-bound, matching the paper's observation that
        // async hides *kernel-side* stalls (transfer > kernel).
        let lt = model_layer_times(&TINYLLAMA_1_1B, &PlConfig::default(), &AxiModel::default());
        assert!(lt.transfer_s > lt.kernel_s * 0.8, "{lt:?}");
        assert!(lt.transfer_s < lt.kernel_s * 2.5, "{lt:?}");
    }

    // Wall-clock Streamer behaviour at scale is covered by rust/tests/
    // integration tests (requires artifacts); prefetch-sequencing
    // regressions are pinned below on the sim runtime.
}

// The sim runtime can be constructed without artifacts (`with_shapes`), so
// the prefetch state machine is testable offline; the PJRT build covers
// the same paths through rust/tests/engine_e2e.rs.
#[cfg(all(test, not(feature = "pjrt")))]
mod streamer_tests {
    use super::*;
    use crate::model::{FloatModel, LlamaConfig, QuantModel};

    fn tiny_cfg() -> LlamaConfig {
        LlamaConfig {
            dim: 64,
            hidden_dim: 128,
            n_layers: 4,
            n_heads: 2,
            n_kv_heads: 1,
            vocab_size: 64,
            seq_len: 32,
            gs: 32,
        }
    }

    fn setup(mode: SchedMode) -> (Streamer, Arc<Vec<QuantLayer>>) {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let s = Streamer::new(rt, MemFetcher { layers: Arc::clone(&layers) }, mode).unwrap();
        (s, layers)
    }

    fn assert_layer_is(s: &mut Streamer, li: usize, layers: &[QuantLayer]) {
        let got = s.layer(li).unwrap();
        assert_eq!(got.host.wqkv.q, layers[li].wqkv.q, "layer {li} staged wrong weights");
    }

    #[test]
    fn sequential_walk_keeps_prefetch_one_ahead() {
        let (mut s, layers) = setup(SchedMode::Async);
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
            assert_eq!(s.pending_layer(), Some((li + 1) % 4));
            // repeated access (the engine hits each layer 4x) must not
            // disturb the armed prefetch
            assert_layer_is(&mut s, li, &layers);
            assert_eq!(s.pending_layer(), Some((li + 1) % 4));
        }
        // wrap: next token's layer 0 is already in flight
        assert_layer_is(&mut s, 0, &layers);
    }

    #[test]
    fn wrong_prefetch_discard_rearms_next_layer() {
        let (mut s, layers) = setup(SchedMode::Async);
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), Some(1));
        // out-of-order jump: pending layer 1 is wrong for layer 2 ->
        // inline staging, and the prefetch must re-arm for layer 3
        assert_layer_is(&mut s, 2, &layers);
        assert_eq!(s.pending_layer(), Some(3), "prefetch not re-armed after discard");
        assert_layer_is(&mut s, 3, &layers);
        assert_eq!(s.pending_layer(), Some(0));
    }

    #[test]
    fn stale_pending_on_repeated_layer_is_replaced() {
        let (mut s, layers) = setup(SchedMode::Async);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers); // pending now 2
        s.reset(); // pending re-armed to 0
        assert_eq!(s.pending_layer(), Some(0));
        // current is layer 1; re-requesting it must not leave the stale
        // layer-0 prefetch parked forever
        assert_layer_is(&mut s, 1, &layers);
        assert_eq!(s.pending_layer(), Some(2), "stale pending must be replaced, not kept");
    }

    #[test]
    fn reset_mid_token_prefetches_layer0() {
        let (mut s, layers) = setup(SchedMode::Async);
        // mid-token: stop after layer 1 of a 4-layer model
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        assert_eq!(s.pending_layer(), Some(2));
        s.reset();
        assert_eq!(s.pending_layer(), Some(0), "reset must re-arm staging of layer 0");
        let transfers_before = s.stats.transfers;
        // the new generation consumes the prefetched layer 0 (one transfer,
        // not an extra discarded one) and keeps streaming ahead
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.stats.transfers, transfers_before + 1);
        assert_eq!(s.pending_layer(), Some(1));
        assert_layer_is(&mut s, 1, &layers);
        assert_layer_is(&mut s, 2, &layers);
    }

    #[test]
    fn reset_with_layer0_resident_prefetches_layer1() {
        let (mut s, layers) = setup(SchedMode::Async);
        // fresh streamer: layer 0 staged at construction, nothing pending
        s.reset();
        assert_eq!(s.pending_layer(), Some(1), "layer 0 resident -> stage layer 1");
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), Some(1));
    }

    #[test]
    fn staged_bytes_tracks_every_transfer() {
        let (mut s, layers) = setup(SchedMode::Async);
        let per = layers[0].stream_bytes() as u64;
        assert_eq!(s.stats.staged_bytes, per, "layer 0 staged at construction");
        for li in 0..4 {
            assert_layer_is(&mut s, li, &layers);
            // repeated access must not re-stage
            assert_layer_is(&mut s, li, &layers);
        }
        assert_eq!(s.stats.staged_bytes, s.stats.transfers * per);
        assert_eq!(s.stats.transfers, 4, "one staging per distinct layer");
    }

    #[test]
    fn sync_mode_reset_arms_nothing() {
        let (mut s, layers) = setup(SchedMode::Sync);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers);
        s.reset();
        assert_eq!(s.pending_layer(), None);
        assert_layer_is(&mut s, 0, &layers);
        assert_eq!(s.pending_layer(), None);
    }

    /// Fetcher that records which OS thread performs each fetch — the
    /// behavioral probe behind the zero-spawn guarantee.
    struct TidFetcher {
        inner: MemFetcher,
        tids: Arc<std::sync::Mutex<std::collections::HashSet<std::thread::ThreadId>>>,
    }

    impl LayerFetcher for TidFetcher {
        fn fetch(&mut self, layer: usize) -> Result<QuantLayer> {
            self.tids.lock().unwrap().insert(std::thread::current().id());
            self.inner.fetch(layer)
        }

        fn n_layers(&self) -> usize {
            self.inner.n_layers()
        }
    }

    #[test]
    fn steady_state_decode_spawns_zero_threads() {
        // The acceptance criterion of the persistent-worker refactor:
        // across a multi-step run (several full layer walks, resets
        // between generations, an out-of-order jump), EVERY staging runs
        // on one long-lived worker thread — reintroducing a per-layer
        // spawn/join pattern would record one fresh ThreadId per staging
        // and fail the distinct-thread assertion below.
        for mode in [SchedMode::Async, SchedMode::Sync] {
            let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 42));
            let layers = Arc::new(qm.layers);
            let tids = Arc::new(std::sync::Mutex::new(std::collections::HashSet::new()));
            let fetcher = TidFetcher {
                inner: MemFetcher { layers: Arc::clone(&layers) },
                tids: Arc::clone(&tids),
            };
            let rt = Arc::new(Runtime::with_shapes(&[]));
            let mut s = Streamer::new(rt, fetcher, mode).unwrap();
            assert_eq!(s.thread_spawns(), 1, "one worker spawned at construction");
            for _gen in 0..3 {
                for li in 0..4 {
                    assert_layer_is(&mut s, li, &layers);
                }
                s.reset();
            }
            assert_layer_is(&mut s, 2, &layers); // out-of-order: inline path
            assert!(s.stats.transfers >= 12, "the walks really staged layers");
            s.shutdown(); // join so no fetch is mid-flight while we read
            let tids = tids.lock().unwrap();
            assert_eq!(
                tids.len(),
                1,
                "all stagings must run on ONE persistent thread ({mode:?}), saw {tids:?}"
            );
            assert!(
                !tids.contains(&std::thread::current().id()),
                "staging must happen off the compute thread ({mode:?})"
            );
            assert_eq!(s.thread_spawns(), 1, "spawn counter stays at the worker ({mode:?})");
        }
    }

    #[test]
    fn shutdown_joins_cleanly_and_fails_fast_after() {
        let (mut s, layers) = setup(SchedMode::Async);
        assert_layer_is(&mut s, 0, &layers);
        assert_layer_is(&mut s, 1, &layers); // a prefetch is now in flight
        s.shutdown();
        s.shutdown(); // idempotent
        assert_eq!(s.pending_layer(), None, "shutdown discards in-flight staging");
        // the resident layer is still readable (no use-after-shutdown of
        // staged buffers)...
        assert_layer_is(&mut s, 1, &layers);
        // ...but anything needing the worker errors instead of hanging
        let err = s.layer(2).unwrap_err().to_string();
        assert!(err.contains("shut down"), "{err}");
        s.reset(); // must not panic after shutdown
    }

    /// Fetcher that panics when asked for one specific layer — models a
    /// staging-path bug inside the worker.
    struct PanicFetcher {
        layers: Arc<Vec<QuantLayer>>,
        panic_on: usize,
    }

    impl LayerFetcher for PanicFetcher {
        fn fetch(&mut self, layer: usize) -> anyhow::Result<QuantLayer> {
            assert_ne!(layer, self.panic_on, "injected staging panic");
            Ok(self.layers[layer].clone())
        }

        fn n_layers(&self) -> usize {
            self.layers.len()
        }
    }

    #[test]
    fn panicked_worker_surfaces_error_not_hang() {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 43));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = PanicFetcher { layers: Arc::clone(&layers), panic_on: 2 };
        let mut s = Streamer::new(rt, fetcher, SchedMode::Async).unwrap();
        s.layer(0).unwrap(); // arms 1
        s.layer(1).unwrap(); // consumes 1, arms 2 -> worker panics
        let err = s.layer(2).unwrap_err().to_string();
        assert!(err.contains("worker died"), "{err}");
        // every later staging attempt keeps failing fast (worker is gone)
        let err = s.layer(3).unwrap_err().to_string();
        assert!(err.contains("worker"), "{err}");
        s.reset(); // tolerated: reset never panics on a dead worker
    }

    #[test]
    fn worker_panic_during_construction_is_an_error() {
        let qm = QuantModel::from_float(&FloatModel::random(tiny_cfg(), 44));
        let layers = Arc::new(qm.layers);
        let rt = Arc::new(Runtime::with_shapes(&[]));
        let fetcher = PanicFetcher { layers, panic_on: 0 };
        assert!(Streamer::new(rt, fetcher, SchedMode::Sync).is_err());
    }
}
