//! Host-simulated device runtime — the default (no-PJRT) backend.
//!
//! Mirrors the PJRT runtime's contract exactly so the engine, the weight
//! streamer and the experiment harness run unchanged in environments where
//! the `xla` bindings are unavailable (CI, fresh checkouts):
//!
//!   * `load` scans the artifacts dir for `gqmv_m*_n*_g*.hlo.txt` kernels
//!     and registers their shapes (the HLO text itself is not parsed);
//!   * `upload` copies the weight tensor into a [`DeviceWeights`] buffer —
//!     a real memcpy, so staging cost and the sync/async scheduling
//!     behaviour around it stay observable;
//!   * `gqmv_device` executes Algorithm 1 with the same cast chain as the
//!     Pallas kernel, so logits are bit-identical to the CPU backends.
//!
//! Shape bookkeeping (and its error messages) is kept identical to the
//! PJRT path so "missing kernel" failures reproduce without hardware.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::ps::gqmv::{check_shapes, gqmv_row, GqmvExec};
use crate::quant::QuantizedTensor;
use crate::runtime::{parse_kernel_filename, ShapeKey};

/// Weights "resident on the device": a staged host copy of the tensor.
pub struct DeviceWeights {
    wq: Vec<i8>,
    ws: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
    pub gs: usize,
}

/// Simulated device runtime holding one registered shape per GQMV kernel.
pub struct Runtime {
    shapes: Mutex<HashSet<ShapeKey>>,
    artifacts_dir: PathBuf,
    pub gs: usize,
}

impl Runtime {
    /// Register every `gqmv_m*_n*_g*.hlo.txt` kernel in `artifacts_dir`.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let rt = Runtime {
            shapes: Mutex::new(HashSet::new()),
            artifacts_dir: artifacts_dir.to_path_buf(),
            gs: crate::DEFAULT_GS,
        };
        let mut found = 0;
        for entry in std::fs::read_dir(artifacts_dir)
            .with_context(|| format!("reading artifacts dir {artifacts_dir:?}"))?
        {
            let path = entry?.path();
            let name = path.file_name().unwrap_or_default().to_string_lossy().to_string();
            if let Some(key) = parse_kernel_filename(&name) {
                rt.shapes.lock().unwrap().insert(key);
                found += 1;
            }
        }
        if found == 0 {
            bail!("no gqmv_m*_n*_g*.hlo.txt kernels in {artifacts_dir:?}; run `make artifacts`");
        }
        Ok(rt)
    }

    /// Runtime with a fixed shape set and no artifacts directory — for
    /// tests that exercise staging/scheduling without built artifacts.
    pub fn with_shapes(shapes: &[ShapeKey]) -> Self {
        Runtime {
            shapes: Mutex::new(shapes.iter().copied().collect()),
            artifacts_dir: PathBuf::new(),
            gs: crate::DEFAULT_GS,
        }
    }

    /// Platform string — surfaced by `llamaf info`.
    pub fn platform(&self) -> String {
        "cpu-sim".to_string()
    }

    pub fn compiled_shapes(&self) -> Vec<ShapeKey> {
        let mut v: Vec<ShapeKey> = self.shapes.lock().unwrap().iter().copied().collect();
        v.sort_unstable();
        v
    }

    /// Register the kernel for (m, n) on demand if the artifact exists.
    pub fn ensure_shape(&self, m: usize, n: usize) -> Result<()> {
        if self.shapes.lock().unwrap().contains(&(m, n)) {
            return Ok(());
        }
        let fname = format!("gqmv_m{m}_n{n}_g{}.hlo.txt", self.gs);
        let path = self.artifacts_dir.join(&fname);
        if !path.exists() {
            bail!(
                "no compiled kernel for GQMV {m}x{n} and artifact {fname} not found; \
                 re-run `make artifacts` (python -m compile.aot)"
            );
        }
        self.shapes.lock().unwrap().insert((m, n));
        Ok(())
    }

    /// Stage a weight matrix "on the device" — a real copy, so the
    /// transfer the async scheduler overlaps still costs wall-clock time.
    pub fn upload(&self, w: &QuantizedTensor) -> Result<DeviceWeights> {
        Ok(DeviceWeights {
            wq: w.q.clone(),
            ws: w.s.clone(),
            rows: w.rows,
            cols: w.cols,
            gs: w.gs,
        })
    }

    /// Execute GQMV with pre-staged weights — Algorithm 1, bit-exact with
    /// every CPU backend and the Pallas kernel.
    pub fn gqmv_device(
        &self,
        dw: &DeviceWeights,
        xq: &[i8],
        xs: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        anyhow::ensure!(xq.len() == dw.cols, "xq len {} != cols {}", xq.len(), dw.cols);
        anyhow::ensure!(out.len() == dw.rows, "out len {} != rows {}", out.len(), dw.rows);
        anyhow::ensure!(
            self.shapes.lock().unwrap().contains(&(dw.rows, dw.cols)),
            "no compiled kernel for {}x{}",
            dw.rows,
            dw.cols
        );
        let gpr = dw.cols / dw.gs;
        for (i, o) in out.iter_mut().enumerate() {
            *o = gqmv_row(
                xq,
                xs,
                &dw.wq[i * dw.cols..(i + 1) * dw.cols],
                &dw.ws[i * gpr..(i + 1) * gpr],
                dw.gs,
            );
        }
        Ok(())
    }

    /// Split-tensor fused launch: execute a same-input group of pre-staged
    /// matrices as ONE device dispatch over their stacked row space (the
    /// device twin of [`crate::ps::gqmv::GqmvExec::gqmv_fused`]).  Every
    /// output row still comes from the Algorithm-1 cast chain of
    /// [`Runtime::gqmv_device`], so the fused launch is bit-identical to
    /// per-matrix launches by row independence.  On the host simulator the
    /// members simply run back to back (a host "launch" is free); the
    /// PJRT backend amortizes its device-lock round-trips the same way.
    pub fn gqmv_device_fused(
        &self,
        dws: &[&DeviceWeights],
        xq: &[i8],
        xs: &[f32],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        super::drive_fused_launch(dws, outs, |dw, out| self.gqmv_device(dw, xq, xs, out))
    }
}

/// `GqmvExec` adapter that stages weights on every call — models the
/// paper's *unscheduled* path where each kernel launch waits for its
/// weight staging.  The scheduled path keeps `DeviceWeights` ahead of the
/// compute via `sched::Streamer` instead.
pub struct PjrtGqmv<'rt> {
    pub rt: &'rt Runtime,
}

impl GqmvExec for PjrtGqmv<'_> {
    fn gqmv(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) -> Result<()> {
        check_shapes(xq, xs, w, out)?;
        self.rt.ensure_shape(w.rows, w.cols)?;
        let dw = self.rt.upload(w)?;
        self.rt.gqmv_device(&dw, xq, xs, out)
    }

    fn name(&self) -> &'static str {
        "sim-pallas"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ps::ScalarGqmv;
    use crate::quant::quantize_activation;
    use crate::util::Rng;

    #[test]
    fn sim_matches_scalar_backend_bitwise() {
        let rt = Runtime::with_shapes(&[(64, 256)]);
        let mut rng = Rng::new(11);
        let w = QuantizedTensor::from_f32(&rng.normal_vec(64 * 256, 0.3), 64, 256, 256);
        let (xq, xs) = quantize_activation(&rng.normal_vec(256, 1.0), 256);
        let mut expect = vec![0.0f32; 64];
        ScalarGqmv.gqmv(&xq, &xs, &w, &mut expect).unwrap();
        let dw = rt.upload(&w).unwrap();
        let mut got = vec![0.0f32; 64];
        rt.gqmv_device(&dw, &xq, &xs, &mut got).unwrap();
        assert_eq!(got, expect);
    }

    #[test]
    fn unregistered_shape_is_error() {
        let rt = Runtime::with_shapes(&[(64, 256)]);
        let mut rng = Rng::new(12);
        let w = QuantizedTensor::from_f32(&rng.normal_vec(32 * 256, 0.3), 32, 256, 256);
        let dw = rt.upload(&w).unwrap();
        let (xq, xs) = quantize_activation(&rng.normal_vec(256, 1.0), 256);
        let mut out = vec![0.0f32; 32];
        let err = rt.gqmv_device(&dw, &xq, &xs, &mut out).unwrap_err().to_string();
        assert!(err.contains("no compiled kernel"), "{err}");
    }

    #[test]
    fn ensure_shape_without_artifact_mentions_aot() {
        let rt = Runtime::with_shapes(&[]);
        let err = rt.ensure_shape(123, 456).unwrap_err().to_string();
        assert!(err.contains("make artifacts") || err.contains("compile.aot"), "{err}");
    }
}
