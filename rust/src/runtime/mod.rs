//! Device runtime facade — the functional stand-in for the FPGA PL.
//!
//! Two interchangeable backends expose the same API (`Runtime`,
//! `DeviceWeights`, `PjrtGqmv`):
//!
//! * **pjrt** (`--features pjrt`) — loads the AOT-compiled Pallas GQMV
//!   kernels (`artifacts/*.hlo.txt`) through the PJRT C API and executes
//!   them from the decode hot path.  Requires the vendored `xla` bindings.
//! * **sim** (default) — a bit-exact host implementation of the same
//!   contract: staging is a real memcpy, kernels run Algorithm 1 with the
//!   Pallas cast chain.  Keeps the full engine (including the weight
//!   streamer and the serving stack) buildable and testable offline.
//!
//! Either way, weights move host → device via [`Runtime::upload`] — the
//! transfer the async scheduler overlaps — and activations are quantized
//! on the PS each call (tiny: n + n/GS bytes).

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{DeviceWeights, PjrtGqmv, Runtime};

#[cfg(not(feature = "pjrt"))]
mod sim;
#[cfg(not(feature = "pjrt"))]
pub use sim::{DeviceWeights, PjrtGqmv, Runtime};

/// A (rows, cols) GQMV shape key.
pub type ShapeKey = (usize, usize);

/// Drive one fused same-input launch over a group of pre-staged device
/// buffers: validate the group shape, then run `launch` per member.
/// Shared by both runtime backends (`sim` and `pjrt`) so the
/// split-tensor fused-launch contract — and its error message — cannot
/// drift between them.
pub(crate) fn drive_fused_launch<D>(
    dws: &[&D],
    outs: &mut [&mut [f32]],
    mut launch: impl FnMut(&D, &mut [f32]) -> anyhow::Result<()>,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        !dws.is_empty() && dws.len() == outs.len(),
        "malformed fused device group ({} weights, {} outputs)",
        dws.len(),
        outs.len()
    );
    for (dw, out) in dws.iter().copied().zip(outs.iter_mut()) {
        launch(dw, &mut **out)?;
    }
    Ok(())
}

/// Parse `gqmv_m{M}_n{N}_g{GS}.hlo.txt` into (M, N).
pub fn parse_kernel_filename(name: &str) -> Option<ShapeKey> {
    let rest = name.strip_prefix("gqmv_m")?;
    let rest = rest.strip_suffix(".hlo.txt")?;
    let (m_str, rest) = rest.split_once("_n")?;
    let (n_str, _gs) = rest.split_once("_g")?;
    Some((m_str.parse().ok()?, n_str.parse().ok()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filename_parsing() {
        assert_eq!(parse_kernel_filename("gqmv_m512_n256_g256.hlo.txt"), Some((512, 256)));
        assert_eq!(parse_kernel_filename("gqmv_m32000_n2048_g256.hlo.txt"), Some((32000, 2048)));
        assert_eq!(parse_kernel_filename("model.hlo.txt"), None);
        assert_eq!(parse_kernel_filename("gqmv_m512_n256_g256.bin"), None);
        assert_eq!(parse_kernel_filename("gqmv_mXX_n256_g256.hlo.txt"), None);
    }

    // Numeric execution against the python-exported golden fixture and the
    // CPU backends lives in rust/tests/runtime_golden.rs (needs artifacts).
}
