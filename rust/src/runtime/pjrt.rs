//! PJRT runtime — loads the AOT-compiled Pallas GQMV kernels and executes
//! them from the decode hot path.  This is the functional stand-in for the
//! FPGA PL: python lowers the kernels once (`make artifacts`), this module
//! compiles the HLO text at startup and serves per-token GQMV calls.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Data movement mirrors the board:
//!   * weights: host (`QuantizedTensor`, the "DDR model buffer") →
//!     [`DeviceWeights`] PJRT buffers (the "pinned kernel buffer") via
//!     [`Runtime::upload`] — the transfer the async scheduler overlaps;
//!   * activations: quantized on the PS each call, tiny (n + n/GS bytes).
//!
//! Compiled only with `--features pjrt` (requires the vendored `xla`
//! bindings); the default build uses the bit-exact host simulator in
//! [`super::sim`] instead.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::ps::gqmv::{check_shapes, GqmvExec};
use crate::quant::QuantizedTensor;
use crate::runtime::{parse_kernel_filename, ShapeKey};

/// Weights resident on the PJRT device (the PL-side buffer analogue).
pub struct DeviceWeights {
    pub wq: xla::PjRtBuffer,
    pub ws: xla::PjRtBuffer,
    pub rows: usize,
    pub cols: usize,
    pub gs: usize,
}

// SAFETY: PJRT C-API objects are thread-safe (the PJRT specification
// requires clients, buffers and executables to support concurrent use; the
// CPU plugin honors it).  The Rust wrapper types only lack the auto-traits
// because they hold raw pointers.  Buffers are created on one thread
// (async prefetch) and consumed on another, never concurrently mutated.
unsafe impl Send for DeviceWeights {}
unsafe impl Sync for DeviceWeights {}

struct Exe(xla::PjRtLoadedExecutable);
// SAFETY: see DeviceWeights — PJRT executables are thread-safe.
unsafe impl Send for Exe {}
unsafe impl Sync for Exe {}

/// PJRT CPU runtime holding one compiled executable per GQMV shape.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: Mutex<HashMap<ShapeKey, Exe>>,
    /// Serializes all PJRT C-API entry points.  Empirically, xla_extension
    /// 0.5.1's buffer creation racing an execute corrupts the allocator
    /// (observed as `malloc_consolidate` aborts), so uploads and executes
    /// take this lock.  The *host-side* half of staging (disk read +
    /// decode, the dominant cost at real scale) still overlaps compute.
    device: Mutex<()>,
    artifacts_dir: PathBuf,
    pub gs: usize,
}

// SAFETY: see DeviceWeights — the PJRT client is thread-safe.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create a CPU PJRT client and pre-compile every `gqmv_m*_n*_g*.hlo.txt`
    /// found in `artifacts_dir`.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let rt = Runtime {
            client,
            exes: Mutex::new(HashMap::new()),
            device: Mutex::new(()),
            artifacts_dir: artifacts_dir.to_path_buf(),
            gs: crate::DEFAULT_GS,
        };
        let mut found = 0;
        for entry in std::fs::read_dir(artifacts_dir)
            .with_context(|| format!("reading artifacts dir {artifacts_dir:?}"))?
        {
            let path = entry?.path();
            let name = path.file_name().unwrap_or_default().to_string_lossy().to_string();
            if let Some(key) = parse_kernel_filename(&name) {
                rt.compile_file(key, &path)?;
                found += 1;
            }
        }
        if found == 0 {
            bail!("no gqmv_m*_n*_g*.hlo.txt kernels in {artifacts_dir:?}; run `make artifacts`");
        }
        Ok(rt)
    }

    /// Platform string (e.g. "cpu") — surfaced by `llamaf info`.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn compiled_shapes(&self) -> Vec<ShapeKey> {
        let mut v: Vec<ShapeKey> = self.exes.lock().unwrap().keys().copied().collect();
        v.sort_unstable();
        v
    }

    fn compile_file(&self, key: ShapeKey, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?;
        self.exes.lock().unwrap().insert(key, Exe(exe));
        Ok(())
    }

    /// Compile the kernel for (m, n) on demand if the artifact exists.
    pub fn ensure_shape(&self, m: usize, n: usize) -> Result<()> {
        if self.exes.lock().unwrap().contains_key(&(m, n)) {
            return Ok(());
        }
        let fname = format!("gqmv_m{m}_n{n}_g{}.hlo.txt", self.gs);
        let path = self.artifacts_dir.join(&fname);
        if !path.exists() {
            bail!(
                "no compiled kernel for GQMV {m}x{n} and artifact {fname} not found; \
                 re-run `make artifacts` (python -m compile.aot)"
            );
        }
        self.compile_file((m, n), &path)
    }

    /// Upload a weight matrix to the device — the DDR→PL staging transfer.
    pub fn upload(&self, w: &QuantizedTensor) -> Result<DeviceWeights> {
        let _guard = self.device.lock().unwrap();
        let wq = self
            .client
            .buffer_from_host_buffer(&w.q, &[w.rows, w.cols], None)
            .context("uploading wq")?;
        let ws = self
            .client
            .buffer_from_host_buffer(&w.s, &[w.rows, w.groups_per_row()], None)
            .context("uploading ws")?;
        Ok(DeviceWeights { wq, ws, rows: w.rows, cols: w.cols, gs: w.gs })
    }

    /// Execute GQMV with pre-uploaded weights.  The activation (xq, xs) is
    /// uploaded inline — it is tiny and changes every call.
    pub fn gqmv_device(
        &self,
        dw: &DeviceWeights,
        xq: &[i8],
        xs: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        anyhow::ensure!(xq.len() == dw.cols, "xq len {} != cols {}", xq.len(), dw.cols);
        anyhow::ensure!(out.len() == dw.rows, "out len {} != rows {}", out.len(), dw.rows);
        let exes = self.exes.lock().unwrap();
        let exe = exes
            .get(&(dw.rows, dw.cols))
            .with_context(|| format!("no compiled kernel for {}x{}", dw.rows, dw.cols))?;
        let _guard = self.device.lock().unwrap();
        let xq_buf = self
            .client
            .buffer_from_host_buffer(xq, &[xq.len()], None)
            .context("uploading xq")?;
        let xs_buf = self
            .client
            .buffer_from_host_buffer(xs, &[xs.len()], None)
            .context("uploading xs")?;
        // Parameter order matches aot.py: (xq, xs, wq, ws).
        let results = exe.0.execute_b(&[&xq_buf, &xs_buf, &dw.wq, &dw.ws])?;
        let lit = results[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True -> 1-tuple.
        let out_lit = lit.to_tuple1()?;
        let v = out_lit.to_vec::<f32>()?;
        anyhow::ensure!(v.len() == out.len(), "kernel returned {} rows", v.len());
        out.copy_from_slice(&v);
        Ok(())
    }

    /// Split-tensor fused launch: execute a same-input group of pre-staged
    /// matrices back to back as one logical dispatch.  Bit-identical to
    /// per-matrix [`Runtime::gqmv_device`] calls by row independence; a
    /// true single-kernel multi-output launch needs a fused HLO artifact
    /// (tracked in ROADMAP).
    pub fn gqmv_device_fused(
        &self,
        dws: &[&DeviceWeights],
        xq: &[i8],
        xs: &[f32],
        outs: &mut [&mut [f32]],
    ) -> Result<()> {
        super::drive_fused_launch(dws, outs, |dw, out| self.gqmv_device(dw, xq, xs, out))
    }
}

/// `GqmvExec` adapter that uploads weights on every call — models the
/// paper's *unscheduled* path where each kernel launch waits for its
/// weight staging.  The scheduled path keeps `DeviceWeights` ahead of the
/// compute via `sched::Streamer` instead.
pub struct PjrtGqmv<'rt> {
    pub rt: &'rt Runtime,
}

impl GqmvExec for PjrtGqmv<'_> {
    fn gqmv(&mut self, xq: &[i8], xs: &[f32], w: &QuantizedTensor, out: &mut [f32]) -> Result<()> {
        check_shapes(xq, xs, w, out)?;
        self.rt.ensure_shape(w.rows, w.cols)?;
        let dw = self.rt.upload(w)?;
        self.rt.gqmv_device(&dw, xq, xs, out)
    }

    fn name(&self) -> &'static str {
        "pjrt-pallas"
    }
}
